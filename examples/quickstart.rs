//! Quickstart: train the paper's MLP in 16-bit LNS on a small synthetic
//! dataset and compare against the float baseline.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use lnsdnn::coordinator::experiments::{paper_config, run_one, ConfigTag};
use lnsdnn::data::{synth_dataset, SynthSpec};

fn main() {
    // A small MNIST-like task (600 train / 100 test images, 10 classes).
    let ds = synth_dataset(&SynthSpec::mnist_like(0.01, 7));
    println!(
        "dataset: {} — {} train / {} test, {} classes\n",
        ds.name,
        ds.train_len(),
        ds.test_len(),
        ds.classes
    );

    for tag in [ConfigTag::Float, ConfigTag::Log16Lut, ConfigTag::Log16Bs] {
        let cfg = paper_config(&ds, tag, 10, 32, 42);
        let rec = run_one(&ds, tag, &cfg);
        println!(
            "{:<10}  test acc {:.1}%  (final val acc {:.1}%, {:.1}s)",
            tag.label(),
            rec.test_accuracy * 100.0,
            rec.curve.last().map(|e| e.val_accuracy * 100.0).unwrap_or(0.0),
            rec.seconds
        );
    }
    println!("\n16-bit LNS should land within ~1-2 points of float — the");
    println!("paper's headline claim, at laptop scale. Scale up with:");
    println!("  cargo run --release -- table1 --scale 1.0 --epochs 20");
}
