//! Batched inference serving over the AOT artifact (L3 ↔ runtime ↔ L2/L1).
//!
//! Trains a small LNS model natively, exports its parameters into the
//! PJRT forward artifact's input layout, then serves concurrent
//! single-image requests through the dynamic batcher with the *artifact*
//! (not the native engine) executing every batch — Python is nowhere in
//! the serving path. Reports latency/throughput/batch-occupancy.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --example serve_infer [n_requests] [n_clients]
//! ```

use lnsdnn::coordinator::server::BatchServer;
use lnsdnn::data::{synth_dataset, SynthSpec};
use lnsdnn::lns::{LnsConfig, LnsSystem};
use lnsdnn::nn::SgdConfig;
use lnsdnn::runtime::{ArtifactExecutable, ArtifactRegistry, Runtime};
use lnsdnn::tensor::{Backend, LnsBackend};
use lnsdnn::train::{train, TrainConfig};
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let n_clients: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);

    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // 1. Train a model natively (fast, small scale).
    let ds = synth_dataset(&SynthSpec::mnist_like(0.01, 7));
    let backend = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let cfg = TrainConfig {
        dims: vec![784, 100, 10],
        epochs: 6,
        batch_size: 5,
        sgd: SgdConfig { lr: 0.01, weight_decay: 1e-4 },
        val_ratio: 5,
        init: lnsdnn::nn::InitScheme::HeNormal,
        seed: 42,
        shard: Default::default(),
    };
    println!("training serving model natively (log16-lut)…");
    let result = train(&backend, &ds, &cfg);
    println!("  test accuracy {:.1}%", result.test.accuracy * 100.0);

    // 2. Export parameters into the artifact's (m, s)-plane layout.
    let mut plane_params: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
    for layer in &result.model.layers {
        let wm: Vec<i32> = layer.w.data.iter().map(|v| v.m).collect();
        let ws: Vec<i32> = layer.w.data.iter().map(|v| v.s as i32).collect();
        let bm: Vec<i32> = layer.b.iter().map(|v| v.m).collect();
        let bs: Vec<i32> = layer.b.iter().map(|v| v.s as i32).collect();
        plane_params.push((wm, ws));
        plane_params.push((bm, bs));
    }

    // 3. The batch handler: encode pixels → planes, pad to the artifact's
    //    compiled batch (64), execute on PJRT, argmax in the log domain.
    //    PJRT handles live in a thread_local because the batcher worker is
    //    a dedicated thread and the xla wrappers are not Sync.
    let sys = LnsSystem::new(LnsConfig::w16_lut());
    let dims = [784usize, 100, 10];
    let art_batch = 64usize;
    let classes = 10usize;

    thread_local! {
        static EXE: std::cell::OnceCell<(Runtime, ArtifactExecutable)> =
            const { std::cell::OnceCell::new() };
    }

    let sys_h = sys.clone();
    let handler = move |flat: &[u8], n: usize| -> Vec<usize> {
        EXE.with(|cell| {
            let (_rt, exe) = cell.get_or_init(|| {
                let rt = Runtime::cpu().expect("PJRT client");
                let mut reg = ArtifactRegistry::open(&PathBuf::from("artifacts")).unwrap();
                reg.load(&rt, "lns_fwd_w16_lut_paper").unwrap();
                // Re-load to own the executable directly (registry keeps a
                // cache; we want a standalone handle).
                let meta = reg.meta("lns_fwd_w16_lut_paper").unwrap().clone();
                let exe = rt
                    .load_hlo_text(&PathBuf::from("artifacts").join(&meta.file))
                    .unwrap();
                (rt, exe)
            });
            // Encode the batch, pad to the compiled batch size.
            let mut xm = vec![lnsdnn::lns::ZERO_M; art_batch * dims[0]];
            let mut xs = vec![1i32; art_batch * dims[0]];
            for i in 0..n {
                for p in 0..dims[0] {
                    let v = sys_h.encode_f64(flat[i * dims[0] + p] as f64 / 255.0);
                    xm[i * dims[0] + p] = v.m;
                    xs[i * dims[0] + p] = v.s as i32;
                }
            }
            let mut inputs = Vec::new();
            for l in 0..2 {
                let (fi, fo) = (dims[l] as i64, dims[l + 1] as i64);
                let (wm, ws) = &plane_params[2 * l];
                let (bm, bs) = &plane_params[2 * l + 1];
                inputs.push(ArtifactExecutable::lit_i32(wm, &[fi, fo]).unwrap());
                inputs.push(ArtifactExecutable::lit_i32(ws, &[fi, fo]).unwrap());
                inputs.push(ArtifactExecutable::lit_i32(bm, &[fo]).unwrap());
                inputs.push(ArtifactExecutable::lit_i32(bs, &[fo]).unwrap());
            }
            inputs.push(
                ArtifactExecutable::lit_i32(&xm, &[art_batch as i64, dims[0] as i64]).unwrap(),
            );
            inputs.push(
                ArtifactExecutable::lit_i32(&xs, &[art_batch as i64, dims[0] as i64]).unwrap(),
            );
            let out = exe.run(&inputs).expect("artifact execution");
            let lm: Vec<i32> = out[0].to_vec().unwrap();
            let ls: Vec<i32> = out[1].to_vec().unwrap();
            (0..n)
                .map(|i| {
                    let mut best = 0usize;
                    let val = |j: usize| {
                        lnsdnn::lns::LnsValue::new(lm[i * classes + j], ls[i * classes + j] == 1)
                    };
                    for j in 1..classes {
                        if sys_h.gt(val(j), val(best)) {
                            best = j;
                        }
                    }
                    best
                })
                .collect()
        })
    };

    // 4. Serve concurrent clients; measure.
    println!(
        "serving {n_requests} requests from {n_clients} clients (batch ≤ {art_batch}, wait 2ms)…"
    );
    let server = BatchServer::start(art_batch, Duration::from_millis(2), 784, handler);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    let per_client = n_requests / n_clients;
    for c in 0..n_clients {
        let client = server.client();
        let imgs: Vec<Vec<u8>> = (0..per_client)
            .map(|i| {
                let idx = (c * per_client + i) % ds.test_len();
                ds.test_images[idx * 784..(idx + 1) * 784].to_vec()
            })
            .collect();
        let labels: Vec<u8> = (0..per_client)
            .map(|i| ds.test_labels[(c * per_client + i) % ds.test_len()])
            .collect();
        handles.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            for (img, &lbl) in imgs.into_iter().zip(&labels) {
                let reply = client.infer(img).expect("reply");
                if reply.class == lbl as usize {
                    correct += 1;
                }
            }
            correct
        }));
    }
    let correct: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed();
    let stats = server.stats();
    println!("\n== serving report ==");
    println!("  served        {} requests in {:.2}s", stats.served, wall.as_secs_f64());
    println!("  throughput    {:.0} req/s", stats.served as f64 / wall.as_secs_f64());
    println!("  mean latency  {:.2} ms", stats.mean_latency().as_secs_f64() * 1e3);
    println!("  max latency   {:.2} ms", stats.max_latency.as_secs_f64() * 1e3);
    println!("  batches       {} (mean occupancy {:.1})", stats.batches, stats.mean_batch());
    println!("  accuracy      {:.1}%  (native-trained model, PJRT-served)",
        100.0 * correct as f64 / (per_client * n_clients) as f64);
    drop(server);
    let _ = backend.decode(result.model.layers[0].b[0]);
}
