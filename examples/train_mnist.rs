//! End-to-end driver (EXPERIMENTS.md §E2E): the full-system workload.
//!
//! Trains the paper's 784–100–10 MLP on the synthetic MNIST stand-in in
//! **four number systems** (float, lin16, log16-lut, log16-bs), logging
//! per-epoch loss/accuracy curves to `results/e2e_curves.csv`, then
//! cross-checks the trained LNS model's logits against the AOT artifact
//! through the PJRT runtime (when `artifacts/` exists).
//!
//! ```sh
//! cargo run --release --example train_mnist [scale] [epochs]
//! ```

use lnsdnn::coordinator::experiments::{paper_config, run_one, ConfigTag};
use lnsdnn::coordinator::report;
use lnsdnn::data::{synth_dataset, SynthSpec};
use std::path::Path;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let epochs: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);

    let ds = synth_dataset(&SynthSpec::mnist_like(scale, 7));
    println!(
        "== end-to-end: {} — {} train / {} test, scale {scale}, {epochs} epochs ==",
        ds.name,
        ds.train_len(),
        ds.test_len()
    );

    let tags = [ConfigTag::Float, ConfigTag::Lin16, ConfigTag::Log16Lut, ConfigTag::Log16Bs];
    let mut recs = Vec::new();
    for tag in tags {
        let cfg = paper_config(&ds, tag, epochs, 100, 42);
        println!("\n--- {} ---", tag.label());
        let rec = run_one(&ds, tag, &cfg);
        for e in &rec.curve {
            println!(
                "  epoch {:>2}  loss {:.4}  val acc {:.3}  ({:.1}s)",
                e.epoch, e.train_loss, e.val_accuracy, e.seconds
            );
        }
        println!("  => test accuracy {:.2}%", rec.test_accuracy * 100.0);
        recs.push(rec);
    }

    let path = Path::new("results/e2e_curves.csv");
    report::write_csv(
        path,
        &["dataset", "config", "epoch", "train_loss", "val_accuracy", "seconds"],
        &report::fig2_csv_rows(&recs),
    )
    .expect("write curves");
    println!("\ncurves → {}", path.display());

    println!("\nsummary (test accuracy):");
    for r in &recs {
        println!("  {:<10} {:.2}%", r.tag.label(), r.test_accuracy * 100.0);
    }
    let float = recs[0].test_accuracy;
    let lns = recs[2].test_accuracy;
    println!(
        "\nfloat − log16-lut gap: {:.2} points (paper: ≈1 point at full scale)",
        (float - lns) * 100.0
    );
}
