//! Tour of the LNS arithmetic API (the paper's §2–3 machinery):
//! encoding, multiplier-free ⊡/⊞, the Δ approximations, error behaviour,
//! and the Eq. 15 bit-width analysis.
//!
//! ```sh
//! cargo run --release --example lns_arithmetic
//! ```

use lnsdnn::lns::{
    delta_minus_exact, delta_plus_exact, min_log_bits, DeltaMode, LnsConfig, LnsSystem, LutSpec,
};
use lnsdnn::rng::SplitMix64;

fn main() {
    let sys = LnsSystem::new(LnsConfig::w16_lut());
    println!("=== 16-bit LNS word (q_i=4, q_f=10, LUT Δ) ===\n");

    // Encoding: v ↔ (log2|v| in fixed point, sign).
    for v in [3.0, -0.5, 1024.0, 0.01] {
        let x = sys.encode_f64(v);
        let dec = sys.decode_f64(x);
        println!("  encode({v:>8}) = (m={:>6}, s={})   decode → {dec:.6}", x.m, x.s as u8);
    }

    // Multiplication is exact (integer add of magnitudes).
    let a = sys.encode_f64(6.25);
    let b = sys.encode_f64(-0.8);
    let prod = sys.decode_f64(sys.mul(a, b));
    println!("\n  6.25 ⊡ -0.8  = {prod:.6}   (exact in log domain: adds magnitudes)");
    println!("  6.25 ÷ -0.8  = {:.6}   (division equally exact)", sys.decode_f64(sys.div(a, b)));

    // Addition is approximate: max + Δ±(d).
    println!("\n  Δ approximations at d = 1.0:");
    let (dp, dm) = (delta_plus_exact(1.0), delta_minus_exact(1.0));
    println!("    exact   Δ+ = {dp:+.4}   Δ− = {dm:+.4}");
    let cfg = sys.config();
    let d = cfg.to_units(1.0);
    println!(
        "    LUT(20) Δ+ = {:+.4}   Δ− = {:+.4}",
        cfg.from_units(sys.delta().plus(d) as i32),
        cfg.from_units(sys.delta().minus(d) as i32)
    );
    let bs = LnsSystem::new(LnsConfig::w16_bitshift());
    println!(
        "    bitshift Δ+ = {:+.4}   Δ− = {:+.4}   (Eq. 9: ±2^-d, −1.5·2^-d)",
        cfg.from_units(bs.delta().plus(d) as i32),
        cfg.from_units(bs.delta().minus(d) as i32)
    );

    // Statistical error of ⊞ over random operands, per Δ mode.
    println!("\n  mean |relative error| of x ⊞ y over 100k random pairs:");
    for (label, mode) in [
        ("exact Δ", DeltaMode::Exact),
        ("LUT d_max=10 r=1/2 (paper MAC)", DeltaMode::Lut(LutSpec::MAC20)),
        ("LUT d_max=10 r=1/64 (paper softmax)", DeltaMode::Lut(LutSpec::SOFTMAX640)),
        ("bit-shift", DeltaMode::BitShift),
    ] {
        let mut cfg = LnsConfig::w16_lut();
        cfg.delta = mode;
        cfg.softmax_delta = mode;
        let s = LnsSystem::new(cfg);
        let mut rng = SplitMix64::new(1);
        let (mut err_sum, mut n) = (0.0, 0u64);
        for _ in 0..100_000 {
            let x = rng.uniform(-8.0, 8.0);
            let y = rng.uniform(-8.0, 8.0);
            if (x + y).abs() < 1e-3 {
                continue;
            }
            let z = s.decode_f64(s.add(s.encode_f64(x), s.encode_f64(y)));
            err_sum += ((z - (x + y)) / (x + y)).abs();
            n += 1;
        }
        println!("    {:<36} {:.4}", label, err_sum / n as f64);
    }

    // Eq. 15: worst-case log-domain width for linear-equivalent precision.
    println!("\n=== Eq. 15 bit-width bound ===");
    for (bi, bf) in [(4u32, 7u32), (4, 11), (4, 19)] {
        let wlin = 1 + bi + bf;
        println!("  W_lin = {wlin:>2} (b_i={bi}, b_f={bf})  →  W_log ≥ {}", min_log_bits(bi, bf));
    }
    println!("\n(The paper's experiments — `table1` — show W_log ≈ W_lin suffices in practice.)");
}
