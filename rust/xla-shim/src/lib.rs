//! Compile-smoke shim of the `xla` bindings' API surface used by
//! `lnsdnn`'s PJRT runtime (see this package's Cargo.toml for the full
//! rationale).
//!
//! Contract: everything **type-checks** exactly like the real bindings at
//! the call sites in `lnsdnn::runtime`, `tests/pjrt_roundtrip.rs`,
//! `benches/pjrt_e2e.rs` and `examples/serve_infer.rs`; the [`Literal`]
//! value plumbing is genuinely functional (so literal-level unit tests
//! pass), while every path that would need a real PJRT client fails at
//! runtime with an error naming the swap-in procedure.

#![forbid(unsafe_code)]

use std::fmt;

/// Shim error: carries a human-readable message, convertible into
/// `anyhow::Error` at the lnsdnn call sites via `std::error::Error`.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn shim(what: &str) -> Error {
        Error(format!(
            "xla shim: {what} requires the real xla bindings — repoint the `xla` \
             dependency in rust/Cargo.toml from rust/xla-shim at a real \
             xla-rs/xla_extension install and rebuild with --features pjrt"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Shim result alias (mirrors the real crate).
pub type Result<T> = std::result::Result<T, Error>;

#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// 32-bit integer plane (the LNS m/s planes).
    I32(Vec<i32>),
    /// 32-bit float plane (loss/logit outputs).
    F32(Vec<f32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::I32(v) => v.len(),
            Payload::F32(v) => v.len(),
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for i32 {}
    impl Sealed for f32 {}
}

/// Element types the shim's [`Literal`] can hold (the two lnsdnn uses).
pub trait NativeType: sealed::Sealed + Copy {
    #[doc(hidden)]
    fn into_payload(data: Vec<Self>) -> Payload;
    #[doc(hidden)]
    fn from_payload(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    fn into_payload(data: Vec<i32>) -> Payload {
        Payload::I32(data)
    }
    fn from_payload(p: &Payload) -> Option<Vec<i32>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            Payload::F32(_) => None,
        }
    }
}

impl NativeType for f32 {
    fn into_payload(data: Vec<f32>) -> Payload {
        Payload::F32(data)
    }
    fn from_payload(p: &Payload) -> Option<Vec<f32>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            Payload::I32(_) => None,
        }
    }
}

/// Host-side typed array — the one shim type with real behaviour, so
/// literal construction helpers and their unit tests work unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], payload: T::into_payload(data.to_vec()) }
    }

    /// Same data, new shape; errors when the element counts differ.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.payload.len() {
            return Err(Error(format!(
                "xla shim: cannot reshape {} elements to {dims:?}",
                self.payload.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    /// Number of elements.
    pub fn element_count(&self) -> usize {
        self.payload.len()
    }

    /// Shape (diagnostics).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy out as a typed host vector; errors on an element-type
    /// mismatch, like the real bindings.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_payload(&self.payload)
            .ok_or_else(|| Error("xla shim: literal element type mismatch".into()))
    }

    /// Decompose a tuple literal. The shim never constructs tuples (they
    /// only arise from real executions), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::shim("tuple literals (execution results)"))
    }
}

/// Parsed HLO module handle. Construction requires the real parser.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file — real bindings only.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::shim("parsing HLO text"))
    }
}

/// Computation wrapper over a parsed module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Wrap a parsed module (trivially constructible: the proto itself
    /// can only come from the real parser).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device-side buffer returned by an execution; never constructed by the
/// shim.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Device→host transfer — real bindings only.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::shim("device-to-host transfer"))
    }
}

/// Compiled executable handle; never constructed by the shim.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host inputs — real bindings only.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::shim("artifact execution"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the shim, so
/// the runtime's error path (not a silent wrong answer) is what users of
/// a shim build hit.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Bring up the CPU client — real bindings only.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::shim("the PJRT CPU client"))
    }

    /// Platform name (unreachable: no constructor succeeds).
    pub fn platform_name(&self) -> String {
        "xla-shim".into()
    }

    /// Device count (unreachable, as above).
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation — real bindings only.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::shim("HLO compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_and_reshapes() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        assert_eq!(l.element_count(), 6);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(r.to_vec::<f32>().is_err(), "type mismatch must error");
        assert!(l.reshape(&[4, 2]).is_err(), "bad element count must error");
        let f = Literal::vec1(&[0.5f32, 1.5]);
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![0.5, 1.5]);
    }

    #[test]
    fn execution_paths_fail_with_swap_in_hint() {
        let err = match PjRtClient::cpu() {
            Err(e) => e.to_string(),
            Ok(_) => panic!("shim client must not come up"),
        };
        assert!(err.contains("rust/xla-shim"), "unhelpful shim error: {err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }
}
