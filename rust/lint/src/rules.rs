//! The lexical numerics rules (docs/NUMERICS.md §10).
//!
//! Each rule is a pass over the token stream of one file. Paths are
//! relative to `rust/` (so `src/lns/system.rs`, `tests/lane_exactness.rs`).
//! The **value path** — the modules whose arithmetic the bit-exactness
//! contract covers — is `src/{lns,fixed,tensor,nn,train}/`. Code inside
//! `#[cfg(test)]` mods is exempt everywhere: tests may compare against
//! float references, time things, and unwrap freely.
//!
//! A finding is suppressed by a waiver pragma on the same line or the
//! line above: `// numerics-lint: allow(<rule>) — <reason>`. A waiver
//! without a reason is itself reported.

use crate::lexer::{analyze, is_float_literal, lex, Analysis, Pragma};

/// One diagnostic. `file` is whatever path the caller handed in (the
/// tree walker passes repo-relative paths so terminals can link them).
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// The rule names a pragma may waive.
pub const RULES: [&str; 6] = [
    "float-leak",
    "regrouping",
    "nondeterminism",
    "atomics",
    "hostile-input",
    "contract-drift",
];

/// Module prefixes whose arithmetic is contract-covered.
pub const VALUE_PATH: [&str; 5] =
    ["src/lns/", "src/fixed/", "src/tensor/", "src/nn/", "src/train/"];

/// Files where float arithmetic is the *point* and already documented:
/// the float reference backend, config/unit conversion (`log2(x)·2^F` at
/// the boundary), Δ LUT construction, reporting/statistics, and the wire
/// format's f32 lane (§6 carries IEEE bits, it does not compute on them).
pub const FLOAT_ALLOW_FILES: [&str; 10] = [
    "src/tensor/backend.rs",
    "src/tensor/autotune.rs",
    "src/lns/config.rs",
    "src/lns/delta.rs",
    "src/lns/cost.rs",
    "src/lns/analysis.rs",
    "src/lns/linconv.rs",
    "src/nn/init.rs",
    "src/train/metrics.rs",
    "src/train/wire.rs",
];

/// Any fn whose name carries these markers converts to/from the float
/// domain by design (`decode_f64`, `to_f32`, …).
pub const FLOAT_ALLOW_FN_SUBSTR: [&str; 2] = ["_f64", "_f32"];

/// Exact `(file, fn)` pairs allowed to touch floats: constructors that
/// encode f64 *configuration* into the backend domain, and report/stat
/// helpers that leave the value path on purpose.
pub const FLOAT_ALLOW_FNS: [(&str, &str); 9] = [
    ("src/fixed/mod.rs", "unit"),
    ("src/lns/system.rs", "new"),
    ("src/nn/sgd.rs", "default"),
    ("src/train/mod.rs", "paper"),
    ("src/train/mod.rs", "lenet"),
    ("src/train/mod.rs", "mean"),
    ("src/train/multiproc.rs", "default"),
    ("src/train/multiproc.rs", "act_probe"),
    ("src/nn/grad.rs", "finish"),
];

/// Value-path files exempt from the nondeterminism scan: the autotuner
/// is timing-driven by nature and perf-only by contract (§2).
pub const NONDET_ALLOW_FILES: [&str; 1] = ["src/tensor/autotune.rs"];

const PAR_ITERS: [&str; 6] =
    ["par_iter", "into_par_iter", "par_iter_mut", "par_chunks", "par_chunks_mut", "par_bridge"];
const REDUCERS: [&str; 3] = ["sum", "reduce", "fold"];
const NONDET_TYPES: [&str; 5] =
    ["HashMap", "HashSet", "RandomState", "DefaultHasher", "thread_rng"];
const PANIC_MACROS: [&str; 7] =
    ["panic", "unreachable", "todo", "unimplemented", "assert", "assert_eq", "assert_ne"];
/// Keywords that may legitimately precede `[` without being an indexing
/// base (`as [u8; 4]` never parses, but stay conservative).
const NOT_INDEX_BASE: [&str; 14] = [
    "as", "in", "return", "mut", "ref", "else", "match", "if", "while", "box", "dyn", "impl",
    "where", "move",
];

fn covered(pragmas: &[Pragma], rule: &str, line: usize) -> bool {
    pragmas.iter().any(|p| p.rule == rule && (p.line == line || p.line + 1 == line))
}

fn fn_name(a: &Analysis, i: usize) -> &str {
    a.fn_of[i].as_deref().unwrap_or("<module scope>")
}

/// Run every lexical rule over one file. `rel` is the path relative to
/// `rust/`.
pub fn lint_source(rel: &str, text: &str) -> Vec<Violation> {
    let (toks, pragmas) = lex(text);
    let a = analyze(&toks);
    let n = toks.len();
    let is_value = VALUE_PATH.iter().any(|p| rel.starts_with(p));
    let mut viol: Vec<Violation> = Vec::new();

    // Waiver hygiene: a pragma naming an unknown rule is a typo that
    // would silently fail to waive; a pragma without a reason defeats
    // the audit trail. Both are reported at the pragma itself.
    for p in &pragmas {
        if !RULES.contains(&p.rule.as_str()) {
            viol.push(Violation {
                file: rel.to_string(),
                line: p.line,
                rule: "pragma",
                msg: format!("waiver names unknown rule `{}`", p.rule),
            });
        } else if p.reason.is_empty() {
            viol.push(Violation {
                file: rel.to_string(),
                line: p.line,
                rule: "pragma",
                msg: format!("waiver for `{}` has no reason — say why the site is sound", p.rule),
            });
        }
    }

    let mut push = |viol: &mut Vec<Violation>, rule: &'static str, line: usize, msg: String| {
        if !covered(&pragmas, rule, line) {
            viol.push(Violation { file: rel.to_string(), line, rule, msg });
        }
    };

    // ------------------------------------------------------ float-leak
    if is_value && !FLOAT_ALLOW_FILES.contains(&rel) {
        for i in 0..n {
            if a.in_test[i] {
                continue;
            }
            if let Some(f) = a.fn_of[i].as_deref() {
                if FLOAT_ALLOW_FN_SUBSTR.iter().any(|s| f.contains(s)) {
                    continue;
                }
                if FLOAT_ALLOW_FNS.contains(&(rel, f)) {
                    continue;
                }
            }
            let t = toks[i].text.as_str();
            if t == "as" && i + 1 < n && (toks[i + 1].text == "f32" || toks[i + 1].text == "f64") {
                push(
                    &mut viol,
                    "float-leak",
                    toks[i].line,
                    format!("cast `as {}` in `{}`", toks[i + 1].text, fn_name(&a, i)),
                );
            } else if (t == "f32" || t == "f64") && i + 1 < n && toks[i + 1].text == "::" {
                let tail = if i + 2 < n { toks[i + 2].text.as_str() } else { "" };
                push(
                    &mut viol,
                    "float-leak",
                    toks[i].line,
                    format!("float path `{}::{}` in `{}`", t, tail, fn_name(&a, i)),
                );
            } else if is_float_literal(t) {
                push(
                    &mut viol,
                    "float-leak",
                    toks[i].line,
                    format!("float literal `{}` in `{}`", t, fn_name(&a, i)),
                );
            }
        }
    }

    // ------------------------------------------------------ regrouping
    if is_value {
        for i in 0..n {
            if a.in_test[i] {
                continue;
            }
            let t = toks[i].text.as_str();
            if PAR_ITERS.contains(&t) {
                let mut j = i;
                while j < n && toks[j].text != ";" && j < i + 120 {
                    if j > 0
                        && REDUCERS.contains(&toks[j].text.as_str())
                        && toks[j - 1].text == "."
                    {
                        push(
                            &mut viol,
                            "regrouping",
                            toks[j].line,
                            format!("parallel reduction `{}…{}` regroups ⊞ (§2)", t, toks[j].text),
                        );
                        break;
                    }
                    j += 1;
                }
            }
        }
    }

    // -------------------------------------------------- nondeterminism
    if is_value && !NONDET_ALLOW_FILES.contains(&rel) {
        for i in 0..n {
            if a.in_test[i] {
                continue;
            }
            let t = toks[i].text.as_str();
            if NONDET_TYPES.contains(&t) {
                push(
                    &mut viol,
                    "nondeterminism",
                    toks[i].line,
                    format!("`{}` in `{}` — iteration order is ambient", t, fn_name(&a, i)),
                );
            }
            if (t == "Instant" || t == "SystemTime")
                && i + 2 < n
                && toks[i + 1].text == "::"
                && toks[i + 2].text == "now"
            {
                push(
                    &mut viol,
                    "nondeterminism",
                    toks[i].line,
                    format!("`{}::now` in `{}`", t, fn_name(&a, i)),
                );
            }
        }
    }

    // ---------------------------------------------------------- atomics
    if rel.starts_with("src/") {
        for i in 0..n {
            if a.in_test[i] {
                continue;
            }
            if toks[i].text == "Ordering" && i + 2 < n && toks[i + 1].text == "::" {
                let ord = toks[i + 2].text.as_str();
                if rel.starts_with("src/obs/") && ord == "Relaxed" {
                    continue;
                }
                push(
                    &mut viol,
                    "atomics",
                    toks[i].line,
                    format!("`Ordering::{}` outside obs/ needs a waiver (§7)", ord),
                );
            }
        }
    }

    // ---------------------------------------------------- hostile-input
    if rel == "src/train/wire.rs" {
        // Indexing into a fn-local fixed array (`let buf = [0u8; N]; … buf[i]`)
        // is driven by our own constants, not the wire — collect those names.
        let mut local_arrays: Vec<(String, String)> = Vec::new();
        for i in 0..n {
            if toks[i].text == "let" {
                let mut j = i + 1;
                if j < n && toks[j].text == "mut" {
                    j += 1;
                }
                if j + 2 < n {
                    let name = toks[j].text.as_str();
                    let c0 = name.as_bytes()[0];
                    if (c0.is_ascii_alphabetic() || c0 == b'_')
                        && toks[j + 1].text == "="
                        && toks[j + 2].text == "["
                    {
                        local_arrays
                            .push((a.fn_of[i].clone().unwrap_or_default(), name.to_string()));
                    }
                }
            }
        }
        let in_decode = |i: usize| -> bool {
            match a.fn_of[i].as_deref() {
                None => false,
                Some(f) => {
                    f.starts_with("read")
                        || f.starts_with("decode")
                        || f.starts_with("from_")
                        || f == "take"
                        || a.impl_of[i].as_deref().map_or(false, |imp| imp.contains("ByteReader"))
                }
            }
        };
        for i in 0..n {
            if a.in_test[i] || !in_decode(i) {
                continue;
            }
            let t = toks[i].text.as_str();
            if (t == "unwrap" || t == "expect") && i > 0 && toks[i - 1].text == "." {
                push(
                    &mut viol,
                    "hostile-input",
                    toks[i].line,
                    format!("`.{}()` in decode fn `{}` (§6)", t, fn_name(&a, i)),
                );
            } else if PANIC_MACROS.contains(&t) && i + 1 < n && toks[i + 1].text == "!" {
                push(
                    &mut viol,
                    "hostile-input",
                    toks[i].line,
                    format!("`{}!` in decode fn `{}` — return WireError (§6)", t, fn_name(&a, i)),
                );
            } else if t == "[" && i > 0 {
                let p = toks[i - 1].text.as_str();
                let c0 = p.as_bytes()[0];
                let indexable =
                    c0.is_ascii_alphabetic() || c0 == b'_' || p == ")" || p == "]" || p == "?";
                if indexable && !NOT_INDEX_BASE.contains(&p) {
                    let owner = a.fn_of[i].clone().unwrap_or_default();
                    let is_local = local_arrays.iter().any(|(f, nm)| *f == owner && nm == p);
                    if !is_local {
                        push(
                            &mut viol,
                            "hostile-input",
                            toks[i].line,
                            format!("slice index after `{}` in `{}` (§6)", p, fn_name(&a, i)),
                        );
                    }
                }
            }
        }
    }

    viol
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(rel: &str, src: &str) -> Vec<String> {
        lint_source(rel, src).into_iter().map(|v| v.rule.to_string()).collect()
    }

    #[test]
    fn float_leak_positive_and_negative() {
        let bad = "fn f(x: i64) -> i64 { let y = x as f64; let z = 0.5; f64::to_bits(z); x }";
        let got = rules_of("src/lns/fixture.rs", bad);
        assert_eq!(got, ["float-leak", "float-leak", "float-leak"]);
        // same text outside the value path is fine
        assert!(rules_of("src/obs/fixture.rs", bad).is_empty());
        // clean integer math is fine
        assert!(rules_of("src/lns/fixture.rs", "fn f(x: i64) -> i64 { x + 1 }").is_empty());
    }

    #[test]
    fn float_leak_exemptions() {
        // `_f64` marker fns convert by design
        let conv = "fn decode_f64(x: u32) -> f64 { x as f64 }";
        assert!(rules_of("src/lns/fixture.rs", conv).is_empty());
        // cfg(test) mods may float freely
        let tested = "fn live(x: i64) -> i64 { x }
#[cfg(test)]
mod tests { fn t() { let y = 0.5; } }";
        assert!(rules_of("src/lns/fixture.rs", tested).is_empty());
        // allowlisted files may float
        assert!(rules_of("src/lns/delta.rs", "fn lut() -> f64 { 0.5 }").is_empty());
    }

    #[test]
    fn regrouping_positive_and_negative() {
        let bad = "fn f(v: &[u64]) -> u64 { v.par_iter().map(|x| x + 1).sum() }";
        assert_eq!(rules_of("src/tensor/fixture.rs", bad), ["regrouping"]);
        let ok = "fn f(v: &mut [u64]) { v.par_iter_mut().for_each(|x| *x += 1); }";
        assert!(rules_of("src/tensor/fixture.rs", ok).is_empty());
    }

    #[test]
    fn nondeterminism_positive_and_negative() {
        let bad = "fn f() { let m: HashMap<u32, u32> = HashMap::new(); let t = Instant::now(); }";
        assert_eq!(
            rules_of("src/train/fixture.rs", bad),
            ["nondeterminism", "nondeterminism", "nondeterminism"]
        );
        // outside the value path: fine
        assert!(rules_of("src/coordinator/fixture.rs", bad).is_empty());
        let ok = "fn f() { let m: BTreeMap<u32, u32> = BTreeMap::new(); }";
        assert!(rules_of("src/train/fixture.rs", ok).is_empty());
    }

    #[test]
    fn atomics_positive_and_negative() {
        let site = "fn f(a: &AtomicU64) { a.store(1, Ordering::SeqCst); }";
        assert_eq!(rules_of("src/tensor/fixture.rs", site), ["atomics"]);
        // Relaxed inside obs/ is the sanctioned pattern
        let relaxed = "fn f(a: &AtomicU64) { a.store(1, Ordering::Relaxed); }";
        assert!(rules_of("src/obs/fixture.rs", relaxed).is_empty());
        // …but SeqCst in obs/ still needs a waiver
        assert_eq!(rules_of("src/obs/fixture.rs", site), ["atomics"]);
    }

    #[test]
    fn hostile_input_decode_scope() {
        let bad = "impl<'a> ByteReader<'a> {
fn u8(&mut self) -> u8 { self.buf[0] }
}
fn decode_x(b: &[u8]) -> u8 { b.first().unwrap() }";
        let got = rules_of("src/train/wire.rs", bad);
        assert_eq!(got, ["hostile-input", "hostile-input"]);
        // same code in any other file: out of scope
        assert!(rules_of("src/train/fixture.rs", bad).is_empty());
        // helper fns outside decode scope are out of scope
        let helper = "fn checksum(v: &[u8]) -> u8 { v[0] }";
        assert!(rules_of("src/train/wire.rs", helper).is_empty());
        // indexing a fn-local fixed array is our constant, not the wire's
        let local = "fn read_header(r: &mut R) -> u8 { let mut h = [0u8; 4]; h[1] }";
        assert!(rules_of("src/train/wire.rs", local).is_empty());
    }

    #[test]
    fn pragma_waives_and_requires_reason() {
        let waived = "fn f(x: i64) -> i64 {
// numerics-lint: allow(float-leak) — fixture justification
let y = x as f64;
x }";
        assert!(rules_of("src/lns/fixture.rs", waived).is_empty());
        // a waiver with no reason is itself flagged (and does still waive)
        let bare = "fn f(x: i64) -> i64 {
// numerics-lint: allow(float-leak)
let y = x as f64;
x }";
        assert_eq!(rules_of("src/lns/fixture.rs", bare), ["pragma"]);
        // a waiver for the wrong rule does not suppress the finding
        let wrong = "fn f(x: i64) -> i64 {
// numerics-lint: allow(atomics) — wrong rule
let y = x as f64;
x }";
        assert_eq!(rules_of("src/lns/fixture.rs", wrong), ["float-leak"]);
        // unknown rule names are typo-guarded
        let typo = "// numerics-lint: allow(float-leek) — oops\nfn f() {}";
        assert_eq!(rules_of("src/obs/fixture.rs", typo), ["pragma"]);
    }
}
