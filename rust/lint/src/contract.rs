//! Contract-drift checks: keep docs/NUMERICS.md §9 and the code honest
//! with each other.
//!
//! Two directions of drift are caught:
//!
//! * **§9 table → tree.** Every backticked reference in the §9
//!   clause→test table must resolve: file paths (`tests/foo.rs`,
//!   `train/shard.rs`) must exist under `rust/`, and bare identifiers
//!   (`accumulate_slots`, `occupancy_snapshots_are_deterministic`) must
//!   appear as a token in the most recent file referenced on the same
//!   table row. Renaming a pinned test without updating the table — or
//!   pointing the table at a test that no longer exists — fails CI.
//! * **Scalar twins → pins.** Every `fn *_scalar` reference kernel in
//!   `src/lns/system.rs` and `src/fixed/mod.rs` must be exercised by
//!   name in `tests/lane_exactness.rs`; a lane kernel whose scalar twin
//!   loses its exactness pin is an unguarded ⊞ chain.
//!
//! Both checks take the file set as data (`&[(path, contents)]`, paths
//! relative to `rust/`) so the self-tests can feed fixtures without
//! touching the filesystem.

use crate::lexer::lex;
use crate::rules::Violation;

/// Does `name` appear in `src` as a whole token (not as a substring of a
/// longer identifier)? Comments count — a pin named only in a comment is
/// caught by the test run itself going red, not by this linter.
fn contains_token(src: &str, name: &str) -> bool {
    let sb = src.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = src[start..].find(name) {
        let p = start + pos;
        let before_ok = p == 0 || {
            let c = sb[p - 1];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        let e = p + name.len();
        let after_ok = e >= sb.len() || {
            let c = sb[e];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        if before_ok && after_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

fn backticked(line: &str) -> Vec<&str> {
    line.split('`').enumerate().filter(|(i, _)| i % 2 == 1).map(|(_, s)| s).collect()
}

fn lookup<'a>(files: &'a [(String, String)], rel: &str) -> Option<&'a str> {
    files.iter().find(|(p, _)| p == rel).map(|(_, s)| s.as_str())
}

fn is_ident(s: &str) -> bool {
    let b = s.as_bytes();
    !b.is_empty()
        && (b[0].is_ascii_alphabetic() || b[0] == b'_')
        && b.iter().all(|&c| c.is_ascii_alphanumeric() || c == b'_')
}

/// Check the §9 clause→test table of `md` (the NUMERICS.md text) against
/// the file set. Violations anchor to `docs/NUMERICS.md` lines.
pub fn check_contract(md: &str, files: &[(String, String)]) -> Vec<Violation> {
    let mut viol = Vec::new();
    let mut in_sec9 = false;
    for (idx, raw) in md.lines().enumerate() {
        let lineno = idx + 1;
        let line = raw.trim();
        if line.starts_with("## ") {
            in_sec9 = line.starts_with("## 9");
            continue;
        }
        if !in_sec9 || !line.starts_with('|') {
            continue;
        }
        // Identifiers bind to the nearest path reference earlier in the
        // same row: "`train/shard.rs` (`accumulate_slots` tests)".
        let mut row_file: Option<String> = None;
        for span in backticked(line) {
            if span.contains('/') && span.ends_with(".rs") {
                let rel = if span.starts_with("tests/") {
                    span.to_string()
                } else {
                    format!("src/{}", span)
                };
                if lookup(files, &rel).is_none() {
                    viol.push(Violation {
                        file: "docs/NUMERICS.md".to_string(),
                        line: lineno,
                        rule: "contract-drift",
                        msg: format!("§9 pins `{}` but rust/{} does not exist", span, rel),
                    });
                    row_file = None;
                } else {
                    row_file = Some(rel);
                }
            } else if is_ident(span) {
                match row_file.as_deref().and_then(|rel| lookup(files, rel).map(|s| (rel, s))) {
                    None => viol.push(Violation {
                        file: "docs/NUMERICS.md".to_string(),
                        line: lineno,
                        rule: "contract-drift",
                        msg: format!("§9 names `{}` but no file earlier in the row resolves", span),
                    }),
                    Some((rel, src)) => {
                        if !contains_token(src, span) {
                            viol.push(Violation {
                                file: "docs/NUMERICS.md".to_string(),
                                line: lineno,
                                rule: "contract-drift",
                                msg: format!("§9 names `{}` but rust/{} lacks it", span, rel),
                            });
                        }
                    }
                }
            }
        }
    }
    viol
}

/// Every `fn *_scalar` in the lane-kernel modules must be named in
/// `tests/lane_exactness.rs`.
pub fn check_scalar_twins(files: &[(String, String)]) -> Vec<Violation> {
    let mut viol = Vec::new();
    let pin_src = lookup(files, "tests/lane_exactness.rs");
    for twin_file in ["src/lns/system.rs", "src/fixed/mod.rs"] {
        let Some(src) = lookup(files, twin_file) else { continue };
        let (toks, _) = lex(src);
        for w in 0..toks.len().saturating_sub(1) {
            if toks[w].text == "fn" && toks[w + 1].text.ends_with("_scalar") {
                let name = toks[w + 1].text.as_str();
                let pinned = pin_src.map_or(false, |s| contains_token(s, name));
                if !pinned {
                    viol.push(Violation {
                        file: format!("rust/{}", twin_file),
                        line: toks[w].line,
                        rule: "contract-drift",
                        msg: format!("scalar twin `{}` has no pin in lane_exactness.rs", name),
                    });
                }
            }
        }
    }
    viol
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fx(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
        pairs.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect()
    }

    const MD: &str = "## 9. Where each clause is pinned\n\n\
        | Clause | Test |\n|--------|------|\n\
        | §2 | `tests/good.rs` |\n\
        | §3 | `train/shard.rs` (`accumulate_slots` tests) |\n\n\
        ## 10. Something else\n\n| `tests/ignored.rs` |\n";

    #[test]
    fn intact_table_is_clean() {
        let files = fx(&[
            ("tests/good.rs", "fn pin() {}"),
            ("src/train/shard.rs", "fn accumulate_slots() {}"),
        ]);
        assert!(check_contract(MD, &files).is_empty());
    }

    #[test]
    fn missing_file_and_renamed_fn_are_drift() {
        // `tests/good.rs` gone → drift; `accumulate_slots` renamed → drift
        let files = fx(&[("src/train/shard.rs", "fn accumulate_slots_v2() {}")]);
        let got = check_contract(MD, &files);
        assert_eq!(got.len(), 2, "{:?}", got);
        assert!(got.iter().all(|v| v.rule == "contract-drift"));
        assert!(got[0].msg.contains("tests/good.rs"));
        assert!(got[1].msg.contains("accumulate_slots"));
    }

    #[test]
    fn rows_outside_section_9_are_ignored() {
        // `tests/ignored.rs` is referenced under §10 and does not exist,
        // but only §9 rows are contract rows.
        let files = fx(&[
            ("tests/good.rs", "x"),
            ("src/train/shard.rs", "accumulate_slots"),
        ]);
        assert!(check_contract(MD, &files).is_empty());
    }

    #[test]
    fn token_matching_is_boundary_aware() {
        assert!(contains_token("call accumulate_slots here", "accumulate_slots"));
        assert!(!contains_token("call accumulate_slots_v2 here", "accumulate_slots"));
        assert!(contains_token("x.mac_row_scalar(k)", "mac_row_scalar"));
    }

    #[test]
    fn scalar_twin_without_pin_is_drift() {
        let files = fx(&[
            ("src/lns/system.rs", "fn mac_row(a: u8) {}\nfn mac_row_scalar(a: u8) {}"),
            ("tests/lane_exactness.rs", "fn pins() { mac_row(); }"),
        ]);
        let got = check_scalar_twins(&files);
        assert_eq!(got.len(), 1, "{:?}", got);
        assert!(got[0].msg.contains("mac_row_scalar"));
        assert_eq!(got[0].line, 2);
    }

    #[test]
    fn pinned_scalar_twin_is_clean() {
        let files = fx(&[
            ("src/lns/system.rs", "fn mac_row_scalar(a: u8) {}"),
            ("tests/lane_exactness.rs", "fn pins() { mac_row_scalar(); }"),
        ]);
        assert!(check_scalar_twins(&files).is_empty());
    }
}
