//! `numerics-lint` CLI — blocking CI gate for docs/NUMERICS.md.
//!
//! Usage: `numerics-lint [repo-root]`. With no argument the repository
//! root is found by walking up from the current directory looking for
//! `docs/NUMERICS.md` next to `rust/src` (so `cargo run -p numerics-lint`
//! works from anywhere inside the workspace).
//!
//! Exit codes: 0 clean, 1 violations (one `file:line: [rule] message`
//! per line on stdout), 2 usage or I/O error.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

fn find_repo_root(start: PathBuf) -> Option<PathBuf> {
    let mut d = start;
    loop {
        if d.join("docs").join("NUMERICS.md").is_file() && d.join("rust").join("src").is_dir() {
            return Some(d);
        }
        if !d.pop() {
            return None;
        }
    }
}

fn main() -> ExitCode {
    let root = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        None => match std::env::current_dir().ok().and_then(find_repo_root) {
            Some(r) => r,
            None => {
                eprintln!(
                    "numerics-lint: no repo root found (want docs/NUMERICS.md beside rust/src); \
                     pass the root as the first argument"
                );
                return ExitCode::from(2);
            }
        },
    };
    match numerics_lint::lint_tree(&root) {
        Err(e) => {
            eprintln!("numerics-lint: failed to read the tree: {}", e);
            ExitCode::from(2)
        }
        Ok(viol) if viol.is_empty() => {
            eprintln!("numerics-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(viol) => {
            for v in &viol {
                println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
            }
            eprintln!(
                "numerics-lint: {} violation(s) — fix the site or waive it with \
                 `// numerics-lint: allow(<rule>) — <reason>` (NUMERICS.md §10)",
                viol.len()
            );
            ExitCode::FAILURE
        }
    }
}
