//! A small hand-rolled Rust lexer — just enough structure for the
//! numerics rules.
//!
//! The lexer strips comments and string/char literals, and emits a flat
//! stream of tokens (identifiers, numeric literals, `::`, and single-char
//! punctuation) each tagged with its 1-based source line. It is *not* a
//! full Rust lexer: the rules in [`crate::rules`] only need token
//! adjacency (`as f64`, `Ordering :: Relaxed`, `. unwrap`), so anything
//! fancier would be wasted precision. What it does get right, because the
//! rules depend on it:
//!
//! * nested block comments (`/* /* */ */`);
//! * raw and byte strings (`r#"…"#`, `br"…"`, `b"…"`);
//! * string escapes, including the `\<newline>` line-continuation (which
//!   must still count the newline so diagnostics stay line-accurate);
//! * lifetimes (`'a`) vs char literals (`'x'`, `'\n'`);
//! * numeric literals: `0..4` must lex as `0`, `.`, `.`, `4` (the dot
//!   only joins a literal when a digit follows), `1e-3` is one token,
//!   and `0x1E` is hex, not scientific notation;
//! * `// numerics-lint: allow(<rule>) — <reason>` waiver pragmas, which
//!   are collected out of comments with their line numbers.

/// One lexed token: its text and 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    pub line: usize,
}

/// A `// numerics-lint: allow(<rule>) — <reason>` waiver found in a
/// comment. A pragma covers findings on its own line and on the line
/// immediately below (the usual "pragma above the offending statement"
/// placement).
#[derive(Debug, Clone)]
pub struct Pragma {
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

fn utf8_width(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xC0 {
        1 // stray continuation byte; advance one so we cannot loop
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

fn parse_pragma(comment: &str, line: usize) -> Option<Pragma> {
    let at = comment.find("numerics-lint:")?;
    let rest = comment[at + "numerics-lint:".len()..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let rule = &rest[..close];
    if rule.is_empty()
        || !rule
            .bytes()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'-')
    {
        return None;
    }
    let reason = rest[close + 1..]
        .trim()
        .trim_start_matches(|c: char| c == '—' || c == '-' || c == ':' || c == ' ')
        .trim()
        .to_string();
    Some(Pragma { line, rule: rule.to_string(), reason })
}

/// Lex `text`, returning the token stream and every waiver pragma.
pub fn lex(text: &str) -> (Vec<Tok>, Vec<Pragma>) {
    let b = text.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut pragmas = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment (and pragma harvesting)
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let j = b[i..].iter().position(|&x| x == b'\n').map_or(n, |p| i + p);
            if let Some(p) = parse_pragma(&text[i..j], line) {
                pragmas.push(p);
            }
            i = j;
            continue;
        }
        // block comment, nesting like Rust's
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw / raw-byte strings: r"…", r#"…"#, br"…", br##"…"##
        {
            let mut k = i;
            if b[k] == b'b' {
                k += 1;
            }
            if k < n && b[k] == b'r' {
                let mut h = k + 1;
                let mut hashes = 0usize;
                while h < n && b[h] == b'#' {
                    hashes += 1;
                    h += 1;
                }
                if h < n && b[h] == b'"' {
                    let mut j = h + 1;
                    while j < n {
                        if b[j] == b'\n' {
                            line += 1;
                        }
                        if b[j] == b'"' {
                            let mut m = 0usize;
                            while m < hashes && j + 1 + m < n && b[j + 1 + m] == b'#' {
                                m += 1;
                            }
                            if m == hashes {
                                j += 1 + hashes;
                                break;
                            }
                        }
                        j += 1;
                    }
                    i = j;
                    continue;
                }
            }
        }
        // ordinary / byte strings
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            i += if c == b'b' { 2 } else { 1 };
            while i < n {
                if b[i] == b'\\' {
                    // a `\<newline>` continuation still advances the line
                    if i + 1 < n && b[i + 1] == b'\n' {
                        line += 1;
                    }
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    i += 1;
                    break;
                }
                if b[i] == b'\n' {
                    line += 1;
                }
                i += 1;
            }
            continue;
        }
        // lifetime vs char literal
        if c == b'\'' {
            let lifetime_like = i + 2 < n
                && (b[i + 1].is_ascii_alphabetic() || b[i + 1] == b'_')
                && b[i + 2] != b'\'';
            if lifetime_like {
                i += 2;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                continue;
            }
            i += 1;
            if i < n && b[i] == b'\\' {
                i += 2;
                while i < n && b[i] != b'\'' {
                    i += 1;
                }
                i += 1;
            } else if i < n {
                i += utf8_width(b[i]);
                if i < n && b[i] == b'\'' {
                    i += 1;
                }
            }
            continue;
        }
        // identifier / keyword
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            toks.push(Tok { text: text[i..j].to_string(), line });
            i = j;
            continue;
        }
        // numeric literal (int / float / hex, with suffixes)
        if c.is_ascii_digit() {
            let mut j = i;
            let is_hex = text[i..].starts_with("0x") || text[i..].starts_with("0X");
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            if j < n && b[j] == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                // fraction — but only when a digit follows, so `0..4` and
                // `1.max(x)` do not swallow the dot
                j += 1;
                while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
            } else if j < n && b[j] == b'.' {
                // trailing-dot float `1.` — but never `0..4` or `1.max(x)`
                let nxt = if j + 1 < n { b[j + 1] } else { 0 };
                if !(nxt == b'.' || nxt.is_ascii_alphabetic() || nxt == b'_') {
                    j += 1;
                }
            }
            // signed exponent: `1e-3` — never inside a hex literal
            while j < n
                && !is_hex
                && (b[j - 1] == b'e' || b[j - 1] == b'E')
                && (b[j] == b'+' || b[j] == b'-')
            {
                j += 1;
                while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
            }
            toks.push(Tok { text: text[i..j].to_string(), line });
            i = j;
            continue;
        }
        // `::` is one token so path checks are simple adjacency
        if c == b':' && i + 1 < n && b[i + 1] == b':' {
            toks.push(Tok { text: "::".to_string(), line });
            i += 2;
            continue;
        }
        if c.is_ascii() {
            toks.push(Tok { text: (c as char).to_string(), line });
            i += 1;
        } else {
            // non-ASCII outside comments/strings: skip the code point
            i += utf8_width(c);
        }
    }
    (toks, pragmas)
}

/// Is this token a floating-point literal? Hex literals are never floats
/// (`0x1E` is not scientific notation), and an `e`/`E` only makes a float
/// when no integer suffix is present (`1e3` yes, `1e3u64` is not valid
/// Rust anyway but stays conservative).
pub fn is_float_literal(t: &str) -> bool {
    let b = t.as_bytes();
    if b.is_empty() || !b[0].is_ascii_digit() {
        return false;
    }
    if t.starts_with("0x") || t.starts_with("0X") {
        return false;
    }
    if t.ends_with("f32") || t.ends_with("f64") {
        return true;
    }
    if t.contains('.') {
        return true;
    }
    if t.contains('e') || t.contains('E') {
        const INT_SUFFIXES: [&str; 10] =
            ["u8", "u16", "u32", "u64", "usize", "i8", "i16", "i32", "i64", "isize"];
        return !INT_SUFFIXES.iter().any(|s| t.contains(s));
    }
    false
}

/// Per-token structural facts computed by one pass of brace matching.
pub struct Analysis {
    /// Token is inside a `#[cfg(test)] mod … { … }` span.
    pub in_test: Vec<bool>,
    /// Name of the innermost enclosing `fn`, if any.
    pub fn_of: Vec<Option<String>>,
    /// Space-joined header of the innermost enclosing `impl` block, if
    /// any (e.g. `WireElem for f32` or `ByteReader < 'a >`).
    pub impl_of: Vec<Option<String>>,
}

/// Walk the token stream once, tracking `{}` depth to attribute each
/// token to its enclosing fn / impl block and to `#[cfg(test)]` mods.
///
/// Heuristics (sufficient for this crate's style): a fn's body opens at
/// the first `{` at bracket depth 0 after its name (a `;` first means a
/// trait method declaration); `impl` headers are collected the same way;
/// `-> impl Trait` in a return type cannot mis-trigger because signature
/// tokens are consumed while a fn is pending.
pub fn analyze(toks: &[Tok]) -> Analysis {
    let n = toks.len();
    let mut in_test = vec![false; n];
    let mut fn_of: Vec<Option<String>> = vec![None; n];
    let mut impl_of: Vec<Option<String>> = vec![None; n];
    let mut depth: i64 = 0;
    let mut test_until_depth: Option<i64> = None;
    let mut fn_stack: Vec<(String, i64)> = Vec::new();
    let mut impl_stack: Vec<(String, i64)> = Vec::new();
    let mut pending_fn: Option<String> = None;
    let mut pending_impl: Option<Vec<String>> = None;
    let mut paren: i64 = 0;
    let mut i = 0usize;
    while i < n {
        let t = toks[i].text.as_str();
        if pending_impl.is_some() {
            if t == "{" && paren == 0 {
                let hdr = pending_impl.take().unwrap();
                impl_stack.push((hdr.join(" "), depth));
                depth += 1;
                i += 1;
                continue;
            } else if t == ";" && paren == 0 {
                pending_impl = None; // `impl Trait for T;`-style — not a block
            } else {
                if t == "(" || t == "[" {
                    paren += 1;
                }
                if t == ")" || t == "]" {
                    paren -= 1;
                }
                if let Some(h) = pending_impl.as_mut() {
                    h.push(t.to_string());
                }
                i += 1;
                continue;
            }
        }
        if pending_fn.is_some() {
            if t == "{" && paren == 0 {
                let name = pending_fn.take().unwrap();
                fn_stack.push((name, depth));
                depth += 1;
                i += 1;
                continue;
            } else if t == ";" && paren == 0 {
                pending_fn = None; // trait method declaration — no body
            } else {
                if t == "(" || t == "[" {
                    paren += 1;
                }
                if t == ")" || t == "]" {
                    paren -= 1;
                }
                i += 1;
                continue;
            }
        }
        if t == "{" {
            depth += 1;
            i += 1;
            continue;
        }
        if t == "}" {
            depth -= 1;
            if fn_stack.last().map_or(false, |f| f.1 == depth) {
                fn_stack.pop();
            }
            if impl_stack.last().map_or(false, |s| s.1 == depth) {
                impl_stack.pop();
            }
            if test_until_depth == Some(depth) {
                test_until_depth = None;
            }
            i += 1;
            continue;
        }
        // `#[cfg(test)]` (possibly followed by more attributes) + `mod`
        if t == "#"
            && i + 6 < n
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
            && toks[i + 5].text == ")"
            && toks[i + 6].text == "]"
        {
            let mut j = i + 7;
            while j < n && toks[j].text == "#" {
                j += 1;
                if j < n && toks[j].text == "[" {
                    let mut d = 1i64;
                    j += 1;
                    while j < n && d > 0 {
                        if toks[j].text == "[" {
                            d += 1;
                        }
                        if toks[j].text == "]" {
                            d -= 1;
                        }
                        j += 1;
                    }
                }
            }
            if j < n && toks[j].text == "mod" {
                let mut k = j;
                while k < n && toks[k].text != "{" {
                    k += 1;
                }
                test_until_depth = Some(depth);
                for m in i..(k + 1).min(n) {
                    in_test[m] = true;
                }
            }
            i += 1;
            continue;
        }
        if t == "fn" && i + 1 < n && toks[i + 1].text != "(" && toks[i + 1].text != "<" {
            pending_fn = Some(toks[i + 1].text.clone());
            paren = 0;
            fn_of[i] = fn_stack.last().map(|f| f.0.clone());
            in_test[i] = test_until_depth.is_some();
            i += 1;
            continue;
        }
        if t == "impl" {
            pending_impl = Some(Vec::new());
            paren = 0;
            i += 1;
            continue;
        }
        in_test[i] = test_until_depth.is_some();
        fn_of[i] = fn_stack.last().map(|f| f.0.clone());
        impl_of[i] = impl_stack.last().map(|s| s.0.clone());
        i += 1;
    }
    Analysis { in_test, fn_of, impl_of }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).0.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn ranges_do_not_become_floats() {
        assert_eq!(texts("for i in 0..4 {}"), ["for", "i", "in", "0", ".", ".", "4", "{", "}"]);
        assert!(!is_float_literal("0"));
        assert!(is_float_literal("0.5"));
        assert!(is_float_literal("1e-3"));
        assert!(is_float_literal("3f32"));
        assert!(!is_float_literal("0x1E"));
        assert!(!is_float_literal("1_000"));
    }

    #[test]
    fn path_sep_is_one_token() {
        assert_eq!(texts("f64::MAX"), ["f64", "::", "MAX"]);
    }

    #[test]
    fn comments_and_strings_keep_line_numbers() {
        let src = "/* a\nb */ x\n\"s\\\n t\" y\nr#\"raw\n\"# z";
        let toks = lex(src).0;
        let got: Vec<(String, usize)> = toks.into_iter().map(|t| (t.text, t.line)).collect();
        assert_eq!(got, [("x".to_string(), 2), ("y".to_string(), 4), ("z".to_string(), 6)]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        assert_eq!(texts("<'a> 'x' '\\n' q"), ["<", ">", "q"]);
    }

    #[test]
    fn pragmas_are_collected_with_reason() {
        let src = "// numerics-lint: allow(float-leak) — because reasons\nlet x = 1;";
        let (_, pragmas) = lex(src);
        assert_eq!(pragmas.len(), 1);
        assert_eq!(pragmas[0].line, 1);
        assert_eq!(pragmas[0].rule, "float-leak");
        assert_eq!(pragmas[0].reason, "because reasons");
    }

    #[test]
    fn pragma_without_reason_is_empty() {
        let (_, pragmas) = lex("// numerics-lint: allow(atomics)\n");
        assert_eq!(pragmas.len(), 1);
        assert!(pragmas[0].reason.is_empty());
    }

    #[test]
    fn cfg_test_mod_spans_are_marked() {
        let src = "fn live() { let a = 1; }\n#[cfg(test)]\nmod tests { fn t() { let b = 2; } }";
        let (toks, _) = lex(src);
        let a = analyze(&toks);
        for (i, t) in toks.iter().enumerate() {
            if t.text == "a" {
                assert!(!a.in_test[i], "`a` must be live code");
            }
            if t.text == "b" {
                assert!(a.in_test[i], "`b` must be in the test mod");
            }
        }
    }

    #[test]
    fn fn_and_impl_attribution() {
        let src = "impl ByteReader { fn read_u8(&self) { self.pos } }\nfn free() { marker }";
        let (toks, _) = lex(src);
        let a = analyze(&toks);
        for (i, t) in toks.iter().enumerate() {
            if t.text == "pos" {
                assert_eq!(a.fn_of[i].as_deref(), Some("read_u8"));
                assert!(a.impl_of[i].as_deref().unwrap().contains("ByteReader"));
            }
            if t.text == "marker" {
                assert_eq!(a.fn_of[i].as_deref(), Some("free"));
                assert_eq!(a.impl_of[i], None);
            }
        }
    }
}
