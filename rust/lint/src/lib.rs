//! # numerics-lint — mechanical enforcement of docs/NUMERICS.md
//!
//! The bit-exactness contract in `docs/NUMERICS.md` is prose; this crate
//! is its police. A hand-rolled lexer ([`lexer`]) turns every file under
//! `rust/src/**` and `rust/tests/**` into a token stream, [`rules`] runs
//! the five lexical rules over it (float-leak, regrouping,
//! nondeterminism, atomics, hostile-input), and [`contract`] checks the
//! §9 clause→test table and the `*_scalar` twin pins against the tree.
//!
//! The `numerics-lint` binary walks the repository, prints
//! `file:line: [rule] message` diagnostics, and exits nonzero on any
//! finding — CI runs it as a blocking step. Individual sites are waived
//! with `// numerics-lint: allow(<rule>) — <reason>` on the line above;
//! see NUMERICS.md §10 for the full rule↔clause map and waiver policy.
//!
//! Zero dependencies by design: the linter must build wherever the crate
//! it guards builds, and its deterministic, ordered output is itself
//! subject to the spirit of the contract (sorted walks, `BTree`-free
//! simple vectors, no wall-clock).

#![forbid(unsafe_code)]

pub mod contract;
pub mod lexer;
pub mod rules;

pub use contract::{check_contract, check_scalar_twins};
pub use rules::{lint_source, Violation};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Read every `.rs` file under `rust/src` and `rust/tests`, keyed by
/// path relative to `rust/`, in sorted order (deterministic output).
pub fn collect_sources(repo: &Path) -> io::Result<Vec<(String, String)>> {
    let rust_root = repo.join("rust");
    let mut out = Vec::new();
    for base in ["src", "tests"] {
        let dir = rust_root.join(base);
        if dir.is_dir() {
            walk(&dir, &rust_root, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

fn walk(dir: &Path, rust_root: &Path, out: &mut Vec<(String, String)>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, rust_root, out)?;
        } else if p.extension().map_or(false, |e| e == "rs") {
            let rel = p
                .strip_prefix(rust_root)
                .expect("walk stays under rust/")
                .to_string_lossy()
                .replace('\\', "/");
            out.push((rel, fs::read_to_string(&p)?));
        }
    }
    Ok(())
}

/// Lint the whole repository rooted at `repo`: all lexical rules over
/// `rust/src` + `rust/tests`, then the contract checks against
/// `docs/NUMERICS.md`. Violations come back sorted by (file, line).
pub fn lint_tree(repo: &Path) -> io::Result<Vec<Violation>> {
    let files = collect_sources(repo)?;
    let mut viol: Vec<Violation> = Vec::new();
    for (rel, text) in &files {
        for mut v in rules::lint_source(rel, text) {
            v.file = format!("rust/{}", v.file);
            viol.push(v);
        }
    }
    let md = fs::read_to_string(repo.join("docs").join("NUMERICS.md"))?;
    viol.extend(contract::check_contract(&md, &files));
    viol.extend(contract::check_scalar_twins(&files));
    viol.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    Ok(viol)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The shipped tree must lint clean: every float/atomic/timing site
    /// in the value path is either allowlisted by design or carries a
    /// reasoned waiver, and §9 of NUMERICS.md matches the tests on disk.
    /// If this test fails after an edit, either fix the site or waive it
    /// with a pragma explaining why it cannot bend the contract.
    #[test]
    fn shipped_tree_is_clean() {
        let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
        let viol = lint_tree(&repo).expect("repository tree must be readable");
        assert!(
            viol.is_empty(),
            "numerics-lint found {} violation(s):\n{}",
            viol.len(),
            viol.iter()
                .map(|v| format!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    /// The walker must see the wire format and the lane tests — if the
    /// layout moves, the linter silently scanning nothing would be worse
    /// than failing.
    #[test]
    fn walker_reaches_known_files() {
        let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
        let files = collect_sources(&repo).expect("readable");
        for want in ["src/train/wire.rs", "src/lns/system.rs", "tests/lane_exactness.rs"] {
            assert!(
                files.iter().any(|(p, _)| p == want),
                "walker did not find rust/{}",
                want
            );
        }
    }
}
