//! Seeded pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so we ship a small,
//! well-known generator: SplitMix64 for raw 64-bit streams (passes BigCrush
//! as a 64-bit mixer; more than adequate for weight init, data synthesis
//! and property testing), plus uniform/normal/permutation helpers.
//! Everything in the library that needs randomness takes one of these,
//! keyed by an explicit seed, so every experiment is reproducible.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams for practical purposes.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` via rejection-free Lemire reduction.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (uses two uniforms per pair; we
    /// discard the second to keep the generator allocation-free).
    pub fn normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Fork an independent stream (hash of the current state + tag).
    pub fn fork(&mut self, tag: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let v = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = SplitMix64::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = SplitMix64::new(11);
        let p = r.permutation(97);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..97).collect::<Vec<_>>());
    }
}
