//! L3 coordinator: experiment drivers that regenerate the paper's tables
//! and figures, report writers, and a batched inference server.
//!
//! The paper's contribution is the numeric format (L1/L2); per the
//! architecture rules this layer is a thin-but-real driver: it owns
//! configuration, process lifecycle, experiment fan-out across threads,
//! metrics and reporting — never the arithmetic itself.

pub mod experiments;
pub mod report;
pub mod server;

pub use experiments::{
    fig1_rows, fig2, run_one, run_one_mp, table1, width_frontier, ConfigTag, FrontierRecord,
    LogMode, RunRecord,
};
pub use report::{write_csv, write_markdown};
pub use server::{train_cnn_multiproc, train_multiproc, BatchServer, MultiprocSpec, ServerStats};
