//! Report writers: CSV series and markdown tables under `results/`.

use super::experiments::{ConfigTag, Fig1Row, FrontierRecord, RunRecord};
use anyhow::{Context, Result};
use std::path::Path;

/// Write a CSV file (creates parent dirs).
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for r in rows {
        out.push_str(&r.join(","));
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Write plain text/markdown (creates parent dirs).
pub fn write_markdown(path: &Path, content: &str) -> Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(path, content).with_context(|| format!("writing {}", path.display()))
}

/// Fig. 1 CSV rows.
pub fn fig1_csv_rows(rows: &[Fig1Row]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                format!("{:.4}", r.d),
                format!("{:.6}", r.exact_plus),
                format!("{:.6}", r.lut_plus),
                format!("{:.6}", r.bs_plus),
                format!("{:.6}", r.exact_minus),
                format!("{:.6}", r.lut_minus),
                format!("{:.6}", r.bs_minus),
            ]
        })
        .collect()
}

/// Fig. 2 CSV: one row per (series, epoch).
pub fn fig2_csv_rows(recs: &[RunRecord]) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for rec in recs {
        for e in &rec.curve {
            rows.push(vec![
                rec.dataset.clone(),
                rec.tag.label().to_string(),
                e.epoch.to_string(),
                format!("{:.6}", e.train_loss),
                format!("{:.4}", e.val_accuracy),
                format!("{:.3}", e.seconds),
            ]);
        }
    }
    rows
}

/// Table 1 in the paper's layout: datasets down, columns across.
pub fn table1_markdown(recs: &[RunRecord]) -> String {
    let cols = ConfigTag::table1_columns();
    let mut datasets: Vec<String> = recs.iter().map(|r| r.dataset.clone()).collect();
    datasets.dedup();
    let mut s = String::new();
    s.push_str("# Table 1 — test accuracy (%) \n\n");
    s.push_str("| Dataset |");
    for c in cols {
        s.push_str(&format!(" {} |", c.label()));
    }
    s.push_str("\n|---|");
    for _ in cols {
        s.push_str("---|");
    }
    s.push('\n');
    for d in &datasets {
        s.push_str(&format!("| {d} |"));
        for c in cols {
            match recs.iter().find(|r| &r.dataset == d && r.tag == c) {
                Some(r) => s.push_str(&format!(" {:.1} |", r.test_accuracy * 100.0)),
                None => s.push_str(" – |"),
            }
        }
        s.push('\n');
    }
    s
}

/// Accuracy-vs-bitwidth frontier as markdown: one row per cell, with
/// the per-layer precision assignment and the occupancy-histogram
/// headroom that motivates (or refutes) narrowing each cell further.
pub fn frontier_markdown(recs: &[FrontierRecord]) -> String {
    let mut s = String::new();
    s.push_str("# Accuracy-vs-bitwidth frontier\n\n");
    s.push_str(
        "Headroom = min over weight layers of (representable exponent \
         ceiling − occupied exponent ceiling); large headroom means the \
         layer could store narrower (see docs/OBSERVABILITY.md).\n\n",
    );
    s.push_str("| Dataset | Config | Bits | Per-layer precision | Test acc (%) | Test loss | Headroom (bits) |\n");
    s.push_str("|---|---|---:|---|---:|---:|---:|\n");
    for r in recs {
        s.push_str(&format!(
            "| {} | {} | {} | {} | {:.1} | {:.4} | {} |\n",
            r.dataset,
            r.label,
            if r.bits == 0 { "–".to_string() } else { r.bits.to_string() },
            r.precision,
            r.test_accuracy * 100.0,
            r.test_loss,
            r.headroom_bits.map_or("–".to_string(), |h| h.to_string()),
        ));
    }
    s
}

/// Frontier CSV rows (same cells as [`frontier_markdown`]).
pub fn frontier_csv_rows(recs: &[FrontierRecord]) -> Vec<Vec<String>> {
    recs.iter()
        .map(|r| {
            vec![
                r.dataset.clone(),
                r.label.clone(),
                r.bits.to_string(),
                r.precision.clone(),
                format!("{:.4}", r.test_accuracy),
                format!("{:.4}", r.test_loss),
                r.headroom_bits.map_or(String::new(), |h| h.to_string()),
                format!("{:.1}", r.seconds),
            ]
        })
        .collect()
}

/// Generic per-run CSV (used by `table1.csv` for machine-readable output).
pub fn runs_csv_rows(recs: &[RunRecord]) -> Vec<Vec<String>> {
    recs.iter()
        .map(|r| {
            vec![
                r.dataset.clone(),
                r.tag.label().to_string(),
                format!("{:.4}", r.test_accuracy),
                format!("{:.4}", r.test_loss),
                format!("{:.1}", r.seconds),
            ]
        })
        .collect()
}

/// End-of-run observation summary as markdown: non-zero counter totals
/// with their per-layer attribution cells, plus span rollups. `--obs`
/// runs write this under `results/` so a run leaves its numerics
/// profile next to the accuracy artifacts it explains.
pub fn obs_markdown(label: &str) -> String {
    let snap = crate::obs::metrics::snapshot();
    let spans = crate::obs::trace::rollup_snapshot();
    let mut out = format!("# Observation summary — {label}\n");
    out.push_str("\n## Counters\n\n| counter | total | per-layer 1… |\n|---|---:|---|\n");
    let mut any = false;
    for e in &snap.entries {
        let total = e.total();
        if total == 0 {
            continue;
        }
        any = true;
        let last = e.by_scope.iter().rposition(|&v| v != 0).unwrap_or(0);
        let layers = if last > 0 {
            e.by_scope[1..=last].iter().map(u64::to_string).collect::<Vec<_>>().join(" / ")
        } else {
            "–".into()
        };
        out.push_str(&format!("| `{}` | {total} | {layers} |\n", e.name));
    }
    if !any {
        out.push_str("| _(no counter activity)_ | | |\n");
    }
    let dist = crate::obs::dist::snapshot();
    if !dist.entries.is_empty() {
        out.push_str("\n## Range occupancy\n\n");
        if let Some((lo, hi)) = crate::obs::dist::exp_range() {
            out.push_str(&format!(
                "Backend representable exponent range: [{lo}, {hi}] \
                 ({} bits of exponent span).\n\n",
                hi - lo + 1
            ));
        }
        out.push_str("| class | layer | samples | zeros | negative | occupied exp span | headroom (bits) | range used |\n");
        out.push_str("|---|---:|---:|---:|---:|---|---:|---:|\n");
        for e in &dist.entries {
            let class = crate::obs::dist::TensorClass::from_code(e.class)
                .map(|c| c.name())
                .unwrap_or("?");
            let (span, headroom, frac) = match (e.occupied_span(), crate::obs::dist::exp_range()) {
                (Some((lo, hi)), Some((rmin, rmax))) => (
                    format!("[{lo}, {hi}]"),
                    format!("{}", rmax - hi),
                    format!("{:.2}", (hi - lo + 1) as f64 / (rmax - rmin + 1).max(1) as f64),
                ),
                (Some((lo, hi)), None) => (format!("[{lo}, {hi}]"), "–".into(), "–".into()),
                _ => ("–".into(), "–".into(), "–".into()),
            };
            out.push_str(&format!(
                "| {class} | {} | {} | {} | {} | {span} | {headroom} | {frac} |\n",
                e.layer,
                e.total(),
                e.zeros,
                e.neg
            ));
        }
        let norms = crate::obs::dist::grad_norms();
        if !norms.is_empty() {
            out.push_str("\nGradient norms (backend arithmetic, last recorded batch):\n\n");
            out.push_str("| layer | L1 | L∞ |\n|---:|---:|---:|\n");
            for (layer, l1, linf) in &norms {
                out.push_str(&format!("| {layer} | {l1:.6} | {linf:.6} |\n"));
            }
        }
    }
    if !spans.is_empty() {
        out.push_str("\n## Spans\n\n| span | count | total ms |\n|---|---:|---:|\n");
        for (name, count, ns) in &spans {
            out.push_str(&format!("| `{name}` | {count} | {:.3} |\n", *ns as f64 / 1e6));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::LogMode;
    use crate::train::EpochRecord;

    fn rec(ds: &str, tag: ConfigTag, acc: f64) -> RunRecord {
        RunRecord {
            dataset: ds.into(),
            tag,
            curve: vec![EpochRecord { epoch: 1, train_loss: 1.0, val_accuracy: acc, seconds: 0.1 }],
            test_accuracy: acc,
            test_loss: 0.5,
            seconds: 1.0,
        }
    }

    #[test]
    fn table1_markdown_layout() {
        let recs = vec![
            rec("mnist", ConfigTag::Float, 0.974),
            rec("mnist", ConfigTag::Log(16, LogMode::Lut), 0.972),
        ];
        let md = table1_markdown(&recs);
        assert!(md.contains("| mnist |"));
        assert!(md.contains("97.4"));
        assert!(md.contains("97.2"));
        assert!(md.contains("–"), "missing cells dashed");
    }

    #[test]
    fn csv_written_to_disk() {
        let dir = std::env::temp_dir().join(format!("lnsdnn-rep-{}", std::process::id()));
        let p = dir.join("x.csv");
        write_csv(&p, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fig2_rows_flatten_curves() {
        let rows = fig2_csv_rows(&[rec("mnist", ConfigTag::Lin(16), 0.9)]);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0][1], "lin16");
    }

    #[test]
    fn frontier_writers_render_cells() {
        let recs = vec![
            FrontierRecord {
                dataset: "mnist".into(),
                label: "log8-lut".into(),
                bits: 8,
                precision: "uniform".into(),
                test_accuracy: 0.91,
                test_loss: 0.4,
                seconds: 2.0,
                headroom_bits: Some(3),
            },
            FrontierRecord {
                dataset: "mnist".into(),
                label: "log16-lut".into(),
                bits: 8,
                precision: "8,-".into(),
                test_accuracy: 0.95,
                test_loss: 0.3,
                seconds: 2.5,
                headroom_bits: None,
            },
        ];
        let md = frontier_markdown(&recs);
        assert!(md.contains("| mnist | log8-lut | 8 | uniform | 91.0 |"));
        assert!(md.contains("| mnist | log16-lut | 8 | 8,- | 95.0 |"));
        let rows = frontier_csv_rows(&recs);
        assert_eq!(rows[0][6], "3");
        assert_eq!(rows[1][6], "");
    }

    #[test]
    fn obs_markdown_has_table_skeleton() {
        // Lib unit tests never enable the global counters, so the exact
        // totals here are whatever local state exists — only the layout
        // is asserted.
        let md = obs_markdown("unit");
        assert!(md.starts_with("# Observation summary — unit\n"));
        assert!(md.contains("## Counters"));
        assert!(md.contains("| counter | total |"));
    }
}
