//! Experiment drivers for the paper's evaluation artifacts.
//!
//! * [`fig1_rows`] — Δ+/Δ− exact vs LUT vs bit-shift curves (Fig. 1).
//! * [`fig2`] — validation-accuracy learning curves, 12/16-bit log vs
//!   linear (Fig. 2).
//! * [`table1`] — test accuracy at 20 epochs for all seven number-system
//!   columns × four datasets (Table 1), fanned out across threads.

use crate::coordinator::server::{train_cnn_multiproc, train_multiproc, MultiprocSpec};
use crate::data::Dataset;
use crate::fixed::{FixedConfig, FixedSystem};
use crate::lns::{DeltaApprox, DeltaMode, LnsConfig, LnsSystem, LutSpec};
use crate::nn::{CnnArch, CnnVariant};
use crate::precision::PrecisionMap;
use crate::tensor::{FixedBackend, FloatBackend, LnsBackend};
use crate::train::{train, train_cnn, CnnTrainConfig, EpochRecord, ShardConfig, TrainConfig};
use rayon::prelude::*;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The leaky/llReLU slope used everywhere (paper's leaky-ReLU).
pub const SLOPE: f64 = 0.01;

/// Δ-approximation family of a log-domain column.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum LogMode {
    /// Uniformly sampled LUT (paper's Table-1 default).
    Lut,
    /// Generalized bit-shift rule.
    Bs,
    /// Exact (float-evaluated) Δ — ablation only.
    Exact,
}

impl LogMode {
    fn suffix(&self) -> &'static str {
        match self {
            LogMode::Lut => "lut",
            LogMode::Bs => "bs",
            LogMode::Exact => "exact",
        }
    }
}

/// A number-system column: the float baseline, or a fixed/log word at a
/// **runtime** width. The paper's seven Table-1 columns are the 12/16-bit
/// instances; any width the validators accept (`lin8`, `log23-bs`, …)
/// is a legal column, which is what the accuracy-vs-bitwidth frontier
/// sweeps over.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum ConfigTag {
    /// Floating-point baseline.
    Float,
    /// Linear fixed-point at a total word width (preset layout).
    Lin(u32),
    /// Log-domain at a total word width (preset layout) with a Δ mode.
    Log(u32, LogMode),
}

impl ConfigTag {
    /// All columns of Table 1, in the paper's order.
    pub fn table1_columns() -> [ConfigTag; 7] {
        [
            ConfigTag::Float,
            ConfigTag::Lin(12),
            ConfigTag::Lin(16),
            ConfigTag::Log(12, LogMode::Lut),
            ConfigTag::Log(16, LogMode::Lut),
            ConfigTag::Log(12, LogMode::Bs),
            ConfigTag::Log(16, LogMode::Bs),
        ]
    }

    /// The four Fig. 2 series.
    pub fn fig2_series() -> [ConfigTag; 4] {
        [
            ConfigTag::Lin(12),
            ConfigTag::Lin(16),
            ConfigTag::Log(12, LogMode::Lut),
            ConfigTag::Log(16, LogMode::Lut),
        ]
    }

    /// Parse a CLI tag like `log16-lut` or `lin8` — any width the
    /// config validators accept, through the same `from_tag` parsers the
    /// worker processes reconstruct backends with.
    pub fn parse(s: &str) -> Option<ConfigTag> {
        if s == "float" {
            return Some(ConfigTag::Float);
        }
        if let Some(fc) = FixedConfig::from_tag(s) {
            return Some(ConfigTag::Lin(fc.total_bits));
        }
        let lc = LnsConfig::from_tag(s)?;
        let mode = match lc.delta {
            DeltaMode::Lut(_) => LogMode::Lut,
            DeltaMode::BitShift => LogMode::Bs,
            DeltaMode::Exact => LogMode::Exact,
        };
        Some(ConfigTag::Log(lc.total_bits, mode))
    }

    /// Report label (also the wire/CLI backend tag).
    pub fn label(&self) -> String {
        match self {
            ConfigTag::Float => "float".into(),
            ConfigTag::Lin(w) => format!("lin{w}"),
            ConfigTag::Log(w, mode) => format!("log{w}-{}", mode.suffix()),
        }
    }

    /// The paper notes 12-bit runs needed a larger weight-decay constant;
    /// these defaults extend that to every narrow word (overridable from
    /// the CLI).
    pub fn default_weight_decay(&self) -> f64 {
        match self.bits() {
            0 => 1e-4,
            w if w <= 12 => 1e-3,
            _ => 1e-4,
        }
    }

    /// Word width (0 = float).
    pub fn bits(&self) -> u32 {
        match self {
            ConfigTag::Float => 0,
            ConfigTag::Lin(w) | ConfigTag::Log(w, _) => *w,
        }
    }
}

/// Outcome of one (dataset × config) training run.
#[derive(Clone, Debug)]
pub struct RunRecord {
    /// Dataset tag.
    pub dataset: String,
    /// Number-system column.
    pub tag: ConfigTag,
    /// Learning curve.
    pub curve: Vec<EpochRecord>,
    /// Final test accuracy.
    pub test_accuracy: f64,
    /// Final test loss.
    pub test_loss: f64,
    /// Total training seconds.
    pub seconds: f64,
}

/// Build the LNS config for a log-domain tag (any valid runtime width).
pub fn lns_config_for(tag: ConfigTag) -> Option<LnsConfig> {
    match tag {
        ConfigTag::Log(w, mode) => {
            let mut cfg = LnsConfig::for_width(w, mode == LogMode::Bs).ok()?;
            if mode == LogMode::Exact {
                cfg.delta = DeltaMode::Exact;
                cfg.softmax_delta = DeltaMode::Exact;
            }
            Some(cfg)
        }
        _ => None,
    }
}

/// Build the fixed-point config for a linear tag (any valid width).
pub fn fixed_config_for(tag: ConfigTag) -> Option<FixedConfig> {
    match tag {
        ConfigTag::Lin(w) => FixedConfig::for_width(w).ok(),
        _ => None,
    }
}

/// Train one (dataset × config) cell.
pub fn run_one(ds: &Dataset, tag: ConfigTag, cfg: &TrainConfig) -> RunRecord {
    let t0 = std::time::Instant::now();
    let (curve, test) = match tag {
        ConfigTag::Float => {
            let r = train(&FloatBackend { slope: SLOPE as f32 }, ds, cfg);
            (r.curve, r.test)
        }
        ConfigTag::Lin(_) => {
            let fc = fixed_config_for(tag).expect("valid lin width");
            let r = train(&FixedBackend::new(FixedSystem::new(fc), SLOPE), ds, cfg);
            (r.curve, r.test)
        }
        _ => {
            let lc = lns_config_for(tag).expect("log tag");
            let r = train(&LnsBackend::new(LnsSystem::new(lc), SLOPE), ds, cfg);
            (r.curve, r.test)
        }
    };
    RunRecord {
        dataset: ds.name.clone(),
        tag,
        curve,
        test_accuracy: test.accuracy,
        test_loss: test.loss,
        seconds: t0.elapsed().as_secs_f64(),
    }
}

/// Multi-process twin of [`run_one`]: identical backends and record, but
/// the training run itself fans out across `spec.workers` local worker
/// processes ([`train_multiproc`]) — trained weights and metrics are
/// bit-identical to [`run_one`] by the multi-process determinism
/// contract (`tests/multiproc_determinism.rs`).
pub fn run_one_mp(
    ds: &Dataset,
    tag: ConfigTag,
    cfg: &TrainConfig,
    spec: &MultiprocSpec,
) -> anyhow::Result<RunRecord> {
    let t0 = std::time::Instant::now();
    let (curve, test) = match tag {
        ConfigTag::Float => {
            let b = FloatBackend { slope: SLOPE as f32 };
            let r = train_multiproc(&b, ds, cfg, spec)?;
            (r.curve, r.test)
        }
        ConfigTag::Lin(_) => {
            let fc = fixed_config_for(tag).expect("valid lin width");
            let b = FixedBackend::new(FixedSystem::new(fc), SLOPE);
            let r = train_multiproc(&b, ds, cfg, spec)?;
            (r.curve, r.test)
        }
        _ => {
            let lc = lns_config_for(tag).expect("log tag");
            let b = LnsBackend::new(LnsSystem::new(lc), SLOPE);
            let r = train_multiproc(&b, ds, cfg, spec)?;
            (r.curve, r.test)
        }
    };
    Ok(RunRecord {
        dataset: ds.name.clone(),
        tag,
        curve,
        test_accuracy: test.accuracy,
        test_loss: test.loss,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Multi-process twin of [`run_one_cnn`].
pub fn run_one_cnn_mp(
    ds: &Dataset,
    tag: ConfigTag,
    cfg: &CnnTrainConfig,
    spec: &MultiprocSpec,
) -> anyhow::Result<RunRecord> {
    let t0 = std::time::Instant::now();
    let (curve, test) = match tag {
        ConfigTag::Float => {
            let b = FloatBackend { slope: SLOPE as f32 };
            let r = train_cnn_multiproc(&b, ds, cfg, spec)?;
            (r.curve, r.test)
        }
        ConfigTag::Lin(_) => {
            let fc = fixed_config_for(tag).expect("valid lin width");
            let b = FixedBackend::new(FixedSystem::new(fc), SLOPE);
            let r = train_cnn_multiproc(&b, ds, cfg, spec)?;
            (r.curve, r.test)
        }
        _ => {
            let lc = lns_config_for(tag).expect("log tag");
            let b = LnsBackend::new(LnsSystem::new(lc), SLOPE);
            let r = train_cnn_multiproc(&b, ds, cfg, spec)?;
            (r.curve, r.test)
        }
    };
    Ok(RunRecord {
        dataset: ds.name.clone(),
        tag,
        curve,
        test_accuracy: test.accuracy,
        test_loss: test.loss,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Size a sweep's [`MultiprocSpec`] to its thread budget: when the
/// caller left `worker_threads` at 0 (library default), each worker
/// process would build a full-machine rayon pool, and with `concurrent`
/// sweep cells in flight the machine would run
/// `concurrent × workers × cores` compute threads. Cap each worker at
/// `threads / (concurrent × workers)` so total active compute threads
/// stay ≈ `threads`, matching the in-process sweeps' sizing invariant.
/// An explicit non-zero `worker_threads` is respected as-is.
fn sized_mp(mp: &MultiprocSpec, threads: usize, concurrent: usize) -> MultiprocSpec {
    let mut eff = mp.clone();
    if eff.is_multiproc() && eff.worker_threads == 0 {
        eff.worker_threads = (threads / (concurrent * eff.workers).max(1)).max(1);
    }
    eff
}

/// Paper training protocol for a dataset, with the tag's weight decay.
pub fn paper_config(
    ds: &Dataset,
    tag: ConfigTag,
    epochs: usize,
    hidden: usize,
    seed: u64,
) -> TrainConfig {
    let mut cfg = TrainConfig::paper(ds.classes);
    cfg.dims = vec![ds.pixels, hidden, ds.classes];
    cfg.epochs = epochs;
    cfg.sgd.weight_decay = tag.default_weight_decay();
    cfg.seed = seed;
    cfg
}

/// Fan a set of (dataset × config) runs across a dedicated rayon pool.
///
/// The runs are independent; this is the coordinator's parallelism on top
/// of the math's. The pool is sized by `threads`, and the per-run tensor
/// ops spawned inside it share the same pool via rayon's work stealing,
/// so total CPU use stays bounded by `threads` no matter how the inner
/// matmuls fan out. Results come back in job order (dataset-major, then
/// tag), independent of completion order.
///
/// `shards` sets each run's data-parallel worker count
/// ([`ShardConfig`]); accuracies are shard-count-invariant, so the axis
/// only moves wall-clock. Each sharded run owns an `n_shards`-thread
/// pool, so the sweep pool is sized to `threads / shards` concurrent
/// jobs — total active workers stay ≈ `threads` instead of
/// multiplying out to `threads × shards`.
///
/// With `mp.workers > 1` every cell instead trains across that many
/// **worker processes** ([`run_one_mp`]); `shards` is then ignored, the
/// sweep pool is sized to `threads / workers`, and each worker
/// process's rayon pool is capped so that
/// `concurrent × workers × worker_threads ≈ threads` (see `sized_mp`;
/// an explicit `worker_threads` is respected as-is). The weights are
/// still bit-identical to the in-process runs. A failed process spawn aborts
/// the sweep (panic with context): a half-degraded sweep would silently
/// report a different machine's worth of throughput.
pub fn run_grid(
    datasets: &[Dataset],
    tags: &[ConfigTag],
    epochs: usize,
    hidden: usize,
    seed: u64,
    threads: usize,
    shards: usize,
    mp: &MultiprocSpec,
) -> Vec<RunRecord> {
    // Fail fast on invalid shard counts, before any pool spins up (the
    // per-job `ShardConfig` below would otherwise panic mid-sweep inside
    // a rayon worker).
    let shard_cfg = ShardConfig::with_shards(shards);
    mp.validate().expect("invalid multi-process spec");
    // Numerics counters are process-global and sweep cells run
    // concurrently, so the sweep resets once up front and reports
    // aggregates over all cells — per-cell attribution would race.
    if crate::obs::counters_enabled() {
        crate::obs::reset_all();
    }
    let jobs: Vec<(usize, ConfigTag)> = (0..datasets.len())
        .flat_map(|d| tags.iter().map(move |&t| (d, t)))
        .collect();
    if jobs.is_empty() {
        return Vec::new();
    }
    let per_job = if mp.is_multiproc() { mp.workers } else { shards };
    let concurrent = (threads / per_job).max(1).clamp(1, jobs.len());
    let mp = sized_mp(mp, threads, concurrent);
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(concurrent)
        .thread_name(|i| format!("sweep-{i}"))
        .build()
        .expect("building the sweep thread pool");
    let done = AtomicUsize::new(0);
    pool.install(|| {
        jobs.par_iter()
            .map(|&(d, tag)| {
                let ds = &datasets[d];
                let mut cfg = paper_config(ds, tag, epochs, hidden, seed);
                cfg.shard = shard_cfg;
                let rec = if mp.is_multiproc() {
                    run_one_mp(ds, tag, &cfg, &mp).expect("multi-process sweep cell failed")
                } else {
                    run_one(ds, tag, &cfg)
                };
                // numerics-lint: allow(atomics) — sweep progress counter for log lines; relaxed count is enough
                let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                eprintln!(
                    "[{finished}/{} done] {} × {:<10} acc={:.3} ({:.1}s)",
                    jobs.len(),
                    rec.dataset,
                    tag.label(),
                    rec.test_accuracy,
                    rec.seconds
                );
                rec
            })
            .collect()
    })
}

/// Table 1: all seven columns over the given datasets.
pub fn table1(
    datasets: &[Dataset],
    epochs: usize,
    hidden: usize,
    seed: u64,
    threads: usize,
    shards: usize,
    mp: &MultiprocSpec,
) -> Vec<RunRecord> {
    run_grid(datasets, &ConfigTag::table1_columns(), epochs, hidden, seed, threads, shards, mp)
}

/// Fig. 2: the four learning-curve series for one dataset.
pub fn fig2(
    ds: &Dataset,
    epochs: usize,
    hidden: usize,
    seed: u64,
    threads: usize,
    shards: usize,
    mp: &MultiprocSpec,
) -> Vec<RunRecord> {
    run_grid(
        std::slice::from_ref(ds),
        &ConfigTag::fig2_series(),
        epochs,
        hidden,
        seed,
        threads,
        shards,
        mp,
    )
}

/// One cell of the accuracy-vs-bitwidth frontier sweep.
#[derive(Clone, Debug)]
pub struct FrontierRecord {
    /// Dataset tag.
    pub dataset: String,
    /// Backend/column label (`float`, `lin8`, `log16-lut`, …).
    pub label: String,
    /// Narrowest storage width in play: the word width for uniform
    /// cells, the narrowest assigned layer width for mixed cells
    /// (0 = float).
    pub bits: u32,
    /// Per-layer precision assignment label (`uniform` or e.g. `8,-`).
    pub precision: String,
    /// Final test accuracy.
    pub test_accuracy: f64,
    /// Final test loss.
    pub test_loss: f64,
    /// Training seconds.
    pub seconds: f64,
    /// Minimum top-of-range headroom over weight layers (exponent
    /// steps), from this cell's own occupancy histograms.
    pub headroom_bits: Option<i32>,
}

/// Minimum top-of-range headroom over all weight-layer occupancy cells:
/// how many exponent steps the hottest layer leaves unused below the
/// active word's ceiling. This is the "choosing per-layer bitwidth from
/// range occupancy" signal of `docs/OBSERVABILITY.md`, computed from
/// whatever the current process banks hold.
pub fn weight_headroom_bits() -> Option<i32> {
    use crate::obs::dist;
    let (_, hi) = dist::exp_range()?;
    let snap = dist::snapshot();
    let mut min_headroom: Option<i32> = None;
    for e in &snap.entries {
        if e.class != dist::TensorClass::Weights.code() {
            continue;
        }
        if let Some((_, ohi)) = e.occupied_span() {
            let h = hi - ohi;
            min_headroom = Some(min_headroom.map_or(h, |m| m.min(h)));
        }
    }
    min_headroom
}

/// Train one frontier cell with a clean, per-cell telemetry bank and
/// annotate the record with its weight-range headroom.
fn frontier_cell(
    ds: &Dataset,
    tag: ConfigTag,
    pmap: PrecisionMap,
    epochs: usize,
    hidden: usize,
    seed: u64,
) -> FrontierRecord {
    // Frontier cells run *sequentially* so the process-global occupancy
    // banks attribute to exactly one cell — the opposite trade from
    // `run_grid`, which runs cells concurrently and can only report
    // sweep-wide aggregates.
    crate::obs::reset_all();
    let mut cfg = paper_config(ds, tag, epochs, hidden, seed);
    cfg.precision = pmap.clone();
    let rec = run_one(ds, tag, &cfg);
    let headroom = weight_headroom_bits();
    let bits = pmap
        .layers()
        .iter()
        .flatten()
        .map(|w| w.total_bits)
        .min()
        .unwrap_or_else(|| tag.bits());
    eprintln!(
        "  frontier {} × {:<12} precision={:<8} acc={:.3} headroom={} ({:.1}s)",
        rec.dataset,
        tag.label(),
        pmap.label(),
        rec.test_accuracy,
        headroom.map_or("-".to_string(), |h| h.to_string()),
        rec.seconds
    );
    FrontierRecord {
        dataset: rec.dataset,
        label: tag.label(),
        bits,
        precision: pmap.label(),
        test_accuracy: rec.test_accuracy,
        test_loss: rec.test_loss,
        seconds: rec.seconds,
        headroom_bits: headroom,
    }
}

/// The accuracy-vs-bitwidth frontier (Table-1-style artifact): for every
/// dataset, a float anchor plus `lin`/`log-lut`/`log-bs` columns at each
/// requested width, plus — when at least two widths are given — two
/// per-layer mixed-precision rows on the widest log-LUT base word
/// (narrowest width stored in the first layer, then in the last), so the
/// artifact shows what per-layer assignment buys over uniform narrowing.
/// Every cell carries its occupancy-histogram headroom, linking the
/// frontier back to the range-occupancy workflow.
pub fn width_frontier(
    datasets: &[Dataset],
    widths: &[u32],
    epochs: usize,
    hidden: usize,
    seed: u64,
) -> Vec<FrontierRecord> {
    assert!(!widths.is_empty(), "width frontier needs at least one width");
    let counters_were_on = crate::obs::counters_enabled();
    crate::obs::set_counters(true);
    let mut out = Vec::new();
    for ds in datasets {
        out.push(frontier_cell(ds, ConfigTag::Float, PrecisionMap::uniform(), epochs, hidden, seed));
        for &w in widths {
            for tag in [
                ConfigTag::Lin(w),
                ConfigTag::Log(w, LogMode::Lut),
                ConfigTag::Log(w, LogMode::Bs),
            ] {
                out.push(frontier_cell(ds, tag, PrecisionMap::uniform(), epochs, hidden, seed));
            }
        }
        let lo = *widths.iter().min().expect("non-empty widths");
        let hi = *widths.iter().max().expect("non-empty widths");
        if lo != hi {
            let base = ConfigTag::Log(hi, LogMode::Lut);
            let base_tag = base.label();
            for spec in [format!("{lo},-"), format!("-,{lo}")] {
                let pmap =
                    PrecisionMap::parse(&spec, &base_tag).expect("frontier precision spec");
                out.push(frontier_cell(ds, base, pmap, epochs, hidden, seed));
            }
        }
    }
    crate::obs::set_counters(counters_were_on);
    out
}

/// CNN training protocol for a dataset of square images: the requested
/// architecture variant (pooled LeNet or stride-2 convs) sized from the
/// dataset, the tag's weight decay, paper epochs/batching, and the
/// sweep's shard count.
pub fn cnn_config(
    ds: &Dataset,
    tag: ConfigTag,
    epochs: usize,
    seed: u64,
    variant: CnnVariant,
    shards: usize,
) -> CnnTrainConfig {
    let side = (ds.pixels as f64).sqrt().round() as usize;
    assert_eq!(side * side, ds.pixels, "CNN workload needs square images");
    let mut cfg = CnnTrainConfig::lenet(side, ds.classes);
    if variant == CnnVariant::StridedV1 {
        cfg.arch = CnnArch::strided_v1(side, ds.classes);
    }
    cfg.epochs = epochs;
    cfg.sgd.weight_decay = tag.default_weight_decay();
    cfg.seed = seed;
    cfg.shard = ShardConfig::with_shards(shards);
    cfg
}

/// Train one (dataset × config) CNN cell — the conv-workload twin of
/// [`run_one`].
pub fn run_one_cnn(ds: &Dataset, tag: ConfigTag, cfg: &CnnTrainConfig) -> RunRecord {
    let t0 = std::time::Instant::now();
    let (curve, test) = match tag {
        ConfigTag::Float => {
            let r = train_cnn(&FloatBackend { slope: SLOPE as f32 }, ds, cfg);
            (r.curve, r.test)
        }
        ConfigTag::Lin(_) => {
            let fc = fixed_config_for(tag).expect("valid lin width");
            let r = train_cnn(&FixedBackend::new(FixedSystem::new(fc), SLOPE), ds, cfg);
            (r.curve, r.test)
        }
        _ => {
            let lc = lns_config_for(tag).expect("log tag");
            let r = train_cnn(&LnsBackend::new(LnsSystem::new(lc), SLOPE), ds, cfg);
            (r.curve, r.test)
        }
    };
    RunRecord {
        dataset: ds.name.clone(),
        tag,
        curve,
        test_accuracy: test.accuracy,
        test_loss: test.loss,
        seconds: t0.elapsed().as_secs_f64(),
    }
}

/// Fan one CNN run per config tag across a dedicated rayon pool (same
/// pooling/work-stealing story as [`run_grid`], including the
/// `threads / shards` sizing when each run brings its own shard pool).
/// Results come back in `tags` order. Unlike [`run_grid`] the pool is
/// **not** clamped to the job count: there are typically only a handful
/// of tags, and the conv runs' nested row-parallel matmuls fill the
/// remaining threads via work stealing.
pub fn cnn_grid(
    ds: &Dataset,
    tags: &[ConfigTag],
    epochs: usize,
    seed: u64,
    threads: usize,
    variant: CnnVariant,
    shards: usize,
    mp: &MultiprocSpec,
) -> Vec<RunRecord> {
    if tags.is_empty() {
        return Vec::new();
    }
    // Fail fast on invalid shard counts (same rationale as `run_grid`).
    ShardConfig::with_shards(shards);
    mp.validate().expect("invalid multi-process spec");
    // Same aggregate-counter story as `run_grid`: one reset per sweep.
    if crate::obs::counters_enabled() {
        crate::obs::reset_all();
    }
    let per_job = if mp.is_multiproc() { mp.workers } else { shards };
    let pool_threads = (threads / per_job).max(1);
    // Effective concurrency is also bounded by how many cells exist.
    let mp = sized_mp(mp, threads, pool_threads.min(tags.len()));
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(pool_threads)
        .thread_name(|i| format!("cnn-sweep-{i}"))
        .build()
        .expect("building the CNN-sweep thread pool");
    let done = AtomicUsize::new(0);
    pool.install(|| {
        tags.par_iter()
            .map(|&tag| {
                let cfg = cnn_config(ds, tag, epochs, seed, variant, shards);
                let rec = if mp.is_multiproc() {
                    run_one_cnn_mp(ds, tag, &cfg, &mp).expect("multi-process CNN cell failed")
                } else {
                    run_one_cnn(ds, tag, &cfg)
                };
                // numerics-lint: allow(atomics) — sweep progress counter for log lines; relaxed count is enough
                let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                eprintln!(
                    "[{finished}/{} done] cnn/{} {} × {:<10} acc={:.3} ({:.1}s)",
                    tags.len(),
                    variant.label(),
                    rec.dataset,
                    tag.label(),
                    rec.test_accuracy,
                    rec.seconds
                );
                rec
            })
            .collect()
    })
}

/// One row of the Δ-LUT co-optimization sweep (paper §6 future work):
/// accuracy vs. table size vs. hardware cost.
#[derive(Clone, Debug)]
pub struct LutSweepRow {
    /// MAC-table dynamic range.
    pub d_max: u32,
    /// MAC-table `log2(1/r)`.
    pub log2_inv_r: u32,
    /// Table entries (`d_max / r`).
    pub table_len: usize,
    /// First-order MAC gate count (see [`crate::lns::lns_mac_cost`]).
    pub gates: f64,
    /// Test accuracy when training with this table.
    pub test_accuracy: f64,
}

/// Sweep MAC-LUT shapes (the soft-max table stays at the paper's
/// r = 1/64): train one model per (d_max, r) and report the
/// accuracy/size/area trade-off — the paper's named future work.
///
/// The sweep configurations are independent and train concurrently on a
/// dedicated pool of `threads` workers (like [`run_grid`], this bounds
/// peak memory and CPU: each in-flight configuration holds its own model
/// and Δ± tables). Rows come back in `shapes` order.
pub fn lut_sweep(
    ds: &Dataset,
    shapes: &[(u32, u32)],
    epochs: usize,
    hidden: usize,
    seed: u64,
    threads: usize,
) -> Vec<LutSweepRow> {
    if shapes.is_empty() {
        return Vec::new();
    }
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(threads.clamp(1, shapes.len()))
        .thread_name(|i| format!("lut-sweep-{i}"))
        .build()
        .expect("building the LUT-sweep thread pool");
    pool.install(|| {
        shapes
            .par_iter()
            .map(|&(d_max, log2_inv_r)| {
                let spec = LutSpec { d_max, log2_inv_r };
                let cfg = LnsConfig { delta: DeltaMode::Lut(spec), ..LnsConfig::w16_lut() };
                let backend = LnsBackend::new(LnsSystem::new(cfg), SLOPE);
                let mut tc = TrainConfig::paper(ds.classes);
                tc.dims = vec![ds.pixels, hidden, ds.classes];
                tc.epochs = epochs;
                tc.seed = seed;
                let acc = train(&backend, ds, &tc).test.accuracy;
                let row = LutSweepRow {
                    d_max,
                    log2_inv_r,
                    table_len: spec.len(),
                    gates: crate::lns::lns_mac_cost(&cfg).total(),
                    test_accuracy: acc,
                };
                eprintln!(
                    "  lut(d_max={d_max}, r=1/{}) → {} entries, {:.0} gates, acc {:.3}",
                    1 << log2_inv_r,
                    row.table_len,
                    row.gates,
                    acc
                );
                row
            })
            .collect()
    })
}

/// One Fig.-1 row: Δ approximations at difference `d`.
#[derive(Clone, Copy, Debug)]
pub struct Fig1Row {
    /// The difference `d = |X − Y|`.
    pub d: f64,
    /// Exact Δ+(d).
    pub exact_plus: f64,
    /// 20-entry-LUT Δ+(d).
    pub lut_plus: f64,
    /// Bit-shift Δ+(d).
    pub bs_plus: f64,
    /// Exact Δ−(d) (0 at d=0 placeholder).
    pub exact_minus: f64,
    /// LUT Δ−(d).
    pub lut_minus: f64,
    /// Bit-shift Δ−(d).
    pub bs_minus: f64,
}

/// Fig. 1 data: Δ± exact vs the paper's 20-entry LUT vs bit-shift, sampled
/// densely over `d ∈ [0, d_end]`.
pub fn fig1_rows(d_end: f64, samples: usize) -> Vec<Fig1Row> {
    let cfg = LnsConfig::w16_lut();
    let lut = DeltaApprox::new(&cfg, DeltaMode::Lut(LutSpec::MAC20));
    let bs = DeltaApprox::new(&cfg, DeltaMode::BitShift);
    let to_f = |u: i64| u as f64 * cfg.unit();
    (0..samples)
        .map(|i| {
            let d = d_end * i as f64 / (samples - 1) as f64;
            let du = cfg.to_units(d);
            Fig1Row {
                d,
                exact_plus: crate::lns::delta_plus_exact(d),
                lut_plus: to_f(lut.plus(du)),
                bs_plus: to_f(bs.plus(du)),
                exact_minus: if d > 0.0 {
                    crate::lns::delta_minus_exact(d)
                } else {
                    f64::NEG_INFINITY
                },
                lut_minus: if du > 0 {
                    to_f(lut.minus(du).max(-(1 << 20)))
                } else {
                    f64::NEG_INFINITY
                },
                bs_minus: if du > 0 { to_f(bs.minus(du)) } else { f64::NEG_INFINITY },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_dataset, SynthSpec};

    fn tiny() -> Dataset {
        synth_dataset(&SynthSpec {
            name: "tiny".into(),
            classes: 3,
            train_per_class: 30,
            test_per_class: 10,
            strokes: 4,
            jitter_px: 1.5,
            jitter_rot: 0.15,
            noise: 0.04,
            seed: 5,
        })
    }

    #[test]
    fn tags_roundtrip_through_parse() {
        for t in ConfigTag::table1_columns() {
            assert_eq!(ConfigTag::parse(&t.label()), Some(t));
        }
        // Runtime widths beyond the presets parse through the same path.
        for (s, t) in [
            ("lin8", ConfigTag::Lin(8)),
            ("log8-lut", ConfigTag::Log(8, LogMode::Lut)),
            ("log23-bs", ConfigTag::Log(23, LogMode::Bs)),
            ("log16-exact", ConfigTag::Log(16, LogMode::Exact)),
        ] {
            assert_eq!(ConfigTag::parse(s), Some(t), "{s}");
            assert_eq!(t.label(), s);
        }
        for bad in ["nope", "lin3", "log6-lut", "log16-nope", "lin99"] {
            assert_eq!(ConfigTag::parse(bad), None, "{bad}");
        }
        assert_eq!(ConfigTag::Lin(8).default_weight_decay(), 1e-3);
        assert_eq!(ConfigTag::Log(16, LogMode::Lut).default_weight_decay(), 1e-4);
        assert_eq!(ConfigTag::Float.bits(), 0);
        assert_eq!(ConfigTag::Log(8, LogMode::Bs).bits(), 8);
    }

    #[test]
    fn width_configs_resolve_for_parsed_tags() {
        let lc = lns_config_for(ConfigTag::parse("log8-lut").unwrap()).unwrap();
        assert_eq!((lc.total_bits, lc.frac_bits), (8, 2));
        let fc = fixed_config_for(ConfigTag::parse("lin8").unwrap()).unwrap();
        assert_eq!((fc.total_bits, fc.frac_bits), (8, 3));
        assert!(lns_config_for(ConfigTag::Log(5, LogMode::Lut)).is_none(), "invalid width");
        assert!(fixed_config_for(ConfigTag::Float).is_none());
    }

    #[test]
    fn fig1_rows_shape_and_agreement_at_zero() {
        let rows = fig1_rows(11.0, 56);
        assert_eq!(rows.len(), 56);
        // At d = 0: exact Δ+ = 1, LUT hits it exactly, bit-shift gives 1.
        assert!((rows[0].exact_plus - 1.0).abs() < 1e-9);
        assert!((rows[0].lut_plus - 1.0).abs() < 0.01);
        assert!((rows[0].bs_plus - 1.0).abs() < 1e-9);
        // Far out: everything ≈ 0.
        let last = rows.last().unwrap();
        assert!(last.exact_plus < 0.001);
        assert_eq!(last.lut_plus, 0.0);
    }

    #[test]
    fn run_one_produces_curve() {
        let ds = tiny();
        let mut cfg = paper_config(&ds, ConfigTag::Float, 2, 12, 3);
        cfg.sgd.lr = 0.02;
        let rec = run_one(&ds, ConfigTag::Float, &cfg);
        assert_eq!(rec.curve.len(), 2);
        assert!(rec.test_accuracy > 0.2, "better than chance");
    }

    #[test]
    fn grid_runs_all_cells_in_parallel() {
        let ds = vec![tiny()];
        let mp = MultiprocSpec::new(1);
        let recs = run_grid(&ds, &[ConfigTag::Float, ConfigTag::Lin(16)], 1, 8, 3, 2, 1, &mp);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].tag, ConfigTag::Float);
        assert_eq!(recs[1].tag, ConfigTag::Lin(16));
    }

    #[test]
    fn sharded_grid_reproduces_serial_grid() {
        // The shards axis moves wall-clock only: identical accuracies.
        let ds = vec![tiny()];
        let mp = MultiprocSpec::new(1);
        let a = run_grid(&ds, &[ConfigTag::Float], 1, 8, 3, 2, 1, &mp);
        let b = run_grid(&ds, &[ConfigTag::Float], 1, 8, 3, 2, 2, &mp);
        assert_eq!(a[0].test_accuracy, b[0].test_accuracy);
        assert_eq!(a[0].test_loss, b[0].test_loss);
    }

    #[test]
    fn cnn_grid_runs_tags_in_order() {
        use crate::data::{stripes_dataset, StripeSpec};
        let ds = stripes_dataset(&StripeSpec {
            train_per_class: 10,
            test_per_class: 4,
            ..StripeSpec::cnn_default(1.0, 5)
        });
        let mp = MultiprocSpec::new(1);
        let tags = [ConfigTag::Float, ConfigTag::Log(16, LogMode::Lut)];
        let recs = cnn_grid(&ds, &tags, 1, 3, 2, CnnVariant::Pooled, 1, &mp);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].tag, ConfigTag::Float);
        assert_eq!(recs[1].tag, ConfigTag::Log(16, LogMode::Lut));
        assert_eq!(recs[0].curve.len(), 1);
        assert_eq!(recs[0].dataset, "stripes");
    }

    #[test]
    fn width_frontier_emits_expected_grid() {
        let ds = vec![tiny()];
        let recs = width_frontier(&ds, &[8, 12], 1, 6, 3);
        // float + 3 columns × 2 widths + 2 mixed-precision rows.
        assert_eq!(recs.len(), 9);
        assert_eq!(recs[0].label, "float");
        assert_eq!(recs[0].bits, 0);
        assert_eq!(recs[1].label, "lin8");
        assert_eq!(recs[2].label, "log8-lut");
        assert_eq!(recs[3].label, "log8-bs");
        assert_eq!(recs[4].label, "lin12");
        let mixed: Vec<&FrontierRecord> =
            recs.iter().filter(|r| r.precision != "uniform").collect();
        assert_eq!(mixed.len(), 2);
        for m in &mixed {
            assert_eq!(m.label, "log12-lut", "mixed rows ride the widest log-LUT base");
            assert_eq!(m.bits, 8, "mixed rows report the narrowest assigned width");
        }
        assert_eq!(mixed[0].precision, "8,-");
        assert_eq!(mixed[1].precision, "-,8");
    }

    #[test]
    fn cnn_grid_strided_variant_trains() {
        use crate::data::{stripes_dataset, StripeSpec};
        let ds = stripes_dataset(&StripeSpec {
            train_per_class: 10,
            test_per_class: 4,
            ..StripeSpec::cnn_default(1.0, 6)
        });
        let mp = MultiprocSpec::new(1);
        let recs = cnn_grid(&ds, &[ConfigTag::Float], 1, 3, 2, CnnVariant::StridedV1, 2, &mp);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].curve.len(), 1);
    }
}
