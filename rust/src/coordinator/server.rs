//! Batched inference server (std-thread implementation; tokio is not
//! available offline).
//!
//! Demonstrates the deployment story: clients submit single images, a
//! collector thread groups them into batches (up to `max_batch`, waiting
//! at most `max_wait` for stragglers) and hands each batch to a pluggable
//! handler — the native LNS engine or a PJRT artifact executable. This is
//! the standard dynamic-batching pattern (vLLM-style router, scaled to
//! this paper's workload).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A single inference request: one 784-pixel 8-bit image.
pub struct InferRequest {
    /// Image pixels.
    pub image: Vec<u8>,
    reply: mpsc::Sender<InferReply>,
}

/// Reply to one request.
#[derive(Clone, Copy, Debug)]
pub struct InferReply {
    /// Predicted class.
    pub class: usize,
    /// End-to-end latency for this request.
    pub latency: Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

/// Rolling server statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// Requests served.
    pub served: u64,
    /// Batches executed.
    pub batches: u64,
    /// Total request latency (for mean computation).
    pub total_latency: Duration,
    /// Max latency seen.
    pub max_latency: Duration,
}

impl ServerStats {
    /// Mean per-request latency.
    pub fn mean_latency(&self) -> Duration {
        if self.served == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.served as u32
        }
    }

    /// Mean batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<(Instant, InferRequest)>,
}

impl Client {
    /// Submit one image and wait for its prediction.
    pub fn infer(&self, image: Vec<u8>) -> Option<InferReply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((Instant::now(), InferRequest { image, reply: rtx })).ok()?;
        rrx.recv().ok()
    }
}

/// The batching server.
pub struct BatchServer {
    client_tx: mpsc::Sender<(Instant, InferRequest)>,
    stats: Arc<Mutex<ServerStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl BatchServer {
    /// Start the collector thread. `handler` maps a batch of images
    /// (row-major `[n × pixels]`) to `n` predicted classes.
    pub fn start<F>(max_batch: usize, max_wait: Duration, pixels: usize, handler: F) -> Self
    where
        F: Fn(&[u8], usize) -> Vec<usize> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<(Instant, InferRequest)>();
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let stats_w = stats.clone();
        let worker = std::thread::spawn(move || {
            loop {
                // Block for the first request of a batch.
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break, // all clients gone → shut down
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + max_wait;
                while batch.len() < max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                // Assemble and run the batch.
                let mut flat = Vec::with_capacity(batch.len() * pixels);
                for (_, req) in &batch {
                    assert_eq!(req.image.len(), pixels, "bad image size");
                    flat.extend_from_slice(&req.image);
                }
                let preds = handler(&flat, batch.len());
                assert_eq!(preds.len(), batch.len(), "handler must return one class per image");
                let bsize = batch.len();
                let mut st = stats_w.lock().unwrap();
                st.batches += 1;
                for ((t0, req), &class) in batch.into_iter().zip(&preds) {
                    let latency = t0.elapsed();
                    st.served += 1;
                    st.total_latency += latency;
                    st.max_latency = st.max_latency.max(latency);
                    let _ = req.reply.send(InferReply { class, latency, batch_size: bsize });
                }
            }
        });
        BatchServer { client_tx: tx, stats, worker: Some(worker) }
    }

    /// A new submission handle.
    pub fn client(&self) -> Client {
        Client { tx: self.client_tx.clone() }
    }

    /// Snapshot of the statistics.
    pub fn stats(&self) -> ServerStats {
        *self.stats.lock().unwrap()
    }

    /// Stop accepting and join the worker (all [`Client`] handles must be
    /// dropped first or the worker keeps waiting for their requests).
    pub fn shutdown(self) -> ServerStats {
        let BatchServer { client_tx, stats, worker } = self;
        drop(client_tx);
        if let Some(w) = worker {
            let _ = w.join();
        }
        let s = *stats.lock().unwrap();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_and_batches() {
        let server = BatchServer::start(4, Duration::from_millis(5), 4, |flat, n| {
            // Predict the index of the max pixel (mod 4) per image.
            (0..n)
                .map(|i| {
                    let img = &flat[i * 4..(i + 1) * 4];
                    img.iter().enumerate().max_by_key(|(_, &p)| p).unwrap().0
                })
                .collect()
        });
        let client = server.client();
        let mut handles = Vec::new();
        for i in 0..8usize {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                let mut img = vec![0u8; 4];
                img[i % 4] = 200;
                c.infer(img).unwrap()
            }));
        }
        let replies: Vec<InferReply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, r) in replies.iter().enumerate() {
            assert_eq!(r.class, i % 4);
            assert!(r.batch_size >= 1);
        }
        let st = server.stats();
        assert_eq!(st.served, 8);
        assert!(st.batches <= 8);
        assert!(st.mean_batch() >= 1.0);
    }

    #[test]
    fn single_request_completes_within_wait_window() {
        let server = BatchServer::start(64, Duration::from_millis(10), 2, |_, n| vec![0; n]);
        let c = server.client();
        let t0 = Instant::now();
        let r = c.infer(vec![1, 2]).unwrap();
        assert_eq!(r.class, 0);
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert_eq!(r.batch_size, 1);
    }
}
