//! The coordinator's server roles: batched inference *and* the
//! multi-process training driver (std-thread/std-process implementation;
//! tokio is not available offline).
//!
//! **Inference** ([`BatchServer`]): clients submit single images, a
//! collector thread groups them into batches (up to `max_batch`, waiting
//! at most `max_wait` for stragglers) and hands each batch to a pluggable
//! handler — the native LNS engine or a PJRT artifact executable. This is
//! the standard dynamic-batching pattern (vLLM-style router, scaled to
//! this paper's workload).
//!
//! **Training** ([`train_multiproc`] / [`train_cnn_multiproc`]): spawns
//! `N` local `lnsdnn worker` processes (over stdio pipes or loopback
//! TCP per [`MultiprocSpec`]), then hands the connections to the
//! transport-agnostic protocol driver in [`crate::train::multiproc`].
//! This module owns only the *process* concerns — spawning, connection
//! establishment, kill-on-error, exit-status collection — so the
//! protocol stays testable without a binary.

use crate::data::Dataset;
use crate::nn::{Cnn, Mlp};
use crate::tensor::Backend;
use crate::train::multiproc::{self, JobEnv, PeerIo, Transport};
use crate::train::shard::MAX_SHARDS;
use crate::train::wire::WireElem;
use crate::train::{CnnTrainConfig, TrainConfig, TrainResult};
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio as ProcStdio};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A single inference request: one 784-pixel 8-bit image.
pub struct InferRequest {
    /// Image pixels.
    pub image: Vec<u8>,
    reply: mpsc::Sender<InferReply>,
}

/// Reply to one request.
#[derive(Clone, Copy, Debug)]
pub struct InferReply {
    /// Predicted class.
    pub class: usize,
    /// End-to-end latency for this request.
    pub latency: Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

/// Rolling server statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// Requests served.
    pub served: u64,
    /// Batches executed.
    pub batches: u64,
    /// Total request latency (for mean computation).
    pub total_latency: Duration,
    /// Max latency seen.
    pub max_latency: Duration,
}

impl ServerStats {
    /// Mean per-request latency.
    pub fn mean_latency(&self) -> Duration {
        if self.served == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.served as u32
        }
    }

    /// Mean batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<(Instant, InferRequest)>,
}

impl Client {
    /// Submit one image and wait for its prediction.
    pub fn infer(&self, image: Vec<u8>) -> Option<InferReply> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send((Instant::now(), InferRequest { image, reply: rtx })).ok()?;
        rrx.recv().ok()
    }
}

/// The batching server.
pub struct BatchServer {
    client_tx: mpsc::Sender<(Instant, InferRequest)>,
    stats: Arc<Mutex<ServerStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl BatchServer {
    /// Start the collector thread. `handler` maps a batch of images
    /// (row-major `[n × pixels]`) to `n` predicted classes.
    pub fn start<F>(max_batch: usize, max_wait: Duration, pixels: usize, handler: F) -> Self
    where
        F: Fn(&[u8], usize) -> Vec<usize> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<(Instant, InferRequest)>();
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let stats_w = stats.clone();
        let worker = std::thread::spawn(move || {
            loop {
                // Block for the first request of a batch.
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break, // all clients gone → shut down
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + max_wait;
                while batch.len() < max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                // Assemble and run the batch.
                let mut flat = Vec::with_capacity(batch.len() * pixels);
                for (_, req) in &batch {
                    assert_eq!(req.image.len(), pixels, "bad image size");
                    flat.extend_from_slice(&req.image);
                }
                let preds = handler(&flat, batch.len());
                assert_eq!(preds.len(), batch.len(), "handler must return one class per image");
                let bsize = batch.len();
                let mut st = stats_w.lock().unwrap();
                st.batches += 1;
                for ((t0, req), &class) in batch.into_iter().zip(&preds) {
                    let latency = t0.elapsed();
                    st.served += 1;
                    st.total_latency += latency;
                    st.max_latency = st.max_latency.max(latency);
                    let _ = req.reply.send(InferReply { class, latency, batch_size: bsize });
                }
            }
        });
        BatchServer { client_tx: tx, stats, worker: Some(worker) }
    }

    /// A new submission handle.
    pub fn client(&self) -> Client {
        Client { tx: self.client_tx.clone() }
    }

    /// Snapshot of the statistics.
    pub fn stats(&self) -> ServerStats {
        *self.stats.lock().unwrap()
    }

    /// Stop accepting and join the worker (all [`Client`] handles must be
    /// dropped first or the worker keeps waiting for their requests).
    pub fn shutdown(self) -> ServerStats {
        let BatchServer { client_tx, stats, worker } = self;
        drop(client_tx);
        if let Some(w) = worker {
            let _ = w.join();
        }
        let s = *stats.lock().unwrap();
        s
    }
}

// ---------------------------------------------------------------------
// Multi-process training driver
// ---------------------------------------------------------------------

/// How to run a multi-process training job: worker count, transport,
/// which binary to spawn, and the worker environment.
#[derive(Clone, Debug)]
pub struct MultiprocSpec {
    /// Worker processes to spawn (1 is legal — one worker computes every
    /// slot — but the interesting counts are ≥ 2).
    pub workers: usize,
    /// stdio pipes or loopback TCP.
    pub transport: Transport,
    /// Worker binary. `None` = `std::env::current_exe()`, which is right
    /// when the coordinator *is* the `lnsdnn` CLI; tests and embedders
    /// must point this at the `lnsdnn` binary explicitly.
    pub worker_exe: Option<PathBuf>,
    /// Rayon threads per worker process (0 = library default). Pick
    /// ≈ cores / workers to avoid oversubscription; the trained bits are
    /// identical either way.
    pub worker_threads: usize,
    /// Leaky/llReLU slope the coordinator's backend uses — workers
    /// rebuild their backend from the tag + this value.
    pub slope: f64,
}

impl MultiprocSpec {
    /// Spec with the given worker count and stdio transport.
    pub fn new(workers: usize) -> Self {
        MultiprocSpec {
            workers,
            transport: Transport::Stdio,
            worker_exe: None,
            worker_threads: 0,
            slope: 0.01,
        }
    }

    /// Does this spec actually fan out across processes? Grid drivers use
    /// the in-process trainers below this threshold.
    pub fn is_multiproc(&self) -> bool {
        self.workers > 1
    }

    /// Range-check the spec (same worker bound as the in-process
    /// trainer's [`crate::train::ShardConfig`]).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (1..=MAX_SHARDS).contains(&self.workers),
            "workers must be in 1..={MAX_SHARDS}, got {}",
            self.workers
        );
        Ok(())
    }
}

/// Train an MLP across `spec.workers` local worker processes. Bit-
/// identical to [`crate::train::train`] at any worker count (see
/// `tests/multiproc_determinism.rs`); `cfg.shard` is ignored because the
/// processes are the shards.
pub fn train_multiproc<B: Backend>(
    backend: &B,
    ds: &Dataset,
    cfg: &TrainConfig,
    spec: &MultiprocSpec,
) -> Result<TrainResult<Mlp<B::E>>>
where
    B::E: WireElem,
{
    spec.validate()?;
    let (peers, children) = spawn_workers(spec)?;
    let env = JobEnv { slope: spec.slope, worker_threads: spec.worker_threads };
    let result = multiproc::coordinate_mlp(backend, ds, cfg, &env, peers);
    finish_children(children, result)
}

/// CNN twin of [`train_multiproc`] (bit-identical to
/// [`crate::train::train_cnn`]).
pub fn train_cnn_multiproc<B: Backend>(
    backend: &B,
    ds: &Dataset,
    cfg: &CnnTrainConfig,
    spec: &MultiprocSpec,
) -> Result<TrainResult<Cnn<B::E>>>
where
    B::E: WireElem,
{
    spec.validate()?;
    let (peers, children) = spawn_workers(spec)?;
    let env = JobEnv { slope: spec.slope, worker_threads: spec.worker_threads };
    let result = multiproc::coordinate_cnn(backend, ds, cfg, &env, peers);
    finish_children(children, result)
}

fn worker_exe(spec: &MultiprocSpec) -> Result<PathBuf> {
    match &spec.worker_exe {
        Some(p) => Ok(p.clone()),
        None => std::env::current_exe().context("resolving the lnsdnn binary for worker spawn"),
    }
}

/// Bytes of worker stderr kept per child for post-mortem error reports.
const STDERR_TAIL_BYTES: usize = 4096;

/// A spawned worker process plus its rank and captured-stderr machinery.
/// Stderr is piped (not inherited): a drainer thread forwards every byte
/// to the coordinator's own stderr — so worker diagnostics stay live —
/// while keeping the last [`STDERR_TAIL_BYTES`] for attachment to
/// dead-worker errors, where "worker 3 exited with signal 9" alone is
/// useless forensics.
struct WorkerProc {
    rank: usize,
    child: Child,
    stderr_tail: Arc<Mutex<Vec<u8>>>,
    drainer: Option<std::thread::JoinHandle<()>>,
}

impl WorkerProc {
    fn new(rank: usize, mut child: Child) -> Self {
        let stderr_tail = Arc::new(Mutex::new(Vec::new()));
        let drainer = child.stderr.take().map(|err| {
            let tail = stderr_tail.clone();
            std::thread::spawn(move || drain_stderr(err, &tail))
        });
        WorkerProc { rank, child, stderr_tail, drainer }
    }

    /// Wait for the drainer to see EOF (the child must be dead or dying,
    /// or this blocks until it is).
    fn join_drainer(&mut self) {
        if let Some(h) = self.drainer.take() {
            let _ = h.join();
        }
    }

    /// The captured stderr tail, lossily decoded.
    fn tail(&self) -> String {
        let tail = self.stderr_tail.lock().unwrap_or_else(|p| p.into_inner());
        if tail.is_empty() {
            "<no stderr output>".into()
        } else {
            String::from_utf8_lossy(&tail).into_owned()
        }
    }
}

fn drain_stderr(mut err: impl Read, tail: &Mutex<Vec<u8>>) {
    let mut buf = [0u8; 1024];
    loop {
        match err.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                let _ = std::io::stderr().write_all(&buf[..n]);
                let mut t = tail.lock().unwrap_or_else(|p| p.into_inner());
                t.extend_from_slice(&buf[..n]);
                if t.len() > STDERR_TAIL_BYTES {
                    let cut = t.len() - STDERR_TAIL_BYTES;
                    t.drain(..cut);
                }
            }
        }
    }
}

/// CLI argv for one worker process. Workers get `--obs` when this
/// coordinator has counters enabled, so their heartbeat frames carry
/// real telemetry; observation never changes trained bits either way.
fn worker_args(transport: Transport, addr: Option<&str>) -> Vec<String> {
    let mut args = vec!["worker".to_string(), "--transport".into(), transport.label().into()];
    if let Some(addr) = addr {
        args.push("--connect".into());
        args.push(addr.into());
    }
    if crate::obs::counters_enabled() {
        args.push("--obs".into());
    }
    args
}

/// Spawn the worker processes and establish one framed duplex connection
/// per worker. On any error, every child spawned so far is killed.
fn spawn_workers(spec: &MultiprocSpec) -> Result<(Vec<PeerIo>, Vec<WorkerProc>)> {
    let mut children = Vec::new();
    match spawn_workers_inner(spec, &mut children) {
        Ok(peers) => Ok((peers, children)),
        Err(e) => {
            kill_children(&mut children);
            Err(e)
        }
    }
}

fn spawn_workers_inner(
    spec: &MultiprocSpec,
    children: &mut Vec<WorkerProc>,
) -> Result<Vec<PeerIo>> {
    let exe = worker_exe(spec)?;
    let mut peers = Vec::with_capacity(spec.workers);
    match spec.transport {
        Transport::Stdio => {
            for rank in 0..spec.workers {
                let mut child = Command::new(&exe)
                    .args(worker_args(Transport::Stdio, None))
                    .stdin(ProcStdio::piped())
                    .stdout(ProcStdio::piped())
                    .stderr(ProcStdio::piped())
                    .spawn()
                    .with_context(|| format!("spawning worker {rank} from {}", exe.display()))?;
                let stdin = child.stdin.take().expect("piped worker stdin");
                let stdout = child.stdout.take().expect("piped worker stdout");
                peers.push(PeerIo {
                    rx: Box::new(BufReader::new(stdout)),
                    tx: Box::new(BufWriter::new(stdin)),
                });
                children.push(WorkerProc::new(rank, child));
            }
        }
        Transport::Tcp => {
            let listener = TcpListener::bind("127.0.0.1:0").context("binding the listener")?;
            let addr = listener.local_addr().context("reading listener address")?.to_string();
            for rank in 0..spec.workers {
                let child = Command::new(&exe)
                    .args(worker_args(Transport::Tcp, Some(&addr)))
                    .stdin(ProcStdio::null())
                    .stderr(ProcStdio::piped())
                    .spawn()
                    .with_context(|| format!("spawning worker {rank} from {}", exe.display()))?;
                children.push(WorkerProc::new(rank, child));
            }
            // Accept with a deadline, watching for children that die
            // before connecting (a blocking accept would hang forever).
            listener.set_nonblocking(true).context("setting listener non-blocking")?;
            let deadline = Instant::now() + Duration::from_secs(30);
            while peers.len() < spec.workers {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).context("resetting socket mode")?;
                        let _ = stream.set_nodelay(true);
                        let rx = stream.try_clone().context("cloning worker socket")?;
                        peers.push(PeerIo {
                            rx: Box::new(BufReader::new(rx)),
                            tx: Box::new(BufWriter::new(stream)),
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        for c in children.iter_mut() {
                            if let Some(status) = c.child.try_wait()? {
                                let rank = c.rank;
                                c.join_drainer();
                                bail!(
                                    "worker {rank} exited with {status} before connecting; \
                                     stderr tail:\n{}",
                                    c.tail()
                                );
                            }
                        }
                        if Instant::now() >= deadline {
                            bail!(
                                "timed out waiting for {} worker connection(s)",
                                spec.workers - peers.len()
                            );
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e).context("accepting worker connection"),
                }
            }
        }
    }
    Ok(peers)
}

fn kill_children(children: &mut [WorkerProc]) {
    for c in children.iter_mut() {
        let _ = c.child.kill();
        let _ = c.child.wait();
        c.join_drainer();
    }
}

/// On success, reap every worker and require a clean exit; on error, kill
/// the fleet so no orphan keeps the pipes (or CI) alive. Either way a
/// failing worker's report carries its rank and captured stderr tail
/// (the protocol error from [`multiproc`] already carries its
/// last-heartbeat progress).
fn finish_children<T>(mut children: Vec<WorkerProc>, result: Result<T>) -> Result<T> {
    match result {
        Ok(v) => {
            for c in children.iter_mut() {
                let rank = c.rank;
                let status =
                    c.child.wait().with_context(|| format!("reaping worker {rank}"))?;
                c.join_drainer();
                ensure!(
                    status.success(),
                    "worker {rank} exited with {status}; stderr tail:\n{}",
                    c.tail()
                );
            }
            Ok(v)
        }
        Err(e) => {
            kill_children(&mut children);
            let mut tails = String::new();
            for c in &children {
                let t = c.tail();
                if t != "<no stderr output>" {
                    tails.push_str(&format!(
                        "\n--- worker {} stderr tail ---\n{}",
                        c.rank,
                        t.trim_end()
                    ));
                }
            }
            if tails.is_empty() {
                Err(e)
            } else {
                Err(e.context(format!("captured worker stderr:{tails}")))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiproc_spec_validates_bounds() {
        assert!(MultiprocSpec::new(1).validate().is_ok());
        assert!(MultiprocSpec::new(MAX_SHARDS).validate().is_ok());
        assert!(MultiprocSpec::new(0).validate().is_err());
        assert!(MultiprocSpec::new(MAX_SHARDS + 1).validate().is_err());
        assert!(!MultiprocSpec::new(1).is_multiproc());
        assert!(MultiprocSpec::new(2).is_multiproc());
        assert_eq!(MultiprocSpec::new(2).transport, Transport::Stdio);
    }

    #[test]
    fn worker_args_carry_transport_and_address() {
        let a = worker_args(Transport::Tcp, Some("127.0.0.1:9"));
        assert_eq!(&a[..3], &["worker".to_string(), "--transport".into(), "tcp".into()]);
        assert!(a.contains(&"--connect".to_string()));
        assert!(a.contains(&"127.0.0.1:9".to_string()));
        let b = worker_args(Transport::Stdio, None);
        assert_eq!(&b[..3], &["worker".to_string(), "--transport".into(), "stdio".into()]);
        assert!(!b.contains(&"--connect".to_string()));
    }

    #[test]
    fn serves_and_batches() {
        let server = BatchServer::start(4, Duration::from_millis(5), 4, |flat, n| {
            // Predict the index of the max pixel (mod 4) per image.
            (0..n)
                .map(|i| {
                    let img = &flat[i * 4..(i + 1) * 4];
                    img.iter().enumerate().max_by_key(|(_, &p)| p).unwrap().0
                })
                .collect()
        });
        let client = server.client();
        let mut handles = Vec::new();
        for i in 0..8usize {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                let mut img = vec![0u8; 4];
                img[i % 4] = 200;
                c.infer(img).unwrap()
            }));
        }
        let replies: Vec<InferReply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (i, r) in replies.iter().enumerate() {
            assert_eq!(r.class, i % 4);
            assert!(r.batch_size >= 1);
        }
        let st = server.stats();
        assert_eq!(st.served, 8);
        assert!(st.batches <= 8);
        assert!(st.mean_batch() >= 1.0);
    }

    #[test]
    fn single_request_completes_within_wait_window() {
        let server = BatchServer::start(64, Duration::from_millis(10), 2, |_, n| vec![0; n]);
        let c = server.client();
        let t0 = Instant::now();
        let r = c.infer(vec![1, 2]).unwrap();
        assert_eq!(r.class, 0);
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert_eq!(r.batch_size, 1);
    }
}
