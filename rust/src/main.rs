//! `lnsdnn` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands regenerate the paper's evaluation artifacts:
//! * `fig1` — Δ± approximation curves → `results/fig1_delta.csv`
//! * `fig2` — learning curves → `results/fig2_<dataset>.csv`
//! * `table1` — the accuracy table → `results/table1.{md,csv}`
//! * `bitwidth` — the Eq. 15 bound table
//! * `train` — one (dataset × config) run with full logging
//! * `cnn` — the conv workload sweep
//! * `worker` — multi-process training worker (spawned by `--workers N`)
//! * `artifacts` — list/verify the AOT bundle via the PJRT runtime
//!
//! Argument parsing is hand-rolled (`clap` is unavailable offline); every
//! flag is `--key value`.

#![forbid(unsafe_code)]

use anyhow::{bail, Context, Result};
use lnsdnn::coordinator::experiments::{ConfigTag, LogMode};
use lnsdnn::coordinator::{experiments, report, MultiprocSpec};
use lnsdnn::data;
use lnsdnn::lns;
use lnsdnn::runtime::{ArtifactRegistry, Runtime};
use lnsdnn::train::Transport;
use std::collections::HashMap;
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parsed `--key value` flags after the subcommand.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut m = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let k = &args[i];
            if !k.starts_with("--") {
                bail!("expected --flag, got '{k}'");
            }
            let v = args.get(i + 1).with_context(|| format!("missing value for {k}"))?;
            m.insert(k[2..].to_string(), v.clone());
            i += 2;
        }
        Ok(Flags(m))
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.0.get(k).map(|s| s.as_str())
    }

    fn f64(&self, k: &str, default: f64) -> Result<f64> {
        match self.get(k) {
            Some(v) => v.parse().with_context(|| format!("--{k} must be a number")),
            None => Ok(default),
        }
    }

    fn usize(&self, k: &str, default: usize) -> Result<usize> {
        match self.get(k) {
            Some(v) => v.parse().with_context(|| format!("--{k} must be an integer")),
            None => Ok(default),
        }
    }

    fn u64(&self, k: &str, default: u64) -> Result<u64> {
        match self.get(k) {
            Some(v) => v.parse().with_context(|| format!("--{k} must be an integer")),
            None => Ok(default),
        }
    }
}

const USAGE: &str = "lnsdnn — LNS DNN training (paper reproduction)

USAGE: lnsdnn <command> [--flag value ...]

COMMANDS
  fig1      [--dmax 11] [--samples 441] [--out results]
  fig2      [--dataset mnist] [--epochs 20] [--scale 0.1] [--hidden 100]
            [--seed 7] [--threads N] [--shards 1] [--workers 1]
            [--transport stdio|tcp] [--worker-threads 0] [--out results]
            [--data-dir DIR]
  table1    [--epochs 20] [--scale 0.1] [--hidden 100] [--seed 7]
            [--threads N] [--shards 1] [--workers 1]
            [--transport stdio|tcp] [--worker-threads 0] [--out results]
            [--data-dir DIR] [--datasets a,b] [--widths 8,12,16]
            (--widths W,... switches to the accuracy-vs-bitwidth frontier:
             lin/log-lut/log-bs columns at each width plus per-layer
             mixed-precision rows, run sequentially with per-cell range
             occupancy → results/width_frontier.{md,csv})
  bitwidth  (prints the Eq. 15 bound table)
  cost      (first-order MAC gate counts: LNS vs linear, per config)
  train     --config log16-lut [--dataset mnist] [--epochs 20]
            [--scale 0.1] [--hidden 100] [--lr 0.01] [--wd 0.0001]
            [--batch 5] [--seed 7] [--shards 1] [--workers 1]
            [--transport stdio|tcp] [--worker-threads 0] [--data-dir DIR]
            [--precision 8,16]
            (--precision assigns per-layer storage widths on the base
             word, layer-ordered, '-' = keep the base width; weights are
             snapped to the narrower grid after init and every update)
  cnn       [--dataset stripes] [--configs float,log16-lut,log16-bs]
            [--arch lenet|strided-v1] [--epochs 8] [--scale 1.0]
            [--seed 7] [--threads N] [--shards 1] [--workers 1]
            [--transport stdio|tcp] [--worker-threads 0] [--out results]
            (conv workload sweep)
  worker    --transport stdio|tcp [--connect HOST:PORT]
            (multi-process training worker; spawned by the coordinator,
             not normally run by hand)
  artifacts [--dir artifacts] (list and smoke-compile the AOT bundle)

CONFIG TAGS
  float lin<W> log<W>-lut log<W>-bs log<W>-exact — W is a runtime word
  width (lin: 6..=31, log: 7..=32); the paper's columns are lin12 lin16
  log12-lut log16-lut log12-bs log16-bs log16-exact, and 8-bit presets
  (lin8, log8-lut, log8-bs) ride the same validators.

OBSERVABILITY (any command; most useful on train/cnn/fig2/table1/worker)
  --obs            enable numerics counters + a per-epoch stderr table,
                   plus an end-of-run summary at <out>/obs_summary.md
  --trace FILE     record phase spans; writes Chrome trace JSON on exit
  --metrics FILE   stream per-epoch counter snapshots as JSON lines
  --obs-listen A   serve live /metrics (Prometheus), /health and /trace
                   on A (e.g. 127.0.0.1:9184; port 0 picks one and the
                   resolved address is printed to stderr)
Observation is read-only: trained weights are bit-identical with or
without these flags (see docs/OBSERVABILITY.md).

Datasets default to the synthetic paper stand-ins; pass --data-dir with
real IDX files (mnist/fmnist/emnistd/emnistl tags) to use them instead.
--scale shrinks the synthetic datasets (1.0 = full paper scale).
--shards N runs each training job data-parallel over N in-process
workers; --workers N runs it over N worker *processes* exchanging
serialized gradient frames (stdio pipes or loopback TCP). Trained
weights are bit-identical for every N on both axes (see README
\"Sharded training\" / \"Multi-process training\" and docs/NUMERICS.md).";

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    // `--obs` is the one bare switch (every other flag is `--key value`),
    // so it is peeled off before the strict k/v parse.
    let mut rest: Vec<String> = args[1..].to_vec();
    let obs_switch = rest.iter().any(|a| a == "--obs");
    rest.retain(|a| a != "--obs");
    let flags = Flags::parse(&rest)?;
    if obs_switch {
        lnsdnn::obs::set_counters(true);
        lnsdnn::obs::metrics::set_table(true);
    }
    let trace = obs_flags(&flags)?;
    // `--obs-listen ADDR` starts the blocking HTTP endpoint before the
    // command runs; counters must be on or every scrape would read zeros.
    let server = match flags.get("obs-listen") {
        Some(addr) => {
            lnsdnn::obs::set_counters(true);
            lnsdnn::obs::set_trace(true);
            let srv = lnsdnn::obs::serve::ObsServer::start(addr)
                .with_context(|| format!("binding --obs-listen {addr}"))?;
            // CI and scripts parse this line to learn the resolved port
            // when ADDR asked for an ephemeral one (`127.0.0.1:0`).
            eprintln!("[obs] listening on http://{}", srv.addr());
            Some(srv)
        }
        None => None,
    };
    let result = match cmd.as_str() {
        "fig1" => cmd_fig1(&flags),
        "fig2" => cmd_fig2(&flags),
        "table1" => cmd_table1(&flags),
        "bitwidth" => cmd_bitwidth(),
        "cost" => cmd_cost(),
        "train" => cmd_train(&flags),
        "cnn" => cmd_cnn(&flags),
        "worker" => cmd_worker(&flags),
        "artifacts" => cmd_artifacts(&flags),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    };
    // Write the trace even when the command failed — a trace of the run
    // that died is exactly what the flag is for.
    if let Some(path) = &trace {
        lnsdnn::obs::trace::write_chrome_trace(path)
            .with_context(|| format!("writing --trace file {}", path.display()))?;
        eprintln!("[obs] Chrome trace → {}", path.display());
    }
    // Workers are excluded: a coordinator passes `--obs` through to all
    // N of them, and N processes racing on one summary file helps nobody
    // — worker telemetry reaches the coordinator via heartbeats instead.
    if obs_switch && result.is_ok() && cmd != "worker" {
        let path = out_dir(&flags).join("obs_summary.md");
        report::write_markdown(&path, &report::obs_markdown(cmd))?;
        eprintln!("[obs] summary → {}", path.display());
    }
    // Stop the endpoint last so a scraper can still read the final state
    // of a failed run while the error propagates.
    if let Some(srv) = server {
        srv.stop();
    }
    result
}

/// Wire the `--trace` / `--metrics` observability sinks. Returns the
/// Chrome-trace output path so [`run`] can render it once the command
/// finishes (span events accumulate until then).
fn obs_flags(flags: &Flags) -> Result<Option<PathBuf>> {
    if let Some(p) = flags.get("metrics") {
        lnsdnn::obs::set_counters(true);
        lnsdnn::obs::metrics::set_metrics_path(std::path::Path::new(p))
            .with_context(|| format!("creating --metrics sink {p}"))?;
    }
    let trace = flags.get("trace").map(PathBuf::from);
    if trace.is_some() {
        lnsdnn::obs::set_trace(true);
    }
    Ok(trace)
}

fn out_dir(flags: &Flags) -> PathBuf {
    PathBuf::from(flags.get("out").unwrap_or("results"))
}

/// Parse and range-check `--shards` so bad values surface as a CLI error
/// (like every other flag) instead of a panic — the bound itself lives
/// in [`lnsdnn::train::ShardConfig::try_with_shards`], the single source
/// of truth.
fn shards_flag(flags: &Flags) -> Result<usize> {
    let n = flags.usize("shards", 1)?;
    lnsdnn::train::ShardConfig::try_with_shards(n)
        .map_err(|e| anyhow::anyhow!("--shards: {e}"))?;
    Ok(n)
}

/// Parse the multi-process axis (`--workers`, `--transport`,
/// `--worker-threads`) into a [`MultiprocSpec`]. `--workers 1` (the
/// default) keeps everything in-process.
fn mp_spec(flags: &Flags) -> Result<MultiprocSpec> {
    let workers = flags.usize("workers", 1)?;
    let t_s = flags.get("transport").unwrap_or("stdio");
    let transport =
        Transport::parse(t_s).with_context(|| format!("bad --transport '{t_s}' (stdio|tcp)"))?;
    let mut spec = MultiprocSpec::new(workers);
    spec.transport = transport;
    spec.worker_threads = flags.usize("worker-threads", 0)?;
    spec.slope = experiments::SLOPE;
    spec.validate().map_err(|e| anyhow::anyhow!("--workers: {e}"))?;
    Ok(spec)
}

fn cmd_worker(flags: &Flags) -> Result<()> {
    let t_s = flags.get("transport").unwrap_or("stdio");
    let transport =
        Transport::parse(t_s).with_context(|| format!("bad --transport '{t_s}' (stdio|tcp)"))?;
    lnsdnn::train::multiproc::run_worker(transport, flags.get("connect"))
}

fn load_dataset(flags: &Flags, name: &str) -> Result<data::Dataset> {
    let scale = flags.f64("scale", 0.1)?;
    let seed = flags.u64("seed", 7)?;
    if let Some(dir) = flags.get("data-dir") {
        let classes = match name {
            "emnistl" => 26,
            _ => 10,
        };
        match data::idx::load_idx_dataset(std::path::Path::new(dir), name, classes) {
            Ok(ds) => {
                eprintln!("loaded real {name} from {dir}");
                return Ok(ds);
            }
            Err(e) => eprintln!("real {name} unavailable ({e:#}); using synthetic"),
        }
    }
    data::paper_dataset(name, scale, seed)
        .with_context(|| format!("unknown dataset '{name}' (mnist|fmnist|emnistd|emnistl)"))
}

fn cmd_fig1(flags: &Flags) -> Result<()> {
    let dmax = flags.f64("dmax", 11.0)?;
    let samples = flags.usize("samples", 441)?;
    let rows = experiments::fig1_rows(dmax, samples);
    let path = out_dir(flags).join("fig1_delta.csv");
    report::write_csv(
        &path,
        &["d", "exact_plus", "lut_plus", "bs_plus", "exact_minus", "lut_minus", "bs_minus"],
        &report::fig1_csv_rows(&rows),
    )?;
    println!("Fig. 1 data → {} ({} samples, d ∈ [0, {dmax}])", path.display(), rows.len());
    println!("  Δ+(0): exact=1.0 lut={:.4} bs={:.4}", rows[0].lut_plus, rows[0].bs_plus);
    Ok(())
}

fn cmd_fig2(flags: &Flags) -> Result<()> {
    let name = flags.get("dataset").unwrap_or("mnist");
    let ds = load_dataset(flags, name)?;
    let epochs = flags.usize("epochs", 20)?;
    let hidden = flags.usize("hidden", 100)?;
    let seed = flags.u64("seed", 7)?;
    let threads = flags.usize("threads", default_threads())?;
    let shards = shards_flag(flags)?;
    let mp = mp_spec(flags)?;
    let recs = experiments::fig2(&ds, epochs, hidden, seed, threads, shards, &mp);
    let path = out_dir(flags).join(format!("fig2_{name}.csv"));
    report::write_csv(
        &path,
        &["dataset", "config", "epoch", "train_loss", "val_accuracy", "seconds"],
        &report::fig2_csv_rows(&recs),
    )?;
    println!("Fig. 2 curves → {}", path.display());
    for r in &recs {
        println!(
            "  {:<10} final val acc {:.3} (test {:.3})",
            r.tag.label(),
            r.curve.last().map(|e| e.val_accuracy).unwrap_or(0.0),
            r.test_accuracy
        );
    }
    Ok(())
}

fn cmd_table1(flags: &Flags) -> Result<()> {
    let epochs = flags.usize("epochs", 20)?;
    let hidden = flags.usize("hidden", 100)?;
    let seed = flags.u64("seed", 7)?;
    let threads = flags.usize("threads", default_threads())?;
    let names: Vec<&str> = flags
        .get("datasets")
        .map(|s| s.split(',').collect())
        .unwrap_or_else(|| vec!["mnist", "fmnist", "emnistd", "emnistl"]);
    let datasets: Vec<data::Dataset> =
        names.iter().map(|n| load_dataset(flags, n)).collect::<Result<_>>()?;
    // `--widths W,...` switches table1 into the accuracy-vs-bitwidth
    // frontier sweep: lin/log columns at every requested width plus
    // per-layer mixed-precision cells, each annotated with the range
    // occupancy headroom collected while that cell ran.
    if let Some(spec) = flags.get("widths") {
        let widths: Vec<u32> = spec
            .split(',')
            .map(|w| w.trim().parse().with_context(|| format!("--widths: bad width '{w}'")))
            .collect::<Result<_>>()?;
        if widths.is_empty() {
            bail!("--widths needs at least one width (e.g. 8,12,16)");
        }
        let recs = experiments::width_frontier(&datasets, &widths, epochs, hidden, seed);
        let md = report::frontier_markdown(&recs);
        let dir = out_dir(flags);
        report::write_markdown(&dir.join("width_frontier.md"), &md)?;
        report::write_csv(
            &dir.join("width_frontier.csv"),
            &[
                "dataset",
                "config",
                "bits",
                "precision",
                "test_accuracy",
                "test_loss",
                "headroom_bits",
                "seconds",
            ],
            &report::frontier_csv_rows(&recs),
        )?;
        println!("{md}");
        println!("Width frontier → {}/width_frontier.{{md,csv}}", dir.display());
        return Ok(());
    }
    let shards = shards_flag(flags)?;
    let mp = mp_spec(flags)?;
    let recs = experiments::table1(&datasets, epochs, hidden, seed, threads, shards, &mp);
    let md = report::table1_markdown(&recs);
    let dir = out_dir(flags);
    report::write_markdown(&dir.join("table1.md"), &md)?;
    report::write_csv(
        &dir.join("table1.csv"),
        &["dataset", "config", "test_accuracy", "test_loss", "seconds"],
        &report::runs_csv_rows(&recs),
    )?;
    println!("{md}");
    println!("Table 1 → {}/table1.{{md,csv}}", dir.display());
    Ok(())
}

fn cmd_bitwidth() -> Result<()> {
    println!("Eq. 15: W_log ≥ 1 + max(⌈log2(b_i+1)⌉, ⌈log2 b_f⌉) + W_lin\n");
    println!("{:>6} {:>5} {:>5} {:>10}", "W_lin", "b_i", "b_f", "W_log_bnd");
    for row in lns::bound_table(&[(4, 3), (4, 7), (4, 11), (4, 15), (4, 19), (4, 27)]) {
        println!("{:>6} {:>5} {:>5} {:>10}", row.w_lin, row.b_i, row.b_f, row.w_log_bound);
    }
    println!("\nPaper: W_lin=16 (b_i=4, b_f=11) → bound 21; experiments show");
    println!("W_log ≈ W_lin suffices in practice (run `table1`).");
    Ok(())
}

fn cmd_cost() -> Result<()> {
    use lnsdnn::lns::{area_ratio, linear_mac_cost, lns_mac_cost, LnsConfig};
    println!("First-order MAC gate model (NAND2-equivalents; lns::cost):\n");
    println!(
        "{:<14} {:>8} {:>10} {:>8} {:>8} {:>8} {:>9}",
        "datapath", "adder", "multiplier", "cmp/sel", "ROM", "shifter", "total"
    );
    let rows = [
        lnsdnn::lns::linear_mac_cost(12),
        linear_mac_cost(16),
        lns_mac_cost(&LnsConfig::w12_lut()),
        lns_mac_cost(&LnsConfig::w16_lut()),
        lns_mac_cost(&LnsConfig::w12_bitshift()),
        lns_mac_cost(&LnsConfig::w16_bitshift()),
    ];
    for c in &rows {
        println!(
            "{:<14} {:>8.0} {:>10.0} {:>8.0} {:>8.0} {:>8.0} {:>9.0}",
            c.label, c.adder, c.multiplier, c.compare_select, c.rom, c.shifter,
            c.total()
        );
    }
    println!(
        "\narea ratio lin16 / lns16-lut : {:.1}×  (paper's cited motivation: ~3.2× area-delay)",
        area_ratio(&LnsConfig::w16_lut())
    );
    println!(
        "area ratio lin16 / lns16-bs  : {:.1}×",
        area_ratio(&LnsConfig::w16_bitshift())
    );
    println!("\nSweep table shapes against accuracy: `cargo bench --bench ablation_lut`.");
    Ok(())
}

fn cmd_train(flags: &Flags) -> Result<()> {
    let tag_s = flags.get("config").unwrap_or("log16-lut");
    let tag = ConfigTag::parse(tag_s).with_context(|| format!("bad --config '{tag_s}'"))?;
    let name = flags.get("dataset").unwrap_or("mnist");
    let ds = load_dataset(flags, name)?;
    let epochs = flags.usize("epochs", 20)?;
    let hidden = flags.usize("hidden", 100)?;
    let seed = flags.u64("seed", 7)?;
    let mut cfg = experiments::paper_config(&ds, tag, epochs, hidden, seed);
    cfg.sgd.lr = flags.f64("lr", cfg.sgd.lr)?;
    cfg.sgd.weight_decay = flags.f64("wd", cfg.sgd.weight_decay)?;
    cfg.batch_size = flags.usize("batch", cfg.batch_size)?;
    if let Some(spec) = flags.get("precision") {
        cfg.precision = lnsdnn::precision::PrecisionMap::parse(spec, &tag.label())
            .map_err(|e| anyhow::anyhow!("--precision: {e}"))?;
    }
    cfg.shard = lnsdnn::train::ShardConfig::with_shards(shards_flag(flags)?);
    let mut mp = mp_spec(flags)?;
    // Without an explicit --worker-threads, split the machine across the
    // worker processes instead of letting each build a full-size pool.
    if mp.is_multiproc() && mp.worker_threads == 0 {
        mp.worker_threads = (default_threads() / mp.workers).max(1);
    }
    println!(
        "training {} on {} ({} train / {} test, {} classes), {} epochs{}",
        tag.label(),
        ds.name,
        ds.train_len(),
        ds.test_len(),
        ds.classes,
        epochs,
        if mp.is_multiproc() {
            format!(", {} worker processes over {}", mp.workers, mp.transport.label())
        } else {
            String::new()
        }
    );
    let rec = if mp.is_multiproc() {
        experiments::run_one_mp(&ds, tag, &cfg, &mp)?
    } else {
        experiments::run_one(&ds, tag, &cfg)
    };
    for e in &rec.curve {
        println!(
            "  epoch {:>3}: loss {:.4}  val acc {:.4}  ({:.1}s)",
            e.epoch, e.train_loss, e.val_accuracy, e.seconds
        );
    }
    println!(
        "test accuracy {:.4}  loss {:.4}  total {:.1}s",
        rec.test_accuracy, rec.test_loss, rec.seconds
    );
    Ok(())
}

fn cmd_cnn(flags: &Flags) -> Result<()> {
    let name = flags.get("dataset").unwrap_or("stripes");
    let seed = flags.u64("seed", 7)?;
    let ds = if name == "stripes" {
        let scale = flags.f64("scale", 1.0)?;
        data::stripes_dataset(&data::StripeSpec::cnn_default(scale, seed))
    } else {
        load_dataset(flags, name)?
    };
    let epochs = flags.usize("epochs", 8)?;
    let threads = flags.usize("threads", default_threads())?;
    let shards = shards_flag(flags)?;
    let arch_s = flags.get("arch").unwrap_or("lenet");
    let variant = lnsdnn::nn::CnnVariant::parse(arch_s)
        .with_context(|| format!("bad --arch '{arch_s}' (lenet|strided-v1)"))?;
    let tags: Vec<ConfigTag> = match flags.get("configs") {
        Some(s) => s
            .split(',')
            .map(|t| ConfigTag::parse(t).with_context(|| format!("bad config tag '{t}'")))
            .collect::<Result<_>>()?,
        None => vec![
            ConfigTag::Float,
            ConfigTag::Log(16, LogMode::Lut),
            ConfigTag::Log(16, LogMode::Bs),
        ],
    };
    let mp = mp_spec(flags)?;
    println!(
        "CNN sweep ({}) on {} ({} train / {} test, {} classes), {} epochs, {} configs, {} shard(s)",
        variant.label(),
        ds.name,
        ds.train_len(),
        ds.test_len(),
        ds.classes,
        epochs,
        tags.len(),
        shards
    );
    let recs = experiments::cnn_grid(&ds, &tags, epochs, seed, threads, variant, shards, &mp);
    let dir = out_dir(flags);
    // Keep the historical filename for the default arch; suffix variants.
    let stem = match variant {
        lnsdnn::nn::CnnVariant::Pooled => format!("cnn_{name}"),
        lnsdnn::nn::CnnVariant::StridedV1 => format!("cnn_{name}_strided_v1"),
    };
    report::write_csv(
        &dir.join(format!("{stem}.csv")),
        &["dataset", "config", "test_accuracy", "test_loss", "seconds"],
        &report::runs_csv_rows(&recs),
    )?;
    for r in &recs {
        println!(
            "  {:<10} test acc {:.4}  loss {:.4}  ({:.1}s)",
            r.tag.label(),
            r.test_accuracy,
            r.test_loss,
            r.seconds
        );
    }
    println!("CNN results → {}/{stem}.csv", dir.display());
    Ok(())
}

fn cmd_artifacts(flags: &Flags) -> Result<()> {
    let dir = PathBuf::from(flags.get("dir").unwrap_or("artifacts"));
    let mut reg = ArtifactRegistry::open(&dir)?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {} ({} device(s))", rt.platform(), rt.device_count());
    for name in reg.names() {
        let meta = reg.meta(&name).unwrap().clone();
        print!(
            "  {:<28} kind={:<11} bits={:<2} delta={:<3} dims={:?} batch={} ... ",
            meta.name, meta.kind, meta.bits, meta.delta, meta.dims, meta.batch
        );
        match reg.load(&rt, &name) {
            Ok(_) => println!("compiles OK"),
            Err(e) => println!("FAILED: {e:#}"),
        }
    }
    Ok(())
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}
