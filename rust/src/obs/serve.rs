//! Zero-dependency observation endpoint: a blocking HTTP 1.1 server on
//! its own thread (`std::net::TcpListener`, no async runtime, no crates)
//! exposing the live telemetry of a running training process:
//!
//! * `GET /metrics` — Prometheus text format 0.0.4: every counter from
//!   [`super::metrics`] (with per-layer attribution), both wire
//!   histograms, the per-layer exponent-occupancy distributions and
//!   derived gauges from [`super::dist`] (gradient norms, headroom to
//!   clamp, fraction of range used, cancellation density), and — on a
//!   multi-process coordinator — the per-rank worker distributions plus
//!   the fleet aggregate.
//! * `GET /health` — JSON liveness: process status and, on a
//!   coordinator, per-worker heartbeat freshness (rank, last progress,
//!   milliseconds since the last heartbeat).
//! * `GET /trace` — the current Chrome trace buffer
//!   ([`super::trace::render_chrome_trace`]), loadable in Perfetto
//!   mid-run.
//!
//! Wired as `--obs-listen ADDR` on every training subcommand (see the
//! CLI usage text). The server only ever *reads* the telemetry banks —
//! scraping mid-run cannot perturb training values or counters, which
//! `tests/obs_exactness.rs` pins with a live scraper hammering
//! `/metrics` during a run.

use super::dist::{self, DistSnapshot, TensorClass, EXP_OFFSET};
use super::metrics;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long the accept loop sleeps between polls (the listener is
/// non-blocking so `stop` can interrupt it promptly).
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Per-connection read deadline; a stalled client cannot wedge the
/// serving thread.
const READ_TIMEOUT: Duration = Duration::from_secs(2);

/// Request head size cap (we only ever need the request line).
const MAX_REQUEST_BYTES: usize = 8192;

// ---------------------------------------------------------------------
// Worker freshness registry (/health)
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
struct WorkerSeen {
    rank: u32,
    epoch: u32,
    step: u32,
    samples_done: u64,
    at: Instant,
}

static WORKERS_SEEN: Mutex<Vec<WorkerSeen>> = Mutex::new(Vec::new());

/// Record a worker heartbeat arrival (called by the multi-process
/// coordinator's heartbeat fold) so `/health` can report freshness.
pub fn note_worker(rank: u32, epoch: u32, step: u32, samples_done: u64) {
    let mut seen = WORKERS_SEEN.lock().unwrap_or_else(PoisonError::into_inner);
    let rec = WorkerSeen { rank, epoch, step, samples_done, at: Instant::now() };
    match seen.iter_mut().find(|w| w.rank == rank) {
        Some(w) => *w = rec,
        None => {
            seen.push(rec);
            seen.sort_by_key(|w| w.rank);
        }
    }
}

/// Clear the worker freshness registry (part of `obs::reset_all`).
pub fn reset_workers() {
    WORKERS_SEEN.lock().unwrap_or_else(PoisonError::into_inner).clear();
}

fn render_health() -> String {
    let seen = WORKERS_SEEN.lock().unwrap_or_else(PoisonError::into_inner).clone();
    let mut out = String::from("{\"status\":\"ok\",\"workers\":[");
    for (i, w) in seen.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"rank\":{},\"epoch\":{},\"step\":{},\"samples_done\":{},\"age_ms\":{}}}",
            w.rank,
            w.epoch,
            w.step,
            w.samples_done,
            w.at.elapsed().as_millis()
        ));
    }
    out.push_str("]}");
    out
}

// ---------------------------------------------------------------------
// Prometheus rendering (/metrics)
// ---------------------------------------------------------------------

/// Escape a Prometheus label value (`\`, `"`, newline).
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn family(out: &mut String, name: &str, help: &str, typ: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {typ}\n"));
}

fn dist_series(out: &mut String, metric: &str, extra: &str, snap: &DistSnapshot) {
    for e in &snap.entries {
        let class = TensorClass::from_code(e.class).map(TensorClass::name).unwrap_or("unknown");
        for (i, &count) in e.buckets.iter().enumerate() {
            if count != 0 {
                out.push_str(&format!(
                    "{metric}{{{extra}class=\"{class}\",layer=\"{}\",exp=\"{}\"}} {count}\n",
                    e.layer,
                    i as i32 - EXP_OFFSET
                ));
            }
        }
    }
}

fn dist_side_series(
    out: &mut String,
    metric: &str,
    extra: &str,
    snap: &DistSnapshot,
    pick: fn(&dist::DistEntry) -> u64,
) {
    for e in &snap.entries {
        let v = pick(e);
        if v != 0 {
            let class = TensorClass::from_code(e.class).map(TensorClass::name).unwrap_or("unknown");
            out.push_str(&format!(
                "{metric}{{{extra}class=\"{class}\",layer=\"{}\"}} {v}\n",
                e.layer
            ));
        }
    }
}

/// Render the full `/metrics` payload (public so the Prometheus-format
/// golden test and the serve-overhead bench can call it directly).
pub fn render_prometheus() -> String {
    let mut out = String::with_capacity(16 * 1024);

    // -- Numerics + wire counters ------------------------------------
    for c in metrics::all() {
        let name = format!("lnsdnn_{}_total", c.name());
        family(&mut out, &name, "Monotone event counter (see docs/OBSERVABILITY.md).", "counter");
        out.push_str(&format!("{name} {}\n", c.total()));
        let by = c.by_scope();
        let layer_name = format!("lnsdnn_{}_layer_total", c.name());
        let mut wrote_head = false;
        for (scope, &v) in by.iter().enumerate().skip(1) {
            if v == 0 {
                continue;
            }
            if !wrote_head {
                family(&mut out, &layer_name, "Per-layer attribution of the counter.", "counter");
                wrote_head = true;
            }
            out.push_str(&format!("{layer_name}{{layer=\"{scope}\"}} {v}\n"));
        }
    }

    // -- Wire histograms ---------------------------------------------
    for h in [&metrics::WIRE_FRAME_BYTES, &metrics::WORKER_DETECT_LATENCY_MS] {
        let name = format!("lnsdnn_{}", h.name());
        family(&mut out, &name, "Bucketed observation histogram.", "histogram");
        let counts = h.counts();
        let mut cum = 0u64;
        for (i, &bound) in h.bounds().iter().enumerate() {
            cum += counts[i];
            out.push_str(&format!("{name}_bucket{{le=\"{bound}\"}} {cum}\n"));
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.total()));
        out.push_str(&format!("{name}_count {}\n", h.total()));
    }

    // -- Value distributions (this process) --------------------------
    let local = dist::snapshot();
    family(
        &mut out,
        "lnsdnn_dist_exp_total",
        "Samples per base-2 exponent bucket, by tensor class and layer.",
        "counter",
    );
    dist_series(&mut out, "lnsdnn_dist_exp_total", "", &local);
    family(&mut out, "lnsdnn_dist_zero_total", "Exact zeros sampled.", "counter");
    dist_side_series(&mut out, "lnsdnn_dist_zero_total", "", &local, |e| e.zeros);
    family(&mut out, "lnsdnn_dist_neg_total", "Negative (non-zero) samples.", "counter");
    dist_side_series(&mut out, "lnsdnn_dist_neg_total", "", &local, |e| e.neg);

    // -- Derived training-dynamics gauges ----------------------------
    if let Some((lo, hi)) = dist::exp_range() {
        family(
            &mut out,
            "lnsdnn_dist_exp_range",
            "Representable exponent range of the recording backend.",
            "gauge",
        );
        out.push_str(&format!("lnsdnn_dist_exp_range{{bound=\"min\"}} {lo}\n"));
        out.push_str(&format!("lnsdnn_dist_exp_range{{bound=\"max\"}} {hi}\n"));
        family(
            &mut out,
            "lnsdnn_dist_headroom_bits",
            "Bits between the hottest occupied exponent and the clamp ceiling.",
            "gauge",
        );
        let mut headroom = String::new();
        let mut range_frac = String::new();
        for e in &local.entries {
            let Some((olo, ohi)) = e.occupied_span() else {
                continue;
            };
            let class = TensorClass::from_code(e.class).map(TensorClass::name).unwrap_or("unknown");
            headroom.push_str(&format!(
                "lnsdnn_dist_headroom_bits{{class=\"{class}\",layer=\"{}\"}} {}\n",
                e.layer,
                hi - ohi
            ));
            let span = (ohi - olo + 1) as f64 / (hi - lo + 1).max(1) as f64;
            range_frac.push_str(&format!(
                "lnsdnn_dist_range_frac{{class=\"{class}\",layer=\"{}\"}} {span}\n",
                e.layer
            ));
        }
        out.push_str(&headroom);
        family(
            &mut out,
            "lnsdnn_dist_range_frac",
            "Fraction of the representable exponent range a cell occupies.",
            "gauge",
        );
        out.push_str(&range_frac);
    }

    let norms = dist::grad_norms();
    if !norms.is_empty() {
        family(
            &mut out,
            "lnsdnn_grad_l1",
            "Latest per-layer gradient L1 norm (backend arithmetic, decoded).",
            "gauge",
        );
        for &(layer, l1, _) in &norms {
            out.push_str(&format!("lnsdnn_grad_l1{{layer=\"{layer}\"}} {l1}\n"));
        }
        family(
            &mut out,
            "lnsdnn_grad_linf",
            "Latest per-layer gradient L-infinity norm (backend arithmetic, decoded).",
            "gauge",
        );
        for &(layer, _, linf) in &norms {
            out.push_str(&format!("lnsdnn_grad_linf{{layer=\"{layer}\"}} {linf}\n"));
        }
    }

    // Cancellation density: catastrophic ⊟ cancellations per ⊞/⊟
    // evaluation — a dynamics signal the raw counters only imply.
    let snap = metrics::snapshot();
    let adds =
        snap.get("delta_lut_adds") + snap.get("delta_shift_adds") + snap.get("delta_exact_adds");
    if adds != 0 {
        family(
            &mut out,
            "lnsdnn_cancel_density",
            "lns_cancel per delta-evaluated add (cancellation density).",
            "gauge",
        );
        out.push_str(&format!(
            "lnsdnn_cancel_density {}\n",
            snap.get("lns_cancel") as f64 / adds as f64
        ));
    }

    // -- Cross-worker aggregation (multi-process coordinator) --------
    let workers = dist::worker_snapshots();
    if !workers.is_empty() {
        family(
            &mut out,
            "lnsdnn_worker_dist_exp_total",
            "Per-rank worker exponent occupancy (from heartbeat v3 deltas).",
            "counter",
        );
        for (rank, snap) in &workers {
            dist_series(
                &mut out,
                "lnsdnn_worker_dist_exp_total",
                &format!("rank=\"{rank}\","),
                snap,
            );
        }
        family(
            &mut out,
            "lnsdnn_fleet_dist_exp_total",
            "Fleet-wide exponent occupancy: local banks plus all worker deltas.",
            "counter",
        );
        dist_series(&mut out, "lnsdnn_fleet_dist_exp_total", "", &dist::fleet_snapshot());
    }

    out
}

// ---------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------

/// Handle to a running observation endpoint. Dropping (or calling
/// [`ObsServer::stop`]) shuts the serving thread down.
pub struct ObsServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`, port `0` for ephemeral) and
    /// start serving on a background thread.
    pub fn start(addr: &str) -> std::io::Result<ObsServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let handle = std::thread::Builder::new()
            .name("lnsdnn-obs-serve".into())
            .spawn(move || serve_loop(listener, &flag))?;
        Ok(ObsServer { addr: local, shutdown, handle: Some(handle) })
    }

    /// The bound address (resolves an ephemeral port request).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shut the serving thread down and join it.
    pub fn stop(self) {
        drop(self);
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_loop(listener: TcpListener, shutdown: &AtomicBool) {
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Serve inline: endpoints render fast and the scrape
                // cadence is seconds — one thread is plenty.
                let _ = handle_conn(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn handle_conn(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > MAX_REQUEST_BYTES {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    if method != "GET" {
        return respond(&mut stream, "405 Method Not Allowed", "text/plain", "GET only\n");
    }
    match path {
        "/metrics" => {
            respond(&mut stream, "200 OK", "text/plain; version=0.0.4", &render_prometheus())
        }
        "/health" => respond(&mut stream, "200 OK", "application/json", &render_health()),
        "/trace" => {
            respond(&mut stream, "200 OK", "application/json", &super::trace::render_chrome_trace())
        }
        "/" => respond(
            &mut stream,
            "200 OK",
            "text/plain",
            "lnsdnn observation endpoint: /metrics /health /trace\n",
        ),
        _ => respond(&mut stream, "404 Not Found", "text/plain", "unknown path\n"),
    }
}

fn respond(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    /// Structural Prometheus-format check on whatever state the process
    /// has (lib unit tests never enable the global counters, so this
    /// asserts format, not totals — the golden totals test lives in
    /// `tests/obs_exactness.rs` under the obs lock).
    #[test]
    fn prometheus_payload_is_well_formed() {
        let text = render_prometheus();
        assert!(text.contains("# HELP lnsdnn_lns_clamp_hi_total"));
        assert!(text.contains("# TYPE lnsdnn_lns_clamp_hi_total counter"));
        assert!(text.contains("# TYPE lnsdnn_wire_frame_bytes histogram"));
        assert!(text.contains("lnsdnn_wire_frame_bytes_bucket{le=\"+Inf\"}"));
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(
                    line.starts_with("# HELP ") || line.starts_with("# TYPE "),
                    "bad comment line: {line}"
                );
                continue;
            }
            let (name_part, value) = line.rsplit_once(' ').expect("sample line has a value");
            let v: f64 = value.parse().unwrap_or_else(|_| panic!("unparsable value: {line}"));
            assert!(v.is_finite(), "non-finite sample: {line}");
            let name = name_part.split('{').next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name: {line}"
            );
        }
    }

    #[test]
    fn server_serves_and_stops() {
        let srv = ObsServer::start("127.0.0.1:0").expect("bind ephemeral");
        let addr = srv.addr();
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream
            .write_all(b"GET /health HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("\"status\":\"ok\""), "{resp}");
        // Unknown path 404s, wrong method 405s.
        let mut s2 = TcpStream::connect(addr).unwrap();
        s2.write_all(b"GET /nope HTTP/1.1\r\n\r\n").unwrap();
        let mut r2 = String::new();
        s2.read_to_string(&mut r2).unwrap();
        assert!(r2.starts_with("HTTP/1.1 404"), "{r2}");
        let mut s3 = TcpStream::connect(addr).unwrap();
        s3.write_all(b"POST /metrics HTTP/1.1\r\n\r\n").unwrap();
        let mut r3 = String::new();
        s3.read_to_string(&mut r3).unwrap();
        assert!(r3.starts_with("HTTP/1.1 405"), "{r3}");
        srv.stop();
        // The port is released after stop: a fresh bind to it succeeds
        // (best-effort — other processes could grab it, so only assert
        // the join completed by reaching this point).
    }

    #[test]
    fn health_reports_worker_freshness() {
        // note_worker feeds a process-global registry; use ranks high
        // enough not to collide with other tests' entries.
        note_worker(901, 3, 7, 4242);
        let body = render_health();
        assert!(body.contains("\"rank\":901"), "{body}");
        assert!(body.contains("\"samples_done\":4242"), "{body}");
    }
}
