//! Value-distribution telemetry: per-layer, per-tensor-class exponent
//! occupancy histograms and derived training-dynamics metrics.
//!
//! The paper's bet is that a 16-bit log-domain word has *enough dynamic
//! range* for training. The counters in [`super::metrics`] say *that*
//! clamps and cancellations happen; this module says *where in value
//! space* each layer actually lives, which is the measurement substrate
//! for per-layer bitwidth selection (see `docs/OBSERVABILITY.md`,
//! "Reading range occupancy").
//!
//! # What is recorded
//!
//! Every sampled element is reduced by its backend to a read-only
//! [`Sample`] — zero flag, linear-domain sign, and the base-2 exponent
//! of its magnitude (the integer part of the LNS log-magnitude `m ≫ q_f`;
//! `⌊log2 |code|⌋ − q_f` for fixed point; the IEEE exponent for floats).
//! Samples land in a fixed bank of [`EXP_BUCKETS`] occupancy buckets per
//! (tensor class × layer scope), plus per-cell zero and negative totals.
//!
//! # Where it is recorded (determinism)
//!
//! Sampling happens only at *deterministic* points of the training loop:
//! activations as each layer's forward output is produced, gradients on
//! the per-batch (or per-sample, in workers) gradient sums, and weights
//! on the post-update parameters at epoch end. Because the sampled
//! values are themselves bit-reproducible per configuration
//! (`docs/NUMERICS.md`), the histograms are too: two runs of the same
//! config produce identical banks (pinned in `tests/obs_exactness.rs`).
//!
//! # The invariant
//!
//! Recording is **read-only** (NUMERICS.md §7): backends expose
//! [`crate::tensor::Backend::dist_sample`] as a pure projection, nothing
//! here is ever read back by an arithmetic path, and every entry point
//! is gated on [`crate::obs::counters_enabled`] so the disabled cost is
//! one relaxed load. The gradient-norm gauges fold through the backend's
//! own *scalar* `add`/`sub`/`gt` — which are not counter-gated and touch
//! no shared state — so even they leave counters and values untouched.

use crate::obs::metrics::MAX_SCOPES;
use crate::tensor::Backend;
use std::sync::atomic::{AtomicI32, AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

/// Occupancy buckets per (class × layer) cell. Bucket `i` holds samples
/// with exponent `i - EXP_OFFSET`; the edge buckets absorb anything
/// beyond the covered span (float gradients can undershoot any fixed
/// word's range).
pub const EXP_BUCKETS: usize = 48;

/// Exponent of bucket 0 is `-EXP_OFFSET`; the covered span is
/// `[-EXP_OFFSET, EXP_BUCKETS - 1 - EXP_OFFSET]` = `[-32, 15]`, which
/// contains every representable 12/16-bit LNS and fixed-point exponent.
pub const EXP_OFFSET: i32 = 32;

/// Tensor classes tracked per layer.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TensorClass {
    /// Post-update parameters (weights and biases).
    Weights,
    /// Forward-pass layer outputs.
    Activations,
    /// Per-batch (coordinator) / per-sample (worker) gradient sums.
    Gradients,
}

/// Number of [`TensorClass`] variants (bank sizing).
pub const CLASSES: usize = 3;

impl TensorClass {
    /// All classes, in wire-code order.
    pub const ALL: [TensorClass; CLASSES] =
        [TensorClass::Weights, TensorClass::Activations, TensorClass::Gradients];

    /// Stable label (metric label values and report rows).
    pub fn name(self) -> &'static str {
        match self {
            TensorClass::Weights => "weights",
            TensorClass::Activations => "activations",
            TensorClass::Gradients => "gradients",
        }
    }

    /// Wire code (heartbeat v3 payloads).
    pub fn code(self) -> u8 {
        match self {
            TensorClass::Weights => 0,
            TensorClass::Activations => 1,
            TensorClass::Gradients => 2,
        }
    }

    /// Decode a wire code.
    pub fn from_code(code: u8) -> Option<TensorClass> {
        match code {
            0 => Some(TensorClass::Weights),
            1 => Some(TensorClass::Activations),
            2 => Some(TensorClass::Gradients),
            _ => None,
        }
    }
}

/// A backend's read-only projection of one element for sampling — see
/// [`crate::tensor::Backend::dist_sample`].
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Sample {
    /// Exact additive identity (not binned; counted separately).
    pub zero: bool,
    /// Linear-domain sign is negative. Meaningless when `zero`.
    pub neg: bool,
    /// Base-2 exponent of the magnitude. Meaningless when `zero`.
    pub exp: i32,
}

/// Bucket index of an exponent (edge buckets absorb out-of-span values).
#[inline]
pub fn bucket_of(exp: i32) -> usize {
    (exp + EXP_OFFSET).clamp(0, EXP_BUCKETS as i32 - 1) as usize
}

// ---------------------------------------------------------------------
// The banks
// ---------------------------------------------------------------------

const EXP_CELLS_LEN: usize = CLASSES * MAX_SCOPES * EXP_BUCKETS;
const SIDE_CELLS_LEN: usize = CLASSES * MAX_SCOPES;
/// Flat bank length: exponent buckets, then zero cells, then neg cells.
const FLAT_LEN: usize = EXP_CELLS_LEN + 2 * SIDE_CELLS_LEN;

static EXP_CELLS: [AtomicU64; EXP_CELLS_LEN] = [const { AtomicU64::new(0) }; EXP_CELLS_LEN];
static ZERO_CELLS: [AtomicU64; SIDE_CELLS_LEN] = [const { AtomicU64::new(0) }; SIDE_CELLS_LEN];
static NEG_CELLS: [AtomicU64; SIDE_CELLS_LEN] = [const { AtomicU64::new(0) }; SIDE_CELLS_LEN];

/// Representable-exponent range of the recording backend (for headroom
/// and fraction-of-range metrics). `i32::MIN` marks "not registered".
static EXP_RANGE_MIN: AtomicI32 = AtomicI32::new(i32::MIN);
static EXP_RANGE_MAX: AtomicI32 = AtomicI32::new(i32::MIN);

/// Latest per-layer gradient norms, decoded once to `f64` and stored as
/// IEEE bit patterns (gauges: the newest recorded batch wins).
static GRAD_L1: [AtomicU64; MAX_SCOPES] = [const { AtomicU64::new(0) }; MAX_SCOPES];
static GRAD_LINF: [AtomicU64; MAX_SCOPES] = [const { AtomicU64::new(0) }; MAX_SCOPES];

#[inline]
fn side_idx(class: TensorClass, layer: usize) -> usize {
    class.code() as usize * MAX_SCOPES + layer.min(MAX_SCOPES - 1)
}

#[inline]
fn exp_base(class: TensorClass, layer: usize) -> usize {
    side_idx(class, layer) * EXP_BUCKETS
}

// ---------------------------------------------------------------------
// Recording
// ---------------------------------------------------------------------

/// Record every element of `xs` into the (class, layer) occupancy cell.
/// Gated on [`crate::obs::counters_enabled`]; the per-element work is a
/// pure [`Backend::dist_sample`] projection into a stack-local tally,
/// flushed as one batch of relaxed `fetch_add`s.
pub fn record_slice<B: Backend>(backend: &B, class: TensorClass, layer: usize, xs: &[B::E]) {
    if !crate::obs::counters_enabled() {
        return;
    }
    let (lo, hi) = backend.dist_exp_range();
    EXP_RANGE_MIN.store(lo, Ordering::Relaxed);
    EXP_RANGE_MAX.store(hi, Ordering::Relaxed);
    let mut buckets = [0u64; EXP_BUCKETS];
    let mut zeros = 0u64;
    let mut negs = 0u64;
    for &x in xs {
        let s = backend.dist_sample(x);
        if s.zero {
            zeros += 1;
            continue;
        }
        if s.neg {
            negs += 1;
        }
        // Clamp to the *backend's* representable range before binning:
        // the bank's fixed span was sized for the 12/16-bit presets, and
        // a wider runtime word (or a float outlier) must saturate at the
        // active config's boundary — not the bank's — so occupied spans
        // and headroom stay meaningful at every width.
        buckets[bucket_of(s.exp.clamp(lo, hi))] += 1;
    }
    let base = exp_base(class, layer);
    for (i, &b) in buckets.iter().enumerate() {
        if b != 0 {
            EXP_CELLS[base + i].fetch_add(b, Ordering::Relaxed);
        }
    }
    if zeros != 0 {
        ZERO_CELLS[side_idx(class, layer)].fetch_add(zeros, Ordering::Relaxed);
    }
    if negs != 0 {
        NEG_CELLS[side_idx(class, layer)].fetch_add(negs, Ordering::Relaxed);
    }
}

/// Record flat per-layer views in the canonical [`crate::nn::GradStore`]
/// order (each layer's weight buffer, then its bias buffer, layers
/// ascending — the same order [`crate::train::wire`] frames use), so
/// view `i` belongs to layer `i/2 + 1`.
pub fn record_layer_views<B: Backend>(backend: &B, class: TensorClass, views: &[&[B::E]]) {
    if !crate::obs::counters_enabled() {
        return;
    }
    for (i, view) in views.iter().enumerate() {
        record_slice(backend, class, i / 2 + 1, view);
    }
}

/// Record per-layer gradient L1/L∞ norms **in the backend's own
/// arithmetic** (|g| is exact in every backend; the L1 fold is the
/// backend's scalar ⊞ chain over view order), decoded once at the end
/// into the gauge bank. Views in canonical order, like
/// [`record_layer_views`].
pub fn record_grad_norms<B: Backend>(backend: &B, views: &[&[B::E]]) {
    if !crate::obs::counters_enabled() {
        return;
    }
    let zero = backend.zero();
    for (l, pair) in views.chunks(2).enumerate() {
        let layer = (l + 1).min(MAX_SCOPES - 1);
        let mut l1 = zero;
        let mut linf = zero;
        for view in pair {
            for &g in view.iter() {
                let s = backend.dist_sample(g);
                if s.zero {
                    continue;
                }
                let a = if s.neg { backend.sub(zero, g) } else { g };
                l1 = backend.add(l1, a);
                if backend.gt(a, linf) {
                    linf = a;
                }
            }
        }
        GRAD_L1[layer].store(backend.decode(l1).to_bits(), Ordering::Relaxed);
        GRAD_LINF[layer].store(backend.decode(linf).to_bits(), Ordering::Relaxed);
    }
}

/// One call covering a batch's gradient views: occupancy histogram plus
/// the norm gauges. The trainers call this on every batch's gradient
/// sums (deterministic points), so the histograms are reproducible.
pub fn record_gradients<B: Backend>(backend: &B, views: &[&[B::E]]) {
    if !crate::obs::counters_enabled() {
        return;
    }
    record_layer_views(backend, TensorClass::Gradients, views);
    record_grad_norms(backend, views);
}

/// The recording backend's representable exponent range, if any slice
/// has been recorded.
pub fn exp_range() -> Option<(i32, i32)> {
    let lo = EXP_RANGE_MIN.load(Ordering::Relaxed);
    let hi = EXP_RANGE_MAX.load(Ordering::Relaxed);
    if lo == i32::MIN && hi == i32::MIN {
        None
    } else {
        Some((lo, hi))
    }
}

/// `(layer, l1, linf)` for every layer with a recorded gradient norm.
pub fn grad_norms() -> Vec<(usize, f64, f64)> {
    let mut out = Vec::new();
    for layer in 1..MAX_SCOPES {
        let l1 = f64::from_bits(GRAD_L1[layer].load(Ordering::Relaxed));
        let linf = f64::from_bits(GRAD_LINF[layer].load(Ordering::Relaxed));
        if l1 != 0.0 || linf != 0.0 {
            out.push((layer, l1, linf));
        }
    }
    out
}

// ---------------------------------------------------------------------
// Snapshots and merging
// ---------------------------------------------------------------------

/// One (class, layer) occupancy cell — the unit heartbeat v3 frames
/// carry and snapshots are made of. Plain data; all counts monotone.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DistEntry {
    /// [`TensorClass::code`].
    pub class: u8,
    /// Layer scope (1-based; see [`MAX_SCOPES`]).
    pub layer: u8,
    /// Exact zeros seen.
    pub zeros: u64,
    /// Negative (non-zero) samples seen.
    pub neg: u64,
    /// Exponent occupancy (index `i` ⇒ exponent `i - EXP_OFFSET`).
    pub buckets: Vec<u64>,
}

impl DistEntry {
    /// Non-zero samples binned in this cell.
    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Occupied exponent span `(lo, hi)`, if any sample landed.
    pub fn occupied_span(&self) -> Option<(i32, i32)> {
        let first = self.buckets.iter().position(|&b| b != 0)?;
        let last = self.buckets.iter().rposition(|&b| b != 0)?;
        Some((first as i32 - EXP_OFFSET, last as i32 - EXP_OFFSET))
    }
}

/// A set of [`DistEntry`] cells, kept sorted by `(class, layer)`.
/// Cell-wise merge is associative and commutative (u64 addition on
/// key-matched cells), so cross-worker aggregation is order-free —
/// pinned by the unit tests below.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DistSnapshot {
    /// Entries sorted by `(class, layer)`.
    pub entries: Vec<DistEntry>,
}

impl DistSnapshot {
    /// Add `entries` into `self` cell-wise; unknown `(class, layer)`
    /// keys are inserted in sorted position. Shorter bucket vectors are
    /// zero-extended, so peers with a different (older/newer) bucket
    /// count still merge losslessly.
    pub fn merge_entries(&mut self, entries: &[DistEntry]) {
        for e in entries {
            let key = (e.class, e.layer);
            match self.entries.binary_search_by_key(&key, |x| (x.class, x.layer)) {
                Ok(i) => {
                    let mine = &mut self.entries[i];
                    mine.zeros += e.zeros;
                    mine.neg += e.neg;
                    if mine.buckets.len() < e.buckets.len() {
                        mine.buckets.resize(e.buckets.len(), 0);
                    }
                    for (a, &b) in mine.buckets.iter_mut().zip(e.buckets.iter()) {
                        *a += b;
                    }
                }
                Err(i) => self.entries.insert(i, e.clone()),
            }
        }
    }

    /// Merge a whole snapshot (cell-wise, see [`Self::merge_entries`]).
    pub fn merge(&mut self, other: &DistSnapshot) {
        self.merge_entries(&other.entries);
    }

    /// The entry for `(class, layer)`, if present.
    pub fn get(&self, class: TensorClass, layer: usize) -> Option<&DistEntry> {
        let key = (class.code(), layer as u8);
        self.entries
            .binary_search_by_key(&key, |x| (x.class, x.layer))
            .ok()
            .map(|i| &self.entries[i])
    }
}

/// Copy the banks into flat form (exp cells, then zeros, then negs).
fn flat_now() -> Vec<u64> {
    let mut out = Vec::with_capacity(FLAT_LEN);
    out.extend(EXP_CELLS.iter().map(|c| c.load(Ordering::Relaxed)));
    out.extend(ZERO_CELLS.iter().map(|c| c.load(Ordering::Relaxed)));
    out.extend(NEG_CELLS.iter().map(|c| c.load(Ordering::Relaxed)));
    out
}

/// Build sorted entries from a flat bank image, dropping all-zero cells.
fn entries_from_flat(flat: &[u64]) -> Vec<DistEntry> {
    let mut entries = Vec::new();
    for class in TensorClass::ALL {
        for layer in 0..MAX_SCOPES {
            let base = exp_base(class, layer);
            let buckets = &flat[base..base + EXP_BUCKETS];
            let zeros = flat[EXP_CELLS_LEN + side_idx(class, layer)];
            let neg = flat[EXP_CELLS_LEN + SIDE_CELLS_LEN + side_idx(class, layer)];
            if zeros == 0 && neg == 0 && buckets.iter().all(|&b| b == 0) {
                continue;
            }
            entries.push(DistEntry {
                class: class.code(),
                layer: layer as u8,
                zeros,
                neg,
                buckets: buckets.to_vec(),
            });
        }
    }
    entries
}

/// Element-wise `cur - last` (counts are monotone, so this never
/// underflows in a well-formed delta; `saturating_sub` guards a reset
/// race anyway).
fn diff_flat(cur: &[u64], last: &[u64]) -> Vec<u64> {
    cur.iter()
        .enumerate()
        .map(|(i, &c)| c.saturating_sub(last.get(i).copied().unwrap_or(0)))
        .collect()
}

/// Point-in-time snapshot of this process's local banks.
pub fn snapshot() -> DistSnapshot {
    DistSnapshot { entries: entries_from_flat(&flat_now()) }
}

/// Bank image at the last [`take_wire_delta`] call (empty = never).
static LAST_SENT: Mutex<Vec<u64>> = Mutex::new(Vec::new());

/// Entries covering everything recorded since the previous call — the
/// delta payload a worker's heartbeat carries. Counts are monotone, so
/// the coordinator reconstructs each worker's full histogram by summing
/// its deltas (order-free; see [`DistSnapshot::merge_entries`]).
pub fn take_wire_delta() -> Vec<DistEntry> {
    let cur = flat_now();
    let mut last = LAST_SENT.lock().unwrap_or_else(PoisonError::into_inner);
    let delta = diff_flat(&cur, &last);
    *last = cur;
    entries_from_flat(&delta)
}

// ---------------------------------------------------------------------
// Coordinator-side worker aggregation
// ---------------------------------------------------------------------

/// Per-rank accumulated worker distributions (heartbeat v3 deltas).
static WORKERS: Mutex<Vec<(u32, DistSnapshot)>> = Mutex::new(Vec::new());

/// Fold one worker's heartbeat delta into its accumulated snapshot.
pub fn merge_worker_delta(rank: u32, entries: &[DistEntry]) {
    if entries.is_empty() {
        return;
    }
    let mut workers = WORKERS.lock().unwrap_or_else(PoisonError::into_inner);
    match workers.iter_mut().find(|(r, _)| *r == rank) {
        Some((_, snap)) => snap.merge_entries(entries),
        None => {
            let mut snap = DistSnapshot::default();
            snap.merge_entries(entries);
            workers.push((rank, snap));
            workers.sort_by_key(|(r, _)| *r);
        }
    }
}

/// Accumulated per-rank worker snapshots (ranks ascending).
pub fn worker_snapshots() -> Vec<(u32, DistSnapshot)> {
    WORKERS.lock().unwrap_or_else(PoisonError::into_inner).clone()
}

/// The fleet-wide view: this process's local banks plus every worker's
/// accumulated deltas.
pub fn fleet_snapshot() -> DistSnapshot {
    let mut snap = snapshot();
    for (_, w) in worker_snapshots() {
        snap.merge(&w);
    }
    snap
}

/// Zero every bank, gauge, delta baseline and worker accumulation.
pub fn reset() {
    for c in EXP_CELLS.iter().chain(ZERO_CELLS.iter()).chain(NEG_CELLS.iter()) {
        c.store(0, Ordering::Relaxed);
    }
    for g in GRAD_L1.iter().chain(GRAD_LINF.iter()) {
        g.store(0, Ordering::Relaxed);
    }
    EXP_RANGE_MIN.store(i32::MIN, Ordering::Relaxed);
    EXP_RANGE_MAX.store(i32::MIN, Ordering::Relaxed);
    LAST_SENT.lock().unwrap_or_else(PoisonError::into_inner).clear();
    WORKERS.lock().unwrap_or_else(PoisonError::into_inner).clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(class: u8, layer: u8, zeros: u64, neg: u64, occupied: &[(usize, u64)]) -> DistEntry {
        let mut buckets = vec![0u64; EXP_BUCKETS];
        for &(i, v) in occupied {
            buckets[i] = v;
        }
        DistEntry { class, layer, zeros, neg, buckets }
    }

    #[test]
    fn bucket_of_covers_and_clamps() {
        assert_eq!(bucket_of(-EXP_OFFSET), 0);
        assert_eq!(bucket_of(0), EXP_OFFSET as usize);
        assert_eq!(bucket_of(15), EXP_OFFSET as usize + 15);
        // Out-of-span exponents land in the edge buckets, never panic.
        assert_eq!(bucket_of(-1000), 0);
        assert_eq!(bucket_of(1000), EXP_BUCKETS - 1);
    }

    #[test]
    fn entry_occupied_span() {
        let e = entry(0, 1, 3, 0, &[(30, 2), (35, 1)]);
        assert_eq!(e.occupied_span(), Some((30 - EXP_OFFSET, 35 - EXP_OFFSET)));
        assert_eq!(e.total(), 3);
        assert_eq!(entry(0, 1, 5, 0, &[]).occupied_span(), None);
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        // Three "worker delta" sets with overlapping and disjoint keys —
        // the shapes cross-worker aggregation actually sees.
        let a = vec![entry(2, 1, 1, 2, &[(10, 5), (11, 1)]), entry(2, 2, 0, 0, &[(12, 7)])];
        let b = vec![entry(2, 1, 3, 1, &[(10, 2), (20, 4)]), entry(0, 1, 0, 0, &[(31, 9)])];
        let c = vec![entry(2, 2, 2, 2, &[(12, 1)]), entry(1, 3, 1, 0, &[(33, 3)])];

        let fold = |sets: &[&Vec<DistEntry>]| {
            let mut s = DistSnapshot::default();
            for set in sets {
                s.merge_entries(set);
            }
            s
        };
        // Commutative: every arrival order gives the same aggregate.
        let abc = fold(&[&a, &b, &c]);
        assert_eq!(abc, fold(&[&c, &b, &a]));
        assert_eq!(abc, fold(&[&b, &a, &c]));
        // Associative: (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) as snapshot merges.
        let mut ab = fold(&[&a, &b]);
        ab.merge_entries(&c);
        let mut bc = fold(&[&b, &c]);
        let mut a_first = fold(&[&a]);
        a_first.merge(&bc);
        assert_eq!(ab, a_first);
        // Entries stay sorted by (class, layer) whatever the order.
        let keys: Vec<(u8, u8)> = abc.entries.iter().map(|e| (e.class, e.layer)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        // And the overlapping cell really summed.
        let g1 = abc.get(TensorClass::Gradients, 1).unwrap();
        assert_eq!((g1.zeros, g1.neg), (4, 3));
        assert_eq!(g1.buckets[10], 7);
    }

    #[test]
    fn merge_zero_extends_shorter_bucket_vectors() {
        let mut s = DistSnapshot::default();
        s.merge_entries(&[DistEntry { class: 0, layer: 1, zeros: 0, neg: 0, buckets: vec![1] }]);
        s.merge_entries(&[entry(0, 1, 0, 0, &[(4, 9)])]);
        let e = s.get(TensorClass::Weights, 1).unwrap();
        assert_eq!(e.buckets.len(), EXP_BUCKETS);
        assert_eq!((e.buckets[0], e.buckets[4]), (1, 9));
    }

    #[test]
    fn flat_diff_is_monotone_delta() {
        let last = vec![3u64, 0, 7];
        let cur = vec![5u64, 2, 7];
        assert_eq!(diff_flat(&cur, &last), vec![2, 2, 0]);
        // Empty baseline = everything is new.
        assert_eq!(diff_flat(&cur, &[]), cur);
    }

    #[test]
    fn entries_from_flat_drops_empty_cells_and_keys_correctly() {
        let mut flat = vec![0u64; FLAT_LEN];
        flat[exp_base(TensorClass::Gradients, 2) + 40] = 6;
        flat[EXP_CELLS_LEN + side_idx(TensorClass::Gradients, 2)] = 11;
        let entries = entries_from_flat(&flat);
        assert_eq!(entries.len(), 1);
        assert_eq!((entries[0].class, entries[0].layer), (TensorClass::Gradients.code(), 2));
        assert_eq!(entries[0].zeros, 11);
        assert_eq!(entries[0].buckets[40], 6);
    }

    #[test]
    fn class_codes_roundtrip() {
        for c in TensorClass::ALL {
            assert_eq!(TensorClass::from_code(c.code()), Some(c));
        }
        assert_eq!(TensorClass::from_code(9), None);
    }
}
