//! Span tracing: RAII timers around the training phases, exported as
//! Chrome `trace_event` JSON (loadable in `about:tracing` / Perfetto)
//! plus always-on per-kind rollups (count + total ns).
//!
//! Like the counters, tracing is **observation only** — spans time code,
//! they never feed a value back into it. Unlike the counters, span
//! *timings* are inherently non-deterministic; the determinism clause in
//! `docs/NUMERICS.md` therefore covers counter values but not span
//! durations. When tracing is disabled ([`crate::obs::trace_enabled`] is
//! `false`) a [`span`] call is one relaxed load and no `Instant` is ever
//! taken.
//!
//! Event buffering is bounded ([`MAX_EVENTS`]): phase-level spans emit
//! begin/end event pairs for the Chrome export, the per-matmul kinds
//! ([`SpanKind::MatmulRow`], [`SpanKind::MatmulTiled`]) are rollup-only
//! so a long training run cannot flood the buffer from the hot loop.

use std::cell::Cell;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};
use std::time::Instant;

/// Span taxonomy — one timer class per pipeline phase or kernel tier.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Model forward pass (one batch or eval chunk).
    Forward,
    /// Model backward pass (gradient sums for one batch).
    Backward,
    /// Shard/worker gradient merge (the canonical ⊞ chain).
    Merge,
    /// Gradient scaling by `1/B`.
    Scale,
    /// SGD parameter update.
    Update,
    /// Validation/test evaluation pass.
    Eval,
    /// One full training epoch.
    Epoch,
    /// Row-engine matmul call (rollup-only).
    MatmulRow,
    /// Cache-tiled matmul call (rollup-only).
    MatmulTiled,
    /// im2col / col2im lowering.
    Im2col,
    /// Wire frame write (header + payload + flush).
    WireEncode,
    /// Wire frame read (header + payload + checksum).
    WireDecode,
    /// One worker-side batch loop iteration (multi-process).
    WorkerBatch,
}

/// Every span kind, in rollup-bank order.
pub const SPAN_KINDS: [SpanKind; 13] = [
    SpanKind::Forward,
    SpanKind::Backward,
    SpanKind::Merge,
    SpanKind::Scale,
    SpanKind::Update,
    SpanKind::Eval,
    SpanKind::Epoch,
    SpanKind::MatmulRow,
    SpanKind::MatmulTiled,
    SpanKind::Im2col,
    SpanKind::WireEncode,
    SpanKind::WireDecode,
    SpanKind::WorkerBatch,
];

impl SpanKind {
    /// Stable name used in trace events, heartbeats and sink lines.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Forward => "forward",
            SpanKind::Backward => "backward",
            SpanKind::Merge => "merge",
            SpanKind::Scale => "scale",
            SpanKind::Update => "update",
            SpanKind::Eval => "eval",
            SpanKind::Epoch => "epoch",
            SpanKind::MatmulRow => "matmul_row",
            SpanKind::MatmulTiled => "matmul_tiled",
            SpanKind::Im2col => "im2col",
            SpanKind::WireEncode => "wire_encode",
            SpanKind::WireDecode => "wire_decode",
            SpanKind::WorkerBatch => "worker_batch",
        }
    }

    #[inline]
    fn idx(self) -> usize {
        self as usize
    }

    /// Phase-level kinds emit Chrome events; per-matmul kinds are
    /// rollup-only (they fire millions of times per run).
    #[inline]
    fn emits_events(self) -> bool {
        !matches!(self, SpanKind::MatmulRow | SpanKind::MatmulTiled)
    }
}

// ---------------------------------------------------------------------
// Rollups and the event buffer
// ---------------------------------------------------------------------

struct SpanCell {
    count: AtomicU64,
    ns: AtomicU64,
}

static ROLLUPS: [SpanCell; SPAN_KINDS.len()] =
    [const { SpanCell { count: AtomicU64::new(0), ns: AtomicU64::new(0) } }; SPAN_KINDS.len()];

#[derive(Copy, Clone, Debug)]
struct Event {
    kind: SpanKind,
    tid: u64,
    ts_ns: u64,
    dur_ns: u64,
}

/// Event-buffer capacity; spans past it bump the dropped counter instead
/// of growing the buffer (rollups keep counting regardless).
pub const MAX_EVENTS: usize = 1 << 16;

static EVENTS: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static DROPPED: AtomicU64 = AtomicU64::new(0);
static PROCESS_EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
}

fn process_epoch() -> Instant {
    *PROCESS_EPOCH.get_or_init(Instant::now)
}

fn tid() -> u64 {
    let t = TID.get();
    if t != 0 {
        return t;
    }
    let t = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    TID.set(t);
    t
}

/// RAII span: records its rollup (and, for phase-level kinds, a Chrome
/// event) when dropped. Inert when tracing was disabled at creation.
pub struct Span {
    live: Option<(SpanKind, Instant)>,
}

/// Open a span of `kind`. One relaxed load when tracing is disabled.
#[inline]
pub fn span(kind: SpanKind) -> Span {
    if super::trace_enabled() {
        process_epoch(); // pin t=0 before the first timestamp
        Span { live: Some((kind, Instant::now())) }
    } else {
        Span { live: None }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some((kind, start)) = self.live else {
            return;
        };
        let dur_ns = start.elapsed().as_nanos() as u64;
        let cell = &ROLLUPS[kind.idx()];
        cell.count.fetch_add(1, Ordering::Relaxed);
        cell.ns.fetch_add(dur_ns, Ordering::Relaxed);
        if kind.emits_events() {
            let ts_ns = start.duration_since(process_epoch()).as_nanos() as u64;
            let mut ev = EVENTS.lock().unwrap_or_else(PoisonError::into_inner);
            if ev.len() < MAX_EVENTS {
                ev.push(Event { kind, tid: tid(), ts_ns, dur_ns });
            } else {
                DROPPED.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// `(name, count, total_ns)` for every span kind with a non-zero count —
/// the rollup form heartbeat frames and sink lines carry.
pub fn rollup_snapshot() -> Vec<(&'static str, u64, u64)> {
    SPAN_KINDS
        .iter()
        .filter_map(|&k| {
            let cell = &ROLLUPS[k.idx()];
            let count = cell.count.load(Ordering::Relaxed);
            (count != 0).then(|| (k.name(), count, cell.ns.load(Ordering::Relaxed)))
        })
        .collect()
}

/// Buffered event count (tests; the Chrome export writes 2× this many
/// `B`/`E` records).
pub fn events_len() -> usize {
    EVENTS.lock().unwrap_or_else(PoisonError::into_inner).len()
}

/// Spans dropped after the event buffer filled.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Zero the rollups and clear the event buffer.
pub fn reset() {
    for cell in &ROLLUPS {
        cell.count.store(0, Ordering::Relaxed);
        cell.ns.store(0, Ordering::Relaxed);
    }
    EVENTS.lock().unwrap_or_else(PoisonError::into_inner).clear();
    DROPPED.store(0, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Chrome trace_event export
// ---------------------------------------------------------------------

fn render_chrome(events: &[Event], dropped: u64) -> String {
    // Begin/end pairs (`ph: B`/`ph: E`) rather than complete (`X`)
    // events: about:tracing accepts both, and balanced pairs are what
    // `bench_util::validate_chrome_trace` pins structurally.
    let mut out = String::with_capacity(64 + events.len() * 160);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    for e in events {
        let ts_us = e.ts_ns as f64 / 1000.0;
        let end_us = (e.ts_ns + e.dur_ns) as f64 / 1000.0;
        for (ph, ts) in [("B", ts_us), ("E", end_us)] {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"lnsdnn\",\"ph\":\"{ph}\",\"pid\":1,\"tid\":{},\"ts\":{ts:.3}}}",
                e.kind.name(),
                e.tid,
            ));
        }
    }
    out.push_str("],\"otherData\":{\"dropped_spans\":");
    out.push_str(&dropped.to_string());
    out.push_str("}}");
    out
}

/// Write the buffered events to `path` as Chrome `trace_event` JSON.
/// Every buffered span becomes a balanced `B`/`E` pair; the file footer
/// records how many spans the bounded buffer dropped.
pub fn write_chrome_trace(path: &Path) -> std::io::Result<()> {
    std::fs::write(path, render_chrome_trace())
}

/// Render the current buffer as Chrome `trace_event` JSON without
/// touching the filesystem — the `/trace` endpoint serves this.
pub fn render_chrome_trace() -> String {
    let ev = EVENTS.lock().unwrap_or_else(PoisonError::into_inner);
    render_chrome(&ev, DROPPED.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_are_unique_and_ordered() {
        for (i, k) in SPAN_KINDS.iter().enumerate() {
            assert_eq!(k.idx(), i, "{k:?} bank index");
            for other in &SPAN_KINDS[i + 1..] {
                assert_ne!(k.name(), other.name());
            }
        }
    }

    #[test]
    fn matmul_tiers_are_rollup_only() {
        assert!(!SpanKind::MatmulRow.emits_events());
        assert!(!SpanKind::MatmulTiled.emits_events());
        assert!(SpanKind::Forward.emits_events());
        assert!(SpanKind::Epoch.emits_events());
    }

    #[test]
    fn disabled_span_is_inert() {
        // Tracing defaults off and lib unit tests never enable it, so
        // span() here must not touch the rollups or the event buffer.
        let before = events_len();
        {
            let _s = span(SpanKind::Forward);
        }
        assert_eq!(events_len(), before);
    }

    #[test]
    fn chrome_render_emits_balanced_pairs() {
        let events = [
            Event { kind: SpanKind::Forward, tid: 1, ts_ns: 1_500, dur_ns: 2_000 },
            Event { kind: SpanKind::Update, tid: 2, ts_ns: 4_000, dur_ns: 500 },
        ];
        let json = render_chrome(&events, 3);
        assert_eq!(json.matches("\"ph\":\"B\"").count(), 2);
        assert_eq!(json.matches("\"ph\":\"E\"").count(), 2);
        assert!(json.contains("\"name\":\"forward\""));
        assert!(json.contains("\"ts\":1.500"));
        assert!(json.contains("\"ts\":3.500"));
        assert!(json.contains("\"dropped_spans\":3"));
        // And the structural checker accepts its own writer's output.
        assert_eq!(crate::bench_util::validate_chrome_trace(&json), Ok(2));
    }

    #[test]
    fn chrome_render_empty_buffer_is_valid() {
        let json = render_chrome(&[], 0);
        assert_eq!(crate::bench_util::validate_chrome_trace(&json), Ok(0));
    }
}
