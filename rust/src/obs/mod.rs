//! Observability: numerics counters, span tracing, and the telemetry
//! plumbing behind worker heartbeats. Zero dependencies, zero effect on
//! values.
//!
//! # The invariant
//!
//! **Observation is read-only.** Enabling any part of this subsystem —
//! counters, spans, heartbeats — produces bit-identical trained weights,
//! losses and eval metrics to running with it disabled, on every backend
//! and every execution path (serial / rayon / tiled / lanes / sharded /
//! multi-process). `tests/obs_exactness.rs` pins this end to end; the
//! clause lives in `docs/NUMERICS.md` §7 and the design rationale in
//! `docs/OBSERVABILITY.md`.
//!
//! Two consequences shape the implementation:
//!
//! * **Counting runs the scalar kernel bodies.** When counters are on,
//!   the slice-kernel dispatchers (`LnsSystem::mac_row` & co.) route to
//!   `*_counted` twins — exact copies of the scalar reference bodies
//!   with a stack-local [`metrics::ObsTally`]. The lane-exactness
//!   contract (NUMERICS.md §2) makes the lane and scalar kernels
//!   bit-identical, so forcing the scalar body changes no values *and*
//!   makes counter totals independent of the lane switch — which is what
//!   lets `tests/obs_exactness.rs` pin identical tallies with lanes on
//!   and off.
//! * **Disabled cost is one relaxed load** per slice-kernel call (plus
//!   one per parallel task for scope hand-off, and one per frame on the
//!   wire paths). The `obs_overhead` lines in `benches/ops.rs` pin the
//!   disabled path within noise of the pre-obs hot path.
//!
//! Counter values are **deterministic** for a fixed configuration
//! (backend, model, seed, shard/worker count): they count arithmetic
//! events, and the arithmetic is bit-reproducible. Span *timings* are
//! not deterministic — only their structure is.

pub mod dist;
pub mod metrics;
pub mod serve;
pub mod trace;

pub use metrics::{layer_scope, reenter_scope, task_scope, ObsTally, ScopeGuard};
pub use trace::{span, Span, SpanKind};

/// Schema version stamped into every `--metrics` JSONL line as `"v"`.
/// v1 (unstamped): PR 7 counters + spans. v2: adds the stamp itself;
/// the shape change this PR makes (dist telemetry lives on /metrics,
/// not in the sink) is detectable via its presence. Readers must
/// tolerate absence (⇒ v1).
pub const METRICS_LINE_VERSION: u32 = 2;

use std::sync::atomic::{AtomicBool, Ordering};

static COUNTERS_ENABLED: AtomicBool = AtomicBool::new(false);
static TRACE_ENABLED: AtomicBool = AtomicBool::new(false);

/// Are the numerics counters enabled? One relaxed load — this is the
/// whole disabled-path cost the hot paths pay.
#[inline(always)]
pub fn counters_enabled() -> bool {
    COUNTERS_ENABLED.load(Ordering::Relaxed)
}

/// Enable/disable the numerics counters (process-wide).
pub fn set_counters(on: bool) {
    COUNTERS_ENABLED.store(on, Ordering::Relaxed);
}

/// Is span tracing enabled? One relaxed load.
#[inline(always)]
pub fn trace_enabled() -> bool {
    TRACE_ENABLED.load(Ordering::Relaxed)
}

/// Enable/disable span tracing (process-wide).
pub fn set_trace(on: bool) {
    TRACE_ENABLED.store(on, Ordering::Relaxed);
}

/// Enable/disable both pillars at once.
pub fn set_all(on: bool) {
    set_counters(on);
    set_trace(on);
}

/// Zero every counter, histogram and span rollup and clear the trace
/// event buffer (the enable flags are left as they are).
pub fn reset_all() {
    metrics::reset_all();
    trace::reset();
    dist::reset();
    serve::reset_workers();
}

/// Per-epoch flush: emit the `--obs` stderr table and/or one JSONL sink
/// line (cumulative counter totals and span rollups, labelled with
/// `label`/`epoch`). No-op when neither output is configured.
pub fn flush_epoch(label: &str, epoch: usize) {
    let table = metrics::table_enabled();
    let sink = metrics::sink_active();
    if !table && !sink {
        return;
    }
    let snap = metrics::snapshot();
    let spans = trace::rollup_snapshot();
    if table {
        let mut line = format!("[obs] {label} epoch {epoch}:");
        let mut any = false;
        for e in &snap.entries {
            let total = e.total();
            if total != 0 {
                line.push_str(&format!(" {}={total}", e.name));
                any = true;
            }
        }
        if !any {
            line.push_str(" (no counter activity)");
        }
        eprintln!("{line}");
    }
    if sink {
        let mut line = format!(
            "{{\"v\":{METRICS_LINE_VERSION},\"label\":\"{}\",\"epoch\":{epoch},\"counters\":{}",
            metrics::json_escape(label),
            snap.to_json()
        );
        line.push_str(",\"spans\":{");
        for (i, (name, count, ns)) in spans.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format!("\"{name}\":{{\"count\":{count},\"ns\":{ns}}}"));
        }
        line.push_str("}}");
        metrics::sink_line(&line);
    }
}

/// Schema version of a `--metrics` JSONL line. Lines written before the
/// `"v"` stamp existed (PR 7) parse as version 1; downstream readers
/// must go through this so old sinks keep loading.
pub fn metrics_line_version(line: &str) -> u32 {
    let trimmed = line.trim_start();
    let Some(rest) = trimmed.strip_prefix("{\"v\":") else {
        return 1;
    };
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_line_version_tolerates_absence() {
        // v2 line as flush_epoch writes it.
        let v2 =
            format!("{{\"v\":{METRICS_LINE_VERSION},\"label\":\"x\",\"epoch\":0,\"counters\":{{}}}}");
        assert_eq!(metrics_line_version(&v2), METRICS_LINE_VERSION);
        // PR 7 line shape: no stamp ⇒ version 1.
        let v1 = "{\"label\":\"x\",\"epoch\":0,\"counters\":{},\"spans\":{}}";
        assert_eq!(metrics_line_version(v1), 1);
        // Garbage degrades to 1, never panics.
        assert_eq!(metrics_line_version(""), 1);
        assert_eq!(metrics_line_version("{\"v\":\"nope\"}"), 1);
    }
}
