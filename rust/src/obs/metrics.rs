//! Metrics registry: relaxed-atomic counters and fixed-bucket histograms
//! under static keys.
//!
//! Every counter is a bank of [`MAX_SCOPES`] relaxed [`AtomicU64`] cells
//! indexed by the thread-local *attribution scope* (scope 0 = unscoped,
//! scopes 1… = model layer), so per-layer ⊞ clamp/cancel statistics come
//! out of the same increment that feeds the global total. Counters are
//! **observation only**: nothing in this module is ever read back by an
//! arithmetic path, so enabling or disabling them cannot change a single
//! trained bit (see `docs/OBSERVABILITY.md` and the invariant clause in
//! `docs/NUMERICS.md`).
//!
//! Cost model: when counting is disabled
//! ([`crate::obs::counters_enabled`] is `false`) the hot paths pay one
//! relaxed atomic load per slice-kernel call and nothing else — the
//! counted kernel bodies are separate functions that are never entered.
//! When enabled, kernels accumulate into a stack-local [`ObsTally`] and
//! flush it with one batch of relaxed `fetch_add`s per call.

use std::cell::Cell;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

/// Number of attribution scopes per counter: scope 0 collects increments
/// made outside any layer scope, scopes `1..MAX_SCOPES` are model layers
/// (deeper layers clamp into the last cell).
pub const MAX_SCOPES: usize = 16;

// ---------------------------------------------------------------------
// Attribution scopes
// ---------------------------------------------------------------------

thread_local! {
    static CURRENT_SCOPE: Cell<usize> = const { Cell::new(0) };
}

/// The attribution scope increments on this thread currently land in.
#[inline]
pub fn current_scope() -> usize {
    CURRENT_SCOPE.get()
}

/// RAII guard restoring the previous attribution scope on drop. Inert
/// (field `None`) when produced by [`layer_scope`] with counting off.
pub struct ScopeGuard {
    prev: Option<usize>,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        if let Some(prev) = self.prev {
            CURRENT_SCOPE.set(prev);
        }
    }
}

/// Enter attribution scope `scope` (clamped to the scope bank) until the
/// returned guard drops.
pub fn enter_scope(scope: usize) -> ScopeGuard {
    let s = scope.min(MAX_SCOPES - 1);
    ScopeGuard { prev: Some(CURRENT_SCOPE.replace(s)) }
}

/// Enter the scope for model layer `layer` (1-based) — a no-op guard when
/// counting is disabled, so the hot path pays one relaxed load.
#[inline]
pub fn layer_scope(layer: usize) -> ScopeGuard {
    if super::counters_enabled() {
        enter_scope(layer)
    } else {
        ScopeGuard { prev: None }
    }
}

/// Capture the current scope for hand-off into rayon tasks: thread-local
/// scope does not cross pool threads, so parallel drivers capture this
/// before fanning out and re-enter it per task (see `tensor/ops.rs`).
/// `None` when counting is disabled — tasks then skip the re-entry.
#[inline]
pub fn task_scope() -> Option<usize> {
    if super::counters_enabled() {
        Some(current_scope())
    } else {
        None
    }
}

/// Re-enter a scope captured by [`task_scope`] inside a worker task.
#[inline]
pub fn reenter_scope(scope: Option<usize>) -> ScopeGuard {
    match scope {
        Some(s) => enter_scope(s),
        None => ScopeGuard { prev: None },
    }
}

// ---------------------------------------------------------------------
// Counters
// ---------------------------------------------------------------------

/// A named monotone counter with per-scope relaxed-atomic cells.
pub struct Counter {
    name: &'static str,
    cells: [AtomicU64; MAX_SCOPES],
}

impl Counter {
    /// New zeroed counter under a static key (const so counters can be
    /// `static` items — the registry is the set of statics below).
    pub const fn new(name: &'static str) -> Self {
        Counter { name, cells: [const { AtomicU64::new(0) }; MAX_SCOPES] }
    }

    /// Static key this counter is registered under.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Add `n` at the current attribution scope (relaxed; no-op for 0).
    #[inline]
    pub fn add(&self, n: u64) {
        if n != 0 {
            self.cells[current_scope()].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Sum over all scopes.
    pub fn total(&self) -> u64 {
        self.cells.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Per-scope values (index 0 = unscoped, 1… = layer).
    pub fn by_scope(&self) -> [u64; MAX_SCOPES] {
        let mut out = [0u64; MAX_SCOPES];
        for (o, c) in out.iter_mut().zip(self.cells.iter()) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }

    /// Zero every cell.
    pub fn reset(&self) {
        for c in &self.cells {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// ⊞ result clamped at the top of the magnitude range (`m > m_max`).
pub static LNS_CLAMP_HI: Counter = Counter::new("lns_clamp_hi");
/// ⊞ result clamped at the bottom of the magnitude range (`m < m_min`).
pub static LNS_CLAMP_LO: Counter = Counter::new("lns_clamp_lo");
/// Opposite-sign equal-magnitude ⊞ cancelled exactly to zero.
pub static LNS_CANCEL: Counter = Counter::new("lns_cancel");
/// ⊡ product magnitude clamped to the representable range.
pub static LNS_MUL_SAT: Counter = Counter::new("lns_mul_sat");
/// Fixed-point product saturated by the post-rounding clamp.
pub static FIXED_MUL_SAT: Counter = Counter::new("fixed_mul_sat");
/// Fixed-point accumulator saturated by the post-add clamp.
pub static FIXED_ACC_SAT: Counter = Counter::new("fixed_acc_sat");
/// Zero operands skipped by the slice kernels (`acc ⊞ 0 = acc` exactly).
pub static DOT_ZERO_SKIP: Counter = Counter::new("dot_zero_skip");
/// Non-zero ⊞ folds evaluated through a Δ± lookup table.
pub static DELTA_LUT_ADDS: Counter = Counter::new("delta_lut_adds");
/// Non-zero ⊞ folds evaluated through the closed-form bit-shift Δ±.
pub static DELTA_SHIFT_ADDS: Counter = Counter::new("delta_shift_adds");
/// Non-zero ⊞ folds evaluated through the Exact (float round-trip) Δ±.
pub static DELTA_EXACT_ADDS: Counter = Counter::new("delta_exact_adds");
/// Wire frames written (header + payload).
pub static WIRE_FRAMES_TX: Counter = Counter::new("wire_frames_tx");
/// Wire frames read and verified.
pub static WIRE_FRAMES_RX: Counter = Counter::new("wire_frames_rx");
/// Bytes written to wire peers (headers included).
pub static WIRE_BYTES_TX: Counter = Counter::new("wire_bytes_tx");
/// Bytes read from wire peers (headers included).
pub static WIRE_BYTES_RX: Counter = Counter::new("wire_bytes_rx");
/// Frames rejected by the FNV-1a payload checksum.
pub static WIRE_CHECKSUM_FAIL: Counter = Counter::new("wire_checksum_fail");
/// Heartbeat frames emitted by this process (worker role).
pub static HEARTBEAT_TX: Counter = Counter::new("heartbeat_tx");
/// Heartbeat frames consumed by this process (coordinator role).
pub static HEARTBEAT_RX: Counter = Counter::new("heartbeat_rx");
/// Worker peers detected dead by the coordinator.
pub static WORKER_DEATHS: Counter = Counter::new("worker_deaths");

/// The counter registry, in stable order (snapshots rely on it).
pub fn all() -> [&'static Counter; 18] {
    [
        &LNS_CLAMP_HI,
        &LNS_CLAMP_LO,
        &LNS_CANCEL,
        &LNS_MUL_SAT,
        &FIXED_MUL_SAT,
        &FIXED_ACC_SAT,
        &DOT_ZERO_SKIP,
        &DELTA_LUT_ADDS,
        &DELTA_SHIFT_ADDS,
        &DELTA_EXACT_ADDS,
        &WIRE_FRAMES_TX,
        &WIRE_FRAMES_RX,
        &WIRE_BYTES_TX,
        &WIRE_BYTES_RX,
        &WIRE_CHECKSUM_FAIL,
        &HEARTBEAT_TX,
        &HEARTBEAT_RX,
        &WORKER_DEATHS,
    ]
}

/// Zero every registered counter and histogram.
pub fn reset_all() {
    for c in all() {
        c.reset();
    }
    WIRE_FRAME_BYTES.reset();
    WORKER_DETECT_LATENCY_MS.reset();
}

// ---------------------------------------------------------------------
// Kernel-local tally
// ---------------------------------------------------------------------

/// Stack-local event tally a counted kernel accumulates into, flushed as
/// one batch of relaxed `fetch_add`s per kernel call — the counted bodies
/// touch no atomics in their inner loops.
#[derive(Default, Clone, Copy, Debug, PartialEq, Eq)]
pub struct ObsTally {
    /// Non-zero ⊞ folds (Δ± evaluations).
    pub adds: u64,
    /// ⊞ results clamped at `m_max`.
    pub clamp_hi: u64,
    /// ⊞ results clamped at `m_min`.
    pub clamp_lo: u64,
    /// Exact opposite-sign cancellations to zero.
    pub cancel: u64,
    /// Product saturations (⊡ magnitude clamp / fixed product clamp).
    pub mul_sat: u64,
    /// Fixed-point accumulator saturations.
    pub acc_sat: u64,
    /// Zero operands skipped.
    pub zero_skip: u64,
}

impl ObsTally {
    /// Flush an LNS kernel tally; `adds_into` selects the Δ-dispatch
    /// counter ([`DELTA_LUT_ADDS`] / [`DELTA_SHIFT_ADDS`] /
    /// [`DELTA_EXACT_ADDS`]) for this system's mode.
    #[inline]
    pub fn flush_lns(self, adds_into: &'static Counter) {
        adds_into.add(self.adds);
        LNS_CLAMP_HI.add(self.clamp_hi);
        LNS_CLAMP_LO.add(self.clamp_lo);
        LNS_CANCEL.add(self.cancel);
        LNS_MUL_SAT.add(self.mul_sat);
        DOT_ZERO_SKIP.add(self.zero_skip);
    }

    /// Flush a fixed-point kernel tally.
    #[inline]
    pub fn flush_fixed(self) {
        FIXED_MUL_SAT.add(self.mul_sat);
        FIXED_ACC_SAT.add(self.acc_sat);
        DOT_ZERO_SKIP.add(self.zero_skip);
    }
}

// ---------------------------------------------------------------------
// Histograms
// ---------------------------------------------------------------------

/// Maximum bucket count (bounds plus one overflow bucket).
pub const MAX_BUCKETS: usize = 9;

/// A fixed-bucket histogram: `bounds` are inclusive upper bounds, the
/// last cell collects everything above the final bound.
pub struct Histogram {
    name: &'static str,
    bounds: &'static [u64],
    cells: [AtomicU64; MAX_BUCKETS],
}

impl Histogram {
    /// New zeroed histogram; `bounds` must hold at most
    /// `MAX_BUCKETS - 1` ascending inclusive upper bounds.
    pub const fn new(name: &'static str, bounds: &'static [u64]) -> Self {
        assert!(bounds.len() < MAX_BUCKETS);
        Histogram { name, bounds, cells: [const { AtomicU64::new(0) }; MAX_BUCKETS] }
    }

    /// Static key this histogram is registered under.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Inclusive upper bounds (the overflow bucket follows them).
    pub fn bounds(&self) -> &'static [u64] {
        self.bounds
    }

    /// Record one observation of `v` (relaxed).
    #[inline]
    pub fn record(&self, v: u64) {
        let mut i = 0;
        while i < self.bounds.len() && v > self.bounds[i] {
            i += 1;
        }
        self.cells[i].fetch_add(1, Ordering::Relaxed);
    }

    /// Bucket counts (bounds buckets, then the overflow bucket).
    pub fn counts(&self) -> Vec<u64> {
        self.cells[..=self.bounds.len()].iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Total observations recorded.
    pub fn total(&self) -> u64 {
        self.counts().iter().sum()
    }

    /// Zero every bucket.
    pub fn reset(&self) {
        for c in &self.cells {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// Wire frame payload sizes in bytes (gradient frames dominate).
pub static WIRE_FRAME_BYTES: Histogram =
    Histogram::new("wire_frame_bytes", &[64, 256, 1024, 4096, 16384, 65536, 262144, 1048576]);
/// Milliseconds between a worker's last heartbeat and the coordinator
/// noticing it dead — the dead-worker-detection-latency metric.
pub static WORKER_DETECT_LATENCY_MS: Histogram =
    Histogram::new("worker_detect_latency_ms", &[1, 10, 50, 100, 500, 1000, 5000, 30000]);

// ---------------------------------------------------------------------
// Snapshots
// ---------------------------------------------------------------------

/// Point-in-time copy of one counter.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CounterSnap {
    /// Registered key.
    pub name: &'static str,
    /// Per-scope values at snapshot time.
    pub by_scope: [u64; MAX_SCOPES],
}

impl CounterSnap {
    /// Sum over all scopes.
    pub fn total(&self) -> u64 {
        self.by_scope.iter().sum()
    }
}

/// Point-in-time copy of the whole counter registry. Mergeable: worker
/// snapshots add into a coordinator-side aggregate entry by entry (the
/// registry order is stable, so merge is positional with a name check).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    /// One entry per registered counter, in registry order.
    pub entries: Vec<CounterSnap>,
}

impl Snapshot {
    /// Add `other` into `self` cell-wise. Entries are matched by name;
    /// unknown names are ignored (a newer peer may know more counters).
    pub fn merge(&mut self, other: &Snapshot) {
        for oe in &other.entries {
            if let Some(e) = self.entries.iter_mut().find(|e| e.name == oe.name) {
                for (a, b) in e.by_scope.iter_mut().zip(oe.by_scope.iter()) {
                    *a += b;
                }
            }
        }
    }

    /// Total for the counter registered under `name` (0 if absent).
    pub fn get(&self, name: &str) -> u64 {
        self.entries.iter().find(|e| e.name == name).map(CounterSnap::total).unwrap_or(0)
    }

    /// Render as a JSON object `{"name": {"total": N, "per_scope":
    /// [...]}}`; `per_scope` is trimmed to the last non-zero cell and
    /// omitted when only scope 0 is populated.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        let mut first = true;
        for e in &self.entries {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("\"{}\":{{\"total\":{}", e.name, e.total()));
            let last_nz = e.by_scope.iter().rposition(|&v| v != 0);
            if let Some(last) = last_nz {
                if last > 0 {
                    out.push_str(",\"per_scope\":[");
                    for (i, v) in e.by_scope[..=last].iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        out.push_str(&v.to_string());
                    }
                    out.push(']');
                }
            }
            out.push('}');
        }
        out.push('}');
        out
    }
}

/// Snapshot every registered counter.
pub fn snapshot() -> Snapshot {
    Snapshot {
        entries: all()
            .iter()
            .map(|c| CounterSnap { name: c.name(), by_scope: c.by_scope() })
            .collect(),
    }
}

/// `(name, total)` pairs for every counter with a non-zero total — the
/// compact form heartbeat frames carry.
pub fn named_totals() -> Vec<(String, u64)> {
    all()
        .iter()
        .filter(|c| c.total() != 0)
        .map(|c| (c.name().to_string(), c.total()))
        .collect()
}

// ---------------------------------------------------------------------
// Per-epoch sink (JSONL) and stderr tables
// ---------------------------------------------------------------------

static SINK: Mutex<Option<BufWriter<File>>> = Mutex::new(None);
static TABLE: AtomicBool = AtomicBool::new(false);

/// Route per-epoch metric lines (JSONL) to `path` (truncates).
pub fn set_metrics_path(path: &Path) -> std::io::Result<()> {
    let f = File::create(path)?;
    *SINK.lock().unwrap_or_else(PoisonError::into_inner) = Some(BufWriter::new(f));
    Ok(())
}

/// Is a JSONL metrics sink installed?
pub fn sink_active() -> bool {
    SINK.lock().unwrap_or_else(PoisonError::into_inner).is_some()
}

/// Append one line to the JSONL sink (no-op without a sink; I/O errors
/// are swallowed — observation must never fail the training run).
pub fn sink_line(line: &str) {
    if let Some(w) = SINK.lock().unwrap_or_else(PoisonError::into_inner).as_mut() {
        let _ = writeln!(w, "{line}");
        let _ = w.flush();
    }
}

/// Toggle the `--obs` stderr epoch tables.
pub fn set_table(on: bool) {
    TABLE.store(on, Ordering::Relaxed);
}

/// Are stderr epoch tables enabled?
pub fn table_enabled() -> bool {
    TABLE.load(Ordering::Relaxed)
}

/// Minimal JSON string escaping for labels going into sink lines.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_guard_restores_nesting() {
        assert_eq!(current_scope(), 0);
        {
            let _a = enter_scope(3);
            assert_eq!(current_scope(), 3);
            {
                let _b = enter_scope(7);
                assert_eq!(current_scope(), 7);
            }
            assert_eq!(current_scope(), 3);
        }
        assert_eq!(current_scope(), 0);
        // Out-of-range scopes clamp into the bank.
        let _c = enter_scope(MAX_SCOPES + 5);
        assert_eq!(current_scope(), MAX_SCOPES - 1);
    }

    #[test]
    fn local_counter_attributes_by_scope() {
        // A local (non-registry) counter: immune to concurrent tests.
        let c = Counter::new("test_local");
        c.add(2);
        {
            let _g = enter_scope(4);
            c.add(5);
        }
        c.add(1);
        assert_eq!(c.total(), 8);
        let by = c.by_scope();
        assert_eq!(by[0], 3);
        assert_eq!(by[4], 5);
        c.reset();
        assert_eq!(c.total(), 0);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        static H: Histogram = Histogram::new("test_hist", &[10, 100]);
        H.reset();
        for v in [0, 10, 11, 100, 101, 5000] {
            H.record(v);
        }
        assert_eq!(H.counts(), vec![2, 2, 2]);
        assert_eq!(H.total(), 6);
    }

    #[test]
    fn snapshot_merge_is_cellwise() {
        let mk = |name, v0, v1| CounterSnap {
            name,
            by_scope: {
                let mut b = [0u64; MAX_SCOPES];
                b[0] = v0;
                b[1] = v1;
                b
            },
        };
        let mut a = Snapshot { entries: vec![mk("x", 1, 2), mk("y", 0, 0)] };
        let b = Snapshot { entries: vec![mk("x", 10, 20), mk("z", 5, 5)] };
        a.merge(&b);
        assert_eq!(a.get("x"), 33);
        assert_eq!(a.get("y"), 0);
        assert_eq!(a.get("z"), 0); // unknown names ignored
    }

    #[test]
    fn snapshot_json_trims_scopes() {
        let mut by = [0u64; MAX_SCOPES];
        by[0] = 3;
        let plain = Snapshot { entries: vec![CounterSnap { name: "a", by_scope: by }] };
        assert_eq!(plain.to_json(), "{\"a\":{\"total\":3}}");
        by[2] = 4;
        let scoped = Snapshot { entries: vec![CounterSnap { name: "a", by_scope: by }] };
        assert_eq!(scoped.to_json(), "{\"a\":{\"total\":7,\"per_scope\":[3,0,4]}}");
    }

    #[test]
    fn tally_flush_routes_fixed_counters() {
        // Registry counters are shared process-wide; assert on deltas so
        // concurrent lib tests cannot race this one into flakiness.
        let before = (FIXED_MUL_SAT.total(), FIXED_ACC_SAT.total());
        let t = ObsTally { mul_sat: 3, acc_sat: 2, ..Default::default() };
        t.flush_fixed();
        assert!(FIXED_MUL_SAT.total() >= before.0 + 3);
        assert!(FIXED_ACC_SAT.total() >= before.1 + 2);
    }

    #[test]
    fn json_escape_controls() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }
}
