//! Per-(backend, shape-class) [`Tiling`] autotuner.
//!
//! The cache-tiled matmuls are bit-identical under **any** tile geometry
//! (NUMERICS.md §2: tiling re-orders which output elements compute when,
//! never any element's ⊞ chain), so tile selection is a pure performance
//! decision — which makes it safe to decide at runtime, per machine.
//!
//! The tuner sweeps a curated `{mc, kc, nc}` candidate list by timing
//! [`super::ops::matmul_tiled_with`] on synthetic backend-encoded
//! operands, and records the winner in a process-global registry keyed by
//! `(backend tag, shape class)`, where the shape class buckets each of
//! `(m, k, n)` by ⌈log2⌉ — near-identical shapes share a tuning, and the
//! sweep cost amortizes across a training run.
//!
//! Tuning is **opt-in** ([`set_autotune`] or `LNSDNN_AUTOTUNE=1`): when
//! off (the default), [`tiling_for`] is a registry lookup falling back to
//! [`Tiling::DEFAULT`], so library users pay nothing. Sweep results
//! convert to/from the repo-root `BENCH_*.json` records
//! ([`crate::bench_util::BenchRecord`]) via [`TuneOutcome::records`] and
//! [`seed_from_records`], which is how the CI benchmark lane persists the
//! measured trajectory.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

use super::ops::{self, Tiling};
use super::{Backend, Tensor};
use crate::bench_util::{bench_n, black_box, BenchRecord};

/// Log2-bucketed matmul shape `(m, k, n)`: each dimension maps to
/// ⌈log2(dim)⌉, so e.g. every `m ∈ (64, 128]` shares a bucket. Coarse on
/// purpose — tile choice is driven by order-of-magnitude cache footprints,
/// and coarse buckets keep the sweep count tiny.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    /// ⌈log2 m⌉ (output rows).
    pub m: u8,
    /// ⌈log2 k⌉ (reduction depth).
    pub k: u8,
    /// ⌈log2 n⌉ (output cols).
    pub n: u8,
}

impl ShapeClass {
    /// Classify a concrete `(m, k, n)`.
    pub fn of(m: usize, k: usize, n: usize) -> Self {
        ShapeClass { m: bucket(m), k: bucket(k), n: bucket(n) }
    }
}

/// ⌈log2(x)⌉ for `x ≥ 1` (0 maps with 1 — degenerate shapes never tile).
fn bucket(x: usize) -> u8 {
    let x = x.max(1);
    (usize::BITS - (x - 1).leading_zeros()) as u8
}

/// Tri-state enable: unset (consult env) / on / off.
static ENABLED: AtomicU8 = AtomicU8::new(0);
/// Fast path: true once any registry entry exists (saves the tag
/// allocation + mutex on the common disabled-and-empty case).
static HAS_ENTRIES: AtomicBool = AtomicBool::new(false);

/// Turn autotuning on or off process-wide (overrides `LNSDNN_AUTOTUNE`).
pub fn set_autotune(on: bool) {
    // numerics-lint: allow(atomics) — perf-only autotune flag; tiling choice never changes bits (§2)
    ENABLED.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

/// Whether a [`tiling_for`] miss triggers a sweep: explicit
/// [`set_autotune`] wins, else `LNSDNN_AUTOTUNE=1` in the environment.
pub fn autotune_enabled() -> bool {
    // numerics-lint: allow(atomics) — perf-only autotune flag; tiling choice never changes bits (§2)
    match ENABLED.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => std::env::var("LNSDNN_AUTOTUNE").is_ok_and(|v| v == "1"),
    }
}

type Registry = Mutex<HashMap<(String, ShapeClass), Tiling>>;

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// The tiling the undecorated tiled matmuls should use for this backend
/// and shape: a seeded/tuned registry entry if one exists, else (with
/// autotuning enabled) the winner of a first-use sweep, else
/// [`Tiling::DEFAULT`].
pub fn tiling_for<B: Backend>(b: &B, m: usize, k: usize, n: usize) -> Tiling {
    // numerics-lint: allow(atomics) — perf-only autotune flag; tiling choice never changes bits (§2)
    if !HAS_ENTRIES.load(Ordering::Relaxed) && !autotune_enabled() {
        return Tiling::DEFAULT;
    }
    let key = (b.tag(), ShapeClass::of(m, k, n));
    if let Some(t) = registry().lock().unwrap().get(&key) {
        return *t;
    }
    if !autotune_enabled() {
        return Tiling::DEFAULT;
    }
    let outcome = tune(b, m, k, n);
    outcome.best
}

/// Pin a tiling for `(tag, shape-class-of(m, k, n))` without sweeping —
/// the warm-start path for tilings carried in `BENCH_*.json`.
pub fn seed_tiling(tag: &str, m: usize, k: usize, n: usize, t: Tiling) {
    registry().lock().unwrap().insert((tag.to_string(), ShapeClass::of(m, k, n)), t);
    // numerics-lint: allow(atomics) — perf-only autotune flag; tiling choice never changes bits (§2)
    HAS_ENTRIES.store(true, Ordering::Relaxed);
}

/// Forget every tuned/seeded tiling (test isolation).
pub fn clear() {
    registry().lock().unwrap().clear();
    // numerics-lint: allow(atomics) — perf-only autotune flag; tiling choice never changes bits (§2)
    HAS_ENTRIES.store(false, Ordering::Relaxed);
}

/// The sweep's curated candidate list: [`Tiling::DEFAULT`] plus
/// neighbours that trade panel depth against width and chunk height —
/// the axes that move L1/L2 residency on real cores. Small on purpose:
/// the sweep runs on first use.
pub fn candidate_tilings() -> Vec<Tiling> {
    vec![
        Tiling::DEFAULT, // {16, 128, 64}
        Tiling { mc: 8, kc: 256, nc: 64 },
        Tiling { mc: 16, kc: 64, nc: 128 },
        Tiling { mc: 32, kc: 128, nc: 32 },
        Tiling { mc: 16, kc: 256, nc: 32 },
        Tiling { mc: 8, kc: 128, nc: 128 },
        Tiling { mc: 32, kc: 64, nc: 64 },
    ]
}

/// One sweep's result: the winning tiling plus every candidate's measured
/// throughput (MAC/s, median-based), for trajectory recording.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// Backend tag the sweep ran on.
    pub backend: String,
    /// Concrete shape the candidates were timed at.
    pub shape: (usize, usize, usize),
    /// The fastest candidate (also inserted into the registry).
    pub best: Tiling,
    /// `(candidate, mac_per_s)` for every swept tiling.
    pub samples: Vec<(Tiling, f64)>,
}

impl TuneOutcome {
    /// Convert the sweep samples into `BENCH_*.json` records: kernel
    /// field `autotune[mc=..,kc=..,nc=..]`, shape field `MxKxN`.
    pub fn records(&self, commit: &str, date: &str) -> Vec<BenchRecord> {
        let (m, k, n) = self.shape;
        self.samples
            .iter()
            .map(|(t, mac_per_s)| BenchRecord {
                commit: commit.to_string(),
                date: date.to_string(),
                backend: self.backend.clone(),
                kernel: kernel_name(t),
                shape: format!("{m}x{k}x{n}"),
                mac_per_s: *mac_per_s,
            })
            .collect()
    }
}

fn kernel_name(t: &Tiling) -> String {
    format!("autotune[mc={},kc={},nc={}]", t.mc, t.kc, t.nc)
}

/// Parse a [`kernel_name`]-formatted kernel field back into a tiling.
fn parse_kernel_name(kernel: &str) -> Option<Tiling> {
    let inner = kernel.strip_prefix("autotune[")?.strip_suffix(']')?;
    let mut dims = [0usize; 3];
    for (slot, part) in dims.iter_mut().zip(inner.splitn(3, ',')) {
        let (_, v) = part.split_once('=')?;
        *slot = v.parse().ok()?;
    }
    // All three dims must have parsed to something tileable (a partial
    // or zero spec would later trip `Tiling::validate`).
    if dims.iter().any(|&d| d == 0) {
        return None;
    }
    Some(Tiling { mc: dims[0], kc: dims[1], nc: dims[2] })
}

/// Parse an `MxKxN` shape field.
fn parse_shape(shape: &str) -> Option<(usize, usize, usize)> {
    let mut it = shape.splitn(3, 'x');
    let m = it.next()?.parse().ok()?;
    let k = it.next()?.parse().ok()?;
    let n = it.next()?.parse().ok()?;
    Some((m, k, n))
}

/// Warm-start the registry from persisted `BENCH_*.json` records: for
/// every `(backend, shape)` the fastest `autotune[..]` record wins.
/// Non-autotune records are ignored. Returns how many tilings were
/// seeded.
pub fn seed_from_records(records: &[BenchRecord]) -> usize {
    let mut best: HashMap<(String, ShapeClass), (f64, Tiling)> = HashMap::new();
    for r in records {
        let (Some(t), Some((m, k, n))) = (parse_kernel_name(&r.kernel), parse_shape(&r.shape))
        else {
            continue;
        };
        let key = (r.backend.clone(), ShapeClass::of(m, k, n));
        let cur = best.entry(key).or_insert((f64::NEG_INFINITY, t));
        if r.mac_per_s > cur.0 {
            *cur = (r.mac_per_s, t);
        }
    }
    let n = best.len();
    if n > 0 {
        let mut reg = registry().lock().unwrap();
        for (key, (_, t)) in best {
            reg.insert(key, t);
        }
        // numerics-lint: allow(atomics) — perf-only autotune flag; tiling choice never changes bits (§2)
        HAS_ENTRIES.store(true, Ordering::Relaxed);
    }
    n
}

/// Sweep every candidate at the given concrete shape on synthetic
/// backend-encoded operands, register the winner for the shape class,
/// and return the full outcome. Per-candidate timing budget comes from
/// `LNSDNN_AUTOTUNE_MS` (default 20 ms + 1 warm-up iteration).
pub fn tune<B: Backend>(b: &B, m: usize, k: usize, n: usize) -> TuneOutcome {
    let budget_ms = std::env::var("LNSDNN_AUTOTUNE_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20u64);
    // Synthetic operands: deterministic pseudo-uniform values in (-1, 1),
    // encoded once. Only throughput matters — every tiling computes the
    // same bits on them anyway.
    let mut rng = crate::rng::SplitMix64::new(0x7EAE ^ (m * 31 + k * 7 + n) as u64);
    let a = Tensor::from_vec(m, k, (0..m * k).map(|_| b.encode(rng.uniform(-1.0, 1.0))).collect());
    let w = Tensor::from_vec(k, n, (0..k * n).map(|_| b.encode(rng.uniform(-1.0, 1.0))).collect());
    let macs = (m * k * n) as f64;
    let mut samples = Vec::new();
    let mut best = (f64::NEG_INFINITY, Tiling::DEFAULT);
    for t in candidate_tilings() {
        let stats = bench_n(&kernel_name(&t), 1, budget_ms, Some(macs), || {
            black_box(ops::matmul_tiled_with(b, &a, &w, &t));
        });
        let mac_per_s = stats.throughput().unwrap_or(0.0);
        if mac_per_s > best.0 {
            best = (mac_per_s, t);
        }
        samples.push((t, mac_per_s));
    }
    seed_tiling(&b.tag(), m, k, n, best.1);
    TuneOutcome { backend: b.tag(), shape: (m, k, n), best: best.1, samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatBackend;

    #[test]
    fn shape_class_buckets_by_ceil_log2() {
        assert_eq!(bucket(1), 0);
        assert_eq!(bucket(2), 1);
        assert_eq!(bucket(3), 2);
        assert_eq!(bucket(64), 6);
        assert_eq!(bucket(65), 7);
        assert_eq!(bucket(128), 7);
        assert_eq!(ShapeClass::of(100, 784, 100), ShapeClass::of(96, 700, 128));
        assert_ne!(ShapeClass::of(256, 256, 256), ShapeClass::of(256, 256, 512));
    }

    #[test]
    fn kernel_name_round_trips() {
        for t in candidate_tilings() {
            assert_eq!(parse_kernel_name(&kernel_name(&t)), Some(t));
        }
        assert_eq!(parse_kernel_name("matmul_tiled"), None);
        assert_eq!(parse_shape("256x784x100"), Some((256, 784, 100)));
        assert_eq!(parse_shape("256x784"), None);
    }

    #[test]
    fn seed_and_lookup_round_trip() {
        // Serialized against other registry tests via the lock itself;
        // use a tag no real backend produces to avoid cross-talk.
        let t = Tiling { mc: 4, kc: 32, nc: 16 };
        seed_tiling("test-seed-tag", 100, 200, 300, t);
        let got = registry()
            .lock()
            .unwrap()
            .get(&("test-seed-tag".to_string(), ShapeClass::of(100, 200, 300)))
            .copied();
        assert_eq!(got, Some(t));
    }

    #[test]
    fn seed_from_records_picks_fastest_per_key() {
        let rec = |kernel: &str, mac_per_s: f64| BenchRecord {
            commit: "c".into(),
            date: "2026-08-08".into(),
            backend: "test-rec-tag".into(),
            kernel: kernel.into(),
            shape: "64x64x64".into(),
            mac_per_s,
        };
        let n = seed_from_records(&[
            rec("autotune[mc=8,kc=64,nc=32]", 1.0e9),
            rec("autotune[mc=16,kc=128,nc=64]", 3.0e9),
            rec("matmul_tiled", 9.9e9), // ignored: not an autotune record
        ]);
        assert_eq!(n, 1);
        let got = registry()
            .lock()
            .unwrap()
            .get(&("test-rec-tag".to_string(), ShapeClass::of(64, 64, 64)))
            .copied();
        assert_eq!(got, Some(Tiling { mc: 16, kc: 128, nc: 64 }));
    }

    #[test]
    fn tiling_for_defaults_when_disabled() {
        set_autotune(false);
        let b = FloatBackend::default();
        // Unseeded class → DEFAULT, no sweep.
        assert_eq!(tiling_for(&b, 3, 5, 7), Tiling::DEFAULT);
        set_autotune(true);
        // Tiny sweep (shape is small, budget irrelevant) registers a
        // winner, after which lookups hit the registry even when off.
        let got = tiling_for(&b, 4, 4, 4);
        set_autotune(false);
        assert_eq!(tiling_for(&b, 4, 4, 4), got);
    }
}
