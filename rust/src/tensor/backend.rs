//! The arithmetic backend abstraction.
//!
//! A [`Backend`] supplies every numeric operation the training engine
//! needs, over an opaque element type. Three implementations cover the
//! paper's comparison axes:
//!
//! | Backend | Domain | Paper column |
//! |---------|--------|--------------|
//! | [`FloatBackend`] | `f32` | "Float" |
//! | [`FixedBackend`] | linear Q-format | "Linear-domain fixed-point" (12b/16b) |
//! | [`LnsBackend`] | log-domain fixed point | "Log-domain fixed-point" (LUT / bit-shift, 12b/16b) |
//!
//! The activation (leaky-ReLU vs llReLU, Eq. 11) and the soft-max +
//! cross-entropy gradient (Eq. 13 vs Eq. 14) are backend methods because
//! their *implementations* are domain-specific even though their
//! mathematical role is identical.

use crate::fixed::{FixedSystem, FixedValue};
use crate::lns::{LnsSystem, LnsValue};
use crate::precision::WordSpec;

/// Everything the generic NN/training engine needs from a number system.
pub trait Backend: Send + Sync {
    /// Element (word) type.
    type E: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static;

    /// Additive identity.
    fn zero(&self) -> Self::E;
    /// Multiplicative identity.
    fn one(&self) -> Self::E;
    /// Quantize/encode a real number.
    fn encode(&self, v: f64) -> Self::E;
    /// Decode back to a real number (metrics/reporting only — never on
    /// the arithmetic path).
    fn decode(&self, e: Self::E) -> f64;

    /// Domain addition.
    fn add(&self, a: Self::E, b: Self::E) -> Self::E;
    /// Domain subtraction.
    fn sub(&self, a: Self::E, b: Self::E) -> Self::E;
    /// Domain multiplication.
    fn mul(&self, a: Self::E, b: Self::E) -> Self::E;
    /// Multiply-accumulate `acc + a·b` — the inner-loop operation.
    #[inline]
    fn mac(&self, acc: Self::E, a: Self::E, b: Self::E) -> Self::E {
        self.add(acc, self.mul(a, b))
    }

    /// Row-vectorized MAC: `acc[j] = acc[j] ⊞ (a ⊡ w[j])` for every `j`.
    ///
    /// This is the matmul inner loop lifted to slice level so backends can
    /// hoist per-call setup (Δ± LUT base pointers, word-format bounds,
    /// the multiplier's sign/magnitude split) out of it and batch the
    /// element work into branchless lanes — see the [`LnsBackend`] and
    /// [`FixedBackend`] overrides. Implementations **must** stay bit-exact
    /// with the default element-by-element definition: the documented
    /// sequential-over-`k` reduction order of the tensor ops (and thus
    /// bit-exactness with the Pallas kernels) depends on it. Lanes may
    /// batch *across `j`* (independent output elements) but never regroup
    /// one element's reduction chain (NUMERICS.md §2).
    #[inline]
    fn mac_row(&self, acc: &mut [Self::E], a: Self::E, w: &[Self::E]) {
        debug_assert_eq!(acc.len(), w.len());
        // Zero multiplier ⇒ every `acc ⊞ (0 ⊡ w)` is exactly `acc`.
        if self.is_zero(a) {
            return;
        }
        for (acc_j, &wv) in acc.iter_mut().zip(w.iter()) {
            *acc_j = self.mac(*acc_j, a, wv);
        }
    }

    /// Panel MAC — the cache-tiled matmul inner kernel: for every `p`
    /// ascending, `acc[j] = acc[j] ⊞ (a[p] ⊡ panel[p·nc + j])` where
    /// `nc = acc.len()` and `panel` is a packed row-major
    /// `a.len() × nc` tile of the stationary operand.
    ///
    /// This is [`Backend::mac_row`] lifted one level: a whole
    /// `kc × nc` tile per call, so backends can hoist per-call setup
    /// once per *panel* instead of once per row — see the
    /// [`LnsBackend`] override. Same contract as `mac_row`:
    /// implementations **must** stay bit-exact with this default
    /// (`p` ascending, elementwise `mac`), because the tiled kernels'
    /// bit-identity with the serial matmuls rests on it.
    #[inline]
    fn mac_panel(&self, acc: &mut [Self::E], a: &[Self::E], panel: &[Self::E]) {
        let nc = acc.len();
        debug_assert_eq!(panel.len(), a.len() * nc);
        for (p, &av) in a.iter().enumerate() {
            // Zero multiplier ⇒ the whole panel row leaves acc unchanged.
            if self.is_zero(av) {
                continue;
            }
            self.mac_row(acc, av, &panel[p * nc..(p + 1) * nc]);
        }
    }

    /// Zero-skipping dot continuation — the `A·Bᵀ` inner kernel: fold
    /// `acc = acc ⊞ (a[i] ⊡ w[i])` over `i` ascending, starting from the
    /// caller's `acc` (the backend zero for a fresh dot, the running
    /// output element for a `kc`-blocked one).
    ///
    /// Same contract as [`Backend::mac_row`]/[`Backend::mac_panel`]:
    /// overrides may hoist per-call setup (the LNS backend hoists its Δ±
    /// LUT pointers and clamp bounds once per slice) but must stay
    /// bit-exact with this default — both the serial `matmul_bt` dot and
    /// the tiled kernel's per-block continuation run through this one
    /// hook, so the two cannot drift apart.
    #[inline]
    fn dot_acc(&self, acc: Self::E, a: &[Self::E], w: &[Self::E]) -> Self::E {
        debug_assert_eq!(a.len(), w.len());
        let mut acc = acc;
        for (&av, &wv) in a.iter().zip(w.iter()) {
            // Zero operand ⇒ `acc ⊞ (0 ⊡ w) = acc` exactly: skip.
            if self.is_zero(av) {
                continue;
            }
            acc = self.mac(acc, av, wv);
        }
        acc
    }

    /// Element-wise slice accumulation: `acc[j] = acc[j] ⊞ x[j]`.
    ///
    /// Same contract as [`Backend::mac_row`]: overrides may hoist setup
    /// but must keep the scalar [`Backend::add`] semantics bit-exact.
    #[inline]
    fn add_slice(&self, acc: &mut [Self::E], x: &[Self::E]) {
        debug_assert_eq!(acc.len(), x.len());
        for (a, &v) in acc.iter_mut().zip(x.iter()) {
            *a = self.add(*a, v);
        }
    }

    /// Multiplication on the **SGD update path** (`η ⊡ g`). Defaults to
    /// [`Backend::mul`]; the linear fixed-point backend overrides it with
    /// stochastic rounding — deterministic round-to-nearest annihilates
    /// sub-half-ulp updates and freezes 12-bit training (Gupta et al.
    /// 2015; DESIGN.md §6).
    #[inline]
    fn mul_update(&self, a: Self::E, b: Self::E) -> Self::E {
        self.mul(a, b)
    }

    /// Snap `x` to the per-layer storage word `spec` (mixed precision,
    /// NUMERICS.md §11): round half-away-from-zero onto the spec's
    /// coarser grid and saturate to the spec's range, with the result
    /// still expressed in the backend's **base** word. Identity by
    /// default — the float backend has no storage-width axis. Called on
    /// *parameters only* (after init and after every SGD update), never
    /// inside a ⊞/⊡ chain, so it changes values, never reduction order.
    #[inline]
    fn quantize(&self, x: Self::E, spec: WordSpec) -> Self::E {
        let _ = spec;
        x
    }

    /// Leaky-ReLU (slope fixed at construction; the paper's llReLU β in
    /// the log domain).
    fn leaky_relu(&self, x: Self::E) -> Self::E;
    /// Backprop through leaky-ReLU: `upstream · act'(preact)`.
    fn leaky_relu_bwd(&self, preact: Self::E, upstream: Self::E) -> Self::E;

    /// Soft-max + cross-entropy gradient init: writes `δ_j = p_j − y_j`
    /// and returns `ln p_label` (natural-log loss contribution, reporting
    /// only).
    fn softmax_ce_grad(&self, logits: &[Self::E], label: usize, grad: &mut [Self::E]) -> f64;

    /// Is `e` the exact additive identity? Lets the matmuls skip inner
    /// loops over zero operands (`acc ⊞ (0 ⊡ w) = acc` exactly, in every
    /// backend) — a large win on sparse image data.
    fn is_zero(&self, e: Self::E) -> bool;

    /// `a > b` in the linear ordering (argmax for accuracy metrics).
    fn gt(&self, a: Self::E, b: Self::E) -> bool;

    /// Read-only value-distribution probe: classify `e` as zero/negative
    /// and report its base-2 exponent (⌊log2 |v|⌋) in the backend's own
    /// representation. **Observation only** — implementations must not
    /// mutate backend state (no SR dither draws, no counters) and callers
    /// must never feed the result back into the value path
    /// (NUMERICS.md §7).
    #[inline]
    fn dist_sample(&self, e: Self::E) -> crate::obs::dist::Sample {
        let v = self.decode(e);
        crate::obs::dist::Sample {
            zero: self.is_zero(e),
            neg: v < 0.0,
            exp: if v == 0.0 { 0 } else { v.abs().log2().floor() as i32 },
        }
    }

    /// Representable exponent range `(lo, hi)` of this backend's word
    /// format: the ⌊log2 |v|⌋ of the smallest and largest nonzero
    /// magnitudes. Headroom-to-clamp gauges are measured against `hi`.
    #[inline]
    fn dist_exp_range(&self) -> (i32, i32) {
        (-126, 127)
    }

    /// Human-readable backend tag for reports (e.g. `log16-lut`).
    fn tag(&self) -> String;
}

// ---------------------------------------------------------------------
// Float
// ---------------------------------------------------------------------

/// IEEE-754 `f32` backend — the paper's floating-point baseline.
#[derive(Clone, Debug)]
pub struct FloatBackend {
    /// Leaky-ReLU negative slope (paper uses 0.01).
    pub slope: f32,
}

impl Default for FloatBackend {
    fn default() -> Self {
        FloatBackend { slope: 0.01 }
    }
}

impl Backend for FloatBackend {
    type E = f32;

    fn zero(&self) -> f32 {
        0.0
    }
    fn one(&self) -> f32 {
        1.0
    }
    fn encode(&self, v: f64) -> f32 {
        v as f32
    }
    fn decode(&self, e: f32) -> f64 {
        e as f64
    }
    #[inline]
    fn add(&self, a: f32, b: f32) -> f32 {
        a + b
    }
    #[inline]
    fn sub(&self, a: f32, b: f32) -> f32 {
        a - b
    }
    #[inline]
    fn mul(&self, a: f32, b: f32) -> f32 {
        a * b
    }
    fn leaky_relu(&self, x: f32) -> f32 {
        if x > 0.0 {
            x
        } else {
            self.slope * x
        }
    }
    fn leaky_relu_bwd(&self, preact: f32, upstream: f32) -> f32 {
        if preact > 0.0 {
            upstream
        } else {
            self.slope * upstream
        }
    }
    fn softmax_ce_grad(&self, logits: &[f32], label: usize, grad: &mut [f32]) -> f64 {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for (g, &l) in grad.iter_mut().zip(logits) {
            *g = (l - max).exp();
            z += *g;
        }
        let mut ln_p = 0.0;
        for (j, g) in grad.iter_mut().enumerate() {
            let p = *g / z;
            if j == label {
                ln_p = (p.max(1e-30) as f64).ln();
            }
            *g = p - if j == label { 1.0 } else { 0.0 };
        }
        ln_p
    }
    fn is_zero(&self, e: f32) -> bool {
        e == 0.0
    }
    fn gt(&self, a: f32, b: f32) -> bool {
        a > b
    }
    fn tag(&self) -> String {
        "float32".into()
    }
}

// ---------------------------------------------------------------------
// Linear fixed point
// ---------------------------------------------------------------------

/// Linear-domain Q-format backend — the paper's fixed-point baseline.
///
/// The soft-max is evaluated by dequantize → float soft-max → requantize:
/// the measured quantity in the paper's linear columns is the fixed-point
/// *MAC pipeline* (matmul/activation/update); its soft-max treatment is
/// unspecified. This substitution is recorded in DESIGN.md §6.
#[derive(Debug)]
pub struct FixedBackend {
    sys: FixedSystem,
    slope: f64,
    slope_q: FixedValue,
    /// Counter for the stochastic-rounding dither sequence (see
    /// [`Backend::mul_update`]); SplitMix64-hashed so the stream is
    /// uniform yet fully deterministic per backend instance.
    sr_counter: std::sync::atomic::AtomicU64,
}

impl Clone for FixedBackend {
    fn clone(&self) -> Self {
        FixedBackend {
            sys: self.sys,
            slope: self.slope,
            slope_q: self.slope_q,
            sr_counter: std::sync::atomic::AtomicU64::new(
                // numerics-lint: allow(atomics) — clone snapshots the instance-local SR dither counter (§5)
                self.sr_counter.load(std::sync::atomic::Ordering::Relaxed),
            ),
        }
    }
}

impl FixedBackend {
    /// Build from a fixed-point system with the given leaky slope.
    pub fn new(sys: FixedSystem, slope: f64) -> Self {
        FixedBackend {
            slope_q: sys.encode_f64(slope),
            sys,
            slope,
            sr_counter: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Next dither word (SplitMix64 output of an incrementing counter).
    fn next_dither(&self) -> u32 {
        // numerics-lint: allow(atomics) — SR dither sequence is per-instance and update-path-serial (§5)
        let c = self.sr_counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut z = c.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        (z ^ (z >> 31)) as u32
    }

    /// The underlying Q-format system.
    pub fn system(&self) -> &FixedSystem {
        &self.sys
    }

    /// The leaky-ReLU slope.
    pub fn slope(&self) -> f64 {
        self.slope
    }
}

impl Backend for FixedBackend {
    type E = FixedValue;

    fn zero(&self) -> FixedValue {
        0
    }
    fn one(&self) -> FixedValue {
        self.sys.encode_f64(1.0)
    }
    fn encode(&self, v: f64) -> FixedValue {
        self.sys.encode_f64(v)
    }
    fn decode(&self, e: FixedValue) -> f64 {
        self.sys.decode_f64(e)
    }
    #[inline]
    fn add(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        self.sys.add(a, b)
    }
    #[inline]
    fn sub(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        self.sys.sub(a, b)
    }
    #[inline]
    fn mul(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        self.sys.mul(a, b)
    }
    /// Stochastic rounding on the update scaling (see trait docs).
    fn mul_update(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        self.sys.mul_sr(a, b, self.next_dither())
    }
    /// Per-layer storage word: round half-away-from-zero onto the spec's
    /// coarser code grid (`2^(b_f − spec.frac_bits)` base codes) and
    /// saturate to the spec's `±(2^(W−1) − 1)` code range. Deterministic
    /// — no SR dither draw, so replicas stay bit-identical.
    fn quantize(&self, x: FixedValue, spec: WordSpec) -> FixedValue {
        let cfg = self.sys.config();
        let bf = cfg.frac_bits;
        let spec_max = (1i64 << (spec.total_bits - 1)) - 1;
        let m = x as i64;
        let q = if spec.frac_bits >= bf {
            // Finer/equal grid: every base code is representable — pure
            // range clamp, with the spec bound floored onto base codes.
            let bound = (spec_max >> (spec.frac_bits - bf)).min(cfg.max_code() as i64);
            m.clamp(-bound, bound)
        } else {
            let shift = bf - spec.frac_bits;
            let half = (1i64 << shift) >> 1;
            let snapped = if m >= 0 { (m + half) >> shift } else { -((-m + half) >> shift) };
            (snapped.clamp(-spec_max, spec_max) << shift)
                .clamp(-(cfg.max_code() as i64), cfg.max_code() as i64)
        };
        q as FixedValue
    }
    /// Branchless lane override (see [`FixedSystem::mac_row`]): the
    /// round/saturate pipeline runs mask-style with no per-element
    /// branches, so LLVM autovectorizes it. Bit-exact with the default;
    /// a zero multiplier yields all-zero products, so no early-out is
    /// needed for equality with the default's skip.
    #[inline]
    fn mac_row(&self, acc: &mut [FixedValue], a: FixedValue, w: &[FixedValue]) {
        self.sys.mac_row(acc, a, w);
    }
    /// Branchless sequential fold (see [`FixedSystem::dot_acc`]):
    /// saturating adds are order-sensitive, so only the per-term branch
    /// goes away, never the fold order. Bit-exact with the default.
    #[inline]
    fn dot_acc(&self, acc: FixedValue, a: &[FixedValue], w: &[FixedValue]) -> FixedValue {
        self.sys.dot_acc(acc, a, w)
    }
    fn leaky_relu(&self, x: FixedValue) -> FixedValue {
        if x > 0 {
            x
        } else {
            self.sys.mul(self.slope_q, x)
        }
    }
    fn leaky_relu_bwd(&self, preact: FixedValue, upstream: FixedValue) -> FixedValue {
        if preact > 0 {
            upstream
        } else {
            self.sys.mul(self.slope_q, upstream)
        }
    }
    fn softmax_ce_grad(&self, logits: &[FixedValue], label: usize, grad: &mut [FixedValue]) -> f64 {
        let f: Vec<f64> = logits.iter().map(|&l| self.sys.decode_f64(l)).collect();
        let max = f.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = f.iter().map(|&v| (v - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        let mut ln_p = 0.0;
        for j in 0..grad.len() {
            let p = exps[j] / z;
            if j == label {
                ln_p = p.max(1e-30).ln();
            }
            grad[j] = self.sys.encode_f64(p - if j == label { 1.0 } else { 0.0 });
        }
        ln_p
    }
    fn is_zero(&self, e: FixedValue) -> bool {
        e == 0
    }
    fn gt(&self, a: FixedValue, b: FixedValue) -> bool {
        a > b
    }
    /// Integer-exact probe: `⌊log2 |code|⌋ − frac_bits` from the code's
    /// bit length — no float round-trip.
    #[inline]
    fn dist_sample(&self, e: FixedValue) -> crate::obs::dist::Sample {
        let frac = self.sys.config().frac_bits as i32;
        crate::obs::dist::Sample {
            zero: e == 0,
            neg: e < 0,
            exp: if e == 0 { 0 } else { 31 - e.unsigned_abs().leading_zeros() as i32 - frac },
        }
    }
    /// Code 1 (one ulp) up to `max_code`, as base-2 exponents.
    #[inline]
    fn dist_exp_range(&self) -> (i32, i32) {
        let cfg = self.sys.config();
        let frac = cfg.frac_bits as i32;
        let hi = 31 - cfg.max_code().unsigned_abs().leading_zeros() as i32 - frac;
        (-frac, hi)
    }
    fn tag(&self) -> String {
        format!("lin{}", self.sys.config().total_bits)
    }
}

// ---------------------------------------------------------------------
// LNS
// ---------------------------------------------------------------------

/// Log-domain fixed-point backend — the paper's contribution.
#[derive(Clone, Debug)]
pub struct LnsBackend {
    sys: LnsSystem,
    /// llReLU β offset in magnitude units: `u(log2 slope)` (Eq. 11).
    beta_units: i32,
}

impl LnsBackend {
    /// Build from an LNS system with the given leaky slope (β = log2 slope).
    pub fn new(sys: LnsSystem, slope: f64) -> Self {
        let beta_units = sys.config().to_units(slope.log2()) as i32;
        LnsBackend { sys, beta_units }
    }

    /// The underlying LNS system.
    pub fn system(&self) -> &LnsSystem {
        &self.sys
    }

    /// The llReLU β in magnitude units.
    pub fn beta_units(&self) -> i32 {
        self.beta_units
    }
}

impl Backend for LnsBackend {
    type E = LnsValue;

    fn zero(&self) -> LnsValue {
        LnsValue::ZERO
    }
    fn one(&self) -> LnsValue {
        LnsValue::ONE
    }
    fn encode(&self, v: f64) -> LnsValue {
        self.sys.encode_f64(v)
    }
    fn decode(&self, e: LnsValue) -> f64 {
        self.sys.decode_f64(e)
    }
    #[inline]
    fn add(&self, a: LnsValue, b: LnsValue) -> LnsValue {
        self.sys.add(a, b)
    }
    #[inline]
    fn sub(&self, a: LnsValue, b: LnsValue) -> LnsValue {
        self.sys.sub(a, b)
    }
    #[inline]
    fn mul(&self, a: LnsValue, b: LnsValue) -> LnsValue {
        self.sys.mul(a, b)
    }
    /// Vectorized override: one Δ±-LUT/bounds hoist per row instead of
    /// per MAC (see [`LnsSystem::mac_row`]). Bit-exact with the default.
    #[inline]
    fn mac_row(&self, acc: &mut [LnsValue], a: LnsValue, w: &[LnsValue]) {
        self.sys.mac_row(acc, a, w);
    }
    /// Panel-level override: one Δ±-LUT/bounds hoist per `kc × nc` tile
    /// (see [`LnsSystem::mac_panel`]) so the tiled hot loop stays
    /// shift → load. Bit-exact with the default.
    #[inline]
    fn mac_panel(&self, acc: &mut [LnsValue], a: &[LnsValue], panel: &[LnsValue]) {
        self.sys.mac_panel(acc, a, panel);
    }
    /// Dot-continuation override with the same per-call hoisting (see
    /// [`LnsSystem::dot_acc`]). Bit-exact with the default.
    #[inline]
    fn dot_acc(&self, acc: LnsValue, a: &[LnsValue], w: &[LnsValue]) -> LnsValue {
        self.sys.dot_acc(acc, a, w)
    }
    /// Vectorized override of the slice accumulation (same hoisting).
    #[inline]
    fn add_slice(&self, acc: &mut [LnsValue], x: &[LnsValue]) {
        self.sys.add_slice(acc, x);
    }
    /// Per-layer storage word: round the log-magnitude half-away-from-zero
    /// onto the spec's coarser grid (`2^(q_f − spec.frac_bits)` base
    /// units) and saturate to the spec's `±(2^(W−2) − 1)` magnitude
    /// range — the same saturation (never flush-to-zero) the base encode
    /// applies at its own range edge. Zero is exact in every width.
    fn quantize(&self, x: LnsValue, spec: WordSpec) -> LnsValue {
        if x.is_zero() {
            return x;
        }
        let cfg = self.sys.config();
        let bf = cfg.frac_bits;
        let spec_max = (1i64 << (spec.total_bits - 2)) - 1;
        let m = x.m as i64;
        let q = if spec.frac_bits >= bf {
            // Finer/equal grid: every base magnitude is representable —
            // pure range clamp, spec bound floored onto base units.
            let bound = (spec_max >> (spec.frac_bits - bf)).min(cfg.m_max() as i64);
            m.clamp(-bound, bound)
        } else {
            let shift = bf - spec.frac_bits;
            let half = (1i64 << shift) >> 1;
            let snapped = if m >= 0 { (m + half) >> shift } else { -((-m + half) >> shift) };
            (snapped.clamp(-spec_max, spec_max) << shift)
                .clamp(cfg.m_min() as i64, cfg.m_max() as i64)
        };
        LnsValue::new(q as i32, x.s)
    }
    /// llReLU (Eq. 11): positive values pass; negative values get β added
    /// to the log-magnitude — a single fixed-point add, no multiplier.
    fn leaky_relu(&self, x: LnsValue) -> LnsValue {
        if x.is_zero() || x.s {
            x
        } else {
            let m = (x.m as i64 + self.beta_units as i64)
                .clamp(self.sys.config().m_min() as i64, self.sys.config().m_max() as i64);
            LnsValue::new(m as i32, x.s)
        }
    }
    /// llReLU backprop: the derivative is 1 (pass) or the slope (β shift
    /// of the upstream magnitude) — again multiplier-free.
    fn leaky_relu_bwd(&self, preact: LnsValue, upstream: LnsValue) -> LnsValue {
        if preact.is_zero() || preact.s {
            upstream
        } else if upstream.is_zero() {
            upstream
        } else {
            let m = (upstream.m as i64 + self.beta_units as i64)
                .clamp(self.sys.config().m_min() as i64, self.sys.config().m_max() as i64);
            LnsValue::new(m as i32, upstream.s)
        }
    }
    fn softmax_ce_grad(&self, logits: &[LnsValue], label: usize, grad: &mut [LnsValue]) -> f64 {
        let log2_p = self.sys.log_softmax_ce_grad(logits, label, grad);
        log2_p * std::f64::consts::LN_2 // ln p = log2 p · ln 2
    }
    fn is_zero(&self, e: LnsValue) -> bool {
        e.is_zero()
    }
    fn gt(&self, a: LnsValue, b: LnsValue) -> bool {
        self.sys.gt(a, b)
    }
    /// Field-exact probe: the LNS word *is* the exponent — integer part
    /// of the log-magnitude via arithmetic shift (floor), sign from the
    /// `s` flag (`s == true ⇔ v > 0`).
    #[inline]
    fn dist_sample(&self, e: LnsValue) -> crate::obs::dist::Sample {
        crate::obs::dist::Sample {
            zero: e.is_zero(),
            neg: !e.is_zero() && !e.s,
            exp: if e.is_zero() { 0 } else { e.m >> self.sys.config().frac_bits },
        }
    }
    /// `m_min()` to `m_max()`, floored to integer exponents.
    #[inline]
    fn dist_exp_range(&self) -> (i32, i32) {
        let cfg = self.sys.config();
        (cfg.m_min() >> cfg.frac_bits, cfg.m_max() >> cfg.frac_bits)
    }
    fn tag(&self) -> String {
        let cfg = self.sys.config();
        let d = match cfg.delta {
            crate::lns::DeltaMode::Lut(_) => "lut",
            crate::lns::DeltaMode::BitShift => "bs",
            crate::lns::DeltaMode::Exact => "exact",
        };
        format!("log{}-{}", cfg.total_bits, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedConfig;
    use crate::lns::LnsConfig;

    fn backends_agree_on<F: Fn(&dyn Fn(f64) -> f64) -> (f64, f64)>(_f: F) {}

    #[test]
    fn float_backend_basics() {
        let b = FloatBackend::default();
        assert_eq!(b.mac(1.0, 2.0, 3.0), 7.0);
        assert_eq!(b.leaky_relu(-2.0), -0.02);
        assert_eq!(b.leaky_relu(2.0), 2.0);
        assert_eq!(b.tag(), "float32");
    }

    #[test]
    fn fixed_backend_tracks_float() {
        let b = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let x = b.encode(1.5);
        let y = b.encode(-0.75);
        assert!((b.decode(b.mul(x, y)) + 1.125).abs() < 2.0 * b.system().config().unit());
        assert!((b.decode(b.leaky_relu(y)) + 0.0075).abs() < 2.0 * b.system().config().unit());
        assert_eq!(b.tag(), "lin16");
    }

    #[test]
    fn lns_backend_llrelu_is_magnitude_shift() {
        let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let x = b.encode(-2.0);
        let y = b.leaky_relu(x);
        assert_eq!(y.m, x.m + b.beta_units());
        assert!(!y.s);
        assert!((b.decode(y) + 0.02).abs() < 0.001);
        // Positive passes untouched.
        let p = b.encode(3.0);
        assert_eq!(b.leaky_relu(p), p);
    }

    #[test]
    fn lns_llrelu_bwd_consistent_with_derivative() {
        let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let up = b.encode(0.5);
        // preact > 0 → pass
        assert_eq!(b.leaky_relu_bwd(b.encode(1.0), up), up);
        // preact < 0 → scaled by slope
        let got = b.decode(b.leaky_relu_bwd(b.encode(-1.0), up));
        assert!((got - 0.005).abs() < 0.0005, "{got}");
    }

    #[test]
    fn softmax_grads_agree_across_backends() {
        let fb = FloatBackend::default();
        let xb = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let lb = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);

        let logits = [0.5f64, -1.0, 2.0, 0.0];
        let label = 2;

        let lf: Vec<f32> = logits.iter().map(|&v| fb.encode(v)).collect();
        let mut gf = vec![0f32; 4];
        let loss_f = fb.softmax_ce_grad(&lf, label, &mut gf);

        let lx: Vec<i32> = logits.iter().map(|&v| xb.encode(v)).collect();
        let mut gx = vec![0i32; 4];
        let loss_x = xb.softmax_ce_grad(&lx, label, &mut gx);

        let ll: Vec<LnsValue> = logits.iter().map(|&v| lb.encode(v)).collect();
        let mut gl = vec![LnsValue::ZERO; 4];
        let loss_l = lb.softmax_ce_grad(&ll, label, &mut gl);

        assert!((loss_f - loss_x).abs() < 0.01);
        assert!((loss_f - loss_l).abs() < 0.08, "{loss_f} vs {loss_l}");
        for j in 0..4 {
            let f = fb.decode(gf[j]);
            assert!((f - xb.decode(gx[j])).abs() < 0.01, "fixed δ[{j}]");
            assert!((f - lb.decode(gl[j])).abs() < 0.05, "lns δ[{j}]");
        }
        backends_agree_on(|_| (0.0, 0.0));
    }

    #[test]
    fn mac_panel_default_matches_scalar_macs() {
        // The default hook must equal the elementwise mac fold (p
        // ascending) on backends that do not override it.
        let b = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let vals = [0.5, -1.25, 0.0, 2.0, -0.125, 0.75];
        let a: Vec<i32> = vals.iter().map(|&v| b.encode(v)).collect();
        let panel: Vec<i32> =
            (0..a.len() * 3).map(|i| b.encode((i as f64 - 8.0) / 4.0)).collect();
        let mut acc = vec![b.encode(0.25); 3];
        let mut want = acc.clone();
        b.mac_panel(&mut acc, &a, &panel);
        for (p, &av) in a.iter().enumerate() {
            for j in 0..3 {
                want[j] = b.mac(want[j], av, panel[p * 3 + j]);
            }
        }
        assert_eq!(acc, want);
    }

    #[test]
    fn dist_probe_matches_representation() {
        // LNS: exponent comes straight off the word's integer field.
        let lb = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let s = lb.dist_sample(lb.encode(-8.0));
        assert!(!s.zero && s.neg);
        assert_eq!(s.exp, 3);
        assert!(lb.dist_sample(lb.zero()).zero);
        let (lo, hi) = lb.dist_exp_range();
        assert!(lo < 0 && hi > 0, "{lo}..{hi}");

        // Fixed: bit length of the code minus the fraction width.
        let fb = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let s = fb.dist_sample(fb.encode(0.5));
        assert!(!s.zero && !s.neg);
        assert_eq!(s.exp, -1);
        assert_eq!(fb.dist_exp_range().0, -(fb.system().config().frac_bits as i32));

        // Float: default decode-based probe.
        let flb = FloatBackend::default();
        let s = flb.dist_sample(-3.0f32);
        assert!(s.neg);
        assert_eq!(s.exp, 1);
    }

    #[test]
    fn quantize_snaps_to_narrow_word() {
        let lb = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let w8 = WordSpec { total_bits: 8, frac_bits: 2 };
        // 2^1.5 → m = 1.5·2^10 = 1536 = 6·2^8: already on the w8 grid.
        let x = lb.encode(2.0f64.powf(1.5));
        assert_eq!(lb.quantize(x, w8), x);
        // Round half-away on the 2^8-unit grid.
        assert_eq!(lb.quantize(LnsValue::new(1536 + 100, true), w8).m, 1536);
        assert_eq!(lb.quantize(LnsValue::new(1536 + 128, true), w8).m, 1536 + 256);
        assert_eq!(lb.quantize(LnsValue::new(-(1536 + 128), false), w8).m, -(1536 + 256));
        // Base m_max (16383) saturates to the w8 range 63·2^8 = 16128.
        let top = LnsValue::new(lb.system().config().m_max(), true);
        assert_eq!(lb.quantize(top, w8).m, 63 << 8);
        // Zero is exact in every width; the base word is an identity spec.
        assert!(lb.quantize(lb.zero(), w8).is_zero());
        let w16 = WordSpec { total_bits: 16, frac_bits: 10 };
        assert_eq!(lb.quantize(LnsValue::new(1536 + 100, true), w16).m, 1536 + 100);

        // Fixed: w16 (b_f 11) → w8 (b_f 3): grid 2^8 codes, range ±127·2^8.
        let fb = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let w8f = WordSpec { total_bits: 8, frac_bits: 3 };
        let c = fb.encode(1.4375); // 2944 codes = 11.5 · 2^8: exactly half
        assert_eq!(fb.quantize(c, w8f), 12 << 8, "half rounds away from zero");
        assert_eq!(fb.quantize(-c, w8f), -(12 << 8));
        assert_eq!(fb.quantize(fb.encode(100.0), w8f), 127 << 8, "clamped to w8 range");

        // Float backend: identity (no storage-width axis).
        let flb = FloatBackend::default();
        assert_eq!(flb.quantize(1.234f32, w8f), 1.234f32);
    }

    #[test]
    fn tags_distinguish_configs() {
        let a = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let b = LnsBackend::new(LnsSystem::new(LnsConfig::w12_bitshift()), 0.01);
        assert_eq!(a.tag(), "log16-lut");
        assert_eq!(b.tag(), "log12-bs");
    }
}
