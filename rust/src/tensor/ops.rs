//! Backend-generic tensor operations (paper Eq. 10 and friends).
//!
//! Reduction order is **fixed and documented** everywhere: LNS addition is
//! approximate and non-associative, so "same order" is part of the numeric
//! spec — the Pallas kernels reduce in the identical order, which is what
//! makes bit-exact cross-checking possible.

use super::{Backend, Tensor};

/// `C = A·B` (`[m,k]·[k,n] → [m,n]`), accumulating **sequentially over k
/// ascending** from the backend zero (Eq. 10's ⊞ chain).
pub fn matmul<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.rows, "matmul inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    for i in 0..m {
        let arow = a.row(i);
        let orow = out.row_mut(i);
        for p in 0..k {
            let av = arow[p];
            // Zero operand ⇒ the whole inner row is `acc ⊞ 0 = acc`: skip.
            // Exact in every backend; large win on sparse image data.
            if b.is_zero(av) {
                continue;
            }
            let wrow = w.row(p);
            for j in 0..n {
                orow[j] = b.mac(orow[j], av, wrow[j]);
            }
        }
    }
    out
}

/// `C = A·Bᵀ` without materializing the transpose (`[m,k]·[n,k] → [m,n]`).
pub fn matmul_bt<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.cols, "matmul_bt inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, w.rows);
    let mut out = Tensor::full(m, n, b.zero());
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let wrow = w.row(j);
            let mut acc = b.zero();
            for p in 0..k {
                if b.is_zero(arow[p]) {
                    continue; // acc ⊞ (0 ⊡ w) = acc exactly
                }
                acc = b.mac(acc, arow[p], wrow[p]);
            }
            *out.at_mut(i, j) = acc;
        }
    }
    out
}

/// `C = Aᵀ·B` (`[k,m]·[k,n] → [m,n]`): the gradient outer-product shape.
/// Accumulates over k ascending.
pub fn matmul_at<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.rows, w.rows, "matmul_at inner-dim mismatch");
    let (k, m, n) = (a.rows, a.cols, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    for p in 0..k {
        let arow = a.row(p);
        let wrow = w.row(p);
        for i in 0..m {
            let av = arow[i];
            if b.is_zero(av) {
                continue; // acc ⊞ (0 ⊡ w) = acc exactly
            }
            let orow = out.row_mut(i);
            for j in 0..n {
                orow[j] = b.mac(orow[j], av, wrow[j]);
            }
        }
    }
    out
}

/// Row-broadcast add: `out[i,j] = x[i,j] + bias[j]`.
pub fn add_bias<B: Backend>(b: &B, x: &mut Tensor<B::E>, bias: &[B::E]) {
    assert_eq!(x.cols, bias.len(), "bias length mismatch");
    for i in 0..x.rows {
        let row = x.row_mut(i);
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v = b.add(*v, bv);
        }
    }
}

/// Column sums (`[m,n] → [n]`), reducing over rows ascending — the bias
/// gradient.
pub fn col_sum<B: Backend>(b: &B, x: &Tensor<B::E>) -> Vec<B::E> {
    let mut out = vec![b.zero(); x.cols];
    for i in 0..x.rows {
        for (o, &v) in out.iter_mut().zip(x.row(i)) {
            *o = b.add(*o, v);
        }
    }
    out
}

/// Elementwise map through the backend activation.
pub fn leaky_relu<B: Backend>(b: &B, x: &Tensor<B::E>) -> Tensor<B::E> {
    Tensor {
        rows: x.rows,
        cols: x.cols,
        data: x.data.iter().map(|&v| b.leaky_relu(v)).collect(),
    }
}

/// Elementwise activation backprop: `out = upstream ⊙ act'(preact)`.
pub fn leaky_relu_bwd<B: Backend>(
    b: &B,
    preact: &Tensor<B::E>,
    upstream: &Tensor<B::E>,
) -> Tensor<B::E> {
    assert_eq!(preact.rows, upstream.rows);
    assert_eq!(preact.cols, upstream.cols);
    Tensor {
        rows: preact.rows,
        cols: preact.cols,
        data: preact
            .data
            .iter()
            .zip(&upstream.data)
            .map(|(&p, &u)| b.leaky_relu_bwd(p, u))
            .collect(),
    }
}

/// Scale every element by a real constant (encoded once).
pub fn scale<B: Backend>(b: &B, x: &mut Tensor<B::E>, c: f64) {
    let ce = b.encode(c);
    for v in x.data.iter_mut() {
        *v = b.mul(*v, ce);
    }
}

/// Index of the row maximum under the backend's linear order (argmax for
/// classification metrics — needs no decode).
pub fn argmax_row<B: Backend>(b: &B, row: &[B::E]) -> usize {
    let mut best = 0;
    for j in 1..row.len() {
        if b.gt(row[j], row[best]) {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatBackend;

    fn fb() -> FloatBackend {
        FloatBackend::default()
    }

    fn t(rows: usize, cols: usize, v: &[f32]) -> Tensor<f32> {
        Tensor::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_known() {
        let b = fb();
        let a = t(2, 2, &[1., 2., 3., 4.]);
        let w = t(2, 2, &[5., 6., 7., 8.]);
        let c = matmul(&b, &a, &w);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let b = fb();
        let a = t(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let w = t(4, 3, &[1., 0., 1., 0., 1., 0., 1., 1., 1., 2., 0., -1.]);
        let direct = matmul_bt(&b, &a, &w);
        let via_t = matmul(&b, &a, &w.transpose());
        assert_eq!(direct.rows, via_t.rows);
        for (x, y) in direct.data.iter().zip(&via_t.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_at_matches_explicit_transpose() {
        let b = fb();
        let a = t(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let w = t(3, 4, &[1., 0., 1., 0., 0., 1., 0., 1., 1., 1., 2., 0.]);
        let direct = matmul_at(&b, &a, &w);
        let via_t = matmul(&b, &a.transpose(), &w);
        for (x, y) in direct.data.iter().zip(&via_t.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_and_colsum_roundtrip() {
        let b = fb();
        let mut x = t(2, 3, &[0., 0., 0., 0., 0., 0.]);
        add_bias(&b, &mut x, &[1., 2., 3.]);
        assert_eq!(col_sum(&b, &x), vec![2., 4., 6.]);
    }

    #[test]
    fn activation_roundtrip() {
        let b = fb();
        let x = t(1, 4, &[-2., -0.5, 0.5, 2.]);
        let y = leaky_relu(&b, &x);
        assert_eq!(y.data, vec![-0.02, -0.005, 0.5, 2.]);
        let up = t(1, 4, &[1., 1., 1., 1.]);
        let g = leaky_relu_bwd(&b, &x, &up);
        assert_eq!(g.data, vec![0.01, 0.01, 1., 1.]);
    }

    #[test]
    fn scale_applies() {
        let b = fb();
        let mut x = t(1, 3, &[2., 4., 6.]);
        scale(&b, &mut x, 0.5);
        assert_eq!(x.data, vec![1., 2., 3.]);
    }

    #[test]
    fn argmax_basic() {
        let b = fb();
        assert_eq!(argmax_row(&b, &[0.1f32, -3.0, 7.0, 2.0]), 2);
        assert_eq!(argmax_row(&b, &[-1.0f32, -0.5]), 1);
    }

    #[test]
    #[should_panic(expected = "inner-dim mismatch")]
    fn shape_mismatch_panics() {
        let b = fb();
        let a = t(2, 3, &[0.; 6]);
        let w = t(2, 2, &[0.; 4]);
        let _ = matmul(&b, &a, &w);
    }
}
