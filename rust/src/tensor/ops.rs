//! Backend-generic tensor operations (paper Eq. 10 and friends).
//!
//! Reduction order is **fixed and documented** everywhere: LNS addition is
//! approximate and non-associative, so "same order" is part of the numeric
//! spec — the Pallas kernels reduce in the identical order, which is what
//! makes bit-exact cross-checking possible.
//!
//! # Parallel execution
//!
//! Every matmul comes in three flavours:
//!
//! * `*_serial` — the reference single-thread implementation,
//! * `*_par` — rayon row-parallel: the **output rows** (the `m`
//!   dimension) are partitioned across threads while each row keeps the
//!   exact sequential-over-`k`-ascending reduction, so results are
//!   **bit-identical** to the serial versions in every backend (see
//!   `tests/parallel_determinism.rs`),
//! * the undecorated name — dispatches to the parallel path when the
//!   problem is big enough to amortize the fork/join overhead.
//!
//! On top of the row engine sit the **cache-tiled** kernels
//! (`*_tiled`, [`Tiling`]): the `w` operand is packed once into
//! L1/L2-sized column panels, each row chunk packs its `a` rows into
//! `kc`-block slabs, and the output is blocked over
//! (row-chunk × column-panel) tiles. The undecorated tiled names take
//! their tile geometry from the [`super::autotune`] registry (default
//! [`Tiling::DEFAULT`] until a sweep has run). Tiling only re-orders *which*
//! output elements are computed when — every individual element still
//! accumulates over `k` ascending (`kc` blocks walked in ascending
//! order, `p` ascending inside each block, no partial accumulators ever
//! merged), so the tiled results are **bit-identical** to the serial
//! references in every backend (see `tests/tiled_exactness.rs`). The
//! undecorated names auto-dispatch to the tiled path when the packed
//! operand is large enough to thrash cache on the row path.
//!
//! All paths drive the backend through the slice-level
//! [`Backend::mac_row`] / [`Backend::add_slice`] /
//! [`Backend::mac_panel`] / [`Backend::dot_acc`] hooks, which lets LNS
//! hoist its Δ± LUT pointers and sign handling out of the inner loop
//! (once per panel or dot slice on the tiled paths).

use super::{Backend, Tensor};
use crate::obs::{reenter_scope, span, task_scope, SpanKind};
use rayon::prelude::*;
use std::sync::atomic::{AtomicU8, Ordering};

/// Minimum total work (MACs for matmuls, elements for maps) before an op
/// takes the parallel path. Below this the fork/join overhead outweighs
/// the win; above it the parallel and serial paths are interchangeable
/// because they are bit-identical.
const PAR_MIN_WORK: usize = 1 << 15;

/// Take the parallel path for an op with `rows` independent output rows
/// and `work` total inner operations?
#[inline]
fn parallel_worthwhile(rows: usize, work: usize) -> bool {
    rows > 1 && work >= PAR_MIN_WORK && rayon::current_num_threads() > 1
}

/// Row count above which per-row *bookkeeping* loops (the soft-max/CE
/// head in `nn::mlp`, the metric loop in `train::metrics`) fan out.
const PAR_MIN_ROWS: usize = 64;

/// Dispatch predicate for those per-row bookkeeping loops — one shared
/// definition so the training and evaluation paths cannot silently
/// diverge on threshold or thread-count handling.
#[inline]
pub(crate) fn par_rows_worthwhile(rows: usize) -> bool {
    rows >= PAR_MIN_ROWS && rayon::current_num_threads() > 1
}

// ---------------------------------------------------------------------
// C = A·B
// ---------------------------------------------------------------------

/// One output row of `A·B`: `out[j] = Σ_p arow[p] ⊡ w[p][j]`, accumulating
/// sequentially over `p` ascending from the caller-initialized zeros.
/// Shared verbatim by the serial and parallel drivers — bit-exactness of
/// the two is by construction.
#[inline]
fn matmul_row<B: Backend>(b: &B, arow: &[B::E], w: &Tensor<B::E>, orow: &mut [B::E]) {
    for (p, &av) in arow.iter().enumerate() {
        // Zero operand ⇒ the whole inner row is `acc ⊞ 0 = acc`: skip.
        // Exact in every backend; large win on sparse image data.
        if b.is_zero(av) {
            continue;
        }
        b.mac_row(orow, av, w.row(p));
    }
}

/// `C = A·B` (`[m,k]·[k,n] → [m,n]`), accumulating **sequentially over k
/// ascending** from the backend zero (Eq. 10's ⊞ chain). Dispatches by
/// shape: the cache-tiled path when the `w` footprint is large enough to
/// thrash the row path, the rayon row-parallel path on other large
/// problems, the serial reference otherwise — all three bit-identical.
pub fn matmul<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    match matmul_dispatch() {
        MatmulDispatch::ForceTiled => return matmul_tiled(b, a, w),
        MatmulDispatch::Auto if tiled_worthwhile(a.rows, a.cols * w.cols) => {
            return matmul_tiled(b, a, w);
        }
        _ => {}
    }
    if parallel_worthwhile(a.rows, a.rows * a.cols * w.cols) {
        matmul_par(b, a, w)
    } else {
        matmul_serial(b, a, w)
    }
}

/// Single-thread reference implementation of [`matmul`].
pub fn matmul_serial<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.rows, "matmul inner-dim mismatch");
    let _sp = span(SpanKind::MatmulRow);
    let (m, n) = (a.rows, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    for i in 0..m {
        matmul_row(b, a.row(i), w, out.row_mut(i));
    }
    out
}

/// Rayon row-parallel [`matmul`]: output rows are distributed across the
/// pool; each row's reduction order is unchanged, so the result is
/// bit-identical to [`matmul_serial`].
pub fn matmul_par<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.rows, "matmul inner-dim mismatch");
    let _sp = span(SpanKind::MatmulRow);
    let (m, n) = (a.rows, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    if n == 0 {
        return out;
    }
    // Thread-local counter scope does not cross the rayon pool: capture
    // it here and re-enter per task (None — and free — when counting is
    // off). Scope is a function of the *spawning* context, never of
    // scheduling, so counter attribution stays deterministic.
    let scope = task_scope();
    out.data.par_chunks_mut(n).enumerate().for_each(|(i, orow)| {
        let _g = reenter_scope(scope);
        matmul_row(b, a.row(i), w, orow)
    });
    out
}

// ---------------------------------------------------------------------
// C = A·Bᵀ
// ---------------------------------------------------------------------

/// Zero-skipping dot product, accumulating over the index ascending —
/// one call into the [`Backend::dot_acc`] hook, which the serial rows
/// and the tiled `kc`-block continuations both use (one copy of the
/// skip/fold logic, so the paths cannot drift).
#[inline]
fn dot_skip_zero<B: Backend>(b: &B, a: &[B::E], w: &[B::E]) -> B::E {
    b.dot_acc(b.zero(), a, w)
}

/// One output row of `A·Bᵀ`.
#[inline]
fn matmul_bt_row<B: Backend>(b: &B, arow: &[B::E], w: &Tensor<B::E>, orow: &mut [B::E]) {
    for (j, o) in orow.iter_mut().enumerate() {
        *o = dot_skip_zero(b, arow, w.row(j));
    }
}

/// `C = A·Bᵀ` without materializing the transpose (`[m,k]·[n,k] → [m,n]`).
/// Dispatches by shape like [`matmul`] (tiled / row-parallel / serial).
pub fn matmul_bt<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    match matmul_dispatch() {
        MatmulDispatch::ForceTiled => return matmul_bt_tiled(b, a, w),
        MatmulDispatch::Auto if tiled_worthwhile(a.rows, w.rows * w.cols) => {
            return matmul_bt_tiled(b, a, w);
        }
        _ => {}
    }
    if parallel_worthwhile(a.rows, a.rows * a.cols * w.rows) {
        matmul_bt_par(b, a, w)
    } else {
        matmul_bt_serial(b, a, w)
    }
}

/// Single-thread reference implementation of [`matmul_bt`].
pub fn matmul_bt_serial<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.cols, "matmul_bt inner-dim mismatch");
    let _sp = span(SpanKind::MatmulRow);
    let (m, n) = (a.rows, w.rows);
    let mut out = Tensor::full(m, n, b.zero());
    for i in 0..m {
        matmul_bt_row(b, a.row(i), w, out.row_mut(i));
    }
    out
}

/// Rayon row-parallel [`matmul_bt`], bit-identical to the serial path.
pub fn matmul_bt_par<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.cols, "matmul_bt inner-dim mismatch");
    let _sp = span(SpanKind::MatmulRow);
    let (m, n) = (a.rows, w.rows);
    let mut out = Tensor::full(m, n, b.zero());
    if n == 0 {
        return out;
    }
    let scope = task_scope();
    out.data.par_chunks_mut(n).enumerate().for_each(|(i, orow)| {
        let _g = reenter_scope(scope);
        matmul_bt_row(b, a.row(i), w, orow)
    });
    out
}

// ---------------------------------------------------------------------
// C = Aᵀ·B
// ---------------------------------------------------------------------

/// `C = Aᵀ·B` (`[k,m]·[k,n] → [m,n]`): the gradient outer-product shape.
/// Accumulates over k ascending. Dispatches by shape like [`matmul`]
/// (tiled / row-parallel / serial).
pub fn matmul_at<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    match matmul_dispatch() {
        MatmulDispatch::ForceTiled => return matmul_at_tiled(b, a, w),
        MatmulDispatch::Auto if tiled_worthwhile(a.cols, a.rows * w.cols) => {
            return matmul_at_tiled(b, a, w);
        }
        _ => {}
    }
    if parallel_worthwhile(a.cols, a.rows * a.cols * w.cols) {
        matmul_at_par(b, a, w)
    } else {
        matmul_at_serial(b, a, w)
    }
}

/// Single-thread reference implementation of [`matmul_at`]. Keeps the
/// seed's cache-friendly `k`-outer loop order; every output element still
/// accumulates over `k` ascending, which is all the numeric spec fixes.
pub fn matmul_at_serial<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.rows, w.rows, "matmul_at inner-dim mismatch");
    let _sp = span(SpanKind::MatmulRow);
    let (k, m, n) = (a.rows, a.cols, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    for p in 0..k {
        let arow = a.row(p);
        let wrow = w.row(p);
        for i in 0..m {
            let av = arow[i];
            if b.is_zero(av) {
                continue; // acc ⊞ (0 ⊡ w) = acc exactly
            }
            b.mac_row(out.row_mut(i), av, wrow);
        }
    }
    out
}

/// Rayon row-parallel [`matmul_at`]: each task owns one output row `i`
/// (one column of `A`) and walks `k` ascending — the per-element
/// reduction order is identical to the serial `k`-outer loop, so results
/// are bit-identical.
pub fn matmul_at_par<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.rows, w.rows, "matmul_at inner-dim mismatch");
    let _sp = span(SpanKind::MatmulRow);
    let (k, m, n) = (a.rows, a.cols, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    if n == 0 {
        return out;
    }
    let scope = task_scope();
    out.data.par_chunks_mut(n).enumerate().for_each(|(i, orow)| {
        let _g = reenter_scope(scope);
        for p in 0..k {
            let av = a.row(p)[i];
            if b.is_zero(av) {
                continue;
            }
            b.mac_row(orow, av, w.row(p));
        }
    });
    out
}

// ---------------------------------------------------------------------
// Cache-tiled kernels
// ---------------------------------------------------------------------

/// Tile geometry for the cache-blocked matmul kernels.
///
/// The stationary operand (`w`, or `a` for `matmul_bt`) is packed once
/// into column panels `nc` wide, each split into `kc`-deep blocks along
/// the reduction dimension, so the hot loop streams a contiguous
/// `kc × nc` panel that fits in L1/L2 instead of striding through full
/// `w` rows. Output rows are processed `mc` at a time (the rayon task
/// granularity).
///
/// Tile sizes affect **performance only**: every output element's ⊞
/// reduction walks `kc` blocks in ascending order with `k` ascending
/// inside each block, so any tiling produces bits identical to
/// [`matmul_serial`] — which is what lets tests sweep tiny tiles to
/// exercise remainder handling.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Tiling {
    /// Output rows per task (row-chunk height).
    pub mc: usize,
    /// Reduction-dimension block depth.
    pub kc: usize,
    /// Column-panel width.
    pub nc: usize,
}

impl Tiling {
    /// Default tile sizes: a `kc × nc` panel is 32 KiB at 4-byte words
    /// (64 KiB for the two-field LNS value) — L1-resident on typical
    /// cores, comfortably L2-resident everywhere.
    ///
    /// Any tiling — including pathological ones — produces bit-identical
    /// results, because tiling only re-orders *which* output elements
    /// compute when (see `docs/NUMERICS.md` §2):
    ///
    /// ```
    /// use lnsdnn::tensor::{ops, FloatBackend, Tensor, Tiling};
    /// let b = FloatBackend::default();
    /// let a = Tensor::from_vec(2, 3, vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
    /// let w = Tensor::from_vec(3, 2, vec![0.5f32, -1.0, 2.0, 0.25, -0.5, 1.5]);
    /// let tiny = Tiling { mc: 1, kc: 2, nc: 1 };
    /// let tiled = ops::matmul_tiled_with(&b, &a, &w, &tiny);
    /// assert_eq!(tiled.data, ops::matmul_serial(&b, &a, &w).data);
    /// ```
    pub const DEFAULT: Tiling = Tiling { mc: 16, kc: 128, nc: 64 };

    fn validate(&self) {
        assert!(self.mc >= 1 && self.kc >= 1 && self.nc >= 1, "tile dims must be ≥ 1");
    }
}

impl Default for Tiling {
    fn default() -> Self {
        Tiling::DEFAULT
    }
}

/// Packed-operand footprint (elements) above which the undecorated
/// matmuls prefer the tiled path: ≈128 KiB at 4-byte words, the point
/// where the row path's full-`w` sweep per output row stops fitting L1/L2
/// comfortably. The 784-wide MLP layers (784·100) and the 256³ bench both
/// clear it; small conv kernel matrices stay on the row path.
const TILED_MIN_FOOTPRINT: usize = 1 << 15;

/// Minimum output rows for the tiled path: packing costs one pass over
/// `w`, which needs a few output rows to amortize (the paper-protocol
/// batch of 5 stays on the row path; eval-sized batches tile).
const TILED_MIN_ROWS: usize = 8;

#[inline]
fn tiled_worthwhile(rows: usize, packed_footprint: usize) -> bool {
    rows >= TILED_MIN_ROWS && packed_footprint >= TILED_MIN_FOOTPRINT
}

/// Runtime override for the undecorated matmul dispatch. Because every
/// path is bit-identical, forcing one globally changes performance only —
/// the shard-determinism suite uses exactly that to re-run full training
/// with the tiled kernels forced on.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum MatmulDispatch {
    /// Shape-based choice between tiled, row-parallel and serial.
    Auto,
    /// Every undecorated matmul takes the cache-tiled path.
    ForceTiled,
    /// Every undecorated matmul takes the row engine (pre-tiling
    /// behaviour) — the A/B baseline for benches and tests.
    ForceRow,
}

static MATMUL_DISPATCH: AtomicU8 = AtomicU8::new(0);

/// Set the process-wide dispatch override (test/bench plumbing; safe at
/// any time because all paths produce identical bits).
pub fn set_matmul_dispatch(d: MatmulDispatch) {
    let v = match d {
        MatmulDispatch::Auto => 0,
        MatmulDispatch::ForceTiled => 1,
        MatmulDispatch::ForceRow => 2,
    };
    // numerics-lint: allow(atomics) — dispatch override is perf-only: every path is bit-identical (§2)
    MATMUL_DISPATCH.store(v, Ordering::Relaxed);
}

/// The dispatch override currently in effect.
pub fn matmul_dispatch() -> MatmulDispatch {
    // numerics-lint: allow(atomics) — dispatch override is perf-only: every path is bit-identical (§2)
    match MATMUL_DISPATCH.load(Ordering::Relaxed) {
        1 => MatmulDispatch::ForceTiled,
        2 => MatmulDispatch::ForceRow,
        _ => MatmulDispatch::Auto,
    }
}

/// Pack `w` (`[k, n]`) into (column-panel × k-block) tiles: panels of
/// `t.nc` columns, each panel stored as ascending `t.kc`-deep blocks of
/// contiguous `depth × width` row-major data. Pure data movement. The
/// panel for columns `[jc0, jc0+width)` and rows `[kc0, kc0+depth)`
/// starts at `k·jc0 + width·kc0` (full preceding panels hold `k`
/// elements per column).
fn pack_panels<E: Copy>(w: &Tensor<E>, t: &Tiling) -> Vec<E> {
    let (k, n) = (w.rows, w.cols);
    let mut data = Vec::with_capacity(k * n);
    let mut jc0 = 0;
    while jc0 < n {
        let width = t.nc.min(n - jc0);
        let mut kc0 = 0;
        while kc0 < k {
            let depth = t.kc.min(k - kc0);
            for p in kc0..kc0 + depth {
                data.extend_from_slice(&w.row(p)[jc0..jc0 + width]);
            }
            kc0 += depth;
        }
        jc0 += width;
    }
    data
}

/// Row-chunk height actually used: honour `t.mc` but shrink just enough
/// that every thread gets a chunk (≈1 per thread). No finer: each chunk
/// streams the whole packed operand once, so over-splitting multiplies
/// panel traffic — the locality the tiles exist for. Chunking only
/// changes scheduling — each chunk computes its rows independently — so
/// the bits are unchanged for any value.
fn effective_mc(t: &Tiling, m: usize) -> usize {
    let per = m.div_ceil(rayon::current_num_threads());
    t.mc.min(per.max(1))
}

/// Pack rows `[i0, i0+rows)` of the moving operand `a` into
/// `kc`-block-major storage: blocks ascending along the reduction
/// dimension, each a contiguous row-major `rows × depth` slab. The hot
/// loop revisits each `a` block once per column panel; packed, those
/// revisits stream one dense slab instead of striding across full `a`
/// rows. Pure data movement (NUMERICS.md §2) — values and fold order are
/// untouched. The slab for block `kc0` starts at `rows · kc0` (all
/// preceding blocks hold `rows` elements per reduction index).
fn pack_a_chunk<E: Copy>(a: &Tensor<E>, i0: usize, rows: usize, kc: usize) -> Vec<E> {
    let k = a.cols;
    let mut data = Vec::with_capacity(rows * k);
    let mut kc0 = 0;
    while kc0 < k {
        let depth = kc.min(k - kc0);
        for r in 0..rows {
            data.extend_from_slice(&a.row(i0 + r)[kc0..kc0 + depth]);
        }
        kc0 += depth;
    }
    data
}

/// Compute the output rows held in `chunk` (width `n`, rows
/// `i0, i0+1, …` of the product) of `A·packed(B)`: column panels outer,
/// `kc` blocks ascending inner, one [`Backend::mac_panel`] call per
/// (row × panel-block) tile. The chunk's `a` rows are packed
/// (`kc`-block-major) once up front so both operands of every panel call
/// are contiguous. Per output element the ⊞ chain is exactly the
/// `k`-ascending reduction of [`matmul_serial`].
fn tiled_chunk<B: Backend>(
    b: &B,
    a: &Tensor<B::E>,
    i0: usize,
    t: &Tiling,
    packed: &[B::E],
    chunk: &mut [B::E],
    n: usize,
) {
    let k = a.cols;
    let rows = chunk.len() / n;
    let packed_a = pack_a_chunk(a, i0, rows, t.kc);
    let mut jc0 = 0;
    while jc0 < n {
        let width = t.nc.min(n - jc0);
        let group = &packed[k * jc0..k * (jc0 + width)];
        let mut kc0 = 0;
        while kc0 < k {
            let depth = t.kc.min(k - kc0);
            let panel = &group[width * kc0..width * (kc0 + depth)];
            for r in 0..rows {
                let arow = &packed_a[rows * kc0 + r * depth..][..depth];
                let acc = &mut chunk[r * n + jc0..r * n + jc0 + width];
                b.mac_panel(acc, arow, panel);
            }
            kc0 += depth;
        }
        jc0 += width;
    }
}

/// Drive `kernel` over the output row chunks — rayon when the problem
/// clears the parallel threshold, sequential otherwise (identical bits
/// either way: chunks are independent).
fn drive_chunks<B, F>(out: &mut Tensor<B::E>, mc: usize, work: usize, kernel: F)
where
    B: Backend,
    F: Fn(usize, &mut [B::E]) + Sync + Send,
{
    let (m, n) = (out.rows, out.cols);
    if parallel_worthwhile(m.div_ceil(mc), work) {
        // Hand the spawning task's counter scope to the pool workers (see
        // `matmul_par`); `None` — and free — when counting is off.
        let scope = task_scope();
        out.data.par_chunks_mut(mc * n).enumerate().for_each(|(ci, chunk)| {
            let _g = reenter_scope(scope);
            kernel(ci * mc, chunk)
        });
    } else {
        for ci in 0..m.div_ceil(mc) {
            let lo = ci * mc * n;
            let hi = (lo + mc * n).min(m * n);
            kernel(ci * mc, &mut out.data[lo..hi]);
        }
    }
}

/// Cache-tiled [`matmul`] with the autotuned (or default) [`Tiling`] for
/// this backend and shape class (see [`super::autotune`]). Bit-identical
/// to [`matmul_serial`] on every backend — tile geometry is perf-only.
pub fn matmul_tiled<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    let t = super::autotune::tiling_for(b, a.rows, a.cols, w.cols);
    matmul_tiled_with(b, a, w, &t)
}

/// Cache-tiled `C = A·B` with explicit tile sizes (tests sweep degenerate
/// tilings through here; results are independent of `t`).
pub fn matmul_tiled_with<B: Backend>(
    b: &B,
    a: &Tensor<B::E>,
    w: &Tensor<B::E>,
    t: &Tiling,
) -> Tensor<B::E> {
    assert_eq!(a.cols, w.rows, "matmul inner-dim mismatch");
    t.validate();
    let _sp = span(SpanKind::MatmulTiled);
    let (m, k, n) = (a.rows, a.cols, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let packed = pack_panels(w, t);
    let mc = effective_mc(t, m);
    drive_chunks::<B, _>(&mut out, mc, m * k * n, |i0, chunk| {
        tiled_chunk(b, a, i0, t, &packed, chunk, n);
    });
    out
}

/// Cache-tiled [`matmul_at`] with the autotuned (or default) [`Tiling`]
/// for this backend and shape class. Bit-identical to
/// [`matmul_at_serial`] on every backend.
pub fn matmul_at_tiled<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    let t = super::autotune::tiling_for(b, a.cols, a.rows, w.cols);
    matmul_at_tiled_with(b, a, w, &t)
}

/// Cache-tiled `C = Aᵀ·B` with explicit tile sizes. Each row chunk first
/// gathers its columns of `A` into contiguous rows (pure data movement),
/// then runs the [`matmul_tiled_with`] kernel — per output element the
/// reduction is the same `k`-ascending chain as [`matmul_at_serial`].
pub fn matmul_at_tiled_with<B: Backend>(
    b: &B,
    a: &Tensor<B::E>,
    w: &Tensor<B::E>,
    t: &Tiling,
) -> Tensor<B::E> {
    assert_eq!(a.rows, w.rows, "matmul_at inner-dim mismatch");
    t.validate();
    let _sp = span(SpanKind::MatmulTiled);
    let (k, m, n) = (a.rows, a.cols, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let packed = pack_panels(w, t);
    let mc = effective_mc(t, m);
    drive_chunks::<B, _>(&mut out, mc, m * k * n, |i0, chunk| {
        let rows = chunk.len() / n;
        // Transpose columns [i0, i0+rows) of `a` into contiguous rows.
        let mut at = Tensor::full(rows, k, b.zero());
        for p in 0..k {
            let arow = a.row(p);
            for r in 0..rows {
                at.data[r * k + p] = arow[i0 + r];
            }
        }
        tiled_chunk(b, &at, 0, t, &packed, chunk, n);
    });
    out
}

/// Cache-tiled [`matmul_bt`] with the autotuned (or default) [`Tiling`]
/// for this backend and shape class. Bit-identical to
/// [`matmul_bt_serial`] on every backend.
pub fn matmul_bt_tiled<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    let t = super::autotune::tiling_for(b, a.rows, a.cols, w.rows);
    matmul_bt_tiled_with(b, a, w, &t)
}

/// Pack `w` (`[n, k]`, the `A·Bᵀ` operand) into (row-panel × k-block)
/// tiles: panels of `t.nc` output columns (rows of `w`), each block
/// stored j-major (`width` contiguous `depth`-long k-slices). Same
/// offset arithmetic as [`pack_panels`].
fn pack_panels_bt<E: Copy>(w: &Tensor<E>, t: &Tiling) -> Vec<E> {
    let (n, k) = (w.rows, w.cols);
    let mut data = Vec::with_capacity(n * k);
    let mut jc0 = 0;
    while jc0 < n {
        let width = t.nc.min(n - jc0);
        let mut kc0 = 0;
        while kc0 < k {
            let depth = t.kc.min(k - kc0);
            for j in jc0..jc0 + width {
                data.extend_from_slice(&w.row(j)[kc0..kc0 + depth]);
            }
            kc0 += depth;
        }
        jc0 += width;
    }
    data
}

/// Cache-tiled `C = A·Bᵀ` with explicit tile sizes. The inner loop is
/// the zero-skipping dot of [`matmul_bt_serial`] restricted to one `kc`
/// block, chained over blocks ascending — the identical per-element ⊞
/// sequence, now over packed contiguous k-slices of `w`.
pub fn matmul_bt_tiled_with<B: Backend>(
    b: &B,
    a: &Tensor<B::E>,
    w: &Tensor<B::E>,
    t: &Tiling,
) -> Tensor<B::E> {
    assert_eq!(a.cols, w.cols, "matmul_bt inner-dim mismatch");
    t.validate();
    let _sp = span(SpanKind::MatmulTiled);
    let (m, k, n) = (a.rows, a.cols, w.rows);
    let mut out = Tensor::full(m, n, b.zero());
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let packed = pack_panels_bt(w, t);
    let mc = effective_mc(t, m);
    drive_chunks::<B, _>(&mut out, mc, m * k * n, |i0, chunk| {
        let rows = chunk.len() / n;
        let packed_a = pack_a_chunk(a, i0, rows, t.kc);
        let mut jc0 = 0;
        while jc0 < n {
            let width = t.nc.min(n - jc0);
            let group = &packed[k * jc0..k * (jc0 + width)];
            let mut kc0 = 0;
            while kc0 < k {
                let depth = t.kc.min(k - kc0);
                let panel = &group[width * kc0..width * (kc0 + depth)];
                for r in 0..rows {
                    let arow = &packed_a[rows * kc0 + r * depth..][..depth];
                    let orow = &mut chunk[r * n + jc0..r * n + jc0 + width];
                    for (j, o) in orow.iter_mut().enumerate() {
                        let wslice = &panel[j * depth..(j + 1) * depth];
                        *o = b.dot_acc(*o, arow, wslice);
                    }
                }
                kc0 += depth;
            }
            jc0 += width;
        }
    });
    out
}

// ---------------------------------------------------------------------
// Elementwise / broadcast ops
// ---------------------------------------------------------------------

/// Row-broadcast add: `out[i,j] = x[i,j] + bias[j]` (row-parallel on
/// large tensors; rows are independent, so order is preserved trivially).
pub fn add_bias<B: Backend>(b: &B, x: &mut Tensor<B::E>, bias: &[B::E]) {
    assert_eq!(x.cols, bias.len(), "bias length mismatch");
    let n = x.cols;
    if n > 0 && parallel_worthwhile(x.rows, x.rows * n) {
        let scope = task_scope();
        x.data.par_chunks_mut(n).for_each(|row| {
            let _g = reenter_scope(scope);
            b.add_slice(row, bias)
        });
    } else {
        for i in 0..x.rows {
            b.add_slice(x.row_mut(i), bias);
        }
    }
}

/// Column sums (`[m,n] → [n]`), reducing over rows ascending — the bias
/// gradient. Kept serial: the row-ascending reduction order is part of
/// the numeric spec and the op is a vanishing fraction of a step.
pub fn col_sum<B: Backend>(b: &B, x: &Tensor<B::E>) -> Vec<B::E> {
    let mut out = vec![b.zero(); x.cols];
    for i in 0..x.rows {
        b.add_slice(&mut out, x.row(i));
    }
    out
}

/// Elementwise map through the backend activation (parallel on large
/// tensors; elementwise ops are order-free so results are unchanged).
pub fn leaky_relu<B: Backend>(b: &B, x: &Tensor<B::E>) -> Tensor<B::E> {
    let data = if parallel_worthwhile(x.len(), x.len()) {
        x.data.par_iter().map(|&v| b.leaky_relu(v)).collect()
    } else {
        x.data.iter().map(|&v| b.leaky_relu(v)).collect()
    };
    Tensor { rows: x.rows, cols: x.cols, data }
}

/// Elementwise activation backprop: `out = upstream ⊙ act'(preact)`.
pub fn leaky_relu_bwd<B: Backend>(
    b: &B,
    preact: &Tensor<B::E>,
    upstream: &Tensor<B::E>,
) -> Tensor<B::E> {
    assert_eq!(preact.rows, upstream.rows);
    assert_eq!(preact.cols, upstream.cols);
    let data = if parallel_worthwhile(preact.len(), preact.len()) {
        preact
            .data
            .par_iter()
            .zip(&upstream.data)
            .map(|(&p, &u)| b.leaky_relu_bwd(p, u))
            .collect()
    } else {
        preact
            .data
            .iter()
            .zip(&upstream.data)
            .map(|(&p, &u)| b.leaky_relu_bwd(p, u))
            .collect()
    };
    Tensor { rows: preact.rows, cols: preact.cols, data }
}

/// Slice-level scaling by a real constant (encoded once): the averaging
/// step of the shard-reduction contract
/// ([`crate::nn::grad::GradStore::scale`] — "⊞-reduce, then one ⊡ by
/// `1/B`"). [`scale`] delegates here, so the gradient stores and the
/// tensor ops cannot diverge on how a constant scaling is evaluated.
/// Elementwise ⊡ is order-free, so the parallel and serial paths are
/// bit-identical.
pub fn scale_slice<B: Backend>(b: &B, xs: &mut [B::E], c: f64) {
    let ce = b.encode(c);
    if parallel_worthwhile(xs.len(), xs.len()) {
        xs.par_iter_mut().for_each(|v| *v = b.mul(*v, ce));
    } else {
        for v in xs.iter_mut() {
            *v = b.mul(*v, ce);
        }
    }
}

/// Scale every element by a real constant (encoded once).
pub fn scale<B: Backend>(b: &B, x: &mut Tensor<B::E>, c: f64) {
    scale_slice(b, &mut x.data, c);
}

/// Soft-max/CE head bookkeeping shared by the MLP and CNN backward
/// passes: writes `δ_j = p_j − y_j` into each row of `delta` and returns
/// the `(loss_sum, correct)` reduction. Rows are independent, so
/// eval-sized batches fan out across the rayon pool; the scalar
/// reduction always happens afterwards in row order, so the parallel and
/// serial paths report identical numbers. One shared definition so the
/// two model families' heads cannot silently diverge (same policy as
/// [`par_rows_worthwhile`]).
pub fn softmax_ce_head<B: Backend>(
    b: &B,
    logits: &Tensor<B::E>,
    labels: &[usize],
    delta: &mut Tensor<B::E>,
) -> (f64, usize) {
    let classes = delta.cols;
    debug_assert_eq!(logits.rows, delta.rows);
    debug_assert_eq!(logits.rows, labels.len());
    let per_row: Vec<(f64, bool)> = if par_rows_worthwhile(logits.rows) && classes > 0 {
        delta
            .data
            .par_chunks_mut(classes)
            .enumerate()
            .map(|(i, grow)| {
                let row = logits.row(i);
                let ln_p = b.softmax_ce_grad(row, labels[i], grow);
                (ln_p, argmax_row(b, row) == labels[i])
            })
            .collect()
    } else {
        (0..logits.rows)
            .map(|i| {
                let ln_p = b.softmax_ce_grad(logits.row(i), labels[i], delta.row_mut(i));
                (ln_p, argmax_row(b, logits.row(i)) == labels[i])
            })
            .collect()
    };
    // numerics-lint: allow(float-leak) — §4 loss accounting: raw per-row f64 sums folded in row order
    let mut loss = 0.0;
    let mut correct = 0usize;
    for &(ln_p, ok) in &per_row {
        loss -= ln_p;
        if ok {
            correct += 1;
        }
    }
    (loss, correct)
}

/// Index of the row maximum under the backend's linear order (argmax for
/// classification metrics — needs no decode).
pub fn argmax_row<B: Backend>(b: &B, row: &[B::E]) -> usize {
    let mut best = 0;
    for j in 1..row.len() {
        if b.gt(row[j], row[best]) {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatBackend;

    fn fb() -> FloatBackend {
        FloatBackend::default()
    }

    fn t(rows: usize, cols: usize, v: &[f32]) -> Tensor<f32> {
        Tensor::from_vec(rows, cols, v.to_vec())
    }

    fn rand_t(rng: &mut crate::rng::SplitMix64, rows: usize, cols: usize) -> Tensor<f32> {
        let data = (0..rows * cols).map(|_| rng.uniform(-1., 1.) as f32).collect();
        Tensor::from_vec(rows, cols, data)
    }

    #[test]
    fn matmul_known() {
        let b = fb();
        let a = t(2, 2, &[1., 2., 3., 4.]);
        let w = t(2, 2, &[5., 6., 7., 8.]);
        let c = matmul(&b, &a, &w);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
        // All three entry points agree on the small case.
        assert_eq!(matmul_serial(&b, &a, &w).data, c.data);
        assert_eq!(matmul_par(&b, &a, &w).data, c.data);
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let b = fb();
        let a = t(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let w = t(4, 3, &[1., 0., 1., 0., 1., 0., 1., 1., 1., 2., 0., -1.]);
        let direct = matmul_bt(&b, &a, &w);
        let via_t = matmul(&b, &a, &w.transpose());
        assert_eq!(direct.rows, via_t.rows);
        for (x, y) in direct.data.iter().zip(&via_t.data) {
            assert!((x - y).abs() < 1e-5);
        }
        assert_eq!(matmul_bt_par(&b, &a, &w).data, direct.data);
    }

    #[test]
    fn matmul_at_matches_explicit_transpose() {
        let b = fb();
        let a = t(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let w = t(3, 4, &[1., 0., 1., 0., 0., 1., 0., 1., 1., 1., 2., 0.]);
        let direct = matmul_at(&b, &a, &w);
        let via_t = matmul(&b, &a.transpose(), &w);
        for (x, y) in direct.data.iter().zip(&via_t.data) {
            assert!((x - y).abs() < 1e-5);
        }
        assert_eq!(matmul_at_par(&b, &a, &w).data, direct.data);
    }

    #[test]
    fn parallel_paths_handle_degenerate_shapes() {
        let b = fb();
        // Zero-width outputs and single rows must not panic on the
        // explicit parallel entry points either.
        let a = t(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let w0 = Tensor::full(2, 0, 0.0f32);
        assert_eq!(matmul_par(&b, &a, &w0).len(), 0);
        let w1 = t(1, 2, &[1., 1.]);
        assert_eq!(matmul_bt_par(&b, &a, &w1).data, vec![3., 7., 11.]);
        let one = t(1, 2, &[2., 3.]);
        let w = t(2, 2, &[1., 0., 0., 1.]);
        assert_eq!(matmul_par(&b, &one, &w).data, vec![2., 3.]);
    }

    #[test]
    fn dispatch_crosses_threshold_consistently() {
        // Big enough to take the parallel path via the public name: the
        // result must equal the serial reference exactly.
        let b = fb();
        let mut rng = crate::rng::SplitMix64::new(5);
        let (m, k, n) = (48, 32, 32);
        let a = Tensor::from_vec(m, k, (0..m * k).map(|_| rng.uniform(-1., 1.) as f32).collect());
        let w = Tensor::from_vec(k, n, (0..k * n).map(|_| rng.uniform(-1., 1.) as f32).collect());
        assert_eq!(matmul(&b, &a, &w).data, matmul_serial(&b, &a, &w).data);
    }

    #[test]
    fn bias_and_colsum_roundtrip() {
        let b = fb();
        let mut x = t(2, 3, &[0., 0., 0., 0., 0., 0.]);
        add_bias(&b, &mut x, &[1., 2., 3.]);
        assert_eq!(col_sum(&b, &x), vec![2., 4., 6.]);
    }

    #[test]
    fn activation_roundtrip() {
        let b = fb();
        let x = t(1, 4, &[-2., -0.5, 0.5, 2.]);
        let y = leaky_relu(&b, &x);
        assert_eq!(y.data, vec![-0.02, -0.005, 0.5, 2.]);
        let up = t(1, 4, &[1., 1., 1., 1.]);
        let g = leaky_relu_bwd(&b, &x, &up);
        assert_eq!(g.data, vec![0.01, 0.01, 1., 1.]);
    }

    #[test]
    fn scale_applies() {
        let b = fb();
        let mut x = t(1, 3, &[2., 4., 6.]);
        scale(&b, &mut x, 0.5);
        assert_eq!(x.data, vec![1., 2., 3.]);
    }

    #[test]
    fn softmax_ce_head_parallel_matches_serial() {
        // Cross the PAR_MIN_ROWS threshold so the rayon branch actually
        // runs, and pin it against a hand-rolled serial reference.
        let b = fb();
        let mut rng = crate::rng::SplitMix64::new(8);
        let (rows, classes) = (80usize, 5usize);
        let logits = Tensor::from_vec(
            rows,
            classes,
            (0..rows * classes).map(|_| rng.uniform(-2., 2.) as f32).collect(),
        );
        let labels: Vec<usize> = (0..rows).map(|i| i % classes).collect();
        let mut delta = Tensor::full(rows, classes, 0.0f32);
        let (loss, correct) = softmax_ce_head(&b, &logits, &labels, &mut delta);

        let mut want_delta = Tensor::full(rows, classes, 0.0f32);
        let mut want_loss = 0.0;
        let mut want_correct = 0usize;
        for i in 0..rows {
            want_loss -= b.softmax_ce_grad(logits.row(i), labels[i], want_delta.row_mut(i));
            if argmax_row(&b, logits.row(i)) == labels[i] {
                want_correct += 1;
            }
        }
        assert_eq!(delta.data, want_delta.data);
        assert_eq!(loss, want_loss);
        assert_eq!(correct, want_correct);
    }

    #[test]
    fn scale_slice_matches_tensor_scale() {
        let b = fb();
        let mut x = t(1, 3, &[2., 4., 6.]);
        let mut flat = x.data.clone();
        scale(&b, &mut x, 0.5);
        scale_slice(&b, &mut flat, 0.5);
        assert_eq!(flat, x.data);
    }

    #[test]
    fn tiled_matches_serial_small_known() {
        let b = fb();
        let a = t(2, 2, &[1., 2., 3., 4.]);
        let w = t(2, 2, &[5., 6., 7., 8.]);
        assert_eq!(matmul_tiled(&b, &a, &w).data, vec![19., 22., 43., 50.]);
        // Degenerate tiling still agrees (remainders everywhere).
        let tiny = Tiling { mc: 1, kc: 1, nc: 1 };
        assert_eq!(matmul_tiled_with(&b, &a, &w, &tiny).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn tiled_variants_match_serial_at_remainder_shapes() {
        let b = fb();
        let mut rng = crate::rng::SplitMix64::new(21);
        // Shapes chosen to straddle the default and custom tile borders.
        for &(m, k, n) in &[(1usize, 37usize, 1usize), (5, 3, 2), (17, 33, 9), (33, 65, 34)] {
            let a = rand_t(&mut rng, m, k);
            let w = rand_t(&mut rng, k, n);
            for tl in [Tiling::DEFAULT, Tiling { mc: 3, kc: 5, nc: 7 }] {
                assert_eq!(
                    matmul_tiled_with(&b, &a, &w, &tl).data,
                    matmul_serial(&b, &a, &w).data,
                    "matmul {m}x{k}x{n} {tl:?}"
                );
                let wt = w.transpose(); // [n,k] operand for bt
                assert_eq!(
                    matmul_bt_tiled_with(&b, &a, &wt, &tl).data,
                    matmul_bt_serial(&b, &a, &wt).data,
                    "matmul_bt {m}x{k}x{n} {tl:?}"
                );
                let at = a.transpose(); // [k,m] operand for at
                assert_eq!(
                    matmul_at_tiled_with(&b, &at, &w, &tl).data,
                    matmul_at_serial(&b, &at, &w).data,
                    "matmul_at {m}x{k}x{n} {tl:?}"
                );
            }
        }
    }

    #[test]
    fn tiled_handles_degenerate_shapes() {
        let b = fb();
        let a = t(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let w0 = Tensor::full(2, 0, 0.0f32);
        assert_eq!(matmul_tiled(&b, &a, &w0).len(), 0);
        let empty_k = Tensor::full(3, 0, 0.0f32);
        let w_ek = Tensor::full(0, 4, 0.0f32);
        assert_eq!(matmul_tiled(&b, &empty_k, &w_ek).data, vec![0.0f32; 12]);
        let w1 = t(2, 1, &[1., 1.]);
        assert_eq!(matmul_tiled(&b, &a, &w1).data, vec![3., 7., 11.]);
        let one = t(1, 2, &[2., 3.]);
        let w = t(2, 2, &[1., 0., 0., 1.]);
        assert_eq!(matmul_tiled(&b, &one, &w).data, vec![2., 3.]);
    }

    #[test]
    fn dispatch_override_round_trips_and_preserves_bits() {
        let b = fb();
        let mut rng = crate::rng::SplitMix64::new(17);
        let (m, k, n) = (12usize, 20usize, 15usize);
        let a = rand_t(&mut rng, m, k);
        let w = rand_t(&mut rng, k, n);
        let want = matmul_serial(&b, &a, &w).data;
        assert_eq!(matmul_dispatch(), MatmulDispatch::Auto);
        for d in [MatmulDispatch::ForceTiled, MatmulDispatch::ForceRow, MatmulDispatch::Auto] {
            set_matmul_dispatch(d);
            assert_eq!(matmul_dispatch(), d);
            assert_eq!(matmul(&b, &a, &w).data, want, "{d:?}");
        }
        set_matmul_dispatch(MatmulDispatch::Auto);
    }

    #[test]
    fn auto_dispatch_takes_tiled_path_bit_identically() {
        // Big enough that `matmul`'s Auto arm picks the tiled kernel
        // (footprint 128·260 ≥ 2^15, rows ≥ 8): the public entry point
        // must still equal the serial reference exactly.
        let b = fb();
        let mut rng = crate::rng::SplitMix64::new(19);
        let (m, k, n) = (16usize, 128usize, 260usize);
        let a = rand_t(&mut rng, m, k);
        let w = rand_t(&mut rng, k, n);
        assert!(tiled_worthwhile(m, k * n));
        assert_eq!(matmul(&b, &a, &w).data, matmul_serial(&b, &a, &w).data);
    }

    #[test]
    fn pack_panels_layout_round_trips() {
        // Reconstruct w from the packed buffer using the documented
        // offset arithmetic: panel (jc0, kc0) starts at k·jc0 + width·kc0.
        let w = t(5, 7, &(0..35).map(|v| v as f32).collect::<Vec<_>>());
        let tl = Tiling { mc: 2, kc: 2, nc: 3 };
        let packed = pack_panels(&w, &tl);
        assert_eq!(packed.len(), 35);
        let (k, n) = (w.rows, w.cols);
        for j in 0..n {
            let jc0 = (j / tl.nc) * tl.nc;
            let width = tl.nc.min(n - jc0);
            for p in 0..k {
                let kc0 = (p / tl.kc) * tl.kc;
                let idx = k * jc0 + width * kc0 + (p - kc0) * width + (j - jc0);
                assert_eq!(packed[idx], w.at(p, j), "w[{p}][{j}]");
            }
        }
    }

    #[test]
    fn pack_a_chunk_layout_round_trips() {
        // Reconstruct the chunk's a rows from the packed buffer using the
        // documented offsets: block kc0 starts at rows·kc0, row r at
        // rows·kc0 + r·depth.
        let a = t(6, 7, &(0..42).map(|v| v as f32).collect::<Vec<_>>());
        let (i0, rows, kc) = (2usize, 3usize, 3usize);
        let packed = pack_a_chunk(&a, i0, rows, kc);
        assert_eq!(packed.len(), rows * a.cols);
        for r in 0..rows {
            for p in 0..a.cols {
                let kc0 = (p / kc) * kc;
                let depth = kc.min(a.cols - kc0);
                let idx = rows * kc0 + r * depth + (p - kc0);
                assert_eq!(packed[idx], a.at(i0 + r, p), "a[{}][{p}]", i0 + r);
            }
        }
    }

    #[test]
    fn argmax_basic() {
        let b = fb();
        assert_eq!(argmax_row(&b, &[0.1f32, -3.0, 7.0, 2.0]), 2);
        assert_eq!(argmax_row(&b, &[-1.0f32, -0.5]), 1);
    }

    #[test]
    #[should_panic(expected = "inner-dim mismatch")]
    fn shape_mismatch_panics() {
        let b = fb();
        let a = t(2, 3, &[0.; 6]);
        let w = t(2, 2, &[0.; 4]);
        let _ = matmul(&b, &a, &w);
    }

    #[test]
    #[should_panic(expected = "inner-dim mismatch")]
    fn shape_mismatch_panics_parallel() {
        let b = fb();
        let a = t(2, 3, &[0.; 6]);
        let w = t(2, 2, &[0.; 4]);
        let _ = matmul_par(&b, &a, &w);
    }
}
