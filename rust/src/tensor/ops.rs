//! Backend-generic tensor operations (paper Eq. 10 and friends).
//!
//! Reduction order is **fixed and documented** everywhere: LNS addition is
//! approximate and non-associative, so "same order" is part of the numeric
//! spec — the Pallas kernels reduce in the identical order, which is what
//! makes bit-exact cross-checking possible.
//!
//! # Parallel execution
//!
//! Every matmul comes in three flavours:
//!
//! * `*_serial` — the reference single-thread implementation,
//! * `*_par` — rayon row-parallel: the **output rows** (the `m`
//!   dimension) are partitioned across threads while each row keeps the
//!   exact sequential-over-`k`-ascending reduction, so results are
//!   **bit-identical** to the serial versions in every backend (see
//!   `tests/parallel_determinism.rs`),
//! * the undecorated name — dispatches to the parallel path when the
//!   problem is big enough to amortize the fork/join overhead.
//!
//! Both paths drive the backend through the slice-level
//! [`Backend::mac_row`] / [`Backend::add_slice`] hooks, which lets LNS
//! hoist its Δ± LUT pointers and sign handling out of the inner loop.

use super::{Backend, Tensor};
use rayon::prelude::*;

/// Minimum total work (MACs for matmuls, elements for maps) before an op
/// takes the parallel path. Below this the fork/join overhead outweighs
/// the win; above it the parallel and serial paths are interchangeable
/// because they are bit-identical.
const PAR_MIN_WORK: usize = 1 << 15;

/// Take the parallel path for an op with `rows` independent output rows
/// and `work` total inner operations?
#[inline]
fn parallel_worthwhile(rows: usize, work: usize) -> bool {
    rows > 1 && work >= PAR_MIN_WORK && rayon::current_num_threads() > 1
}

/// Row count above which per-row *bookkeeping* loops (the soft-max/CE
/// head in `nn::mlp`, the metric loop in `train::metrics`) fan out.
const PAR_MIN_ROWS: usize = 64;

/// Dispatch predicate for those per-row bookkeeping loops — one shared
/// definition so the training and evaluation paths cannot silently
/// diverge on threshold or thread-count handling.
#[inline]
pub(crate) fn par_rows_worthwhile(rows: usize) -> bool {
    rows >= PAR_MIN_ROWS && rayon::current_num_threads() > 1
}

// ---------------------------------------------------------------------
// C = A·B
// ---------------------------------------------------------------------

/// One output row of `A·B`: `out[j] = Σ_p arow[p] ⊡ w[p][j]`, accumulating
/// sequentially over `p` ascending from the caller-initialized zeros.
/// Shared verbatim by the serial and parallel drivers — bit-exactness of
/// the two is by construction.
#[inline]
fn matmul_row<B: Backend>(b: &B, arow: &[B::E], w: &Tensor<B::E>, orow: &mut [B::E]) {
    for (p, &av) in arow.iter().enumerate() {
        // Zero operand ⇒ the whole inner row is `acc ⊞ 0 = acc`: skip.
        // Exact in every backend; large win on sparse image data.
        if b.is_zero(av) {
            continue;
        }
        b.mac_row(orow, av, w.row(p));
    }
}

/// `C = A·B` (`[m,k]·[k,n] → [m,n]`), accumulating **sequentially over k
/// ascending** from the backend zero (Eq. 10's ⊞ chain). Dispatches to
/// the rayon row-parallel path when the problem is large enough.
pub fn matmul<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    if parallel_worthwhile(a.rows, a.rows * a.cols * w.cols) {
        matmul_par(b, a, w)
    } else {
        matmul_serial(b, a, w)
    }
}

/// Single-thread reference implementation of [`matmul`].
pub fn matmul_serial<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.rows, "matmul inner-dim mismatch");
    let (m, n) = (a.rows, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    for i in 0..m {
        matmul_row(b, a.row(i), w, out.row_mut(i));
    }
    out
}

/// Rayon row-parallel [`matmul`]: output rows are distributed across the
/// pool; each row's reduction order is unchanged, so the result is
/// bit-identical to [`matmul_serial`].
pub fn matmul_par<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.rows, "matmul inner-dim mismatch");
    let (m, n) = (a.rows, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    if n == 0 {
        return out;
    }
    out.data
        .par_chunks_mut(n)
        .enumerate()
        .for_each(|(i, orow)| matmul_row(b, a.row(i), w, orow));
    out
}

// ---------------------------------------------------------------------
// C = A·Bᵀ
// ---------------------------------------------------------------------

/// Zero-skipping dot product, accumulating over the index ascending.
#[inline]
fn dot_skip_zero<B: Backend>(b: &B, a: &[B::E], w: &[B::E]) -> B::E {
    let mut acc = b.zero();
    for (&av, &wv) in a.iter().zip(w.iter()) {
        if b.is_zero(av) {
            continue; // acc ⊞ (0 ⊡ w) = acc exactly
        }
        acc = b.mac(acc, av, wv);
    }
    acc
}

/// One output row of `A·Bᵀ`.
#[inline]
fn matmul_bt_row<B: Backend>(b: &B, arow: &[B::E], w: &Tensor<B::E>, orow: &mut [B::E]) {
    for (j, o) in orow.iter_mut().enumerate() {
        *o = dot_skip_zero(b, arow, w.row(j));
    }
}

/// `C = A·Bᵀ` without materializing the transpose (`[m,k]·[n,k] → [m,n]`).
/// Dispatches to the rayon row-parallel path on large problems.
pub fn matmul_bt<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    if parallel_worthwhile(a.rows, a.rows * a.cols * w.rows) {
        matmul_bt_par(b, a, w)
    } else {
        matmul_bt_serial(b, a, w)
    }
}

/// Single-thread reference implementation of [`matmul_bt`].
pub fn matmul_bt_serial<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.cols, "matmul_bt inner-dim mismatch");
    let (m, n) = (a.rows, w.rows);
    let mut out = Tensor::full(m, n, b.zero());
    for i in 0..m {
        matmul_bt_row(b, a.row(i), w, out.row_mut(i));
    }
    out
}

/// Rayon row-parallel [`matmul_bt`], bit-identical to the serial path.
pub fn matmul_bt_par<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.cols, w.cols, "matmul_bt inner-dim mismatch");
    let (m, n) = (a.rows, w.rows);
    let mut out = Tensor::full(m, n, b.zero());
    if n == 0 {
        return out;
    }
    out.data
        .par_chunks_mut(n)
        .enumerate()
        .for_each(|(i, orow)| matmul_bt_row(b, a.row(i), w, orow));
    out
}

// ---------------------------------------------------------------------
// C = Aᵀ·B
// ---------------------------------------------------------------------

/// `C = Aᵀ·B` (`[k,m]·[k,n] → [m,n]`): the gradient outer-product shape.
/// Accumulates over k ascending. Dispatches to the row-parallel path on
/// large problems.
pub fn matmul_at<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    if parallel_worthwhile(a.cols, a.rows * a.cols * w.cols) {
        matmul_at_par(b, a, w)
    } else {
        matmul_at_serial(b, a, w)
    }
}

/// Single-thread reference implementation of [`matmul_at`]. Keeps the
/// seed's cache-friendly `k`-outer loop order; every output element still
/// accumulates over `k` ascending, which is all the numeric spec fixes.
pub fn matmul_at_serial<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.rows, w.rows, "matmul_at inner-dim mismatch");
    let (k, m, n) = (a.rows, a.cols, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    for p in 0..k {
        let arow = a.row(p);
        let wrow = w.row(p);
        for i in 0..m {
            let av = arow[i];
            if b.is_zero(av) {
                continue; // acc ⊞ (0 ⊡ w) = acc exactly
            }
            b.mac_row(out.row_mut(i), av, wrow);
        }
    }
    out
}

/// Rayon row-parallel [`matmul_at`]: each task owns one output row `i`
/// (one column of `A`) and walks `k` ascending — the per-element
/// reduction order is identical to the serial `k`-outer loop, so results
/// are bit-identical.
pub fn matmul_at_par<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>) -> Tensor<B::E> {
    assert_eq!(a.rows, w.rows, "matmul_at inner-dim mismatch");
    let (k, m, n) = (a.rows, a.cols, w.cols);
    let mut out = Tensor::full(m, n, b.zero());
    if n == 0 {
        return out;
    }
    out.data.par_chunks_mut(n).enumerate().for_each(|(i, orow)| {
        for p in 0..k {
            let av = a.row(p)[i];
            if b.is_zero(av) {
                continue;
            }
            b.mac_row(orow, av, w.row(p));
        }
    });
    out
}

// ---------------------------------------------------------------------
// Elementwise / broadcast ops
// ---------------------------------------------------------------------

/// Row-broadcast add: `out[i,j] = x[i,j] + bias[j]` (row-parallel on
/// large tensors; rows are independent, so order is preserved trivially).
pub fn add_bias<B: Backend>(b: &B, x: &mut Tensor<B::E>, bias: &[B::E]) {
    assert_eq!(x.cols, bias.len(), "bias length mismatch");
    let n = x.cols;
    if n > 0 && parallel_worthwhile(x.rows, x.rows * n) {
        x.data.par_chunks_mut(n).for_each(|row| b.add_slice(row, bias));
    } else {
        for i in 0..x.rows {
            b.add_slice(x.row_mut(i), bias);
        }
    }
}

/// Column sums (`[m,n] → [n]`), reducing over rows ascending — the bias
/// gradient. Kept serial: the row-ascending reduction order is part of
/// the numeric spec and the op is a vanishing fraction of a step.
pub fn col_sum<B: Backend>(b: &B, x: &Tensor<B::E>) -> Vec<B::E> {
    let mut out = vec![b.zero(); x.cols];
    for i in 0..x.rows {
        b.add_slice(&mut out, x.row(i));
    }
    out
}

/// Elementwise map through the backend activation (parallel on large
/// tensors; elementwise ops are order-free so results are unchanged).
pub fn leaky_relu<B: Backend>(b: &B, x: &Tensor<B::E>) -> Tensor<B::E> {
    let data = if parallel_worthwhile(x.len(), x.len()) {
        x.data.par_iter().map(|&v| b.leaky_relu(v)).collect()
    } else {
        x.data.iter().map(|&v| b.leaky_relu(v)).collect()
    };
    Tensor { rows: x.rows, cols: x.cols, data }
}

/// Elementwise activation backprop: `out = upstream ⊙ act'(preact)`.
pub fn leaky_relu_bwd<B: Backend>(
    b: &B,
    preact: &Tensor<B::E>,
    upstream: &Tensor<B::E>,
) -> Tensor<B::E> {
    assert_eq!(preact.rows, upstream.rows);
    assert_eq!(preact.cols, upstream.cols);
    let data = if parallel_worthwhile(preact.len(), preact.len()) {
        preact
            .data
            .par_iter()
            .zip(&upstream.data)
            .map(|(&p, &u)| b.leaky_relu_bwd(p, u))
            .collect()
    } else {
        preact
            .data
            .iter()
            .zip(&upstream.data)
            .map(|(&p, &u)| b.leaky_relu_bwd(p, u))
            .collect()
    };
    Tensor { rows: preact.rows, cols: preact.cols, data }
}

/// Slice-level scaling by a real constant (encoded once): the averaging
/// step of the shard-reduction contract
/// ([`crate::nn::grad::GradStore::scale`] — "⊞-reduce, then one ⊡ by
/// `1/B`"). [`scale`] delegates here, so the gradient stores and the
/// tensor ops cannot diverge on how a constant scaling is evaluated.
/// Elementwise ⊡ is order-free, so the parallel and serial paths are
/// bit-identical.
pub fn scale_slice<B: Backend>(b: &B, xs: &mut [B::E], c: f64) {
    let ce = b.encode(c);
    if parallel_worthwhile(xs.len(), xs.len()) {
        xs.par_iter_mut().for_each(|v| *v = b.mul(*v, ce));
    } else {
        for v in xs.iter_mut() {
            *v = b.mul(*v, ce);
        }
    }
}

/// Scale every element by a real constant (encoded once).
pub fn scale<B: Backend>(b: &B, x: &mut Tensor<B::E>, c: f64) {
    scale_slice(b, &mut x.data, c);
}

/// Soft-max/CE head bookkeeping shared by the MLP and CNN backward
/// passes: writes `δ_j = p_j − y_j` into each row of `delta` and returns
/// the `(loss_sum, correct)` reduction. Rows are independent, so
/// eval-sized batches fan out across the rayon pool; the scalar
/// reduction always happens afterwards in row order, so the parallel and
/// serial paths report identical numbers. One shared definition so the
/// two model families' heads cannot silently diverge (same policy as
/// [`par_rows_worthwhile`]).
pub fn softmax_ce_head<B: Backend>(
    b: &B,
    logits: &Tensor<B::E>,
    labels: &[usize],
    delta: &mut Tensor<B::E>,
) -> (f64, usize) {
    let classes = delta.cols;
    debug_assert_eq!(logits.rows, delta.rows);
    debug_assert_eq!(logits.rows, labels.len());
    let per_row: Vec<(f64, bool)> = if par_rows_worthwhile(logits.rows) && classes > 0 {
        delta
            .data
            .par_chunks_mut(classes)
            .enumerate()
            .map(|(i, grow)| {
                let row = logits.row(i);
                let ln_p = b.softmax_ce_grad(row, labels[i], grow);
                (ln_p, argmax_row(b, row) == labels[i])
            })
            .collect()
    } else {
        (0..logits.rows)
            .map(|i| {
                let ln_p = b.softmax_ce_grad(logits.row(i), labels[i], delta.row_mut(i));
                (ln_p, argmax_row(b, logits.row(i)) == labels[i])
            })
            .collect()
    };
    let mut loss = 0.0;
    let mut correct = 0usize;
    for &(ln_p, ok) in &per_row {
        loss -= ln_p;
        if ok {
            correct += 1;
        }
    }
    (loss, correct)
}

/// Index of the row maximum under the backend's linear order (argmax for
/// classification metrics — needs no decode).
pub fn argmax_row<B: Backend>(b: &B, row: &[B::E]) -> usize {
    let mut best = 0;
    for j in 1..row.len() {
        if b.gt(row[j], row[best]) {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatBackend;

    fn fb() -> FloatBackend {
        FloatBackend::default()
    }

    fn t(rows: usize, cols: usize, v: &[f32]) -> Tensor<f32> {
        Tensor::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_known() {
        let b = fb();
        let a = t(2, 2, &[1., 2., 3., 4.]);
        let w = t(2, 2, &[5., 6., 7., 8.]);
        let c = matmul(&b, &a, &w);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
        // All three entry points agree on the small case.
        assert_eq!(matmul_serial(&b, &a, &w).data, c.data);
        assert_eq!(matmul_par(&b, &a, &w).data, c.data);
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let b = fb();
        let a = t(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let w = t(4, 3, &[1., 0., 1., 0., 1., 0., 1., 1., 1., 2., 0., -1.]);
        let direct = matmul_bt(&b, &a, &w);
        let via_t = matmul(&b, &a, &w.transpose());
        assert_eq!(direct.rows, via_t.rows);
        for (x, y) in direct.data.iter().zip(&via_t.data) {
            assert!((x - y).abs() < 1e-5);
        }
        assert_eq!(matmul_bt_par(&b, &a, &w).data, direct.data);
    }

    #[test]
    fn matmul_at_matches_explicit_transpose() {
        let b = fb();
        let a = t(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let w = t(3, 4, &[1., 0., 1., 0., 0., 1., 0., 1., 1., 1., 2., 0.]);
        let direct = matmul_at(&b, &a, &w);
        let via_t = matmul(&b, &a.transpose(), &w);
        for (x, y) in direct.data.iter().zip(&via_t.data) {
            assert!((x - y).abs() < 1e-5);
        }
        assert_eq!(matmul_at_par(&b, &a, &w).data, direct.data);
    }

    #[test]
    fn parallel_paths_handle_degenerate_shapes() {
        let b = fb();
        // Zero-width outputs and single rows must not panic on the
        // explicit parallel entry points either.
        let a = t(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let w0 = Tensor::full(2, 0, 0.0f32);
        assert_eq!(matmul_par(&b, &a, &w0).len(), 0);
        let w1 = t(1, 2, &[1., 1.]);
        assert_eq!(matmul_bt_par(&b, &a, &w1).data, vec![3., 7., 11.]);
        let one = t(1, 2, &[2., 3.]);
        let w = t(2, 2, &[1., 0., 0., 1.]);
        assert_eq!(matmul_par(&b, &one, &w).data, vec![2., 3.]);
    }

    #[test]
    fn dispatch_crosses_threshold_consistently() {
        // Big enough to take the parallel path via the public name: the
        // result must equal the serial reference exactly.
        let b = fb();
        let mut rng = crate::rng::SplitMix64::new(5);
        let (m, k, n) = (48, 32, 32);
        let a = Tensor::from_vec(m, k, (0..m * k).map(|_| rng.uniform(-1., 1.) as f32).collect());
        let w = Tensor::from_vec(k, n, (0..k * n).map(|_| rng.uniform(-1., 1.) as f32).collect());
        assert_eq!(matmul(&b, &a, &w).data, matmul_serial(&b, &a, &w).data);
    }

    #[test]
    fn bias_and_colsum_roundtrip() {
        let b = fb();
        let mut x = t(2, 3, &[0., 0., 0., 0., 0., 0.]);
        add_bias(&b, &mut x, &[1., 2., 3.]);
        assert_eq!(col_sum(&b, &x), vec![2., 4., 6.]);
    }

    #[test]
    fn activation_roundtrip() {
        let b = fb();
        let x = t(1, 4, &[-2., -0.5, 0.5, 2.]);
        let y = leaky_relu(&b, &x);
        assert_eq!(y.data, vec![-0.02, -0.005, 0.5, 2.]);
        let up = t(1, 4, &[1., 1., 1., 1.]);
        let g = leaky_relu_bwd(&b, &x, &up);
        assert_eq!(g.data, vec![0.01, 0.01, 1., 1.]);
    }

    #[test]
    fn scale_applies() {
        let b = fb();
        let mut x = t(1, 3, &[2., 4., 6.]);
        scale(&b, &mut x, 0.5);
        assert_eq!(x.data, vec![1., 2., 3.]);
    }

    #[test]
    fn softmax_ce_head_parallel_matches_serial() {
        // Cross the PAR_MIN_ROWS threshold so the rayon branch actually
        // runs, and pin it against a hand-rolled serial reference.
        let b = fb();
        let mut rng = crate::rng::SplitMix64::new(8);
        let (rows, classes) = (80usize, 5usize);
        let logits = Tensor::from_vec(
            rows,
            classes,
            (0..rows * classes).map(|_| rng.uniform(-2., 2.) as f32).collect(),
        );
        let labels: Vec<usize> = (0..rows).map(|i| i % classes).collect();
        let mut delta = Tensor::full(rows, classes, 0.0f32);
        let (loss, correct) = softmax_ce_head(&b, &logits, &labels, &mut delta);

        let mut want_delta = Tensor::full(rows, classes, 0.0f32);
        let mut want_loss = 0.0;
        let mut want_correct = 0usize;
        for i in 0..rows {
            want_loss -= b.softmax_ce_grad(logits.row(i), labels[i], want_delta.row_mut(i));
            if argmax_row(&b, logits.row(i)) == labels[i] {
                want_correct += 1;
            }
        }
        assert_eq!(delta.data, want_delta.data);
        assert_eq!(loss, want_loss);
        assert_eq!(correct, want_correct);
    }

    #[test]
    fn scale_slice_matches_tensor_scale() {
        let b = fb();
        let mut x = t(1, 3, &[2., 4., 6.]);
        let mut flat = x.data.clone();
        scale(&b, &mut x, 0.5);
        scale_slice(&b, &mut flat, 0.5);
        assert_eq!(flat, x.data);
    }

    #[test]
    fn argmax_basic() {
        let b = fb();
        assert_eq!(argmax_row(&b, &[0.1f32, -3.0, 7.0, 2.0]), 2);
        assert_eq!(argmax_row(&b, &[-1.0f32, -0.5]), 1);
    }

    #[test]
    #[should_panic(expected = "inner-dim mismatch")]
    fn shape_mismatch_panics() {
        let b = fb();
        let a = t(2, 3, &[0.; 6]);
        let w = t(2, 2, &[0.; 4]);
        let _ = matmul(&b, &a, &w);
    }

    #[test]
    #[should_panic(expected = "inner-dim mismatch")]
    fn shape_mismatch_panics_parallel() {
        let b = fb();
        let a = t(2, 3, &[0.; 6]);
        let w = t(2, 2, &[0.; 4]);
        let _ = matmul_par(&b, &a, &w);
    }
}
