//! Minimal 2-D tensor substrate, generic over the arithmetic backend.
//!
//! The paper's workloads are MLPs: everything is dense row-major matrices.
//! Elements are opaque to `Tensor` — all arithmetic goes through a
//! [`backend::Backend`], which is what lets one training engine run in
//! float, linear fixed-point, or LNS (with any Δ approximation) and makes
//! the numeric format a first-class, swappable component.

pub mod autotune;
pub mod backend;
pub mod im2col;
pub mod ops;

pub use backend::{Backend, FixedBackend, FloatBackend, LnsBackend};
pub use im2col::ConvShape;
pub use ops::{MatmulDispatch, Tiling};

/// Dense row-major matrix of backend elements.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<E> {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `rows · cols` elements.
    pub data: Vec<E>,
}

impl<E: Copy> Tensor<E> {
    /// A `rows × cols` tensor filled with `fill`.
    pub fn full(rows: usize, cols: usize, fill: E) -> Self {
        Tensor { rows, cols, data: vec![fill; rows * cols] }
    }

    /// Build from row-major data (length must be `rows·cols`).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<E>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Tensor { rows, cols, data }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Immutable element access.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> E {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Mutable element access.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut E {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// A row as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[E] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// A row as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [E] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Tensor<E> {
        let mut out = Vec::with_capacity(self.len());
        for c in 0..self.cols {
            for r in 0..self.rows {
                out.push(self.at(r, c));
            }
        }
        Tensor::from_vec(self.cols, self.rows, out)
    }

    /// Map every element.
    pub fn map<F: Fn(E) -> E>(&self, f: F) -> Tensor<E> {
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&e| f(e)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.at(0, 0), 1);
        assert_eq!(t.at(1, 2), 6);
        assert_eq!(t.row(1), &[4, 5, 6]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        let _ = Tensor::from_vec(2, 2, vec![1, 2, 3]);
    }

    #[test]
    fn transpose_involution() {
        let t = Tensor::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]);
        let tt = t.transpose();
        assert_eq!(tt.rows, 3);
        assert_eq!(tt.at(2, 1), 6);
        assert_eq!(tt.transpose(), t);
    }

    #[test]
    fn map_applies() {
        let t = Tensor::from_vec(1, 3, vec![1, 2, 3]).map(|x| x * 10);
        assert_eq!(t.data, vec![10, 20, 30]);
    }
}
