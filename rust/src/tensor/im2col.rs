//! im2col/col2im: lowering 2-D convolution onto the matmul engine.
//!
//! `im2col` gathers every receptive-field patch of a CHW image batch into
//! one row of a patch matrix, so conv forward becomes a single
//! `patches · weights` matmul on the row-parallel engine — rayon
//! parallelism, the serial↔parallel bit-exactness contract of
//! [`super::ops`], and the cache-tiled kernels carry over to convolution
//! for free, in every backend. (Under auto dispatch the tiled path
//! engages on the gradient's tall `patchesᵀ·δ` outer product once
//! `B·OH·OW·out_c` clears the footprint threshold; the small
//! `[patch_len, out_c]` forward kernels already fit in L1 and keep the
//! row path unless the tiled mode is forced.)
//! `col2im` is the transpose scatter (patch rows ⊞-accumulated back into
//! image rows), which is exactly the input-gradient lowering.
//!
//! Layout conventions (fixed; the conv layers and the naive references in
//! the tests all share them):
//!
//! * image rows are channel-major CHW: pixel `(c, y, x)` lives at column
//!   `(c·H + y)·W + x`,
//! * patch rows are `(c, ky, kx)` lexicographic: entry `(c, ky, kx)` lives
//!   at column `(c·k_h + ky)·k_w + kx`,
//! * patch row `r` of the output covers sample `r / (OH·OW)`, output pixel
//!   `((r mod OH·OW) / OW, (r mod OH·OW) mod OW)`.
//!
//! `im2col` is a pure gather (padding reads the backend zero word) and
//! `col2im` accumulates every target cell in patch-ascending, then
//! entry-ascending order — both are bit-identical between the serial and
//! rayon paths by construction, because the parallel drivers only
//! partition *output rows* (patches / samples) across threads.

use super::ops::par_rows_worthwhile;
use super::{Backend, Tensor};
use rayon::prelude::*;

/// Geometry of one 2-D convolution lowering.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct ConvShape {
    /// Input channels.
    pub in_c: usize,
    /// Input height.
    pub in_h: usize,
    /// Input width.
    pub in_w: usize,
    /// Kernel height.
    pub k_h: usize,
    /// Kernel width.
    pub k_w: usize,
    /// Stride (both axes).
    pub stride: usize,
    /// Zero padding (both axes, both sides).
    pub pad: usize,
}

impl ConvShape {
    /// Square-input, square-kernel shape.
    pub fn square(in_c: usize, side: usize, k: usize, stride: usize, pad: usize) -> Self {
        ConvShape { in_c, in_h: side, in_w: side, k_h: k, k_w: k, stride, pad }
    }

    /// Output height `(H + 2p − k_h)/s + 1`.
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k_h) / self.stride + 1
    }

    /// Output width `(W + 2p − k_w)/s + 1`.
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k_w) / self.stride + 1
    }

    /// Patch length `C·k_h·k_w` — the matmul inner dimension.
    pub fn patch_len(&self) -> usize {
        self.in_c * self.k_h * self.k_w
    }

    /// Flattened input row width `C·H·W`.
    pub fn in_len(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }

    /// Flattened output row width `out_c·OH·OW` for `out_c` channels.
    pub fn out_len(&self, out_c: usize) -> usize {
        out_c * self.out_h() * self.out_w()
    }

    /// Patches per image `OH·OW` — patch-matrix rows per sample.
    pub fn patches_per_image(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// Panic early on geometries the formulas above would silently
    /// mangle (kernel larger than the padded input, zero stride).
    fn validate(&self) {
        assert!(self.stride >= 1, "conv stride must be ≥ 1");
        assert!(self.in_c >= 1 && self.k_h >= 1 && self.k_w >= 1, "conv dims must be ≥ 1");
        assert!(
            self.in_h + 2 * self.pad >= self.k_h && self.in_w + 2 * self.pad >= self.k_w,
            "conv kernel exceeds padded input"
        );
    }
}

/// Fill one patch row: the `(oy, ox)` receptive field of `xrow`, with
/// out-of-bounds (padding) entries set to the backend zero word.
#[inline]
fn fill_patch<B: Backend>(
    b: &B,
    xrow: &[B::E],
    s: &ConvShape,
    oy: usize,
    ox: usize,
    out: &mut [B::E],
) {
    let (ih, iw) = (s.in_h as isize, s.in_w as isize);
    let mut idx = 0;
    for c in 0..s.in_c {
        let base = c * s.in_h * s.in_w;
        for ky in 0..s.k_h {
            let y = (oy * s.stride + ky) as isize - s.pad as isize;
            for kx in 0..s.k_w {
                let x = (ox * s.stride + kx) as isize - s.pad as isize;
                out[idx] = if y >= 0 && y < ih && x >= 0 && x < iw {
                    xrow[base + y as usize * s.in_w + x as usize]
                } else {
                    b.zero()
                };
                idx += 1;
            }
        }
    }
}

/// Gather a `[batch, C·H·W]` image batch into the `[batch·OH·OW,
/// patch_len]` patch matrix. Dispatches to the rayon patch-row-parallel
/// path on large problems; both paths are pure gathers and bit-identical.
pub fn im2col<B: Backend>(b: &B, x: &Tensor<B::E>, s: &ConvShape) -> Tensor<B::E> {
    if par_rows_worthwhile(x.rows * s.patches_per_image()) {
        im2col_par(b, x, s)
    } else {
        im2col_serial(b, x, s)
    }
}

/// Single-thread reference implementation of [`im2col`].
pub fn im2col_serial<B: Backend>(b: &B, x: &Tensor<B::E>, s: &ConvShape) -> Tensor<B::E> {
    s.validate();
    assert_eq!(x.cols, s.in_len(), "im2col input width mismatch");
    let ppi = s.patches_per_image();
    let ow = s.out_w();
    let mut out = Tensor::full(x.rows * ppi, s.patch_len(), b.zero());
    for r in 0..out.rows {
        let (sample, p) = (r / ppi, r % ppi);
        fill_patch(b, x.row(sample), s, p / ow, p % ow, out.row_mut(r));
    }
    out
}

/// Rayon patch-row-parallel [`im2col`], bit-identical to the serial path
/// (each output row is an independent gather).
pub fn im2col_par<B: Backend>(b: &B, x: &Tensor<B::E>, s: &ConvShape) -> Tensor<B::E> {
    s.validate();
    assert_eq!(x.cols, s.in_len(), "im2col input width mismatch");
    let ppi = s.patches_per_image();
    let ow = s.out_w();
    let plen = s.patch_len();
    let mut out = Tensor::full(x.rows * ppi, plen, b.zero());
    out.data.par_chunks_mut(plen).enumerate().for_each(|(r, orow)| {
        let (sample, p) = (r / ppi, r % ppi);
        fill_patch(b, x.row(sample), s, p / ow, p % ow, orow);
    });
    out
}

/// ⊞-scatter one sample's patch rows back into its image row. Fixed
/// reduction order: patches ascending, then patch entries ascending —
/// every target cell sees the same ⊞ sequence on every path.
#[inline]
fn scatter_sample<B: Backend>(
    b: &B,
    cols: &Tensor<B::E>,
    s: &ConvShape,
    sample: usize,
    orow: &mut [B::E],
) {
    let ppi = s.patches_per_image();
    let ow = s.out_w();
    let (ih, iw) = (s.in_h as isize, s.in_w as isize);
    for p in 0..ppi {
        let prow = cols.row(sample * ppi + p);
        let (oy, ox) = (p / ow, p % ow);
        let mut idx = 0;
        for c in 0..s.in_c {
            let base = c * s.in_h * s.in_w;
            for ky in 0..s.k_h {
                let y = (oy * s.stride + ky) as isize - s.pad as isize;
                for kx in 0..s.k_w {
                    let x = (ox * s.stride + kx) as isize - s.pad as isize;
                    if y >= 0 && y < ih && x >= 0 && x < iw {
                        let t = base + y as usize * s.in_w + x as usize;
                        orow[t] = b.add(orow[t], prow[idx]);
                    }
                    idx += 1;
                }
            }
        }
    }
}

/// Transpose of [`im2col`]: ⊞-accumulate a `[batch·OH·OW, patch_len]`
/// patch-gradient matrix back into `[batch, C·H·W]` image rows (the conv
/// input gradient). Dispatches to the rayon sample-parallel path on large
/// problems; per-sample scatter order is fixed, so results are
/// bit-identical.
pub fn col2im<B: Backend>(b: &B, cols: &Tensor<B::E>, s: &ConvShape, batch: usize) -> Tensor<B::E> {
    if par_rows_worthwhile(batch) {
        col2im_par(b, cols, s, batch)
    } else {
        col2im_serial(b, cols, s, batch)
    }
}

/// Single-thread reference implementation of [`col2im`].
pub fn col2im_serial<B: Backend>(
    b: &B,
    cols: &Tensor<B::E>,
    s: &ConvShape,
    batch: usize,
) -> Tensor<B::E> {
    s.validate();
    assert_eq!(cols.rows, batch * s.patches_per_image(), "col2im row-count mismatch");
    assert_eq!(cols.cols, s.patch_len(), "col2im patch-length mismatch");
    let mut out = Tensor::full(batch, s.in_len(), b.zero());
    for sample in 0..batch {
        scatter_sample(b, cols, s, sample, out.row_mut(sample));
    }
    out
}

/// Rayon sample-parallel [`col2im`]: each task owns one image row and
/// replays the identical per-sample scatter order, so the result is
/// bit-identical to [`col2im_serial`].
pub fn col2im_par<B: Backend>(
    b: &B,
    cols: &Tensor<B::E>,
    s: &ConvShape,
    batch: usize,
) -> Tensor<B::E> {
    s.validate();
    assert_eq!(cols.rows, batch * s.patches_per_image(), "col2im row-count mismatch");
    assert_eq!(cols.cols, s.patch_len(), "col2im patch-length mismatch");
    let in_len = s.in_len();
    let mut out = Tensor::full(batch, in_len, b.zero());
    if in_len == 0 {
        return out;
    }
    out.data.par_chunks_mut(in_len).enumerate().for_each(|(sample, orow)| {
        scatter_sample(b, cols, s, sample, orow);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatBackend;

    fn fb() -> FloatBackend {
        FloatBackend::default()
    }

    #[test]
    fn shape_arithmetic() {
        let s = ConvShape::square(3, 12, 5, 1, 2);
        assert_eq!(s.out_h(), 12);
        assert_eq!(s.out_w(), 12);
        assert_eq!(s.patch_len(), 75);
        assert_eq!(s.in_len(), 432);
        assert_eq!(s.out_len(8), 8 * 144);
        let strided = ConvShape::square(1, 8, 3, 2, 0);
        assert_eq!(strided.out_h(), 3);
        assert_eq!(strided.patches_per_image(), 9);
    }

    #[test]
    fn identity_kernel_extracts_pixels() {
        // 1×1 kernel, stride 1, no pad: each patch row is one pixel, in
        // scan order.
        let b = fb();
        let s = ConvShape::square(1, 2, 1, 1, 0);
        let x = Tensor::from_vec(2, 4, vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let cols = im2col(&b, &x, &s);
        assert_eq!(cols.rows, 8);
        assert_eq!(cols.cols, 1);
        assert_eq!(cols.data, x.data);
    }

    #[test]
    fn known_patch_with_padding() {
        // 3×3 input, 2×2 kernel, pad 1 → 4×4 patches; the top-left patch
        // sees three padding zeros and the (0,0) pixel.
        let b = fb();
        let s = ConvShape::square(1, 3, 2, 1, 1);
        let x = Tensor::from_vec(1, 9, (1..=9).map(|v| v as f32).collect());
        let cols = im2col(&b, &x, &s);
        assert_eq!(cols.rows, 16);
        assert_eq!(cols.row(0), &[0.0, 0.0, 0.0, 1.0]);
        // An interior patch (oy=1, ox=1) covers pixels (0,0)..(1,1).
        assert_eq!(cols.row(5), &[1.0, 2.0, 4.0, 5.0]);
        // The bottom-right patch sees pixel 9 and three zeros.
        assert_eq!(cols.row(15), &[9.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn channel_major_patch_layout() {
        // Two channels: the patch is (c, ky, kx) lexicographic.
        let b = fb();
        let s = ConvShape::square(2, 2, 2, 1, 0);
        #[rustfmt::skip]
        let x = Tensor::from_vec(1, 8, vec![
            1.0f32, 2.0, 3.0, 4.0, // channel 0
            10.0, 20.0, 30.0, 40.0, // channel 1
        ]);
        let cols = im2col(&b, &x, &s);
        assert_eq!(cols.rows, 1);
        assert_eq!(cols.row(0), &[1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0]);
    }

    #[test]
    fn serial_parallel_bit_identical() {
        let b = fb();
        let s = ConvShape::square(2, 9, 3, 2, 1);
        let mut rng = crate::rng::SplitMix64::new(3);
        let x = Tensor::from_vec(
            7,
            s.in_len(),
            (0..7 * s.in_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let a = im2col_serial(&b, &x, &s);
        let p = im2col_par(&b, &x, &s);
        assert_eq!(a.data, p.data);
        let ys = col2im_serial(&b, &a, &s, 7);
        let yp = col2im_par(&b, &a, &s, 7);
        assert_eq!(ys.data, yp.data);
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // ⟨im2col(x), y⟩ = ⟨x, col2im(y)⟩ for the float backend — the
        // linear-algebra identity that makes col2im the correct input
        // gradient.
        let b = fb();
        let s = ConvShape::square(2, 6, 3, 1, 1);
        let mut rng = crate::rng::SplitMix64::new(11);
        let batch = 3;
        let x = Tensor::from_vec(
            batch,
            s.in_len(),
            (0..batch * s.in_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let rows = batch * s.patches_per_image();
        let y = Tensor::from_vec(
            rows,
            s.patch_len(),
            (0..rows * s.patch_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let cols = im2col(&b, &x, &s);
        let back = col2im(&b, &y, &s, batch);
        let lhs: f64 = cols.data.iter().zip(&y.data).map(|(&a, &c)| (a * c) as f64).sum();
        let rhs: f64 = x.data.iter().zip(&back.data).map(|(&a, &c)| (a * c) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    #[should_panic(expected = "kernel exceeds padded input")]
    fn oversized_kernel_panics() {
        let b = fb();
        let s = ConvShape::square(1, 2, 5, 1, 0);
        let x = Tensor::full(1, 4, 0.0f32);
        let _ = im2col(&b, &x, &s);
    }
}
