//! LNS word-format and Δ-approximation configuration.

/// Specification of a Δ± look-up table (paper §3, Fig. 1).
///
/// The dynamic range of the difference `d = |X − Y|` covered by the table
/// is `[0, d_max)` and the resolution is `r = 2^{-log2_inv_r}` — i.e. each
/// unit interval holds `1/r` uniformly sampled points, so the table has
/// `d_max / r = d_max << log2_inv_r` entries. Resolutions are restricted
/// to powers of two so indexing is a bit shift (this is the hardware
/// motivation; the paper's chosen values `r = 1/2` and `r = 1/64` both
/// satisfy it).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct LutSpec {
    /// Dynamic range `d_max` (in log-domain units, i.e. the table covers
    /// differences `d ∈ [0, d_max)`).
    pub d_max: u32,
    /// `log2(1/r)`: 1 ⇒ r = 1/2 (paper's MAC table), 6 ⇒ r = 1/64
    /// (paper's soft-max table), 0 ⇒ r = 1 (the bit-shift-equivalent
    /// resolution).
    pub log2_inv_r: u32,
}

impl LutSpec {
    /// Paper's MAC-path table: `d_max = 10, r = 1/2` → 20 entries.
    pub const MAC20: LutSpec = LutSpec { d_max: 10, log2_inv_r: 1 };
    /// Paper's soft-max table: `d_max = 10, r = 1/64` → 640 entries.
    pub const SOFTMAX640: LutSpec = LutSpec { d_max: 10, log2_inv_r: 6 };

    /// MAC-path table for an arbitrary word: the paper's `d_max = 10`
    /// range at `r = 1/2`, with the resolution capped at the word's own
    /// fractional grid (a table finer than `2^-q_f` cannot be indexed by
    /// shifting — `DeltaApprox::new` rejects it).
    pub fn mac_for(frac_bits: u32) -> LutSpec {
        LutSpec { d_max: 10, log2_inv_r: 1.min(frac_bits) }
    }

    /// Soft-max table for an arbitrary word: the paper's `d_max = 10`
    /// range at `r = 1/64`, capped at the word's fractional grid. At
    /// `q_f = 10` this is exactly [`LutSpec::SOFTMAX640`]; an 8-bit word
    /// (`q_f = 2`) gets the 40-entry `r = 1/4` table its grid supports.
    pub fn softmax_for(frac_bits: u32) -> LutSpec {
        LutSpec { d_max: 10, log2_inv_r: 6.min(frac_bits) }
    }

    /// Number of entries `d_max / r`.
    pub fn len(&self) -> usize {
        (self.d_max as usize) << self.log2_inv_r
    }

    /// True when the table holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resolution `r` as a float (for reporting).
    pub fn r(&self) -> f64 {
        1.0 / (1u64 << self.log2_inv_r) as f64
    }
}

/// How the Δ± terms of log-domain addition are approximated (paper §3).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DeltaMode {
    /// Uniformly sampled look-up table.
    Lut(LutSpec),
    /// Generalized bit-shift rule of Eq. (9):
    /// `Δ+(d) ≈ 2^{-⌊d⌋}`, `Δ−(d) ≈ −1.5·2^{-⌊d⌋}` — equivalent to a
    /// LUT with `r = 1` and range set by the word width.
    BitShift,
    /// Exact transcendental evaluation (float) — not hardware-friendly;
    /// used as the reference curve in Fig. 1 and for ablations.
    Exact,
}

/// Full LNS word-format configuration.
///
/// A word has `total_bits = 2 + q_i + q_f` bits: one linear-sign bit, one
/// sign bit for the log-magnitude itself, `q_i` integer and `q_f = frac_bits`
/// fractional bits (paper §4, "Fixed-Point Implementation"). The paper's
/// 16-bit setting uses `q_f = 10`; the 12-bit setting uses `q_f = 6`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct LnsConfig {
    /// Total word width `W` in bits (including both sign bits).
    pub total_bits: u32,
    /// Fractional bits `q_f` of the log-magnitude.
    pub frac_bits: u32,
    /// Δ approximation used on the MAC path (matmul/bias/updates).
    pub delta: DeltaMode,
    /// Δ approximation used inside the soft-max (the paper found the
    /// soft-max markedly more sensitive and used a finer `r = 1/64` table).
    pub softmax_delta: DeltaMode,
}

impl LnsConfig {
    /// Validated arbitrary-width constructor — the runtime word-width
    /// axis. Checks the word layout (`4 ≤ W ≤ 32` so the magnitude field
    /// fits an `i32`; `1 ≤ q_f ≤ W − 3` so there is at least one integer
    /// bit and the fixed-point grid is non-degenerate) and every LUT
    /// spec's indexability (`log2(1/r) ≤ q_f`, the precondition
    /// `DeltaApprox::new` would otherwise panic on).
    pub fn custom(
        total_bits: u32,
        frac_bits: u32,
        delta: DeltaMode,
        softmax_delta: DeltaMode,
    ) -> Result<Self, String> {
        if !(4..=32).contains(&total_bits) {
            return Err(format!("LNS total_bits must be in 4..=32, got {total_bits}"));
        }
        if frac_bits == 0 || frac_bits > total_bits - 3 {
            return Err(format!(
                "LNS frac_bits must be in 1..={} for a {total_bits}-bit word, got {frac_bits}",
                total_bits - 3
            ));
        }
        for (path, mode) in [("delta", delta), ("softmax_delta", softmax_delta)] {
            if let DeltaMode::Lut(spec) = mode {
                if spec.d_max == 0 {
                    return Err(format!("{path}: LUT d_max must be nonzero"));
                }
                if spec.log2_inv_r > frac_bits {
                    return Err(format!(
                        "{path}: LUT resolution 2^-{} finer than word resolution 2^-{frac_bits}",
                        spec.log2_inv_r
                    ));
                }
            }
        }
        Ok(LnsConfig { total_bits, frac_bits, delta, softmax_delta })
    }

    /// Config for a total width with the preset int/frac split
    /// (`q_i = 4`, matching the paper's 16- and 12-bit settings, so
    /// `q_f = W − 6`) and width-capped LUTs. `bitshift` selects the Δ
    /// mode for both paths. Valid for `W ∈ 7..=32`.
    pub fn for_width(total_bits: u32, bitshift: bool) -> Result<Self, String> {
        if !(7..=32).contains(&total_bits) {
            return Err(format!(
                "preset-layout LNS widths are 7..=32 (q_f = W − 6 ≥ 1), got {total_bits}"
            ));
        }
        let frac_bits = total_bits - 6;
        let (delta, softmax_delta) = if bitshift {
            (DeltaMode::BitShift, DeltaMode::BitShift)
        } else {
            (
                DeltaMode::Lut(LutSpec::mac_for(frac_bits)),
                DeltaMode::Lut(LutSpec::softmax_for(frac_bits)),
            )
        };
        Self::custom(total_bits, frac_bits, delta, softmax_delta)
    }

    /// Parse a backend tag of the form `log<W>-lut`, `log<W>-bs`, or
    /// `log<W>-exact` into a validated config. Inverse of
    /// `LnsBackend::tag()` for preset-layout widths; `None` on anything
    /// unparseable or out of range.
    pub fn from_tag(tag: &str) -> Option<Self> {
        let rest = tag.strip_prefix("log")?;
        let dash = rest.find('-')?;
        let width: u32 = rest[..dash].parse().ok()?;
        let mut cfg = Self::for_width(width, false).ok()?;
        match &rest[dash + 1..] {
            "lut" => {}
            "bs" => {
                cfg.delta = DeltaMode::BitShift;
                cfg.softmax_delta = DeltaMode::BitShift;
            }
            "exact" => {
                cfg.delta = DeltaMode::Exact;
                cfg.softmax_delta = DeltaMode::Exact;
            }
            _ => return None,
        }
        Some(cfg)
    }

    /// 8-bit LUT configuration (`q_f = 2`): the MAC table keeps the
    /// paper's `r = 1/2`, the soft-max table is capped to the word's
    /// `r = 1/4` grid (40 entries).
    pub fn w8_lut() -> Self {
        Self::for_width(8, false).expect("8-bit preset is statically valid")
    }

    /// 8-bit bit-shift configuration.
    pub fn w8_bitshift() -> Self {
        Self::for_width(8, true).expect("8-bit preset is statically valid")
    }

    /// Paper's 16-bit LUT configuration (`q_f = 10`, MAC LUT 20 entries,
    /// soft-max LUT 640 entries).
    pub fn w16_lut() -> Self {
        LnsConfig {
            total_bits: 16,
            frac_bits: 10,
            delta: DeltaMode::Lut(LutSpec::MAC20),
            softmax_delta: DeltaMode::Lut(LutSpec::SOFTMAX640),
        }
    }

    /// Paper's 12-bit LUT configuration (`q_f = 6`).
    pub fn w12_lut() -> Self {
        LnsConfig {
            total_bits: 12,
            frac_bits: 6,
            delta: DeltaMode::Lut(LutSpec::MAC20),
            softmax_delta: DeltaMode::Lut(LutSpec::SOFTMAX640),
        }
    }

    /// Paper's 16-bit bit-shift configuration.
    pub fn w16_bitshift() -> Self {
        LnsConfig {
            total_bits: 16,
            frac_bits: 10,
            delta: DeltaMode::BitShift,
            // The soft-max keeps the fine LUT even in the bit-shift rows:
            // the paper states Fig. 2/Table 1 used r=1/64 "for all
            // operations except the soft-max" approximations being varied.
            // We expose this choice; `examples/` ablate it.
            softmax_delta: DeltaMode::BitShift,
        }
    }

    /// Paper's 12-bit bit-shift configuration.
    pub fn w12_bitshift() -> Self {
        LnsConfig {
            total_bits: 12,
            frac_bits: 6,
            delta: DeltaMode::BitShift,
            softmax_delta: DeltaMode::BitShift,
        }
    }

    /// Largest representable log-magnitude in fixed-point units.
    ///
    /// The magnitude field has `total_bits − 2` bits (one bit goes to the
    /// linear sign, one to the magnitude's own sign), so it spans
    /// `[-(2^{W-2}−1), 2^{W-2}−1]`; the most negative code is reserved as
    /// the exact-zero sentinel (§DESIGN.md-5).
    pub fn m_max(&self) -> i32 {
        (1i32 << (self.total_bits - 2)) - 1
    }

    /// Smallest representable (non-zero) log-magnitude.
    pub fn m_min(&self) -> i32 {
        -self.m_max()
    }

    /// Integer bits `q_i = W − 2 − q_f`.
    pub fn int_bits(&self) -> u32 {
        self.total_bits - 2 - self.frac_bits
    }

    /// One fixed-point unit = `2^{-q_f}` in log-domain value.
    pub fn unit(&self) -> f64 {
        1.0 / (1i64 << self.frac_bits) as f64
    }

    /// Convert a real-valued log-magnitude to fixed-point units
    /// (round-half-away-from-zero), without clamping.
    pub fn to_units(&self, x: f64) -> i64 {
        let scaled = x * (1i64 << self.frac_bits) as f64;
        if scaled >= 0.0 {
            (scaled + 0.5).floor() as i64
        } else {
            (scaled - 0.5).ceil() as i64
        }
    }

    /// Convert fixed-point units back to a real log-magnitude.
    pub fn from_units(&self, m: i32) -> f64 {
        m as f64 * self.unit()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_sizes_match_paper() {
        assert_eq!(LutSpec::MAC20.len(), 20);
        assert_eq!(LutSpec::SOFTMAX640.len(), 640);
        assert!((LutSpec::MAC20.r() - 0.5).abs() < 1e-12);
        assert!((LutSpec::SOFTMAX640.r() - 1.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn word_layout_16() {
        let c = LnsConfig::w16_lut();
        assert_eq!(c.int_bits(), 4); // 16 = 2 + 4 + 10
        assert_eq!(c.m_max(), (1 << 14) - 1);
        assert_eq!(c.m_min(), -((1 << 14) - 1));
    }

    #[test]
    fn word_layout_12() {
        let c = LnsConfig::w12_lut();
        assert_eq!(c.int_bits(), 4); // 12 = 2 + 4 + 6
        assert_eq!(c.m_max(), 1023);
    }

    #[test]
    fn to_units_rounds_half_away() {
        let c = LnsConfig::w16_lut(); // q_f = 10
        assert_eq!(c.to_units(0.0), 0);
        assert_eq!(c.to_units(1.0), 1024);
        // 0.5 ulp rounds away from zero
        assert_eq!(c.to_units(0.5 / 1024.0), 1);
        assert_eq!(c.to_units(-0.5 / 1024.0), -1);
        assert_eq!(c.to_units(0.49 / 1024.0), 0);
    }

    #[test]
    fn units_roundtrip() {
        let c = LnsConfig::w12_lut();
        for m in [-500i32, -1, 0, 1, 700] {
            assert_eq!(c.to_units(c.from_units(m)) as i32, m);
        }
    }

    #[test]
    fn word_layout_8() {
        let c = LnsConfig::w8_lut();
        assert_eq!(c.int_bits(), 4); // 8 = 2 + 4 + 2
        assert_eq!(c.frac_bits, 2);
        assert_eq!(c.m_max(), 63);
        // The soft-max LUT is capped at the word's grid: r = 1/4.
        assert_eq!(c.delta, DeltaMode::Lut(LutSpec { d_max: 10, log2_inv_r: 1 }));
        assert_eq!(c.softmax_delta, DeltaMode::Lut(LutSpec { d_max: 10, log2_inv_r: 2 }));
        assert_eq!(LutSpec::softmax_for(2).len(), 40);
    }

    #[test]
    fn presets_agree_with_for_width() {
        assert_eq!(LnsConfig::for_width(16, false).unwrap(), LnsConfig::w16_lut());
        assert_eq!(LnsConfig::for_width(12, false).unwrap(), LnsConfig::w12_lut());
        assert_eq!(LnsConfig::for_width(16, true).unwrap(), LnsConfig::w16_bitshift());
        assert_eq!(LnsConfig::for_width(12, true).unwrap(), LnsConfig::w12_bitshift());
        assert_eq!(LnsConfig::for_width(8, true).unwrap(), LnsConfig::w8_bitshift());
    }

    #[test]
    fn custom_rejects_bad_layouts() {
        let bs = DeltaMode::BitShift;
        assert!(LnsConfig::custom(3, 1, bs, bs).is_err(), "too narrow");
        assert!(LnsConfig::custom(33, 10, bs, bs).is_err(), "too wide for i32 magnitude");
        assert!(LnsConfig::custom(8, 0, bs, bs).is_err(), "no fractional bits");
        assert!(LnsConfig::custom(8, 6, bs, bs).is_err(), "no integer bit left");
        // An un-indexable LUT is refused here, not at DeltaApprox::new.
        let fine = DeltaMode::Lut(LutSpec::SOFTMAX640);
        assert!(LnsConfig::custom(8, 2, fine, bs).is_err(), "LUT finer than word");
        assert!(LnsConfig::custom(8, 2, bs, fine).is_err(), "softmax LUT finer than word");
        assert!(LnsConfig::custom(8, 2, bs, bs).is_ok());
    }

    #[test]
    fn tag_parse_roundtrips_and_rejects_garbage() {
        assert_eq!(LnsConfig::from_tag("log16-lut"), Some(LnsConfig::w16_lut()));
        assert_eq!(LnsConfig::from_tag("log12-bs"), Some(LnsConfig::w12_bitshift()));
        assert_eq!(LnsConfig::from_tag("log8-lut"), Some(LnsConfig::w8_lut()));
        let exact = LnsConfig::from_tag("log16-exact").unwrap();
        assert_eq!(exact.delta, DeltaMode::Exact);
        assert_eq!(exact.softmax_delta, DeltaMode::Exact);
        for bad in ["log16", "log-lut", "logx-lut", "log16-nope", "lin16", "log6-lut", "log99-bs"] {
            assert_eq!(LnsConfig::from_tag(bad), None, "{bad}");
        }
    }
}
