//! LNS word-format and Δ-approximation configuration.

/// Specification of a Δ± look-up table (paper §3, Fig. 1).
///
/// The dynamic range of the difference `d = |X − Y|` covered by the table
/// is `[0, d_max)` and the resolution is `r = 2^{-log2_inv_r}` — i.e. each
/// unit interval holds `1/r` uniformly sampled points, so the table has
/// `d_max / r = d_max << log2_inv_r` entries. Resolutions are restricted
/// to powers of two so indexing is a bit shift (this is the hardware
/// motivation; the paper's chosen values `r = 1/2` and `r = 1/64` both
/// satisfy it).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct LutSpec {
    /// Dynamic range `d_max` (in log-domain units, i.e. the table covers
    /// differences `d ∈ [0, d_max)`).
    pub d_max: u32,
    /// `log2(1/r)`: 1 ⇒ r = 1/2 (paper's MAC table), 6 ⇒ r = 1/64
    /// (paper's soft-max table), 0 ⇒ r = 1 (the bit-shift-equivalent
    /// resolution).
    pub log2_inv_r: u32,
}

impl LutSpec {
    /// Paper's MAC-path table: `d_max = 10, r = 1/2` → 20 entries.
    pub const MAC20: LutSpec = LutSpec { d_max: 10, log2_inv_r: 1 };
    /// Paper's soft-max table: `d_max = 10, r = 1/64` → 640 entries.
    pub const SOFTMAX640: LutSpec = LutSpec { d_max: 10, log2_inv_r: 6 };

    /// Number of entries `d_max / r`.
    pub fn len(&self) -> usize {
        (self.d_max as usize) << self.log2_inv_r
    }

    /// True when the table holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resolution `r` as a float (for reporting).
    pub fn r(&self) -> f64 {
        1.0 / (1u64 << self.log2_inv_r) as f64
    }
}

/// How the Δ± terms of log-domain addition are approximated (paper §3).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum DeltaMode {
    /// Uniformly sampled look-up table.
    Lut(LutSpec),
    /// Generalized bit-shift rule of Eq. (9):
    /// `Δ+(d) ≈ 2^{-⌊d⌋}`, `Δ−(d) ≈ −1.5·2^{-⌊d⌋}` — equivalent to a
    /// LUT with `r = 1` and range set by the word width.
    BitShift,
    /// Exact transcendental evaluation (float) — not hardware-friendly;
    /// used as the reference curve in Fig. 1 and for ablations.
    Exact,
}

/// Full LNS word-format configuration.
///
/// A word has `total_bits = 2 + q_i + q_f` bits: one linear-sign bit, one
/// sign bit for the log-magnitude itself, `q_i` integer and `q_f = frac_bits`
/// fractional bits (paper §4, "Fixed-Point Implementation"). The paper's
/// 16-bit setting uses `q_f = 10`; the 12-bit setting uses `q_f = 6`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct LnsConfig {
    /// Total word width `W` in bits (including both sign bits).
    pub total_bits: u32,
    /// Fractional bits `q_f` of the log-magnitude.
    pub frac_bits: u32,
    /// Δ approximation used on the MAC path (matmul/bias/updates).
    pub delta: DeltaMode,
    /// Δ approximation used inside the soft-max (the paper found the
    /// soft-max markedly more sensitive and used a finer `r = 1/64` table).
    pub softmax_delta: DeltaMode,
}

impl LnsConfig {
    /// Paper's 16-bit LUT configuration (`q_f = 10`, MAC LUT 20 entries,
    /// soft-max LUT 640 entries).
    pub fn w16_lut() -> Self {
        LnsConfig {
            total_bits: 16,
            frac_bits: 10,
            delta: DeltaMode::Lut(LutSpec::MAC20),
            softmax_delta: DeltaMode::Lut(LutSpec::SOFTMAX640),
        }
    }

    /// Paper's 12-bit LUT configuration (`q_f = 6`).
    pub fn w12_lut() -> Self {
        LnsConfig {
            total_bits: 12,
            frac_bits: 6,
            delta: DeltaMode::Lut(LutSpec::MAC20),
            softmax_delta: DeltaMode::Lut(LutSpec::SOFTMAX640),
        }
    }

    /// Paper's 16-bit bit-shift configuration.
    pub fn w16_bitshift() -> Self {
        LnsConfig {
            total_bits: 16,
            frac_bits: 10,
            delta: DeltaMode::BitShift,
            // The soft-max keeps the fine LUT even in the bit-shift rows:
            // the paper states Fig. 2/Table 1 used r=1/64 "for all
            // operations except the soft-max" approximations being varied.
            // We expose this choice; `examples/` ablate it.
            softmax_delta: DeltaMode::BitShift,
        }
    }

    /// Paper's 12-bit bit-shift configuration.
    pub fn w12_bitshift() -> Self {
        LnsConfig {
            total_bits: 12,
            frac_bits: 6,
            delta: DeltaMode::BitShift,
            softmax_delta: DeltaMode::BitShift,
        }
    }

    /// Largest representable log-magnitude in fixed-point units.
    ///
    /// The magnitude field has `total_bits − 2` bits (one bit goes to the
    /// linear sign, one to the magnitude's own sign), so it spans
    /// `[-(2^{W-2}−1), 2^{W-2}−1]`; the most negative code is reserved as
    /// the exact-zero sentinel (§DESIGN.md-5).
    pub fn m_max(&self) -> i32 {
        (1i32 << (self.total_bits - 2)) - 1
    }

    /// Smallest representable (non-zero) log-magnitude.
    pub fn m_min(&self) -> i32 {
        -self.m_max()
    }

    /// Integer bits `q_i = W − 2 − q_f`.
    pub fn int_bits(&self) -> u32 {
        self.total_bits - 2 - self.frac_bits
    }

    /// One fixed-point unit = `2^{-q_f}` in log-domain value.
    pub fn unit(&self) -> f64 {
        1.0 / (1i64 << self.frac_bits) as f64
    }

    /// Convert a real-valued log-magnitude to fixed-point units
    /// (round-half-away-from-zero), without clamping.
    pub fn to_units(&self, x: f64) -> i64 {
        let scaled = x * (1i64 << self.frac_bits) as f64;
        if scaled >= 0.0 {
            (scaled + 0.5).floor() as i64
        } else {
            (scaled - 0.5).ceil() as i64
        }
    }

    /// Convert fixed-point units back to a real log-magnitude.
    pub fn from_units(&self, m: i32) -> f64 {
        m as f64 * self.unit()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_sizes_match_paper() {
        assert_eq!(LutSpec::MAC20.len(), 20);
        assert_eq!(LutSpec::SOFTMAX640.len(), 640);
        assert!((LutSpec::MAC20.r() - 0.5).abs() < 1e-12);
        assert!((LutSpec::SOFTMAX640.r() - 1.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn word_layout_16() {
        let c = LnsConfig::w16_lut();
        assert_eq!(c.int_bits(), 4); // 16 = 2 + 4 + 10
        assert_eq!(c.m_max(), (1 << 14) - 1);
        assert_eq!(c.m_min(), -((1 << 14) - 1));
    }

    #[test]
    fn word_layout_12() {
        let c = LnsConfig::w12_lut();
        assert_eq!(c.int_bits(), 4); // 12 = 2 + 4 + 6
        assert_eq!(c.m_max(), 1023);
    }

    #[test]
    fn to_units_rounds_half_away() {
        let c = LnsConfig::w16_lut(); // q_f = 10
        assert_eq!(c.to_units(0.0), 0);
        assert_eq!(c.to_units(1.0), 1024);
        // 0.5 ulp rounds away from zero
        assert_eq!(c.to_units(0.5 / 1024.0), 1);
        assert_eq!(c.to_units(-0.5 / 1024.0), -1);
        assert_eq!(c.to_units(0.49 / 1024.0), 0);
    }

    #[test]
    fn units_roundtrip() {
        let c = LnsConfig::w12_lut();
        for m in [-500i32, -1, 0, 1, 700] {
            assert_eq!(c.to_units(c.from_units(m)) as i32, m);
        }
    }
}
