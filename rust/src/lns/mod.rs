//! Logarithmic Number System (LNS) fixed-point arithmetic.
//!
//! This is the paper's core numeric substrate (Sections 2–3). A real
//! number `v` is carried as `(m, s)` where `s` is the linear sign
//! (`true ⇔ v > 0`, matching the paper's `sign(v)=1` convention) and `m`
//! is the log-magnitude `X = log2|v|` in signed fixed point with
//! `frac_bits` fractional bits.
//!
//! * multiplication ⊡ → integer addition of magnitudes + XNOR of signs,
//! * addition ⊞ → `max(X,Y) + Δ±(|X−Y|)` with `Δ±` approximated by a
//!   look-up table ([`DeltaMode::Lut`]) or bit-shifts
//!   ([`DeltaMode::BitShift`]),
//! * subtraction ⊟ → ⊞ with the second operand's sign flipped.
//!
//! The module is the **single source of truth for the integer semantics**:
//! the Python/Pallas kernels implement exactly the same rules and the test
//! suite cross-checks bit-exactness through the PJRT runtime.

mod analysis;
mod config;
mod cost;
mod delta;
pub mod lanes;
mod linconv;
mod system;
mod value;

pub use analysis::{bound_table, min_log_bits, BitWidthRow};
pub use cost::{area_ratio, linear_mac_cost, lns_mac_cost, MacCost};
pub use config::{DeltaMode, LnsConfig, LutSpec};
pub use delta::{delta_minus_exact, delta_plus_exact, DeltaApprox};
pub use lanes::LANES;
pub use linconv::Pow2Table;
pub use system::LnsSystem;
pub use value::{LnsValue, ZERO_M};
