//! Bit-width analysis (paper §4, Eq. 15).
//!
//! The paper bounds the log-domain word width needed to cover the same
//! range and precision as a linear-domain fixed-point word with `b_i`
//! integer and `b_f` fractional bits (plus sign):
//!
//! ```text
//! W_log ≥ 1 + max(⌈log2(b_i + 1)⌉, ⌈log2 b_f⌉) + W_lin
//! ```
//!
//! For the typical `W_lin = 16` (`b_i = 4`, `b_f = 11`) this gives
//! `W_log = 21`; the paper's experiments show `W_log ≈ W_lin` suffices in
//! practice — the `bitwidth` CLI subcommand and `table1` results exhibit
//! exactly that gap.

/// One row of the Eq.-15 bound table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitWidthRow {
    /// Linear word width (1 sign + `b_i` + `b_f`).
    pub w_lin: u32,
    /// Linear integer bits.
    pub b_i: u32,
    /// Linear fractional bits.
    pub b_f: u32,
    /// Eq.-15 lower bound on the log-domain width.
    pub w_log_bound: u32,
}

/// Eq. 15: minimum log-domain width guaranteeing the linear format's
/// range *and* precision (worst case).
pub fn min_log_bits(b_i: u32, b_f: u32) -> u32 {
    assert!(b_f >= 1, "need at least one fractional bit");
    let w_lin = 1 + b_i + b_f;
    let ceil_log2 = |x: u32| -> u32 {
        assert!(x >= 1);
        32 - (x - 1).leading_zeros()
    };
    1 + ceil_log2(b_i + 1).max(ceil_log2(b_f)) + w_lin
}

/// The bound table for a sweep of linear widths (the `bitwidth` CLI
/// subcommand prints this).
pub fn bound_table(rows: &[(u32, u32)]) -> Vec<BitWidthRow> {
    rows.iter()
        .map(|&(b_i, b_f)| BitWidthRow {
            w_lin: 1 + b_i + b_f,
            b_i,
            b_f,
            w_log_bound: min_log_bits(b_i, b_f),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_16bit() {
        // Paper: W_lin = 16 with b_i = 4, b_f = 11 → W_log = 21.
        assert_eq!(min_log_bits(4, 11), 21);
    }

    #[test]
    fn twelve_bit_case() {
        // W_lin = 12 with b_i = 4, b_f = 7: max(⌈log2 5⌉, ⌈log2 7⌉) = 3
        // → 1 + 3 + 12 = 16.
        assert_eq!(min_log_bits(4, 7), 16);
    }

    #[test]
    fn bound_grows_with_width() {
        let mut prev = 0;
        for bf in 2..24 {
            let b = min_log_bits(4, bf);
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn table_shape() {
        let t = bound_table(&[(4, 7), (4, 11), (4, 19)]);
        assert_eq!(t.len(), 3);
        assert_eq!(t[1].w_lin, 16);
        assert_eq!(t[1].w_log_bound, 21);
    }
}
