//! The Δ± terms of log-domain addition and their approximations (paper §3).
//!
//! Exact:
//! ```text
//! Δ+(d) = log2(1 + 2^-d)   d ≥ 0
//! Δ−(d) = log2(1 − 2^-d)   d > 0
//! ```
//! Approximations:
//! * **LUT** — uniformly sampled table over `d ∈ [0, d_max)` with
//!   power-of-two resolution `r`; lookup is **round-to-nearest sample**
//!   `(d + bin/2) >> (q_f − log2(1/r))`. (Floor indexing systematically
//!   overestimates the decreasing Δ+; the bias compounds over 784-term ⊞
//!   reductions and destabilizes training — see EXPERIMENTS.md.)
//! * **Bit-shift** (Eq. 9) — `Δ+(d) ≈ 2^{-⌊d⌋}`, `Δ−(d) ≈ −1.5·2^{-⌊d⌋}`,
//!   i.e. a LUT with `r = 1` (floor-indexed, exactly as the shift does).
//! * **Exact** — reference/ablation mode, materialized at the word's own
//!   resolution.
//!
//! Internally every mode is materialized as a **padded direct-index
//! table** covering the full reachable difference range `[0, 2·m_max]`,
//! so the hot-path lookup is shift → load with no mode dispatch and no
//! bounds branch (this is also exactly the hardware structure: an indexed
//! ROM). All values are fixed-point units; the Rust engine and the Pallas
//! kernels are bit-exact against each other.

use super::config::{DeltaMode, LnsConfig};
#[cfg(test)]
use super::config::LutSpec;

/// Exact `Δ+(d) = log2(1 + 2^-d)` over real-valued `d ≥ 0`.
pub fn delta_plus_exact(d: f64) -> f64 {
    debug_assert!(d >= 0.0);
    (1.0 + (-d).exp2()).log2()
}

/// Exact `Δ−(d) = log2(1 − 2^-d)` over real-valued `d > 0`.
/// Diverges to −∞ as `d → 0`.
pub fn delta_minus_exact(d: f64) -> f64 {
    debug_assert!(d > 0.0);
    (1.0 - (-d).exp2()).log2()
}

/// Sentinel for "Δ− evaluated in its singular bin": the paper sets the
/// value at 0 to the most negative representable number; callers clamp
/// the subsequent add, so any value far below −m_max behaves identically.
/// Kept well inside `i32` so plain 32-bit adds cannot wrap.
const DELTA_MINUS_NEG_SAT: i32 = i32::MIN / 4;

/// A Δ± approximator materialized for a specific word format.
#[derive(Clone, Debug)]
pub struct DeltaApprox {
    mode: DeltaMode,
    /// Right-shift turning a fixed-point difference into a table index.
    index_shift: u32,
    /// Pre-shift rounding bias: `bin/2` for nearest-sample LUTs, 0 for
    /// the floor-indexed bit-shift/exact modes.
    index_round: i32,
    /// Entries of the *logical* table (before range padding) — what the
    /// paper's hardware would store; reported by [`Self::table_len`].
    logical_len: usize,
    /// Δ+ entries, padded to cover every reachable `d ∈ [0, 2·m_max]`.
    table_plus: Vec<i32>,
    /// Δ− entries; index 0 is the singular bin (→ huge negative).
    table_minus: Vec<i32>,
}

impl DeltaApprox {
    /// Build the approximator for `mode` under `cfg`'s fixed-point format.
    ///
    /// Panics if a LUT resolution is finer than the word's fractional
    /// resolution (`log2(1/r) > q_f`) — such a table cannot be indexed by
    /// shifting and would be meaningless in hardware.
    pub fn new(cfg: &LnsConfig, mode: DeltaMode) -> Self {
        let d_reach = 2 * cfg.m_max() as i64; // max |X − Y| in units
        match mode {
            DeltaMode::Lut(spec) => {
                assert!(
                    spec.log2_inv_r <= cfg.frac_bits,
                    "LUT resolution 2^-{} finer than word resolution 2^-{}",
                    spec.log2_inv_r,
                    cfg.frac_bits
                );
                let shift = cfg.frac_bits - spec.log2_inv_r;
                let round = ((1i64 << shift) >> 1) as i32;
                let n_padded = (((d_reach + round as i64) >> shift) + 1) as usize;
                let logical = spec.len();
                let r = spec.r();
                let mut plus = Vec::with_capacity(n_padded);
                let mut minus = Vec::with_capacity(n_padded);
                for i in 0..n_padded {
                    if i < logical {
                        let d = i as f64 * r;
                        plus.push(cfg.to_units(delta_plus_exact(d)) as i32);
                        minus.push(if i == 0 {
                            DELTA_MINUS_NEG_SAT
                        } else {
                            cfg.to_units(delta_minus_exact(d)) as i32
                        });
                    } else {
                        plus.push(0); // beyond the dynamic range Δ± ≈ 0
                        minus.push(0);
                    }
                }
                DeltaApprox {
                    mode,
                    index_shift: shift,
                    index_round: round,
                    logical_len: logical,
                    table_plus: plus,
                    table_minus: minus,
                }
            }
            DeltaMode::BitShift => {
                // Equivalent LUT with r = 1, floor-indexed (that is what a
                // shifter computes): T+[i] = 2^{q_f} >> i, T−[i] = −(1.5·
                // 2^{q_f}) >> i. No singular bin: Δ−(0⁺) ≈ −1.5 (Eq. 9b).
                let shift = cfg.frac_bits;
                let n_padded = ((d_reach >> shift) + 1) as usize;
                let base_minus = (3i64 << cfg.frac_bits) >> 1;
                let plus: Vec<i32> = (0..n_padded)
                    .map(|i| if i < 63 { ((1i64 << cfg.frac_bits) >> i) as i32 } else { 0 })
                    .collect();
                let minus: Vec<i32> = (0..n_padded)
                    .map(|i| if i < 63 { -((base_minus >> i) as i32) } else { 0 })
                    .collect();
                DeltaApprox {
                    mode,
                    index_shift: shift,
                    index_round: 0,
                    logical_len: 0,
                    table_plus: plus,
                    table_minus: minus,
                }
            }
            DeltaMode::Exact => {
                // Materialized at the word's own resolution (shift 0): the
                // float-free equivalent of evaluating the closed form per
                // call, used as the reference/ablation mode. Entries round
                // through [`LnsConfig::to_units`] — the word format's one
                // rounding rule, shared with the LUT builder above — so a
                // future rounding change cannot silently fork the modes
                // (pinned by `modes_agree_at_shared_entries`).
                let n_padded = (d_reach + 1) as usize;
                let mut plus = Vec::with_capacity(n_padded);
                let mut minus = Vec::with_capacity(n_padded);
                for i in 0..n_padded {
                    let d = cfg.from_units(i as i32);
                    plus.push(cfg.to_units(delta_plus_exact(d)) as i32);
                    if i == 0 {
                        minus.push(DELTA_MINUS_NEG_SAT);
                    } else {
                        let m = delta_minus_exact(d);
                        let units = if m.is_finite() { cfg.to_units(m) } else { i64::MIN };
                        minus.push(units.max(DELTA_MINUS_NEG_SAT as i64) as i32);
                    }
                }
                DeltaApprox {
                    mode,
                    index_shift: 0,
                    index_round: 0,
                    logical_len: n_padded,
                    table_plus: plus,
                    table_minus: minus,
                }
            }
        }
    }

    /// The mode this approximator was built for.
    pub fn mode(&self) -> DeltaMode {
        self.mode
    }

    /// Number of *logical* table entries (what the hardware would store:
    /// 20 for the paper's MAC LUT, 640 for the soft-max LUT; 0 for the
    /// bit-shift mode, which needs no ROM).
    pub fn table_len(&self) -> usize {
        self.logical_len
    }

    /// Right-shift turning a fixed-point difference into a table index
    /// (kernel export: the lane kernels in `lns::lanes` re-derive the
    /// shift→load indexing outside this struct).
    pub fn index_shift(&self) -> u32 {
        self.index_shift
    }

    /// Pre-shift rounding bias paired with [`Self::index_shift`].
    pub fn index_round(&self) -> i32 {
        self.index_round
    }

    /// Raw Δ+ table access (kernel export / artifact cross-checks).
    pub fn table_plus(&self) -> &[i32] {
        &self.table_plus
    }

    /// Raw Δ− table access.
    pub fn table_minus(&self) -> &[i32] {
        &self.table_minus
    }

    /// `Δ+` of a fixed-point difference `d ∈ [0, 2·m_max]` (units of
    /// `2^-q_f`), in the same units. Monotonically non-increasing.
    #[inline(always)]
    pub fn plus(&self, d: i64) -> i64 {
        debug_assert!(d >= 0);
        let idx = ((d as i32 + self.index_round) >> self.index_shift) as usize;
        debug_assert!(idx < self.table_plus.len(), "d out of reachable range");
        self.table_plus[idx] as i64
    }

    /// `Δ−` of a fixed-point difference `d ∈ (0, 2·m_max]`, in the same
    /// units. Always ≤ 0; the singular bin returns a huge negative value
    /// that callers clamp with saturating arithmetic. `d == 0` must be
    /// handled by the caller (exact cancellation → zero).
    #[inline(always)]
    pub fn minus(&self, d: i64) -> i64 {
        debug_assert!(d > 0);
        let idx = ((d as i32 + self.index_round) >> self.index_shift) as usize;
        debug_assert!(idx < self.table_minus.len(), "d out of reachable range");
        self.table_minus[idx] as i64
    }

    /// 32-bit fast path of [`Self::plus`] (hot loop; values cannot wrap:
    /// entries ≤ 2^{q_f}, differences ≤ 2·m_max).
    #[inline(always)]
    pub fn plus_i32(&self, d: i32) -> i32 {
        debug_assert!(d >= 0);
        self.table_plus[((d + self.index_round) >> self.index_shift) as usize]
    }

    /// 32-bit fast path of [`Self::minus`].
    #[inline(always)]
    pub fn minus_i32(&self, d: i32) -> i32 {
        debug_assert!(d > 0);
        self.table_minus[((d + self.index_round) >> self.index_shift) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg16() -> LnsConfig {
        LnsConfig::w16_lut()
    }

    #[test]
    fn exact_delta_known_values() {
        // Δ+(0) = log2(2) = 1; Δ+(∞) → 0.
        assert!((delta_plus_exact(0.0) - 1.0).abs() < 1e-12);
        assert!(delta_plus_exact(40.0).abs() < 1e-9);
        // Δ−(1) = log2(1 - 1/2) = -1.
        assert!((delta_minus_exact(1.0) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn lut_matches_exact_at_sample_points() {
        let cfg = cfg16();
        let ap = DeltaApprox::new(&cfg, DeltaMode::Lut(LutSpec::MAC20));
        // Sample point d = i*r: LUT is exact (up to rounding) there.
        for i in 1..20 {
            let d_real = i as f64 * 0.5;
            let d_units = cfg.to_units(d_real);
            let got = ap.plus(d_units);
            let want = cfg.to_units(delta_plus_exact(d_real));
            assert_eq!(got, want, "Δ+ at d={d_real}");
            let got = ap.minus(d_units);
            let want = cfg.to_units(delta_minus_exact(d_real));
            assert_eq!(got, want, "Δ− at d={d_real}");
        }
    }

    #[test]
    fn lut_is_piecewise_constant_nearest() {
        let cfg = cfg16();
        let ap = DeltaApprox::new(&cfg, DeltaMode::Lut(LutSpec::MAC20));
        // Nearest-sample indexing: everything in [0.25, 0.75) maps to the
        // d = 0.5 sample.
        let lo = cfg.to_units(0.25);
        let hi = cfg.to_units(0.75) - 1;
        assert_eq!(ap.plus(lo), ap.plus(hi));
        assert_eq!(ap.plus(lo), cfg.to_units(delta_plus_exact(0.5)));
        // And [0, 0.25) maps to the d = 0 sample.
        assert_eq!(ap.plus(0), cfg.to_units(delta_plus_exact(0.0)));
        assert_eq!(ap.plus(cfg.to_units(0.25) - 1), ap.plus(0));
    }

    #[test]
    fn beyond_range_is_zero() {
        let cfg = cfg16();
        let ap = DeltaApprox::new(&cfg, DeltaMode::Lut(LutSpec::MAC20));
        let d = cfg.to_units(10.0); // d_max
        assert_eq!(ap.plus(d), 0);
        assert_eq!(ap.minus(d), 0);
        // Largest reachable difference stays in range.
        let d_reach = 2 * cfg.m_max() as i64;
        assert_eq!(ap.plus(d_reach), 0);
        assert_eq!(ap.minus(d_reach), 0);
    }

    #[test]
    fn minus_singular_bin_saturates() {
        let cfg = cfg16();
        let ap = DeltaApprox::new(&cfg, DeltaMode::Lut(LutSpec::MAC20));
        // d in (0, r/2): nearest-maps to bin 0 → huge negative.
        assert!(ap.minus(1) < cfg.m_min() as i64 * 2);
    }

    #[test]
    fn bitshift_matches_eq9() {
        let cfg = cfg16();
        let ap = DeltaApprox::new(&cfg, DeltaMode::BitShift);
        let q = cfg.frac_bits;
        // d = 0 → Δ+ = 1.0 (1024 units), Δ− = -1.5 (-1536 units).
        assert_eq!(ap.plus(0), 1i64 << q);
        // d = 1.0 → Δ+ = 0.5, Δ− = -0.75.
        assert_eq!(ap.plus(1i64 << q), 1i64 << (q - 1));
        assert_eq!(ap.minus(1i64 << q), -(3i64 << q) >> 2);
        // d = 3.25 → ⌊d⌋ = 3 → Δ+ = 2^-3 (floor indexing, like a shifter).
        assert_eq!(ap.plus((13i64 << q) / 4), (1i64 << q) >> 3);
        // Largest reachable d → 0-ish (entry 31).
        let d_reach = 2 * cfg.m_max() as i64;
        assert!(ap.plus(d_reach) <= 1);
    }

    #[test]
    fn bitshift_equals_r1_lut_shape() {
        // Paper: the bit-shift rule is a LUT with r = 1. Verify Δ+ of the
        // bit-shift at integer d matches 2^-d within one LUT-entry rounding.
        let cfg = cfg16();
        let bs = DeltaApprox::new(&cfg, DeltaMode::BitShift);
        for d in 0..10i64 {
            let du = d << cfg.frac_bits;
            let want = cfg.to_units((-(d as f64)).exp2());
            assert_eq!(bs.plus(du), want);
        }
    }

    #[test]
    fn modes_agree_at_shared_entries() {
        // All three Δ modes now round through `LnsConfig::to_units`, so
        // wherever two modes sample the same `d` their table entries must
        // be equal — the guard that keeps a future rounding change from
        // silently forking them.
        for cfg in [LnsConfig::w16_lut(), LnsConfig::w12_lut()] {
            let exact = DeltaApprox::new(&cfg, DeltaMode::Exact);
            // LUT sample points d = i·r are shared with the Exact table.
            for spec in [LutSpec::MAC20, LutSpec::SOFTMAX640] {
                if spec.log2_inv_r > cfg.frac_bits {
                    continue; // finer than the word — unrepresentable
                }
                let lut = DeltaApprox::new(&cfg, DeltaMode::Lut(spec));
                assert_eq!(lut.plus(0), exact.plus(0), "Δ+(0) ({spec:?})");
                for i in 1..spec.len() {
                    let d = cfg.to_units(i as f64 * spec.r());
                    assert_eq!(lut.plus(d), exact.plus(d), "Δ+ at sample {i} ({spec:?})");
                    assert_eq!(lut.minus(d), exact.minus(d), "Δ− at sample {i} ({spec:?})");
                }
            }
            // Bit-shift entries at integer d are exact shifts of Eq. 9's
            // constants — the same values `to_units` produces for 2^-d and
            // −1.5·2^-d while the shifts stay exact.
            let bs = DeltaApprox::new(&cfg, DeltaMode::BitShift);
            for d in 0..=6i64 {
                let du = d << cfg.frac_bits;
                let dr = d as f64;
                assert_eq!(bs.plus(du), cfg.to_units((-dr).exp2()), "bit-shift Δ+ d={d}");
                // Δ−'s base is 1.5·2^{q_f}, so its shift stays exact only
                // for d < q_f; beyond that the shifter truncates where
                // `to_units` would round — that truncation *is* Eq. 9's
                // hardware behaviour, so only the exact range is shared.
                if d > 0 && d < cfg.frac_bits as i64 {
                    assert_eq!(
                        bs.minus(du),
                        cfg.to_units(-1.5 * (-dr).exp2()),
                        "bit-shift Δ− d={d}"
                    );
                }
            }
        }
    }

    #[test]
    fn plus_monotone_nonincreasing() {
        let cfg = cfg16();
        for mode in [
            DeltaMode::Lut(LutSpec::MAC20),
            DeltaMode::Lut(LutSpec::SOFTMAX640),
            DeltaMode::BitShift,
            DeltaMode::Exact,
        ] {
            let ap = DeltaApprox::new(&cfg, mode);
            let mut prev = ap.plus(0);
            for d in 1..(12i64 << cfg.frac_bits) {
                let cur = ap.plus(d);
                assert!(cur <= prev, "Δ+ not monotone at d={d} ({mode:?})");
                prev = cur;
            }
        }
    }

    #[test]
    fn minus_monotone_nondecreasing() {
        let cfg = cfg16();
        for mode in [
            DeltaMode::Lut(LutSpec::MAC20),
            DeltaMode::BitShift,
            DeltaMode::Exact,
        ] {
            let ap = DeltaApprox::new(&cfg, mode);
            let mut prev = ap.minus(1);
            for d in 2..(12i64 << cfg.frac_bits) {
                let cur = ap.minus(d);
                assert!(cur >= prev, "Δ− not monotone at d={d} ({mode:?})");
                prev = cur;
            }
        }
    }

    #[test]
    fn exact_mode_tracks_closed_form() {
        let cfg = cfg16();
        let ap = DeltaApprox::new(&cfg, DeltaMode::Exact);
        for d_real in [0.0, 0.25, 1.0, 2.5, 7.0] {
            let d = cfg.to_units(d_real);
            let want = cfg.to_units(delta_plus_exact(d_real));
            assert!((ap.plus(d) - want).abs() <= 1);
        }
    }

    #[test]
    fn logical_len_reports_hardware_rom_size() {
        let cfg = cfg16();
        assert_eq!(DeltaApprox::new(&cfg, DeltaMode::Lut(LutSpec::MAC20)).table_len(), 20);
        assert_eq!(
            DeltaApprox::new(&cfg, DeltaMode::Lut(LutSpec::SOFTMAX640)).table_len(),
            640
        );
        assert_eq!(DeltaApprox::new(&cfg, DeltaMode::BitShift).table_len(), 0);
    }

    #[test]
    #[should_panic(expected = "finer than word resolution")]
    fn lut_finer_than_word_panics() {
        let cfg = LnsConfig::w12_lut(); // q_f = 6
        let _ = DeltaApprox::new(
            &cfg,
            DeltaMode::Lut(LutSpec { d_max: 10, log2_inv_r: 8 }),
        );
    }
}
