//! Hardware-complexity model for LNS vs linear MAC units.
//!
//! The paper's motivation (§1, citing Arnold et al. [14]) is that an
//! LNS MAC replaces the multiplier array with an adder plus a small
//! Δ-ROM and shifter, claiming ~3.2× area-delay improvement at 8-in/16-
//! out precision. This module provides a transparent first-order gate
//! model so the `cost` CLI subcommand and the LUT-sweep ablation can
//! report an **area proxy per configuration** next to its accuracy —
//! the paper's named future work ("co-optimization of Δ-term
//! approximations considering classification accuracy and hardware
//! complexity").
//!
//! Conventions (standard textbook first-order counts, in NAND2-equivalent
//! gate units — coarse by construction, which is all a co-optimization
//! sweep needs):
//! * ripple adder: 5 gates/bit (full adder ≈ 5 NAND2),
//! * array multiplier n×n: one AND + one FA per partial-product bit
//!   ≈ 6·n² gates,
//! * barrel shifter n-bit: log2(n) mux stages ≈ 3·n·log2(n),
//! * ROM: ~0.25 gate-equivalents per bit (dense NOR ROM),
//! * comparator / mux: 3 gates per bit.

use super::config::{DeltaMode, LnsConfig};

/// First-order gate-count breakdown of one MAC datapath.
#[derive(Clone, Debug, PartialEq)]
pub struct MacCost {
    /// Human label (`lns16-lut20`, `lin16`, …).
    pub label: String,
    /// Adder gates.
    pub adder: f64,
    /// Multiplier-array gates (linear MAC only).
    pub multiplier: f64,
    /// Comparator + max-select gates (LNS only).
    pub compare_select: f64,
    /// Δ ROM storage gates (LUT mode).
    pub rom: f64,
    /// Shifter gates (bit-shift mode / pow2 path).
    pub shifter: f64,
}

impl MacCost {
    /// Total NAND2-equivalent gates.
    pub fn total(&self) -> f64 {
        self.adder + self.multiplier + self.compare_select + self.rom + self.shifter
    }
}

const FA_GATES: f64 = 5.0;
const MUL_GATES_PER_BIT2: f64 = 6.0;
const ROM_GATES_PER_BIT: f64 = 0.25;
const CMP_GATES_PER_BIT: f64 = 3.0;

fn shifter_gates(bits: f64) -> f64 {
    3.0 * bits * bits.log2().max(1.0)
}

/// Cost of a linear fixed-point MAC at width `w` (sign + b_i + b_f):
/// an n×n multiplier array plus a 2n-bit accumulate adder.
pub fn linear_mac_cost(w: u32) -> MacCost {
    let n = w as f64;
    MacCost {
        label: format!("lin{w}"),
        adder: 2.0 * n * FA_GATES,
        multiplier: MUL_GATES_PER_BIT2 * n * n,
        compare_select: 0.0,
        rom: 0.0,
        shifter: 0.0,
    }
}

/// Cost of an LNS MAC for a word config: ⊡ is a (W−2)-bit adder; ⊞ is a
/// comparator + subtract + Δ evaluation + final add.
pub fn lns_mac_cost(cfg: &LnsConfig) -> MacCost {
    let m_bits = (cfg.total_bits - 1) as f64; // magnitude incl. its sign
    let adders = 3.0 * m_bits * FA_GATES; // ⊡ add, |X−Y| sub, max+Δ add
    let cmp = 2.0 * CMP_GATES_PER_BIT * m_bits; // compare + select muxes
    let (rom, shifter, tag) = match cfg.delta {
        DeltaMode::Lut(spec) => {
            // Two tables (Δ+, Δ−) of `spec.len()` words × q_f+1 bits.
            let bits = 2.0 * spec.len() as f64 * (cfg.frac_bits + 1) as f64;
            (bits * ROM_GATES_PER_BIT, 0.0, format!("lut{}", spec.len()))
        }
        DeltaMode::BitShift => (0.0, 2.0 * shifter_gates(m_bits), "bs".into()),
        DeltaMode::Exact => (f64::INFINITY, 0.0, "exact".into()),
    };
    MacCost {
        label: format!("lns{}-{tag}", cfg.total_bits),
        adder: adders,
        multiplier: 0.0,
        compare_select: cmp,
        rom,
        shifter,
    }
}

/// The headline ratio: linear-MAC gates / LNS-MAC gates at equal width.
pub fn area_ratio(cfg: &LnsConfig) -> f64 {
    linear_mac_cost(cfg.total_bits).total() / lns_mac_cost(cfg).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lns::config::LutSpec;

    #[test]
    fn linear_cost_dominated_by_multiplier() {
        let c = linear_mac_cost(16);
        assert!(c.multiplier > 0.8 * c.total());
        assert_eq!(c.compare_select, 0.0);
    }

    #[test]
    fn lns_cost_has_no_multiplier() {
        let c = lns_mac_cost(&LnsConfig::w16_lut());
        assert_eq!(c.multiplier, 0.0);
        assert!(c.rom > 0.0);
        let b = lns_mac_cost(&LnsConfig::w16_bitshift());
        assert_eq!(b.rom, 0.0);
        assert!(b.shifter > 0.0);
    }

    #[test]
    fn lns_wins_at_16_bits_like_the_papers_motivation() {
        // The cited claim is ~3.2× area-delay at 8-in/16-out; our pure-
        // area first-order model should at least show a clear multi-×
        // advantage at 16 bits.
        let r = area_ratio(&LnsConfig::w16_lut());
        assert!(r > 2.0, "area ratio {r}");
        let r12 = area_ratio(&LnsConfig::w12_lut());
        assert!(r12 > 1.5, "12-bit ratio {r12}");
    }

    #[test]
    fn bigger_tables_cost_more() {
        let mut small = LnsConfig::w16_lut();
        small.delta = DeltaMode::Lut(LutSpec { d_max: 10, log2_inv_r: 1 });
        let mut big = LnsConfig::w16_lut();
        big.delta = DeltaMode::Lut(LutSpec { d_max: 10, log2_inv_r: 6 });
        assert!(
            lns_mac_cost(&big).total() > lns_mac_cost(&small).total(),
            "640-entry table must cost more than 20-entry"
        );
    }

    #[test]
    fn bitshift_vs_lut_crossover() {
        // A noteworthy model outcome: the variable barrel shifter the
        // Eq.-9 rule needs is *pricier* than the paper's tiny 20-entry
        // ROM — the bit-shift only wins against big tables. (Consistent
        // with the paper's closing caveat that the adder datapath cost
        // decides practicality.)
        let mut big = LnsConfig::w16_lut();
        big.delta = DeltaMode::Lut(LutSpec { d_max: 10, log2_inv_r: 6 });
        let lut640 = lns_mac_cost(&big).total();
        let bs = lns_mac_cost(&LnsConfig::w16_bitshift()).total();
        assert!(bs < lut640, "shift beats the 640-entry ROM");
    }
}
