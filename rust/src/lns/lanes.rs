//! Branchless lane-vectorized ⊞ kernels (stable Rust, no `std::simd`).
//!
//! The scalar ⊞ core ([`super::system`]'s `add_nonzero`) decides between
//! four outcomes per element — zero-skip, take-the-other-operand, exact
//! cancellation, and the max+Δ± path — with data-dependent branches. Those
//! branches are unpredictable on real operand streams, and they stop LLVM
//! from autovectorizing the MAC inner loops. This module re-expresses the
//! same integer semantics over fixed-width lanes of `[i32; LANES]`:
//!
//! * every condition becomes an all-ones/all-zeros **mask**
//!   (`-(cond as i32)`), every choice a mask select
//!   `(a & m) | (b & !m)` — no per-element branching anywhere;
//! * bit-shift mode evaluates Δ± as a **closed-form shift** per lane
//!   (exactly the padded table's constructor expression, so the values are
//!   equal by construction);
//! * LUT/Exact modes batch the index arithmetic across the lane, then
//!   gather the Δ± entries with plain loads.
//!
//! **Bit-exactness contract (NUMERICS.md §2):** the lane kernels compute,
//! element by element, the *same bits* as the scalar kernels they replace
//! — including the `ZERO_M` sentinel's sign field, the cancellation sign
//! (`LnsValue::ZERO.s == true`), and the clamp behaviour at `±m_max`.
//! Lanes batch *independent output elements* (`j` across a row); the
//! k-ascending ⊞ chain of any single element is never regrouped. Slice
//! tails shorter than [`LANES`] run the scalar twin. Pinned by
//! `tests/lane_exactness.rs` and the equivalence probes in
//! `lns::system::tests`.
//!
//! Two invariants make the branchless form safe:
//! * the exact-zero sentinel `ZERO_M = i32::MIN` is mask-substituted with
//!   `0` *before* any subtraction, so `|X − Y|` cannot wrap;
//! * the unconditional two-sided clamp equals the scalar's one-sided
//!   clamps (Δ+ ≥ 0 makes the lower clamp a no-op on the same-sign path;
//!   Δ− ≤ 0 makes the upper clamp a no-op on the opposite-sign path).
//!
//! **Observability:** the lane kernels carry no event counters. When the
//! numerics counters are enabled, the `LnsSystem` dispatchers route to
//! counted copies of the scalar twins *before* consulting [`enabled`] —
//! the lane/scalar bit-exactness contract above is exactly what makes
//! that value-preserving, and it keeps clamp/cancel tallies independent
//! of this switch (`tests/obs_exactness.rs` pins both properties).

use std::sync::atomic::{AtomicBool, Ordering};

use super::config::DeltaMode;
use super::delta::DeltaApprox;
use super::system::add_nonzero;
use super::value::{LnsValue, ZERO_M};

/// Lane width. Eight `i32`s = one 256-bit vector register; narrower ISAs
/// split it into two 128-bit ops, which LLVM handles for free.
pub const LANES: usize = 8;

/// Process-global lane-kernel switch (default **on**).
///
/// Exists for apples-to-apples benchmarking (`benches/ops.rs` times the
/// lane and scalar paths through the same public entry points) and as an
/// escape hatch while triaging a miscompile. Because both paths are
/// bit-identical, flipping it mid-run can never change any result — only
/// throughput.
static LANES_ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable or disable the lane kernels process-wide.
pub fn set_enabled(on: bool) {
    // numerics-lint: allow(atomics) — perf-only toggle: both paths are bit-identical (§2)
    LANES_ENABLED.store(on, Ordering::Relaxed);
}

/// Whether the lane kernels are enabled.
#[inline]
pub fn enabled() -> bool {
    // numerics-lint: allow(atomics) — perf-only toggle: both paths are bit-identical (§2)
    LANES_ENABLED.load(Ordering::Relaxed)
}

/// `-1` (all ones) when `c`, else `0` — the lane-mask idiom.
#[inline(always)]
fn mask(c: bool) -> i32 {
    -(c as i32)
}

/// `LnsValue` sign as a mask: `-1` ⇔ `s == true`.
#[inline(always)]
fn smask(s: bool) -> i32 {
    -(s as i32)
}

/// Hoisted per-kernel state: Δ± evaluation plan plus clamp bounds.
///
/// Bit-shift mode carries only the shift amount and re-derives the padded
/// table's constructor expression per lane (branchless, no loads); the
/// LUT/Exact modes carry the table base pointers and gather.
struct Ctx<'a> {
    shift_form: bool,
    index_shift: u32,
    index_round: i32,
    table_plus: &'a [i32],
    table_minus: &'a [i32],
    m_min: i32,
    m_max: i32,
}

impl<'a> Ctx<'a> {
    #[inline(always)]
    fn new(ap: &'a DeltaApprox, m_min: i32, m_max: i32) -> Self {
        Ctx {
            shift_form: ap.mode() == DeltaMode::BitShift,
            index_shift: ap.index_shift(),
            index_round: ap.index_round(),
            table_plus: ap.table_plus(),
            table_minus: ap.table_minus(),
            m_min,
            m_max,
        }
    }

    /// Δ± for one lane's difference `d ∈ [0, 2·m_max]`, as `(Δ+, Δ−)`.
    ///
    /// Shift form: `idx = d >> q_f`, `Δ+ = (1 << q_f) >> idx`,
    /// `Δ− = −((3 << q_f) >> 1 >> idx)` — literally the bit-shift table
    /// constructor from `delta.rs` (entries are 0 from index 63 on, which
    /// the `min(63)` shift clamp reproduces), so equality with the gather
    /// path is by construction, not by coincidence. Test-only reference;
    /// the kernels inline both forms in `lane_acc_add`.
    #[cfg(test)]
    #[inline(always)]
    fn delta_pair(&self, d: i32) -> (i32, i32) {
        if self.shift_form {
            let idx = ((d >> self.index_shift) as u32).min(63);
            let dp = ((1i64 << self.index_shift) >> idx) as i32;
            let dm = -((((3i64 << self.index_shift) >> 1) >> idx) as i32);
            (dp, dm)
        } else {
            let idx = ((d + self.index_round) >> self.index_shift) as usize;
            (self.table_plus[idx], self.table_minus[idx])
        }
    }
}

/// Branchless lane ⊞-accumulate: `acc ⊞= p` per lane, with `pz` marking
/// lanes whose `p` operand is the exact zero word (those lanes keep `acc`
/// bit-for-bit, matching the scalar zero-skip `continue`).
///
/// Inputs: `am`/`asm_` are the accumulator magnitude and sign-mask lanes
/// (updated in place); `pm`/`ps` the other operand's, with `pm` already
/// in clamped word range for non-zero lanes (zero lanes may carry any
/// in-range magnitude — they are masked out). Select priority mirrors the
/// scalar kernels exactly: p-zero → acc unchanged, acc-zero → p, exact
/// cancellation → the canonical `ZERO` word (`s = true`), else
/// `clamp(max + Δ±)` with the larger operand's sign.
#[inline(always)]
fn lane_acc_add(
    ctx: &Ctx,
    am: &mut [i32; LANES],
    asm_: &mut [i32; LANES],
    pm: &[i32; LANES],
    ps: &[i32; LANES],
    pz: &[i32; LANES],
) {
    let mut d = [0i32; LANES];
    let mut mmax = [0i32; LANES];
    let mut sz = [0i32; LANES];
    let mut same = [0i32; LANES];
    let mut az = [0i32; LANES];
    for i in 0..LANES {
        let a = am[i];
        let azm = mask(a == ZERO_M);
        // Substitute 0 for the sentinel before subtracting (wrap hazard).
        let a2 = a & !azm;
        let p = pm[i];
        // Strict `>` matches the scalar tie rule: ties take p's sign.
        let gt = mask(a2 > p);
        mmax[i] = (a2 & gt) | (p & !gt);
        let draw = a2 - p;
        let sg = draw >> 31;
        d[i] = (draw ^ sg) - sg; // |a2 − p|, branchless abs
        sz[i] = (asm_[i] & gt) | (ps[i] & !gt);
        same[i] = !(asm_[i] ^ ps[i]);
        az[i] = azm;
    }
    let mut dp = [0i32; LANES];
    let mut dm = [0i32; LANES];
    if ctx.shift_form {
        // Closed-form shifts: fully branchless, no memory traffic.
        for i in 0..LANES {
            let idx = ((d[i] >> ctx.index_shift) as u32).min(63);
            dp[i] = ((1i64 << ctx.index_shift) >> idx) as i32;
            dm[i] = -((((3i64 << ctx.index_shift) >> 1) >> idx) as i32);
        }
    } else {
        // Gather: index arithmetic vectorizes; the loads are scalar but
        // straight-line (no data-dependent control flow).
        for i in 0..LANES {
            let idx = ((d[i] + ctx.index_round) >> ctx.index_shift) as usize;
            dp[i] = ctx.table_plus[idx];
            dm[i] = ctx.table_minus[idx];
        }
    }
    for i in 0..LANES {
        let delta = (dp[i] & same[i]) | (dm[i] & !same[i]);
        let mres = (mmax[i] + delta).clamp(ctx.m_min, ctx.m_max);
        // Opposite signs at d = 0: exact cancellation → canonical ZERO.
        let cancel = !same[i] & mask(d[i] == 0);
        let m_nz = (ZERO_M & cancel) | (mres & !cancel);
        let s_nz = cancel | (sz[i] & !cancel); // ZERO.s = true
        // acc-zero lanes take p verbatim.
        let m_inner = (pm[i] & az[i]) | (m_nz & !az[i]);
        let s_inner = (ps[i] & az[i]) | (s_nz & !az[i]);
        // p-zero lanes keep acc verbatim (outermost priority).
        am[i] = (am[i] & pz[i]) | (m_inner & !pz[i]);
        asm_[i] = (asm_[i] & pz[i]) | (s_inner & !pz[i]);
    }
}

/// Lane body shared by `mac_row` and `mac_panel`: one full-width chunk of
/// `acc[j] ⊞= (a ⊡ w[j])` for a non-zero scalar multiplier `a`.
#[inline(always)]
fn mac_lane_chunk(ctx: &Ctx, a_m: i32, a_s: i32, acc: &mut [LnsValue], w: &[LnsValue]) {
    let mut am = [0i32; LANES];
    let mut asm_ = [0i32; LANES];
    let mut pm = [0i32; LANES];
    let mut ps = [0i32; LANES];
    let mut pz = [0i32; LANES];
    for i in 0..LANES {
        am[i] = acc[i].m;
        asm_[i] = smask(acc[i].s);
        let wv = w[i];
        let wz = mask(wv.m == ZERO_M);
        // ⊡ (Eq. 2) on the zero-substituted magnitude: the product lane is
        // garbage when w is zero, but pz masks it out downstream.
        let wm2 = wv.m & !wz;
        pm[i] = (a_m + wm2).clamp(ctx.m_min, ctx.m_max);
        ps[i] = !(a_s ^ smask(wv.s));
        pz[i] = wz;
    }
    lane_acc_add(ctx, &mut am, &mut asm_, &pm, &ps, &pz);
    for i in 0..LANES {
        acc[i] = LnsValue { m: am[i], s: asm_[i] != 0 };
    }
}

/// Scalar tail of the MAC kernels — the exact per-element logic of
/// `LnsSystem::mac_row_scalar`, applied to a remainder shorter than
/// [`LANES`].
#[inline(always)]
fn mac_scalar_tail(
    ap: &DeltaApprox,
    m_min: i32,
    m_max: i32,
    a_m: i32,
    a_s: bool,
    acc: &mut [LnsValue],
    w: &[LnsValue],
) {
    for (acc_j, &wv) in acc.iter_mut().zip(w.iter()) {
        if wv.is_zero() {
            continue;
        }
        let p = LnsValue { m: (a_m + wv.m).clamp(m_min, m_max), s: !(a_s ^ wv.s) };
        let x = *acc_j;
        *acc_j = if x.is_zero() { p } else { add_nonzero(ap, m_min, m_max, x, p) };
    }
}

/// Lane `mac_row`: `acc[j] = acc[j] ⊞ (a ⊡ w[j])`. Caller guarantees
/// `a` non-zero (the dispatcher early-returns otherwise).
pub(crate) fn mac_row(
    ap: &DeltaApprox,
    m_min: i32,
    m_max: i32,
    acc: &mut [LnsValue],
    a: LnsValue,
    w: &[LnsValue],
) {
    debug_assert_eq!(acc.len(), w.len());
    debug_assert!(!a.is_zero());
    let ctx = Ctx::new(ap, m_min, m_max);
    mac_row_with(&ctx, ap, a, acc, w);
}

/// `mac_row` body over a pre-hoisted [`Ctx`] (shared with `mac_panel`).
#[inline(always)]
fn mac_row_with(ctx: &Ctx, ap: &DeltaApprox, a: LnsValue, acc: &mut [LnsValue], w: &[LnsValue]) {
    let (a_m, a_s) = (a.m, smask(a.s));
    let mut acc_it = acc.chunks_exact_mut(LANES);
    let mut w_it = w.chunks_exact(LANES);
    for (ac, wc) in (&mut acc_it).zip(&mut w_it) {
        mac_lane_chunk(ctx, a_m, a_s, ac, wc);
    }
    mac_scalar_tail(ap, ctx.m_min, ctx.m_max, a.m, a.s, acc_it.into_remainder(), w_it.remainder());
}

/// Lane `mac_panel`: `acc[j] ⊞= (a[p] ⊡ panel[p·nc + j])`, `p` ascending.
/// The [`Ctx`] hoists once per panel; each panel row reuses the lane
/// `mac_row` body. Per-row zero-skip keeps the scalar semantics (`a[p] =
/// 0` leaves `acc` untouched for the whole row).
pub(crate) fn mac_panel(
    ap: &DeltaApprox,
    m_min: i32,
    m_max: i32,
    acc: &mut [LnsValue],
    a: &[LnsValue],
    panel: &[LnsValue],
) {
    let nc = acc.len();
    debug_assert_eq!(panel.len(), a.len() * nc);
    let ctx = Ctx::new(ap, m_min, m_max);
    for (p, &av) in a.iter().enumerate() {
        if av.is_zero() {
            continue;
        }
        mac_row_with(&ctx, ap, av, acc, &panel[p * nc..(p + 1) * nc]);
    }
}

/// Lane `dot_acc`: zero-skipping continuation `acc ⊞ Σ_i (a[i] ⊡ w[i])`,
/// `i` ascending.
///
/// The ⊞ chain here runs through a **single accumulator**, so lane-folding
/// it would regroup the chain — forbidden by NUMERICS.md §2. Instead the
/// order-free part (the ⊡ products: magnitude adds, sign XNORs, zero
/// detects) is lane-batched, and the fold itself stays a sequential
/// `add_nonzero` walk in the original order.
pub(crate) fn dot_acc(
    ap: &DeltaApprox,
    m_min: i32,
    m_max: i32,
    acc: LnsValue,
    a: &[LnsValue],
    w: &[LnsValue],
) -> LnsValue {
    debug_assert_eq!(a.len(), w.len());
    let mut acc = acc;
    let mut a_it = a.chunks_exact(LANES);
    let mut w_it = w.chunks_exact(LANES);
    for (ac, wc) in (&mut a_it).zip(&mut w_it) {
        let mut pm = [0i32; LANES];
        let mut ps = [0i32; LANES];
        let mut pz = [0i32; LANES];
        for i in 0..LANES {
            let av = ac[i];
            let wv = wc[i];
            let azm = mask(av.m == ZERO_M);
            let wzm = mask(wv.m == ZERO_M);
            pm[i] = ((av.m & !azm) + (wv.m & !wzm)).clamp(m_min, m_max);
            ps[i] = !(smask(av.s) ^ smask(wv.s));
            pz[i] = azm | wzm;
        }
        // Ordered fold over the batched products (i ascending, unchanged).
        for i in 0..LANES {
            if pz[i] == 0 {
                let prod = LnsValue { m: pm[i], s: ps[i] != 0 };
                acc = if acc.is_zero() {
                    prod
                } else {
                    add_nonzero(ap, m_min, m_max, acc, prod)
                };
            }
        }
    }
    for (&av, &wv) in a_it.remainder().iter().zip(w_it.remainder().iter()) {
        if av.is_zero() || wv.is_zero() {
            continue;
        }
        let prod = LnsValue { m: (av.m + wv.m).clamp(m_min, m_max), s: !(av.s ^ wv.s) };
        acc = if acc.is_zero() { prod } else { add_nonzero(ap, m_min, m_max, acc, prod) };
    }
    acc
}

/// Lane `add_slice`: `acc[j] = acc[j] ⊞ x[j]`.
///
/// The select priority differs from the MAC kernels — the scalar
/// `add_slice` checks the **accumulator** for zero first and copies `x[j]`
/// verbatim (whatever its bits), so zero lanes must yield the *original*
/// `x` word, not the zero-substituted magnitude used for arithmetic.
pub(crate) fn add_slice(
    ap: &DeltaApprox,
    m_min: i32,
    m_max: i32,
    acc: &mut [LnsValue],
    x: &[LnsValue],
) {
    debug_assert_eq!(acc.len(), x.len());
    let ctx = Ctx::new(ap, m_min, m_max);
    let mut acc_it = acc.chunks_exact_mut(LANES);
    let mut x_it = x.chunks_exact(LANES);
    for (ac, xc) in (&mut acc_it).zip(&mut x_it) {
        let mut am = [0i32; LANES];
        let mut asm_ = [0i32; LANES];
        let mut ym = [0i32; LANES];
        let mut ym2 = [0i32; LANES];
        let mut ys = [0i32; LANES];
        let mut yz = [0i32; LANES];
        for i in 0..LANES {
            am[i] = ac[i].m;
            asm_[i] = smask(ac[i].s);
            let yv = xc[i];
            let z = mask(yv.m == ZERO_M);
            ym[i] = yv.m;
            ym2[i] = yv.m & !z;
            ys[i] = smask(yv.s);
            yz[i] = z;
        }
        let mut a2 = am;
        let mut s2 = asm_;
        // Run the shared core with substituted y-magnitudes; its az branch
        // (acc zero → take p) returns ym2, which we then patch back to the
        // original y bits to match the scalar verbatim copy.
        let pm: [i32; LANES] = ym2;
        lane_acc_add(&ctx, &mut a2, &mut s2, &pm, &ys, &yz);
        for i in 0..LANES {
            let az = mask(am[i] == ZERO_M);
            // acc-zero lanes: scalar copies y before looking at y's zero
            // bit, so they win over the core's y-zero keep.
            let m_out = (ym[i] & az) | (a2[i] & !az);
            let s_out = (ys[i] & az) | (s2[i] & !az);
            ac[i] = LnsValue { m: m_out, s: s_out != 0 };
        }
    }
    for (a, &y) in acc_it.into_remainder().iter_mut().zip(x_it.remainder().iter()) {
        let xv = *a;
        if xv.is_zero() {
            *a = y;
            continue;
        }
        if y.is_zero() {
            continue;
        }
        *a = add_nonzero(ap, m_min, m_max, xv, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lns::config::LnsConfig;

    #[test]
    fn toggle_roundtrips() {
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
        set_enabled(true);
        assert!(enabled());
    }

    /// The shift closed form must equal the padded bit-shift table at
    /// every reachable difference — the equality `lane_acc_add` relies on
    /// to skip the gather in bit-shift mode.
    #[test]
    fn shift_closed_form_matches_bitshift_table() {
        for cfg in [LnsConfig::w16_bitshift(), LnsConfig::w12_bitshift()] {
            let ap = DeltaApprox::new(&cfg, DeltaMode::BitShift);
            let ctx = Ctx::new(&ap, cfg.m_min(), cfg.m_max());
            assert!(ctx.shift_form);
            for d in 0..=(2 * cfg.m_max()) {
                let (dp, dm) = ctx.delta_pair(d);
                assert_eq!(dp, ap.plus_i32(d), "Δ+ at d={d} ({}b)", cfg.total_bits);
                if d > 0 {
                    assert_eq!(dm, ap.minus_i32(d), "Δ− at d={d} ({}b)", cfg.total_bits);
                }
            }
        }
    }

    /// Gather form must reproduce the accessor indexing bit-for-bit.
    #[test]
    fn gather_form_matches_lut_accessors() {
        let cfg = LnsConfig::w16_lut();
        let ap = DeltaApprox::new(&cfg, cfg.delta);
        let ctx = Ctx::new(&ap, cfg.m_min(), cfg.m_max());
        assert!(!ctx.shift_form);
        for d in 0..=(2 * cfg.m_max()) {
            let (dp, dm) = ctx.delta_pair(d);
            assert_eq!(dp, ap.plus_i32(d), "Δ+ at d={d}");
            if d > 0 {
                assert_eq!(dm, ap.minus_i32(d), "Δ− at d={d}");
            }
        }
    }

    #[test]
    fn mask_idiom() {
        assert_eq!(mask(true), -1);
        assert_eq!(mask(false), 0);
        assert_eq!((7 & mask(true)) | (9 & !mask(true)), 7);
        assert_eq!((7 & mask(false)) | (9 & !mask(false)), 9);
    }
}
