//! [`LnsSystem`]: a word format + Δ approximators, with all arithmetic.
//!
//! This is the object threaded through the tensor/NN layers. It owns the
//! precomputed Δ tables so the per-MAC hot path is shift/clamp/load only.

use super::config::{DeltaMode, LnsConfig};
use super::delta::DeltaApprox;
use super::linconv::Pow2Table;
use super::value::LnsValue;
use crate::obs::metrics::{self, ObsTally};

/// The non-zero ⊞ core (Eq. 3) over a pre-hoisted Δ± approximator and
/// clamp bounds. Both operands must be non-zero words — zero handling
/// stays with the callers, which is what lets the slice kernels skip it
/// per shape. This is the **single copy** of the max/Δ±/tie logic that
/// [`LnsSystem::add_with`], [`LnsSystem::mac_row`] and
/// [`LnsSystem::add_slice`] all share (the lane kernels in `lns::lanes`
/// use it for sequential folds and remainder tails), so the bit-exactness
/// contract between the scalar and vectorized paths holds by construction.
#[inline(always)]
pub(crate) fn add_nonzero(
    ap: &DeltaApprox,
    m_min: i32,
    m_max: i32,
    x: LnsValue,
    y: LnsValue,
) -> LnsValue {
    debug_assert!(!x.is_zero() && !y.is_zero());
    // (max, other-sign bookkeeping). Eq. 3c: s_z = s_x if X > Y else s_y.
    let (mmax, d, s_z) = if x.m > y.m { (x.m, x.m - y.m, x.s) } else { (y.m, y.m - x.m, y.s) };
    if x.s == y.s {
        LnsValue { m: (mmax + ap.plus_i32(d)).min(m_max), s: s_z }
    } else if d == 0 {
        // Exact cancellation: +v ⊞ −v = 0.
        LnsValue::ZERO
    } else {
        LnsValue { m: (mmax + ap.minus_i32(d)).max(m_min), s: s_z }
    }
}

/// [`add_nonzero`] plus event counting into a stack-local
/// [`ObsTally`]. A **verbatim copy** of the reference arithmetic — the
/// clamp/cancel observations read the same intermediates the reference
/// computes, they never feed back into the value — so the counted and
/// uncounted paths are bit-identical by construction
/// (`tests/obs_exactness.rs` pins it end to end).
#[inline(always)]
pub(crate) fn add_nonzero_counted(
    ap: &DeltaApprox,
    m_min: i32,
    m_max: i32,
    x: LnsValue,
    y: LnsValue,
    t: &mut ObsTally,
) -> LnsValue {
    debug_assert!(!x.is_zero() && !y.is_zero());
    t.adds += 1;
    let (mmax, d, s_z) = if x.m > y.m { (x.m, x.m - y.m, x.s) } else { (y.m, y.m - x.m, y.s) };
    if x.s == y.s {
        let m = mmax + ap.plus_i32(d);
        if m > m_max {
            t.clamp_hi += 1;
        }
        LnsValue { m: m.min(m_max), s: s_z }
    } else if d == 0 {
        t.cancel += 1;
        LnsValue::ZERO
    } else {
        let m = mmax + ap.minus_i32(d);
        if m < m_min {
            t.clamp_lo += 1;
        }
        LnsValue { m: m.max(m_min), s: s_z }
    }
}

/// A concrete LNS arithmetic system (paper §2–3).
#[derive(Clone, Debug)]
pub struct LnsSystem {
    cfg: LnsConfig,
    /// Δ approximator for the MAC path (matmul, bias, SGD updates).
    delta: DeltaApprox,
    /// Finer Δ approximator for the soft-max path (paper §5: the soft-max
    /// is markedly more sensitive; Fig. 2 used r = 1/64 there).
    softmax_delta: DeltaApprox,
    /// Fractional `2^f` table for the one LNS→linear conversion the
    /// soft-max needs (see `linconv`).
    pow2: Pow2Table,
    /// `u(log2(log2 e))`: constant folded into the soft-max conversion.
    log2_log2e_units: i64,
}

impl LnsSystem {
    /// Build a system, materializing the Δ tables.
    pub fn new(cfg: LnsConfig) -> Self {
        LnsSystem {
            delta: DeltaApprox::new(&cfg, cfg.delta),
            softmax_delta: DeltaApprox::new(&cfg, cfg.softmax_delta),
            pow2: Pow2Table::new(&cfg),
            log2_log2e_units: cfg.to_units(std::f64::consts::LOG2_E.log2()),
            cfg,
        }
    }

    /// The word-format configuration.
    pub fn config(&self) -> &LnsConfig {
        &self.cfg
    }

    /// MAC-path Δ approximator.
    pub fn delta(&self) -> &DeltaApprox {
        &self.delta
    }

    /// Soft-max-path Δ approximator.
    pub fn softmax_delta(&self) -> &DeltaApprox {
        &self.softmax_delta
    }

    // ---------------------------------------------------------------
    // Encode / decode
    // ---------------------------------------------------------------

    /// Clamp a wide log-magnitude into the word's range.
    #[inline]
    fn sat(&self, m: i64) -> i32 {
        let lo = self.cfg.m_min() as i64;
        let hi = self.cfg.m_max() as i64;
        m.clamp(lo, hi) as i32
    }

    /// Encode a real number (paper Eq. 1): `m = round(log2|v| · 2^{q_f})`,
    /// clamped into the word's range; 0 and anything whose magnitude
    /// underflows the most negative representable log-magnitude by more
    /// than the clamp maps to the exact-zero word.
    pub fn encode_f64(&self, v: f64) -> LnsValue {
        if v == 0.0 || !v.is_finite() && v.is_nan() {
            return LnsValue::ZERO;
        }
        let m = self.cfg.to_units(v.abs().log2());
        LnsValue { m: self.sat(m), s: v > 0.0 }
    }

    /// Decode back to `f64`: `v = ±2^{m · 2^{-q_f}}`.
    pub fn decode_f64(&self, x: LnsValue) -> f64 {
        if x.is_zero() {
            return 0.0;
        }
        let mag = (self.cfg.from_units(x.m)).exp2();
        if x.s {
            mag
        } else {
            -mag
        }
    }

    // ---------------------------------------------------------------
    // Arithmetic (paper Eqs. 2–6)
    // ---------------------------------------------------------------

    /// ⊡ multiplication (Eq. 2): add magnitudes, XNOR signs.
    /// (32-bit: clamped magnitudes sum within ±2^15·2 ≪ i32 range.)
    #[inline(always)]
    pub fn mul(&self, x: LnsValue, y: LnsValue) -> LnsValue {
        if x.is_zero() || y.is_zero() {
            return LnsValue::ZERO;
        }
        LnsValue {
            m: (x.m + y.m).clamp(self.cfg.m_min(), self.cfg.m_max()),
            s: !(x.s ^ y.s),
        }
    }

    /// Exact division (subtract magnitudes): the LNS bonus operation.
    #[inline]
    pub fn div(&self, x: LnsValue, y: LnsValue) -> LnsValue {
        debug_assert!(!y.is_zero(), "LNS division by zero");
        if x.is_zero() {
            return LnsValue::ZERO;
        }
        LnsValue {
            m: self.sat(x.m as i64 - y.m as i64),
            s: !(x.s ^ y.s),
        }
    }

    /// ⊞ addition (Eq. 3) with the MAC-path Δ approximator.
    #[inline]
    pub fn add(&self, x: LnsValue, y: LnsValue) -> LnsValue {
        self.add_with(&self.delta, x, y)
    }

    /// ⊟ subtraction (Eq. 5): flip the second operand's sign and add.
    #[inline]
    pub fn sub(&self, x: LnsValue, y: LnsValue) -> LnsValue {
        self.add_with(&self.delta, x, y.neg())
    }

    /// ⊞ with an explicit Δ approximator (the soft-max path passes the
    /// finer table).
    ///
    /// Pure 32-bit hot path: operands are clamped words, so `|X − Y| ≤
    /// 2·m_max` and `max + Δ±` cannot wrap an `i32` (the Δ− singular
    /// sentinel is `i32::MIN/4`); Δ+ ≥ 0 needs only the upper clamp and
    /// Δ− ≤ 0 only the lower one.
    #[inline(always)]
    pub fn add_with(&self, ap: &DeltaApprox, x: LnsValue, y: LnsValue) -> LnsValue {
        if x.is_zero() {
            return y;
        }
        if y.is_zero() {
            return x;
        }
        add_nonzero(ap, self.cfg.m_min(), self.cfg.m_max(), x, y)
    }

    /// Fused multiply-accumulate `acc ⊞ (x ⊡ y)` — the paper's MAC.
    #[inline]
    pub fn mac(&self, acc: LnsValue, x: LnsValue, y: LnsValue) -> LnsValue {
        self.add(acc, self.mul(x, y))
    }

    /// Row-vectorized MAC: `acc[j] = acc[j] ⊞ (a ⊡ w[j])` for every `j`.
    ///
    /// Dispatches to the branchless lane kernel ([`crate::lns::lanes`])
    /// unless the process-global lane switch is off, in which case the
    /// scalar twin [`LnsSystem::mac_row_scalar`] runs. Both paths are
    /// bit-identical, so the switch can never change results.
    ///
    /// **Bit-exactness contract:** identical results, element by element,
    /// to `acc[j] = self.mac(acc[j], a, w[j])`. The parallel tensor ops
    /// and the Pallas cross-checks both rely on this.
    pub fn mac_row(&self, acc: &mut [LnsValue], a: LnsValue, w: &[LnsValue]) {
        debug_assert_eq!(acc.len(), w.len());
        // a = 0 ⇒ every product is the exact zero word ⇒ acc unchanged.
        if a.is_zero() {
            return;
        }
        // Counting forces the counted scalar body regardless of the lane
        // switch: lane/scalar are bit-identical (NUMERICS.md §2), so this
        // changes no values and makes tallies lane-invariant. Disabled
        // cost: this one relaxed load.
        if crate::obs::counters_enabled() {
            return self.mac_row_counted(acc, a, w);
        }
        if super::lanes::enabled() {
            super::lanes::mac_row(&self.delta, self.cfg.m_min(), self.cfg.m_max(), acc, a, w);
        } else {
            self.mac_row_scalar(acc, a, w);
        }
    }

    /// Scalar `mac_row` (the lane kernels' reference semantics).
    ///
    /// Written so everything loop-invariant is hoisted out of the inner
    /// loop: the Δ± approximator reference (and through it the LUT base
    /// pointers), the word-format clamp bounds, and the multiplier's
    /// `(m, s)` split. The loop body is then integer add → clamp → compare
    /// → shift-indexed table load, with no per-element re-derivation of
    /// any of those.
    pub fn mac_row_scalar(&self, acc: &mut [LnsValue], a: LnsValue, w: &[LnsValue]) {
        debug_assert_eq!(acc.len(), w.len());
        if a.is_zero() {
            return;
        }
        let ap = &self.delta;
        let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
        let (a_m, a_s) = (a.m, a.s);
        for (acc_j, &wv) in acc.iter_mut().zip(w.iter()) {
            // ⊡ (Eq. 2): magnitudes add, signs XNOR; zero annihilates.
            if wv.is_zero() {
                continue; // acc ⊞ 0 = acc exactly
            }
            let p = LnsValue { m: (a_m + wv.m).clamp(m_min, m_max), s: !(a_s ^ wv.s) };
            let x = *acc_j;
            *acc_j = if x.is_zero() { p } else { add_nonzero(ap, m_min, m_max, x, p) };
        }
    }

    /// Panel-vectorized MAC — the cache-tiled matmul inner kernel:
    /// `acc[j] = acc[j] ⊞ (a[p] ⊡ panel[p·nc + j])` for `p` ascending,
    /// where `panel` is a packed row-major `a.len() × nc` tile
    /// (`nc = acc.len()`).
    ///
    /// The tile-level twin of [`LnsSystem::mac_row`]; like it, dispatches
    /// to the branchless lane kernel unless lanes are switched off.
    ///
    /// **Bit-exactness contract:** identical results, element by element,
    /// to `for p { self.mac_row(&mut acc, a[p], panel_row_p) }` — i.e. to
    /// the scalar `mac` fold with `p` ascending. The tiled tensor kernels
    /// rely on this (`tests/tiled_exactness.rs`).
    pub fn mac_panel(&self, acc: &mut [LnsValue], a: &[LnsValue], panel: &[LnsValue]) {
        debug_assert_eq!(panel.len(), a.len() * acc.len());
        if crate::obs::counters_enabled() {
            return self.mac_panel_counted(acc, a, panel);
        }
        if super::lanes::enabled() {
            super::lanes::mac_panel(&self.delta, self.cfg.m_min(), self.cfg.m_max(), acc, a, panel);
        } else {
            self.mac_panel_scalar(acc, a, panel);
        }
    }

    /// Scalar `mac_panel`, hoisting the Δ± approximator reference and the
    /// word-format clamp bounds **once per panel** rather than once per
    /// row: the hot loop is integer add → clamp → compare → shift-indexed
    /// table load for the entire `kc × nc` tile, with the per-`p` work
    /// reduced to one zero test and one `(m, s)` split.
    pub fn mac_panel_scalar(&self, acc: &mut [LnsValue], a: &[LnsValue], panel: &[LnsValue]) {
        let nc = acc.len();
        debug_assert_eq!(panel.len(), a.len() * nc);
        let ap = &self.delta;
        let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
        for (p, &av) in a.iter().enumerate() {
            // a[p] = 0 ⇒ every product in this panel row is the exact
            // zero word ⇒ acc unchanged.
            if av.is_zero() {
                continue;
            }
            let (a_m, a_s) = (av.m, av.s);
            let wrow = &panel[p * nc..(p + 1) * nc];
            for (acc_j, &wv) in acc.iter_mut().zip(wrow.iter()) {
                if wv.is_zero() {
                    continue; // acc ⊞ 0 = acc exactly
                }
                let prod = LnsValue { m: (a_m + wv.m).clamp(m_min, m_max), s: !(a_s ^ wv.s) };
                let x = *acc_j;
                *acc_j = if x.is_zero() { prod } else { add_nonzero(ap, m_min, m_max, x, prod) };
            }
        }
    }

    /// Zero-skipping dot continuation `acc ⊞ Σ_i (a[i] ⊡ w[i])` (fold
    /// order: `i` ascending) with the Δ±-LUT/bounds hoisting of
    /// [`LnsSystem::mac_row`] — the `A·Bᵀ` inner kernel, shared by the
    /// serial dot and the tiled kernel's per-`kc`-block continuation.
    ///
    /// **Bit-exactness contract:** identical to the scalar fold
    /// `acc = self.mac(acc, a[i], w[i])` over `i` ascending. The lane
    /// path batches only the order-free ⊡ products; the ⊞ chain itself
    /// stays a sequential fold (NUMERICS.md §2 forbids regrouping it).
    pub fn dot_acc(&self, acc: LnsValue, a: &[LnsValue], w: &[LnsValue]) -> LnsValue {
        debug_assert_eq!(a.len(), w.len());
        if crate::obs::counters_enabled() {
            return self.dot_acc_counted(acc, a, w);
        }
        if super::lanes::enabled() {
            let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
            return super::lanes::dot_acc(&self.delta, m_min, m_max, acc, a, w);
        }
        self.dot_acc_scalar(acc, a, w)
    }

    /// Scalar `dot_acc` (the lane kernel's reference semantics).
    pub fn dot_acc_scalar(&self, acc: LnsValue, a: &[LnsValue], w: &[LnsValue]) -> LnsValue {
        debug_assert_eq!(a.len(), w.len());
        let ap = &self.delta;
        let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
        let mut acc = acc;
        for (&av, &wv) in a.iter().zip(w.iter()) {
            // Either operand zero ⇒ the product is the exact zero word ⇒
            // acc ⊞ 0 = acc.
            if av.is_zero() || wv.is_zero() {
                continue;
            }
            let prod = LnsValue { m: (av.m + wv.m).clamp(m_min, m_max), s: !(av.s ^ wv.s) };
            acc = if acc.is_zero() { prod } else { add_nonzero(ap, m_min, m_max, acc, prod) };
        }
        acc
    }

    /// Element-wise slice accumulation `acc[j] = acc[j] ⊞ x[j]` with the
    /// same hoisting, lane dispatch, and bit-exactness contract (vs
    /// [`LnsSystem::add`]) as [`LnsSystem::mac_row`].
    pub fn add_slice(&self, acc: &mut [LnsValue], x: &[LnsValue]) {
        debug_assert_eq!(acc.len(), x.len());
        if crate::obs::counters_enabled() {
            return self.add_slice_counted(acc, x);
        }
        if super::lanes::enabled() {
            super::lanes::add_slice(&self.delta, self.cfg.m_min(), self.cfg.m_max(), acc, x);
        } else {
            self.add_slice_scalar(acc, x);
        }
    }

    /// Scalar `add_slice` (the lane kernel's reference semantics).
    pub fn add_slice_scalar(&self, acc: &mut [LnsValue], x: &[LnsValue]) {
        debug_assert_eq!(acc.len(), x.len());
        let ap = &self.delta;
        let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
        for (a, &y) in acc.iter_mut().zip(x.iter()) {
            let xv = *a;
            if xv.is_zero() {
                *a = y;
                continue;
            }
            if y.is_zero() {
                continue;
            }
            *a = add_nonzero(ap, m_min, m_max, xv, y);
        }
    }

    // ---------------------------------------------------------------
    // Counted slice-kernel twins (observability)
    // ---------------------------------------------------------------
    //
    // Verbatim copies of the `*_scalar` reference bodies accumulating a
    // stack-local `ObsTally`, flushed as one atomic batch per call. They
    // run only when `crate::obs::counters_enabled()` — the dispatchers
    // above route here *before* the lane switch, so (a) values are
    // unchanged (scalar ≡ lanes bit-for-bit, NUMERICS.md §2) and
    // (b) counter totals are independent of the lane switch.

    /// The Δ-dispatch counter for this system's MAC-path mode.
    fn mac_adds_counter(&self) -> &'static metrics::Counter {
        match self.cfg.delta {
            DeltaMode::Lut(_) => &metrics::DELTA_LUT_ADDS,
            DeltaMode::BitShift => &metrics::DELTA_SHIFT_ADDS,
            DeltaMode::Exact => &metrics::DELTA_EXACT_ADDS,
        }
    }

    fn mac_row_counted(&self, acc: &mut [LnsValue], a: LnsValue, w: &[LnsValue]) {
        let mut t = ObsTally::default();
        self.mac_row_tallied(acc, a, w, &mut t);
        t.flush_lns(self.mac_adds_counter());
    }

    /// [`LnsSystem::mac_row_scalar`] with event tallying (exercised
    /// directly by the counter-pin unit tests below).
    pub(crate) fn mac_row_tallied(
        &self,
        acc: &mut [LnsValue],
        a: LnsValue,
        w: &[LnsValue],
        t: &mut ObsTally,
    ) {
        debug_assert_eq!(acc.len(), w.len());
        if a.is_zero() {
            return;
        }
        let ap = &self.delta;
        let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
        let (a_m, a_s) = (a.m, a.s);
        for (acc_j, &wv) in acc.iter_mut().zip(w.iter()) {
            if wv.is_zero() {
                t.zero_skip += 1;
                continue; // acc ⊞ 0 = acc exactly
            }
            let pm = a_m + wv.m;
            let pmc = pm.clamp(m_min, m_max);
            if pmc != pm {
                t.mul_sat += 1;
            }
            let p = LnsValue { m: pmc, s: !(a_s ^ wv.s) };
            let x = *acc_j;
            *acc_j = if x.is_zero() { p } else { add_nonzero_counted(ap, m_min, m_max, x, p, t) };
        }
    }

    fn mac_panel_counted(&self, acc: &mut [LnsValue], a: &[LnsValue], panel: &[LnsValue]) {
        let mut t = ObsTally::default();
        self.mac_panel_tallied(acc, a, panel, &mut t);
        t.flush_lns(self.mac_adds_counter());
    }

    /// [`LnsSystem::mac_panel_scalar`] with event tallying.
    pub(crate) fn mac_panel_tallied(
        &self,
        acc: &mut [LnsValue],
        a: &[LnsValue],
        panel: &[LnsValue],
        t: &mut ObsTally,
    ) {
        let nc = acc.len();
        debug_assert_eq!(panel.len(), a.len() * nc);
        let ap = &self.delta;
        let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
        for (p, &av) in a.iter().enumerate() {
            if av.is_zero() {
                // The uncounted kernels skip the whole panel row in one
                // test; tally it as `nc` skipped products so totals match
                // the per-element definition used everywhere else.
                t.zero_skip += nc as u64;
                continue;
            }
            let (a_m, a_s) = (av.m, av.s);
            let wrow = &panel[p * nc..(p + 1) * nc];
            for (acc_j, &wv) in acc.iter_mut().zip(wrow.iter()) {
                if wv.is_zero() {
                    t.zero_skip += 1;
                    continue; // acc ⊞ 0 = acc exactly
                }
                let pm = a_m + wv.m;
                let pmc = pm.clamp(m_min, m_max);
                if pmc != pm {
                    t.mul_sat += 1;
                }
                let prod = LnsValue { m: pmc, s: !(a_s ^ wv.s) };
                let x = *acc_j;
                *acc_j = if x.is_zero() {
                    prod
                } else {
                    add_nonzero_counted(ap, m_min, m_max, x, prod, t)
                };
            }
        }
    }

    fn dot_acc_counted(&self, acc: LnsValue, a: &[LnsValue], w: &[LnsValue]) -> LnsValue {
        let mut t = ObsTally::default();
        let out = self.dot_acc_tallied(acc, a, w, &mut t);
        t.flush_lns(self.mac_adds_counter());
        out
    }

    /// [`LnsSystem::dot_acc_scalar`] with event tallying.
    pub(crate) fn dot_acc_tallied(
        &self,
        acc: LnsValue,
        a: &[LnsValue],
        w: &[LnsValue],
        t: &mut ObsTally,
    ) -> LnsValue {
        debug_assert_eq!(a.len(), w.len());
        let ap = &self.delta;
        let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
        let mut acc = acc;
        for (&av, &wv) in a.iter().zip(w.iter()) {
            if av.is_zero() || wv.is_zero() {
                t.zero_skip += 1;
                continue;
            }
            let pm = av.m + wv.m;
            let pmc = pm.clamp(m_min, m_max);
            if pmc != pm {
                t.mul_sat += 1;
            }
            let prod = LnsValue { m: pmc, s: !(av.s ^ wv.s) };
            acc = if acc.is_zero() {
                prod
            } else {
                add_nonzero_counted(ap, m_min, m_max, acc, prod, t)
            };
        }
        acc
    }

    fn add_slice_counted(&self, acc: &mut [LnsValue], x: &[LnsValue]) {
        let mut t = ObsTally::default();
        self.add_slice_tallied(acc, x, &mut t);
        t.flush_lns(self.mac_adds_counter());
    }

    /// [`LnsSystem::add_slice_scalar`] with event tallying.
    pub(crate) fn add_slice_tallied(&self, acc: &mut [LnsValue], x: &[LnsValue], t: &mut ObsTally) {
        debug_assert_eq!(acc.len(), x.len());
        let ap = &self.delta;
        let (m_min, m_max) = (self.cfg.m_min(), self.cfg.m_max());
        for (a, &y) in acc.iter_mut().zip(x.iter()) {
            let xv = *a;
            if xv.is_zero() {
                *a = y;
                continue;
            }
            if y.is_zero() {
                t.zero_skip += 1;
                continue;
            }
            *a = add_nonzero_counted(ap, m_min, m_max, xv, y, t);
        }
    }

    /// Log-domain exponentiation on a positive radix (Eq. 6):
    /// `w = x^y ↔ (y·X, 1)` where `y` is a small *linear-domain* integer.
    pub fn powi(&self, x: LnsValue, y: i32) -> LnsValue {
        if x.is_zero() {
            return if y == 0 { LnsValue::ONE } else { LnsValue::ZERO };
        }
        debug_assert!(x.s, "Eq. 6 requires a positive radix");
        LnsValue { m: self.sat(x.m as i64 * y as i64), s: true }
    }

    /// Magnitude comparison `|x| > |y|` (free in LNS: integer compare).
    #[inline]
    pub fn abs_gt(&self, x: LnsValue, y: LnsValue) -> bool {
        if x.is_zero() {
            false
        } else if y.is_zero() {
            true
        } else {
            x.m > y.m
        }
    }

    // ---------------------------------------------------------------
    // Soft-max support (paper Eq. 14)
    // ---------------------------------------------------------------

    /// The `2^f` conversion table.
    pub fn pow2_table(&self) -> &Pow2Table {
        &self.pow2
    }

    /// Convert an LNS activation `a` into the *log-magnitude field* of the
    /// pair `(a·log2 e, s_a)` used by the log-domain soft-max (Eq. 14a).
    ///
    /// Mathematically: `round(a · log2 e · 2^{q_f})`, saturated into the
    /// word's magnitude range. Implemented as one shift-and-LUT `2^x`
    /// evaluation: `|a|·log2 e·2^{q_f} = 2^{(m_a + u(log2 log2 e) + q_f·2^{q_f}) / 2^{q_f}}`.
    /// Logits outside the representable field saturate — the format's
    /// intrinsic logit clipping (DESIGN.md §5).
    pub fn softmax_logit_units(&self, a: LnsValue) -> i64 {
        if a.is_zero() {
            return 0;
        }
        let q = self.cfg.frac_bits as i64;
        let e_units = a.m as i64 + self.log2_log2e_units + (q << self.cfg.frac_bits);
        let mag = self.pow2.pow2(e_units).min(self.cfg.m_max() as i64);
        if a.s {
            mag
        } else {
            -mag
        }
    }

    /// Full log-domain soft-max with cross-entropy gradient init
    /// (Eq. 14a/14b): writes `δ_j = p_j ⊟ y_j` into `grad_out` and returns
    /// `(log2 p)` of the true class in real units (for loss reporting).
    ///
    /// All ⊞ reductions use the finer soft-max Δ approximator. The
    /// reduction order is fixed (ascending `j`) — the Pallas kernel
    /// mirrors it for bit-exactness.
    pub fn log_softmax_ce_grad(
        &self,
        logits: &[LnsValue],
        label: usize,
        grad_out: &mut [LnsValue],
    ) -> f64 {
        debug_assert_eq!(logits.len(), grad_out.len());
        debug_assert!(label < logits.len());
        // t_j = m-field of (a_j · log2 e); the pair (t_j, +) represents
        // e^{a_j} in linear domain.
        let mut lse = LnsValue::ZERO;
        let mut t = vec![0i64; logits.len()];
        for (j, &a) in logits.iter().enumerate() {
            let tj = self.softmax_logit_units(a);
            t[j] = tj;
            lse = self.add_with(&self.softmax_delta, lse, LnsValue::new(tj as i32, true));
        }
        // log2 p_j = t_j − lse (plain saturating fixed-point subtract).
        let lse_m = if lse.is_zero() { self.cfg.m_min() as i64 } else { lse.m as i64 };
        // numerics-lint: allow(float-leak) — the CE loss leaves the value path here as an f64 statistic (§4)
        let mut log2_p_label = 0.0;
        for j in 0..logits.len() {
            let m_p = self.sat(t[j] - lse_m);
            let p = LnsValue::new(m_p, true);
            if j == label {
                log2_p_label = self.cfg.from_units(m_p);
            }
            // δ = p ⊟ y, y ∈ {0, 1} one-hot (Eq. 14b).
            let y = if j == label { LnsValue::ONE } else { LnsValue::ZERO };
            grad_out[j] = self.add_with(&self.softmax_delta, p, y.neg());
        }
        log2_p_label
    }

    /// Signed comparison `x > y` without decoding.
    pub fn gt(&self, x: LnsValue, y: LnsValue) -> bool {
        match (x.is_zero(), y.is_zero()) {
            (true, true) => false,
            (true, false) => !y.s,
            (false, true) => x.s,
            (false, false) => match (x.s, y.s) {
                (true, false) => true,
                (false, true) => false,
                (true, true) => x.m > y.m,
                (false, false) => x.m < y.m,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lns::config::{DeltaMode, LutSpec};

    fn sys16() -> LnsSystem {
        LnsSystem::new(LnsConfig::w16_lut())
    }

    fn sys(delta: DeltaMode) -> LnsSystem {
        let mut cfg = LnsConfig::w16_lut();
        cfg.delta = delta;
        cfg.softmax_delta = delta;
        LnsSystem::new(cfg)
    }

    #[test]
    fn encode_decode_roundtrip_error_bounded() {
        let s = sys16();
        // Half-ulp in log2 domain → relative error ≤ 2^(0.5·2^-10) − 1.
        let tol = (0.5 / 1024f64).exp2() - 1.0 + 1e-9;
        for v in [1.0, -1.0, 3.25, -0.001, 123.456, 1e-3, -7.0, 15.9] {
            let dec = s.decode_f64(s.encode_f64(v));
            let rel = ((dec - v) / v).abs();
            assert!(rel <= tol, "v={v} dec={dec} rel={rel}");
        }
    }

    #[test]
    fn encode_zero_and_specials() {
        let s = sys16();
        assert!(s.encode_f64(0.0).is_zero());
        assert_eq!(s.decode_f64(LnsValue::ZERO), 0.0);
        assert_eq!(s.decode_f64(LnsValue::ONE), 1.0);
        // Overflow saturates to the largest magnitude, keeps sign.
        let big = s.encode_f64(1e30);
        assert_eq!(big.m, s.config().m_max());
        // Underflow saturates to the smallest nonzero magnitude.
        let tiny = s.encode_f64(1e-30);
        assert_eq!(tiny.m, s.config().m_min());
    }

    #[test]
    fn mul_is_exact_in_log_domain() {
        let s = sys16();
        // 2 * 4 = 8 exactly (all powers of two).
        let p = s.mul(s.encode_f64(2.0), s.encode_f64(4.0));
        assert_eq!(s.decode_f64(p), 8.0);
        // Sign rules.
        assert!(!s.mul(s.encode_f64(2.0), s.encode_f64(-4.0)).s);
        assert!(s.mul(s.encode_f64(-2.0), s.encode_f64(-4.0)).s);
        // Multiplication by zero annihilates.
        assert!(s.mul(s.encode_f64(5.0), LnsValue::ZERO).is_zero());
    }

    #[test]
    fn div_inverts_mul() {
        let s = sys16();
        let x = s.encode_f64(3.7);
        let y = s.encode_f64(-1.3);
        let q = s.div(s.mul(x, y), y);
        assert_eq!(q, x, "x*y/y must be bit-exact x (integer adds cancel)");
    }

    #[test]
    fn add_same_sign_close_to_real() {
        for mode in [DeltaMode::Lut(LutSpec::MAC20), DeltaMode::Exact] {
            let s = sys(mode);
            for (a, b) in [(3.0, 1.5), (0.1, 0.1), (10.0, 0.25), (-2.0, -6.0)] {
                let z = s.decode_f64(s.add(s.encode_f64(a), s.encode_f64(b)));
                let rel = ((z - (a + b)) / (a + b)).abs();
                // LUT bin width 1/2 in d → worst-case Δ error ≈ 0.15 in
                // log2 ⇒ ~11% relative; exact mode ≪ that.
                let tol = if mode == DeltaMode::Exact { 0.002 } else { 0.12 };
                assert!(rel < tol, "{a}+{b}: got {z} (mode {mode:?})");
            }
        }
    }

    #[test]
    fn add_opposite_sign_close_to_real() {
        let s = sys(DeltaMode::Exact);
        for (a, b) in [(3.0, -1.5), (-10.0, 4.0), (0.7, -0.1)] {
            let z = s.decode_f64(s.add(s.encode_f64(a), s.encode_f64(b)));
            let rel = ((z - (a + b)) / (a + b)).abs();
            assert!(rel < 0.01, "{a}+{b}: got {z}");
        }
    }

    #[test]
    fn add_exact_cancellation_is_zero() {
        let s = sys16();
        let x = s.encode_f64(2.75);
        assert!(s.add(x, x.neg()).is_zero());
        assert!(s.sub(x, x).is_zero());
    }

    #[test]
    fn add_zero_identity() {
        let s = sys16();
        let x = s.encode_f64(-0.4);
        assert_eq!(s.add(x, LnsValue::ZERO), x);
        assert_eq!(s.add(LnsValue::ZERO, x), x);
    }

    #[test]
    fn add_commutative() {
        // ⊞ is commutative by construction (max/|d| are symmetric; the
        // tie sign rule picks s_y, and at a tie both operands have equal
        // magnitude — same-sign ties give the shared sign, opposite-sign
        // ties give zero — so the result is symmetric).
        let s = sys16();
        for (a, b) in [(1.0, 2.0), (-3.0, 0.5), (4.0, -4.0), (-1.0, -9.0)] {
            let x = s.encode_f64(a);
            let y = s.encode_f64(b);
            assert_eq!(s.add(x, y), s.add(y, x), "a={a} b={b}");
        }
    }

    #[test]
    fn near_cancellation_saturates_small() {
        let s = sys16();
        // 1.0 ⊞ (−(1+ε)): d falls in the singular LUT bin → result is the
        // smallest magnitude (not zero, not garbage).
        let x = LnsValue::new(0, true);
        let y = LnsValue::new(1, false);
        let z = s.add(x, y);
        assert!(!z.is_zero());
        assert_eq!(z.m, s.config().m_min());
        assert!(!z.s, "sign of larger magnitude (y)");
    }

    #[test]
    fn powi_matches_repeated_mul() {
        let s = sys16();
        let x = s.encode_f64(1.7);
        let mut acc = LnsValue::ONE;
        for _ in 0..3 {
            acc = s.mul(acc, x);
        }
        assert_eq!(s.powi(x, 3), acc);
        assert_eq!(s.powi(x, 0), LnsValue::ONE);
    }

    #[test]
    fn gt_total_order_consistent_with_decode() {
        let s = sys16();
        let vals = [-5.0, -0.2, 0.0, 0.3, 7.0];
        for &a in &vals {
            for &b in &vals {
                let x = s.encode_f64(a);
                let y = s.encode_f64(b);
                assert_eq!(s.gt(x, y), a > b, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn saturation_on_mul_overflow() {
        let s = sys16();
        let big = s.encode_f64(1e4);
        let p = s.mul(big, big);
        assert_eq!(p.m, s.config().m_max());
        let tiny = s.encode_f64(1e-4);
        let q = s.mul(tiny, tiny);
        assert_eq!(q.m, s.config().m_min());
    }

    #[test]
    fn softmax_delta_is_finer() {
        let s = sys16();
        assert_eq!(s.delta().table_len(), 20);
        assert_eq!(s.softmax_delta().table_len(), 640);
    }

    #[test]
    fn softmax_logit_units_tracks_float() {
        let s = sys16();
        for a in [-4.0, -0.5, 0.0, 0.3, 2.0, 5.5] {
            let t = s.softmax_logit_units(s.encode_f64(a)) as f64;
            let want = a * std::f64::consts::LOG2_E * 1024.0;
            let tol = (want.abs() * 0.004).max(2.0);
            assert!((t - want).abs() <= tol, "a={a}: t={t} want={want}");
        }
    }

    #[test]
    fn softmax_probs_close_to_float() {
        let s = sys16();
        let logits_f = [1.0, -0.5, 0.25, 2.0];
        let logits: Vec<LnsValue> = logits_f.iter().map(|&v| s.encode_f64(v)).collect();
        let mut grad = vec![LnsValue::ZERO; 4];
        let label = 3usize;
        let log2_p = s.log_softmax_ce_grad(&logits, label, &mut grad);

        // Float reference.
        let exps: Vec<f64> = logits_f.iter().map(|&v| v.exp()).collect();
        let z: f64 = exps.iter().sum();
        let p: Vec<f64> = exps.iter().map(|&e| e / z).collect();
        assert!(
            (log2_p - p[label].log2()).abs() < 0.05,
            "log2 p: {log2_p} vs {}",
            p[label].log2()
        );
        for j in 0..4 {
            let want = p[j] - if j == label { 1.0 } else { 0.0 };
            let got = s.decode_f64(grad[j]);
            assert!((got - want).abs() < 0.03, "δ[{j}]: {got} vs {want}");
        }
    }

    #[test]
    fn softmax_grad_sums_near_zero() {
        // Σ_j δ_j = Σ p − 1 ≈ 0: a good end-to-end consistency probe of
        // the approximate pipeline.
        let s = sys16();
        let logits: Vec<LnsValue> =
            [-1.0, 0.0, 1.0, 0.5, -2.0].iter().map(|&v| s.encode_f64(v)).collect();
        let mut grad = vec![LnsValue::ZERO; 5];
        s.log_softmax_ce_grad(&logits, 2, &mut grad);
        let total: f64 = grad.iter().map(|&g| s.decode_f64(g)).sum();
        assert!(total.abs() < 0.05, "Σδ = {total}");
    }

    /// Random valid word (including the exact-zero sentinel) for the
    /// vectorized-kernel equivalence probes.
    fn arb(rng: &mut crate::rng::SplitMix64, s: &LnsSystem) -> LnsValue {
        if rng.next_f64() < 0.15 {
            return LnsValue::ZERO;
        }
        let span = (s.config().m_max() as i64 - s.config().m_min() as i64 + 1) as u64;
        LnsValue::new(
            (s.config().m_min() as i64 + rng.next_below(span) as i64) as i32,
            rng.next_below(2) == 1,
        )
    }

    #[test]
    fn mac_row_bitexact_vs_scalar_mac() {
        for (tag, cfg) in [
            ("w16_lut", LnsConfig::w16_lut()),
            ("w12_lut", LnsConfig::w12_lut()),
            ("w16_bs", {
                let mut c = LnsConfig::w16_lut();
                c.delta = DeltaMode::BitShift;
                c
            }),
            ("w16_exact", {
                let mut c = LnsConfig::w16_lut();
                c.delta = DeltaMode::Exact;
                c
            }),
        ] {
            let s = LnsSystem::new(cfg);
            let mut rng = crate::rng::SplitMix64::new(0xACC0 ^ tag.len() as u64);
            for case in 0..200 {
                let n = 1 + rng.next_below(48) as usize;
                let a = arb(&mut rng, &s);
                let acc: Vec<LnsValue> = (0..n).map(|_| arb(&mut rng, &s)).collect();
                let w: Vec<LnsValue> = (0..n).map(|_| arb(&mut rng, &s)).collect();
                let mut fast = acc.clone();
                s.mac_row(&mut fast, a, &w);
                let slow: Vec<LnsValue> =
                    acc.iter().zip(&w).map(|(&o, &wv)| s.mac(o, a, wv)).collect();
                assert_eq!(fast, slow, "{tag} case {case}: mac_row diverged from mac");
            }
        }
    }

    #[test]
    fn mac_panel_bitexact_vs_mac_row_fold() {
        for (tag, cfg) in [
            ("w16_lut", LnsConfig::w16_lut()),
            ("w12_bs", LnsConfig::w12_bitshift()),
            ("w16_exact", {
                let mut c = LnsConfig::w16_lut();
                c.delta = DeltaMode::Exact;
                c
            }),
        ] {
            let s = LnsSystem::new(cfg);
            let mut rng = crate::rng::SplitMix64::new(0xFA9E1 ^ tag.len() as u64);
            for case in 0..120 {
                let nc = 1 + rng.next_below(17) as usize;
                let depth = 1 + rng.next_below(9) as usize;
                let a: Vec<LnsValue> = (0..depth).map(|_| arb(&mut rng, &s)).collect();
                let acc: Vec<LnsValue> = (0..nc).map(|_| arb(&mut rng, &s)).collect();
                let panel: Vec<LnsValue> = (0..depth * nc).map(|_| arb(&mut rng, &s)).collect();
                let mut fast = acc.clone();
                s.mac_panel(&mut fast, &a, &panel);
                let mut slow = acc;
                for (p, &av) in a.iter().enumerate() {
                    s.mac_row(&mut slow, av, &panel[p * nc..(p + 1) * nc]);
                }
                assert_eq!(fast, slow, "{tag} case {case}: mac_panel diverged");
            }
        }
    }

    #[test]
    fn dot_acc_bitexact_vs_scalar_mac_fold() {
        for cfg in [LnsConfig::w16_lut(), LnsConfig::w12_bitshift()] {
            let s = LnsSystem::new(cfg);
            let mut rng = crate::rng::SplitMix64::new(0xD07 ^ cfg.total_bits as u64);
            for case in 0..200 {
                let n = 1 + rng.next_below(48) as usize;
                let acc0 = arb(&mut rng, &s);
                let a: Vec<LnsValue> = (0..n).map(|_| arb(&mut rng, &s)).collect();
                let w: Vec<LnsValue> = (0..n).map(|_| arb(&mut rng, &s)).collect();
                let fast = s.dot_acc(acc0, &a, &w);
                let mut slow = acc0;
                for (&av, &wv) in a.iter().zip(w.iter()) {
                    slow = s.mac(slow, av, wv);
                }
                assert_eq!(fast, slow, "case {case}: dot_acc diverged from mac fold");
            }
        }
    }

    #[test]
    fn add_slice_bitexact_vs_scalar_add() {
        for cfg in [LnsConfig::w16_lut(), LnsConfig::w12_bitshift()] {
            let s = LnsSystem::new(cfg);
            let mut rng = crate::rng::SplitMix64::new(0xADD5 ^ cfg.total_bits as u64);
            for case in 0..200 {
                let n = 1 + rng.next_below(48) as usize;
                let acc: Vec<LnsValue> = (0..n).map(|_| arb(&mut rng, &s)).collect();
                let x: Vec<LnsValue> = (0..n).map(|_| arb(&mut rng, &s)).collect();
                let mut fast = acc.clone();
                s.add_slice(&mut fast, &x);
                let slow: Vec<LnsValue> =
                    acc.iter().zip(&x).map(|(&o, &v)| s.add(o, v)).collect();
                assert_eq!(fast, slow, "case {case}: add_slice diverged from add");
            }
        }
    }

    #[test]
    fn tallied_kernels_bitexact_vs_scalar_twins() {
        // The counted bodies must be value-for-value identical to the
        // scalar references on random operand sets (the observation must
        // be read-only). Exercised directly — no global obs flags — so
        // this cannot race other tests.
        use crate::obs::metrics::ObsTally;
        for cfg in [LnsConfig::w16_lut(), LnsConfig::w12_bitshift()] {
            let s = LnsSystem::new(cfg);
            let mut rng = crate::rng::SplitMix64::new(0x0B5 ^ cfg.total_bits as u64);
            for _ in 0..120 {
                let n = 1 + rng.next_below(40) as usize;
                let a = arb(&mut rng, &s);
                let acc: Vec<LnsValue> = (0..n).map(|_| arb(&mut rng, &s)).collect();
                let w: Vec<LnsValue> = (0..n).map(|_| arb(&mut rng, &s)).collect();
                let mut t = ObsTally::default();

                let mut counted = acc.clone();
                s.mac_row_tallied(&mut counted, a, &w, &mut t);
                let mut scalar = acc.clone();
                s.mac_row_scalar(&mut scalar, a, &w);
                assert_eq!(counted, scalar, "mac_row_tallied diverged");

                let acc0 = arb(&mut rng, &s);
                assert_eq!(
                    s.dot_acc_tallied(acc0, &acc, &w, &mut t),
                    s.dot_acc_scalar(acc0, &acc, &w),
                    "dot_acc_tallied diverged"
                );

                let mut counted = acc.clone();
                s.add_slice_tallied(&mut counted, &w, &mut t);
                let mut scalar = acc.clone();
                s.add_slice_scalar(&mut scalar, &w);
                assert_eq!(counted, scalar, "add_slice_tallied diverged");

                let nc = 1 + rng.next_below(9) as usize;
                let depth = 1 + rng.next_below(5) as usize;
                let av: Vec<LnsValue> = (0..depth).map(|_| arb(&mut rng, &s)).collect();
                let panel: Vec<LnsValue> = (0..depth * nc).map(|_| arb(&mut rng, &s)).collect();
                let mut counted: Vec<LnsValue> = acc.iter().copied().take(nc).collect();
                let mut scalar = counted.clone();
                while counted.len() < nc {
                    counted.push(LnsValue::ZERO);
                    scalar.push(LnsValue::ZERO);
                }
                s.mac_panel_tallied(&mut counted, &av, &panel, &mut t);
                s.mac_panel_scalar(&mut scalar, &av, &panel);
                assert_eq!(counted, scalar, "mac_panel_tallied diverged");
            }
        }
    }

    #[test]
    fn tally_pins_on_hand_counted_operands() {
        use crate::obs::metrics::ObsTally;
        let s = sys16();
        let hi = s.config().m_max();
        let pos_max = LnsValue::new(hi, true);
        let one = LnsValue::ONE; // m = 0
        let x = s.encode_f64(2.75);

        // Exact cancellation: one ⊞ fold, one cancel, no clamps.
        let mut t = ObsTally::default();
        let mut acc = vec![x];
        s.add_slice_tallied(&mut acc, &[x.neg()], &mut t);
        assert!(acc[0].is_zero());
        assert_eq!(t, ObsTally { adds: 1, cancel: 1, ..Default::default() });

        // Top-of-range same-sign add: Δ+ pushes past m_max → clamp_hi.
        let mut t = ObsTally::default();
        let mut acc = vec![pos_max];
        s.add_slice_tallied(&mut acc, &[pos_max], &mut t);
        assert_eq!(acc[0].m, hi);
        assert_eq!(t, ObsTally { adds: 1, clamp_hi: 1, ..Default::default() });

        // mac_row over [1, 0, max]: one zero skip, one product
        // saturation (max ⊡ max), two non-zero ⊞ folds onto acc = 1.
        let mut t = ObsTally::default();
        let mut acc = vec![one, one, one];
        s.mac_row_tallied(&mut acc, pos_max, &[one, LnsValue::ZERO, pos_max], &mut t);
        assert_eq!(t.zero_skip, 1);
        assert_eq!(t.mul_sat, 1);
        assert_eq!(t.adds, 2);

        // dot_acc zero skips count either-operand-zero pairs.
        let mut t = ObsTally::default();
        let out = s.dot_acc_tallied(
            LnsValue::ZERO,
            &[x, LnsValue::ZERO, x],
            &[LnsValue::ZERO, x, x],
            &mut t,
        );
        assert!(!out.is_zero());
        assert_eq!(t.zero_skip, 2);
        assert_eq!(t.adds, 0, "first non-zero product lands in a zero acc");
    }

    #[test]
    fn softmax_extreme_logits_saturate_gracefully() {
        let s = sys16();
        let logits: Vec<LnsValue> =
            [30.0, -30.0, 0.0].iter().map(|&v| s.encode_f64(v)).collect();
        let mut grad = vec![LnsValue::ZERO; 3];
        s.log_softmax_ce_grad(&logits, 0, &mut grad);
        // True class dominates: δ_0 ≈ 0, δ_1 ≈ 0, δ_2 ≈ 0 after clipping.
        for (j, g) in grad.iter().enumerate() {
            assert!(s.decode_f64(*g).abs() < 0.2, "δ[{j}] = {:?}", g);
        }
    }
}
