//! LNS → linear fixed-point conversion via a `2^f` look-up table.
//!
//! The log-domain soft-max (paper Eq. 14a) forms pairs whose *log-magnitude
//! field* is the linear value `a·log2 e` of an LNS-encoded activation `a`.
//! Producing that field requires one LNS→linear conversion: `±2^{E/2^{q_f}}`
//! for a fixed-point exponent `E`. In hardware this is a shift plus a
//! fractional `2^f` LUT (`f ∈ [0,1)`), exactly analogous to the Δ tables —
//! we implement precisely that, in pure integer arithmetic, so the Rust
//! engine and the Pallas kernels stay bit-exact.

use super::config::LnsConfig;

/// Fractional `2^f` table: `T[i] = round(2^{i/2^k} · 2^{q_f})` for
/// `i ∈ [0, 2^k)`.
#[derive(Clone, Debug)]
pub struct Pow2Table {
    /// log2 of the table length.
    k: u32,
    /// Word fractional bits (also the entry scale).
    frac_bits: u32,
    entries: Vec<i64>,
}

impl Pow2Table {
    /// Build for a word format. The table resolution is
    /// `k = min(q_f, 10)` bits — at q_f ≤ 10 the table is exact to the
    /// word's own resolution; beyond that 1024 entries keep the entry
    /// error below half an output ulp for the ranges the soft-max needs.
    pub fn new(cfg: &LnsConfig) -> Self {
        let k = cfg.frac_bits.min(10);
        let n = 1usize << k;
        let scale = (1i64 << cfg.frac_bits) as f64;
        let entries = (0..n)
            .map(|i| {
                let f = i as f64 / n as f64;
                (f.exp2() * scale + 0.5).floor() as i64
            })
            .collect();
        Pow2Table { k, frac_bits: cfg.frac_bits, entries }
    }

    /// Table length.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the table holds no entries (never, in practice).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Raw entries (artifact export).
    pub fn entries(&self) -> &[i64] {
        &self.entries
    }

    /// `round(2^{e_units / 2^{q_f}})` as a plain integer, computed with a
    /// shift and one table load. Returns a saturated `i64` (callers clamp
    /// to their word). `e_units` is a fixed-point exponent in `2^{-q_f}`
    /// units.
    pub fn pow2(&self, e_units: i64) -> i64 {
        let q = self.frac_bits;
        // Arithmetic floor-division split: E = I·2^q + F, F ∈ [0, 2^q).
        let i_part = e_units >> q;
        let f_part = e_units - (i_part << q);
        debug_assert!((0..(1i64 << q)).contains(&f_part));
        let entry = self.entries[(f_part >> (q - self.k)) as usize]; // ≈ 2^{q+f}
        // T = entry · 2^{I−q}, rounded.
        let shift = i_part - q as i64;
        if shift >= 0 {
            if shift >= 62 - q as i64 {
                i64::MAX / 2 // saturate far above any word's m_max
            } else {
                entry << shift
            }
        } else {
            let s = -shift;
            if s >= 63 {
                0
            } else {
                // round-half-up on the discarded bits
                (entry + (1i64 << (s - 1))) >> s
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg16() -> LnsConfig {
        LnsConfig::w16_lut()
    }

    #[test]
    fn table_shape() {
        let t = Pow2Table::new(&cfg16());
        assert_eq!(t.len(), 1024);
        assert_eq!(t.entries()[0], 1024); // 2^0 · 2^10
        // Last entry ≈ 2^(1023/1024) · 1024 < 2048.
        assert!(*t.entries().last().unwrap() < 2048);
    }

    #[test]
    fn pow2_exact_on_integers() {
        let t = Pow2Table::new(&cfg16());
        let q = 10u32;
        for e in 0..12i64 {
            assert_eq!(t.pow2(e << q), 1i64 << e, "2^{e}");
        }
        // Negative exponents round to nearest.
        assert_eq!(t.pow2(-1i64 << q), 1); // 2^-1 = 0.5 → rounds to 1 (half-up)
        assert_eq!(t.pow2(-2i64 << q), 0); // 2^-2 = 0.25 → 0
    }

    #[test]
    fn pow2_tracks_float_within_ulp() {
        let t = Pow2Table::new(&cfg16());
        let q = 10u32;
        for e_units in (-(8i64 << q)..(14i64 << q)).step_by(137) {
            let want = (e_units as f64 / (1i64 << q) as f64).exp2();
            let got = t.pow2(e_units) as f64;
            let tol = want * 0.002 + 0.51; // table quantization ~2^-10 + rounding
            assert!((got - want).abs() <= tol, "e={e_units}: got {got}, want {want}");
        }
    }

    #[test]
    fn pow2_monotone() {
        let t = Pow2Table::new(&cfg16());
        let mut prev = t.pow2(-(4i64 << 10));
        for e in (-(4i64 << 10) + 1)..(14i64 << 10) {
            let cur = t.pow2(e);
            assert!(cur >= prev, "pow2 not monotone at {e}");
            prev = cur;
        }
    }

    #[test]
    fn pow2_saturates_not_overflows() {
        let t = Pow2Table::new(&cfg16());
        assert!(t.pow2(i64::MAX / 2) > 0);
        assert_eq!(t.pow2(-(1i64 << 40)), 0);
    }

    #[test]
    fn coarse_word_uses_small_table() {
        let t = Pow2Table::new(&LnsConfig::w12_lut()); // q_f = 6
        assert_eq!(t.len(), 64);
        assert_eq!(t.pow2(3 << 6), 8);
    }

    /// A word with q_f > 10 must clamp the table to 1024 entries (k = 10)
    /// while still indexing and shifting correctly against the wider
    /// fractional field.
    #[test]
    fn fine_word_clamps_table_resolution() {
        use crate::lns::config::{DeltaMode, LutSpec};
        let cfg = LnsConfig {
            total_bits: 20,
            frac_bits: 12,
            delta: DeltaMode::Lut(LutSpec::MAC20),
            softmax_delta: DeltaMode::Lut(LutSpec::SOFTMAX640),
        };
        let t = Pow2Table::new(&cfg);
        assert_eq!(t.len(), 1024, "k = min(q_f, 10) caps the table");
        assert_eq!(t.entries()[0], 4096, "entries scale by 2^q_f, not 2^k");
        // Integer exponents stay exact through the k < q_f indexing path.
        let q = 12u32;
        for e in 0..10i64 {
            assert_eq!(t.pow2(e << q), 1i64 << e, "2^{e} at q_f=12");
        }
        // Fractional exponents track float within the 2^-10 table grid.
        for e_units in (-(6i64 << q)..(10i64 << q)).step_by(389) {
            let want = (e_units as f64 / (1i64 << q) as f64).exp2();
            let got = t.pow2(e_units) as f64;
            let tol = want * 0.002 + 0.51;
            assert!((got - want).abs() <= tol, "q12 e={e_units}: got {got}, want {want}");
        }
    }

    /// The floor-division split must place boundary fractional parts in
    /// the first/last table bins, not wrap or off-by-one them.
    #[test]
    fn boundary_fractional_indices() {
        let t = Pow2Table::new(&cfg16());
        let q = 10i64;
        // f = 0 exactly (first entry) on both sides of zero.
        assert_eq!(t.pow2(0), 1);
        assert_eq!(t.pow2(1 << q), 2);
        // f = 2^q − 1 (last entry): 2^(1023/1024) ≈ 1.99932 rounds to 2,
        // and one unit below an integer exponent stays monotone with it.
        assert_eq!(t.pow2((1 << q) - 1), 2);
        assert!(t.pow2((4 << q) - 1) <= t.pow2(4 << q));
        assert_eq!(t.pow2((4 << q) - 1), 16, "2^(4 − 1/1024) ≈ 15.99 rounds to 16");
    }

    /// Negative exponents exercise the arithmetic-shift split: `i_part`
    /// floors (not truncates) and `f_part` stays in [0, 2^q).
    #[test]
    fn negative_exponent_floor_split() {
        let t = Pow2Table::new(&cfg16());
        let q = 10i64;
        // Just below zero: E = −1 → I = −1, F = 1023 → ≈ 2^(−1/1024) ≈
        // 0.99932 → rounds to 1 (not 0, which truncation toward zero
        // would produce via I = 0, F = −1 indexing garbage).
        assert_eq!(t.pow2(-1), 1);
        // Deeply negative integer exponents halve cleanly until the
        // round-to-nearest floor: 2^-1 → 1 (half-up), 2^-2 → 0.
        assert_eq!(t.pow2(-(1 << q)), 1);
        assert_eq!(t.pow2(-(2 << q)), 0);
        // Monotone through the negative range (no seam at unit steps).
        let mut prev = t.pow2(-(6 << q));
        for e in (-(6 << q) + 1)..=0 {
            let cur = t.pow2(e);
            assert!(cur >= prev, "negative-range monotonicity broke at {e}");
            prev = cur;
        }
    }
}
