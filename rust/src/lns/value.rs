//! The LNS word: a `(log-magnitude, sign)` pair.

/// Sentinel log-magnitude encoding exact zero (`log2 0 = −∞`).
///
/// The most negative `i32` is never produced by clamped arithmetic (word
/// formats clamp to `±(2^{W−2}−1)`), so it is safe as an in-band sentinel;
/// real hardware would reserve the most negative code of the word.
pub const ZERO_M: i32 = i32::MIN;

/// A fixed-point LNS value `v ↔ (m, s)` (paper Eq. 1):
/// `m = log2|v|` in units of `2^{-q_f}`, `s = sign(v)` with the paper's
/// convention `s = 1 ⇔ v > 0` (represented as `true`).
///
/// `LnsValue` is a plain data carrier; all arithmetic lives on
/// [`super::LnsSystem`], which knows the word format and Δ approximations.
#[derive(Copy, Clone, PartialEq, Eq, Hash)]
pub struct LnsValue {
    /// Log-magnitude in fixed-point units, or [`ZERO_M`] for exact zero.
    pub m: i32,
    /// Linear-domain sign: `true ⇔ v > 0`. Meaningless when `m == ZERO_M`.
    pub s: bool,
}

impl LnsValue {
    /// The exact-zero word.
    pub const ZERO: LnsValue = LnsValue { m: ZERO_M, s: true };
    /// The exact-one word (`log2 1 = 0`, positive).
    pub const ONE: LnsValue = LnsValue { m: 0, s: true };

    /// Construct from raw parts.
    #[inline]
    pub fn new(m: i32, s: bool) -> Self {
        LnsValue { m, s }
    }

    /// Is this the exact-zero word?
    #[inline]
    pub fn is_zero(&self) -> bool {
        self.m == ZERO_M
    }

    /// Same magnitude, flipped linear sign (linear negation — exact in LNS).
    #[inline]
    pub fn neg(self) -> Self {
        if self.is_zero() {
            self
        } else {
            LnsValue { m: self.m, s: !self.s }
        }
    }

    /// Same magnitude, positive sign (absolute value — exact in LNS).
    #[inline]
    pub fn abs(self) -> Self {
        LnsValue { m: self.m, s: true }
    }
}

impl std::fmt::Debug for LnsValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_zero() {
            write!(f, "LNS(0)")
        } else {
            write!(f, "LNS(m={}, {})", self.m, if self.s { '+' } else { '-' })
        }
    }
}

impl Default for LnsValue {
    fn default() -> Self {
        LnsValue::ZERO
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_properties() {
        assert!(LnsValue::ZERO.is_zero());
        assert!(!LnsValue::ONE.is_zero());
        assert_eq!(LnsValue::ZERO.neg(), LnsValue::ZERO);
    }

    #[test]
    fn neg_involution() {
        let v = LnsValue::new(123, true);
        assert_eq!(v.neg().neg(), v);
        assert_eq!(v.neg().m, v.m);
        assert!(!v.neg().s);
    }

    #[test]
    fn abs_positive() {
        assert!(LnsValue::new(5, false).abs().s);
        assert_eq!(LnsValue::new(5, false).abs().m, 5);
    }
}
