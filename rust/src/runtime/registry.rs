//! Artifact registry: discovery + metadata for the AOT bundle.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.tsv` — one row per
//! artifact: `name, file, kind, bits, delta, dims, batch` (tab-separated;
//! a deliberately dependency-free format). The registry parses it and
//! lazily loads/compiles executables on first use.

use super::{ArtifactExecutable, Runtime};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Metadata for one artifact row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Registry key, e.g. `lns_fwd_w16_lut`.
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Kind tag: `fwd`, `train_step`, `float_fwd`, …
    pub kind: String,
    /// Word width (0 for float artifacts).
    pub bits: u32,
    /// Delta mode tag (`lut`, `bs`, `-` for float).
    pub delta: String,
    /// Model layer dims, e.g. `784x100x10`.
    pub dims: Vec<usize>,
    /// Compiled batch size.
    pub batch: usize,
}

/// Registry over an artifact directory.
pub struct ArtifactRegistry {
    dir: PathBuf,
    metas: HashMap<String, ArtifactMeta>,
    loaded: HashMap<String, ArtifactExecutable>,
}

impl ArtifactRegistry {
    /// Parse `manifest.tsv` under `dir`.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest.display()))?;
        let mut metas = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let meta = Self::parse_row(line)
                .with_context(|| format!("manifest.tsv line {}", lineno + 1))?;
            metas.insert(meta.name.clone(), meta);
        }
        if metas.is_empty() {
            bail!("manifest.tsv has no artifact rows");
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), metas, loaded: HashMap::new() })
    }

    fn parse_row(line: &str) -> Result<ArtifactMeta> {
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() != 7 {
            bail!("expected 7 tab-separated fields, got {}", f.len());
        }
        let dims = f[5]
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactMeta {
            name: f[0].to_string(),
            file: f[1].to_string(),
            kind: f[2].to_string(),
            bits: f[3].parse().context("bad bits")?,
            delta: f[4].to_string(),
            dims,
            batch: f[6].parse().context("bad batch")?,
        })
    }

    /// All known artifact names (sorted).
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.metas.keys().cloned().collect();
        v.sort();
        v
    }

    /// Metadata lookup.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    /// Load (compile) an artifact by name, caching the executable.
    pub fn load(&mut self, rt: &Runtime, name: &str) -> Result<&ArtifactExecutable> {
        if !self.loaded.contains_key(name) {
            let meta = self
                .metas
                .get(name)
                .with_context(|| format!("unknown artifact '{name}'"))?;
            let exe = rt.load_hlo_text(&self.dir.join(&meta.file))?;
            self.loaded.insert(name.to_string(), exe);
        }
        Ok(&self.loaded[name])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_row_roundtrip() {
        let m = ArtifactRegistry::parse_row(
            "lns_fwd_w16_lut\tlns_fwd_w16_lut.hlo.txt\tfwd\t16\tlut\t784x100x10\t5",
        )
        .unwrap();
        assert_eq!(m.name, "lns_fwd_w16_lut");
        assert_eq!(m.dims, vec![784, 100, 10]);
        assert_eq!(m.batch, 5);
        assert_eq!(m.bits, 16);
    }

    #[test]
    fn parse_rejects_short_rows() {
        assert!(ArtifactRegistry::parse_row("a\tb\tc").is_err());
    }

    #[test]
    fn open_missing_dir_fails_helpfully() {
        let err = match ArtifactRegistry::open(Path::new("/definitely/not/here")) {
            Err(e) => e,
            Ok(_) => panic!("open should fail on a missing directory"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn open_parses_manifest_file() {
        let dir = std::env::temp_dir().join(format!("lnsdnn-reg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# comment\nfoo\tfoo.hlo.txt\tfwd\t16\tlut\t4x3x2\t1\n",
        )
        .unwrap();
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.names(), vec!["foo"]);
        assert_eq!(reg.meta("foo").unwrap().dims, vec![4, 3, 2]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
