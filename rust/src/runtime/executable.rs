//! A compiled AOT artifact and typed input/output plumbing.

use anyhow::{Context, Result};
use std::path::Path;

/// One compiled HLO artifact, executable on the PJRT CPU client.
pub struct ArtifactExecutable {
    exe: xla::PjRtLoadedExecutable,
    path: std::path::PathBuf,
}

impl ArtifactExecutable {
    /// Parse HLO text, compile on the client.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(ArtifactExecutable { exe, path: path.to_path_buf() })
    }

    /// Artifact path (diagnostics).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Execute with literal inputs; returns the flattened tuple outputs.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the raw
    /// result is a one-element device list holding a tuple literal that we
    /// decompose here.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.path.display()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("device→host transfer")?;
        Ok(lit.to_tuple()?)
    }

    /// Convenience: build an `i32` literal of the given shape.
    pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// Convenience: build an `f32` literal of the given shape.
    pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }
}

#[cfg(test)]
mod tests {
    // Executable-level integration tests live in `rust/tests/pjrt_roundtrip.rs`
    // (they need artifacts on disk); here we only test the literal helpers.
    use super::*;

    #[test]
    fn literal_builders_shape_correctly() {
        let l = ArtifactExecutable::lit_i32(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        assert_eq!(l.element_count(), 6);
        let back: Vec<i32> = l.to_vec().unwrap();
        assert_eq!(back, vec![1, 2, 3, 4, 5, 6]);
        let f = ArtifactExecutable::lit_f32(&[0.5, 1.5], &[2]).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![0.5, 1.5]);
    }
}
