//! PJRT runtime: load AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them from Rust.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto` — jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `/opt/xla-example/README.md`). Python never
//! runs at request time: `make artifacts` is the only compile step.

mod executable;
mod registry;

pub use executable::ArtifactExecutable;
pub use registry::{ArtifactMeta, ArtifactRegistry};

use anyhow::Result;

/// Shared PJRT CPU client. One per process; executables borrow it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Bring up the PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Device count.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &std::path::Path) -> Result<ArtifactExecutable> {
        ArtifactExecutable::load(&self.client, path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform().is_empty());
    }
}
