//! PJRT runtime: load AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them from Rust.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto` — jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `/opt/xla-example/README.md`). Python never
//! runs at request time: `make artifacts` is the only compile step.
//!
//! # The `pjrt` feature
//!
//! The `xla` bindings exist only on hosts with an XLA extension install,
//! so everything PJRT-backed sits behind the `pjrt` cargo feature (see
//! `rust/Cargo.toml` for how to supply the dependency). Without the
//! feature this module compiles to an API-compatible stub: the artifact
//! *registry* (manifest parsing, metadata) keeps working, while
//! [`Runtime::cpu`] returns a descriptive error — so the CLI, tests and
//! benches of the native engine stay hermetic.

mod registry;

pub use registry::{ArtifactMeta, ArtifactRegistry};

#[cfg(feature = "pjrt")]
mod executable;
#[cfg(feature = "pjrt")]
pub use executable::ArtifactExecutable;

#[cfg(feature = "pjrt")]
use anyhow::Result;

/// Shared PJRT CPU client. One per process; executables borrow it.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Bring up the PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Device count.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &std::path::Path) -> Result<ArtifactExecutable> {
        ArtifactExecutable::load(&self.client, path)
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use anyhow::{bail, Result};
    use std::path::Path;

    const HINT: &str = "PJRT support is not compiled in: provide the `xla` \
         bindings and rebuild with `--features pjrt` (see rust/Cargo.toml)";

    /// Stub PJRT runtime: every constructor explains how to enable the
    /// real one. Keeps the registry/CLI compiling on hermetic hosts.
    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        /// Always fails in stub builds.
        pub fn cpu() -> Result<Self> {
            bail!(HINT)
        }

        /// Platform tag (unreachable in practice: [`Runtime::cpu`] never
        /// constructs a stub instance).
        pub fn platform(&self) -> String {
            "pjrt-disabled".into()
        }

        /// Device count (unreachable, as above).
        pub fn device_count(&self) -> usize {
            0
        }

        /// Always fails in stub builds.
        pub fn load_hlo_text(&self, _path: &Path) -> Result<ArtifactExecutable> {
            bail!(HINT)
        }
    }

    /// Stub compiled-artifact handle; never constructed in stub builds.
    pub struct ArtifactExecutable {
        path: std::path::PathBuf,
    }

    impl ArtifactExecutable {
        /// Artifact path (diagnostics).
        pub fn path(&self) -> &Path {
            &self.path
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{ArtifactExecutable, Runtime};

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    /// With real bindings the CPU client comes up; with the in-repo
    /// compile-smoke shim (the default `xla` dependency, see
    /// rust/Cargo.toml) construction fails with the swap-in hint
    /// instead. Both are the correct behaviour for their configuration —
    /// anything else (a silent success on the shim, an unrelated error
    /// on real bindings) is a bug.
    #[test]
    fn cpu_client_comes_up_or_names_the_shim() {
        match Runtime::cpu() {
            Ok(rt) => {
                assert!(rt.device_count() >= 1);
                assert!(!rt.platform().is_empty());
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("xla shim"), "unexpected PJRT init failure: {msg}");
            }
        }
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_disabled_feature() {
        let err = match Runtime::cpu() {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("stub Runtime::cpu must fail"),
        };
        assert!(err.contains("pjrt"), "unhelpful stub error: {err}");
    }
}
