//! Neural-network substrate: dense MLPs, the conv/pool subsystem and its
//! LeNet-style CNN, weight init, SGD — all generic over the arithmetic
//! [`Backend`](crate::tensor::Backend) so the same model definition
//! trains in float, linear fixed point, or LNS.

pub mod conv;
pub mod grad;
pub mod init;
pub mod mlp;
pub mod sgd;

pub use conv::{Cnn, CnnArch, CnnCache, CnnVariant, Conv2d, Pool2d, PoolKind};
pub use grad::{GradStore, RawStepStats};
pub use init::{he_normal_init, log_domain_init, InitScheme};
pub use mlp::{Dense, Gradients, Mlp, StepStats};
pub use sgd::{quantize_cnn, quantize_mlp, SgdConfig};
