//! Neural-network substrate: dense MLPs, weight init, SGD — all generic
//! over the arithmetic [`Backend`](crate::tensor::Backend) so the same
//! model definition trains in float, linear fixed point, or LNS.

pub mod init;
pub mod mlp;
pub mod sgd;

pub use init::{he_normal_init, log_domain_init, InitScheme};
pub use mlp::{Gradients, Mlp, StepStats};
pub use sgd::SgdConfig;
