//! Stochastic gradient descent with weight decay, in the backend domain.
//!
//! The update is carried out entirely with backend ops (paper §4):
//! `g' = g ⊞ (λ ⊡ w)` then `w ← w ⊟ (η ⊡ g')` — in LNS both scalings are
//! single fixed-point adds to the magnitude, so the optimizer is
//! multiplier-free too.

use super::conv::Cnn;
use super::mlp::{Gradients, Mlp};
use crate::obs::{span, SpanKind};
use crate::precision::{PrecisionMap, WordSpec};
use crate::tensor::{Backend, Tensor};

/// SGD hyper-parameters (paper §5: lr = 0.01, mini-batch 5, per-dataset
/// weight decay).
#[derive(Copy, Clone, Debug)]
pub struct SgdConfig {
    /// Learning rate η.
    pub lr: f64,
    /// L2 weight-decay coefficient λ (applied to weights, not biases —
    /// standard practice).
    pub weight_decay: f64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { lr: 0.01, weight_decay: 0.0 }
    }
}

impl SgdConfig {
    /// The single-layer update shared by every model: `w ← w ⊟ η(g ⊞ λw)`
    /// for weights, `b ← b ⊟ ηg` for biases (no decay on biases).
    fn update_layer<B: Backend>(
        &self,
        backend: &B,
        w: &mut Tensor<B::E>,
        b: &mut [B::E],
        dw: &Tensor<B::E>,
        db: &[B::E],
    ) {
        debug_assert_eq!(w.len(), dw.len());
        debug_assert_eq!(b.len(), db.len());
        let lr = backend.encode(self.lr);
        let wd = backend.encode(self.weight_decay);
        // numerics-lint: allow(float-leak) — hyper-parameter gate on the f64 config, not value math
        let use_wd = self.weight_decay != 0.0;
        for (w, &g) in w.data.iter_mut().zip(&dw.data) {
            let g = if use_wd { backend.add(g, backend.mul(wd, *w)) } else { g };
            *w = backend.sub(*w, backend.mul_update(lr, g));
        }
        for (b, &g) in b.iter_mut().zip(db) {
            *b = backend.sub(*b, backend.mul_update(lr, g));
        }
    }

    /// Apply one update in-place.
    pub fn apply<B: Backend>(&self, backend: &B, mlp: &mut Mlp<B::E>, grads: &Gradients<B::E>) {
        let _sp = span(SpanKind::Update);
        for (layer, (dw, db)) in mlp.layers.iter_mut().zip(grads.dw.iter().zip(&grads.db)) {
            self.update_layer(backend, &mut layer.w, &mut layer.b, dw, db);
        }
    }

    /// Apply one update to a CNN, matching the gradient layer order of
    /// [`Cnn::backprop`]: `[conv1, conv2, fc1, fc2]`.
    pub fn apply_cnn<B: Backend>(&self, backend: &B, cnn: &mut Cnn<B::E>, grads: &Gradients<B::E>) {
        let _sp = span(SpanKind::Update);
        assert_eq!(grads.dw.len(), 4, "CNN gradients carry four layers");
        self.update_layer(backend, &mut cnn.conv1.w, &mut cnn.conv1.b, &grads.dw[0], &grads.db[0]);
        self.update_layer(backend, &mut cnn.conv2.w, &mut cnn.conv2.b, &grads.dw[1], &grads.db[1]);
        self.update_layer(backend, &mut cnn.fc1.w, &mut cnn.fc1.b, &grads.dw[2], &grads.db[2]);
        self.update_layer(backend, &mut cnn.fc2.w, &mut cnn.fc2.b, &grads.dw[3], &grads.db[3]);
    }
}

/// Snap one layer's parameters to its storage word (weights and biases —
/// both are parameters, both live in the narrow word on real hardware).
fn quantize_layer<B: Backend>(backend: &B, w: &mut Tensor<B::E>, b: &mut [B::E], spec: WordSpec) {
    for w in w.data.iter_mut() {
        *w = backend.quantize(*w, spec);
    }
    for b in b.iter_mut() {
        *b = backend.quantize(*b, spec);
    }
}

/// Snap every MLP layer with an assigned storage word to that word
/// (NUMERICS.md §11). Called at the two points where parameters change —
/// after init and after every [`SgdConfig::apply`] — identically on every
/// execution path (serial, sharded, multi-process replica), so mixed
/// precision never perturbs the bit-identity guarantees. No-op for the
/// uniform map.
pub fn quantize_mlp<B: Backend>(backend: &B, mlp: &mut Mlp<B::E>, pmap: &PrecisionMap) {
    if pmap.is_uniform() {
        return;
    }
    for (l, layer) in mlp.layers.iter_mut().enumerate() {
        if let Some(spec) = pmap.get(l) {
            quantize_layer(backend, &mut layer.w, &mut layer.b, spec);
        }
    }
}

/// CNN variant of [`quantize_mlp`]; layer indices follow the gradient
/// order of [`Cnn::backprop`]: `0 = conv1, 1 = conv2, 2 = fc1, 3 = fc2`.
pub fn quantize_cnn<B: Backend>(backend: &B, cnn: &mut Cnn<B::E>, pmap: &PrecisionMap) {
    if pmap.is_uniform() {
        return;
    }
    if let Some(spec) = pmap.get(0) {
        quantize_layer(backend, &mut cnn.conv1.w, &mut cnn.conv1.b, spec);
    }
    if let Some(spec) = pmap.get(1) {
        quantize_layer(backend, &mut cnn.conv2.w, &mut cnn.conv2.b, spec);
    }
    if let Some(spec) = pmap.get(2) {
        quantize_layer(backend, &mut cnn.fc1.w, &mut cnn.fc1.b, spec);
    }
    if let Some(spec) = pmap.get(3) {
        quantize_layer(backend, &mut cnn.fc2.w, &mut cnn.fc2.b, spec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::InitScheme;
    use crate::rng::SplitMix64;
    use crate::tensor::{FloatBackend, Tensor};

    #[test]
    fn sgd_matches_closed_form_float() {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(8);
        let mut mlp = crate::nn::Mlp::init(&b, &[2, 3, 2], InitScheme::HeNormal, &mut rng);
        let w_before = mlp.layers[0].w.data.clone();
        let x = Tensor::from_vec(1, 2, vec![0.5f32, -0.25]);
        let (g, _) = mlp.backprop(&b, &x, &[1]);
        let cfg = SgdConfig { lr: 0.1, weight_decay: 0.01 };
        cfg.apply(&b, &mut mlp, &g);
        for i in 0..w_before.len() {
            let want = w_before[i] - 0.1 * (g.dw[0].data[i] + 0.01 * w_before[i]);
            assert!((mlp.layers[0].w.data[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn loss_decreases_over_steps_float() {
        // A few SGD steps on a fixed batch must reduce the loss.
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(21);
        let mut mlp = crate::nn::Mlp::init(&b, &[4, 8, 3], InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(
            6,
            4,
            (0..24).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let labels = vec![0, 1, 2, 0, 1, 2];
        let cfg = SgdConfig { lr: 0.1, weight_decay: 0.0 };
        let (_, s0) = mlp.backprop(&b, &x, &labels);
        for _ in 0..100 {
            let (g, _) = mlp.backprop(&b, &x, &labels);
            cfg.apply(&b, &mut mlp, &g);
        }
        let (_, s1) = mlp.backprop(&b, &x, &labels);
        assert!(
            s1.loss < s0.loss * 0.5,
            "loss should halve: {} → {}",
            s0.loss,
            s1.loss
        );
    }

    #[test]
    fn quantize_mlp_snaps_assigned_layers_only() {
        use crate::lns::{LnsConfig, LnsSystem};
        use crate::tensor::LnsBackend;
        let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let mut rng = SplitMix64::new(11);
        let mut mlp = crate::nn::Mlp::init(&b, &[4, 6, 3], InitScheme::HeNormal, &mut rng);
        let untouched = mlp.layers[1].w.data.clone();
        let pmap = PrecisionMap::parse("8,-", "log16-lut").unwrap();
        quantize_mlp(&b, &mut mlp, &pmap);
        // Layer 0 magnitudes sit on the w8 grid (2^(10−2) base units)…
        for w in &mlp.layers[0].w.data {
            assert!(w.is_zero() || w.m % (1 << 8) == 0, "off-grid m = {}", w.m);
        }
        // …layer 1 (no assignment) is untouched, and the snap is idempotent.
        assert_eq!(mlp.layers[1].w.data, untouched);
        let snapped = mlp.layers[0].w.data.clone();
        quantize_mlp(&b, &mut mlp, &pmap);
        assert_eq!(mlp.layers[0].w.data, snapped);
    }

    #[test]
    fn zero_lr_is_noop() {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(3);
        let mut mlp = crate::nn::Mlp::init(&b, &[2, 2, 2], InitScheme::HeNormal, &mut rng);
        let snapshot = mlp.layers[0].w.data.clone();
        let x = Tensor::from_vec(1, 2, vec![1.0f32, 1.0]);
        let (g, _) = mlp.backprop(&b, &x, &[0]);
        SgdConfig { lr: 0.0, weight_decay: 0.0 }.apply(&b, &mut mlp, &g);
        assert_eq!(mlp.layers[0].w.data, snapshot);
    }
}
