//! Weight initialization (paper §4, "Weight Initialization", Eq. 12).
//!
//! Weights are drawn from a conventional symmetric distribution (we use
//! He-normal, matching the paper's He et al. citation) and encoded into
//! the target number system. For symmetric `f_w`, the log-domain sign is
//! Bernoulli(½) and the log-magnitude density is
//! `f_W(y) = 2^{y+1} ln(2) f_w(2^y)` — [`log_domain_init`] samples that
//! density directly (via inverse-CDF of `|w|` then `log2`), demonstrating
//! the paper's "initialize the log-domain weights accordingly" path; the
//! two routes agree in distribution (see tests).

use crate::rng::SplitMix64;

/// Which initialization route to use.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum InitScheme {
    /// Sample float, encode into the backend (reference route).
    HeNormal,
    /// Sample the log-domain density of Eq. 12 directly (LNS-native route;
    /// distributionally identical for symmetric `f_w`).
    LogDomain,
}

/// He-normal sample stream: `w ~ N(0, 2/fan_in)`.
pub fn he_normal_init(rng: &mut SplitMix64, fan_in: usize, n: usize) -> Vec<f64> {
    let std = (2.0 / fan_in as f64).sqrt();
    (0..n).map(|_| rng.normal_ms(0.0, std)).collect()
}

/// Eq.-12 route: sample `(Y = log2|w|, s)` directly. For `w ~ N(0, σ²)`,
/// `|w| = σ·|z|` with `z` standard normal, so `Y = log2 σ + log2|z|` — we
/// sample `z` and transform, which *is* inverse-CDF sampling of `f_W`;
/// the sign is an independent fair Bernoulli, exactly as the paper notes.
pub fn log_domain_init(rng: &mut SplitMix64, fan_in: usize, n: usize) -> Vec<(f64, bool)> {
    let sigma = (2.0 / fan_in as f64).sqrt();
    (0..n)
        .map(|_| {
            let z = rng.normal().abs().max(f64::MIN_POSITIVE);
            let y = sigma.log2() + z.log2();
            let s = rng.next_u64() & 1 == 1;
            (y, s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn he_normal_variance() {
        let mut r = SplitMix64::new(5);
        let v = he_normal_init(&mut r, 100, 100_000);
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.002);
        assert!((var - 0.02).abs() < 0.001, "var={var}");
    }

    #[test]
    fn log_domain_matches_float_route_in_distribution() {
        // Compare quantiles of log2|w| from both routes.
        let mut r1 = SplitMix64::new(9);
        let mut r2 = SplitMix64::new(10);
        let n = 100_000;
        let mut a: Vec<f64> = he_normal_init(&mut r1, 784, n)
            .into_iter()
            .map(|w| w.abs().max(f64::MIN_POSITIVE).log2())
            .collect();
        let mut b: Vec<f64> =
            log_domain_init(&mut r2, 784, n).into_iter().map(|(y, _)| y).collect();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for q in [0.1, 0.25, 0.5, 0.75, 0.9] {
            let i = (q * n as f64) as usize;
            assert!(
                (a[i] - b[i]).abs() < 0.06,
                "quantile {q}: {} vs {}",
                a[i],
                b[i]
            );
        }
    }

    #[test]
    fn log_domain_signs_balanced() {
        let mut r = SplitMix64::new(77);
        let v = log_domain_init(&mut r, 10, 50_000);
        let pos = v.iter().filter(|(_, s)| *s).count();
        let frac = pos as f64 / v.len() as f64;
        assert!((frac - 0.5).abs() < 0.01, "sign fraction {frac}");
    }
}
