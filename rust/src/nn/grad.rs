//! Gradients as first-class mergeable values.
//!
//! The sharded trainer ([`crate::train::shard`]) needs to move gradients
//! between workers and combine them in a *fixed* ⊞ order, so gradients
//! can no longer be an opaque value consumed inside `backprop`. This
//! module gives them an algebra:
//!
//! * [`GradStore`] — a mergeable, scalable bag of per-layer gradient
//!   buffers with flat slice views (the wire format every reduction,
//!   checkpoint, or future multi-process transport works over),
//! * [`RawStepStats`] — the unscaled loss/accuracy sums that ride along
//!   with gradient sums and merge by plain addition.
//!
//! The reduction contract: [`GradStore::accumulate`] is elementwise
//! backend ⊞ over the flat views via [`Backend::add_slice`] (so LNS gets
//! its hoisted Δ±-LUT fast path), and callers fix the merge *order* —
//! ⊞ is approximate and non-associative in LNS, so the order is part of
//! the numeric spec exactly as it is for the matmul reductions.

use super::mlp::{Gradients, StepStats};
use crate::tensor::{ops, Backend, Tensor};

/// Unscaled per-batch sums from a backward pass: the mergeable twin of
/// [`StepStats`]. Merging is plain addition, so any grouping of shards
/// produces identical integer counts; the f64 loss sum is folded in slot
/// order by [`crate::train::shard::accumulate_tree`]'s caller.
#[derive(Copy, Clone, Debug, Default)]
pub struct RawStepStats {
    /// Σ over samples of −ln p_label (natural-log CE, unscaled).
    pub loss_sum: f64,
    /// Correct argmax predictions.
    pub correct: usize,
    /// Samples summed over.
    pub n: usize,
}

impl RawStepStats {
    /// One sample's contribution.
    pub fn one(ln_p: f64, ok: bool) -> Self {
        RawStepStats { loss_sum: -ln_p, correct: ok as usize, n: 1 }
    }

    /// Fold another partial in (left ⊞ right, matching the serial
    /// row-ascending loss accumulation bit for bit: `a − l` ≡ `a + (−l)`
    /// in IEEE arithmetic).
    pub fn merge(&mut self, other: &RawStepStats) {
        self.loss_sum += other.loss_sum;
        self.correct += other.correct;
        self.n += other.n;
    }

    /// Average into the reported [`StepStats`] — the same `sum × (1/n)`
    /// expression the un-sharded backward passes have always used.
    pub fn finish(&self) -> StepStats {
        let inv = 1.0 / self.n as f64;
        StepStats { loss: self.loss_sum * inv, accuracy: self.correct as f64 * inv }
    }
}

/// A mergeable gradient container: per-layer buffers exposed as flat
/// slices in a fixed layer order.
///
/// Implementations must keep the view order stable across calls and
/// across same-shaped instances — [`GradStore::accumulate`] zips the
/// views positionally, and the sharded trainer's bit-exactness guarantee
/// rests on every worker agreeing on that layout.
pub trait GradStore<B: Backend>: Sized + Send {
    /// A same-shaped store holding the backend zero everywhere (the ⊞
    /// identity — merging it into any store is exact in every backend).
    fn zeros_like(&self, backend: &B) -> Self;

    /// Flat per-layer views in the canonical order (each layer's weight
    /// buffer, then its bias buffer).
    fn flat_views(&self) -> Vec<&[B::E]>;

    /// Mutable twin of [`GradStore::flat_views`], same order.
    fn flat_views_mut(&mut self) -> Vec<&mut [B::E]>;

    /// `self ⊞= other`, elementwise over the flat views (left ⊞ right).
    fn accumulate(&mut self, backend: &B, other: &Self) {
        let theirs = other.flat_views();
        let mut mine = self.flat_views_mut();
        assert_eq!(mine.len(), theirs.len(), "gradient layout mismatch");
        for (dst, src) in mine.iter_mut().zip(theirs) {
            assert_eq!(dst.len(), src.len(), "gradient view length mismatch");
            backend.add_slice(dst, src);
        }
    }

    /// Scale every element by a real constant (encoded once) — the single
    /// `1/B` averaging step after a reduction.
    ///
    /// (Deserialized gradient frames do not land through this trait:
    /// [`crate::train::multiproc::build_grads`] moves decoded wire views
    /// straight into a store without a zero-fill or copy.)
    fn scale(&mut self, backend: &B, c: f64) {
        for view in self.flat_views_mut() {
            ops::scale_slice(backend, view, c);
        }
    }
}

/// The MLP/CNN gradient bundle is the canonical store: `dw[l]` then
/// `db[l]`, layers ascending.
impl<B: Backend> GradStore<B> for Gradients<B::E> {
    fn zeros_like(&self, backend: &B) -> Self {
        Gradients {
            dw: self
                .dw
                .iter()
                .map(|t| Tensor::full(t.rows, t.cols, backend.zero()))
                .collect(),
            db: self.db.iter().map(|b| vec![backend.zero(); b.len()]).collect(),
        }
    }

    fn flat_views(&self) -> Vec<&[B::E]> {
        let mut v = Vec::with_capacity(2 * self.dw.len());
        for (dw, db) in self.dw.iter().zip(&self.db) {
            v.push(dw.data.as_slice());
            v.push(db.as_slice());
        }
        v
    }

    fn flat_views_mut(&mut self) -> Vec<&mut [B::E]> {
        let mut v = Vec::with_capacity(2 * self.dw.len());
        for (dw, db) in self.dw.iter_mut().zip(self.db.iter_mut()) {
            v.push(dw.data.as_mut_slice());
            v.push(db.as_mut_slice());
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{InitScheme, Mlp};
    use crate::rng::SplitMix64;
    use crate::tensor::FloatBackend;

    fn grads() -> (FloatBackend, Gradients<f32>) {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(3);
        let mlp = Mlp::init(&b, &[3, 4, 2], InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(2, 3, vec![0.5f32, -0.25, 1.0, 0.0, 0.75, -1.0]);
        let (g, _) = mlp.backprop(&b, &x, &[0, 1]);
        (b, g)
    }

    #[test]
    fn flat_views_cover_every_parameter() {
        let (_, g) = grads();
        let total: usize = GradStore::<FloatBackend>::flat_views(&g).iter().map(|v| v.len()).sum();
        assert_eq!(total, 3 * 4 + 4 + 4 * 2 + 2);
    }

    #[test]
    fn zeros_like_is_accumulate_identity() {
        let (b, g) = grads();
        let mut acc = g.zeros_like(&b);
        acc.accumulate(&b, &g);
        let got = GradStore::<FloatBackend>::flat_views(&acc);
        let want = GradStore::<FloatBackend>::flat_views(&g);
        assert_eq!(got, want);
    }

    #[test]
    fn scale_matches_tensor_scale() {
        let (b, g) = grads();
        let mut via_store = g.clone();
        GradStore::<FloatBackend>::scale(&mut via_store, &b, 0.25);
        let mut via_ops = g.clone();
        for t in via_ops.dw.iter_mut() {
            ops::scale(&b, t, 0.25);
        }
        for (s, o) in via_store.dw.iter().zip(&via_ops.dw) {
            assert_eq!(s.data, o.data);
        }
    }

    #[test]
    fn raw_stats_finish_matches_manual_average() {
        let mut s = RawStepStats::one(-0.7, true);
        s.merge(&RawStepStats::one(-1.1, false));
        s.merge(&RawStepStats::one(-0.2, true));
        let f = s.finish();
        assert_eq!(s.n, 3);
        assert!((f.loss - (0.7 + 1.1 + 0.2) / 3.0).abs() < 1e-12);
        assert!((f.accuracy - 2.0 / 3.0).abs() < 1e-12);
    }
}
