//! Backend-generic 2-D convolution and pooling with manual backprop,
//! plus a LeNet-style CNN workload.
//!
//! Convolution forward/backward lower onto the row-parallel matmul engine
//! via im2col/col2im ([`crate::tensor::im2col`]), exactly the route
//! Miyashita et al. and the approximate-tensor-ops line of work take: the
//! receptive-field patches become matmul rows, so every number system the
//! engine supports (float, linear fixed point, LNS LUT/bit-shift) gets
//! convolution — with rayon parallelism and serial↔parallel bit-exactness
//! — without a single new arithmetic primitive. Pooling is the one place
//! convolution needs an op matmul doesn't: the *log-domain compare* of
//! [`crate::tensor::Backend::gt`], which in LNS is a free integer compare
//! (max pooling) paired with a single ⊡ rescale (average pooling).
//!
//! As with the MLP, autodiff is impossible through the discrete LNS ops,
//! so the backward pass is written out in backend ⊞/⊡: the float backend
//! recovers textbook conv backprop, which the tests exploit as a gradient
//! oracle.

use super::grad::{GradStore, RawStepStats};
use super::init::InitScheme;
use super::mlp::{Dense, Gradients, StepStats};
use crate::obs::{layer_scope, span, SpanKind};
use crate::rng::SplitMix64;
use crate::tensor::im2col::{self, ConvShape};
use crate::tensor::{ops, Backend, Tensor};

/// Which engine path a conv op runs on. `Auto` lets each lowered matmul
/// dispatch on problem size; `Serial`/`Par`/`Tiled` force one path end
/// to end. All four produce bit-identical results (see
/// `tests/parallel_determinism.rs` and `tests/tiled_exactness.rs`), so
/// the explicit modes exist for benchmarking and for proving exactly
/// that. The im2col gather/scatter has no tiled flavour (it is pure
/// data movement), so `Tiled` auto-dispatches it.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
enum Mode {
    Serial,
    Par,
    Tiled,
    Auto,
}

/// Mode-dispatched `C = A·B`.
fn mm<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>, mode: Mode) -> Tensor<B::E> {
    match mode {
        Mode::Serial => ops::matmul_serial(b, a, w),
        Mode::Par => ops::matmul_par(b, a, w),
        Mode::Tiled => ops::matmul_tiled(b, a, w),
        Mode::Auto => ops::matmul(b, a, w),
    }
}

/// Mode-dispatched `C = Aᵀ·B` (gradient outer product).
fn mm_at<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>, mode: Mode) -> Tensor<B::E> {
    match mode {
        Mode::Serial => ops::matmul_at_serial(b, a, w),
        Mode::Par => ops::matmul_at_par(b, a, w),
        Mode::Tiled => ops::matmul_at_tiled(b, a, w),
        Mode::Auto => ops::matmul_at(b, a, w),
    }
}

/// Mode-dispatched `C = A·Bᵀ` (delta back-propagation).
fn mm_bt<B: Backend>(b: &B, a: &Tensor<B::E>, w: &Tensor<B::E>, mode: Mode) -> Tensor<B::E> {
    match mode {
        Mode::Serial => ops::matmul_bt_serial(b, a, w),
        Mode::Par => ops::matmul_bt_par(b, a, w),
        Mode::Tiled => ops::matmul_bt_tiled(b, a, w),
        Mode::Auto => ops::matmul_bt(b, a, w),
    }
}

/// Permute matmul output `[batch·OH·OW, C]` (patch-major) into CHW image
/// rows `[batch, C·OH·OW]`. Pure data movement — no arithmetic.
fn patch_rows_to_images<B: Backend>(
    backend: &B,
    y_cols: &Tensor<B::E>,
    batch: usize,
    oh: usize,
    ow: usize,
    c: usize,
) -> Tensor<B::E> {
    let hw = oh * ow;
    debug_assert_eq!(y_cols.rows, batch * hw);
    debug_assert_eq!(y_cols.cols, c);
    let mut out = Tensor::full(batch, c * hw, backend.zero());
    for s in 0..batch {
        let orow = out.row_mut(s);
        for p in 0..hw {
            for (ch, &v) in y_cols.row(s * hw + p).iter().enumerate() {
                orow[ch * hw + p] = v;
            }
        }
    }
    out
}

/// Inverse permutation of [`patch_rows_to_images`]: CHW image rows
/// `[batch, C·OH·OW]` into patch-major `[batch·OH·OW, C]`.
fn images_to_patch_rows<B: Backend>(
    backend: &B,
    y: &Tensor<B::E>,
    oh: usize,
    ow: usize,
    c: usize,
) -> Tensor<B::E> {
    let hw = oh * ow;
    debug_assert_eq!(y.cols, c * hw);
    let batch = y.rows;
    let mut out = Tensor::full(batch * hw, c, backend.zero());
    for s in 0..batch {
        let yrow = y.row(s);
        for p in 0..hw {
            let orow = out.row_mut(s * hw + p);
            for (ch, o) in orow.iter_mut().enumerate() {
                *o = yrow[ch * hw + p];
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// Conv2d
// ---------------------------------------------------------------------

/// One 2-D convolution layer's parameters, stored in im2col layout so
/// forward is a single matmul.
#[derive(Clone, Debug)]
pub struct Conv2d<E> {
    /// Input geometry + kernel/stride/padding.
    pub shape: ConvShape,
    /// Output channels.
    pub out_c: usize,
    /// `[patch_len, out_c]` kernel matrix; row `(c·k_h + ky)·k_w + kx`
    /// holds that tap across all output channels.
    pub w: Tensor<E>,
    /// `[out_c]` bias.
    pub b: Vec<E>,
}

impl<E: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static> Conv2d<E> {
    /// Initialize with the given scheme; fan-in is the receptive-field
    /// size `C·k_h·k_w`, exactly as for a dense layer of that width.
    pub fn init<B: Backend<E = E>>(
        backend: &B,
        shape: ConvShape,
        out_c: usize,
        scheme: InitScheme,
        rng: &mut SplitMix64,
    ) -> Self {
        let d = Dense::init(backend, shape.patch_len(), out_c, scheme, rng);
        Conv2d { shape, out_c, w: d.w, b: d.b }
    }

    /// Number of scalar parameters.
    pub fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn forward_mode<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
        mode: Mode,
    ) -> (Tensor<E>, Tensor<E>) {
        assert_eq!(x.cols, self.shape.in_len(), "conv input width mismatch");
        let cols = {
            let _sp = span(SpanKind::Im2col);
            match mode {
                Mode::Serial => im2col::im2col_serial(backend, x, &self.shape),
                Mode::Par => im2col::im2col_par(backend, x, &self.shape),
                Mode::Tiled | Mode::Auto => im2col::im2col(backend, x, &self.shape),
            }
        };
        let mut y_cols = mm(backend, &cols, &self.w, mode);
        // Row-broadcast bias: bit-identical on either engine path.
        ops::add_bias(backend, &mut y_cols, &self.b);
        let y = patch_rows_to_images(
            backend,
            &y_cols,
            x.rows,
            self.shape.out_h(),
            self.shape.out_w(),
            self.out_c,
        );
        (cols, y)
    }

    /// Forward pass: returns `(cols, y)` where `cols` is the im2col patch
    /// matrix (cached for backward) and `y` is the `[batch, out_c·OH·OW]`
    /// pre-activation in CHW layout. Auto-dispatches each lowered matmul.
    pub fn forward<B: Backend<E = E>>(&self, backend: &B, x: &Tensor<E>) -> (Tensor<E>, Tensor<E>) {
        self.forward_mode(backend, x, Mode::Auto)
    }

    /// [`Conv2d::forward`] forced onto the serial engine path.
    pub fn forward_serial<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
    ) -> (Tensor<E>, Tensor<E>) {
        self.forward_mode(backend, x, Mode::Serial)
    }

    /// [`Conv2d::forward`] forced onto the rayon-parallel engine path.
    pub fn forward_par<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
    ) -> (Tensor<E>, Tensor<E>) {
        self.forward_mode(backend, x, Mode::Par)
    }

    /// [`Conv2d::forward`] with every lowered matmul forced onto the
    /// cache-tiled kernels (the im2col gather keeps auto dispatch — it is
    /// pure data movement). Bit-identical to the other paths.
    pub fn forward_tiled<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
    ) -> (Tensor<E>, Tensor<E>) {
        self.forward_mode(backend, x, Mode::Tiled)
    }

    fn backward_mode<B: Backend<E = E>>(
        &self,
        backend: &B,
        cols: &Tensor<E>,
        upstream: &Tensor<E>,
        need_dx: bool,
        mode: Mode,
    ) -> (Tensor<E>, Vec<E>, Option<Tensor<E>>) {
        let batch = upstream.rows;
        assert_eq!(upstream.cols, self.shape.out_len(self.out_c), "conv upstream width mismatch");
        assert_eq!(cols.rows, batch * self.shape.patches_per_image(), "conv cache row mismatch");
        let d_cols = images_to_patch_rows(
            backend,
            upstream,
            self.shape.out_h(),
            self.shape.out_w(),
            self.out_c,
        );
        // dW = colsᵀ·δ — the gradient outer product over all patches.
        let dw = mm_at(backend, cols, &d_cols, mode);
        // db = Σ_patches δ (row-ascending reduction, part of the spec).
        let db = ops::col_sum(backend, &d_cols);
        // dX = col2im(δ·Wᵀ): route each patch gradient back through the
        // receptive field it came from.
        let dx = if need_dx {
            let d_patches = mm_bt(backend, &d_cols, &self.w, mode);
            let _sp = span(SpanKind::Im2col);
            Some(match mode {
                Mode::Serial => im2col::col2im_serial(backend, &d_patches, &self.shape, batch),
                Mode::Par => im2col::col2im_par(backend, &d_patches, &self.shape, batch),
                Mode::Tiled | Mode::Auto => im2col::col2im(backend, &d_patches, &self.shape, batch),
            })
        } else {
            None
        };
        (dw, db, dx)
    }

    /// Backward pass from the cached patch matrix and the upstream
    /// gradient (CHW layout, same shape as the forward output). Returns
    /// `(dW, db, dX)` as **raw sums over the batch** — averaging is the
    /// model's job, mirroring the MLP. `dX` is skipped (None) when
    /// `need_dx` is false (first layer).
    pub fn backward<B: Backend<E = E>>(
        &self,
        backend: &B,
        cols: &Tensor<E>,
        upstream: &Tensor<E>,
        need_dx: bool,
    ) -> (Tensor<E>, Vec<E>, Option<Tensor<E>>) {
        self.backward_mode(backend, cols, upstream, need_dx, Mode::Auto)
    }

    /// [`Conv2d::backward`] forced onto the serial engine path.
    pub fn backward_serial<B: Backend<E = E>>(
        &self,
        backend: &B,
        cols: &Tensor<E>,
        upstream: &Tensor<E>,
        need_dx: bool,
    ) -> (Tensor<E>, Vec<E>, Option<Tensor<E>>) {
        self.backward_mode(backend, cols, upstream, need_dx, Mode::Serial)
    }

    /// [`Conv2d::backward`] forced onto the rayon-parallel engine path.
    pub fn backward_par<B: Backend<E = E>>(
        &self,
        backend: &B,
        cols: &Tensor<E>,
        upstream: &Tensor<E>,
        need_dx: bool,
    ) -> (Tensor<E>, Vec<E>, Option<Tensor<E>>) {
        self.backward_mode(backend, cols, upstream, need_dx, Mode::Par)
    }

    /// [`Conv2d::backward`] with every lowered matmul forced onto the
    /// cache-tiled kernels (col2im keeps auto dispatch). Bit-identical to
    /// the other paths.
    pub fn backward_tiled<B: Backend<E = E>>(
        &self,
        backend: &B,
        cols: &Tensor<E>,
        upstream: &Tensor<E>,
        need_dx: bool,
    ) -> (Tensor<E>, Vec<E>, Option<Tensor<E>>) {
        self.backward_mode(backend, cols, upstream, need_dx, Mode::Tiled)
    }
}

// ---------------------------------------------------------------------
// Pooling
// ---------------------------------------------------------------------

/// Pooling flavour.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum PoolKind {
    /// Window maximum under the backend's signed order
    /// ([`Backend::gt`] — a free integer compare in LNS).
    Max,
    /// Window mean: ⊞-sum then one ⊡ by the encoded `1/k²`.
    Avg,
}

/// A 2-D pooling layer (square window, per-channel, CHW layout). Kept
/// serial: pooling is a vanishing fraction of a step next to the lowered
/// matmuls, and a fixed scan order keeps it trivially deterministic.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Pool2d {
    /// Channels (pooled independently).
    pub channels: usize,
    /// Input height.
    pub in_h: usize,
    /// Input width.
    pub in_w: usize,
    /// Window side length.
    pub k: usize,
    /// Stride (defaults to `k` via the constructors: non-overlapping).
    pub stride: usize,
    /// Max or average.
    pub kind: PoolKind,
}

impl Pool2d {
    /// Non-overlapping max pool with a `k×k` window.
    pub fn max(channels: usize, in_h: usize, in_w: usize, k: usize) -> Self {
        Pool2d { channels, in_h, in_w, k, stride: k, kind: PoolKind::Max }
    }

    /// Non-overlapping average pool with a `k×k` window.
    pub fn avg(channels: usize, in_h: usize, in_w: usize, k: usize) -> Self {
        Pool2d { channels, in_h, in_w, k, stride: k, kind: PoolKind::Avg }
    }

    /// Output height `(H − k)/s + 1` (rows the windows don't reach are
    /// dropped, and correspondingly receive zero gradient). Panics with
    /// the geometry error — not a usize underflow — when the window
    /// exceeds the input, which otherwise surfaces as a far-away
    /// capacity panic from `CnnArch::flat_len` on too-small archs.
    pub fn out_h(&self) -> usize {
        assert!(
            self.k >= 1 && self.stride >= 1 && self.k <= self.in_h,
            "pool window {} exceeds input height {}",
            self.k,
            self.in_h
        );
        (self.in_h - self.k) / self.stride + 1
    }

    /// Output width `(W − k)/s + 1` (same guard as [`Pool2d::out_h`]).
    pub fn out_w(&self) -> usize {
        assert!(
            self.k >= 1 && self.stride >= 1 && self.k <= self.in_w,
            "pool window {} exceeds input width {}",
            self.k,
            self.in_w
        );
        (self.in_w - self.k) / self.stride + 1
    }

    /// Flattened input row width.
    pub fn in_len(&self) -> usize {
        self.channels * self.in_h * self.in_w
    }

    /// Flattened output row width.
    pub fn out_len(&self) -> usize {
        self.channels * self.out_h() * self.out_w()
    }

    /// Forward pass over `[batch, C·H·W]` rows. Returns the pooled
    /// `[batch, C·OH·OW]` tensor and, for Max, the per-output flat input
    /// index that won each window (first maximum on ties — fixed scan
    /// order) — the backward routing table. Empty for Avg.
    pub fn forward<B: Backend>(&self, backend: &B, x: &Tensor<B::E>) -> (Tensor<B::E>, Vec<usize>) {
        assert_eq!(x.cols, self.in_len(), "pool input width mismatch");
        assert!(self.k >= 1 && self.stride >= 1 && self.k <= self.in_h && self.k <= self.in_w);
        let (oh, ow) = (self.out_h(), self.out_w());
        let out_len = self.out_len();
        let mut out = Tensor::full(x.rows, out_len, backend.zero());
        let mut route =
            if self.kind == PoolKind::Max { vec![0usize; x.rows * out_len] } else { Vec::new() };
        // numerics-lint: allow(float-leak) — constant 1/k² pool weight, encoded once; averaging is ⊡
        let inv = backend.encode(1.0 / (self.k * self.k) as f64);
        for s in 0..x.rows {
            let xrow = x.row(s);
            let orow = out.row_mut(s);
            for c in 0..self.channels {
                let base = c * self.in_h * self.in_w;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let o = (c * oh + oy) * ow + ox;
                        let top = base + oy * self.stride * self.in_w + ox * self.stride;
                        match self.kind {
                            PoolKind::Max => {
                                let mut best_idx = top;
                                let mut best = xrow[top];
                                for ky in 0..self.k {
                                    for kx in 0..self.k {
                                        let idx = top + ky * self.in_w + kx;
                                        if backend.gt(xrow[idx], best) {
                                            best = xrow[idx];
                                            best_idx = idx;
                                        }
                                    }
                                }
                                orow[o] = best;
                                route[s * out_len + o] = best_idx;
                            }
                            PoolKind::Avg => {
                                let mut acc = backend.zero();
                                for ky in 0..self.k {
                                    for kx in 0..self.k {
                                        acc = backend.add(acc, xrow[top + ky * self.in_w + kx]);
                                    }
                                }
                                orow[o] = backend.mul(acc, inv);
                            }
                        }
                    }
                }
            }
        }
        (out, route)
    }

    /// Backward pass: Max routes each upstream gradient to its recorded
    /// argmax cell; Avg spreads `upstream ⊡ 1/k²` over the window. Both
    /// ⊞-accumulate in the forward scan order.
    pub fn backward<B: Backend>(
        &self,
        backend: &B,
        route: &[usize],
        upstream: &Tensor<B::E>,
    ) -> Tensor<B::E> {
        let out_len = self.out_len();
        assert_eq!(upstream.cols, out_len, "pool upstream width mismatch");
        if self.kind == PoolKind::Max {
            assert_eq!(route.len(), upstream.rows * out_len, "pool route length mismatch");
        }
        let (oh, ow) = (self.out_h(), self.out_w());
        let mut dx = Tensor::full(upstream.rows, self.in_len(), backend.zero());
        // numerics-lint: allow(float-leak) — constant 1/k² pool weight, encoded once; averaging is ⊡
        let inv = backend.encode(1.0 / (self.k * self.k) as f64);
        for s in 0..upstream.rows {
            let urow = upstream.row(s);
            let drow = dx.row_mut(s);
            match self.kind {
                PoolKind::Max => {
                    for (o, &u) in urow.iter().enumerate() {
                        let t = route[s * out_len + o];
                        drow[t] = backend.add(drow[t], u);
                    }
                }
                PoolKind::Avg => {
                    for c in 0..self.channels {
                        let base = c * self.in_h * self.in_w;
                        for oy in 0..oh {
                            for ox in 0..ow {
                                let o = (c * oh + oy) * ow + ox;
                                let g = backend.mul(urow[o], inv);
                                let top = base + oy * self.stride * self.in_w + ox * self.stride;
                                for ky in 0..self.k {
                                    for kx in 0..self.k {
                                        let idx = top + ky * self.in_w + kx;
                                        drow[idx] = backend.add(drow[idx], g);
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        dx
    }
}

// ---------------------------------------------------------------------
// LeNet-style CNN
// ---------------------------------------------------------------------

/// How the CNN downsamples between its two conv stages.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum CnnVariant {
    /// Classic LeNet shape: stride-1 convs, each followed by a pool.
    Pooled,
    /// Strided workload: the pools are dropped and both convs run at
    /// stride 2, so downsampling is *learned* — this exercises the
    /// `ConvShape` stride support end to end (forward, im2col/col2im
    /// backward, training).
    StridedV1,
}

impl CnnVariant {
    /// Parse a CLI tag (`lenet` / `strided-v1`).
    pub fn parse(s: &str) -> Option<CnnVariant> {
        Some(match s {
            "lenet" | "pooled" => CnnVariant::Pooled,
            "strided-v1" => CnnVariant::StridedV1,
            _ => return None,
        })
    }

    /// Report label.
    pub fn label(&self) -> &'static str {
        match self {
            CnnVariant::Pooled => "lenet",
            CnnVariant::StridedV1 => "strided-v1",
        }
    }
}

/// Architecture of the conv–pool–conv–pool–dense–dense CNN.
/// (`PartialEq`/`Eq` because the multi-process wire format round-trips
/// it inside [`crate::train::wire::ModelSpec`].)
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CnnArch {
    /// Input channels.
    pub in_c: usize,
    /// Input height.
    pub in_h: usize,
    /// Input width.
    pub in_w: usize,
    /// Conv-1 output channels.
    pub c1: usize,
    /// Conv-2 output channels.
    pub c2: usize,
    /// Conv kernel side (both layers, stride 1).
    pub k: usize,
    /// Conv zero padding (both layers).
    pub pad: usize,
    /// Pool window = stride (both layers).
    pub pool: usize,
    /// Pooling flavour (Max for the workload; Avg is smooth everywhere,
    /// which the finite-difference gradient oracle exploits).
    pub pool_kind: PoolKind,
    /// Hidden dense width.
    pub hidden: usize,
    /// Output classes.
    pub classes: usize,
    /// Downsampling scheme (pooled LeNet vs stride-2 convs).
    pub variant: CnnVariant,
}

impl CnnArch {
    /// LeNet-style defaults for square single-channel `side×side` inputs:
    /// 5×5 kernels with pad 2 (shape-preserving), 2×2 max pools.
    pub fn lenet(side: usize, classes: usize) -> Self {
        CnnArch {
            in_c: 1,
            in_h: side,
            in_w: side,
            c1: 6,
            c2: 12,
            k: 5,
            pad: 2,
            pool: 2,
            pool_kind: PoolKind::Max,
            hidden: 64,
            classes,
            variant: CnnVariant::Pooled,
        }
    }

    /// The stride-2 workload: [`CnnArch::lenet`] with the pools replaced
    /// by stride-2 convolutions (`pool`/`pool_kind` become inert).
    pub fn strided_v1(side: usize, classes: usize) -> Self {
        CnnArch { variant: CnnVariant::StridedV1, ..Self::lenet(side, classes) }
    }

    /// Conv stride implied by the variant.
    fn conv_stride(&self) -> usize {
        match self.variant {
            CnnVariant::Pooled => 1,
            CnnVariant::StridedV1 => 2,
        }
    }

    /// Flattened input width `C·H·W`.
    pub fn input_len(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }

    /// Conv-1 geometry.
    pub fn conv1_shape(&self) -> ConvShape {
        ConvShape {
            in_c: self.in_c,
            in_h: self.in_h,
            in_w: self.in_w,
            k_h: self.k,
            k_w: self.k,
            stride: self.conv_stride(),
            pad: self.pad,
        }
    }

    /// Pool-1 geometry (over conv-1's output map). Only meaningful for
    /// [`CnnVariant::Pooled`] — the strided variant has no pools.
    pub fn pool1(&self) -> Pool2d {
        let s = self.conv1_shape();
        Pool2d {
            channels: self.c1,
            in_h: s.out_h(),
            in_w: s.out_w(),
            k: self.pool,
            stride: self.pool,
            kind: self.pool_kind,
        }
    }

    /// Conv-2 geometry (over the conv-2 input map: pool-1's output when
    /// pooled, conv-1's activation map when strided).
    pub fn conv2_shape(&self) -> ConvShape {
        let (in_h, in_w) = match self.variant {
            CnnVariant::Pooled => {
                let p = self.pool1();
                (p.out_h(), p.out_w())
            }
            CnnVariant::StridedV1 => {
                let s = self.conv1_shape();
                (s.out_h(), s.out_w())
            }
        };
        ConvShape {
            in_c: self.c1,
            in_h,
            in_w,
            k_h: self.k,
            k_w: self.k,
            stride: self.conv_stride(),
            pad: self.pad,
        }
    }

    /// Pool-2 geometry (over conv-2's output map). Only meaningful for
    /// [`CnnVariant::Pooled`].
    pub fn pool2(&self) -> Pool2d {
        let s = self.conv2_shape();
        Pool2d {
            channels: self.c2,
            in_h: s.out_h(),
            in_w: s.out_w(),
            k: self.pool,
            stride: self.pool,
            kind: self.pool_kind,
        }
    }

    /// Flattened width entering the dense head.
    pub fn flat_len(&self) -> usize {
        match self.variant {
            CnnVariant::Pooled => self.pool2().out_len(),
            CnnVariant::StridedV1 => self.conv2_shape().out_len(self.c2),
        }
    }
}

/// Intermediate activations of one CNN forward pass (backprop inputs).
#[derive(Clone, Debug)]
pub struct CnnCache<E> {
    /// Conv-1 im2col patches.
    pub cols1: Tensor<E>,
    /// Conv-1 pre-activation.
    pub z1: Tensor<E>,
    /// Conv-2 input: pool-1 output when pooled, the conv-1 activation
    /// map when strided.
    pub p1: Tensor<E>,
    /// Pool-1 max routing (empty for avg pooling and for the strided
    /// variant).
    pub route1: Vec<usize>,
    /// Conv-2 im2col patches.
    pub cols2: Tensor<E>,
    /// Conv-2 pre-activation.
    pub z2: Tensor<E>,
    /// Flattened dense-head input: pool-2 output when pooled, the conv-2
    /// activation map when strided.
    pub p2: Tensor<E>,
    /// Pool-2 max routing (empty for avg pooling and for the strided
    /// variant).
    pub route2: Vec<usize>,
    /// Dense hidden pre-activation.
    pub zf: Tensor<E>,
    /// Dense hidden activation.
    pub af: Tensor<E>,
    /// Head logits.
    pub logits: Tensor<E>,
}

/// The LeNet-style CNN: conv–pool–conv–pool–dense–dense, llReLU hidden
/// activations, linear head feeding the backend's log-domain soft-max/CE.
#[derive(Clone, Debug)]
pub struct Cnn<E> {
    /// Architecture (fixes every derived geometry).
    pub arch: CnnArch,
    /// First convolution.
    pub conv1: Conv2d<E>,
    /// Second convolution.
    pub conv2: Conv2d<E>,
    /// Hidden dense layer.
    pub fc1: Dense<E>,
    /// Classifier head.
    pub fc2: Dense<E>,
}

impl<E: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static> Cnn<E> {
    /// Initialize all four layers with the given scheme.
    pub fn init<B: Backend<E = E>>(
        backend: &B,
        arch: &CnnArch,
        scheme: InitScheme,
        rng: &mut SplitMix64,
    ) -> Self {
        let conv1 = Conv2d::init(backend, arch.conv1_shape(), arch.c1, scheme, rng);
        let conv2 = Conv2d::init(backend, arch.conv2_shape(), arch.c2, scheme, rng);
        let fc1 = Dense::init(backend, arch.flat_len(), arch.hidden, scheme, rng);
        let fc2 = Dense::init(backend, arch.hidden, arch.classes, scheme, rng);
        Cnn { arch: arch.clone(), conv1, conv2, fc1, fc2 }
    }

    /// Number of scalar parameters.
    pub fn param_count(&self) -> usize {
        self.conv1.param_count()
            + self.conv2.param_count()
            + self.fc1.w.len()
            + self.fc1.b.len()
            + self.fc2.w.len()
            + self.fc2.b.len()
    }

    fn forward_mode<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
        mode: Mode,
    ) -> CnnCache<E> {
        assert_eq!(x.cols, self.arch.input_len(), "CNN input width mismatch");
        let _sp = span(SpanKind::Forward);
        let pooled = self.arch.variant == CnnVariant::Pooled;
        // Counter scopes 1–4 attribute numerics tallies to conv1, conv2,
        // fc1, fc2 respectively (free when counting is off).
        let (cols1, z1, a1) = {
            let _scope = layer_scope(1);
            let (cols1, z1) = self.conv1.forward_mode(backend, x, mode);
            let a1 = ops::leaky_relu(backend, &z1);
            // Value-distribution sampling of each layer output (read-only
            // probe, gated inside; NUMERICS.md §7). Scopes 1–4 mirror the
            // counter attribution above.
            crate::obs::dist::record_slice(
                backend,
                crate::obs::dist::TensorClass::Activations,
                1,
                &a1.data,
            );
            (cols1, z1, a1)
        };
        // Strided variant: the activation map feeds conv-2 directly
        // (`p1 = a1`, empty routing) — downsampling happened in the conv.
        let (p1, route1) = if pooled {
            self.arch.pool1().forward(backend, &a1)
        } else {
            (a1, Vec::new())
        };
        let (cols2, z2, a2) = {
            let _scope = layer_scope(2);
            let (cols2, z2) = self.conv2.forward_mode(backend, &p1, mode);
            let a2 = ops::leaky_relu(backend, &z2);
            crate::obs::dist::record_slice(
                backend,
                crate::obs::dist::TensorClass::Activations,
                2,
                &a2.data,
            );
            (cols2, z2, a2)
        };
        let (p2, route2) = if pooled {
            self.arch.pool2().forward(backend, &a2)
        } else {
            (a2, Vec::new())
        };
        let (zf, af) = {
            let _scope = layer_scope(3);
            let mut zf = mm(backend, &p2, &self.fc1.w, mode);
            ops::add_bias(backend, &mut zf, &self.fc1.b);
            let af = ops::leaky_relu(backend, &zf);
            crate::obs::dist::record_slice(
                backend,
                crate::obs::dist::TensorClass::Activations,
                3,
                &af.data,
            );
            (zf, af)
        };
        let logits = {
            let _scope = layer_scope(4);
            let mut logits = mm(backend, &af, &self.fc2.w, mode);
            ops::add_bias(backend, &mut logits, &self.fc2.b);
            crate::obs::dist::record_slice(
                backend,
                crate::obs::dist::TensorClass::Activations,
                4,
                &logits.data,
            );
            logits
        };
        CnnCache { cols1, z1, p1, route1, cols2, z2, p2, route2, zf, af, logits }
    }

    /// Full forward pass with caches for backprop.
    pub fn forward<B: Backend<E = E>>(&self, backend: &B, x: &Tensor<E>) -> CnnCache<E> {
        self.forward_mode(backend, x, Mode::Auto)
    }

    /// Logits only (inference path).
    pub fn logits<B: Backend<E = E>>(&self, backend: &B, x: &Tensor<E>) -> Tensor<E> {
        self.forward(backend, x).logits
    }

    /// Predicted class per row.
    pub fn predict<B: Backend<E = E>>(&self, backend: &B, x: &Tensor<E>) -> Vec<usize> {
        let logits = self.logits(backend, x);
        (0..logits.rows).map(|i| ops::argmax_row(backend, logits.row(i))).collect()
    }

    /// Full training-step math: forward, soft-max CE gradient init
    /// (Eq. 13/14), manual backprop through dense, pool and conv layers,
    /// gradient averaging over the batch. Gradient layer order:
    /// `[conv1, conv2, fc1, fc2]`. Does **not** update parameters — that
    /// is [`super::SgdConfig::apply_cnn`].
    pub fn backprop<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
        labels: &[usize],
    ) -> (Gradients<E>, StepStats) {
        let (grads, raw) = self.backprop_avg(backend, x, labels);
        (grads, raw.finish())
    }

    /// [`Cnn::backprop_sums`] followed by the single `1/B` scale —
    /// averaged gradients with the **raw** statistics still attached
    /// (the mirror of [`crate::nn::Mlp::backprop_avg`]).
    pub fn backprop_avg<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
        labels: &[usize],
    ) -> (Gradients<E>, RawStepStats) {
        let (mut grads, raw) = self.backprop_sums(backend, x, labels);
        // numerics-lint: allow(float-leak) — the single 1/B scale (§3), computed in f64, encoded once
        grads.scale(backend, 1.0 / raw.n as f64);
        (grads, raw)
    }

    /// [`Cnn::backprop`] without the `1/B` averaging: gradients come back
    /// as **raw ⊞-sums over the batch** ([`RawStepStats`] likewise) — the
    /// shard-mergeable form consumed by [`crate::train::shard`]. Unlike
    /// the MLP, a CNN sample contributes `OH·OW` ⊞ terms per conv-kernel
    /// gradient element (one per patch), so per-sample shards are
    /// *subtrees* of the reduction rather than single terms — see the
    /// shard module docs for what that means for the canonical order.
    pub fn backprop_sums<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
        labels: &[usize],
    ) -> (Gradients<E>, RawStepStats) {
        let batch = x.rows;
        assert_eq!(labels.len(), batch);
        let cache = self.forward(backend, x);
        // As in the MLP, the Backward span opens after the forward pass so
        // the trace shows the two phases side by side.
        let _sp = span(SpanKind::Backward);
        let classes = self.arch.classes;
        let pooled = self.arch.variant == CnnVariant::Pooled;

        // δ_head = p − y per row, plus loss/accuracy bookkeeping — the
        // same shared [`ops::softmax_ce_head`] the MLP uses, so the CNN
        // head fans eval-sized batches across the rayon pool too (ROADMAP
        // follow-up) without a second copy of the reduction code.
        let mut delta = Tensor::full(batch, classes, backend.zero());
        let (loss, correct) = ops::softmax_ce_head(backend, &cache.logits, labels, &mut delta);

        // Head: dW = afᵀ·δ, db = Σ δ, δ ← (δ·W₂ᵀ) ⊙ act'(zf).
        let (dw_fc2, db_fc2, d_hidden) = {
            let _scope = layer_scope(4);
            let dw_fc2 = ops::matmul_at(backend, &cache.af, &delta);
            let db_fc2 = ops::col_sum(backend, &delta);
            let back = ops::matmul_bt(backend, &delta, &self.fc2.w);
            (dw_fc2, db_fc2, ops::leaky_relu_bwd(backend, &cache.zf, &back))
        };

        // Hidden dense: dW = p₂ᵀ·δ, then δ leaves the dense head as the
        // flattened pool-2 (or conv-2 activation) gradient.
        let (dw_fc1, db_fc1, d_p2) = {
            let _scope = layer_scope(3);
            let dw_fc1 = ops::matmul_at(backend, &cache.p2, &d_hidden);
            let db_fc1 = ops::col_sum(backend, &d_hidden);
            (dw_fc1, db_fc1, ops::matmul_bt(backend, &d_hidden, &self.fc1.w))
        };

        // Pool-2 (identity when strided) → llReLU → conv-2.
        let d_a2 = if pooled {
            self.arch.pool2().backward(backend, &cache.route2, &d_p2)
        } else {
            d_p2
        };
        let (dw2, db2, d_p1) = {
            let _scope = layer_scope(2);
            let d_z2 = ops::leaky_relu_bwd(backend, &cache.z2, &d_a2);
            self.conv2.backward(backend, &cache.cols2, &d_z2, true)
        };
        let d_p1 = d_p1.expect("conv2 backward with need_dx");

        // Pool-1 (identity when strided) → llReLU → conv-1 (input
        // gradient not needed).
        let d_a1 = if pooled {
            self.arch.pool1().backward(backend, &cache.route1, &d_p1)
        } else {
            d_p1
        };
        let (dw1, db1, _) = {
            let _scope = layer_scope(1);
            let d_z1 = ops::leaky_relu_bwd(backend, &cache.z1, &d_a1);
            self.conv1.backward(backend, &cache.cols1, &d_z1, false)
        };

        (
            Gradients {
                dw: vec![dw1, dw2, dw_fc1, dw_fc2],
                db: vec![db1, db2, db_fc1, db_fc2],
            },
            RawStepStats { loss_sum: loss, correct, n: batch },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatBackend;

    fn fb() -> FloatBackend {
        FloatBackend::default()
    }

    /// Naive direct convolution in f32, same CHW/kernel layout as the
    /// im2col lowering — the correctness reference.
    fn conv_naive(x: &Tensor<f32>, layer: &Conv2d<f32>) -> Tensor<f32> {
        let s = &layer.shape;
        let (oh, ow) = (s.out_h(), s.out_w());
        let mut out = Tensor::full(x.rows, s.out_len(layer.out_c), 0.0f32);
        for smp in 0..x.rows {
            for co in 0..layer.out_c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = layer.b[co];
                        for c in 0..s.in_c {
                            for ky in 0..s.k_h {
                                for kx in 0..s.k_w {
                                    let y = (oy * s.stride + ky) as isize - s.pad as isize;
                                    let xx = (ox * s.stride + kx) as isize - s.pad as isize;
                                    if y >= 0
                                        && (y as usize) < s.in_h
                                        && xx >= 0
                                        && (xx as usize) < s.in_w
                                    {
                                        let xi = (c * s.in_h + y as usize) * s.in_w + xx as usize;
                                        let wi = (c * s.k_h + ky) * s.k_w + kx;
                                        acc += x.at(smp, xi) * layer.w.at(wi, co);
                                    }
                                }
                            }
                        }
                        *out.at_mut(smp, (co * oh + oy) * ow + ox) = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_forward_matches_naive_reference() {
        let b = fb();
        let mut rng = SplitMix64::new(42);
        let cases = [(1usize, 5usize, 3usize, 1usize, 2usize), (2, 6, 3, 0, 3), (3, 4, 1, 0, 4)];
        for (in_c, side, k, pad, out_c) in cases {
            let shape = ConvShape::square(in_c, side, k, 1, pad);
            let layer = Conv2d::init(&b, shape, out_c, InitScheme::HeNormal, &mut rng);
            let x = Tensor::from_vec(
                3,
                shape.in_len(),
                (0..3 * shape.in_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
            );
            let (_, y) = layer.forward(&b, &x);
            let want = conv_naive(&x, &layer);
            assert_eq!(y.rows, want.rows);
            assert_eq!(y.cols, want.cols);
            for (a, w) in y.data.iter().zip(&want.data) {
                assert!((a - w).abs() < 1e-4, "conv {in_c}x{side} k{k}: {a} vs {w}");
            }
        }
    }

    #[test]
    fn strided_conv_forward_matches_naive_reference() {
        // The stride-2 cases the StridedV1 workload exercises, against the
        // same naive direct-convolution reference (which honours stride).
        let b = fb();
        let mut rng = SplitMix64::new(23);
        let cases = [
            (1usize, 6usize, 3usize, 1usize, 2usize, 2usize),
            (2, 8, 5, 2, 4, 2),
            (3, 7, 3, 0, 2, 2),
            (1, 9, 3, 1, 3, 3),
        ];
        for (in_c, side, k, pad, out_c, stride) in cases {
            let shape = ConvShape::square(in_c, side, k, stride, pad);
            let layer = Conv2d::init(&b, shape, out_c, InitScheme::HeNormal, &mut rng);
            let x = Tensor::from_vec(
                2,
                shape.in_len(),
                (0..2 * shape.in_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
            );
            let (_, y) = layer.forward(&b, &x);
            let want = conv_naive(&x, &layer);
            assert_eq!(y.rows, want.rows);
            assert_eq!(y.cols, want.cols);
            for (a, w) in y.data.iter().zip(&want.data) {
                let msg = format!("strided conv {in_c}x{side} k{k} s{stride}: {a} vs {w}");
                assert!((a - w).abs() < 1e-4, "{msg}");
            }
        }
    }

    #[test]
    fn strided_v1_geometry_chains() {
        let arch = CnnArch::strided_v1(12, 4);
        assert_eq!(arch.conv1_shape().stride, 2);
        // (12 + 2·2 − 5)/2 + 1 = 6, then (6 + 4 − 5)/2 + 1 = 3.
        assert_eq!(arch.conv1_shape().out_h(), 6);
        assert_eq!(arch.conv2_shape().in_h, 6);
        assert_eq!(arch.conv2_shape().out_h(), 3);
        assert_eq!(arch.flat_len(), 12 * 9);
        assert_eq!(CnnVariant::parse("strided-v1"), Some(CnnVariant::StridedV1));
        assert_eq!(CnnVariant::parse(CnnVariant::Pooled.label()), Some(CnnVariant::Pooled));
        assert_eq!(CnnVariant::parse("nope"), None);
    }

    #[test]
    fn strided_v1_forward_shapes_and_backprop_runs() {
        let b = fb();
        let mut rng = SplitMix64::new(31);
        let arch = CnnArch { c1: 3, c2: 4, hidden: 10, ..CnnArch::strided_v1(12, 3) };
        let cnn = Cnn::init(&b, &arch, InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(
            4,
            arch.input_len(),
            (0..4 * arch.input_len()).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        );
        let cache = cnn.forward(&b, &x);
        assert!(cache.route1.is_empty() && cache.route2.is_empty(), "no pool routing");
        assert_eq!(cache.p1.cols, 3 * 36, "p1 is the conv-1 activation map");
        assert_eq!(cache.p2.cols, arch.flat_len());
        let (g, s) = cnn.backprop(&b, &x, &[0, 1, 2, 0]);
        assert_eq!(g.dw.len(), 4);
        assert_eq!(g.dw[0].rows, arch.conv1_shape().patch_len());
        assert!(s.loss > 0.0);
    }

    /// Finite-difference gradcheck through the strided variant: with no
    /// pools in the path this pins the stride-2 col2im backward exactly
    /// where the pooled gradcheck (tests/train_integration.rs) cannot.
    #[test]
    fn strided_v1_gradcheck_float() {
        let b = fb();
        let mut rng = SplitMix64::new(37);
        let arch = CnnArch { c1: 2, c2: 3, k: 3, pad: 1, hidden: 8, ..CnnArch::strided_v1(8, 3) };
        let mut cnn = Cnn::init(&b, &arch, InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(
            3,
            arch.input_len(),
            (0..3 * arch.input_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let labels = vec![0usize, 2, 1];
        let loss_of = |m: &Cnn<f32>| -> f64 { m.backprop(&b, &x, &labels).1.loss };
        let (grads, _) = cnn.backprop(&b, &x, &labels);
        let eps = 1e-3f32;
        fn layer_w(cnn: &mut Cnn<f32>, l: usize) -> &mut Vec<f32> {
            match l {
                0 => &mut cnn.conv1.w.data,
                1 => &mut cnn.conv2.w.data,
                2 => &mut cnn.fc1.w.data,
                _ => &mut cnn.fc2.w.data,
            }
        }
        for (l, idx) in [(0usize, 3usize), (0, 11), (1, 5), (1, 40), (2, 7), (3, 2)] {
            let orig = layer_w(&mut cnn, l)[idx];
            layer_w(&mut cnn, l)[idx] = orig + eps;
            let lp = loss_of(&cnn);
            layer_w(&mut cnn, l)[idx] = orig - eps;
            let lm = loss_of(&cnn);
            layer_w(&mut cnn, l)[idx] = orig;
            let num = (lp - lm) / (2.0 * eps as f64);
            let ana = grads.dw[l].data[idx] as f64;
            assert!(
                (num - ana).abs() < 1e-2 * (1.0 + num.abs()),
                "strided layer {l} idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn conv_backward_is_exact_for_linear_loss() {
        // Conv output is linear in W, b and x, so with upstream ≡ 1 the
        // analytic gradients equal finite differences up to float
        // rounding — an exact oracle for the im2col/col2im plumbing.
        let b = fb();
        let mut rng = SplitMix64::new(7);
        let shape = ConvShape::square(2, 5, 3, 1, 1);
        let mut layer = Conv2d::init(&b, shape, 3, InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(
            2,
            shape.in_len(),
            (0..2 * shape.in_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let ones = Tensor::full(2, shape.out_len(3), 1.0f32);
        let (cols, _) = layer.forward(&b, &x);
        let (dw, db, dx) = layer.backward(&b, &cols, &ones, true);
        let dx = dx.unwrap();
        let loss = |layer: &Conv2d<f32>, x: &Tensor<f32>| -> f64 {
            let (_, y) = layer.forward(&b, x);
            y.data.iter().map(|&v| v as f64).sum()
        };
        let eps = 1e-2f32;
        for wi in [0usize, 7, 25, dw.len() - 1] {
            let orig = layer.w.data[wi];
            layer.w.data[wi] = orig + eps;
            let lp = loss(&layer, &x);
            layer.w.data[wi] = orig - eps;
            let lm = loss(&layer, &x);
            layer.w.data[wi] = orig;
            let num = (lp - lm) / (2.0 * eps as f64);
            let ana = dw.data[wi] as f64;
            assert!((num - ana).abs() < 1e-2 * (1.0 + num.abs()), "dW[{wi}]: {num} vs {ana}");
        }
        // Bias gradient: every output position contributes 1.
        let patches = 2.0 * shape.patches_per_image() as f64;
        for &g in &db {
            assert!((g as f64 - patches).abs() < 1e-2, "db: {g} vs {patches}");
        }
        // Input gradient via finite differences on x.
        let mut xp = x.clone();
        for xi in [0usize, 13, shape.in_len() - 1] {
            let orig = xp.data[xi];
            xp.data[xi] = orig + eps;
            let lp = loss(&layer, &xp);
            xp.data[xi] = orig - eps;
            let lm = loss(&layer, &xp);
            xp.data[xi] = orig;
            let num = (lp - lm) / (2.0 * eps as f64);
            let ana = dx.data[xi] as f64;
            assert!((num - ana).abs() < 1e-2 * (1.0 + num.abs()), "dX[{xi}]: {num} vs {ana}");
        }
    }

    #[test]
    fn maxpool_forward_and_routing() {
        let b = fb();
        let pool = Pool2d::max(1, 4, 4, 2);
        #[rustfmt::skip]
        let x = Tensor::from_vec(1, 16, vec![
            1.0f32, 2.0, 0.0, 0.0,
            3.0, 4.0, 0.0, 5.0,
            0.0, 0.0, -1.0, -2.0,
            0.0, 0.0, -3.0, -4.0,
        ]);
        let (y, route) = pool.forward(&b, &x);
        assert_eq!(y.data, vec![4.0, 5.0, 0.0, -1.0]);
        assert_eq!(route, vec![5, 7, 8, 10]);
        // Backward routes upstream to the argmax cells only.
        let up = Tensor::from_vec(1, 4, vec![1.0f32, 2.0, 3.0, 4.0]);
        let dx = pool.backward(&b, &route, &up);
        let mut want = vec![0.0f32; 16];
        want[5] = 1.0;
        want[7] = 2.0;
        want[8] = 3.0;
        want[10] = 4.0;
        assert_eq!(dx.data, want);
    }

    #[test]
    fn maxpool_ties_take_first_in_scan_order() {
        let b = fb();
        let pool = Pool2d::max(1, 2, 2, 2);
        let x = Tensor::from_vec(1, 4, vec![7.0f32, 7.0, 7.0, 7.0]);
        let (y, route) = pool.forward(&b, &x);
        assert_eq!(y.data, vec![7.0]);
        assert_eq!(route, vec![0], "strict gt keeps the first maximum");
    }

    #[test]
    fn avgpool_forward_and_conservation() {
        let b = fb();
        let pool = Pool2d::avg(2, 4, 4, 2);
        let mut rng = SplitMix64::new(9);
        let x = Tensor::from_vec(
            3,
            pool.in_len(),
            (0..3 * pool.in_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let (y, route) = pool.forward(&b, &x);
        assert!(route.is_empty());
        // Each output is the window mean.
        let mean00 = (x.at(0, 0) + x.at(0, 1) + x.at(0, 4) + x.at(0, 5)) / 4.0;
        assert!((y.at(0, 0) - mean00).abs() < 1e-6);
        // Backward conserves mass: Σ dx = Σ upstream (k²·(1/k²) = 1).
        let up = Tensor::from_vec(
            3,
            pool.out_len(),
            (0..3 * pool.out_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let dx = pool.backward(&b, &route, &up);
        let su: f64 = up.data.iter().map(|&v| v as f64).sum();
        let sd: f64 = dx.data.iter().map(|&v| v as f64).sum();
        assert!((su - sd).abs() < 1e-4, "{su} vs {sd}");
    }

    #[test]
    #[should_panic(expected = "pool window")]
    fn undersized_pool_panics_with_geometry_error() {
        let _ = Pool2d::max(1, 1, 1, 2).out_h();
    }

    #[test]
    fn arch_geometry_chains() {
        let arch = CnnArch::lenet(28, 10);
        assert_eq!(arch.conv1_shape().out_h(), 28);
        assert_eq!(arch.pool1().out_h(), 14);
        assert_eq!(arch.conv2_shape().out_h(), 14);
        assert_eq!(arch.pool2().out_h(), 7);
        assert_eq!(arch.flat_len(), 12 * 49);
        let small = CnnArch { in_h: 12, in_w: 12, ..CnnArch::lenet(12, 4) };
        assert_eq!(small.flat_len(), 12 * 9);
    }

    #[test]
    fn cnn_forward_shapes_and_backprop_runs() {
        let b = fb();
        let mut rng = SplitMix64::new(4);
        let arch = CnnArch {
            c1: 3,
            c2: 4,
            k: 3,
            pad: 1,
            hidden: 10,
            ..CnnArch::lenet(8, 3)
        };
        let cnn = Cnn::init(&b, &arch, InitScheme::HeNormal, &mut rng);
        let x =
            Tensor::from_vec(5, 64, (0..5 * 64).map(|_| rng.uniform(0.0, 1.0) as f32).collect());
        let cache = cnn.forward(&b, &x);
        assert_eq!(cache.z1.cols, 3 * 64);
        assert_eq!(cache.p1.cols, 3 * 16);
        assert_eq!(cache.p2.cols, arch.flat_len());
        assert_eq!(cache.logits.rows, 5);
        assert_eq!(cache.logits.cols, 3);
        let (g, s) = cnn.backprop(&b, &x, &[0, 1, 2, 0, 1]);
        assert_eq!(g.dw.len(), 4);
        assert_eq!(g.dw[0].rows, 9);
        assert_eq!(g.dw[0].cols, 3);
        assert_eq!(g.db[1].len(), 4);
        assert!(s.loss > 0.0);
        assert!(cnn.param_count() > 0);
    }

    #[test]
    fn permutations_roundtrip() {
        let b = fb();
        let mut rng = SplitMix64::new(6);
        let (batch, oh, ow, c) = (3usize, 4usize, 5usize, 2usize);
        let y = Tensor::from_vec(
            batch,
            c * oh * ow,
            (0..batch * c * oh * ow).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let rows = images_to_patch_rows(&b, &y, oh, ow, c);
        assert_eq!(rows.rows, batch * oh * ow);
        let back = patch_rows_to_images(&b, &rows, batch, oh, ow, c);
        assert_eq!(back.data, y.data);
    }
}
