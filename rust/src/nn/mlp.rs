//! Backend-generic multi-layer perceptron with manual backprop.
//!
//! This is the paper's model (784–100–C with llReLU hidden activation and
//! a log-domain soft-max head), generalized to arbitrary depth. Autodiff
//! is impossible through discrete LNS ops, so the backward pass is written
//! out (exactly as the paper does) in terms of backend ⊞/⊡ — the float
//! backend recovers textbook backprop, which the tests exploit as a
//! gradient oracle.
//!
//! Forward and backward run on the row-parallel tensor engine
//! ([`crate::tensor::ops`]): large batches fan their matmuls and the
//! soft-max/CE head across the rayon pool while keeping every reduction
//! bit-identical to the serial reference, so training stays exactly
//! deterministic in the seed. The 784-wide layers are a motivating shape
//! for the engine's cache-tiled kernels: eval-sized batches against the
//! `[784, 100]` weight matrix auto-dispatch onto column-panel tiles
//! (`ops::matmul_tiled`), again with bit-identical results.

use super::grad::{GradStore, RawStepStats};
use super::init::{he_normal_init, log_domain_init, InitScheme};
use crate::obs::{layer_scope, span, SpanKind};
use crate::rng::SplitMix64;
use crate::tensor::{ops, Backend, Tensor};

/// One dense layer's parameters.
#[derive(Clone, Debug)]
pub struct Dense<E> {
    /// `[fan_in, fan_out]` weight matrix.
    pub w: Tensor<E>,
    /// `[fan_out]` bias.
    pub b: Vec<E>,
}

impl<E: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static> Dense<E> {
    /// Initialize one dense layer with the given scheme (bias starts at
    /// zero). Shared by the MLP and the conv subsystem, which reuses it
    /// for both its `[patch_len, out_c]` kernels and its fully-connected
    /// head.
    pub fn init<B: Backend<E = E>>(
        backend: &B,
        fan_in: usize,
        fan_out: usize,
        scheme: InitScheme,
        rng: &mut SplitMix64,
    ) -> Self {
        let n = fan_in * fan_out;
        let data: Vec<E> = match scheme {
            InitScheme::HeNormal => he_normal_init(rng, fan_in, n)
                .into_iter()
                .map(|v| backend.encode(v))
                .collect(),
            InitScheme::LogDomain => log_domain_init(rng, fan_in, n)
                .into_iter()
                .map(|(y, s)| {
                    // Encode from the log-domain sample: v = ±2^y.
                    let mag = y.exp2();
                    backend.encode(if s { mag } else { -mag })
                })
                .collect(),
        };
        Dense { w: Tensor::from_vec(fan_in, fan_out, data), b: vec![backend.zero(); fan_out] }
    }
}

/// An MLP: hidden layers with leaky-ReLU/llReLU, linear head + soft-max.
#[derive(Clone, Debug)]
pub struct Mlp<E> {
    /// Layer sizes, e.g. `[784, 100, 10]`.
    pub dims: Vec<usize>,
    /// Dense layers (`dims.len() − 1` of them).
    pub layers: Vec<Dense<E>>,
}

/// Per-layer gradients, same shapes as the parameters.
#[derive(Clone, Debug)]
pub struct Gradients<E> {
    /// `∂L/∂W` per layer.
    pub dw: Vec<Tensor<E>>,
    /// `∂L/∂b` per layer.
    pub db: Vec<Vec<E>>,
}

/// Loss/accuracy statistics for one batch.
#[derive(Copy, Clone, Debug, Default)]
pub struct StepStats {
    /// Mean cross-entropy (natural log) over the batch.
    pub loss: f64,
    /// Fraction of correct argmax predictions.
    pub accuracy: f64,
}

impl<E: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static> Mlp<E> {
    /// Initialize with the given scheme. Biases start at zero (standard
    /// practice; the paper does not state otherwise).
    pub fn init<B: Backend<E = E>>(
        backend: &B,
        dims: &[usize],
        scheme: InitScheme,
        rng: &mut SplitMix64,
    ) -> Self {
        assert!(dims.len() >= 2, "need at least input and output dims");
        let mut layers = Vec::with_capacity(dims.len() - 1);
        for l in 0..dims.len() - 1 {
            // Same per-layer RNG consumption as the seed: one init stream
            // draw per weight, in layer order.
            layers.push(Dense::init(backend, dims[l], dims[l + 1], scheme, rng));
        }
        Mlp { dims: dims.to_vec(), layers }
    }

    /// Number of scalar parameters.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Forward pass: returns per-layer pre-activations `z_l` and
    /// activations `a_l` (`a_0 = x`), with the head left linear (logits).
    pub fn forward<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
    ) -> (Vec<Tensor<E>>, Vec<Tensor<E>>) {
        assert_eq!(x.cols, self.dims[0], "input width mismatch");
        let _sp = span(SpanKind::Forward);
        let mut zs = Vec::with_capacity(self.layers.len());
        let mut acts = Vec::with_capacity(self.layers.len() + 1);
        acts.push(x.clone());
        for (l, layer) in self.layers.iter().enumerate() {
            // Attribute this layer's numerics counters to scope `l + 1`
            // (scope 0 stays "unscoped"); free when counting is off.
            let _scope = layer_scope(l + 1);
            let mut z = ops::matmul(backend, acts.last().unwrap(), &layer.w);
            ops::add_bias(backend, &mut z, &layer.b);
            let a = if l + 1 == self.layers.len() {
                z.clone() // linear head
            } else {
                ops::leaky_relu(backend, &z)
            };
            // Value-distribution sampling of the layer output (read-only
            // probe, gated inside; NUMERICS.md §7).
            crate::obs::dist::record_slice(
                backend,
                crate::obs::dist::TensorClass::Activations,
                l + 1,
                &a.data,
            );
            zs.push(z);
            acts.push(a);
        }
        (zs, acts)
    }

    /// Logits only (inference path).
    pub fn logits<B: Backend<E = E>>(&self, backend: &B, x: &Tensor<E>) -> Tensor<E> {
        let (_, acts) = self.forward(backend, x);
        acts.into_iter().last().unwrap()
    }

    /// Predicted class per row.
    pub fn predict<B: Backend<E = E>>(&self, backend: &B, x: &Tensor<E>) -> Vec<usize> {
        let logits = self.logits(backend, x);
        (0..logits.rows).map(|i| ops::argmax_row(backend, logits.row(i))).collect()
    }

    /// Full training step math: forward, soft-max CE gradient init
    /// (Eq. 13/14), manual backprop, gradient averaging over the batch.
    /// Returns gradients and batch statistics. Does **not** update
    /// parameters — that's [`super::SgdConfig::apply`].
    pub fn backprop<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
        labels: &[usize],
    ) -> (Gradients<E>, StepStats) {
        let (grads, raw) = self.backprop_avg(backend, x, labels);
        (grads, raw.finish())
    }

    /// [`Mlp::backprop_sums`] followed by the single `1/B` scale —
    /// averaged gradients with the **raw** statistics still attached, so
    /// the epoch loop can fold exact per-sample loss sums
    /// ([`crate::train::EpochLoss`]). This is the one copy of the
    /// sums+scale composition; [`Mlp::backprop`] delegates here.
    pub fn backprop_avg<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
        labels: &[usize],
    ) -> (Gradients<E>, RawStepStats) {
        let (mut grads, raw) = self.backprop_sums(backend, x, labels);
        // numerics-lint: allow(float-leak) — the single 1/B scale (§3), computed in f64, encoded once
        grads.scale(backend, 1.0 / raw.n as f64);
        (grads, raw)
    }

    /// [`Mlp::backprop`] without the `1/B` averaging: gradients come back
    /// as **raw ⊞-sums over the batch rows** and the statistics as raw
    /// sums ([`RawStepStats`]). This is the shard-mergeable form: because
    /// every sample contributes exactly one ⊞ term per gradient element
    /// (`dW` is a row-ascending `matmul_at` fold, `db` a row-ascending
    /// `col_sum` fold), per-sample calls merged in sample order by
    /// [`crate::train::shard::accumulate_tree`] reproduce this batched
    /// fold bit for bit — the foundation of the sharded trainer's
    /// determinism guarantee.
    pub fn backprop_sums<B: Backend<E = E>>(
        &self,
        backend: &B,
        x: &Tensor<E>,
        labels: &[usize],
    ) -> (Gradients<E>, RawStepStats) {
        let batch = x.rows;
        assert_eq!(labels.len(), batch);
        let (zs, acts) = self.forward(backend, x);
        // The Backward span opens after the forward pass so the trace
        // shows the two phases side by side, head bookkeeping included.
        let _sp = span(SpanKind::Backward);
        let logits = acts.last().unwrap();
        let classes = self.dims[self.dims.len() - 1];

        // δ_head = p − y (per row), plus loss/accuracy bookkeeping —
        // the shared head of [`ops::softmax_ce_head`]: row-parallel for
        // large batches, scalar reduction in row order either way.
        let mut delta = Tensor::full(batch, classes, backend.zero());
        let (loss, correct) = ops::softmax_ce_head(backend, logits, labels, &mut delta);

        // Walk layers backwards: dW_l = a_{l-1}ᵀ · δ, db_l = Σ_rows δ,
        // δ_{l-1} = (δ · W_lᵀ) ⊙ act'(z_{l-1}). Sums stay unscaled; the
        // single `1/B` lives in [`Mlp::backprop`] / the shard reduction.
        let nl = self.layers.len();
        let mut dw = vec![Tensor::full(0, 0, backend.zero()); nl];
        let mut db = vec![Vec::new(); nl];
        for l in (0..nl).rev() {
            let _scope = layer_scope(l + 1);
            dw[l] = ops::matmul_at(backend, &acts[l], &delta);
            db[l] = ops::col_sum(backend, &delta);
            if l > 0 {
                let back = ops::matmul_bt(backend, &delta, &self.layers[l].w);
                delta = ops::leaky_relu_bwd(backend, &zs[l - 1], &back);
            }
        }

        (Gradients { dw, db }, RawStepStats { loss_sum: loss, correct, n: batch })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatBackend;

    fn tiny_mlp(seed: u64) -> (FloatBackend, Mlp<f32>) {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(seed);
        let mlp = Mlp::init(&b, &[4, 6, 3], InitScheme::HeNormal, &mut rng);
        (b, mlp)
    }

    #[test]
    fn shapes_and_param_count() {
        let (_, mlp) = tiny_mlp(1);
        assert_eq!(mlp.layers.len(), 2);
        assert_eq!(mlp.layers[0].w.rows, 4);
        assert_eq!(mlp.layers[0].w.cols, 6);
        assert_eq!(mlp.param_count(), 4 * 6 + 6 + 6 * 3 + 3);
    }

    #[test]
    fn forward_shapes() {
        let (b, mlp) = tiny_mlp(2);
        let x = Tensor::full(5, 4, 0.5f32);
        let (zs, acts) = mlp.forward(&b, &x);
        assert_eq!(zs.len(), 2);
        assert_eq!(acts.len(), 3);
        assert_eq!(acts[2].rows, 5);
        assert_eq!(acts[2].cols, 3);
    }

    /// Finite-difference gradient check: the manual backprop against a
    /// numerical derivative of the float loss. This validates the shared
    /// backprop math that all backends (incl. LNS) reuse.
    #[test]
    fn gradcheck_float() {
        let (b, mut mlp) = tiny_mlp(3);
        let mut rng = SplitMix64::new(99);
        let x = Tensor::from_vec(
            3,
            4,
            (0..12).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        let labels = vec![0usize, 2, 1];

        let loss_of = |m: &Mlp<f32>| -> f64 {
            let (g, s) = m.backprop(&b, &x, &labels);
            let _ = g;
            s.loss
        };

        let (grads, _) = mlp.backprop(&b, &x, &labels);
        let eps = 1e-3f32;
        // Check a scatter of weight coords in both layers.
        for (l, idx) in [(0usize, 5usize), (0, 17), (1, 3), (1, 11)] {
            let orig = mlp.layers[l].w.data[idx];
            mlp.layers[l].w.data[idx] = orig + eps;
            let lp = loss_of(&mlp);
            mlp.layers[l].w.data[idx] = orig - eps;
            let lm = loss_of(&mlp);
            mlp.layers[l].w.data[idx] = orig;
            let num = (lp - lm) / (2.0 * eps as f64);
            let ana = grads.dw[l].data[idx] as f64;
            assert!(
                (num - ana).abs() < 1e-2 * (1.0 + num.abs()),
                "layer {l} idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
        // And bias coords.
        for (l, idx) in [(0usize, 2usize), (1, 1)] {
            let orig = mlp.layers[l].b[idx];
            mlp.layers[l].b[idx] = orig + eps;
            let lp = loss_of(&mlp);
            mlp.layers[l].b[idx] = orig - eps;
            let lm = loss_of(&mlp);
            mlp.layers[l].b[idx] = orig;
            let num = (lp - lm) / (2.0 * eps as f64);
            let ana = grads.db[l][idx] as f64;
            assert!(
                (num - ana).abs() < 1e-2 * (1.0 + num.abs()),
                "bias layer {l} idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn backprop_is_scaled_backprop_sums() {
        let (b, mlp) = tiny_mlp(6);
        let x = Tensor::full(4, 4, 0.3f32);
        let labels = [0usize, 1, 2, 0];
        let (avg, stats) = mlp.backprop(&b, &x, &labels);
        let (mut sums, raw) = mlp.backprop_sums(&b, &x, &labels);
        assert_eq!(raw.n, 4);
        assert_eq!(raw.finish().loss, stats.loss);
        sums.scale(&b, 1.0 / 4.0);
        for l in 0..avg.dw.len() {
            assert_eq!(avg.dw[l].data, sums.dw[l].data, "layer {l} dW");
            assert_eq!(avg.db[l], sums.db[l], "layer {l} db");
        }
    }

    #[test]
    fn deeper_network_backprop_runs() {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(4);
        let mlp = Mlp::init(&b, &[8, 16, 16, 5], InitScheme::HeNormal, &mut rng);
        let x = Tensor::full(2, 8, 0.1f32);
        let (g, s) = mlp.backprop(&b, &x, &[1, 4]);
        assert_eq!(g.dw.len(), 3);
        assert!(s.loss > 0.0);
    }

    #[test]
    fn log_domain_init_trains_equivalently_at_start() {
        // Same seed, both schemes: loss at init should be ~ln(C) either way.
        let b = FloatBackend::default();
        for scheme in [InitScheme::HeNormal, InitScheme::LogDomain] {
            let mut rng = SplitMix64::new(5);
            let mlp = Mlp::init(&b, &[10, 20, 4], scheme, &mut rng);
            let x = Tensor::full(8, 10, 0.2f32);
            let (_, s) = mlp.backprop(&b, &x, &[0, 1, 2, 3, 0, 1, 2, 3]);
            assert!((s.loss - (4.0f64).ln()).abs() < 0.7, "{scheme:?}: {}", s.loss);
        }
    }
}
