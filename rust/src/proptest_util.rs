//! Minimal property-testing helper (proptest is unavailable offline).
//!
//! [`run_prop`] drives a seeded generator through many cases and, on
//! failure, reports the failing case index and seed so the case can be
//! replayed deterministically. Generators are plain closures over
//! [`SplitMix64`].

use crate::rng::SplitMix64;

/// Number of cases per property (mirrors proptest's default).
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` generated inputs. `gen` receives a fresh,
/// per-case RNG stream; `prop` returns `Err(msg)` to fail. Panics with a
/// replayable seed on the first failure.
pub fn run_prop<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut SplitMix64) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut master = SplitMix64::new(seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let mut rng = SplitMix64::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay seed {case_seed:#x}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Convenience: assert an approximate equality inside a property.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {} > {tol}", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivial", 1, 50, |r| r.next_u64(), |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'bad' failed")]
    fn failing_property_panics_with_seed() {
        run_prop("bad", 2, 10, |r| r.next_below(100), |&v| {
            if v < 1000 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.005, 0.01).is_ok());
        assert!(close(1.0, 2.0, 0.01).is_err());
    }
}
