//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! Provides warmed-up, repeated timing with median/p95 statistics and a
//! uniform report line format shared by every `cargo bench` target. Bench
//! binaries are declared with `harness = false` and call [`bench`] /
//! [`bench_n`] directly.
//!
//! Also home to the `BENCH_*.json` trajectory format ([`BenchRecord`]):
//! a flat JSON array of throughput records that the CI benchmark lane
//! appends to on every PR, hand-serialized here because the crate takes
//! no serde dependency.

use std::time::Instant;

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Case label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: usize,
    /// Median per-iteration time, nanoseconds.
    pub median_ns: f64,
    /// 95th-percentile per-iteration time, nanoseconds.
    pub p95_ns: f64,
    /// Mean per-iteration time, nanoseconds.
    pub mean_ns: f64,
    /// Optional throughput denominator ("items" processed per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchStats {
    /// Items per second, when a denominator was supplied.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / (self.median_ns * 1e-9))
    }

    /// The uniform report line.
    pub fn report(&self) -> String {
        let tput = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) => format!("  {:8.2} item/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} median {:>12} p95  ({} iters){}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            tput
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Run `f` repeatedly for roughly `budget_ms` (after `warmup` calls) and
/// collect statistics. `items_per_iter` feeds throughput reporting.
pub fn bench_n<F: FnMut()>(
    name: &str,
    warmup: usize,
    budget_ms: u64,
    items_per_iter: Option<f64>,
    mut f: F,
) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut samples_ns: Vec<f64> = Vec::new();
    while start.elapsed() < budget || samples_ns.len() < 5 {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if samples_ns.len() >= 100_000 {
            break;
        }
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let median_ns = samples_ns[n / 2];
    let p95_ns = samples_ns[((n as f64 * 0.95) as usize).min(n - 1)];
    let mean_ns = samples_ns.iter().sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        median_ns,
        p95_ns,
        mean_ns,
        items_per_iter,
    }
}

/// [`bench_n`] with standard defaults (3 warmups, 300 ms budget), printing
/// the report line.
pub fn bench<F: FnMut()>(name: &str, items_per_iter: Option<f64>, f: F) -> BenchStats {
    let s = bench_n(name, 3, 300, items_per_iter, f);
    println!("{}", s.report());
    s
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One measured throughput point in the repo-root `BENCH_*.json`
/// trajectory. The flat shape is deliberate: every field a plain string
/// (plus one number) keeps the files diffable and the parser trivial.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Git commit the measurement was taken at (or `"uncommitted"`).
    pub commit: String,
    /// UTC date, `YYYY-MM-DD`.
    pub date: String,
    /// Backend tag ([`crate::tensor::Backend::tag`]), e.g. `"log16-bs"`.
    pub backend: String,
    /// Kernel label, e.g. `"matmul_tiled"` or `"autotune[mc=16,kc=128,nc=64]"`.
    pub kernel: String,
    /// Problem shape, e.g. `"256x256x256"`.
    pub shape: String,
    /// Measured multiply-accumulates per second (median-based).
    pub mac_per_s: f64,
}

/// Serialize records as a pretty-printed JSON array (one record per
/// object, stable field order) — the on-disk `BENCH_*.json` format.
pub fn records_to_json(records: &[BenchRecord]) -> String {
    let mut out = String::from("[");
    for (i, r) in records.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("\n  {");
        out.push_str(&format!("\"commit\": {}, ", json_string(&r.commit)));
        out.push_str(&format!("\"date\": {}, ", json_string(&r.date)));
        out.push_str(&format!("\"backend\": {}, ", json_string(&r.backend)));
        out.push_str(&format!("\"kernel\": {}, ", json_string(&r.kernel)));
        out.push_str(&format!("\"shape\": {}, ", json_string(&r.shape)));
        out.push_str(&format!("\"mac_per_s\": {:.1}", r.mac_per_s));
        out.push('}');
    }
    out.push_str("\n]\n");
    out
}

fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse a `BENCH_*.json` array back into records. Minimal hand-rolled
/// parser for the subset [`records_to_json`] emits (flat objects, string
/// and number values); unknown keys are skipped, records missing a field
/// get that field's default. Returns `None` on malformed input.
pub fn records_from_json(text: &str) -> Option<Vec<BenchRecord>> {
    let mut chars = text.char_indices().peekable();
    skip_ws(&mut chars);
    if chars.next()?.1 != '[' {
        return None;
    }
    let mut records = Vec::new();
    loop {
        skip_ws(&mut chars);
        match chars.peek()?.1 {
            ']' => {
                chars.next();
                return Some(records);
            }
            ',' => {
                chars.next();
            }
            '{' => {
                chars.next();
                let mut r = BenchRecord {
                    commit: String::new(),
                    date: String::new(),
                    backend: String::new(),
                    kernel: String::new(),
                    shape: String::new(),
                    mac_per_s: 0.0,
                };
                loop {
                    skip_ws(&mut chars);
                    match chars.peek()?.1 {
                        '}' => {
                            chars.next();
                            break;
                        }
                        ',' => {
                            chars.next();
                            continue;
                        }
                        _ => {}
                    }
                    let key = parse_json_string(&mut chars)?;
                    skip_ws(&mut chars);
                    if chars.next()?.1 != ':' {
                        return None;
                    }
                    skip_ws(&mut chars);
                    if chars.peek()?.1 == '"' {
                        let v = parse_json_string(&mut chars)?;
                        match key.as_str() {
                            "commit" => r.commit = v,
                            "date" => r.date = v,
                            "backend" => r.backend = v,
                            "kernel" => r.kernel = v,
                            "shape" => r.shape = v,
                            _ => {}
                        }
                    } else {
                        let v = parse_json_number(&mut chars)?;
                        if key == "mac_per_s" {
                            r.mac_per_s = v;
                        }
                    }
                }
                records.push(r);
            }
            _ => return None,
        }
    }
}

type CharStream<'a> = std::iter::Peekable<std::str::CharIndices<'a>>;

fn skip_ws(chars: &mut CharStream) {
    while chars.peek().is_some_and(|&(_, c)| c.is_whitespace()) {
        chars.next();
    }
}

fn parse_json_string(chars: &mut CharStream) -> Option<String> {
    if chars.next()?.1 != '"' {
        return None;
    }
    let mut out = String::new();
    loop {
        match chars.next()?.1 {
            '"' => return Some(out),
            '\\' => match chars.next()?.1 {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                'n' => out.push('\n'),
                't' => out.push('\t'),
                'u' => {
                    let mut v = 0u32;
                    for _ in 0..4 {
                        v = v * 16 + chars.next()?.1.to_digit(16)?;
                    }
                    out.push(char::from_u32(v)?);
                }
                _ => return None,
            },
            c => out.push(c),
        }
    }
}

fn parse_json_number(chars: &mut CharStream) -> Option<f64> {
    let mut buf = String::new();
    while chars.peek().is_some_and(|&(_, c)| c.is_ascii_digit() || "+-.eE".contains(c)) {
        buf.push(chars.next()?.1);
    }
    buf.parse().ok()
}

/// A parsed JSON value — just enough structure for
/// [`validate_chrome_trace`] to walk a trace document.
enum JsonValue {
    Null,
    Bool(#[allow(dead_code)] bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

fn parse_json_value(chars: &mut CharStream) -> Option<JsonValue> {
    skip_ws(chars);
    match chars.peek()?.1 {
        '"' => Some(JsonValue::Str(parse_json_string(chars)?)),
        '{' => {
            chars.next();
            let mut obj = Vec::new();
            skip_ws(chars);
            if chars.peek()?.1 == '}' {
                chars.next();
                return Some(JsonValue::Obj(obj));
            }
            loop {
                skip_ws(chars);
                let key = parse_json_string(chars)?;
                skip_ws(chars);
                if chars.next()?.1 != ':' {
                    return None;
                }
                obj.push((key, parse_json_value(chars)?));
                skip_ws(chars);
                match chars.next()?.1 {
                    ',' => continue,
                    '}' => return Some(JsonValue::Obj(obj)),
                    _ => return None,
                }
            }
        }
        '[' => {
            chars.next();
            let mut arr = Vec::new();
            skip_ws(chars);
            if chars.peek()?.1 == ']' {
                chars.next();
                return Some(JsonValue::Arr(arr));
            }
            loop {
                arr.push(parse_json_value(chars)?);
                skip_ws(chars);
                match chars.next()?.1 {
                    ',' => continue,
                    ']' => return Some(JsonValue::Arr(arr)),
                    _ => return None,
                }
            }
        }
        c if c.is_ascii_alphabetic() => {
            let mut word = String::new();
            while chars.peek().is_some_and(|&(_, c)| c.is_ascii_alphabetic()) {
                word.push(chars.next()?.1);
            }
            match word.as_str() {
                "true" => Some(JsonValue::Bool(true)),
                "false" => Some(JsonValue::Bool(false)),
                "null" => Some(JsonValue::Null),
                _ => None,
            }
        }
        _ => Some(JsonValue::Num(parse_json_number(chars)?)),
    }
}

/// Validate a Chrome `trace_event` document as produced by
/// [`crate::obs::trace::write_chrome_trace`]: the text must parse as a
/// JSON object whose `traceEvents` member is an array of event objects
/// with a `name`, a numeric `tid`, and per-tid balanced `"B"`/`"E"`
/// duration pairs. Returns the completed-span count (the number of `"E"`
/// events). This is the checker the CI obs-smoke step runs over a real
/// `--trace` artifact — it proves the writer emits loadable JSON without
/// taking a JSON (or browser) dependency.
pub fn validate_chrome_trace(text: &str) -> Result<usize, String> {
    let mut chars = text.char_indices().peekable();
    let doc = parse_json_value(&mut chars).ok_or_else(|| "trace is not valid JSON".to_string())?;
    skip_ws(&mut chars);
    if chars.next().is_some() {
        return Err("trailing bytes after the JSON document".into());
    }
    let JsonValue::Obj(top) = doc else {
        return Err("top level is not a JSON object".into());
    };
    let top_field = |name: &str| top.iter().find(|(k, _)| k == name).map(|(_, v)| v);
    let Some(JsonValue::Arr(events)) = top_field("traceEvents") else {
        return Err("no traceEvents array at the top level".into());
    };
    let mut depth: std::collections::HashMap<i64, i64> = std::collections::HashMap::new();
    let mut completed = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let JsonValue::Obj(ev) = ev else {
            return Err(format!("traceEvents[{i}] is not an object"));
        };
        let field = |name: &str| ev.iter().find(|(k, _)| k == name).map(|(_, v)| v);
        let Some(JsonValue::Str(ph)) = field("ph") else {
            return Err(format!("traceEvents[{i}] has no \"ph\" string"));
        };
        let tid = match field("tid") {
            Some(JsonValue::Num(t)) => *t as i64,
            _ => return Err(format!("traceEvents[{i}] has no numeric \"tid\"")),
        };
        if !matches!(field("name"), Some(JsonValue::Str(_))) {
            return Err(format!("traceEvents[{i}] has no \"name\" string"));
        }
        match ph.as_str() {
            "B" => *depth.entry(tid).or_insert(0) += 1,
            "E" => {
                let d = depth.entry(tid).or_insert(0);
                if *d == 0 {
                    return Err(format!(
                        "traceEvents[{i}]: \"E\" with no open \"B\" on tid {tid}"
                    ));
                }
                *d -= 1;
                completed += 1;
            }
            other => return Err(format!("traceEvents[{i}]: unsupported ph {other:?}")),
        }
    }
    if let Some((tid, d)) = depth.iter().find(|(_, &d)| d != 0) {
        return Err(format!("{d} unclosed \"B\" event(s) on tid {tid}"));
    }
    Ok(completed)
}

/// Today's UTC date as `YYYY-MM-DD`, from the system clock (no chrono
/// dependency; days-to-civil conversion per Howard Hinnant's algorithm).
pub fn utc_date_string() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0);
    let (y, m, d) = civil_from_days(secs.div_euclid(86_400));
    format!("{y:04}-{m:02}-{d:02}")
}

/// Days-since-1970-01-01 → (year, month, day), proleptic Gregorian.
fn civil_from_days(days: i64) -> (i64, u32, u32) {
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (y + i64::from(m <= 2), m, d)
}

/// Compare a fresh run against a baseline: for every `(backend, kernel,
/// shape)` present in both, report a line when the new throughput fell
/// more than `tol` (fractional, e.g. `0.10`) below the baseline. Keys
/// only in one set are ignored — kernels come and go across PRs.
pub fn regressions(new: &[BenchRecord], old: &[BenchRecord], tol: f64) -> Vec<String> {
    let mut out = Vec::new();
    for n in new {
        let Some(o) = old
            .iter()
            .find(|o| o.backend == n.backend && o.kernel == n.kernel && o.shape == n.shape)
        else {
            continue;
        };
        if o.mac_per_s > 0.0 && n.mac_per_s < o.mac_per_s * (1.0 - tol) {
            out.push(format!(
                "{}/{}/{}: {:.3e} MAC/s vs baseline {:.3e} ({:+.1}%)",
                n.backend,
                n.kernel,
                n.shape,
                n.mac_per_s,
                o.mac_per_s,
                (n.mac_per_s / o.mac_per_s - 1.0) * 100.0
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let mut acc = 0u64;
        let s = bench_n("noop-ish", 1, 10, Some(100.0), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 5);
        assert!(s.median_ns >= 0.0);
        assert!(s.p95_ns >= s.median_ns);
        assert!(s.throughput().unwrap() > 0.0);
    }

    fn rec(backend: &str, kernel: &str, shape: &str, mac_per_s: f64) -> BenchRecord {
        BenchRecord {
            commit: "abc1234".into(),
            date: "2026-08-08".into(),
            backend: backend.into(),
            kernel: kernel.into(),
            shape: shape.into(),
            mac_per_s,
        }
    }

    #[test]
    fn records_json_round_trip() {
        let records = vec![
            rec("log16-bs", "mac_panel_lane", "256x256x256", 1.25e9),
            rec("float32", "autotune[mc=16,kc=128,nc=64]", "256x784x100", 3.5e9),
        ];
        let text = records_to_json(&records);
        assert_eq!(records_from_json(&text).unwrap(), records);
        assert!(records_from_json("[]").unwrap().is_empty());
        assert!(records_from_json("[\n]\n").unwrap().is_empty());
        assert!(records_from_json("not json").is_none());
        assert!(records_from_json("[{\"commit\": }]").is_none());
    }

    #[test]
    fn records_json_tolerates_unknown_keys() {
        let text = r#"[
          {"commit": "x", "extra": "ignored", "n_iters": 42,
           "backend": "lin16", "kernel": "k", "shape": "8x8x8",
           "mac_per_s": 12.5, "date": "2026-01-01"}
        ]"#;
        let got = records_from_json(text).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].backend, "lin16");
        assert_eq!(got[0].mac_per_s, 12.5);
        assert_eq!(got[0].date, "2026-01-01");
    }

    #[test]
    fn json_string_escapes() {
        assert_eq!(json_string("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        let mut chars = "\"a\\\"b\\\\c\\nd\"".char_indices().peekable();
        assert_eq!(parse_json_string(&mut chars).unwrap(), "a\"b\\c\nd");
    }

    #[test]
    fn civil_from_days_known_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1)); // leap year start
        assert_eq!(civil_from_days(19_782), (2024, 2, 29)); // leap day
        assert_eq!(civil_from_days(-1), (1969, 12, 31));
        let today = utc_date_string();
        assert_eq!(today.len(), 10);
        assert_eq!(today.as_bytes()[4], b'-');
    }

    #[test]
    fn regressions_flags_only_real_drops() {
        let old = vec![
            rec("log16-bs", "matmul_tiled", "256x256x256", 1.0e9),
            rec("float32", "matmul_tiled", "256x256x256", 2.0e9),
        ];
        let new = vec![
            rec("log16-bs", "matmul_tiled", "256x256x256", 0.85e9), // -15%
            rec("float32", "matmul_tiled", "256x256x256", 1.95e9),  // -2.5%
            rec("lin16", "brand_new_kernel", "256x256x256", 1.0),   // no baseline
        ];
        let hits = regressions(&new, &old, 0.10);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].contains("log16-bs"), "{hits:?}");
        assert!(regressions(&new, &old, 0.20).is_empty());
    }

    #[test]
    fn chrome_trace_validator_accepts_balanced_pairs() {
        let good = r#"{"displayTimeUnit":"ms","traceEvents":[
          {"name":"forward","cat":"lnsdnn","ph":"B","pid":1,"tid":1,"ts":0.000},
          {"name":"eval","cat":"lnsdnn","ph":"B","pid":1,"tid":2,"ts":1.500},
          {"name":"eval","cat":"lnsdnn","ph":"E","pid":1,"tid":2,"ts":2.000},
          {"name":"forward","cat":"lnsdnn","ph":"E","pid":1,"tid":1,"ts":3.250}
        ],"otherData":{"dropped_spans":0}}"#;
        assert_eq!(validate_chrome_trace(good), Ok(2));
        let empty = r#"{"traceEvents":[]}"#;
        assert_eq!(validate_chrome_trace(empty), Ok(0));
    }

    #[test]
    fn chrome_trace_validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("[1,2,3]").is_err());
        assert!(validate_chrome_trace(r#"{"events":[]}"#).is_err());
        // Unbalanced: E without B on that tid.
        let bad = r#"{"traceEvents":[
          {"name":"x","ph":"E","tid":1,"ts":0.0}
        ]}"#;
        assert!(validate_chrome_trace(bad).unwrap_err().contains("no open"), "{bad}");
        // Unclosed B at end of stream.
        let open = r#"{"traceEvents":[
          {"name":"x","ph":"B","tid":1,"ts":0.0}
        ]}"#;
        assert!(validate_chrome_trace(open).unwrap_err().contains("unclosed"), "{open}");
        // Trailing garbage after a valid document.
        assert!(validate_chrome_trace("{\"traceEvents\":[]} x").is_err());
    }

    #[test]
    fn report_formats() {
        let s = BenchStats {
            name: "x".into(),
            iters: 10,
            median_ns: 1500.0,
            p95_ns: 2e6,
            mean_ns: 1600.0,
            items_per_iter: Some(1e6),
        };
        let r = s.report();
        assert!(r.contains("µs"), "{r}");
        assert!(r.contains("ms"), "{r}");
    }
}
