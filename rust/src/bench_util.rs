//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! Provides warmed-up, repeated timing with median/p95 statistics and a
//! uniform report line format shared by every `cargo bench` target. Bench
//! binaries are declared with `harness = false` and call [`bench`] /
//! [`bench_n`] directly.

use std::time::Instant;

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Case label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: usize,
    /// Median per-iteration time, nanoseconds.
    pub median_ns: f64,
    /// 95th-percentile per-iteration time, nanoseconds.
    pub p95_ns: f64,
    /// Mean per-iteration time, nanoseconds.
    pub mean_ns: f64,
    /// Optional throughput denominator ("items" processed per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchStats {
    /// Items per second, when a denominator was supplied.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / (self.median_ns * 1e-9))
    }

    /// The uniform report line.
    pub fn report(&self) -> String {
        let tput = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) => format!("  {:8.2} item/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} median {:>12} p95  ({} iters){}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            tput
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Run `f` repeatedly for roughly `budget_ms` (after `warmup` calls) and
/// collect statistics. `items_per_iter` feeds throughput reporting.
pub fn bench_n<F: FnMut()>(
    name: &str,
    warmup: usize,
    budget_ms: u64,
    items_per_iter: Option<f64>,
    mut f: F,
) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut samples_ns: Vec<f64> = Vec::new();
    while start.elapsed() < budget || samples_ns.len() < 5 {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if samples_ns.len() >= 100_000 {
            break;
        }
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let median_ns = samples_ns[n / 2];
    let p95_ns = samples_ns[((n as f64 * 0.95) as usize).min(n - 1)];
    let mean_ns = samples_ns.iter().sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        median_ns,
        p95_ns,
        mean_ns,
        items_per_iter,
    }
}

/// [`bench_n`] with standard defaults (3 warmups, 300 ms budget), printing
/// the report line.
pub fn bench<F: FnMut()>(name: &str, items_per_iter: Option<f64>, f: F) -> BenchStats {
    let s = bench_n(name, 3, 300, items_per_iter, f);
    println!("{}", s.report());
    s
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let mut acc = 0u64;
        let s = bench_n("noop-ish", 1, 10, Some(100.0), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 5);
        assert!(s.median_ns >= 0.0);
        assert!(s.p95_ns >= s.median_ns);
        assert!(s.throughput().unwrap() > 0.0);
    }

    #[test]
    fn report_formats() {
        let s = BenchStats {
            name: "x".into(),
            iters: 10,
            median_ns: 1500.0,
            p95_ns: 2e6,
            mean_ns: 1600.0,
            items_per_iter: Some(1e6),
        };
        let r = s.report();
        assert!(r.contains("µs"), "{r}");
        assert!(r.contains("ms"), "{r}");
    }
}
