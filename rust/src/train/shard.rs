//! Sharded data-parallel training: split a mini-batch across workers,
//! reduce gradients in a fixed topology, stay bit-exact.
//!
//! # The reduction contract
//!
//! LNS ⊞ is approximate and **non-associative**, so "average the shard
//! gradients" is not a well-defined number until the grouping of the ⊞
//! chain is pinned. This module pins it:
//!
//! 1. A mini-batch of `B` samples is split into `B` per-sample gradient
//!    partials (the existing backward passes run per sample — see
//!    [`crate::nn::Mlp::backprop_sums`] /
//!    [`crate::nn::Cnn::backprop_sums`]).
//! 2. The partials are merged by [`accumulate_tree`]: a **fixed-topology
//!    left-leaning binary tree** (a chain) over the *sample index* —
//!    `((g₀ ⊞ g₁) ⊞ g₂) ⊞ …` — evaluated elementwise with the backend's
//!    slice-level ⊞ ([`crate::tensor::Backend::add_slice`]).
//! 3. One final ⊡ by `1/B` ([`crate::nn::GradStore::scale`]).
//!
//! The topology is a function of the batch alone — **never** of the
//! worker count or of which worker finished first — so the trained
//! weights are bit-identical for every `n_shards`, proven across
//! `{1, 2, 4, 8}` on all four backends by `tests/shard_determinism.rs`.
//!
//! # Why a chain and not a balanced tree
//!
//! The chain is the unique topology that makes sharding a *conservative
//! extension* of the serial trainer: in the MLP every sample contributes
//! exactly one ⊞ term per gradient element (`matmul_at` / `col_sum` fold
//! rows ascending), so the chain over per-sample partials reproduces the
//! un-sharded batched fold **bit for bit** — `n_shards = 1` (which keeps
//! the original full-batch backward) and `n_shards ∈ {2, 4, 8}` agree
//! exactly. A balanced tree would parallelize the merge itself but would
//! redefine every historical result. The merge is `O(B·|θ|)` cheap next
//! to the `O(B·model)` backward work, which is what actually fans out
//! across the pool.
//!
//! For the CNN, conv-kernel gradients fold over `B·OH·OW` patch terms,
//! so a per-sample partial is a *subtree* (its own `OH·OW`-term chain),
//! and regrouping is unavoidable under sample sharding. The per-sample
//! order is therefore the canonical order at **every** shard count for
//! `train_cnn` (including 1), keeping the shard-invariance guarantee; it
//! differs from the pre-shard flat patch-major chain only in ⊞ grouping.
//!
//! Future scaling work (multi-process, PJRT offload) plugs into this
//! contract: a remote worker owns a contiguous sample range, computes the
//! same per-sample partials, and the coordinator merges them by index.

use crate::nn::{GradStore, RawStepStats};
use crate::obs::{span, SpanKind};
use crate::tensor::{Backend, Tensor};
use rayon::prelude::*;

/// Most workers the trainer will build a pool for. The determinism
/// guarantee holds for any count (the reduction never sees the worker
/// count); this bound only guards against nonsensical pool sizes.
pub const MAX_SHARDS: usize = 64;

/// Data-parallel execution settings for one training run.
///
/// ```
/// use lnsdnn::train::ShardConfig;
/// let cfg = ShardConfig::with_shards(4);
/// assert!(cfg.is_sharded());
/// // The fallible twin is the single source of truth for the bounds.
/// assert!(ShardConfig::try_with_shards(0).is_err());
/// assert!(!ShardConfig::default().is_sharded());
/// ```
///
/// `n_shards` is a worker-count **cap**, not a boost: a sharded run
/// confines its step and evaluation work to a dedicated pool of exactly
/// that many threads (nested tensor ops included, via rayon pool
/// nesting), while `n_shards = 1` keeps the legacy path on whatever
/// pool the caller provides. On a many-core host, `--shards 2` can
/// therefore be *slower* than the unsharded run on the full global pool
/// — pick `n_shards` near the cores you want the run to own, or use
/// the sweep-level `threads / shards` sizing the coordinator applies.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// Workers the mini-batch (and evaluation chunks) fan out across.
    /// `1` = no dedicated pool (work runs on the ambient rayon pool);
    /// the trained weights are the same either way.
    pub n_shards: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig { n_shards: 1 }
    }
}

impl ShardConfig {
    /// Config with the given worker count. Panics on counts outside
    /// `1..=MAX_SHARDS`; front ends that want an error instead use
    /// [`ShardConfig::try_with_shards`].
    pub fn with_shards(n_shards: usize) -> Self {
        Self::try_with_shards(n_shards).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible twin of [`ShardConfig::with_shards`] — the single source
    /// of truth for what counts are honoured (the CLI maps the error
    /// onto its usual flag-error path instead of panicking).
    pub fn try_with_shards(n_shards: usize) -> Result<Self, String> {
        if (1..=MAX_SHARDS).contains(&n_shards) {
            Ok(ShardConfig { n_shards })
        } else {
            Err(format!("n_shards must be in 1..={MAX_SHARDS}, got {n_shards}"))
        }
    }

    /// Panic early on worker counts the trainer won't honour.
    pub fn validate(&self) {
        if let Err(e) = Self::try_with_shards(self.n_shards) {
            panic!("{e}");
        }
    }

    /// Does this config fan work out at all?
    pub fn is_sharded(&self) -> bool {
        self.n_shards > 1
    }

    /// Build the sized worker pool, or `None` for serial execution. The
    /// pool is built once per training run; per-step work is dispatched
    /// onto it with `install`, and the tensor ops' nested rayon calls
    /// share it via work stealing.
    pub fn build_pool(&self) -> Option<rayon::ThreadPool> {
        self.validate();
        if !self.is_sharded() {
            return None;
        }
        Some(
            rayon::ThreadPoolBuilder::new()
                .num_threads(self.n_shards)
                .thread_name(|i| format!("shard-{i}"))
                .build()
                .expect("building the shard thread pool"),
        )
    }
}

/// One sample's row as a `[1, cols]` tensor (the unit of shard work).
pub fn sample_row<E: Copy>(x: &Tensor<E>, i: usize) -> Tensor<E> {
    Tensor::from_vec(1, x.cols, x.row(i).to_vec())
}

/// The contiguous slot range worker `rank` owns in a batch of `batch`
/// samples split across `workers` workers: the first `batch % workers`
/// workers get one extra slot. The partition is a pure function of
/// `(batch, workers, rank)`, so every process in a multi-process run
/// (see [`crate::train::multiproc`]) derives the identical assignment
/// without negotiation. Ranges may be empty when `batch < workers`.
pub fn worker_range(batch: usize, workers: usize, rank: usize) -> std::ops::Range<usize> {
    assert!(workers > 0, "worker_range needs at least one worker");
    assert!(rank < workers, "rank {rank} out of range for {workers} workers");
    let base = batch / workers;
    let extra = batch % workers;
    let lo = rank * base + rank.min(extra);
    let hi = lo + base + usize::from(rank < extra);
    lo..hi
}

/// [`accumulate_tree`] over a slot table that may have holes: the merge
/// the multi-process coordinator runs after collecting gradient frames.
///
/// A `None` slot means a worker dropped (or never sent) that sample's
/// partial. That is a **hard error**, never a silent skip: removing a
/// term would regroup the non-associative ⊞ chain and quietly change
/// the trained weights, which is exactly what the fixed-topology
/// contract forbids. Slots are merged in index order, so late or
/// out-of-order *arrival* is harmless as long as every slot is filled.
pub fn accumulate_slots<B: Backend, G: GradStore<B>>(
    backend: &B,
    slots: Vec<Option<G>>,
) -> Result<G, String> {
    let missing: Vec<usize> = slots
        .iter()
        .enumerate()
        .filter_map(|(i, s)| s.is_none().then_some(i))
        .collect();
    if !missing.is_empty() {
        return Err(format!(
            "gradient reduction is missing sample slots {missing:?}: a worker dropped \
             mid-run; refusing to regroup the fixed ⊞ chain around the gap"
        ));
    }
    let parts: Vec<G> = slots.into_iter().map(|s| s.unwrap()).collect();
    accumulate_tree(backend, parts).ok_or_else(|| "empty slot table".to_string())
}

/// Merge gradient partials in the canonical fixed topology: the
/// left-leaning binary chain over the *slot index* (see module docs).
///
/// Only the position in `parts` matters — compute the partials in any
/// order, on any worker, and the result is identical as long as each one
/// lands in its own slot (`tests/shard_determinism.rs` proves this by
/// filling the slots in permuted order). Returns `None` for no parts.
pub fn accumulate_tree<B: Backend, G: GradStore<B>>(backend: &B, parts: Vec<G>) -> Option<G> {
    // Every gradient merge — in-process sharding and the multi-process
    // slot table alike — funnels through this chain, so one span here
    // covers the whole reduction phase.
    let _sp = span(SpanKind::Merge);
    let mut it = parts.into_iter();
    let mut acc = it.next()?;
    for p in it {
        acc.accumulate(backend, &p);
    }
    Some(acc)
}

/// One sharded backward pass: fan `local(i)` (the per-sample gradient
/// sums for sample `i`) across the pool — or across the *ambient* rayon
/// pool when no dedicated one was built, which is safe because the
/// reduction depends only on slot positions, never on which worker
/// computed what — then reduce with [`accumulate_tree`] and fold the
/// statistics in sample order.
///
/// Returns **unscaled** sums — callers apply the single `1/B`
/// ([`GradStore::scale`]) exactly as the un-sharded backward passes do.
pub fn sharded_backprop_sums<B, G, F>(
    backend: &B,
    pool: Option<&rayon::ThreadPool>,
    batch: usize,
    local: F,
) -> (G, RawStepStats)
where
    B: Backend,
    G: GradStore<B>,
    F: Fn(usize) -> (G, RawStepStats) + Sync,
{
    assert!(batch > 0, "sharded backward needs a non-empty batch");
    let parts: Vec<(G, RawStepStats)> = match pool {
        Some(p) if batch > 1 => p.install(|| (0..batch).into_par_iter().map(&local).collect()),
        None if batch > 1 => (0..batch).into_par_iter().map(&local).collect(),
        _ => (0..batch).map(&local).collect(),
    };
    let mut stats = RawStepStats::default();
    let mut grads = Vec::with_capacity(parts.len());
    for (g, s) in parts {
        stats.merge(&s);
        grads.push(g);
    }
    let grads = accumulate_tree(backend, grads).expect("non-empty batch");
    (grads, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Gradients, InitScheme, Mlp};
    use crate::rng::SplitMix64;
    use crate::tensor::FloatBackend;

    fn fixture() -> (FloatBackend, Mlp<f32>, Tensor<f32>, Vec<usize>) {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(12);
        let mlp = Mlp::init(&b, &[5, 7, 3], InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(
            6,
            5,
            (0..30).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        (b, mlp, x, vec![0, 1, 2, 0, 1, 2])
    }

    #[test]
    fn config_validates_bounds() {
        ShardConfig::default().validate();
        ShardConfig::with_shards(MAX_SHARDS).validate();
        assert!(!ShardConfig::default().is_sharded());
        assert!(ShardConfig::with_shards(2).is_sharded());
        assert!(ShardConfig::default().build_pool().is_none());
    }

    #[test]
    #[should_panic(expected = "n_shards must be in")]
    fn zero_shards_panics() {
        ShardConfig { n_shards: 0 }.validate();
    }

    #[test]
    fn per_sample_chain_matches_batched_sums_float() {
        // The MLP equivalence theorem, float instance (the LNS instances
        // live in tests/shard_determinism.rs): per-sample partials merged
        // in sample order equal the batched fold exactly.
        let (b, mlp, x, labels) = fixture();
        let (batched, braw) = mlp.backprop_sums(&b, &x, &labels);
        let parts: Vec<(Gradients<f32>, RawStepStats)> = (0..x.rows)
            .map(|i| mlp.backprop_sums(&b, &sample_row(&x, i), &labels[i..i + 1]))
            .collect();
        let mut stats = RawStepStats::default();
        let mut grads = Vec::new();
        for (g, s) in parts {
            stats.merge(&s);
            grads.push(g);
        }
        let merged = accumulate_tree(&b, grads).unwrap();
        assert_eq!(stats.n, braw.n);
        assert_eq!(stats.loss_sum, braw.loss_sum);
        assert_eq!(stats.correct, braw.correct);
        for l in 0..batched.dw.len() {
            assert_eq!(batched.dw[l].data, merged.dw[l].data, "layer {l} dW");
            assert_eq!(batched.db[l], merged.db[l], "layer {l} db");
        }
    }

    #[test]
    fn worker_range_partitions_exactly() {
        for batch in [0usize, 1, 2, 5, 7, 16, 33] {
            for workers in [1usize, 2, 3, 5, 8] {
                let mut covered = Vec::new();
                for rank in 0..workers {
                    let r = worker_range(batch, workers, rank);
                    // Contiguous with the previous worker's range.
                    assert_eq!(r.start, covered.len(), "batch {batch} workers {workers}");
                    covered.extend(r);
                }
                assert_eq!(covered, (0..batch).collect::<Vec<_>>());
                // Balanced: sizes differ by at most one.
                let sizes: Vec<usize> =
                    (0..workers).map(|r| worker_range(batch, workers, r).len()).collect();
                let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "{sizes:?}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn worker_range_rejects_bad_rank() {
        let _ = worker_range(4, 2, 2);
    }

    #[test]
    fn accumulate_slots_matches_tree_when_full() {
        let (b, mlp, x, labels) = fixture();
        let parts: Vec<Gradients<f32>> = (0..x.rows)
            .map(|i| mlp.backprop_sums(&b, &sample_row(&x, i), &labels[i..i + 1]).0)
            .collect();
        let want = accumulate_tree(&b, parts.clone()).unwrap();
        // Fill the slot table in permuted ("late shard") order: arrival
        // order must not matter, only the slot index.
        let mut slots: Vec<Option<Gradients<f32>>> = (0..parts.len()).map(|_| None).collect();
        for i in [3usize, 0, 5, 1, 4, 2] {
            slots[i] = Some(parts[i].clone());
        }
        let got = accumulate_slots(&b, slots).unwrap();
        for l in 0..want.dw.len() {
            assert_eq!(want.dw[l].data, got.dw[l].data, "layer {l}");
            assert_eq!(want.db[l], got.db[l], "layer {l} bias");
        }
    }

    #[test]
    fn accumulate_slots_hard_errors_on_missing_shard() {
        let (b, mlp, x, labels) = fixture();
        let mut slots: Vec<Option<Gradients<f32>>> = (0..x.rows)
            .map(|i| Some(mlp.backprop_sums(&b, &sample_row(&x, i), &labels[i..i + 1]).0))
            .collect();
        // Worker holding slots 2 and 4 "dropped mid-run".
        slots[2] = None;
        slots[4] = None;
        let err = accumulate_slots(&b, slots).unwrap_err();
        assert!(err.contains("[2, 4]"), "{err}");
        assert!(err.contains("refusing to regroup"), "{err}");
        let empty: Vec<Option<Gradients<f32>>> = Vec::new();
        assert!(accumulate_slots(&b, empty).is_err());
    }

    #[test]
    fn sharded_driver_matches_serial_driver() {
        let (b, mlp, x, labels) = fixture();
        let local = |i: usize| mlp.backprop_sums(&b, &sample_row(&x, i), &labels[i..i + 1]);
        let (g_serial, s_serial) = sharded_backprop_sums(&b, None, x.rows, local);
        let pool = ShardConfig::with_shards(4).build_pool().unwrap();
        let (g_par, s_par): (Gradients<f32>, _) =
            sharded_backprop_sums(&b, Some(&pool), x.rows, local);
        assert_eq!(s_serial.loss_sum, s_par.loss_sum);
        for l in 0..g_serial.dw.len() {
            assert_eq!(g_serial.dw[l].data, g_par.dw[l].data, "layer {l}");
        }
    }
}
