//! Sharded data-parallel training: split a mini-batch across workers,
//! reduce gradients in a fixed topology, stay bit-exact.
//!
//! # The reduction contract
//!
//! LNS ⊞ is approximate and **non-associative**, so "average the shard
//! gradients" is not a well-defined number until the grouping of the ⊞
//! chain is pinned. This module pins it:
//!
//! 1. A mini-batch of `B` samples is split into `B` per-sample gradient
//!    partials (the existing backward passes run per sample — see
//!    [`crate::nn::Mlp::backprop_sums`] /
//!    [`crate::nn::Cnn::backprop_sums`]).
//! 2. The partials are merged by [`accumulate_tree`]: a **fixed-topology
//!    left-leaning binary tree** (a chain) over the *sample index* —
//!    `((g₀ ⊞ g₁) ⊞ g₂) ⊞ …` — evaluated elementwise with the backend's
//!    slice-level ⊞ ([`crate::tensor::Backend::add_slice`]).
//! 3. One final ⊡ by `1/B` ([`crate::nn::GradStore::scale`]).
//!
//! The topology is a function of the batch alone — **never** of the
//! worker count or of which worker finished first — so the trained
//! weights are bit-identical for every `n_shards`, proven across
//! `{1, 2, 4, 8}` on all four backends by `tests/shard_determinism.rs`.
//!
//! # Why a chain and not a balanced tree
//!
//! The chain is the unique topology that makes sharding a *conservative
//! extension* of the serial trainer: in the MLP every sample contributes
//! exactly one ⊞ term per gradient element (`matmul_at` / `col_sum` fold
//! rows ascending), so the chain over per-sample partials reproduces the
//! un-sharded batched fold **bit for bit** — `n_shards = 1` (which keeps
//! the original full-batch backward) and `n_shards ∈ {2, 4, 8}` agree
//! exactly. A balanced tree would parallelize the merge itself but would
//! redefine every historical result. The merge is `O(B·|θ|)` cheap next
//! to the `O(B·model)` backward work, which is what actually fans out
//! across the pool.
//!
//! For the CNN, conv-kernel gradients fold over `B·OH·OW` patch terms,
//! so a per-sample partial is a *subtree* (its own `OH·OW`-term chain),
//! and regrouping is unavoidable under sample sharding. The per-sample
//! order is therefore the canonical order at **every** shard count for
//! `train_cnn` (including 1), keeping the shard-invariance guarantee; it
//! differs from the pre-shard flat patch-major chain only in ⊞ grouping.
//!
//! Future scaling work (multi-process, PJRT offload) plugs into this
//! contract: a remote worker owns a contiguous sample range, computes the
//! same per-sample partials, and the coordinator merges them by index.

use crate::nn::{GradStore, RawStepStats};
use crate::tensor::{Backend, Tensor};
use rayon::prelude::*;

/// Most workers the trainer will build a pool for. The determinism
/// guarantee holds for any count (the reduction never sees the worker
/// count); this bound only guards against nonsensical pool sizes.
pub const MAX_SHARDS: usize = 64;

/// Data-parallel execution settings for one training run.
///
/// `n_shards` is a worker-count **cap**, not a boost: a sharded run
/// confines its step and evaluation work to a dedicated pool of exactly
/// that many threads (nested tensor ops included, via rayon pool
/// nesting), while `n_shards = 1` keeps the legacy path on whatever
/// pool the caller provides. On a many-core host, `--shards 2` can
/// therefore be *slower* than the unsharded run on the full global pool
/// — pick `n_shards` near the cores you want the run to own, or use
/// the sweep-level `threads / shards` sizing the coordinator applies.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// Workers the mini-batch (and evaluation chunks) fan out across.
    /// `1` = no dedicated pool (work runs on the ambient rayon pool);
    /// the trained weights are the same either way.
    pub n_shards: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig { n_shards: 1 }
    }
}

impl ShardConfig {
    /// Config with the given worker count. Panics on counts outside
    /// `1..=MAX_SHARDS`; front ends that want an error instead use
    /// [`ShardConfig::try_with_shards`].
    pub fn with_shards(n_shards: usize) -> Self {
        Self::try_with_shards(n_shards).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible twin of [`ShardConfig::with_shards`] — the single source
    /// of truth for what counts are honoured (the CLI maps the error
    /// onto its usual flag-error path instead of panicking).
    pub fn try_with_shards(n_shards: usize) -> Result<Self, String> {
        if (1..=MAX_SHARDS).contains(&n_shards) {
            Ok(ShardConfig { n_shards })
        } else {
            Err(format!("n_shards must be in 1..={MAX_SHARDS}, got {n_shards}"))
        }
    }

    /// Panic early on worker counts the trainer won't honour.
    pub fn validate(&self) {
        if let Err(e) = Self::try_with_shards(self.n_shards) {
            panic!("{e}");
        }
    }

    /// Does this config fan work out at all?
    pub fn is_sharded(&self) -> bool {
        self.n_shards > 1
    }

    /// Build the sized worker pool, or `None` for serial execution. The
    /// pool is built once per training run; per-step work is dispatched
    /// onto it with `install`, and the tensor ops' nested rayon calls
    /// share it via work stealing.
    pub fn build_pool(&self) -> Option<rayon::ThreadPool> {
        self.validate();
        if !self.is_sharded() {
            return None;
        }
        Some(
            rayon::ThreadPoolBuilder::new()
                .num_threads(self.n_shards)
                .thread_name(|i| format!("shard-{i}"))
                .build()
                .expect("building the shard thread pool"),
        )
    }
}

/// One sample's row as a `[1, cols]` tensor (the unit of shard work).
pub fn sample_row<E: Copy>(x: &Tensor<E>, i: usize) -> Tensor<E> {
    Tensor::from_vec(1, x.cols, x.row(i).to_vec())
}

/// Merge gradient partials in the canonical fixed topology: the
/// left-leaning binary chain over the *slot index* (see module docs).
///
/// Only the position in `parts` matters — compute the partials in any
/// order, on any worker, and the result is identical as long as each one
/// lands in its own slot (`tests/shard_determinism.rs` proves this by
/// filling the slots in permuted order). Returns `None` for no parts.
pub fn accumulate_tree<B: Backend, G: GradStore<B>>(backend: &B, parts: Vec<G>) -> Option<G> {
    let mut it = parts.into_iter();
    let mut acc = it.next()?;
    for p in it {
        acc.accumulate(backend, &p);
    }
    Some(acc)
}

/// One sharded backward pass: fan `local(i)` (the per-sample gradient
/// sums for sample `i`) across the pool — or across the *ambient* rayon
/// pool when no dedicated one was built, which is safe because the
/// reduction depends only on slot positions, never on which worker
/// computed what — then reduce with [`accumulate_tree`] and fold the
/// statistics in sample order.
///
/// Returns **unscaled** sums — callers apply the single `1/B`
/// ([`GradStore::scale`]) exactly as the un-sharded backward passes do.
pub fn sharded_backprop_sums<B, G, F>(
    backend: &B,
    pool: Option<&rayon::ThreadPool>,
    batch: usize,
    local: F,
) -> (G, RawStepStats)
where
    B: Backend,
    G: GradStore<B>,
    F: Fn(usize) -> (G, RawStepStats) + Sync,
{
    assert!(batch > 0, "sharded backward needs a non-empty batch");
    let parts: Vec<(G, RawStepStats)> = match pool {
        Some(p) if batch > 1 => p.install(|| (0..batch).into_par_iter().map(&local).collect()),
        None if batch > 1 => (0..batch).into_par_iter().map(&local).collect(),
        _ => (0..batch).map(&local).collect(),
    };
    let mut stats = RawStepStats::default();
    let mut grads = Vec::with_capacity(parts.len());
    for (g, s) in parts {
        stats.merge(&s);
        grads.push(g);
    }
    let grads = accumulate_tree(backend, grads).expect("non-empty batch");
    (grads, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Gradients, InitScheme, Mlp};
    use crate::rng::SplitMix64;
    use crate::tensor::FloatBackend;

    fn fixture() -> (FloatBackend, Mlp<f32>, Tensor<f32>, Vec<usize>) {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(12);
        let mlp = Mlp::init(&b, &[5, 7, 3], InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(
            6,
            5,
            (0..30).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        );
        (b, mlp, x, vec![0, 1, 2, 0, 1, 2])
    }

    #[test]
    fn config_validates_bounds() {
        ShardConfig::default().validate();
        ShardConfig::with_shards(MAX_SHARDS).validate();
        assert!(!ShardConfig::default().is_sharded());
        assert!(ShardConfig::with_shards(2).is_sharded());
        assert!(ShardConfig::default().build_pool().is_none());
    }

    #[test]
    #[should_panic(expected = "n_shards must be in")]
    fn zero_shards_panics() {
        ShardConfig { n_shards: 0 }.validate();
    }

    #[test]
    fn per_sample_chain_matches_batched_sums_float() {
        // The MLP equivalence theorem, float instance (the LNS instances
        // live in tests/shard_determinism.rs): per-sample partials merged
        // in sample order equal the batched fold exactly.
        let (b, mlp, x, labels) = fixture();
        let (batched, braw) = mlp.backprop_sums(&b, &x, &labels);
        let parts: Vec<(Gradients<f32>, RawStepStats)> = (0..x.rows)
            .map(|i| mlp.backprop_sums(&b, &sample_row(&x, i), &labels[i..i + 1]))
            .collect();
        let mut stats = RawStepStats::default();
        let mut grads = Vec::new();
        for (g, s) in parts {
            stats.merge(&s);
            grads.push(g);
        }
        let merged = accumulate_tree(&b, grads).unwrap();
        assert_eq!(stats.n, braw.n);
        assert_eq!(stats.loss_sum, braw.loss_sum);
        assert_eq!(stats.correct, braw.correct);
        for l in 0..batched.dw.len() {
            assert_eq!(batched.dw[l].data, merged.dw[l].data, "layer {l} dW");
            assert_eq!(batched.db[l], merged.db[l], "layer {l} db");
        }
    }

    #[test]
    fn sharded_driver_matches_serial_driver() {
        let (b, mlp, x, labels) = fixture();
        let local = |i: usize| mlp.backprop_sums(&b, &sample_row(&x, i), &labels[i..i + 1]);
        let (g_serial, s_serial) = sharded_backprop_sums(&b, None, x.rows, local);
        let pool = ShardConfig::with_shards(4).build_pool().unwrap();
        let (g_par, s_par): (Gradients<f32>, _) =
            sharded_backprop_sums(&b, Some(&pool), x.rows, local);
        assert_eq!(s_serial.loss_sum, s_par.loss_sum);
        for l in 0..g_serial.dw.len() {
            assert_eq!(g_serial.dw[l].data, g_par.dw[l].data, "layer {l}");
        }
    }
}
