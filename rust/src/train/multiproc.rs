//! Multi-process sharded training over serialized [`wire`] frames.
//!
//! This module is the designed-for consumer of the sharded-training
//! reduction contract ([`crate::train::shard`]): it moves the per-sample
//! gradient partials across a **process boundary** (stdio pipes or TCP
//! sockets) and proves the bit-exactness guarantee survives the trip.
//!
//! # Design: mirrored replicas, per-sample frames
//!
//! Every process — the coordinator and each of the `N` workers — runs
//! the *same* deterministic training loop from the *same* [`JobSpec`]:
//! identical seed, identical weight init, identical validation split,
//! identical per-epoch shuffles, identical SGD updates. The only thing
//! that is divided is the per-batch gradient work:
//!
//! 1. For each mini-batch of `m` samples, worker `r` computes the
//!    unscaled per-sample gradient sums for the slots in
//!    [`shard::worker_range`]`(m, N, r)` and sends each one as a
//!    [`FrameKind::GradSums`] frame tagged with its in-batch slot index.
//! 2. The coordinator places the frames into their slots and merges them
//!    with [`shard::accumulate_slots`] → [`shard::accumulate_tree`] — the
//!    canonical left-leaning ⊞ chain over the *sample index*, exactly the
//!    reduction the in-process sharded trainer performs. A missing,
//!    duplicate, or out-of-range slot is a **hard error**; the chain is
//!    never silently regrouped around a dropped worker.
//! 3. The coordinator broadcasts the merged **unscaled** sums back as one
//!    [`FrameKind::Merged`] frame; every replica (coordinator included)
//!    then applies the identical `1/B` scale and SGD update.
//!
//! Because serialization is exact data movement ([`wire::WireElem`]) and
//! the reduction topology is a function of the batch alone, the trained
//! weights are **bit-identical** to the in-process sharded trainer and to
//! the serial trainer, for every worker count, on all four backends —
//! pinned end to end by `tests/multiproc_determinism.rs`. As a belt-and-
//! braces check each worker ends its run with a [`FrameKind::Digest`]
//! frame (FNV-1a over its final parameter words); the coordinator
//! verifies every digest against its own replica and hard-errors on any
//! divergence.
//!
//! Per-sample frames are what make the cross-process chain possible: a
//! worker must not pre-reduce its slot range (except the rank-0 prefix,
//! which we deliberately do not special-case), because merging per-worker
//! subtotals would regroup the non-associative ⊞ chain. The traffic is
//! therefore `B` gradient-sized frames up and one down per step — fine at
//! the paper's mini-batch 5; `benches/multiproc_scaling.rs` measures the
//! trade.
//!
//! Evaluation (validation curve, final test metrics) runs only on the
//! coordinator's replica, through the same [`evaluate_with`] entry point
//! as the in-process trainer, so reported metrics are bit-identical too.
//!
//! # Transports
//!
//! [`Transport::Stdio`] pipes frames through the worker's stdin/stdout
//! (workers must keep stdout clean — diagnostics go to stderr);
//! [`Transport::Tcp`] connects workers to an ephemeral loopback listener.
//! Process spawning lives in [`crate::coordinator::server`]; this module
//! is transport-agnostic over [`PeerIo`] byte streams, which is what lets
//! the unit tests drive the full protocol over in-memory pipes
//! ([`mem_pipe`]) without spawning anything.

use crate::data::Dataset;
use crate::fixed::{FixedConfig, FixedSystem};
use crate::lns::{LnsConfig, LnsSystem};
use crate::nn::{
    quantize_cnn, quantize_mlp, Cnn, Gradients, GradStore, InitScheme, Mlp, RawStepStats,
    SgdConfig,
};
use crate::obs::{self, span, SpanKind};
use crate::precision::PrecisionMap;
use crate::rng::SplitMix64;
use crate::tensor::{Backend, FixedBackend, FloatBackend, LnsBackend, Tensor};
use crate::train::wire::{
    self, DigestMsg, FrameKind, GradFrame, HeartbeatMsg, JobSpec, ModelSpec, WireElem,
};
use crate::train::{
    evaluate_with, shard, CnnTrainConfig, EpochLoss, EpochRecord, TrainConfig, TrainResult,
};
use anyhow::{bail, ensure, Context, Result};
use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex};

/// How coordinator and workers exchange frames.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Transport {
    /// Frames over the worker's stdin/stdout pipes.
    Stdio,
    /// Frames over loopback TCP (coordinator listens, workers connect).
    Tcp,
}

impl Transport {
    /// Parse a CLI tag (`stdio` / `tcp`).
    pub fn parse(s: &str) -> Option<Transport> {
        Some(match s {
            "stdio" | "pipe" => Transport::Stdio,
            "tcp" => Transport::Tcp,
            _ => return None,
        })
    }

    /// Report label.
    pub fn label(&self) -> &'static str {
        match self {
            Transport::Stdio => "stdio",
            Transport::Tcp => "tcp",
        }
    }
}

/// Worker-environment knobs that ride in the job frame but are not part
/// of the training hyper-parameters.
#[derive(Copy, Clone, Debug)]
pub struct JobEnv {
    /// Leaky/llReLU slope the coordinator's backend was built with —
    /// **must** match, since workers reconstruct their backend from the
    /// tag + this slope. A mismatch is caught up front by the job
    /// frame's activation probe ([`act_probe`]): the digest alone could
    /// not catch it, because every replica applies the same merged
    /// gradient frames and would stay in lockstep while training
    /// different numbers than the in-process trainer.
    pub slope: f64,
    /// Rayon threads per worker process (0 = library default). The
    /// trained bits are identical for any value; this only moves
    /// wall-clock and core oversubscription.
    pub worker_threads: usize,
}

impl Default for JobEnv {
    fn default() -> Self {
        JobEnv { slope: 0.01, worker_threads: 0 }
    }
}

/// One worker connection as seen by the coordinator: a framed byte
/// stream in each direction. Process/socket details live with whoever
/// built it ([`crate::coordinator::server`] or [`mem_pipe`]).
pub struct PeerIo {
    /// Worker → coordinator frames.
    pub rx: Box<dyn Read + Send>,
    /// Coordinator → worker frames.
    pub tx: Box<dyn Write + Send>,
}

/// Training hyper-parameters shared by both model families (the
/// model-specific part travels as [`ModelSpec`]).
#[derive(Clone, Debug)]
pub struct JobParams {
    /// Epochs.
    pub epochs: usize,
    /// Mini-batch size.
    pub batch_size: usize,
    /// SGD settings.
    pub sgd: SgdConfig,
    /// Validation hold-back denominator.
    pub val_ratio: usize,
    /// Weight-init scheme.
    pub init: InitScheme,
    /// Master seed.
    pub seed: u64,
    /// Per-layer storage-width assignment, replicated to every worker
    /// (wire v4). Every replica must quantize at the same two points
    /// (post-init, post-update) or the digests diverge.
    pub precision: PrecisionMap,
}

// ---------------------------------------------------------------------
// The model abstraction the protocol trains
// ---------------------------------------------------------------------

/// What the multi-process protocol needs from a trainable model. One
/// coordinator loop and one worker loop serve both model families
/// through this trait, so the two cannot drift protocol-wise.
pub trait ProtoModel<B: Backend>: Sized {
    /// Deterministically initialize from a [`ModelSpec`] (same RNG
    /// consumption as the in-process trainers).
    fn from_spec(
        backend: &B,
        spec: &ModelSpec,
        init: InitScheme,
        rng: &mut SplitMix64,
    ) -> Result<Self>;
    /// Input width (pixels).
    fn input_len(&self) -> usize;
    /// Output classes.
    fn classes(&self) -> usize;
    /// Unscaled per-sample gradient sums + raw statistics.
    fn backprop_sums(
        &self,
        backend: &B,
        x: &Tensor<B::E>,
        labels: &[usize],
    ) -> (Gradients<B::E>, RawStepStats);
    /// Per-layer `(w_rows, w_cols, b_len)` gradient shapes in the
    /// canonical order — the decode contract for incoming gradient
    /// frames (see [`build_grads`]).
    fn grad_shapes(&self) -> Vec<(usize, usize, usize)>;
    /// Apply one SGD update.
    fn apply_update(&mut self, backend: &B, sgd: &SgdConfig, grads: &Gradients<B::E>);
    /// Snap parameters to the per-layer storage widths (NUMERICS.md §11).
    /// Called at the same two points as the in-process trainers —
    /// after init and after every update — on every replica.
    fn quantize_params(&mut self, backend: &B, pmap: &PrecisionMap);
    /// Logits for an input chunk (evaluation path).
    fn logits(&self, backend: &B, x: &Tensor<B::E>) -> Tensor<B::E>;
    /// Flat parameter views in canonical layer order (weights then bias
    /// per layer) — digest input.
    fn param_views(&self) -> Vec<&[B::E]>;
}

impl<B: Backend> ProtoModel<B> for Mlp<B::E> {
    fn from_spec(
        backend: &B,
        spec: &ModelSpec,
        init: InitScheme,
        rng: &mut SplitMix64,
    ) -> Result<Self> {
        match spec {
            ModelSpec::Mlp { dims } => {
                ensure!(dims.len() >= 2, "MLP spec needs at least input and output dims");
                Ok(Mlp::init(backend, dims, init, rng))
            }
            ModelSpec::Cnn { .. } => bail!("job spec says CNN but the MLP loop was dispatched"),
        }
    }

    fn input_len(&self) -> usize {
        self.dims[0]
    }

    fn classes(&self) -> usize {
        self.dims[self.dims.len() - 1]
    }

    fn backprop_sums(
        &self,
        backend: &B,
        x: &Tensor<B::E>,
        labels: &[usize],
    ) -> (Gradients<B::E>, RawStepStats) {
        Mlp::backprop_sums(self, backend, x, labels)
    }

    fn grad_shapes(&self) -> Vec<(usize, usize, usize)> {
        self.layers.iter().map(|l| (l.w.rows, l.w.cols, l.b.len())).collect()
    }

    fn apply_update(&mut self, backend: &B, sgd: &SgdConfig, grads: &Gradients<B::E>) {
        sgd.apply(backend, self, grads);
    }

    fn quantize_params(&mut self, backend: &B, pmap: &PrecisionMap) {
        quantize_mlp(backend, self, pmap);
    }

    fn logits(&self, backend: &B, x: &Tensor<B::E>) -> Tensor<B::E> {
        Mlp::logits(self, backend, x)
    }

    fn param_views(&self) -> Vec<&[B::E]> {
        let mut v = Vec::with_capacity(2 * self.layers.len());
        for l in &self.layers {
            v.push(l.w.data.as_slice());
            v.push(l.b.as_slice());
        }
        v
    }
}

impl<B: Backend> ProtoModel<B> for Cnn<B::E> {
    fn from_spec(
        backend: &B,
        spec: &ModelSpec,
        init: InitScheme,
        rng: &mut SplitMix64,
    ) -> Result<Self> {
        match spec {
            ModelSpec::Cnn { arch } => Ok(Cnn::init(backend, arch, init, rng)),
            ModelSpec::Mlp { .. } => bail!("job spec says MLP but the CNN loop was dispatched"),
        }
    }

    fn input_len(&self) -> usize {
        self.arch.input_len()
    }

    fn classes(&self) -> usize {
        self.arch.classes
    }

    fn backprop_sums(
        &self,
        backend: &B,
        x: &Tensor<B::E>,
        labels: &[usize],
    ) -> (Gradients<B::E>, RawStepStats) {
        Cnn::backprop_sums(self, backend, x, labels)
    }

    fn grad_shapes(&self) -> Vec<(usize, usize, usize)> {
        vec![
            (self.conv1.w.rows, self.conv1.w.cols, self.conv1.b.len()),
            (self.conv2.w.rows, self.conv2.w.cols, self.conv2.b.len()),
            (self.fc1.w.rows, self.fc1.w.cols, self.fc1.b.len()),
            (self.fc2.w.rows, self.fc2.w.cols, self.fc2.b.len()),
        ]
    }

    fn apply_update(&mut self, backend: &B, sgd: &SgdConfig, grads: &Gradients<B::E>) {
        sgd.apply_cnn(backend, self, grads);
    }

    fn quantize_params(&mut self, backend: &B, pmap: &PrecisionMap) {
        quantize_cnn(backend, self, pmap);
    }

    fn logits(&self, backend: &B, x: &Tensor<B::E>) -> Tensor<B::E> {
        Cnn::logits(self, backend, x)
    }

    fn param_views(&self) -> Vec<&[B::E]> {
        vec![
            self.conv1.w.data.as_slice(),
            self.conv1.b.as_slice(),
            self.conv2.w.data.as_slice(),
            self.conv2.b.as_slice(),
            self.fc1.w.data.as_slice(),
            self.fc1.b.as_slice(),
            self.fc2.w.data.as_slice(),
            self.fc2.b.as_slice(),
        ]
    }
}

/// Backend fingerprint carried in the job frame. The worker recomputes
/// it on its reconstructed backend and refuses to run on a mismatch —
/// the tag + slope pair in the job frame under-determines a live
/// backend, and a silent divergence here would train different bits
/// than the in-process trainer while every replica still agreed with
/// every other (the end-of-run digests compare replicas to each other,
/// not to the in-process result).
///
/// The probe exercises each configuration axis a tag cannot express:
/// `leaky_relu(encode(−1))` (slope / word format), ⊞ and ⊟ at generic
/// operands (the Δ± approximation mode *and* LUT shape), the
/// soft-max/CE head (the separate soft-max Δ tables), and — per
/// assigned layer of the precision map — a `quantize` sample at the
/// layer's storage width, so a coordinator/worker disagreement over a
/// per-layer grid is refused at the handshake instead of surfacing as
/// an end-of-run digest divergence. It is a spot check at fixed sample
/// points, not an exhaustive equality proof — but any config divergence
/// visible at these points is caught before a single gradient flows.
pub fn act_probe<B: Backend>(backend: &B, precision: &PrecisionMap) -> Vec<u8>
where
    B::E: WireElem,
{
    let mut out = Vec::with_capacity(64);
    backend.leaky_relu(backend.encode(-1.0)).put(&mut out);
    backend.add(backend.encode(0.75), backend.encode(0.3)).put(&mut out);
    backend.sub(backend.encode(0.9), backend.encode(0.4)).put(&mut out);
    let logits = [backend.encode(0.5), backend.encode(-0.25), backend.encode(0.125)];
    let mut grad = vec![backend.zero(); 3];
    let ln_p = backend.softmax_ce_grad(&logits, 1, &mut grad);
    for g in &grad {
        g.put(&mut out);
    }
    out.extend_from_slice(&ln_p.to_bits().to_le_bytes());
    // Per-layer width samples: a value off every coarser grid, snapped.
    for spec in precision.layers() {
        match spec {
            Some(w) => {
                out.push(1);
                backend.quantize(backend.encode(0.7), *w).put(&mut out);
            }
            None => out.push(0),
        }
    }
    out
}

/// Assemble a [`Gradients`] store directly from decoded wire views —
/// the buffers are *moved* into place (no zero-fill, no copy; this runs
/// once per frame on the protocol's hottest path). Shape mismatches are
/// errors, not panics, because the views come from another process.
pub fn build_grads<E: Copy>(
    shapes: &[(usize, usize, usize)],
    mut views: Vec<Vec<E>>,
) -> Result<Gradients<E>, String> {
    if views.len() != 2 * shapes.len() {
        return Err(format!(
            "gradient layout mismatch: {} views on the wire, the model has {}",
            views.len(),
            2 * shapes.len()
        ));
    }
    let mut dw = Vec::with_capacity(shapes.len());
    let mut db = Vec::with_capacity(shapes.len());
    for (l, &(rows, cols, b_len)) in shapes.iter().enumerate() {
        let w = std::mem::take(&mut views[2 * l]);
        let b = std::mem::take(&mut views[2 * l + 1]);
        if w.len() != rows * cols || b.len() != b_len {
            return Err(format!(
                "gradient view {l} shape mismatch: got {}/{} elements, want {}/{b_len}",
                w.len(),
                b.len(),
                rows * cols
            ));
        }
        dw.push(Tensor::from_vec(rows, cols, w));
        db.push(b);
    }
    Ok(Gradients { dw, db })
}

/// FNV-1a digest over a model's parameter words (wire encoding, canonical
/// layer order) — the end-of-run replica-divergence check.
pub fn param_digest<B, M>(model: &M) -> DigestMsg
where
    B: Backend,
    M: ProtoModel<B>,
    B::E: WireElem,
{
    let mut bytes = Vec::new();
    let mut params = 0u64;
    for view in model.param_views() {
        params += view.len() as u64;
        for e in view {
            e.put(&mut bytes);
        }
    }
    DigestMsg { digest: wire::fnv1a64(&bytes), params }
}

// ---------------------------------------------------------------------
// Worker heartbeats (observability)
// ---------------------------------------------------------------------

/// Heartbeat cadence in steps. Emission is a pure function of the step
/// index (plus "final batch of the epoch"), never of wall-clock time, so
/// the frame sequence is reproducible run-to-run.
const HEARTBEAT_EVERY: u32 = 8;

/// Last-known progress of one worker, distilled from its heartbeat
/// frames on the coordinator side.
#[derive(Clone, Debug, Default)]
struct WorkerHealth {
    last: Option<HeartbeatSeen>,
}

#[derive(Clone, Debug)]
struct HeartbeatSeen {
    epoch: u32,
    step: u32,
    samples_done: u64,
    at: std::time::Instant,
}

fn note_heartbeat(health: &mut [WorkerHealth], rank: usize, hb: &HeartbeatMsg) {
    if obs::counters_enabled() {
        obs::metrics::HEARTBEAT_RX.add(1);
        // Fold the worker's distribution delta into its per-rank
        // accumulation (feeds the /metrics fleet view) and refresh its
        // /health freshness record. Both are observation-only.
        obs::dist::merge_worker_delta(hb.rank, &hb.dist);
        obs::serve::note_worker(hb.rank, hb.epoch, hb.step, hb.samples_done);
    }
    health[rank].last = Some(HeartbeatSeen {
        epoch: hb.epoch,
        step: hb.step,
        samples_done: hb.samples_done,
        // numerics-lint: allow(nondeterminism) — heartbeat freshness timestamp: telemetry only (§7)
        at: std::time::Instant::now(),
    });
    if obs::metrics::table_enabled() {
        eprintln!(
            "[obs] worker {rank}: epoch {} step {} ({} samples done)",
            hb.epoch, hb.step, hb.samples_done
        );
    }
}

fn describe_last_heartbeat(h: &WorkerHealth) -> String {
    match &h.last {
        Some(hb) => format!(
            "last heartbeat: epoch {} step {} ({} samples done), {} ms ago",
            hb.epoch,
            hb.step,
            hb.samples_done,
            hb.at.elapsed().as_millis()
        ),
        None => "no heartbeat received from this worker".into(),
    }
}

/// Read the next non-heartbeat frame from a worker, folding heartbeat
/// frames into its health record along the way. A read failure becomes
/// a dead-worker report carrying the worker's last-known progress; the
/// detection latency (now − last heartbeat) feeds the
/// [`obs::metrics::WORKER_DETECT_LATENCY_MS`] histogram.
fn read_data_frame(
    peer: &mut PeerIo,
    rank: usize,
    health: &mut [WorkerHealth],
) -> Result<wire::Frame> {
    loop {
        let frame = match wire::read_frame(&mut peer.rx) {
            Ok(f) => f,
            Err(e) => {
                if obs::counters_enabled() {
                    obs::metrics::WORKER_DEATHS.add(1);
                    if let Some(hb) = &health[rank].last {
                        obs::metrics::WORKER_DETECT_LATENCY_MS
                            .record(hb.at.elapsed().as_millis() as u64);
                    }
                }
                let ctx = describe_last_heartbeat(&health[rank]);
                return Err(e.context(format!("worker {rank} stream failed ({ctx})")));
            }
        };
        if frame.kind == FrameKind::Heartbeat {
            let hb = HeartbeatMsg::decode(&frame.payload)?;
            ensure!(
                hb.rank as usize == rank,
                "heartbeat for rank {} arrived on worker {rank}'s stream",
                hb.rank
            );
            note_heartbeat(health, rank, &hb);
            continue;
        }
        return Ok(frame);
    }
}

/// Worker side: emit a heartbeat frame if this step is on the cadence.
/// Only fires when this process has counters enabled — the payload
/// (span rollups + counter totals) would be empty noise otherwise.
fn maybe_heartbeat<W: Write>(
    tx: &mut W,
    job: &JobSpec,
    epoch: usize,
    step: u32,
    samples_done: u64,
    last_batch: bool,
) -> Result<()> {
    if !obs::counters_enabled() {
        return Ok(());
    }
    if step % HEARTBEAT_EVERY != 0 && !last_batch {
        return Ok(());
    }
    let hb = HeartbeatMsg {
        rank: job.rank as u32,
        epoch: epoch as u32,
        step,
        samples_done,
        spans: obs::trace::rollup_snapshot()
            .into_iter()
            .map(|(name, count, ns)| (name.to_string(), count, ns))
            .collect(),
        counters: obs::metrics::named_totals(),
        dist: obs::dist::take_wire_delta(),
    };
    obs::metrics::HEARTBEAT_TX.add(1);
    wire::write_frame(tx, FrameKind::Heartbeat, &hb.encode())
        .with_context(|| format!("worker {}: sending heartbeat", job.rank))
}

// ---------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------

/// Drive a multi-process MLP training run over already-established
/// worker connections. Spawning helpers live in
/// [`crate::coordinator::server::train_multiproc`]; this function owns
/// the protocol only, so tests can drive it over [`mem_pipe`] streams.
///
/// `cfg.shard` is ignored: the worker processes *are* the shards here
/// (each computes its slot range serially; tensor-op parallelism inside
/// a worker is governed by [`JobEnv::worker_threads`]).
pub fn coordinate_mlp<B: Backend>(
    backend: &B,
    ds: &Dataset,
    cfg: &TrainConfig,
    env: &JobEnv,
    peers: Vec<PeerIo>,
) -> Result<TrainResult<Mlp<B::E>>>
where
    B::E: WireElem,
{
    let spec = ModelSpec::Mlp { dims: cfg.dims.clone() };
    let params = JobParams {
        epochs: cfg.epochs,
        batch_size: cfg.batch_size,
        sgd: cfg.sgd,
        val_ratio: cfg.val_ratio,
        init: cfg.init,
        seed: cfg.seed,
        precision: cfg.precision.clone(),
    };
    coordinate::<B, Mlp<B::E>>(backend, ds, spec, params, env, peers)
}

/// CNN twin of [`coordinate_mlp`].
pub fn coordinate_cnn<B: Backend>(
    backend: &B,
    ds: &Dataset,
    cfg: &CnnTrainConfig,
    env: &JobEnv,
    peers: Vec<PeerIo>,
) -> Result<TrainResult<Cnn<B::E>>>
where
    B::E: WireElem,
{
    let spec = ModelSpec::Cnn { arch: cfg.arch.clone() };
    let params = JobParams {
        epochs: cfg.epochs,
        batch_size: cfg.batch_size,
        sgd: cfg.sgd,
        val_ratio: cfg.val_ratio,
        init: cfg.init,
        seed: cfg.seed,
        precision: cfg.precision.clone(),
    };
    coordinate::<B, Cnn<B::E>>(backend, ds, spec, params, env, peers)
}

fn coordinate<B, M>(
    backend: &B,
    ds: &Dataset,
    spec: ModelSpec,
    params: JobParams,
    env: &JobEnv,
    mut peers: Vec<PeerIo>,
) -> Result<TrainResult<M>>
where
    B: Backend,
    M: ProtoModel<B>,
    B::E: WireElem,
{
    let workers = peers.len();
    ensure!(workers >= 1, "multi-process training needs at least one worker");
    ensure!(params.batch_size > 0, "batch_size must be positive");

    // Hand every worker its job (rank + shared spec + the dataset).
    let probe = act_probe(backend, &params.precision);
    for (rank, peer) in peers.iter_mut().enumerate() {
        let job = JobSpec {
            backend_tag: backend.tag(),
            slope: env.slope,
            act_probe: probe.clone(),
            model: spec.clone(),
            epochs: params.epochs,
            batch_size: params.batch_size,
            lr: params.sgd.lr,
            weight_decay: params.sgd.weight_decay,
            val_ratio: params.val_ratio,
            init: params.init,
            seed: params.seed,
            rank,
            workers,
            worker_threads: env.worker_threads,
            precision: params.precision.clone(),
        };
        wire::write_job_frame(&mut peer.tx, &job, ds)
            .with_context(|| format!("sending job to worker {rank}"))?;
    }

    // Mirror the in-process trainer's prologue exactly: same RNG stream
    // (init then per-epoch shuffles), same split, same encode.
    let mut rng = SplitMix64::new(params.seed);
    let mut model = M::from_spec(backend, &spec, params.init, &mut rng)?;
    model.quantize_params(backend, &params.precision);
    ensure!(model.input_len() == ds.pixels, "model input must match dataset pixels");
    ensure!(model.classes() == ds.classes, "model head must match dataset classes");

    let split = ds.split_validation(params.val_ratio, params.seed ^ 0xA11CE);
    let train_y = ds.labels_of(&ds.train_labels, &split.train_idx);
    let val_x = ds.encode_batch(backend, &ds.train_images, &split.val_idx);
    let val_y = ds.labels_of(&ds.train_labels, &split.val_idx);
    let test_x = ds.encode_test(backend);
    let test_y: Vec<usize> = ds.test_labels.iter().map(|&l| l as usize).collect();

    let n = train_y.len();
    ensure!(n > 0, "empty training set");
    let bs = params.batch_size;
    let classes = model.classes();
    let mut curve = Vec::with_capacity(params.epochs);
    let mut order: Vec<usize> = (0..n).collect();
    let mut health: Vec<WorkerHealth> = vec![WorkerHealth::default(); workers];
    let tag = backend.tag();

    for epoch in 1..=params.epochs {
        let _sp = span(SpanKind::Epoch);
        rng.shuffle(&mut order);
        // numerics-lint: allow(nondeterminism) — wall-clock for the reported `seconds` field only (§8)
        let start = std::time::Instant::now();
        let mut loss = EpochLoss::default();
        let mut step: u32 = 0;
        for batch_start in (0..n).step_by(bs) {
            let m = (batch_start + bs).min(n) - batch_start;
            let (merged, raw) =
                collect_step(backend, &model, &mut peers, &mut health, epoch, step, m)?;

            // Broadcast the merged *unscaled* sums; every replica then
            // applies the identical scale + update.
            {
                let views = GradStore::<B>::flat_views(&merged);
                let payload = GradFrame::<B::E>::encode_parts(
                    epoch as u32,
                    step,
                    wire::MERGED_SLOT,
                    &raw,
                    &views,
                );
                for (rank, peer) in peers.iter_mut().enumerate() {
                    wire::write_frame(&mut peer.tx, FrameKind::Merged, &payload)
                        .with_context(|| format!("broadcasting merged sums to worker {rank}"))?;
                }
            }

            let mut grads = merged;
            {
                let _sp = span(SpanKind::Scale);
                // numerics-lint: allow(float-leak) — the single 1/B scale (§3), in f64, encoded once
                grads.scale(backend, 1.0 / raw.n as f64);
            }
            // Same deterministic sampling points as the in-process
            // trainers: the scaled batch gradient, then post-update
            // weights at epoch end (read-only; NUMERICS.md §7).
            if obs::counters_enabled() {
                obs::dist::record_gradients(backend, &GradStore::<B>::flat_views(&grads));
            }
            model.apply_update(backend, &params.sgd, &grads);
            model.quantize_params(backend, &params.precision);
            loss.add_sum(raw.loss_sum, raw.n);
            step += 1;
        }
        if obs::counters_enabled() {
            obs::dist::record_layer_views(
                backend,
                obs::dist::TensorClass::Weights,
                &model.param_views(),
            );
        }
        let seconds = start.elapsed().as_secs_f64();
        let val = evaluate_with(backend, classes, |v| model.logits(backend, v), &val_x, &val_y);
        curve.push(EpochRecord {
            epoch,
            train_loss: loss.mean(),
            val_accuracy: val.accuracy,
            seconds,
        });
        obs::flush_epoch(&tag, epoch);
    }

    let test = evaluate_with(backend, classes, |v| model.logits(backend, v), &test_x, &test_y);

    // End-of-run replica verification: every worker's parameter digest
    // must equal ours bit for bit.
    let mine = param_digest::<B, M>(&model);
    for (rank, peer) in peers.iter_mut().enumerate() {
        let frame = read_data_frame(peer, rank, &mut health)
            .with_context(|| format!("reading final digest from worker {rank}"))?;
        ensure!(
            frame.kind == FrameKind::Digest,
            "expected digest frame from worker {rank}, got {:?}",
            frame.kind
        );
        let theirs = DigestMsg::decode(&frame.payload)?;
        ensure!(
            theirs == mine,
            "replica divergence: worker {rank} finished with parameter digest \
             {:#018x} ({} params), coordinator has {:#018x} ({} params)",
            theirs.digest,
            theirs.params,
            mine.digest,
            mine.params
        );
    }

    Ok(TrainResult { model, curve, test })
}

/// Collect one step's per-sample gradient frames from every worker and
/// merge them in the canonical slot order. Any protocol slip — missing
/// or duplicate slot, wrong epoch/step echo, dead worker — is a hard
/// error: the ⊞ chain is never regrouped around an absent partial.
fn collect_step<B, M>(
    backend: &B,
    model: &M,
    peers: &mut [PeerIo],
    health: &mut [WorkerHealth],
    epoch: usize,
    step: u32,
    m: usize,
) -> Result<(Gradients<B::E>, RawStepStats)>
where
    B: Backend,
    M: ProtoModel<B>,
    B::E: WireElem,
{
    let epoch = epoch as u32;
    let workers = peers.len();
    let shapes = model.grad_shapes();
    let mut slots: Vec<Option<Gradients<B::E>>> = (0..m).map(|_| None).collect();
    let mut stat_slots: Vec<Option<RawStepStats>> = vec![None; m];
    for (rank, peer) in peers.iter_mut().enumerate() {
        let range = shard::worker_range(m, workers, rank);
        for _ in range.clone() {
            let frame = read_data_frame(peer, rank, health).with_context(|| {
                format!(
                    "reading gradient frame from worker {rank} \
                     (epoch {epoch}, step {step}) — did the worker die?"
                )
            })?;
            ensure!(
                frame.kind == FrameKind::GradSums,
                "expected gradient frame from worker {rank}, got {:?}",
                frame.kind
            );
            let gf: GradFrame<B::E> = GradFrame::decode(&frame.payload)?;
            ensure!(
                gf.epoch == epoch && gf.step == step,
                "worker {rank} is desynchronized: frame for epoch {}/step {}, \
                 coordinator is at epoch {epoch}/step {step}",
                gf.epoch,
                gf.step
            );
            let slot = gf.slot as usize;
            ensure!(
                range.contains(&slot),
                "worker {rank} sent slot {slot} outside its range {range:?}"
            );
            ensure!(slots[slot].is_none(), "duplicate gradient frame for slot {slot}");
            let g = build_grads(&shapes, gf.views)
                .map_err(|e| anyhow::anyhow!("worker {rank} slot {slot}: {e}"))?;
            slots[slot] = Some(g);
            stat_slots[slot] = Some(gf.stats);
        }
    }
    let mut raw = RawStepStats::default();
    for (i, s) in stat_slots.iter().enumerate() {
        match s {
            Some(s) => raw.merge(s),
            None => bail!("no statistics arrived for sample slot {i}"),
        }
    }
    ensure!(raw.n == m, "statistics cover {} samples, batch has {m}", raw.n);
    let merged = shard::accumulate_slots(backend, slots).map_err(|e| anyhow::anyhow!(e))?;
    Ok((merged, raw))
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// Read the leading job frame off a worker connection.
pub fn read_job<R: Read>(rx: &mut R) -> Result<(JobSpec, Dataset)> {
    let frame = wire::read_frame(rx).context("reading job frame")?;
    ensure!(frame.kind == FrameKind::Job, "expected job frame first, got {:?}", frame.kind);
    wire::decode_job(&frame.payload)
}

/// Serve one worker connection: read the job frame, then run the
/// training loop against it. Protocol only — process concerns (thread
/// pools, transport setup) live in [`run_worker`].
pub fn serve_connection<R: Read, W: Write>(mut rx: R, tx: W) -> Result<()> {
    let (job, ds) = read_job(&mut rx)?;
    serve_job(&job, &ds, &mut rx, tx)
}

/// Run the worker training loop for an already-decoded job: reconstruct
/// the backend from its tag + slope, then dispatch the model family.
/// Tags are parsed through the same width-generic validators the
/// coordinator uses ([`FixedConfig::from_tag`], [`LnsConfig::from_tag`]),
/// so every runtime width a coordinator can run — `lin8`, `log8-lut`,
/// `log23-bs`, … — is servable, not just the preset list.
pub fn serve_job<R: Read, W: Write>(
    job: &JobSpec,
    ds: &Dataset,
    rx: &mut R,
    tx: W,
) -> Result<()> {
    let slope = job.slope;
    let tag = job.backend_tag.as_str();
    if tag == "float32" {
        // numerics-lint: allow(float-leak) — float-backend construction: config slope → native f32
        return dispatch_model(&FloatBackend { slope: slope as f32 }, job, ds, rx, tx);
    }
    if let Some(cfg) = FixedConfig::from_tag(tag) {
        let b = FixedBackend::new(FixedSystem::new(cfg), slope);
        return dispatch_model(&b, job, ds, rx, tx);
    }
    if let Some(cfg) = LnsConfig::from_tag(tag) {
        return lns_dispatch(cfg, job, ds, rx, tx);
    }
    bail!("unknown backend tag '{tag}' in job spec")
}

fn lns_dispatch<R: Read, W: Write>(
    cfg: LnsConfig,
    job: &JobSpec,
    ds: &Dataset,
    rx: &mut R,
    tx: W,
) -> Result<()> {
    let b = LnsBackend::new(LnsSystem::new(cfg), job.slope);
    dispatch_model(&b, job, ds, rx, tx)
}

fn dispatch_model<B, R, W>(
    backend: &B,
    job: &JobSpec,
    ds: &Dataset,
    rx: &mut R,
    tx: W,
) -> Result<()>
where
    B: Backend,
    B::E: WireElem,
    R: Read,
    W: Write,
{
    // Refuse to run on a backend that is not bit-for-bit the
    // coordinator's: the tag + slope under-determine it (see
    // [`act_probe`]). The probe also covers the per-layer storage grids
    // of the job's precision map, so a width disagreement is refused
    // here too.
    ensure!(
        act_probe(backend, &job.precision) == job.act_probe,
        "worker backend mismatch: activation probe differs for tag '{}' at slope {} — \
         the coordinator's backend was built differently (check MultiprocSpec/JobEnv slope)",
        job.backend_tag,
        job.slope
    );
    match job.model {
        ModelSpec::Mlp { .. } => worker_loop::<B, Mlp<B::E>, _, _>(backend, job, ds, rx, tx),
        ModelSpec::Cnn { .. } => worker_loop::<B, Cnn<B::E>, _, _>(backend, job, ds, rx, tx),
    }
}

fn worker_loop<B, M, R, W>(
    backend: &B,
    job: &JobSpec,
    ds: &Dataset,
    rx: &mut R,
    mut tx: W,
) -> Result<()>
where
    B: Backend,
    M: ProtoModel<B>,
    B::E: WireElem,
    R: Read,
    W: Write,
{
    // Identical prologue to the coordinator (and the in-process
    // trainers): one RNG stream for init + shuffles, one for the split.
    let mut rng = SplitMix64::new(job.seed);
    let mut model = M::from_spec(backend, &job.model, job.init, &mut rng)?;
    model.quantize_params(backend, &job.precision);
    ensure!(model.input_len() == ds.pixels, "job model input must match dataset pixels");
    ensure!(model.classes() == ds.classes, "job model head must match dataset classes");

    let split = ds.split_validation(job.val_ratio, job.seed ^ 0xA11CE);
    let train_x = ds.encode_batch(backend, &ds.train_images, &split.train_idx);
    let train_y = ds.labels_of(&ds.train_labels, &split.train_idx);
    let n = train_y.len();
    ensure!(n > 0, "empty training set");
    let bs = job.batch_size;
    let sgd = SgdConfig { lr: job.lr, weight_decay: job.weight_decay };
    let shapes = model.grad_shapes();
    let mut order: Vec<usize> = (0..n).collect();
    let mut samples_done: u64 = 0;

    for epoch in 1..=job.epochs {
        rng.shuffle(&mut order);
        let mut step: u32 = 0;
        for batch_start in (0..n).step_by(bs) {
            let _sp = span(SpanKind::WorkerBatch);
            let end = (batch_start + bs).min(n);
            let chunk = &order[batch_start..end];
            let m = chunk.len();

            // Progress/telemetry frame ahead of the gradient frames, so
            // the coordinator's collect loop can fold it in before the
            // data it is waiting for. Pure observability (see
            // [`HeartbeatMsg`]); emitted only when counters are on.
            maybe_heartbeat(&mut tx, job, epoch, step, samples_done, end == n)?;

            // Compute and ship this worker's slice of the batch, one
            // frame per sample slot (never pre-reduced — see module
            // docs).
            for slot in shard::worker_range(m, job.workers, job.rank) {
                let xi = shard::sample_row(&train_x, chunk[slot]);
                let lbl = [train_y[chunk[slot]]];
                let (g, s) = model.backprop_sums(backend, &xi, &lbl);
                samples_done += 1;
                let views = GradStore::<B>::flat_views(&g);
                // Worker-side sampling point: this rank's per-sample
                // gradient sums (read-only; ships to the coordinator as
                // a heartbeat v3 delta).
                obs::dist::record_gradients(backend, &views);
                let payload = GradFrame::<B::E>::encode_parts(
                    epoch as u32,
                    step,
                    slot as u32,
                    &s,
                    &views,
                );
                wire::write_frame(&mut tx, FrameKind::GradSums, &payload).with_context(|| {
                    format!("worker {}: sending slot {slot} gradient frame", job.rank)
                })?;
            }

            // Receive the merged sums and mirror the update.
            let frame = wire::read_frame(rx).with_context(|| {
                format!(
                    "worker {}: reading merged frame (epoch {epoch}, step {step}) \
                     — did the coordinator die?",
                    job.rank
                )
            })?;
            ensure!(
                frame.kind == FrameKind::Merged,
                "worker {}: expected merged frame, got {:?}",
                job.rank,
                frame.kind
            );
            let mf: GradFrame<B::E> = GradFrame::decode(&frame.payload)?;
            ensure!(
                mf.epoch == epoch as u32 && mf.step == step && mf.slot == wire::MERGED_SLOT,
                "worker {}: desynchronized merged frame (epoch {}/step {}/slot {:#x}, \
                 expected epoch {epoch}/step {step})",
                job.rank,
                mf.epoch,
                mf.step,
                mf.slot
            );
            ensure!(
                mf.stats.n == m,
                "worker {}: merged frame covers {} samples, batch has {m}",
                job.rank,
                mf.stats.n
            );
            let mut grads = build_grads(&shapes, mf.views)
                .map_err(|e| anyhow::anyhow!("worker {}: {e}", job.rank))?;
            {
                let _sp = span(SpanKind::Scale);
                // numerics-lint: allow(float-leak) — the single 1/B scale (§3), in f64, encoded once
                grads.scale(backend, 1.0 / mf.stats.n as f64);
            }
            model.apply_update(backend, &sgd, &grads);
            model.quantize_params(backend, &job.precision);
            step += 1;
        }
        // Worker epoch-end weights (mirror of the coordinator's point).
        if obs::counters_enabled() {
            obs::dist::record_layer_views(
                backend,
                obs::dist::TensorClass::Weights,
                &model.param_views(),
            );
        }
    }

    // Prove the replica never diverged.
    let digest = param_digest::<B, M>(&model);
    wire::write_frame(&mut tx, FrameKind::Digest, &digest.encode())
        .with_context(|| format!("worker {}: sending final digest", job.rank))?;
    Ok(())
}

/// Process entry point for `lnsdnn worker`: set up the transport, apply
/// the job's thread config to this process's global rayon pool, run the
/// loop. With [`Transport::Stdio`] the frames own stdout — the worker
/// must write diagnostics to stderr only.
pub fn run_worker(transport: Transport, connect: Option<&str>) -> Result<()> {
    match transport {
        Transport::Stdio => {
            let mut rx = BufReader::new(std::io::stdin());
            let tx = BufWriter::new(std::io::stdout());
            worker_serve(&mut rx, tx)
        }
        Transport::Tcp => {
            let addr = connect.context("tcp transport needs --connect HOST:PORT")?;
            let stream = TcpStream::connect(addr)
                .with_context(|| format!("connecting to coordinator at {addr}"))?;
            let _ = stream.set_nodelay(true);
            let mut rx = BufReader::new(stream.try_clone().context("cloning worker socket")?);
            let tx = BufWriter::new(stream);
            worker_serve(&mut rx, tx)
        }
    }
}

fn worker_serve<R: Read, W: Write>(rx: &mut R, tx: W) -> Result<()> {
    let (job, ds) = read_job(rx)?;
    if job.worker_threads > 0 {
        // Global because every tensor op in this process should share it;
        // ignore the error if something already built the global pool.
        let _ = rayon::ThreadPoolBuilder::new()
            .num_threads(job.worker_threads)
            .thread_name(|i| format!("mp-worker-{i}"))
            .build_global();
    }
    serve_job(&job, &ds, rx, tx)
}

// ---------------------------------------------------------------------
// In-memory transport (tests, benches, single-process experiments)
// ---------------------------------------------------------------------

struct PipeState {
    buf: VecDeque<u8>,
    write_closed: bool,
    read_closed: bool,
}

struct PipeShared {
    state: Mutex<PipeState>,
    cond: Condvar,
}

/// Writing end of an in-memory byte pipe (see [`mem_pipe`]).
pub struct PipeWriter(Arc<PipeShared>);

/// Reading end of an in-memory byte pipe (see [`mem_pipe`]).
pub struct PipeReader(Arc<PipeShared>);

/// An unbounded in-memory byte pipe with pipe-like EOF semantics:
/// dropping the writer yields EOF at the reader, dropping the reader
/// makes writes fail with `BrokenPipe`. This is the in-process transport
/// that lets unit tests drive the full multi-process protocol — both
/// loops, real frames — without spawning a process.
pub fn mem_pipe() -> (PipeWriter, PipeReader) {
    let shared = Arc::new(PipeShared {
        state: Mutex::new(PipeState {
            buf: VecDeque::new(),
            write_closed: false,
            read_closed: false,
        }),
        cond: Condvar::new(),
    });
    (PipeWriter(shared.clone()), PipeReader(shared))
}

impl Read for PipeReader {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        let mut st = self.0.state.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                let n = out.len().min(st.buf.len());
                for slot in out.iter_mut().take(n) {
                    *slot = st.buf.pop_front().unwrap();
                }
                return Ok(n);
            }
            if st.write_closed {
                return Ok(0); // EOF
            }
            st = self.0.cond.wait(st).unwrap();
        }
    }
}

impl Write for PipeWriter {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        let mut st = self.0.state.lock().unwrap();
        if st.read_closed {
            return Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "in-memory pipe reader was dropped",
            ));
        }
        st.buf.extend(data.iter().copied());
        self.0.cond.notify_all();
        Ok(data.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        st.write_closed = true;
        self.0.cond.notify_all();
    }
}

impl Drop for PipeReader {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        st.read_closed = true;
        self.0.cond.notify_all();
    }
}

/// Build `workers` in-memory duplex links: the coordinator-side
/// [`PeerIo`] list plus each worker's `(rx, tx)` pair.
pub fn mem_peers(workers: usize) -> (Vec<PeerIo>, Vec<(PipeReader, PipeWriter)>) {
    let mut peers = Vec::with_capacity(workers);
    let mut ends = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (coord_tx, worker_rx) = mem_pipe();
        let (worker_tx, coord_rx) = mem_pipe();
        peers.push(PeerIo { rx: Box::new(coord_rx), tx: Box::new(coord_tx) });
        ends.push((worker_rx, worker_tx));
    }
    (peers, ends)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{stripes_dataset, synth_dataset, StripeSpec, SynthSpec};
    use crate::train::{train, train_cnn, ShardConfig};

    fn tiny_ds() -> Dataset {
        synth_dataset(&SynthSpec {
            name: "tiny".into(),
            classes: 2,
            train_per_class: 12,
            test_per_class: 4,
            strokes: 4,
            jitter_px: 1.5,
            jitter_rot: 0.15,
            noise: 0.04,
            seed: 31,
        })
    }

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            dims: vec![784, 6, 2],
            epochs: 2,
            batch_size: 5,
            sgd: SgdConfig { lr: 0.02, weight_decay: 0.0 },
            val_ratio: 5,
            init: InitScheme::HeNormal,
            seed: 11,
            shard: ShardConfig::default(),
            precision: crate::precision::PrecisionMap::uniform(),
        }
    }

    /// Run `workers` in-process protocol workers on threads and the
    /// coordinator on this thread, over in-memory pipes.
    fn run_mem_multiproc<B, M, F>(workers: usize, coordinate_fn: F) -> Result<TrainResult<M>>
    where
        B: Backend,
        M: ProtoModel<B>,
        B::E: WireElem,
        F: FnOnce(Vec<PeerIo>) -> Result<TrainResult<M>>,
    {
        let (peers, ends) = mem_peers(workers);
        let mut handles = Vec::new();
        for (rx, tx) in ends {
            handles.push(std::thread::spawn(move || serve_connection(rx, tx)));
        }
        let result = coordinate_fn(peers);
        for h in handles {
            h.join().expect("worker thread panicked")?;
        }
        result
    }

    #[test]
    fn mem_pipe_eof_and_broken_pipe() {
        let (mut tx, mut rx) = mem_pipe();
        tx.write_all(b"abc").unwrap();
        drop(tx);
        let mut got = Vec::new();
        rx.read_to_end(&mut got).unwrap();
        assert_eq!(got, b"abc");

        let (mut tx, rx) = mem_pipe();
        drop(rx);
        assert!(tx.write_all(b"x").is_err());
    }

    #[test]
    fn protocol_mlp_float_matches_serial_and_sharded() {
        let ds = tiny_ds();
        let cfg = tiny_cfg();
        let serial = train(&FloatBackend::default(), &ds, &cfg);
        let mut sharded_cfg = cfg.clone();
        sharded_cfg.shard = ShardConfig::with_shards(2);
        let sharded = train(&FloatBackend::default(), &ds, &sharded_cfg);

        let env = JobEnv::default();
        let mp = run_mem_multiproc::<FloatBackend, Mlp<f32>, _>(2, |peers| {
            coordinate_mlp(&FloatBackend::default(), &ds, &cfg, &env, peers)
        })
        .expect("multi-process run failed");

        for l in 0..serial.model.layers.len() {
            assert_eq!(serial.model.layers[l].w.data, mp.model.layers[l].w.data, "layer {l} w");
            assert_eq!(serial.model.layers[l].b, mp.model.layers[l].b, "layer {l} b");
            assert_eq!(sharded.model.layers[l].w.data, mp.model.layers[l].w.data);
        }
        assert_eq!(serial.test.accuracy, mp.test.accuracy);
        assert_eq!(serial.test.loss, mp.test.loss);
        for (a, b) in serial.curve.iter().zip(&mp.curve) {
            assert_eq!(a.train_loss, b.train_loss, "epoch {} loss", a.epoch);
            assert_eq!(a.val_accuracy, b.val_accuracy, "epoch {} val", a.epoch);
        }
    }

    #[test]
    fn protocol_mlp_lns_matches_inprocess_shards() {
        let ds = tiny_ds();
        let cfg = tiny_cfg();
        let mk = || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let mut sharded_cfg = cfg.clone();
        sharded_cfg.shard = ShardConfig::with_shards(3);
        let sharded = train(&mk(), &ds, &sharded_cfg);
        let env = JobEnv::default();
        let mp = run_mem_multiproc::<LnsBackend, Mlp<crate::lns::LnsValue>, _>(3, |peers| {
            coordinate_mlp(&mk(), &ds, &cfg, &env, peers)
        })
        .expect("multi-process LNS run failed");
        for l in 0..sharded.model.layers.len() {
            assert_eq!(sharded.model.layers[l].w.data, mp.model.layers[l].w.data, "layer {l}");
            assert_eq!(sharded.model.layers[l].b, mp.model.layers[l].b, "layer {l} bias");
        }
        assert_eq!(sharded.test.accuracy, mp.test.accuracy);
        assert_eq!(sharded.test.loss, mp.test.loss);
    }

    #[test]
    fn protocol_cnn_float_matches_inprocess() {
        let ds = stripes_dataset(&StripeSpec {
            train_per_class: 8,
            test_per_class: 3,
            ..StripeSpec::cnn_default(1.0, 21)
        });
        let mut cfg = CnnTrainConfig::lenet(12, 4);
        cfg.arch.c1 = 2;
        cfg.arch.c2 = 3;
        cfg.arch.hidden = 8;
        cfg.epochs = 1;
        cfg.sgd = SgdConfig { lr: 0.02, weight_decay: 0.0 };
        cfg.seed = 13;
        let inproc = train_cnn(&FloatBackend::default(), &ds, &cfg);
        let env = JobEnv::default();
        let mp = run_mem_multiproc::<FloatBackend, Cnn<f32>, _>(2, |peers| {
            coordinate_cnn(&FloatBackend::default(), &ds, &cfg, &env, peers)
        })
        .expect("multi-process CNN run failed");
        assert_eq!(inproc.model.conv1.w.data, mp.model.conv1.w.data);
        assert_eq!(inproc.model.conv2.w.data, mp.model.conv2.w.data);
        assert_eq!(inproc.model.fc1.w.data, mp.model.fc1.w.data);
        assert_eq!(inproc.model.fc2.w.data, mp.model.fc2.w.data);
        assert_eq!(inproc.test.accuracy, mp.test.accuracy);
        assert_eq!(inproc.test.loss, mp.test.loss);
    }

    #[test]
    fn dead_worker_is_a_hard_error() {
        // One live worker, one that closes its connection immediately:
        // the coordinator must fail, never regroup around the gap.
        let ds = tiny_ds();
        let cfg = tiny_cfg();
        let (peers, mut ends) = mem_peers(2);
        let (rx0, tx0) = ends.remove(0);
        let live = std::thread::spawn(move || {
            // This worker will itself error once the coordinator vanishes;
            // that's expected.
            let _ = serve_connection(rx0, tx0);
        });
        drop(ends); // worker 1 never comes up: its ends are dropped
        let env = JobEnv::default();
        let err = coordinate_mlp(&FloatBackend::default(), &ds, &cfg, &env, peers)
            .expect_err("coordinator must hard-error when a worker is gone");
        let msg = format!("{err:#}");
        assert!(msg.contains("worker 1"), "{msg}");
        live.join().unwrap();
    }

    #[test]
    fn slope_mismatch_is_caught_by_the_activation_probe() {
        // JobEnv says 0.02 but the coordinator backend was built with the
        // default 0.01: the worker must refuse up front (the digest could
        // never catch this — all replicas would stay in lockstep).
        let ds = tiny_ds();
        let cfg = tiny_cfg();
        let (peers, mut ends) = mem_peers(1);
        let (rx, tx) = ends.remove(0);
        let worker = std::thread::spawn(move || serve_connection(rx, tx));
        let env = JobEnv { slope: 0.02, worker_threads: 0 };
        let res = coordinate_mlp(&FloatBackend::default(), &ds, &cfg, &env, peers);
        assert!(res.is_err(), "coordinator must fail once the worker bails");
        let werr = worker.join().unwrap().unwrap_err();
        assert!(format!("{werr:#}").contains("activation probe"), "{werr:#}");
    }

    #[test]
    fn digest_detects_divergence() {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(4);
        let m1 = Mlp::init(&b, &[3, 4, 2], InitScheme::HeNormal, &mut rng);
        let mut m2 = m1.clone();
        let d1 = param_digest::<FloatBackend, Mlp<f32>>(&m1);
        assert_eq!(d1, param_digest::<FloatBackend, Mlp<f32>>(&m2));
        m2.layers[0].w.data[0] += 1.0e-7;
        let d2 = param_digest::<FloatBackend, Mlp<f32>>(&m2);
        assert_eq!(d1.params, d2.params);
        assert_ne!(d1.digest, d2.digest);
    }

    #[test]
    fn garbage_job_frame_is_rejected() {
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, FrameKind::Job, b"not a job spec").unwrap();
        let out: Vec<u8> = Vec::new();
        assert!(serve_connection(buf.as_slice(), out).is_err());
    }

    #[test]
    fn transport_parses() {
        assert_eq!(Transport::parse("stdio"), Some(Transport::Stdio));
        assert_eq!(Transport::parse("tcp"), Some(Transport::Tcp));
        assert_eq!(Transport::parse("smoke-signals"), None);
        assert_eq!(Transport::Tcp.label(), "tcp");
    }
}
