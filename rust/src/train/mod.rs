//! The training engine: epoch loop, mini-batching, validation curves.
//!
//! Matches the paper's protocol (§5): SGD, mini-batch 5, lr 0.01,
//! per-dataset weight decay, 1:5 validation hold-back, 20 epochs,
//! validation accuracy recorded per epoch (Fig. 2) and test accuracy at
//! the end (Table 1).
//!
//! All tensor work inside a step runs on the row-parallel engine in
//! [`crate::tensor::ops`]; because the parallel paths are bit-identical
//! to the serial references, training remains exactly deterministic in
//! the seed regardless of thread count (see
//! `tests/parallel_determinism.rs`).
//!
//! On top of that, the epoch loop itself is data-parallel: a
//! [`ShardConfig`] splits every mini-batch across workers and merges the
//! per-sample gradients in the fixed reduction order of
//! [`shard::accumulate_tree`], so the trained weights are bit-identical
//! for every worker count (see `tests/shard_determinism.rs`).
//!
//! The same reduction contract crosses process boundaries: [`multiproc`]
//! runs the per-sample gradient work in separate worker processes,
//! moving partials as versioned [`wire`] frames and merging them in the
//! identical slot order — multi-process training is bit-identical to the
//! in-process trainers too (`tests/multiproc_determinism.rs`). The full
//! contract is written up in `docs/NUMERICS.md`.

pub mod metrics;
pub mod multiproc;
pub mod shard;
pub mod wire;

pub use metrics::{evaluate, evaluate_with, EvalResult};
pub use multiproc::{JobEnv, PeerIo, Transport};
pub use shard::ShardConfig;

use multiproc::ProtoModel;

use crate::data::Dataset;
use crate::nn::{
    quantize_cnn, quantize_mlp, Cnn, CnnArch, GradStore, InitScheme, Mlp, RawStepStats, SgdConfig,
};
use crate::obs::{self, span, SpanKind};
use crate::precision::PrecisionMap;
use crate::rng::SplitMix64;
use crate::tensor::{Backend, Tensor};

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Layer sizes including input/output, e.g. `[784, 100, 10]`.
    pub dims: Vec<usize>,
    /// Epochs (paper: 20).
    pub epochs: usize,
    /// Mini-batch size (paper: 5).
    pub batch_size: usize,
    /// SGD settings (paper: lr = 0.01, per-dataset weight decay).
    pub sgd: SgdConfig,
    /// Validation hold-back denominator (paper: 5 ⇒ 1:5).
    pub val_ratio: usize,
    /// Weight-init scheme.
    pub init: InitScheme,
    /// Master seed (init, shuffles, split).
    pub seed: u64,
    /// Data-parallel execution (bit-exact for every worker count).
    pub shard: ShardConfig,
    /// Per-layer storage words (mixed precision, NUMERICS.md §11);
    /// uniform = every layer keeps the backend's base word.
    pub precision: PrecisionMap,
}

impl TrainConfig {
    /// The paper's §5 protocol for a dataset with `classes` outputs.
    pub fn paper(classes: usize) -> Self {
        TrainConfig {
            dims: vec![784, 100, classes],
            epochs: 20,
            batch_size: 5,
            sgd: SgdConfig { lr: 0.01, weight_decay: 1e-4 },
            val_ratio: 5,
            init: InitScheme::HeNormal,
            seed: 0x5EED,
            shard: ShardConfig::default(),
            precision: PrecisionMap::uniform(),
        }
    }
}

/// Sample-weighted epoch-loss accumulator.
///
/// Epoch `train_loss` must weight every *sample* equally. A plain mean
/// of per-batch means (`Σ batch_mean / batches`) overweights the final
/// batch whenever `n % batch_size != 0` — its (fewer) samples count as
/// much as a full batch's. Folding the **raw per-batch loss sums**
/// ([`RawStepStats::loss_sum`]) and dividing by the total sample count
/// once gives the exact per-sample mean; both training loops report
/// through this one accumulator so they cannot diverge on the weighting
/// rule again.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochLoss {
    /// Σ per-sample losses across the folded batches.
    loss_sum: f64,
    /// Σ batch lengths.
    samples: usize,
}

impl EpochLoss {
    /// Fold one batch's raw loss sum over `batch` samples.
    pub fn add_sum(&mut self, batch_loss_sum: f64, batch: usize) {
        self.loss_sum += batch_loss_sum;
        self.samples += batch;
    }

    /// Sample-weighted mean over everything folded so far (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.loss_sum / self.samples as f64
        }
    }
}

/// One epoch's record in a learning curve.
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    /// Epoch index (1-based, 0 = before training).
    pub epoch: usize,
    /// Sample-weighted mean training loss over the epoch (natural-log
    /// CE; every sample counts once, see [`EpochLoss`]).
    pub train_loss: f64,
    /// Validation accuracy after the epoch.
    pub val_accuracy: f64,
    /// Wall-clock seconds spent in the epoch's training steps.
    pub seconds: f64,
}

/// Result of a full training run, generic over the trained model type
/// ([`Mlp`] for [`train`], [`Cnn`] for [`train_cnn`]).
#[derive(Clone, Debug)]
pub struct TrainResult<M> {
    /// The trained model.
    pub model: M,
    /// Per-epoch learning curve (Fig. 2's series).
    pub curve: Vec<EpochRecord>,
    /// Final test-set evaluation (Table 1's cell).
    pub test: EvalResult,
}

/// Train an MLP on a dataset with the given backend. The entire arithmetic
/// path — forward, softmax+CE gradient, backprop, updates — runs in the
/// backend's number system; floats appear only in reporting.
///
/// With `cfg.shard.n_shards > 1` every mini-batch (and the evaluation
/// passes) fan out across a pool of that many workers; the gradient
/// reduction order of [`shard`] makes the trained weights **bit-identical
/// to the serial trainer** for every worker count (the MLP's per-sample
/// gradients are single ⊞ terms of the batched fold — see
/// [`Mlp::backprop_sums`]).
pub fn train<B: Backend>(backend: &B, ds: &Dataset, cfg: &TrainConfig) -> TrainResult<Mlp<B::E>> {
    assert_eq!(cfg.dims[0], ds.pixels, "model input must match dataset pixels");
    assert_eq!(
        *cfg.dims.last().unwrap(),
        ds.classes,
        "model head must match dataset classes"
    );
    cfg.shard.validate();
    let pool = cfg.shard.build_pool();
    let mut rng = SplitMix64::new(cfg.seed);
    let mut model = Mlp::init(backend, &cfg.dims, cfg.init, &mut rng);
    // Mixed precision: parameters live in their per-layer storage words
    // from the very first forward pass (NUMERICS.md §11).
    quantize_mlp(backend, &mut model, &cfg.precision);

    let split = ds.split_validation(cfg.val_ratio, cfg.seed ^ 0xA11CE);
    // Encode everything once: conversion is the paper's offline
    // pre-processing step and must not be timed inside the epochs.
    let train_x = ds.encode_batch(backend, &ds.train_images, &split.train_idx);
    let train_y = ds.labels_of(&ds.train_labels, &split.train_idx);
    let val_x = ds.encode_batch(backend, &ds.train_images, &split.val_idx);
    let val_y = ds.labels_of(&ds.train_labels, &split.val_idx);
    let test_x = ds.encode_test(backend);
    let test_y: Vec<usize> = ds.test_labels.iter().map(|&l| l as usize).collect();

    let n = train_y.len();
    let bs = cfg.batch_size;
    let mut curve = Vec::with_capacity(cfg.epochs);
    let mut order: Vec<usize> = (0..n).collect();
    let tag = backend.tag();

    for epoch in 1..=cfg.epochs {
        let _sp = span(SpanKind::Epoch);
        rng.shuffle(&mut order);
        // numerics-lint: allow(nondeterminism) — wall-clock for the reported `seconds` field only (§8)
        let start = std::time::Instant::now();
        let mut loss = EpochLoss::default();
        let mut chunk = Vec::with_capacity(bs);
        for batch_start in (0..n).step_by(bs) {
            let end = (batch_start + bs).min(n);
            chunk.clear();
            chunk.extend_from_slice(&order[batch_start..end]);
            let (bx, by) = gather_batch(backend, &train_x, &train_y, &chunk);
            // Sharded: per-sample backward passes fanned across the pool,
            // reduced in the canonical order — bit-identical to the
            // serial full-batch sums+scale below (shard module docs;
            // `backprop` is defined as exactly that composition, pinned
            // by `backprop_is_scaled_backprop_sums`).
            let (grads, raw) = if cfg.shard.is_sharded() {
                sharded_step(backend, pool.as_ref(), bx.rows, |i| {
                    let xi = shard::sample_row(&bx, i);
                    model.backprop_sums(backend, &xi, &by[i..i + 1])
                })
            } else {
                model.backprop_avg(backend, &bx, &by)
            };
            // Deterministic sampling point: the batch gradient about to
            // be applied (read-only; see obs::dist module docs).
            if obs::counters_enabled() {
                obs::dist::record_gradients(backend, &GradStore::<B>::flat_views(&grads));
            }
            cfg.sgd.apply(backend, &mut model, &grads);
            // Snap updated parameters back to their storage words — the
            // same point in the step on every execution path.
            quantize_mlp(backend, &mut model, &cfg.precision);
            loss.add_sum(raw.loss_sum, raw.n);
        }
        // Deterministic sampling point: post-update parameters at epoch
        // end, in canonical param_views order.
        if obs::counters_enabled() {
            obs::dist::record_layer_views(
                backend,
                obs::dist::TensorClass::Weights,
                &ProtoModel::<B>::param_views(&model),
            );
        }
        let seconds = start.elapsed().as_secs_f64();
        let val = eval_pooled(pool.as_ref(), || evaluate(backend, &model, &val_x, &val_y));
        curve.push(EpochRecord {
            epoch,
            train_loss: loss.mean(),
            val_accuracy: val.accuracy,
            seconds,
        });
        obs::flush_epoch(&tag, epoch);
    }

    let test = eval_pooled(pool.as_ref(), || evaluate(backend, &model, &test_x, &test_y));
    TrainResult { model, curve, test }
}

/// Run an evaluation closure on the shard pool when one exists (so the
/// eval set fans out across the sized workers), inline otherwise. The
/// metric reductions are row-ordered, so the numbers are identical on
/// both paths.
fn eval_pooled<R, F>(pool: Option<&rayon::ThreadPool>, f: F) -> R
where
    R: Send,
    F: FnOnce() -> R + Send,
{
    match pool {
        Some(p) => p.install(f),
        None => f(),
    }
}

/// Training hyper-parameters for the CNN workload.
#[derive(Clone, Debug)]
pub struct CnnTrainConfig {
    /// Model architecture (conv–pool–conv–pool–dense–dense).
    pub arch: CnnArch,
    /// Epochs.
    pub epochs: usize,
    /// Mini-batch size (paper protocol: 5).
    pub batch_size: usize,
    /// SGD settings.
    pub sgd: SgdConfig,
    /// Validation hold-back denominator (paper: 5 ⇒ 1:5).
    pub val_ratio: usize,
    /// Weight-init scheme.
    pub init: InitScheme,
    /// Master seed (init, shuffles, split).
    pub seed: u64,
    /// Data-parallel execution (bit-exact for every worker count).
    pub shard: ShardConfig,
    /// Per-layer storage words (mixed precision, NUMERICS.md §11);
    /// layer order `[conv1, conv2, fc1, fc2]`.
    pub precision: PrecisionMap,
}

impl CnnTrainConfig {
    /// The paper's §5 protocol around a LeNet-style architecture for
    /// square `side×side` single-channel images.
    pub fn lenet(side: usize, classes: usize) -> Self {
        CnnTrainConfig {
            arch: CnnArch::lenet(side, classes),
            epochs: 10,
            batch_size: 5,
            sgd: SgdConfig { lr: 0.01, weight_decay: 1e-4 },
            val_ratio: 5,
            init: InitScheme::HeNormal,
            seed: 0x5EED,
            shard: ShardConfig::default(),
            precision: PrecisionMap::uniform(),
        }
    }
}

/// Train the LeNet-style CNN on a dataset with the given backend — the
/// same epoch/mini-batch/validation protocol as [`train`], with the conv
/// subsystem's backprop and [`SgdConfig::apply_cnn`] updates. Everything
/// arithmetic runs in the backend's number system.
///
/// The CNN's batch gradient is *defined* as the per-sample reduction of
/// [`shard`] at **every** shard count, including 1: conv kernels fold
/// over `B·OH·OW` patch terms, so sample sharding necessarily regroups
/// the ⊞ chain into per-sample subtrees, and using that grouping
/// uniformly is what makes the weights invariant in `n_shards` (see the
/// shard module docs for the full argument).
pub fn train_cnn<B: Backend>(
    backend: &B,
    ds: &Dataset,
    cfg: &CnnTrainConfig,
) -> TrainResult<Cnn<B::E>> {
    assert_eq!(cfg.arch.input_len(), ds.pixels, "CNN input must match dataset pixels");
    assert_eq!(cfg.arch.classes, ds.classes, "CNN head must match dataset classes");
    cfg.shard.validate();
    let pool = cfg.shard.build_pool();
    let mut rng = SplitMix64::new(cfg.seed);
    let mut model = Cnn::init(backend, &cfg.arch, cfg.init, &mut rng);
    // Same mixed-precision points as [`train`] (NUMERICS.md §11).
    quantize_cnn(backend, &mut model, &cfg.precision);

    let split = ds.split_validation(cfg.val_ratio, cfg.seed ^ 0xA11CE);
    let train_x = ds.encode_batch(backend, &ds.train_images, &split.train_idx);
    let train_y = ds.labels_of(&ds.train_labels, &split.train_idx);
    let val_x = ds.encode_batch(backend, &ds.train_images, &split.val_idx);
    let val_y = ds.labels_of(&ds.train_labels, &split.val_idx);
    let test_x = ds.encode_test(backend);
    let test_y: Vec<usize> = ds.test_labels.iter().map(|&l| l as usize).collect();

    let n = train_y.len();
    let bs = cfg.batch_size;
    let classes = cfg.arch.classes;
    let mut curve = Vec::with_capacity(cfg.epochs);
    let mut order: Vec<usize> = (0..n).collect();
    let tag = backend.tag();

    for epoch in 1..=cfg.epochs {
        let _sp = span(SpanKind::Epoch);
        rng.shuffle(&mut order);
        // numerics-lint: allow(nondeterminism) — wall-clock for the reported `seconds` field only (§8)
        let start = std::time::Instant::now();
        let mut loss = EpochLoss::default();
        let mut chunk = Vec::with_capacity(bs);
        for batch_start in (0..n).step_by(bs) {
            let end = (batch_start + bs).min(n);
            chunk.clear();
            chunk.extend_from_slice(&order[batch_start..end]);
            let (bx, by) = gather_batch(backend, &train_x, &train_y, &chunk);
            let (grads, raw) = sharded_step(backend, pool.as_ref(), bx.rows, |i| {
                let xi = shard::sample_row(&bx, i);
                model.backprop_sums(backend, &xi, &by[i..i + 1])
            });
            // Same deterministic sampling points as [`train`].
            if obs::counters_enabled() {
                obs::dist::record_gradients(backend, &GradStore::<B>::flat_views(&grads));
            }
            cfg.sgd.apply_cnn(backend, &mut model, &grads);
            quantize_cnn(backend, &mut model, &cfg.precision);
            loss.add_sum(raw.loss_sum, raw.n);
        }
        if obs::counters_enabled() {
            obs::dist::record_layer_views(
                backend,
                obs::dist::TensorClass::Weights,
                &ProtoModel::<B>::param_views(&model),
            );
        }
        let seconds = start.elapsed().as_secs_f64();
        let val = eval_pooled(pool.as_ref(), || {
            evaluate_with(backend, classes, |v| model.logits(backend, v), &val_x, &val_y)
        });
        curve.push(EpochRecord {
            epoch,
            train_loss: loss.mean(),
            val_accuracy: val.accuracy,
            seconds,
        });
        obs::flush_epoch(&tag, epoch);
    }

    let test = eval_pooled(pool.as_ref(), || {
        evaluate_with(backend, classes, |v| model.logits(backend, v), &test_x, &test_y)
    });
    TrainResult { model, curve, test }
}

/// One sharded training step, shared by both model families: fan the
/// per-sample backward `local` across the pool (the ambient rayon pool
/// when `pool` is `None` — same bits either way, since the reduction is
/// slot-positional), reduce in the canonical order, apply the single
/// `1/B` scale. Statistics come back as **raw sums** so the epoch loop
/// can fold exact per-sample loss sums ([`EpochLoss::add_sum`]).
fn sharded_step<B, G, F>(
    backend: &B,
    pool: Option<&rayon::ThreadPool>,
    batch: usize,
    local: F,
) -> (G, RawStepStats)
where
    B: Backend,
    G: GradStore<B>,
    F: Fn(usize) -> (G, RawStepStats) + Sync,
{
    let (mut g, raw) = shard::sharded_backprop_sums(backend, pool, batch, local);
    let _sp = span(SpanKind::Scale);
    // numerics-lint: allow(float-leak) — the single 1/B scale (§3), computed in f64, encoded once
    g.scale(backend, 1.0 / raw.n as f64);
    (g, raw)
}

/// Gather a batch by row indices from a pre-encoded tensor.
fn gather_batch<B: Backend>(
    backend: &B,
    x: &Tensor<B::E>,
    y: &[usize],
    idx: &[usize],
) -> (Tensor<B::E>, Vec<usize>) {
    let mut data = Vec::with_capacity(idx.len() * x.cols);
    let mut labels = Vec::with_capacity(idx.len());
    for &i in idx {
        data.extend_from_slice(x.row(i));
        labels.push(y[i]);
    }
    let _ = backend;
    (Tensor::from_vec(idx.len(), x.cols, data), labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_dataset, SynthSpec};
    use crate::fixed::{FixedConfig, FixedSystem};
    use crate::lns::{LnsConfig, LnsSystem};
    use crate::tensor::{FixedBackend, FloatBackend, LnsBackend};

    fn tiny_ds() -> Dataset {
        synth_dataset(&SynthSpec {
            name: "tiny".into(),
            classes: 3,
            train_per_class: 40,
            test_per_class: 10,
            strokes: 4,
            jitter_px: 1.5,
            jitter_rot: 0.15,
            noise: 0.04,
            seed: 99,
        })
    }

    fn tiny_cfg(classes: usize, epochs: usize) -> TrainConfig {
        TrainConfig {
            dims: vec![784, 16, classes],
            epochs,
            batch_size: 5,
            sgd: SgdConfig { lr: 0.02, weight_decay: 0.0 },
            val_ratio: 5,
            init: InitScheme::HeNormal,
            seed: 7,
            shard: ShardConfig::default(),
            precision: PrecisionMap::uniform(),
        }
    }

    #[test]
    fn epoch_loss_weights_partial_final_batch_by_samples() {
        // n = 7, batch_size = 5 ⇒ n % bs = 2: a 5-sample batch with loss
        // sum 5.0 (mean 1.0) and a 2-sample batch with loss sum 8.0
        // (mean 4.0).
        let mut acc = EpochLoss::default();
        acc.add_sum(5.0, 5);
        acc.add_sum(8.0, 2);
        let want = (5.0 + 8.0) / 7.0;
        assert!((acc.mean() - want).abs() < 1e-12, "{} vs {want}", acc.mean());
        // The pre-fix batches-mean formula would report (1 + 4)/2 = 2.5,
        // overweighting the 2-sample batch.
        assert!((acc.mean() - 2.5).abs() > 0.3);
        assert_eq!(EpochLoss::default().mean(), 0.0);
    }

    #[test]
    fn epoch_loss_equals_batch_mean_for_uniform_batches() {
        // With every batch full the sample weighting reduces to the old
        // mean-of-batch-means — the fix must not change full-batch
        // epochs: sums 1, 2, 3 over 4 samples each (means 0.25/0.5/0.75).
        let mut acc = EpochLoss::default();
        for sum in [1.0, 2.0, 3.0] {
            acc.add_sum(sum, 4);
        }
        assert!((acc.mean() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sharded_mlp_training_matches_serial_bitwise() {
        let ds = tiny_ds();
        let serial = train(&FloatBackend::default(), &ds, &tiny_cfg(3, 2));
        let mut cfg = tiny_cfg(3, 2);
        cfg.shard = ShardConfig::with_shards(3);
        let sharded = train(&FloatBackend::default(), &ds, &cfg);
        for l in 0..serial.model.layers.len() {
            assert_eq!(serial.model.layers[l].w.data, sharded.model.layers[l].w.data);
            assert_eq!(serial.model.layers[l].b, sharded.model.layers[l].b);
        }
        assert_eq!(serial.test.accuracy, sharded.test.accuracy);
        assert_eq!(serial.test.loss, sharded.test.loss);
    }

    #[test]
    fn float_training_learns_tiny_task() {
        let ds = tiny_ds();
        let r = train(&FloatBackend::default(), &ds, &tiny_cfg(3, 6));
        assert_eq!(r.curve.len(), 6);
        assert!(
            r.test.accuracy > 0.8,
            "float should learn the tiny task: acc={}",
            r.test.accuracy
        );
        assert!(r.curve.last().unwrap().train_loss < r.curve[0].train_loss);
    }

    #[test]
    fn lns16_training_tracks_float() {
        let ds = tiny_ds();
        let float_acc = train(&FloatBackend::default(), &ds, &tiny_cfg(3, 6)).test.accuracy;
        let lns = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let lns_acc = train(&lns, &ds, &tiny_cfg(3, 6)).test.accuracy;
        assert!(
            lns_acc > float_acc - 0.12,
            "16-bit LNS should track float: {lns_acc} vs {float_acc}"
        );
    }

    #[test]
    fn fixed16_training_tracks_float() {
        let ds = tiny_ds();
        let float_acc = train(&FloatBackend::default(), &ds, &tiny_cfg(3, 6)).test.accuracy;
        let fx = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let fx_acc = train(&fx, &ds, &tiny_cfg(3, 6)).test.accuracy;
        assert!(
            fx_acc > float_acc - 0.12,
            "16-bit fixed should track float: {fx_acc} vs {float_acc}"
        );
    }

    #[test]
    fn training_is_deterministic_in_seed() {
        let ds = tiny_ds();
        let a = train(&FloatBackend::default(), &ds, &tiny_cfg(3, 2));
        let b = train(&FloatBackend::default(), &ds, &tiny_cfg(3, 2));
        assert_eq!(a.test.accuracy, b.test.accuracy);
        assert_eq!(a.model.layers[0].w.data, b.model.layers[0].w.data);
    }

    #[test]
    #[should_panic(expected = "model head must match")]
    fn wrong_head_panics() {
        let ds = tiny_ds();
        let _ = train(&FloatBackend::default(), &ds, &tiny_cfg(5, 1));
    }

    #[test]
    fn cnn_float_training_learns_stripes() {
        use crate::data::{stripes_dataset, StripeSpec};
        let ds = stripes_dataset(&StripeSpec {
            name: "stripes".into(),
            side: 12,
            classes: 4,
            train_per_class: 60,
            test_per_class: 15,
            wavelength: 4.0,
            jitter_rot: 0.08,
            noise: 0.02,
            seed: 5,
        });
        let mut cfg = CnnTrainConfig::lenet(12, 4);
        cfg.arch.c1 = 4;
        cfg.arch.c2 = 8;
        cfg.arch.hidden = 32;
        cfg.epochs = 5;
        cfg.sgd = SgdConfig { lr: 0.02, weight_decay: 0.0 };
        cfg.seed = 9;
        let r = train_cnn(&FloatBackend::default(), &ds, &cfg);
        assert_eq!(r.curve.len(), 5);
        assert!(
            r.test.accuracy > 0.8,
            "float CNN should learn oriented stripes: acc={}",
            r.test.accuracy
        );
        assert!(r.curve.last().unwrap().train_loss < r.curve[0].train_loss);
    }

    #[test]
    #[should_panic(expected = "CNN head must match")]
    fn cnn_wrong_head_panics() {
        let ds = tiny_ds();
        let cfg = CnnTrainConfig::lenet(28, 5);
        let _ = train_cnn(&FloatBackend::default(), &ds, &cfg);
    }
}
