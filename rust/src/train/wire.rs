//! Versioned wire format for multi-process sharded training.
//!
//! The multi-process trainer ([`crate::train::multiproc`]) moves
//! gradients between worker processes and the coordinator as
//! **length-prefixed frames** over a byte stream (an stdio pipe or a TCP
//! socket — the format is transport-agnostic). Everything that crosses
//! the process boundary is a frame; nothing else is ever written to the
//! stream.
//!
//! # Framing rules
//!
//! Every frame is a fixed 19-byte header followed by the payload:
//!
//! | offset | size | field | meaning |
//! |--------|------|-------|---------|
//! | 0 | 4 | magic | `b"LNSW"` — stream sanity check |
//! | 4 | 2 | version | [`WIRE_VERSION`], little-endian `u16` |
//! | 6 | 1 | kind | [`FrameKind`] discriminant |
//! | 7 | 4 | len | payload length, little-endian `u32` |
//! | 11 | 8 | checksum | FNV-1a 64 of the payload ([`fnv1a64`]) |
//! | 19 | len | payload | kind-specific body |
//!
//! Decoding is strict: a wrong magic, an unknown kind, a version other
//! than [`WIRE_VERSION`], a payload that fails its checksum, or a
//! truncated stream are all **hard errors** — a frame is either accepted
//! bit-exactly or the training run aborts. There is no renegotiation and
//! no silent skip, because a dropped or altered gradient frame would
//! change the ⊞ reduction chain and break the bit-exactness contract
//! (see `docs/NUMERICS.md`).
//!
//! All multi-byte integers are little-endian. `f64` fields travel as
//! their IEEE-754 bit patterns, and backend elements travel as their
//! exact in-memory words ([`WireElem`]), so every numeric value
//! round-trips **bit-identically** — serialization is pure data
//! movement, never arithmetic.
//!
//! ```
//! use lnsdnn::train::wire::{self, FrameKind};
//! let mut buf = Vec::new();
//! wire::write_frame(&mut buf, FrameKind::Digest, b"hello").unwrap();
//! let frame = wire::read_frame(&mut buf.as_slice()).unwrap();
//! assert_eq!(frame.kind, FrameKind::Digest);
//! assert_eq!(frame.payload, b"hello");
//! ```

use crate::data::Dataset;
use crate::lns::LnsValue;
use crate::nn::{CnnArch, CnnVariant, InitScheme, PoolKind, RawStepStats};
use crate::obs::{self, span, SpanKind};
use crate::precision::{PrecisionMap, WordSpec, MAX_PRECISION_LAYERS};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// Stream sanity marker at the start of every frame.
pub const WIRE_MAGIC: [u8; 4] = *b"LNSW";

/// Wire protocol version. Bump on ANY layout change — peers reject every
/// other version outright (bit-exactness makes "best-effort" decoding of
/// a near-miss layout worse than failing).
///
/// History: v1 = initial framing; v2 = added [`FrameKind::Heartbeat`]
/// (worker progress/telemetry frames); v3 = heartbeat payloads grew the
/// trailing `dist` section (value-distribution histogram deltas,
/// [`crate::obs::dist::DistEntry`]) for fleet-wide range-occupancy
/// aggregation; v4 = job payloads carry the per-layer precision table
/// ([`PrecisionMap`] — mixed-precision training) between the
/// `worker_threads` field and the dataset section, so workers reproduce
/// the exact per-layer storage widths (v3 peers are refused outright).
pub const WIRE_VERSION: u16 = 4;

/// Upper bound on a single payload (guards against allocating from a
/// corrupt or hostile length field).
pub const MAX_FRAME_LEN: u32 = 1 << 30;

/// The slot marker for a coordinator→worker merged-sums broadcast
/// (per-sample frames use their global in-batch sample index).
pub const MERGED_SLOT: u32 = u32::MAX;

/// What a frame carries.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Coordinator → worker: the full job description ([`JobSpec`] +
    /// dataset) — always the first frame on a connection.
    Job = 1,
    /// Worker → coordinator: one sample's unscaled gradient sums
    /// ([`GradFrame`] with the sample's in-batch slot index).
    GradSums = 2,
    /// Coordinator → worker: the merged unscaled batch sums
    /// ([`GradFrame`] with slot [`MERGED_SLOT`]).
    Merged = 3,
    /// Worker → coordinator: final parameter digest ([`DigestMsg`]) for
    /// end-of-run replica verification.
    Digest = 4,
    /// Worker → coordinator: progress + telemetry ([`HeartbeatMsg`]).
    /// Pure observability — carries no values that feed any reduction,
    /// so the coordinator may consume it at any point between gradient
    /// frames without touching the numerics (since wire v2).
    Heartbeat = 5,
}

impl FrameKind {
    fn from_u8(v: u8) -> Result<FrameKind> {
        Ok(match v {
            1 => FrameKind::Job,
            2 => FrameKind::GradSums,
            3 => FrameKind::Merged,
            4 => FrameKind::Digest,
            5 => FrameKind::Heartbeat,
            other => bail!("unknown frame kind {other}"),
        })
    }
}

/// One decoded frame.
#[derive(Clone, Debug)]
pub struct Frame {
    /// What the payload is.
    pub kind: FrameKind,
    /// Kind-specific body bytes (checksum already verified).
    pub payload: Vec<u8>,
}

/// Streaming FNV-1a 64 — the frame checksum and the parameter-digest
/// hash. Not cryptographic; it detects corruption and replica
/// divergence, not adversaries. The streaming form lets
/// [`write_job_frame`] checksum a multi-megabyte dataset without
/// materializing the payload.
pub struct Fnv64(u64);

impl Fnv64 {
    /// Fresh hasher at the FNV-1a offset basis.
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    /// Fold more bytes in.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// The digest so far.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// One-shot [`Fnv64`].
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

fn frame_header(version: u16, kind: FrameKind, len: usize, checksum: u64) -> [u8; 19] {
    let mut header = [0u8; 19];
    header[0..4].copy_from_slice(&WIRE_MAGIC);
    header[4..6].copy_from_slice(&version.to_le_bytes());
    header[6] = kind as u8;
    header[7..11].copy_from_slice(&(len as u32).to_le_bytes());
    header[11..19].copy_from_slice(&checksum.to_le_bytes());
    header
}

/// Write one frame (header + payload) and flush the stream.
pub fn write_frame<W: Write>(w: &mut W, kind: FrameKind, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME_LEN as usize,
        "frame payload too large: {} bytes",
        payload.len()
    );
    write_frame_with_version(w, WIRE_VERSION, kind, payload)
}

/// [`write_frame`] with an explicit version stamp. This is the test seam
/// for the version-mismatch rejection path; production code always goes
/// through [`write_frame`].
pub fn write_frame_with_version<W: Write>(
    w: &mut W,
    version: u16,
    kind: FrameKind,
    payload: &[u8],
) -> Result<()> {
    let _sp = span(SpanKind::WireEncode);
    tally_tx(payload.len());
    let header = frame_header(version, kind, payload.len(), fnv1a64(payload));
    w.write_all(&header).context("writing frame header")?;
    w.write_all(payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Observability hook for an outgoing frame: frame/byte counters plus
/// the payload-size histogram. One relaxed load when counting is off.
fn tally_tx(payload_len: usize) {
    if obs::counters_enabled() {
        obs::metrics::WIRE_FRAMES_TX.add(1);
        obs::metrics::WIRE_BYTES_TX.add(19 + payload_len as u64);
        obs::metrics::WIRE_FRAME_BYTES.record(payload_len as u64);
    }
}

/// Read one frame, verifying magic, version, length bound and checksum.
/// Every failure (including EOF mid-frame) is a hard error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let _sp = span(SpanKind::WireDecode);
    let mut header = [0u8; 19];
    r.read_exact(&mut header).context("reading frame header (peer closed the stream?)")?;
    ensure!(
        header[0..4] == WIRE_MAGIC,
        "bad frame magic {:02x?} (stream is not speaking the lnsdnn wire format)",
        &header[0..4]
    );
    let version = u16::from_le_bytes([header[4], header[5]]);
    ensure!(
        version == WIRE_VERSION,
        "wire version mismatch: peer speaks v{version}, this build speaks v{WIRE_VERSION}"
    );
    let kind = FrameKind::from_u8(header[6])?;
    let len = u32::from_le_bytes([header[7], header[8], header[9], header[10]]);
    ensure!(len <= MAX_FRAME_LEN, "frame payload length {len} exceeds MAX_FRAME_LEN");
    // numerics-lint: allow(hostile-input) — constant 8-byte split of the stack header; cannot fail
    let want_sum = u64::from_le_bytes(header[11..19].try_into().unwrap());
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).context("reading frame payload (truncated frame)")?;
    let got_sum = fnv1a64(&payload);
    if obs::counters_enabled() {
        obs::metrics::WIRE_FRAMES_RX.add(1);
        obs::metrics::WIRE_BYTES_RX.add(19 + len as u64);
        if got_sum != want_sum {
            obs::metrics::WIRE_CHECKSUM_FAIL.add(1);
        }
    }
    ensure!(
        got_sum == want_sum,
        "frame checksum mismatch (corrupt frame): got {got_sum:#018x}, header says {want_sum:#018x}"
    );
    Ok(Frame { kind, payload })
}

// ---------------------------------------------------------------------
// Element encoding
// ---------------------------------------------------------------------

/// A backend element that can cross the wire as its exact word.
///
/// The contract is bit-exact round-tripping: `take(put(e)) == e` for
/// every representable element, including negative zeros, the LNS zero
/// word, and saturated fixed-point values. Each element type carries a
/// distinct tag so a coordinator/worker backend mismatch is detected at
/// decode time instead of silently reinterpreting words.
pub trait WireElem: Copy {
    /// Type tag stored in gradient frames (1 = f32, 2 = fixed i32,
    /// 3 = LNS).
    const TAG: u8;
    /// Encoded size in bytes.
    const SIZE: usize;
    /// Append the exact wire encoding to `out`.
    fn put(&self, out: &mut Vec<u8>);
    /// Decode from exactly [`WireElem::SIZE`] bytes.
    fn take(bytes: &[u8]) -> Self;
}

impl WireElem for f32 {
    const TAG: u8 = 1;
    const SIZE: usize = 4;
    fn put(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bits().to_le_bytes());
    }
    fn take(bytes: &[u8]) -> Self {
        // numerics-lint: allow(hostile-input) — callers hand exactly SIZE length-checked bytes
        f32::from_bits(u32::from_le_bytes(bytes[0..4].try_into().unwrap()))
    }
}

/// Linear fixed point ([`crate::fixed::FixedValue`] is `i32`).
impl WireElem for i32 {
    const TAG: u8 = 2;
    const SIZE: usize = 4;
    fn put(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn take(bytes: &[u8]) -> Self {
        // numerics-lint: allow(hostile-input) — callers hand exactly SIZE length-checked bytes
        i32::from_le_bytes(bytes[0..4].try_into().unwrap())
    }
}

impl WireElem for LnsValue {
    const TAG: u8 = 3;
    const SIZE: usize = 5;
    fn put(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.m.to_le_bytes());
        out.push(self.s as u8);
    }
    fn take(bytes: &[u8]) -> Self {
        // numerics-lint: allow(hostile-input) — callers hand exactly SIZE length-checked bytes
        LnsValue::new(i32::from_le_bytes(bytes[0..4].try_into().unwrap()), bytes[4] != 0)
    }
}

// ---------------------------------------------------------------------
// Payload primitives
// ---------------------------------------------------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_bytes(out: &mut Vec<u8>, v: &[u8]) {
    put_u64(out, v.len() as u64);
    out.extend_from_slice(v);
}

fn put_str(out: &mut Vec<u8>, v: &str) {
    put_bytes(out, v.as_bytes());
}

/// Bounds-checked cursor over a payload.
struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // Checked: `n` often comes straight from an untrusted length
        // field, so `pos + n` must not be allowed to wrap.
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            bail!(
                "truncated payload: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        };
        // numerics-lint: allow(hostile-input) — `end` was overflow- and bounds-checked just above
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        // numerics-lint: allow(hostile-input) — take(1) returned exactly one byte
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        // numerics-lint: allow(hostile-input) — take(4) returned exactly four bytes; cannot fail
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        // numerics-lint: allow(hostile-input) — take(8) returned exactly eight bytes; cannot fail
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.usize()?;
        Ok(self.take(n)?.to_vec())
    }

    fn string(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?).context("payload string is not UTF-8")
    }

    fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "payload has {} trailing bytes",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Gradient frames
// ---------------------------------------------------------------------

/// A decoded gradient-carrying payload: either one sample's unscaled
/// gradient sums (worker → coordinator, `slot` = the sample's in-batch
/// index) or the merged batch sums (coordinator → worker,
/// `slot` = [`MERGED_SLOT`]).
///
/// `views` are the flat per-layer gradient views in the canonical
/// [`crate::nn::GradStore`] order (each layer's weight buffer, then its
/// bias buffer, layers ascending) — the same order every reduction in
/// the tree uses, so the wire never reorders a ⊞ chain.
#[derive(Clone, Debug)]
pub struct GradFrame<E> {
    /// Epoch the step belongs to (1-based, mirrors the trainer).
    pub epoch: u32,
    /// Step index within the epoch (0-based).
    pub step: u32,
    /// In-batch sample index, or [`MERGED_SLOT`] for a broadcast.
    pub slot: u32,
    /// Raw loss/accuracy sums riding along with the gradient sums.
    pub stats: RawStepStats,
    /// Flat per-layer gradient views, canonical order.
    pub views: Vec<Vec<E>>,
}

impl<E: WireElem> GradFrame<E> {
    /// Encode a gradient payload directly from borrowed views (avoids
    /// copying the gradient store just to serialize it).
    pub fn encode_parts(
        epoch: u32,
        step: u32,
        slot: u32,
        stats: &RawStepStats,
        views: &[&[E]],
    ) -> Vec<u8> {
        let elems: usize = views.iter().map(|v| v.len()).sum();
        let mut out = Vec::with_capacity(32 + views.len() * 8 + elems * E::SIZE);
        put_u8(&mut out, E::TAG);
        put_u32(&mut out, epoch);
        put_u32(&mut out, step);
        put_u32(&mut out, slot);
        put_f64(&mut out, stats.loss_sum);
        put_u64(&mut out, stats.correct as u64);
        put_u64(&mut out, stats.n as u64);
        put_u32(&mut out, views.len() as u32);
        for view in views {
            put_u64(&mut out, view.len() as u64);
            for e in view.iter() {
                e.put(&mut out);
            }
        }
        out
    }

    /// Decode, checking the element tag against the caller's backend.
    pub fn decode(payload: &[u8]) -> Result<GradFrame<E>> {
        let mut r = ByteReader::new(payload);
        let tag = r.u8()?;
        ensure!(
            tag == E::TAG,
            "gradient element tag mismatch: frame carries tag {tag}, this backend expects {} \
             (coordinator and worker must run the same backend)",
            E::TAG
        );
        let epoch = r.u32()?;
        let step = r.u32()?;
        let slot = r.u32()?;
        let stats = RawStepStats {
            loss_sum: r.f64()?,
            correct: r.u64()? as usize,
            n: r.u64()? as usize,
        };
        let n_views = r.u32()? as usize;
        // Every view costs at least its 8-byte length prefix, so a count
        // beyond that is a corrupt/hostile header — reject before
        // allocating anything sized by it.
        ensure!(
            n_views <= r.remaining() / 8,
            "gradient frame claims {n_views} views but only {} payload bytes remain",
            r.remaining()
        );
        let mut views = Vec::with_capacity(n_views);
        for _ in 0..n_views {
            let len = r.usize()?;
            let byte_len = len
                .checked_mul(E::SIZE)
                .filter(|&b| b <= r.remaining())
                .with_context(|| format!("gradient view length {len} exceeds the payload"))?;
            let bytes = r.take(byte_len)?;
            let mut view = Vec::with_capacity(len);
            for i in 0..len {
                // numerics-lint: allow(hostile-input) — byte_len = len·SIZE was checked above; i < len
                view.push(E::take(&bytes[i * E::SIZE..(i + 1) * E::SIZE]));
            }
            views.push(view);
        }
        r.done()?;
        Ok(GradFrame { epoch, step, slot, stats, views })
    }
}

/// End-of-run digest: FNV-1a 64 over the worker's final parameter words
/// (in [`WireElem`] encoding, canonical layer order) plus the parameter
/// count. The coordinator compares it against its own replica to prove
/// the mirrored updates never diverged.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct DigestMsg {
    /// FNV-1a 64 of the encoded parameters.
    pub digest: u64,
    /// Scalar parameter count (cheap extra shape check).
    pub params: u64,
}

impl DigestMsg {
    /// Encode to a payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        put_u64(&mut out, self.digest);
        put_u64(&mut out, self.params);
        out
    }

    /// Decode from a payload.
    pub fn decode(payload: &[u8]) -> Result<DigestMsg> {
        let mut r = ByteReader::new(payload);
        let msg = DigestMsg { digest: r.u64()?, params: r.u64()? };
        r.done()?;
        Ok(msg)
    }
}

// ---------------------------------------------------------------------
// Heartbeat frames (wire v2)
// ---------------------------------------------------------------------

/// Worker → coordinator progress + telemetry (a [`FrameKind::Heartbeat`]
/// payload). Strictly observational: nothing in it feeds a reduction or
/// an update, so a heartbeat can never change trained bits. Workers emit
/// them at *deterministic* points in the batch loop (a function of the
/// step index, never of wall-clock time) so the frame sequence itself is
/// reproducible run-to-run; only the latencies the coordinator derives
/// from them are timing-dependent.
#[derive(Clone, Debug, PartialEq)]
pub struct HeartbeatMsg {
    /// Sender's worker rank.
    pub rank: u32,
    /// Epoch the worker is in (1-based, mirrors the trainer).
    pub epoch: u32,
    /// Step index within the epoch (0-based).
    pub step: u32,
    /// Samples processed so far across the whole run.
    pub samples_done: u64,
    /// Span rollups at emission time: `(span name, count, total ns)`.
    pub spans: Vec<(String, u64, u64)>,
    /// Counter totals at emission time: `(counter name, total)`.
    pub counters: Vec<(String, u64)>,
    /// Value-distribution histogram *deltas* since the previous
    /// heartbeat ([`crate::obs::dist::take_wire_delta`]); the
    /// coordinator reconstructs the worker's full occupancy banks by
    /// summing them (order-free — cell-wise u64 addition). Since
    /// wire v3.
    pub dist: Vec<crate::obs::dist::DistEntry>,
}

impl HeartbeatMsg {
    /// Encode to a payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.spans.len() * 40 + self.counters.len() * 32);
        put_u32(&mut out, self.rank);
        put_u32(&mut out, self.epoch);
        put_u32(&mut out, self.step);
        put_u64(&mut out, self.samples_done);
        put_u32(&mut out, self.spans.len() as u32);
        for (name, count, ns) in &self.spans {
            put_str(&mut out, name);
            put_u64(&mut out, *count);
            put_u64(&mut out, *ns);
        }
        put_u32(&mut out, self.counters.len() as u32);
        for (name, total) in &self.counters {
            put_str(&mut out, name);
            put_u64(&mut out, *total);
        }
        put_u32(&mut out, self.dist.len() as u32);
        for e in &self.dist {
            put_u8(&mut out, e.class);
            put_u8(&mut out, e.layer);
            put_u64(&mut out, e.zeros);
            put_u64(&mut out, e.neg);
            put_u32(&mut out, e.buckets.len() as u32);
            for &b in &e.buckets {
                put_u64(&mut out, b);
            }
        }
        out
    }

    /// Decode from a payload.
    pub fn decode(payload: &[u8]) -> Result<HeartbeatMsg> {
        let mut r = ByteReader::new(payload);
        let rank = r.u32()?;
        let epoch = r.u32()?;
        let step = r.u32()?;
        let samples_done = r.u64()?;
        let n_spans = r.u32()? as usize;
        // A span entry costs at least its 8-byte name prefix plus two
        // u64s; reject corrupt counts before allocating by them.
        ensure!(
            n_spans <= r.remaining() / 24,
            "heartbeat claims {n_spans} spans but only {} payload bytes remain",
            r.remaining()
        );
        let mut spans = Vec::with_capacity(n_spans);
        for _ in 0..n_spans {
            let name = r.string()?;
            let count = r.u64()?;
            let ns = r.u64()?;
            spans.push((name, count, ns));
        }
        let n_counters = r.u32()? as usize;
        ensure!(
            n_counters <= r.remaining() / 16,
            "heartbeat claims {n_counters} counters but only {} payload bytes remain",
            r.remaining()
        );
        let mut counters = Vec::with_capacity(n_counters);
        for _ in 0..n_counters {
            let name = r.string()?;
            let total = r.u64()?;
            counters.push((name, total));
        }
        let n_dist = r.u32()? as usize;
        // A dist entry costs at least class + layer + zeros + neg + the
        // bucket-count u32 (22 bytes); reject hostile counts before
        // allocating by them.
        ensure!(
            n_dist <= r.remaining() / 22,
            "heartbeat claims {n_dist} dist entries but only {} payload bytes remain",
            r.remaining()
        );
        let mut dist = Vec::with_capacity(n_dist);
        for _ in 0..n_dist {
            let class = r.u8()?;
            let layer = r.u8()?;
            let zeros = r.u64()?;
            let neg = r.u64()?;
            let n_buckets = r.u32()? as usize;
            ensure!(
                n_buckets <= r.remaining() / 8,
                "heartbeat dist entry claims {n_buckets} buckets but only {} payload bytes remain",
                r.remaining()
            );
            let mut buckets = Vec::with_capacity(n_buckets);
            for _ in 0..n_buckets {
                buckets.push(r.u64()?);
            }
            dist.push(crate::obs::dist::DistEntry { class, layer, zeros, neg, buckets });
        }
        r.done()?;
        Ok(HeartbeatMsg { rank, epoch, step, samples_done, spans, counters, dist })
    }
}

// ---------------------------------------------------------------------
// Job frames
// ---------------------------------------------------------------------

/// Which model family a job trains (the architecture travels with it).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelSpec {
    /// MLP with the given layer sizes (input and output included).
    Mlp {
        /// Layer sizes, e.g. `[784, 100, 10]`.
        dims: Vec<usize>,
    },
    /// The LeNet-style CNN with its full architecture record.
    Cnn {
        /// Architecture (includes the pooled/strided variant).
        arch: CnnArch,
    },
}

/// Everything a worker needs to replicate the coordinator's training run
/// deterministically: model + hyper-parameters + its own shard identity.
/// The dataset rides in the same frame (see [`encode_job`]) so workers
/// need no filesystem access and no generator coupling.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Backend tag ([`crate::tensor::Backend::tag`] format, e.g.
    /// `log16-lut`); the worker reconstructs the identical backend.
    pub backend_tag: String,
    /// Leaky/llReLU slope the backend was built with.
    pub slope: f64,
    /// Backend fingerprint ([`crate::train::multiproc::act_probe`]):
    /// wire encodings of `leaky_relu(encode(-1.0))`, a ⊞ and a ⊟ at
    /// generic operands, and a small soft-max/CE evaluation — sensitive
    /// to the slope, the word format, the Δ± mode and LUT shape, and
    /// the soft-max Δ tables. The tag + slope pair under-determines a
    /// backend, so the worker recomputes this probe on its
    /// reconstruction and refuses to run on a mismatch — a silent
    /// config divergence would train different bits.
    pub act_probe: Vec<u8>,
    /// Model family + architecture.
    pub model: ModelSpec,
    /// Epochs.
    pub epochs: usize,
    /// Mini-batch size.
    pub batch_size: usize,
    /// SGD learning rate.
    pub lr: f64,
    /// SGD weight decay.
    pub weight_decay: f64,
    /// Validation hold-back denominator.
    pub val_ratio: usize,
    /// Weight-init scheme.
    pub init: InitScheme,
    /// Master seed (init, shuffles, split) — identical on every replica.
    pub seed: u64,
    /// This worker's rank in `0..workers`.
    pub rank: usize,
    /// Total worker count (fixes the per-batch slot ranges).
    pub workers: usize,
    /// Rayon threads the worker should build its global pool with
    /// (0 = library default).
    pub worker_threads: usize,
    /// Per-layer storage words (mixed precision, NUMERICS.md §11).
    /// Replicated exactly: a replica quantizing to different widths
    /// would train different bits, so the table travels in the job
    /// frame and the [`crate::train::multiproc::act_probe`] fingerprint
    /// covers it too (since wire v4).
    pub precision: PrecisionMap,
}

fn put_init(out: &mut Vec<u8>, init: InitScheme) {
    let code = match init {
        InitScheme::HeNormal => 0,
        InitScheme::LogDomain => 1,
    };
    put_u8(out, code);
}

fn read_init(r: &mut ByteReader<'_>) -> Result<InitScheme> {
    Ok(match r.u8()? {
        0 => InitScheme::HeNormal,
        1 => InitScheme::LogDomain,
        other => bail!("unknown init scheme {other}"),
    })
}

fn put_model(out: &mut Vec<u8>, model: &ModelSpec) {
    match model {
        ModelSpec::Mlp { dims } => {
            put_u8(out, 0);
            put_u32(out, dims.len() as u32);
            for &d in dims {
                put_u64(out, d as u64);
            }
        }
        ModelSpec::Cnn { arch } => {
            put_u8(out, 1);
            let geometry = [
                arch.in_c,
                arch.in_h,
                arch.in_w,
                arch.c1,
                arch.c2,
                arch.k,
                arch.pad,
                arch.pool,
                arch.hidden,
                arch.classes,
            ];
            for v in geometry {
                put_u64(out, v as u64);
            }
            let pool_code = match arch.pool_kind {
                PoolKind::Max => 0,
                PoolKind::Avg => 1,
            };
            put_u8(out, pool_code);
            let variant_code = match arch.variant {
                CnnVariant::Pooled => 0,
                CnnVariant::StridedV1 => 1,
            };
            put_u8(out, variant_code);
        }
    }
}

fn read_model(r: &mut ByteReader<'_>) -> Result<ModelSpec> {
    Ok(match r.u8()? {
        0 => {
            let n = r.u32()? as usize;
            ensure!(
                n <= r.remaining() / 8,
                "MLP spec claims {n} dims but only {} payload bytes remain",
                r.remaining()
            );
            let mut dims = Vec::with_capacity(n);
            for _ in 0..n {
                dims.push(r.usize()?);
            }
            ModelSpec::Mlp { dims }
        }
        1 => {
            let in_c = r.usize()?;
            let in_h = r.usize()?;
            let in_w = r.usize()?;
            let c1 = r.usize()?;
            let c2 = r.usize()?;
            let k = r.usize()?;
            let pad = r.usize()?;
            let pool = r.usize()?;
            let hidden = r.usize()?;
            let classes = r.usize()?;
            let pool_kind = match r.u8()? {
                0 => PoolKind::Max,
                1 => PoolKind::Avg,
                other => bail!("unknown pool kind {other}"),
            };
            let variant = match r.u8()? {
                0 => CnnVariant::Pooled,
                1 => CnnVariant::StridedV1,
                other => bail!("unknown CNN variant {other}"),
            };
            ModelSpec::Cnn {
                arch: CnnArch {
                    in_c,
                    in_h,
                    in_w,
                    c1,
                    c2,
                    k,
                    pad,
                    pool,
                    pool_kind,
                    hidden,
                    classes,
                    variant,
                },
            }
        }
        other => bail!("unknown model kind {other}"),
    })
}

/// Per-layer precision table (wire v4): a `u32` layer count, then per
/// layer a presence flag `u8` (0 = base word, 1 = assigned) followed by
/// `total_bits` and `frac_bits` as one byte each (zero when unassigned).
fn put_precision(out: &mut Vec<u8>, pmap: &PrecisionMap) {
    let layers = pmap.layers();
    put_u32(out, layers.len() as u32);
    for spec in layers {
        match spec {
            Some(w) => {
                put_u8(out, 1);
                put_u8(out, w.total_bits as u8);
                put_u8(out, w.frac_bits as u8);
            }
            None => {
                put_u8(out, 0);
                put_u8(out, 0);
                put_u8(out, 0);
            }
        }
    }
}

/// Decode the wire-v4 precision table. Hard errors on a hostile layer
/// count, an unknown presence flag, or a word layout outside
/// [`WordSpec::validate`] bounds (e.g. out-of-range `frac_bits`) — a
/// silently defaulted table would train different bits than the
/// coordinator.
fn decode_precision(r: &mut ByteReader<'_>) -> Result<PrecisionMap> {
    let n = r.u32()? as usize;
    // Each entry costs exactly 3 bytes; also cap at the engine's layer
    // bound so hostile counts are rejected before allocating by them.
    ensure!(
        n <= MAX_PRECISION_LAYERS && n <= r.remaining() / 3,
        "job precision table claims {n} layers but only {} payload bytes remain",
        r.remaining()
    );
    let mut layers = Vec::with_capacity(n);
    for _ in 0..n {
        let flag = r.u8()?;
        let total = r.u8()? as u32;
        let frac = r.u8()? as u32;
        match flag {
            0 => {
                ensure!(
                    total == 0 && frac == 0,
                    "unassigned precision entry carries width bits {total}/{frac}"
                );
                layers.push(None);
            }
            1 => layers.push(Some(WordSpec { total_bits: total, frac_bits: frac })),
            other => bail!("unknown precision entry flag {other}"),
        }
    }
    PrecisionMap::from_layers(layers).map_err(|e| anyhow::anyhow!("job precision table: {e}"))
}

/// Everything in a job payload *before* the four dataset byte arrays
/// (which [`write_job_frame`] streams rather than materializing).
fn encode_job_head(job: &JobSpec, ds: &Dataset) -> Vec<u8> {
    let mut out = Vec::with_capacity(256);
    put_str(&mut out, &job.backend_tag);
    put_f64(&mut out, job.slope);
    put_bytes(&mut out, &job.act_probe);
    put_model(&mut out, &job.model);
    put_u64(&mut out, job.epochs as u64);
    put_u64(&mut out, job.batch_size as u64);
    put_f64(&mut out, job.lr);
    put_f64(&mut out, job.weight_decay);
    put_u64(&mut out, job.val_ratio as u64);
    put_init(&mut out, job.init);
    put_u64(&mut out, job.seed);
    put_u32(&mut out, job.rank as u32);
    put_u32(&mut out, job.workers as u32);
    put_u32(&mut out, job.worker_threads as u32);
    put_precision(&mut out, &job.precision);
    put_str(&mut out, &ds.name);
    put_u64(&mut out, ds.classes as u64);
    put_u64(&mut out, ds.pixels as u64);
    out
}

/// Encode a [`JobSpec`] plus the full dataset into a [`FrameKind::Job`]
/// payload. The dataset travels verbatim (8-bit images + labels), so a
/// worker reproduces the coordinator's encode/split/shuffle stream
/// exactly without regenerating or re-reading anything.
///
/// This materializes the whole payload (dataset copy included); the
/// coordinator's send path uses [`write_job_frame`], which produces the
/// identical bytes while streaming the dataset straight from `ds`.
pub fn encode_job(job: &JobSpec, ds: &Dataset) -> Vec<u8> {
    let img_bytes = ds.train_images.len() + ds.test_images.len();
    let lbl_bytes = ds.train_labels.len() + ds.test_labels.len();
    let mut out = encode_job_head(job, ds);
    out.reserve(img_bytes + lbl_bytes + 32);
    put_bytes(&mut out, &ds.train_images);
    put_bytes(&mut out, &ds.train_labels);
    put_bytes(&mut out, &ds.test_images);
    put_bytes(&mut out, &ds.test_labels);
    out
}

/// Write a complete [`FrameKind::Job`] frame, streaming the dataset
/// arrays directly from `ds` instead of copying them into a payload
/// buffer first (a full-scale dataset is tens of megabytes, and the
/// coordinator sends one job frame per worker). Byte-for-byte identical
/// to `write_frame(w, FrameKind::Job, &encode_job(job, ds))`.
pub fn write_job_frame<W: Write>(w: &mut W, job: &JobSpec, ds: &Dataset) -> Result<()> {
    let _sp = span(SpanKind::WireEncode);
    let head = encode_job_head(job, ds);
    let arrays: [&[u8]; 4] =
        [&ds.train_images, &ds.train_labels, &ds.test_images, &ds.test_labels];
    let mut len = head.len();
    let mut crc = Fnv64::new();
    crc.update(&head);
    let mut prefixes = [[0u8; 8]; 4];
    for (prefix, arr) in prefixes.iter_mut().zip(arrays) {
        *prefix = (arr.len() as u64).to_le_bytes();
        crc.update(prefix);
        crc.update(arr);
        len += 8 + arr.len();
    }
    ensure!(len <= MAX_FRAME_LEN as usize, "job frame too large: {len} bytes");
    tally_tx(len);
    let header = frame_header(WIRE_VERSION, FrameKind::Job, len, crc.finish());
    w.write_all(&header).context("writing job frame header")?;
    w.write_all(&head).context("writing job frame head")?;
    for (prefix, arr) in prefixes.iter().zip(arrays) {
        w.write_all(prefix).context("writing job array prefix")?;
        w.write_all(arr).context("writing job array")?;
    }
    w.flush().context("flushing job frame")?;
    Ok(())
}

/// Decode a [`FrameKind::Job`] payload back into the job and dataset.
pub fn decode_job(payload: &[u8]) -> Result<(JobSpec, Dataset)> {
    let mut r = ByteReader::new(payload);
    let backend_tag = r.string()?;
    let slope = r.f64()?;
    let act_probe = r.bytes()?;
    let model = read_model(&mut r)?;
    let epochs = r.usize()?;
    let batch_size = r.usize()?;
    let lr = r.f64()?;
    let weight_decay = r.f64()?;
    let val_ratio = r.usize()?;
    let init = read_init(&mut r)?;
    let seed = r.u64()?;
    let rank = r.u32()? as usize;
    let workers = r.u32()? as usize;
    let worker_threads = r.u32()? as usize;
    let precision = decode_precision(&mut r)?;
    let name = r.string()?;
    let classes = r.usize()?;
    let pixels = r.usize()?;
    let train_images = r.bytes()?;
    let train_labels = r.bytes()?;
    let test_images = r.bytes()?;
    let test_labels = r.bytes()?;
    r.done()?;
    ensure!(batch_size > 0, "job batch_size must be positive");
    ensure!(val_ratio > 0, "job val_ratio must be positive");
    ensure!(workers > 0 && rank < workers, "bad worker identity {rank}/{workers}");
    ensure!(pixels > 0, "job dataset has zero pixels");
    ensure!(
        train_images.len() == train_labels.len() * pixels,
        "job dataset train images/labels are inconsistent"
    );
    ensure!(
        test_images.len() == test_labels.len() * pixels,
        "job dataset test images/labels are inconsistent"
    );
    let ds = Dataset {
        name,
        classes,
        pixels,
        train_images,
        train_labels,
        test_images,
        test_labels,
    };
    let job = JobSpec {
        backend_tag,
        slope,
        act_probe,
        model,
        epochs,
        batch_size,
        lr,
        weight_decay,
        val_ratio,
        init,
        seed,
        rank,
        workers,
        worker_threads,
        precision,
    };
    Ok((job, ds))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_dataset() -> Dataset {
        Dataset {
            name: "toy".into(),
            classes: 2,
            pixels: 4,
            train_images: (0..24).map(|i| (i * 9) as u8).collect(),
            train_labels: vec![0, 1, 0, 1, 0, 1],
            test_images: vec![7; 8],
            test_labels: vec![1, 0],
        }
    }

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::GradSums, b"payload bytes").unwrap();
        write_frame(&mut buf, FrameKind::Digest, b"").unwrap();
        let mut r = buf.as_slice();
        let a = read_frame(&mut r).unwrap();
        assert_eq!(a.kind, FrameKind::GradSums);
        assert_eq!(a.payload, b"payload bytes");
        let b = read_frame(&mut r).unwrap();
        assert_eq!(b.kind, FrameKind::Digest);
        assert!(b.payload.is_empty());
        assert!(r.is_empty());
    }

    #[test]
    fn corrupt_payload_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Merged, b"sensitive gradient bits").unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x01;
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn bad_magic_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Job, b"x").unwrap();
        buf[0] = b'X';
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut buf = Vec::new();
        write_frame_with_version(&mut buf, WIRE_VERSION + 1, FrameKind::Job, b"x").unwrap();
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("version mismatch"), "{msg}");
        assert!(msg.contains(&format!("v{}", WIRE_VERSION + 1)), "{msg}");
    }

    #[test]
    fn truncated_stream_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Digest, b"0123456789").unwrap();
        let cut = &buf[..buf.len() - 3];
        assert!(read_frame(&mut &cut[..]).is_err());
        // And inside the header too.
        assert!(read_frame(&mut &buf[..7]).is_err());
    }

    #[test]
    fn elements_roundtrip_bitwise() {
        for v in [0.0f32, -0.0, 1.5, -3.25e-20, f32::MAX, f32::MIN_POSITIVE] {
            let mut out = Vec::new();
            v.put(&mut out);
            assert_eq!(f32::take(&out).to_bits(), v.to_bits());
        }
        for v in [0i32, -1, i32::MAX, i32::MIN, 12345] {
            let mut out = Vec::new();
            v.put(&mut out);
            assert_eq!(i32::take(&out), v);
        }
        let lns_vals = [
            LnsValue::ZERO,
            LnsValue::ONE,
            LnsValue::new(-77, false),
            LnsValue::new(42, true),
        ];
        for v in lns_vals {
            let mut out = Vec::new();
            v.put(&mut out);
            assert_eq!(LnsValue::take(&out), v);
        }
    }

    #[test]
    fn grad_frame_roundtrip_lns() {
        let stats = RawStepStats { loss_sum: 1.25, correct: 3, n: 5 };
        let v0 = vec![LnsValue::ZERO, LnsValue::new(-3, false)];
        let v1 = vec![LnsValue::ONE];
        let views: Vec<&[LnsValue]> = vec![&v0, &v1];
        let payload = GradFrame::<LnsValue>::encode_parts(2, 7, 4, &stats, &views);
        let f = GradFrame::<LnsValue>::decode(&payload).unwrap();
        assert_eq!((f.epoch, f.step, f.slot), (2, 7, 4));
        assert_eq!(f.stats.loss_sum, 1.25);
        assert_eq!((f.stats.correct, f.stats.n), (3, 5));
        assert_eq!(f.views, vec![v0, v1]);
    }

    #[test]
    fn hostile_length_fields_error_instead_of_panicking() {
        // Length fields come off the wire: absurd values must surface as
        // Err (the hard-error decode policy), never a panic or an
        // allocation abort. The view length u64 sits 8 bytes before the
        // single view's 4 data bytes; the view count u32 sits before it.
        let views: Vec<&[f32]> = vec![&[1.0]];
        let mut payload =
            GradFrame::<f32>::encode_parts(1, 0, 0, &RawStepStats::default(), &views);
        let len_off = payload.len() - 4 - 8;
        payload[len_off..len_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(GradFrame::<f32>::decode(&payload).is_err());

        let mut payload =
            GradFrame::<f32>::encode_parts(1, 0, 0, &RawStepStats::default(), &views);
        let cnt_off = payload.len() - 4 - 8 - 4;
        payload[cnt_off..cnt_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(GradFrame::<f32>::decode(&payload).is_err());
    }

    #[test]
    fn grad_frame_rejects_wrong_element_tag() {
        let views: Vec<&[f32]> = vec![&[1.0, 2.0]];
        let payload = GradFrame::<f32>::encode_parts(1, 0, 0, &RawStepStats::default(), &views);
        let err = GradFrame::<i32>::decode(&payload).unwrap_err();
        assert!(err.to_string().contains("element tag mismatch"), "{err}");
    }

    #[test]
    fn job_roundtrip_mlp() {
        let ds = toy_dataset();
        let job = JobSpec {
            backend_tag: "log16-lut".into(),
            slope: 0.01,
            act_probe: vec![1, 2, 3, 4, 5],
            model: ModelSpec::Mlp { dims: vec![4, 8, 2] },
            epochs: 3,
            batch_size: 5,
            lr: 0.02,
            weight_decay: 1e-4,
            val_ratio: 5,
            init: InitScheme::HeNormal,
            seed: 0x5EED,
            rank: 1,
            workers: 2,
            worker_threads: 1,
            precision: PrecisionMap::parse("8,-", "log16-lut").unwrap(),
        };
        let payload = encode_job(&job, &ds);
        let (j2, d2) = decode_job(&payload).unwrap();
        assert_eq!(j2.backend_tag, "log16-lut");
        assert_eq!(j2.act_probe, vec![1, 2, 3, 4, 5]);
        assert_eq!(j2.model, job.model);
        assert_eq!((j2.rank, j2.workers), (1, 2));
        assert_eq!(j2.seed, job.seed);
        assert_eq!(j2.precision, job.precision, "per-layer widths round-trip exactly");
        assert_eq!(d2.name, ds.name);
        assert_eq!(d2.train_images, ds.train_images);
        assert_eq!(d2.test_labels, ds.test_labels);
    }

    #[test]
    fn job_roundtrip_cnn_and_consistency_checks() {
        let ds = toy_dataset();
        let arch = CnnArch::lenet(12, 2);
        let job = JobSpec {
            backend_tag: "float32".into(),
            slope: 0.01,
            act_probe: Vec::new(),
            model: ModelSpec::Cnn { arch: arch.clone() },
            epochs: 1,
            batch_size: 2,
            lr: 0.01,
            weight_decay: 0.0,
            val_ratio: 5,
            init: InitScheme::LogDomain,
            seed: 9,
            rank: 0,
            workers: 1,
            worker_threads: 0,
            precision: PrecisionMap::uniform(),
        };
        let payload = encode_job(&job, &ds);
        let (j2, _) = decode_job(&payload).unwrap();
        assert_eq!(j2.model, ModelSpec::Cnn { arch });
        assert_eq!(j2.init, InitScheme::LogDomain);

        // Inconsistent image/label sizes must be rejected.
        let mut bad = toy_dataset();
        bad.train_images.pop();
        let payload = encode_job(&job, &bad);
        assert!(decode_job(&payload).is_err());
    }

    #[test]
    fn job_precision_table_hostile_inputs_error() {
        let ds = toy_dataset();
        let job = JobSpec {
            backend_tag: "log16-lut".into(),
            slope: 0.01,
            act_probe: Vec::new(),
            model: ModelSpec::Mlp { dims: vec![4, 8, 2] },
            epochs: 1,
            batch_size: 5,
            lr: 0.01,
            weight_decay: 0.0,
            val_ratio: 5,
            init: InitScheme::HeNormal,
            seed: 1,
            rank: 0,
            workers: 1,
            worker_threads: 0,
            precision: PrecisionMap::parse("8,-", "log16-lut").unwrap(),
        };
        let payload = encode_job(&job, &ds);
        // The precision section (count u32 + 2 × 3-byte entries) sits
        // right before the dataset tail: name + classes + pixels + four
        // length-prefixed arrays.
        let tail = (8 + ds.name.len())
            + 8
            + 8
            + (8 + ds.train_images.len())
            + (8 + ds.train_labels.len())
            + (8 + ds.test_images.len())
            + (8 + ds.test_labels.len());
        let sect = payload.len() - tail - (4 + 2 * 3);
        assert_eq!(
            u32::from_le_bytes(payload[sect..sect + 4].try_into().unwrap()),
            2,
            "offset arithmetic must land on the layer count"
        );

        // Oversized layer count (≈4 billion entries claimed).
        let mut p = payload.clone();
        p[sect..sect + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_job(&p).is_err());

        // Truncated width table: count says 3 but only 2 entries follow —
        // the decoder walks into the dataset section and must Err, never
        // panic or silently mis-decode.
        let mut p = payload.clone();
        p[sect..sect + 4].copy_from_slice(&3u32.to_le_bytes());
        assert!(decode_job(&p).is_err());

        // Out-of-range frac_bits on the assigned entry (an 8-bit word
        // cannot carry 7 fractional bits).
        let mut p = payload.clone();
        p[sect + 6] = 7;
        assert!(decode_job(&p).is_err());

        // Unknown presence flag.
        let mut p = payload.clone();
        p[sect + 4] = 9;
        assert!(decode_job(&p).is_err());

        // An unassigned entry must not smuggle width bits.
        let mut p = payload.clone();
        p[sect + 8] = 16;
        assert!(decode_job(&p).is_err());
    }

    #[test]
    fn v3_job_frame_is_refused_by_v4_reader() {
        // A pre-mixed-precision peer (wire v3) must be rejected at the
        // framing layer — its job payload has no precision table, so
        // "best-effort" decoding it would fabricate widths.
        let mut buf = Vec::new();
        write_frame_with_version(&mut buf, 3, FrameKind::Job, b"v3 job bytes").unwrap();
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("version mismatch") && msg.contains("v3"), "{msg}");
    }

    #[test]
    fn streaming_job_frame_matches_buffered_encoding() {
        // write_job_frame must be byte-identical to the buffered path —
        // same payload, same checksum, decodable by the same reader.
        let ds = toy_dataset();
        let job = JobSpec {
            backend_tag: "lin16".into(),
            slope: 0.01,
            act_probe: vec![9, 9],
            model: ModelSpec::Mlp { dims: vec![4, 3, 2] },
            epochs: 2,
            batch_size: 3,
            lr: 0.01,
            weight_decay: 1e-4,
            val_ratio: 5,
            init: InitScheme::HeNormal,
            seed: 1,
            rank: 0,
            workers: 2,
            worker_threads: 1,
            precision: PrecisionMap::parse("-,8", "lin16").unwrap(),
        };
        let mut buffered = Vec::new();
        write_frame(&mut buffered, FrameKind::Job, &encode_job(&job, &ds)).unwrap();
        let mut streamed = Vec::new();
        write_job_frame(&mut streamed, &job, &ds).unwrap();
        assert_eq!(buffered, streamed);
        let frame = read_frame(&mut streamed.as_slice()).unwrap();
        let (j2, d2) = decode_job(&frame.payload).unwrap();
        assert_eq!(j2.backend_tag, "lin16");
        assert_eq!(d2.train_images, ds.train_images);
    }

    #[test]
    fn streaming_fnv_matches_one_shot() {
        let mut h = Fnv64::new();
        h.update(b"hel");
        h.update(b"");
        h.update(b"lo frame");
        assert_eq!(h.finish(), fnv1a64(b"hello frame"));
    }

    #[test]
    fn digest_roundtrip() {
        let d = DigestMsg { digest: 0xDEAD_BEEF_0BAD_F00D, params: 1234 };
        assert_eq!(DigestMsg::decode(&d.encode()).unwrap(), d);
    }

    #[test]
    fn heartbeat_roundtrip() {
        let hb = HeartbeatMsg {
            rank: 3,
            epoch: 2,
            step: 17,
            samples_done: 4242,
            spans: vec![("forward".into(), 12, 345_678), ("wire_encode".into(), 9, 1000)],
            counters: vec![("lns_cancel".into(), 7), ("delta_lut_adds".into(), 99_000)],
            dist: vec![crate::obs::dist::DistEntry {
                class: 2,
                layer: 1,
                zeros: 5,
                neg: 9,
                buckets: vec![0, 3, 0, 11],
            }],
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Heartbeat, &hb.encode()).unwrap();
        let frame = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(frame.kind, FrameKind::Heartbeat);
        assert_eq!(HeartbeatMsg::decode(&frame.payload).unwrap(), hb);

        // Empty rollups are a valid (early) heartbeat.
        let hb0 = HeartbeatMsg {
            rank: 0,
            epoch: 1,
            step: 0,
            samples_done: 0,
            spans: Vec::new(),
            counters: Vec::new(),
            dist: Vec::new(),
        };
        assert_eq!(HeartbeatMsg::decode(&hb0.encode()).unwrap(), hb0);
    }

    #[test]
    fn heartbeat_hostile_counts_error_instead_of_panicking() {
        let hb = HeartbeatMsg {
            rank: 1,
            epoch: 1,
            step: 0,
            samples_done: 1,
            spans: vec![("eval".into(), 1, 2)],
            counters: Vec::new(),
            dist: Vec::new(),
        };
        let mut payload = hb.encode();
        // The span-count u32 sits right after rank/epoch/step/samples
        // (offset 4 + 4 + 4 + 8 = 20).
        payload[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(HeartbeatMsg::decode(&payload).is_err());

        // The dist-entry count is the trailing u32 section (v3): an
        // empty-dist payload ends with its count, then each entry's
        // bucket count is length-guarded too.
        let mut payload = hb.encode();
        let n = payload.len();
        payload[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(HeartbeatMsg::decode(&payload).is_err());

        let with_dist = HeartbeatMsg {
            dist: vec![crate::obs::dist::DistEntry {
                class: 0,
                layer: 1,
                zeros: 0,
                neg: 0,
                buckets: vec![1, 2],
            }],
            ..hb
        };
        let mut payload = with_dist.encode();
        // The bucket-count u32 sits 2 × 8 bucket bytes from the end.
        let off = payload.len() - 16 - 4;
        payload[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(HeartbeatMsg::decode(&payload).is_err());
    }

    #[test]
    fn fnv_is_stable() {
        // Pinned reference values: the checksum is part of the wire
        // contract, so it must never drift between builds.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
