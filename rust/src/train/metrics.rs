//! Evaluation metrics.
//!
//! Evaluation is the batched hot path at serving scale: the forward pass
//! runs on the row-parallel tensor engine, and the per-row soft-max/
//! argmax bookkeeping fans out across the rayon pool for large chunks.
//! Loss/accuracy are reduced in row order afterwards, so parallel and
//! serial evaluation report identical numbers.
//!
//! Loss accounting goes through the one shared accumulator,
//! [`crate::train::EpochLoss`] — per-row raw loss sums folded in row
//! order, divided by the sample count once at the end. Every evaluation
//! caller (the epoch-loop validation passes, the final test pass, the
//! multi-process coordinator) lands here, so nobody can reintroduce the
//! per-batch mean-of-means weighting bug that used to overweight partial
//! final batches (PR 4).

use crate::nn::Mlp;
use crate::obs::{span, SpanKind};
use crate::tensor::{ops, Backend, Tensor};
use crate::train::EpochLoss;
use rayon::prelude::*;

/// Accuracy/loss summary over a dataset slice.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    /// Fraction of correct argmax predictions.
    pub accuracy: f64,
    /// Mean natural-log cross-entropy.
    pub loss: f64,
    /// Number of examples evaluated.
    pub n: usize,
}

/// Evaluate a model: forward pass + argmax in the backend's own domain
/// (no decode on the prediction path — argmax uses the backend's order).
pub fn evaluate<B: Backend>(
    backend: &B,
    model: &Mlp<B::E>,
    x: &Tensor<B::E>,
    labels: &[usize],
) -> EvalResult {
    let classes = model.dims[model.dims.len() - 1];
    evaluate_with(backend, classes, |view| model.logits(backend, view), x, labels)
}

/// Model-agnostic evaluation core: `logits_of` maps an input chunk to its
/// logits (the MLP and CNN both plug in here). Chunking, the parallel
/// per-row bookkeeping, and the row-order reductions are identical to the
/// seed's MLP path, so `evaluate` reports unchanged numbers.
pub fn evaluate_with<B: Backend, F>(
    backend: &B,
    classes: usize,
    logits_of: F,
    x: &Tensor<B::E>,
    labels: &[usize],
) -> EvalResult
where
    F: Fn(&Tensor<B::E>) -> Tensor<B::E>,
{
    assert_eq!(x.rows, labels.len());
    if labels.is_empty() {
        return EvalResult::default();
    }
    // Every evaluation caller lands here, so this one span covers the
    // epoch-loop validation passes, the final test pass, and the
    // multi-process coordinator alike.
    let _sp = span(SpanKind::Eval);
    // Evaluate in modest chunks to bound peak memory on large test sets.
    const CHUNK: usize = 256;
    let mut correct = 0usize;
    // Per-row raw loss sums fold through the shared sample-weighted
    // accumulator in row order — the identical IEEE chain the seed's
    // single `loss -= ln_p` accumulator produced (`a − l ≡ a + (−l)`).
    let mut loss = EpochLoss::default();
    let mut grad_scratch = vec![backend.zero(); classes];
    for start in (0..x.rows).step_by(CHUNK) {
        let end = (start + CHUNK).min(x.rows);
        let view = Tensor::from_vec(
            end - start,
            x.cols,
            x.data[start * x.cols..end * x.cols].to_vec(),
        );
        let logits = logits_of(&view);
        let per_row: Vec<(bool, f64)> = if ops::par_rows_worthwhile(logits.rows) {
            // `map_init` gives each worker one reusable scratch gradient
            // buffer (mirroring the serial branch's single buffer) instead
            // of allocating per row.
            (0..logits.rows)
                .into_par_iter()
                .map_init(
                    || vec![backend.zero(); classes],
                    |scratch, i| {
                        let row = logits.row(i);
                        let ln_p = backend.softmax_ce_grad(row, labels[start + i], scratch);
                        (ops::argmax_row(backend, row) == labels[start + i], ln_p)
                    },
                )
                .collect()
        } else {
            (0..logits.rows)
                .map(|i| {
                    let row = logits.row(i);
                    let ln_p =
                        backend.softmax_ce_grad(row, labels[start + i], &mut grad_scratch);
                    (ops::argmax_row(backend, row) == labels[start + i], ln_p)
                })
                .collect()
        };
        for &(ok, ln_p) in &per_row {
            if ok {
                correct += 1;
            }
            loss.add_sum(-ln_p, 1);
        }
    }
    EvalResult {
        accuracy: correct as f64 / labels.len() as f64,
        loss: loss.mean(),
        n: labels.len(),
    }
}

/// Confusion matrix (`classes × classes`, rows = truth, cols = predicted).
pub fn confusion<B: Backend>(
    backend: &B,
    model: &Mlp<B::E>,
    x: &Tensor<B::E>,
    labels: &[usize],
    classes: usize,
) -> Vec<Vec<usize>> {
    let preds = model.predict(backend, x);
    let mut m = vec![vec![0usize; classes]; classes];
    for (&p, &t) in preds.iter().zip(labels) {
        m[t][p] += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::InitScheme;
    use crate::rng::SplitMix64;
    use crate::tensor::FloatBackend;

    #[test]
    fn evaluate_counts_correctly() {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(1);
        let model = Mlp::init(&b, &[2, 4, 2], InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(4, 2, vec![1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0, -1.0, -1.0]);
        let preds = model.predict(&b, &x);
        let r = evaluate(&b, &model, &x, &preds);
        assert_eq!(r.accuracy, 1.0, "evaluating against own predictions");
        assert_eq!(r.n, 4);
        let wrong: Vec<usize> = preds.iter().map(|&p| 1 - p).collect();
        let r2 = evaluate(&b, &model, &x, &wrong);
        assert_eq!(r2.accuracy, 0.0);
    }

    #[test]
    fn confusion_diagonal_for_perfect_predictions() {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(2);
        let model = Mlp::init(&b, &[2, 4, 3], InitScheme::HeNormal, &mut rng);
        let x = Tensor::from_vec(3, 2, vec![0.5f32, -0.5, 1.0, 1.0, -1.0, 0.25]);
        let preds = model.predict(&b, &x);
        let m = confusion(&b, &model, &x, &preds, 3);
        let off_diag: usize = (0..3)
            .flat_map(|i| (0..3).filter(move |&j| j != i).map(move |j| (i, j)))
            .map(|(i, j)| m[i][j])
            .sum();
        assert_eq!(off_diag, 0);
        let diag: usize = (0..3).map(|i| m[i][i]).sum();
        assert_eq!(diag, 3);
    }

    #[test]
    fn eval_loss_is_the_row_order_sample_weighted_chain() {
        // Pins the EpochLoss refactor: the reported loss must equal the
        // row-ascending −ln p chain divided by the sample count once.
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(9);
        let model = Mlp::init(&b, &[3, 5, 2], InitScheme::HeNormal, &mut rng);
        let data: Vec<f32> = (0..15).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let x = Tensor::from_vec(5, 3, data);
        let labels = vec![0, 1, 0, 1, 1];
        let r = evaluate(&b, &model, &x, &labels);
        let logits = model.logits(&b, &x);
        let mut scratch = vec![0f32; 2];
        let mut want = 0.0f64;
        for i in 0..5 {
            want -= b.softmax_ce_grad(logits.row(i), labels[i], &mut scratch);
        }
        assert_eq!(r.loss, want / 5.0);
    }

    #[test]
    fn empty_eval_is_default() {
        let b = FloatBackend::default();
        let mut rng = SplitMix64::new(3);
        let model = Mlp::init(&b, &[2, 2, 2], InitScheme::HeNormal, &mut rng);
        let x = Tensor::full(0, 2, 0.0f32);
        let r = evaluate(&b, &model, &x, &[]);
        assert_eq!(r.n, 0);
    }
}
