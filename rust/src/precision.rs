//! Per-layer precision assignment (the mixed-precision axis).
//!
//! A [`PrecisionMap`] assigns an optional storage [`WordSpec`] to each
//! model layer. Semantics (NUMERICS.md §11): mixed precision is
//! **weight-storage quantization** — arithmetic (forward, backward, the
//! ⊞/⊡ chains) always runs in the backend's base word format; after
//! initialization and after every SGD update, a layer's parameters are
//! snapped to its assigned narrower word (round-half-away-from-zero to
//! the coarser grid, clamped to the narrower range) via
//! [`crate::tensor::Backend::quantize`]. Layers without an assignment
//! keep the base word untouched. Assignment is **per-layer, never
//! per-element**, and changes *values*, never any chain's order — so
//! every execution-path guarantee (serial ≡ sharded ≡ multi-process)
//! holds for mixed-precision runs exactly as for uniform ones.
//!
//! The float backend has no storage-width axis; its `quantize` is the
//! identity and a map parsed for it is rejected at construction.

use crate::fixed::FixedConfig;
use crate::lns::LnsConfig;

/// Most layers any supported model has; the wire decoder uses the same
/// bound to reject hostile layer counts.
pub const MAX_PRECISION_LAYERS: usize = 4096;

/// A storage word format: total width and fractional bits. The meaning
/// of `frac_bits` follows the backend family the spec is built for
/// (LNS log-magnitude grid vs linear Q-format grid).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct WordSpec {
    /// Total word width in bits.
    pub total_bits: u32,
    /// Fractional bits of the word's grid.
    pub frac_bits: u32,
}

impl WordSpec {
    /// Family-agnostic layout check (the wire decoder's guard): width
    /// fits the engine's `i32` words and the split leaves at least one
    /// non-fractional bit. Family constructors enforce tighter bounds.
    pub fn validate(&self) -> Result<(), String> {
        if !(4..=32).contains(&self.total_bits) {
            return Err(format!("word total_bits must be in 4..=32, got {}", self.total_bits));
        }
        if self.frac_bits == 0 || self.frac_bits > self.total_bits - 2 {
            return Err(format!(
                "word frac_bits must be in 1..={} for a {}-bit word, got {}",
                self.total_bits - 2,
                self.total_bits,
                self.frac_bits
            ));
        }
        Ok(())
    }

    /// Preset-layout spec for a width under the backend family named by
    /// `tag` (`log…` → LNS layout `q_f = W − 6`, `lin…` → Q-format
    /// layout `b_f = W − 5`). The float backend has no width axis.
    pub fn for_backend_tag(width: u32, tag: &str) -> Result<WordSpec, String> {
        if tag.starts_with("log") {
            let c = LnsConfig::for_width(width, true)?;
            Ok(WordSpec { total_bits: c.total_bits, frac_bits: c.frac_bits })
        } else if tag.starts_with("lin") {
            let c = FixedConfig::for_width(width)?;
            Ok(WordSpec { total_bits: c.total_bits, frac_bits: c.frac_bits })
        } else {
            Err(format!("backend '{tag}' has no per-layer storage-width axis"))
        }
    }
}

/// Layer → optional storage word. `None` (and any layer beyond the
/// vector's length) means "base word, no quantization".
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PrecisionMap {
    layers: Vec<Option<WordSpec>>,
}

impl PrecisionMap {
    /// The uniform map: every layer keeps the backend's base word.
    pub fn uniform() -> Self {
        PrecisionMap::default()
    }

    /// Build from explicit per-layer entries (validated).
    pub fn from_layers(layers: Vec<Option<WordSpec>>) -> Result<Self, String> {
        if layers.len() > MAX_PRECISION_LAYERS {
            return Err(format!(
                "precision map has {} layers; the engine caps at {MAX_PRECISION_LAYERS}",
                layers.len()
            ));
        }
        for (l, spec) in layers.iter().enumerate() {
            if let Some(s) = spec {
                s.validate().map_err(|e| format!("layer {l}: {e}"))?;
            }
        }
        Ok(PrecisionMap { layers })
    }

    /// Parse a CLI spec like `"8,16"` or `"-,8"` for the backend named
    /// by `tag`: one comma-separated entry per layer, a width in bits or
    /// `-` for "base word".
    pub fn parse(spec: &str, tag: &str) -> Result<Self, String> {
        let mut layers = Vec::new();
        for (l, part) in spec.split(',').enumerate() {
            let part = part.trim();
            if part.is_empty() || part == "-" {
                layers.push(None);
            } else {
                let width: u32 = part
                    .parse()
                    .map_err(|_| format!("layer {l}: '{part}' is not a width in bits"))?;
                layers.push(Some(WordSpec::for_backend_tag(width, tag)?));
            }
        }
        Self::from_layers(layers)
    }

    /// The storage word for `layer` (0-based), if one is assigned.
    pub fn get(&self, layer: usize) -> Option<WordSpec> {
        self.layers.get(layer).copied().flatten()
    }

    /// True when no layer has an assignment — the base-word fast path.
    pub fn is_uniform(&self) -> bool {
        self.layers.iter().all(|s| s.is_none())
    }

    /// The raw per-layer entries (wire encoding, reports).
    pub fn layers(&self) -> &[Option<WordSpec>] {
        &self.layers
    }

    /// Compact human-readable label (`uniform`, `8,16`, `-,8`).
    pub fn label(&self) -> String {
        if self.is_uniform() {
            return "uniform".into();
        }
        self.layers
            .iter()
            .map(|s| match s {
                Some(w) => w.total_bits.to_string(),
                None => "-".into(),
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_maps_widths_per_family() {
        let m = PrecisionMap::parse("8,16", "log16-lut").unwrap();
        assert_eq!(m.get(0), Some(WordSpec { total_bits: 8, frac_bits: 2 }));
        assert_eq!(m.get(1), Some(WordSpec { total_bits: 16, frac_bits: 10 }));
        assert_eq!(m.get(2), None, "layers beyond the spec keep the base word");
        assert!(!m.is_uniform());
        assert_eq!(m.label(), "8,16");

        let m = PrecisionMap::parse("-,8", "lin16").unwrap();
        assert_eq!(m.get(0), None);
        assert_eq!(m.get(1), Some(WordSpec { total_bits: 8, frac_bits: 3 }));
        assert_eq!(m.label(), "-,8");
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(PrecisionMap::parse("8", "float32").is_err(), "float has no width axis");
        assert!(PrecisionMap::parse("5", "log16-lut").is_err(), "below preset range");
        assert!(PrecisionMap::parse("x", "log16-lut").is_err(), "not a number");
        assert!(PrecisionMap::parse("99", "lin16").is_err(), "beyond i32 codes");
    }

    #[test]
    fn uniform_map_is_uniform() {
        assert!(PrecisionMap::uniform().is_uniform());
        assert_eq!(PrecisionMap::uniform().label(), "uniform");
        let m = PrecisionMap::parse("-,-", "log16-lut").unwrap();
        assert!(m.is_uniform(), "all-dash spec is uniform too");
    }

    #[test]
    fn word_spec_validation_bounds() {
        assert!(WordSpec { total_bits: 8, frac_bits: 2 }.validate().is_ok());
        assert!(WordSpec { total_bits: 3, frac_bits: 1 }.validate().is_err());
        assert!(WordSpec { total_bits: 33, frac_bits: 10 }.validate().is_err());
        assert!(WordSpec { total_bits: 8, frac_bits: 0 }.validate().is_err());
        assert!(WordSpec { total_bits: 8, frac_bits: 7 }.validate().is_err());
    }

    #[test]
    fn from_layers_caps_layer_count() {
        let too_many = vec![None; MAX_PRECISION_LAYERS + 1];
        assert!(PrecisionMap::from_layers(too_many).is_err());
    }
}
