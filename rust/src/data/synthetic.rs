//! Procedural MNIST-family stand-ins (DESIGN.md §6).
//!
//! Each class gets a random *glyph template*: a small set of anisotropic
//! Gaussian strokes on the 28×28 canvas. Samples are rendered from the
//! class template under a random affine perturbation (shift, rotation,
//! scale) plus pixel noise, then quantized to 8 bits — matching the
//! originals' format (sparse 8-bit grey, 784 dims) and giving a task
//! whose difficulty is tuned per dataset (FMNIST-like uses denser,
//! overlapping templates; EMNIST-Letters-like uses 26 classes) so the
//! float-vs-LNS accuracy *gap* the paper measures remains meaningful.

use super::dataset::Dataset;
use crate::rng::SplitMix64;

const SIDE: usize = 28;

/// One Gaussian stroke of a glyph template.
#[derive(Clone, Copy, Debug)]
struct Stroke {
    cx: f64,
    cy: f64,
    /// Principal axis direction.
    theta: f64,
    /// Std along the principal axis.
    s_major: f64,
    /// Std across it.
    s_minor: f64,
    /// Peak intensity.
    amp: f64,
}

/// Generation parameters for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Dataset tag.
    pub name: String,
    /// Number of classes.
    pub classes: usize,
    /// Training images per class.
    pub train_per_class: usize,
    /// Test images per class.
    pub test_per_class: usize,
    /// Strokes per glyph template.
    pub strokes: usize,
    /// Max |shift| in pixels for the per-sample affine jitter.
    pub jitter_px: f64,
    /// Max |rotation| in radians.
    pub jitter_rot: f64,
    /// Additive pixel-noise std (in [0,1] intensity units).
    pub noise: f64,
    /// Template RNG seed (class templates and samples derive from it).
    pub seed: u64,
}

impl SynthSpec {
    /// MNIST-like: 10 classes, 6000/1000 per class at `scale = 1`,
    /// crisp well-separated glyphs.
    pub fn mnist_like(scale: f64, seed: u64) -> Self {
        SynthSpec {
            name: "mnist".into(),
            classes: 10,
            train_per_class: scaled(6000, scale),
            test_per_class: scaled(1000, scale),
            strokes: 5,
            jitter_px: 2.0,
            jitter_rot: 0.18,
            noise: 0.04,
            seed,
        }
    }

    /// FMNIST-like: 10 classes, same sizes, denser overlapping textures —
    /// a harder task, mirroring FMNIST's lower accuracies in Table 1.
    pub fn fmnist_like(scale: f64, seed: u64) -> Self {
        SynthSpec {
            name: "fmnist".into(),
            classes: 10,
            train_per_class: scaled(6000, scale),
            test_per_class: scaled(1000, scale),
            strokes: 9,
            jitter_px: 3.0,
            jitter_rot: 0.35,
            noise: 0.10,
            seed,
        }
    }

    /// EMNIST-Digits-like: 10 classes, 24000/4000 per class at scale 1.
    pub fn emnist_digits_like(scale: f64, seed: u64) -> Self {
        SynthSpec {
            name: "emnistd".into(),
            classes: 10,
            train_per_class: scaled(24000, scale),
            test_per_class: scaled(4000, scale),
            strokes: 5,
            jitter_px: 2.5,
            jitter_rot: 0.22,
            noise: 0.05,
            seed,
        }
    }

    /// EMNIST-Letters-like: 26 classes, 4800/800 per class at scale 1 —
    /// many classes with template collisions, the paper's hardest set.
    pub fn emnist_letters_like(scale: f64, seed: u64) -> Self {
        SynthSpec {
            name: "emnistl".into(),
            classes: 26,
            train_per_class: scaled(4800, scale),
            test_per_class: scaled(800, scale),
            strokes: 6,
            jitter_px: 3.0,
            jitter_rot: 0.30,
            noise: 0.08,
            seed,
        }
    }
}

fn scaled(n: usize, scale: f64) -> usize {
    ((n as f64 * scale).round() as usize).max(8)
}

fn class_template(rng: &mut SplitMix64, strokes: usize) -> Vec<Stroke> {
    (0..strokes)
        .map(|_| Stroke {
            cx: rng.uniform(7.0, 21.0),
            cy: rng.uniform(7.0, 21.0),
            theta: rng.uniform(0.0, std::f64::consts::PI),
            s_major: rng.uniform(2.2, 5.5),
            s_minor: rng.uniform(0.8, 1.8),
            amp: rng.uniform(0.55, 1.0),
        })
        .collect()
}

/// Render one sample: template under affine jitter + noise → 8-bit pixels.
fn render(template: &[Stroke], rng: &mut SplitMix64, spec: &SynthSpec, out: &mut [u8]) {
    debug_assert_eq!(out.len(), SIDE * SIDE);
    let dx = rng.uniform(-spec.jitter_px, spec.jitter_px);
    let dy = rng.uniform(-spec.jitter_px, spec.jitter_px);
    let rot = rng.uniform(-spec.jitter_rot, spec.jitter_rot);
    let scale = rng.uniform(0.88, 1.12);
    let (sin_r, cos_r) = rot.sin_cos();
    let c = (SIDE as f64 - 1.0) / 2.0;

    // Transform stroke centers/axes once per sample.
    let strokes: Vec<Stroke> = template
        .iter()
        .map(|s| {
            let (x, y) = (s.cx - c, s.cy - c);
            Stroke {
                cx: c + scale * (cos_r * x - sin_r * y) + dx,
                cy: c + scale * (sin_r * x + cos_r * y) + dy,
                theta: s.theta + rot,
                s_major: s.s_major * scale,
                s_minor: s.s_minor * scale,
                amp: s.amp,
            }
        })
        .collect();

    for py in 0..SIDE {
        for px in 0..SIDE {
            let mut v = 0.0f64;
            for s in &strokes {
                let (st, ct) = s.theta.sin_cos();
                let rx = (px as f64 - s.cx) * ct + (py as f64 - s.cy) * st;
                let ry = -(px as f64 - s.cx) * st + (py as f64 - s.cy) * ct;
                let q = (rx / s.s_major).powi(2) + (ry / s.s_minor).powi(2);
                if q < 12.0 {
                    v += s.amp * (-0.5 * q).exp();
                }
            }
            v += rng.normal() * spec.noise;
            out[py * SIDE + px] = (v.clamp(0.0, 1.0) * 255.0).round() as u8;
        }
    }
}

/// Generate a full dataset from a spec (deterministic in the seed).
pub fn synth_dataset(spec: &SynthSpec) -> Dataset {
    let mut template_rng = SplitMix64::new(spec.seed);
    let templates: Vec<Vec<Stroke>> =
        (0..spec.classes).map(|_| class_template(&mut template_rng, spec.strokes)).collect();

    let pixels = SIDE * SIDE;
    let n_train = spec.classes * spec.train_per_class;
    let n_test = spec.classes * spec.test_per_class;
    let mut train_images = vec![0u8; n_train * pixels];
    let mut train_labels = vec![0u8; n_train];
    let mut test_images = vec![0u8; n_test * pixels];
    let mut test_labels = vec![0u8; n_test];

    // Interleave classes so truncated prefixes stay balanced.
    let mut sample_rng = template_rng.fork(0xDA7A);
    for i in 0..n_train {
        let cls = i % spec.classes;
        train_labels[i] = cls as u8;
        let img = &mut train_images[i * pixels..(i + 1) * pixels];
        render(&templates[cls], &mut sample_rng, spec, img);
    }
    for i in 0..n_test {
        let cls = i % spec.classes;
        test_labels[i] = cls as u8;
        let img = &mut test_images[i * pixels..(i + 1) * pixels];
        render(&templates[cls], &mut sample_rng, spec, img);
    }

    Dataset {
        name: spec.name.clone(),
        classes: spec.classes,
        pixels,
        train_images,
        train_labels,
        test_images,
        test_labels,
    }
}

// ---------------------------------------------------------------------
// Oriented-stripes CNN task
// ---------------------------------------------------------------------

/// Generation parameters for the oriented-stripes image task — the conv
/// workload's dataset. Each class is a sinusoidal grating at a fixed
/// orientation (`c·π/classes`); samples draw a random phase (so no single
/// pixel is informative — the cue is spatial structure, which is what a
/// convolution + pooling stack extracts and a translation-sensitive model
/// cannot), a small orientation jitter, and pixel noise.
#[derive(Clone, Debug)]
pub struct StripeSpec {
    /// Dataset tag.
    pub name: String,
    /// Square image side (the CNN's input is `side×side×1`).
    pub side: usize,
    /// Number of orientation classes.
    pub classes: usize,
    /// Training images per class.
    pub train_per_class: usize,
    /// Test images per class.
    pub test_per_class: usize,
    /// Grating wavelength in pixels.
    pub wavelength: f64,
    /// Max |orientation jitter| around the class angle, radians.
    pub jitter_rot: f64,
    /// Additive pixel-noise std (in [0,1] intensity units).
    pub noise: f64,
    /// Master seed.
    pub seed: u64,
}

impl StripeSpec {
    /// Default CNN workload: 12×12 gratings, 4 orientations 45° apart,
    /// 400/100 per class at `scale = 1`.
    pub fn cnn_default(scale: f64, seed: u64) -> Self {
        StripeSpec {
            name: "stripes".into(),
            side: 12,
            classes: 4,
            train_per_class: scaled(400, scale),
            test_per_class: scaled(100, scale),
            wavelength: 4.0,
            jitter_rot: 0.10,
            noise: 0.03,
            seed,
        }
    }
}

/// Render one stripes sample: class grating under phase/orientation
/// jitter + noise → 8-bit pixels.
fn render_stripes(spec: &StripeSpec, cls: usize, rng: &mut SplitMix64, out: &mut [u8]) {
    debug_assert_eq!(out.len(), spec.side * spec.side);
    let theta = cls as f64 * std::f64::consts::PI / spec.classes as f64
        + rng.uniform(-spec.jitter_rot, spec.jitter_rot);
    let phase = rng.uniform(0.0, std::f64::consts::TAU);
    let freq = std::f64::consts::TAU / spec.wavelength;
    let (sin_t, cos_t) = theta.sin_cos();
    for y in 0..spec.side {
        for x in 0..spec.side {
            let u = x as f64 * cos_t + y as f64 * sin_t;
            let v = 0.5 + 0.5 * (freq * u + phase).cos() + rng.normal() * spec.noise;
            out[y * spec.side + x] = (v.clamp(0.0, 1.0) * 255.0).round() as u8;
        }
    }
}

/// Generate the oriented-stripes dataset (deterministic in the seed,
/// class-interleaved so truncated prefixes stay balanced).
pub fn stripes_dataset(spec: &StripeSpec) -> Dataset {
    let pixels = spec.side * spec.side;
    let n_train = spec.classes * spec.train_per_class;
    let n_test = spec.classes * spec.test_per_class;
    let mut train_images = vec![0u8; n_train * pixels];
    let mut train_labels = vec![0u8; n_train];
    let mut test_images = vec![0u8; n_test * pixels];
    let mut test_labels = vec![0u8; n_test];

    let mut rng = SplitMix64::new(spec.seed ^ 0x57A1_9E55);
    for i in 0..n_train {
        let cls = i % spec.classes;
        train_labels[i] = cls as u8;
        render_stripes(spec, cls, &mut rng, &mut train_images[i * pixels..(i + 1) * pixels]);
    }
    for i in 0..n_test {
        let cls = i % spec.classes;
        test_labels[i] = cls as u8;
        render_stripes(spec, cls, &mut rng, &mut test_images[i * pixels..(i + 1) * pixels]);
    }

    Dataset {
        name: spec.name.clone(),
        classes: spec.classes,
        pixels,
        train_images,
        train_labels,
        test_images,
        test_labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SynthSpec {
        SynthSpec {
            name: "t".into(),
            classes: 4,
            train_per_class: 12,
            test_per_class: 4,
            strokes: 4,
            jitter_px: 2.0,
            jitter_rot: 0.2,
            noise: 0.05,
            seed: 123,
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = synth_dataset(&small_spec());
        let b = synth_dataset(&small_spec());
        assert_eq!(a.train_images, b.train_images);
        let mut s2 = small_spec();
        s2.seed = 124;
        let c = synth_dataset(&s2);
        assert_ne!(a.train_images, c.train_images);
    }

    #[test]
    fn balanced_labels() {
        let d = synth_dataset(&small_spec());
        for cls in 0..4u8 {
            let n = d.train_labels.iter().filter(|&&l| l == cls).count();
            assert_eq!(n, 12);
        }
    }

    #[test]
    fn images_are_sparse_8bit_grey() {
        let d = synth_dataset(&small_spec());
        // MNIST-like statistics: most pixels near zero, some bright.
        let total: usize = d.train_images.len();
        let dark = d.train_images.iter().filter(|&&p| p < 32).count();
        let bright = d.train_images.iter().filter(|&&p| p > 160).count();
        assert!(dark as f64 / total as f64 > 0.5, "should be mostly background");
        assert!(bright > 0, "should have bright stroke pixels");
    }

    #[test]
    fn classes_are_distinguishable_by_template_distance() {
        // Mean images of different classes should differ much more than
        // two halves of the same class — i.e. the task is learnable.
        let d = synth_dataset(&SynthSpec { train_per_class: 30, ..small_spec() });
        let mean_img = |cls: u8, half: usize| -> Vec<f64> {
            let mut acc = vec![0.0f64; d.pixels];
            let mut n = 0.0;
            for (i, &l) in d.train_labels.iter().enumerate() {
                if l == cls && (i / d.classes) % 2 == half {
                    let img = &d.train_images[i * d.pixels..(i + 1) * d.pixels];
                    for (a, &p) in acc.iter_mut().zip(img) {
                        *a += p as f64;
                    }
                    n += 1.0;
                }
            }
            acc.iter().map(|&a| a / n).collect()
        };
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt()
        };
        let same = dist(&mean_img(0, 0), &mean_img(0, 1));
        let cross = dist(&mean_img(0, 0), &mean_img(1, 0));
        assert!(
            cross > 2.0 * same,
            "cross-class distance {cross} should dominate within-class {same}"
        );
    }

    fn stripe_spec() -> StripeSpec {
        StripeSpec { train_per_class: 10, test_per_class: 4, ..StripeSpec::cnn_default(1.0, 77) }
    }

    #[test]
    fn stripes_deterministic_and_balanced() {
        let a = stripes_dataset(&stripe_spec());
        let b = stripes_dataset(&stripe_spec());
        assert_eq!(a.train_images, b.train_images);
        assert_eq!(a.pixels, 144);
        for cls in 0..4u8 {
            assert_eq!(a.train_labels.iter().filter(|&&l| l == cls).count(), 10);
            assert_eq!(a.test_labels.iter().filter(|&&l| l == cls).count(), 4);
        }
        let mut s2 = stripe_spec();
        s2.seed = 78;
        assert_ne!(a.train_images, stripes_dataset(&s2).train_images);
    }

    #[test]
    fn stripes_orientations_are_distinguishable() {
        // Gratings at different orientations should decorrelate strongly
        // once phase is averaged out: compare per-class mean |FFT|-proxy —
        // here simply the mean absolute horizontal vs vertical gradient,
        // which separates the 0° and 90° classes.
        let ds = stripes_dataset(&StripeSpec {
            train_per_class: 40,
            ..StripeSpec::cnn_default(1.0, 3)
        });
        let side = 12usize;
        let grad_ratio = |cls: u8| -> f64 {
            let (mut gx, mut gy, mut n) = (0.0f64, 0.0f64, 0.0f64);
            for (i, &l) in ds.train_labels.iter().enumerate() {
                if l != cls {
                    continue;
                }
                let img = &ds.train_images[i * ds.pixels..(i + 1) * ds.pixels];
                for y in 0..side {
                    for x in 0..side - 1 {
                        gx += (img[y * side + x + 1] as f64 - img[y * side + x] as f64).abs();
                    }
                }
                for y in 0..side - 1 {
                    for x in 0..side {
                        gy += (img[(y + 1) * side + x] as f64 - img[y * side + x] as f64).abs();
                    }
                }
                n += 1.0;
            }
            (gx / n) / (gy / n + 1.0)
        };
        // Class 0 stripes vary along x (vertical bars): gx ≫ gy; class 2
        // (90°) is the opposite.
        assert!(grad_ratio(0) > 2.0 * grad_ratio(2), "{} vs {}", grad_ratio(0), grad_ratio(2));
    }
}
