//! In-memory image-classification dataset with the paper's conventions:
//! 8-bit grey images, a train/test split, and a held-back validation
//! fraction (1:5 of train, paper §5).

use crate::rng::SplitMix64;
use crate::tensor::{Backend, Tensor};

/// A labelled 8-bit image dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Dataset tag used in reports (`mnist`, `fmnist`, …).
    pub name: String,
    /// Number of classes.
    pub classes: usize,
    /// Pixels per image (784 for the paper's datasets).
    pub pixels: usize,
    /// Training images, row-major `[n_train × pixels]`.
    pub train_images: Vec<u8>,
    /// Training labels.
    pub train_labels: Vec<u8>,
    /// Test images.
    pub test_images: Vec<u8>,
    /// Test labels.
    pub test_labels: Vec<u8>,
}

/// An index-based view of a subset of a dataset's training data.
#[derive(Clone, Debug)]
pub struct Split {
    /// Indices into the training arrays.
    pub train_idx: Vec<usize>,
    /// Validation indices.
    pub val_idx: Vec<usize>,
}

impl Dataset {
    /// Number of training images.
    pub fn train_len(&self) -> usize {
        self.train_labels.len()
    }

    /// Number of test images.
    pub fn test_len(&self) -> usize {
        self.test_labels.len()
    }

    /// Hold back validation data with the paper's 1:5 ratio (seeded,
    /// shuffled). `ratio` is the validation fraction denominator, i.e.
    /// `5` ⇒ 1/5 validation.
    pub fn split_validation(&self, ratio: usize, seed: u64) -> Split {
        let mut idx: Vec<usize> = (0..self.train_len()).collect();
        let mut rng = SplitMix64::new(seed);
        rng.shuffle(&mut idx);
        let n_val = idx.len() / ratio;
        let val_idx = idx[..n_val].to_vec();
        let train_idx = idx[n_val..].to_vec();
        Split { train_idx, val_idx }
    }

    /// Encode images (by index) into a backend tensor: pixel `p` maps to
    /// `p/255 ∈ [0,1]` then through the backend encoder (the paper's
    /// offline dataset conversion, §4). Zero pixels become exact LNS zero.
    pub fn encode_batch<B: Backend>(
        &self,
        backend: &B,
        images: &[u8],
        idx: &[usize],
    ) -> Tensor<B::E> {
        let mut data = Vec::with_capacity(idx.len() * self.pixels);
        for &i in idx {
            let img = &images[i * self.pixels..(i + 1) * self.pixels];
            data.extend(img.iter().map(|&p| backend.encode(p as f64 / 255.0)));
        }
        Tensor::from_vec(idx.len(), self.pixels, data)
    }

    /// Encode the full train set in index order.
    pub fn encode_train<B: Backend>(&self, backend: &B) -> Tensor<B::E> {
        let idx: Vec<usize> = (0..self.train_len()).collect();
        self.encode_batch(backend, &self.train_images, &idx)
    }

    /// Encode the full test set in index order.
    pub fn encode_test<B: Backend>(&self, backend: &B) -> Tensor<B::E> {
        let idx: Vec<usize> = (0..self.test_len()).collect();
        self.encode_batch(backend, &self.test_images, &idx)
    }

    /// Labels (by index) as `usize`.
    pub fn labels_of(&self, labels: &[u8], idx: &[usize]) -> Vec<usize> {
        idx.iter().map(|&i| labels[i] as usize).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatBackend;

    fn toy() -> Dataset {
        Dataset {
            name: "toy".into(),
            classes: 2,
            pixels: 4,
            train_images: (0..40).map(|i| (i * 6) as u8).collect(),
            train_labels: (0..10).map(|i| (i % 2) as u8).collect(),
            test_images: vec![255; 8],
            test_labels: vec![0, 1],
        }
    }

    #[test]
    fn split_ratio_respected() {
        let d = toy();
        let s = d.split_validation(5, 42);
        assert_eq!(s.val_idx.len(), 2);
        assert_eq!(s.train_idx.len(), 8);
        // Disjoint and covering.
        let mut all: Vec<usize> = s.train_idx.iter().chain(&s.val_idx).cloned().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn split_is_seeded() {
        let d = toy();
        assert_eq!(d.split_validation(5, 1).val_idx, d.split_validation(5, 1).val_idx);
        assert_ne!(d.split_validation(5, 1).val_idx, d.split_validation(5, 2).val_idx);
    }

    #[test]
    fn encode_normalizes_to_unit_range() {
        let d = toy();
        let b = FloatBackend::default();
        let t = d.encode_test(&b);
        assert_eq!(t.rows, 2);
        assert_eq!(t.cols, 4);
        assert!(t.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert_eq!(t.data[0], 1.0); // pixel 255
    }

    #[test]
    fn labels_map_to_usize() {
        let d = toy();
        let l = d.labels_of(&d.train_labels, &[0, 1, 2]);
        assert_eq!(l, vec![0, 1, 0]);
    }
}
