//! IDX file loader (the MNIST/EMNIST container format).
//!
//! When real dataset files are available (`--data-dir` on the CLI), the
//! experiment drivers prefer them over the synthetic stand-ins. Layout
//! expected under the directory, per dataset tag:
//! `<tag>-train-images` / `<tag>-train-labels` / `<tag>-test-images` /
//! `<tag>-test-labels`, optionally with the canonical `-idx3-ubyte`
//! suffixes. Gzipped files are recognized but rejected with a descriptive
//! error: the hermetic build carries no gzip dependency (`flate2` is not
//! available offline), so distribute pre-gunzipped copies next to the
//! originals.

use super::dataset::Dataset;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parse an IDX byte stream: magic `0x00 0x00 <dtype> <ndim>`, big-endian
/// u32 dims, then raw data. Only `u8` payloads (dtype 0x08) are needed for
/// the MNIST family.
pub fn parse_idx(bytes: &[u8]) -> Result<(Vec<usize>, Vec<u8>)> {
    if bytes.len() < 4 {
        bail!("IDX stream too short");
    }
    if bytes[0] != 0 || bytes[1] != 0 {
        bail!("bad IDX magic prefix {:02x}{:02x}", bytes[0], bytes[1]);
    }
    if bytes[2] != 0x08 {
        bail!("unsupported IDX dtype 0x{:02x} (only u8 supported)", bytes[2]);
    }
    let ndim = bytes[3] as usize;
    let header = 4 + 4 * ndim;
    if bytes.len() < header {
        bail!("IDX header truncated");
    }
    let mut dims = Vec::with_capacity(ndim);
    for d in 0..ndim {
        let off = 4 + 4 * d;
        let v = u32::from_be_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]]);
        dims.push(v as usize);
    }
    let expected: usize = dims.iter().product();
    let data = &bytes[header..];
    if data.len() != expected {
        bail!("IDX payload size {} != expected {}", data.len(), expected);
    }
    Ok((dims, data.to_vec()))
}

/// Read a raw IDX file. `.gz` paths are rejected with guidance (see the
/// module docs): the offline build deliberately carries no gzip decoder,
/// and silently mis-parsing compressed bytes would be worse than asking
/// for a gunzipped copy.
pub fn read_maybe_gz(path: &Path) -> Result<Vec<u8>> {
    if path.extension().map(|e| e == "gz").unwrap_or(false) {
        bail!(
            "{} is gzip-compressed; the hermetic build has no gzip decoder — \
             gunzip it alongside the original and retry",
            path.display()
        );
    }
    std::fs::read(path).with_context(|| format!("reading {}", path.display()))
}

/// Find the first existing variant of a dataset component file.
fn find_component(dir: &Path, tag: &str, split: &str, kind: &str) -> Option<PathBuf> {
    let idx_kind = if kind == "images" { "idx3" } else { "idx1" };
    let stems = [
        format!("{tag}-{split}-{kind}"),
        format!("{tag}-{split}-{kind}-{idx_kind}-ubyte"),
        // Canonical LeCun-site naming for MNIST.
        format!("{split}-{kind}-{idx_kind}-ubyte"),
    ];
    for stem in &stems {
        for ext in ["", ".gz"] {
            let p = dir.join(format!("{stem}{ext}"));
            if p.exists() {
                return Some(p);
            }
        }
    }
    None
}

/// Load a real dataset from IDX files under `dir`, if all four components
/// exist. `classes` must be supplied (IDX does not carry it).
pub fn load_idx_dataset(dir: &Path, tag: &str, classes: usize) -> Result<Dataset> {
    let mut parts = Vec::new();
    for (split, kind) in
        [("train", "images"), ("train", "labels"), ("t10k", "images"), ("t10k", "labels")]
    {
        let split_names: &[&str] =
            if split == "t10k" { &["t10k", "test"] } else { &["train"] };
        let path = split_names
            .iter()
            .find_map(|s| find_component(dir, tag, s, kind))
            .with_context(|| format!("missing {tag} {split} {kind} under {}", dir.display()))?;
        parts.push(parse_idx(&read_maybe_gz(&path)?)?);
    }
    let (ti_dims, train_images) = parts.remove(0);
    let (tl_dims, train_labels) = parts.remove(0);
    let (si_dims, test_images) = parts.remove(0);
    let (sl_dims, test_labels) = parts.remove(0);
    if ti_dims.len() != 3 || si_dims.len() != 3 {
        bail!("image IDX must be rank 3");
    }
    let pixels = ti_dims[1] * ti_dims[2];
    if ti_dims[0] != tl_dims[0] || si_dims[0] != sl_dims[0] {
        bail!("image/label count mismatch");
    }
    Ok(Dataset {
        name: tag.to_string(),
        classes,
        pixels,
        train_images,
        train_labels,
        test_images,
        test_labels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_idx(dims: &[u32], data: &[u8]) -> Vec<u8> {
        let mut v = vec![0, 0, 0x08, dims.len() as u8];
        for d in dims {
            v.extend_from_slice(&d.to_be_bytes());
        }
        v.extend_from_slice(data);
        v
    }

    #[test]
    fn parse_roundtrip() {
        let bytes = make_idx(&[2, 3], &[1, 2, 3, 4, 5, 6]);
        let (dims, data) = parse_idx(&bytes).unwrap();
        assert_eq!(dims, vec![2, 3]);
        assert_eq!(data, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_idx(&[1, 0, 8, 1, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let bytes = make_idx(&[4], &[1, 2]);
        assert!(parse_idx(&bytes).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        let mut bytes = make_idx(&[1], &[1]);
        bytes[2] = 0x0D; // float
        assert!(parse_idx(&bytes).is_err());
    }

    #[test]
    fn plain_file_roundtrip_and_gz_guidance() {
        let dir = std::env::temp_dir().join(format!("lnsdnn-idx-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let payload = make_idx(&[2, 2, 2], &[9, 8, 7, 6, 5, 4, 3, 2]);
        let plain = dir.join("x-train-images");
        std::fs::write(&plain, &payload).unwrap();
        assert_eq!(read_maybe_gz(&plain).unwrap(), payload);
        // Compressed files are rejected with actionable guidance rather
        // than mis-parsed (no gzip decoder in the hermetic build).
        let gz_path = dir.join("x.gz");
        std::fs::write(&gz_path, [0x1f, 0x8b, 0x08, 0x00]).unwrap();
        let err = read_maybe_gz(&gz_path).unwrap_err().to_string();
        assert!(err.contains("gunzip"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_full_dataset_layout() {
        let dir = std::env::temp_dir().join(format!("lnsdnn-idxds-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let img = |n: u32| make_idx(&[n, 2, 2], &vec![7u8; (n * 4) as usize]);
        let lab = |n: u32| make_idx(&[n], &vec![1u8; n as usize]);
        std::fs::write(dir.join("toy-train-images"), img(6)).unwrap();
        std::fs::write(dir.join("toy-train-labels"), lab(6)).unwrap();
        std::fs::write(dir.join("toy-test-images"), img(2)).unwrap();
        std::fs::write(dir.join("toy-test-labels"), lab(2)).unwrap();
        let d = load_idx_dataset(&dir, "toy", 2).unwrap();
        assert_eq!(d.train_len(), 6);
        assert_eq!(d.test_len(), 2);
        assert_eq!(d.pixels, 4);
        std::fs::remove_dir_all(&dir).ok();
    }
}
