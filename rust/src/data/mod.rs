//! Datasets: the paper's four MNIST-family benchmarks.
//!
//! Real MNIST/FMNIST/EMNIST files load through the [`idx`] module when a
//! data directory is supplied. The offline reproduction default is the
//! [`synthetic`] generator — procedurally rendered 28×28 8-bit grey
//! glyph datasets with matched shape/statistics (DESIGN.md §6 records the
//! substitution rationale).

pub mod dataset;
pub mod idx;
pub mod synthetic;

pub use dataset::{Dataset, Split};
pub use synthetic::{stripes_dataset, synth_dataset, StripeSpec, SynthSpec};

/// The paper's four benchmarks, as synthetic stand-ins (name, classes,
/// per-class sizes mirror the originals; `scale` shrinks them uniformly
/// for fast runs — `1.0` is full paper scale).
pub fn paper_datasets(scale: f64, seed: u64) -> Vec<Dataset> {
    vec![
        synth_dataset(&SynthSpec::mnist_like(scale, seed)),
        synth_dataset(&SynthSpec::fmnist_like(scale, seed + 1)),
        synth_dataset(&SynthSpec::emnist_digits_like(scale, seed + 2)),
        synth_dataset(&SynthSpec::emnist_letters_like(scale, seed + 3)),
    ]
}

/// Look up one paper dataset by name (`mnist|fmnist|emnistd|emnistl`).
pub fn paper_dataset(name: &str, scale: f64, seed: u64) -> Option<Dataset> {
    let spec = match name {
        "mnist" => SynthSpec::mnist_like(scale, seed),
        "fmnist" => SynthSpec::fmnist_like(scale, seed + 1),
        "emnistd" => SynthSpec::emnist_digits_like(scale, seed + 2),
        "emnistl" => SynthSpec::emnist_letters_like(scale, seed + 3),
        _ => return None,
    };
    Some(synth_dataset(&spec))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_datasets_have_expected_shapes() {
        let ds = paper_datasets(0.02, 7);
        assert_eq!(ds.len(), 4);
        assert_eq!(ds[0].classes, 10);
        assert_eq!(ds[3].classes, 26);
        for d in &ds {
            assert_eq!(d.pixels, 784);
            assert!(d.train_len() > 0 && d.test_len() > 0);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(paper_dataset("mnist", 0.02, 1).is_some());
        assert!(paper_dataset("nope", 0.02, 1).is_none());
    }
}
