//! Linear-domain fixed-point arithmetic (the paper's linear baseline).
//!
//! Two's-complement Q(`b_i`, `b_f`) words with saturating add/mul and
//! round-to-nearest on the product shift. The paper's baselines: 16-bit
//! (`b_f = 11`) and 12-bit (`b_f = 7`), each with 1 sign + 4 integer bits.

/// Q-format configuration for the linear fixed-point baseline.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct FixedConfig {
    /// Total word width (1 sign + `b_i` + `b_f`).
    pub total_bits: u32,
    /// Fractional bits `b_f`.
    pub frac_bits: u32,
}

impl FixedConfig {
    /// Paper's 16-bit linear baseline: `b_i = 4, b_f = 11`.
    pub fn w16() -> Self {
        FixedConfig { total_bits: 16, frac_bits: 11 }
    }

    /// Paper's 12-bit linear baseline: `b_i = 4, b_f = 7`.
    pub fn w12() -> Self {
        FixedConfig { total_bits: 12, frac_bits: 7 }
    }

    /// Largest representable code.
    pub fn max_code(&self) -> i32 {
        (1i32 << (self.total_bits - 1)) - 1
    }

    /// Smallest representable code (symmetric clamp: we avoid the
    /// asymmetric extra negative code so negation is always exact).
    pub fn min_code(&self) -> i32 {
        -self.max_code()
    }

    /// One unit in the last place as a real value.
    pub fn unit(&self) -> f64 {
        1.0 / (1i64 << self.frac_bits) as f64
    }
}

/// A linear fixed-point arithmetic system.
#[derive(Copy, Clone, Debug)]
pub struct FixedSystem {
    cfg: FixedConfig,
}

/// A Q-format word (carried as `i32`; only the low `total_bits` span is
/// ever occupied thanks to saturation).
pub type FixedValue = i32;

impl FixedSystem {
    /// Build a system for a Q-format.
    pub fn new(cfg: FixedConfig) -> Self {
        FixedSystem { cfg }
    }

    /// The configuration.
    pub fn config(&self) -> &FixedConfig {
        &self.cfg
    }

    #[inline]
    fn sat(&self, wide: i64) -> FixedValue {
        wide.clamp(self.cfg.min_code() as i64, self.cfg.max_code() as i64) as i32
    }

    /// Quantize a real number (round-half-away-from-zero, saturating).
    pub fn encode_f64(&self, v: f64) -> FixedValue {
        if v.is_nan() {
            return 0;
        }
        let scaled = v * (1i64 << self.cfg.frac_bits) as f64;
        let r = if scaled >= 0.0 {
            (scaled + 0.5).floor()
        } else {
            (scaled - 0.5).ceil()
        };
        self.sat(r as i64)
    }

    /// Back to `f64`.
    pub fn decode_f64(&self, x: FixedValue) -> f64 {
        x as f64 * self.cfg.unit()
    }

    /// Saturating addition.
    #[inline]
    pub fn add(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        self.sat(a as i64 + b as i64)
    }

    /// Saturating subtraction.
    #[inline]
    pub fn sub(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        self.sat(a as i64 - b as i64)
    }

    /// Saturating multiplication with round-to-nearest on the `>> b_f`
    /// rescale (round-half-away-from-zero, matching the encoder).
    #[inline]
    pub fn mul(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        let p = a as i64 * b as i64;
        let half = 1i64 << (self.cfg.frac_bits - 1);
        let rounded = if p >= 0 {
            (p + half) >> self.cfg.frac_bits
        } else {
            -((-p + half) >> self.cfg.frac_bits)
        };
        self.sat(rounded)
    }

    /// Multiply-accumulate `acc + a·b` (single rounding of the product,
    /// then saturating add — the standard fixed-point MAC).
    #[inline]
    pub fn mac(&self, acc: FixedValue, a: FixedValue, b: FixedValue) -> FixedValue {
        self.add(acc, self.mul(a, b))
    }

    /// Multiplication with **stochastic rounding** of the `>> b_f` rescale:
    /// `floor((a·b + u) / 2^{b_f})` with `u` uniform in `[0, 2^{b_f})`.
    ///
    /// Needed on the SGD update path: with round-to-nearest, any update
    /// smaller than half an ulp (e.g. `lr·g` at `b_f = 7` with `lr = 0.01`)
    /// deterministically rounds to zero and 12-bit training never moves
    /// (Gupta et al. 2015). Stochastic rounding makes the update correct
    /// in expectation. `u` comes from the caller so the system stays pure.
    #[inline]
    pub fn mul_sr(&self, a: FixedValue, b: FixedValue, u: u32) -> FixedValue {
        let p = a as i64 * b as i64;
        let dither = (u & ((1u32 << self.cfg.frac_bits) - 1)) as i64;
        // Arithmetic right shift implements floor for both signs.
        self.sat((p + dither) >> self.cfg.frac_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s16() -> FixedSystem {
        FixedSystem::new(FixedConfig::w16())
    }

    #[test]
    fn encode_decode_quantization_error() {
        let s = s16();
        let half_ulp = s.config().unit() / 2.0 + 1e-12;
        for v in [0.0, 1.0, -1.0, 3.999, -7.3, 0.0004] {
            let err = (s.decode_f64(s.encode_f64(v)) - v).abs();
            assert!(err <= half_ulp, "v={v} err={err}");
        }
    }

    #[test]
    fn saturation_clamps() {
        let s = s16();
        assert_eq!(s.encode_f64(1e9), s.config().max_code());
        assert_eq!(s.encode_f64(-1e9), s.config().min_code());
        let m = s.config().max_code();
        assert_eq!(s.add(m, m), m);
        assert_eq!(s.sub(-m, m), -m);
    }

    #[test]
    fn mul_rounds_to_nearest() {
        let s = s16();
        let a = s.encode_f64(0.5);
        let b = s.encode_f64(0.5);
        assert_eq!(s.decode_f64(s.mul(a, b)), 0.25);
        // Symmetric for negatives.
        assert_eq!(s.mul(-a, b), -s.mul(a, b));
        assert_eq!(s.mul(-a, -b), s.mul(a, b));
    }

    #[test]
    fn mac_matches_mul_then_add() {
        let s = s16();
        let (a, b, c) = (s.encode_f64(1.5), s.encode_f64(-2.25), s.encode_f64(0.75));
        assert_eq!(s.mac(c, a, b), s.add(c, s.mul(a, b)));
    }

    #[test]
    fn twelve_bit_is_coarser() {
        let s12 = FixedSystem::new(FixedConfig::w12());
        let s16 = s16();
        assert!(s12.config().unit() > s16.config().unit());
        assert_eq!(s12.config().max_code(), (1 << 11) - 1);
    }

    #[test]
    fn negation_exact_with_symmetric_clamp() {
        let s = s16();
        for v in [0.1, 3.9, 15.9] {
            let x = s.encode_f64(v);
            assert_eq!(s.sub(0, x), -x);
        }
    }
}
