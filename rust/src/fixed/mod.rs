//! Linear-domain fixed-point arithmetic (the paper's linear baseline).
//!
//! Two's-complement Q(`b_i`, `b_f`) words with saturating add/mul and
//! round-to-nearest on the product shift. The paper's baselines: 16-bit
//! (`b_f = 11`) and 12-bit (`b_f = 7`), each with 1 sign + 4 integer bits.

use crate::obs::metrics::ObsTally;

/// Q-format configuration for the linear fixed-point baseline.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct FixedConfig {
    /// Total word width (1 sign + `b_i` + `b_f`).
    pub total_bits: u32,
    /// Fractional bits `b_f`.
    pub frac_bits: u32,
}

impl FixedConfig {
    /// Validated arbitrary-width constructor — the runtime word-width
    /// axis. `4 ≤ W ≤ 31` keeps the code (and its products' rounding
    /// constants) inside `i32`/`i64`; `1 ≤ b_f ≤ W − 2` leaves the sign
    /// bit plus at least one integer bit.
    pub fn try_new(total_bits: u32, frac_bits: u32) -> Result<Self, String> {
        if !(4..=31).contains(&total_bits) {
            return Err(format!("fixed total_bits must be in 4..=31, got {total_bits}"));
        }
        if frac_bits == 0 || frac_bits > total_bits - 2 {
            return Err(format!(
                "fixed frac_bits must be in 1..={} for a {total_bits}-bit word, got {frac_bits}",
                total_bits - 2
            ));
        }
        Ok(FixedConfig { total_bits, frac_bits })
    }

    /// Config for a total width with the preset sign/int/frac split
    /// (1 sign + 4 integer bits, matching the paper's 16- and 12-bit
    /// baselines, so `b_f = W − 5`). Valid for `W ∈ 6..=31`.
    pub fn for_width(total_bits: u32) -> Result<Self, String> {
        if total_bits < 6 {
            return Err(format!(
                "preset-layout fixed widths are 6..=31 (b_f = W − 5 ≥ 1), got {total_bits}"
            ));
        }
        Self::try_new(total_bits, total_bits - 5)
    }

    /// Parse a backend tag of the form `lin<W>` into a validated
    /// preset-layout config. Inverse of `FixedBackend::tag()`; `None` on
    /// anything unparseable or out of range.
    pub fn from_tag(tag: &str) -> Option<Self> {
        let width: u32 = tag.strip_prefix("lin")?.parse().ok()?;
        Self::for_width(width).ok()
    }

    /// Paper's 16-bit linear baseline: `b_i = 4, b_f = 11`.
    pub fn w16() -> Self {
        FixedConfig { total_bits: 16, frac_bits: 11 }
    }

    /// Paper's 12-bit linear baseline: `b_i = 4, b_f = 7`.
    pub fn w12() -> Self {
        FixedConfig { total_bits: 12, frac_bits: 7 }
    }

    /// 8-bit linear baseline with the same layout: `b_i = 4, b_f = 3`.
    pub fn w8() -> Self {
        FixedConfig { total_bits: 8, frac_bits: 3 }
    }

    /// Largest representable code.
    pub fn max_code(&self) -> i32 {
        (1i32 << (self.total_bits - 1)) - 1
    }

    /// Smallest representable code (symmetric clamp: we avoid the
    /// asymmetric extra negative code so negation is always exact).
    pub fn min_code(&self) -> i32 {
        -self.max_code()
    }

    /// One unit in the last place as a real value.
    pub fn unit(&self) -> f64 {
        1.0 / (1i64 << self.frac_bits) as f64
    }
}

/// A linear fixed-point arithmetic system.
#[derive(Copy, Clone, Debug)]
pub struct FixedSystem {
    cfg: FixedConfig,
}

/// A Q-format word (carried as `i32`; only the low `total_bits` span is
/// ever occupied thanks to saturation).
pub type FixedValue = i32;

impl FixedSystem {
    /// Build a system for a Q-format.
    pub fn new(cfg: FixedConfig) -> Self {
        FixedSystem { cfg }
    }

    /// The configuration.
    pub fn config(&self) -> &FixedConfig {
        &self.cfg
    }

    #[inline]
    fn sat(&self, wide: i64) -> FixedValue {
        wide.clamp(self.cfg.min_code() as i64, self.cfg.max_code() as i64) as i32
    }

    /// Quantize a real number (round-half-away-from-zero, saturating).
    pub fn encode_f64(&self, v: f64) -> FixedValue {
        if v.is_nan() {
            return 0;
        }
        let scaled = v * (1i64 << self.cfg.frac_bits) as f64;
        let r = if scaled >= 0.0 {
            (scaled + 0.5).floor()
        } else {
            (scaled - 0.5).ceil()
        };
        self.sat(r as i64)
    }

    /// Back to `f64`.
    pub fn decode_f64(&self, x: FixedValue) -> f64 {
        x as f64 * self.cfg.unit()
    }

    /// Saturating addition.
    #[inline]
    pub fn add(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        self.sat(a as i64 + b as i64)
    }

    /// Saturating subtraction.
    #[inline]
    pub fn sub(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        self.sat(a as i64 - b as i64)
    }

    /// Saturating multiplication with round-to-nearest on the `>> b_f`
    /// rescale (round-half-away-from-zero, matching the encoder).
    #[inline]
    pub fn mul(&self, a: FixedValue, b: FixedValue) -> FixedValue {
        let p = a as i64 * b as i64;
        let half = 1i64 << (self.cfg.frac_bits - 1);
        let rounded = if p >= 0 {
            (p + half) >> self.cfg.frac_bits
        } else {
            -((-p + half) >> self.cfg.frac_bits)
        };
        self.sat(rounded)
    }

    /// Multiply-accumulate `acc + a·b` (single rounding of the product,
    /// then saturating add — the standard fixed-point MAC).
    #[inline]
    pub fn mac(&self, acc: FixedValue, a: FixedValue, b: FixedValue) -> FixedValue {
        self.add(acc, self.mul(a, b))
    }

    /// Row-vectorized MAC: `acc[j] = sat(acc[j] + mul(a, w[j]))` for every
    /// `j` — the fixed-point twin of the LNS lane kernels.
    ///
    /// The body is fully branchless: the round-half-away-from-zero rescale
    /// is computed sign-magnitude style (`|p|` via the xor/sub trick,
    /// round, negate back), which is bit-identical to [`FixedSystem::mul`]'s
    /// two-sided branch, and both saturations (post-mul and post-add) are
    /// plain clamps. With no data-dependent control flow in the loop, LLVM
    /// autovectorizes it.
    ///
    /// **Bit-exactness contract:** identical, element by element, to
    /// `acc[j] = self.mac(acc[j], a, w[j])` (`tests/lane_exactness.rs`).
    pub fn mac_row(&self, acc: &mut [FixedValue], a: FixedValue, w: &[FixedValue]) {
        debug_assert_eq!(acc.len(), w.len());
        // Saturation counting runs a counted copy of this body (identical
        // values — the clamps are observed, never altered). Disabled
        // cost: this one relaxed load.
        if crate::obs::counters_enabled() {
            let mut t = ObsTally::default();
            self.mac_row_tallied(acc, a, w, &mut t);
            t.flush_fixed();
            return;
        }
        let f = self.cfg.frac_bits;
        let half = 1i64 << (f - 1);
        let lo = self.cfg.min_code() as i64;
        let hi = self.cfg.max_code() as i64;
        let aw = a as i64;
        for (acc_j, &wv) in acc.iter_mut().zip(w.iter()) {
            let p = aw * wv as i64;
            let sg = p >> 63;
            let pa = (p ^ sg) - sg; // |p|
            let rs = (((pa + half) >> f) ^ sg) - sg; // round-half-away
            let prod = rs.clamp(lo, hi);
            *acc_j = (*acc_j as i64 + prod).clamp(lo, hi) as i32;
        }
    }

    /// Dot continuation `acc + Σ_i mul(a[i], w[i])`, `i` ascending, with
    /// per-term saturation — branchless body, but **sequentially folded**:
    /// saturating adds are order-sensitive, so the chain must not be
    /// regrouped (NUMERICS.md §2).
    ///
    /// **Bit-exactness contract:** identical to the zero-skipping fold
    /// `acc = self.mac(acc, a[i], w[i]) when a[i] != 0` — skipping a zero
    /// term equals adding its exactly-zero product, so dropping the skip
    /// branch changes nothing but the control flow.
    pub fn dot_acc(&self, acc: FixedValue, a: &[FixedValue], w: &[FixedValue]) -> FixedValue {
        debug_assert_eq!(a.len(), w.len());
        if crate::obs::counters_enabled() {
            let mut t = ObsTally::default();
            let out = self.dot_acc_tallied(acc, a, w, &mut t);
            t.flush_fixed();
            return out;
        }
        let f = self.cfg.frac_bits;
        let half = 1i64 << (f - 1);
        let lo = self.cfg.min_code() as i64;
        let hi = self.cfg.max_code() as i64;
        let mut acc = acc as i64;
        for (&av, &wv) in a.iter().zip(w.iter()) {
            let p = av as i64 * wv as i64;
            let sg = p >> 63;
            let pa = (p ^ sg) - sg;
            let rs = (((pa + half) >> f) ^ sg) - sg;
            acc = (acc + rs.clamp(lo, hi)).clamp(lo, hi);
        }
        acc as i32
    }

    /// [`FixedSystem::mac_row`] with saturation tallying — a verbatim
    /// copy of the branchless body plus clamp observations
    /// (`mul_sat`: product clamp engaged, `acc_sat`: accumulate clamp
    /// engaged). Bit-identical to the uncounted body by construction.
    pub(crate) fn mac_row_tallied(
        &self,
        acc: &mut [FixedValue],
        a: FixedValue,
        w: &[FixedValue],
        t: &mut ObsTally,
    ) {
        debug_assert_eq!(acc.len(), w.len());
        let f = self.cfg.frac_bits;
        let half = 1i64 << (f - 1);
        let lo = self.cfg.min_code() as i64;
        let hi = self.cfg.max_code() as i64;
        let aw = a as i64;
        for (acc_j, &wv) in acc.iter_mut().zip(w.iter()) {
            let p = aw * wv as i64;
            let sg = p >> 63;
            let pa = (p ^ sg) - sg; // |p|
            let rs = (((pa + half) >> f) ^ sg) - sg; // round-half-away
            let prod = rs.clamp(lo, hi);
            if prod != rs {
                t.mul_sat += 1;
            }
            let sum = *acc_j as i64 + prod;
            let sumc = sum.clamp(lo, hi);
            if sumc != sum {
                t.acc_sat += 1;
            }
            *acc_j = sumc as i32;
        }
    }

    /// [`FixedSystem::dot_acc`] with saturation tallying (same contract
    /// as [`FixedSystem::mac_row_tallied`]).
    pub(crate) fn dot_acc_tallied(
        &self,
        acc: FixedValue,
        a: &[FixedValue],
        w: &[FixedValue],
        t: &mut ObsTally,
    ) -> FixedValue {
        debug_assert_eq!(a.len(), w.len());
        let f = self.cfg.frac_bits;
        let half = 1i64 << (f - 1);
        let lo = self.cfg.min_code() as i64;
        let hi = self.cfg.max_code() as i64;
        let mut acc = acc as i64;
        for (&av, &wv) in a.iter().zip(w.iter()) {
            let p = av as i64 * wv as i64;
            let sg = p >> 63;
            let pa = (p ^ sg) - sg;
            let rs = (((pa + half) >> f) ^ sg) - sg;
            let prod = rs.clamp(lo, hi);
            if prod != rs {
                t.mul_sat += 1;
            }
            let sum = acc + prod;
            acc = sum.clamp(lo, hi);
            if acc != sum {
                t.acc_sat += 1;
            }
        }
        acc as i32
    }

    /// Multiplication with **stochastic rounding** of the `>> b_f` rescale:
    /// `floor((a·b + u) / 2^{b_f})` with `u` uniform in `[0, 2^{b_f})`.
    ///
    /// Needed on the SGD update path: with round-to-nearest, any update
    /// smaller than half an ulp (e.g. `lr·g` at `b_f = 7` with `lr = 0.01`)
    /// deterministically rounds to zero and 12-bit training never moves
    /// (Gupta et al. 2015). Stochastic rounding makes the update correct
    /// in expectation. `u` comes from the caller so the system stays pure.
    #[inline]
    pub fn mul_sr(&self, a: FixedValue, b: FixedValue, u: u32) -> FixedValue {
        let p = a as i64 * b as i64;
        let dither = (u & ((1u32 << self.cfg.frac_bits) - 1)) as i64;
        // Arithmetic right shift implements floor for both signs.
        self.sat((p + dither) >> self.cfg.frac_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s16() -> FixedSystem {
        FixedSystem::new(FixedConfig::w16())
    }

    #[test]
    fn encode_decode_quantization_error() {
        let s = s16();
        let half_ulp = s.config().unit() / 2.0 + 1e-12;
        for v in [0.0, 1.0, -1.0, 3.999, -7.3, 0.0004] {
            let err = (s.decode_f64(s.encode_f64(v)) - v).abs();
            assert!(err <= half_ulp, "v={v} err={err}");
        }
    }

    #[test]
    fn saturation_clamps() {
        let s = s16();
        assert_eq!(s.encode_f64(1e9), s.config().max_code());
        assert_eq!(s.encode_f64(-1e9), s.config().min_code());
        let m = s.config().max_code();
        assert_eq!(s.add(m, m), m);
        assert_eq!(s.sub(-m, m), -m);
    }

    #[test]
    fn mul_rounds_to_nearest() {
        let s = s16();
        let a = s.encode_f64(0.5);
        let b = s.encode_f64(0.5);
        assert_eq!(s.decode_f64(s.mul(a, b)), 0.25);
        // Symmetric for negatives.
        assert_eq!(s.mul(-a, b), -s.mul(a, b));
        assert_eq!(s.mul(-a, -b), s.mul(a, b));
    }

    #[test]
    fn mac_matches_mul_then_add() {
        let s = s16();
        let (a, b, c) = (s.encode_f64(1.5), s.encode_f64(-2.25), s.encode_f64(0.75));
        assert_eq!(s.mac(c, a, b), s.add(c, s.mul(a, b)));
    }

    #[test]
    fn twelve_bit_is_coarser() {
        let s12 = FixedSystem::new(FixedConfig::w12());
        let s16 = s16();
        assert!(s12.config().unit() > s16.config().unit());
        assert_eq!(s12.config().max_code(), (1 << 11) - 1);
    }

    #[test]
    fn width_constructors_validate_and_match_presets() {
        assert_eq!(FixedConfig::for_width(16).unwrap(), FixedConfig::w16());
        assert_eq!(FixedConfig::for_width(12).unwrap(), FixedConfig::w12());
        assert_eq!(FixedConfig::for_width(8).unwrap(), FixedConfig::w8());
        assert_eq!(FixedConfig::from_tag("lin8"), Some(FixedConfig::w8()));
        assert_eq!(FixedConfig::from_tag("lin16"), Some(FixedConfig::w16()));
        for bad in ["lin", "lin5", "lin32", "linx", "log16-lut"] {
            assert_eq!(FixedConfig::from_tag(bad), None, "{bad}");
        }
        assert!(FixedConfig::try_new(3, 1).is_err(), "too narrow");
        assert!(FixedConfig::try_new(32, 11).is_err(), "code would not fit i32");
        assert!(FixedConfig::try_new(8, 0).is_err(), "no fractional bits");
        assert!(FixedConfig::try_new(8, 7).is_err(), "no integer bit left");
        let c = FixedConfig::w8();
        assert_eq!(c.max_code(), 127);
        assert_eq!(c.min_code(), -127);
    }

    #[test]
    fn mac_row_bitexact_vs_scalar_mac() {
        for cfg in [FixedConfig::w16(), FixedConfig::w12(), FixedConfig::w8()] {
            let s = FixedSystem::new(cfg);
            let mc = cfg.max_code();
            // Deterministic mix of interior, boundary, and zero codes.
            let codes: Vec<i32> = (0..97i64)
                .map(|i| ((i * 2654435761) % (2 * mc as i64 + 1)) as i32 - mc)
                .collect();
            for &a in &[0, 1, -1, mc, -mc, mc / 3, -(mc / 5)] {
                let mut fast = codes.clone();
                let w: Vec<i32> = codes.iter().rev().cloned().collect();
                s.mac_row(&mut fast, a, &w);
                let slow: Vec<i32> =
                    codes.iter().zip(&w).map(|(&o, &wv)| s.mac(o, a, wv)).collect();
                assert_eq!(fast, slow, "a={a} ({}b)", cfg.total_bits);
            }
        }
    }

    #[test]
    fn dot_acc_bitexact_vs_scalar_mac_fold() {
        let s = s16();
        let mc = s.config().max_code();
        let a: Vec<i32> = (0..41).map(|i| (i * 37) % mc - mc / 2).collect();
        let w: Vec<i32> = (0..41).map(|i| (i * 53) % mc - mc / 3).collect();
        let fast = s.dot_acc(100, &a, &w);
        let mut slow = 100;
        for (&av, &wv) in a.iter().zip(&w) {
            slow = s.mac(slow, av, wv);
        }
        assert_eq!(fast, slow);
    }

    #[test]
    fn tallied_kernels_bitexact_and_pin_saturation_counts() {
        use crate::obs::metrics::ObsTally;
        let s = s16();
        let mc = s.config().max_code();

        // Values: the counted bodies must match the branchless references
        // on a saturation-heavy operand set.
        let codes: Vec<i32> = (0..61i64)
            .map(|i| ((i * 2654435761) % (2 * mc as i64 + 1)) as i32 - mc)
            .collect();
        for &a in &[0, 1, -1, mc, -mc] {
            let mut counted = codes.clone();
            let mut plain = codes.clone();
            let mut t = ObsTally::default();
            s.mac_row_tallied(&mut counted, a, &codes, &mut t);
            s.mac_row(&mut plain, a, &codes);
            assert_eq!(counted, plain, "mac_row_tallied diverged at a={a}");
            let mut t = ObsTally::default();
            assert_eq!(
                s.dot_acc_tallied(7, &codes, &codes, &mut t),
                s.dot_acc(7, &codes, &codes),
                "dot_acc_tallied diverged"
            );
        }

        // Hand-counted pins: max·max saturates the product; adding it to
        // a max accumulator saturates the accumulate too.
        let mut t = ObsTally::default();
        let mut acc = vec![mc, 0];
        s.mac_row_tallied(&mut acc, mc, &[mc, 0], &mut t);
        assert_eq!(acc, vec![mc, 0]);
        assert_eq!(t.mul_sat, 1, "max·max clamps the product");
        assert_eq!(t.acc_sat, 1, "max + max clamps the accumulate");

        // An in-range product on a zero accumulator saturates nothing.
        let mut t = ObsTally::default();
        let one = s.encode_f64(1.0);
        let mut acc = vec![0];
        s.mac_row_tallied(&mut acc, one, &[one], &mut t);
        assert_eq!(acc, vec![one]);
        assert_eq!(t, ObsTally::default());
    }

    #[test]
    fn negation_exact_with_symmetric_clamp() {
        let s = s16();
        for v in [0.1, 3.9, 15.9] {
            let x = s.encode_f64(v);
            assert_eq!(s.sub(0, x), -x);
        }
    }
}
