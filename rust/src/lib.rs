//! # lnsdnn — Neural Network Training with Approximate Logarithmic Computations
//!
//! A three-layer reproduction of Sanyal, Beerel & Chugg (2019):
//! end-to-end DNN training and inference in the **Logarithmic Number
//! System (LNS)** with fixed-point words, where multiplications become
//! integer additions and additions become `max + Δ±(|X−Y|)` with the
//! transcendental `Δ±` terms approximated by look-up tables or bit-shifts.
//!
//! Layering (see `DESIGN.md`):
//! * **L1/L2 (build-time Python)** — Pallas LNS kernels + a JAX MLP with a
//!   manual log-domain backward pass, AOT-lowered to HLO text in
//!   `artifacts/`.
//! * **L3 (this crate)** — the bit-exact native LNS engine used for the
//!   paper's experiment sweeps, the PJRT runtime that loads and executes
//!   the AOT artifacts, and the experiment coordinator/CLI.
//!
//! Inside L3, dependencies point strictly downward:
//!
//! | Layer | Modules | Role |
//! |-------|---------|------|
//! | coordinator | [`coordinator`] | sweeps, reports, batched inference, worker-process spawning |
//! | training | [`train`] | epoch loop, metrics, in-process + multi-process sharding, wire format |
//! | models | [`nn`] | MLP/CNN with manual ⊞/⊡ backprop, SGD, mergeable gradients |
//! | engine | [`tensor`] | backend trait, row-parallel + cache-tiled matmuls, im2col |
//! | number systems | [`lns`], [`fixed`] | the paper's arithmetic (Δ± LUT/bit-shift/exact), linear baseline |
//! | observability | [`obs`] | numerics counters, span tracing, heartbeat telemetry (side layer: read-only, hooked from every tier) |
//!
//! The architecture map lives in `docs/ARCHITECTURE.md`; the bit-exactness
//! contract every execution path obeys (reduction orders, tiling argument,
//! shard topology, wire framing) is specified in `docs/NUMERICS.md` —
//! read that before touching any reduction.
//!
//! Quick start:
//! ```no_run
//! use lnsdnn::lns::{LnsConfig, DeltaMode, LnsSystem};
//! let sys = LnsSystem::new(LnsConfig::w16_lut());
//! let a = sys.encode_f64(3.0);
//! let b = sys.encode_f64(-1.5);
//! let s = sys.add(a, b);
//! assert!((sys.decode_f64(s) - 1.5).abs() < 0.02);
//! ```

// The whole engine is safe Rust; keep it that way mechanically. Bit-level
// work (LNS packing, wire encode/decode) goes through integer ops and
// `to_le_bytes`/`from_le_bytes`, never transmutes.
#![forbid(unsafe_code)]

pub mod bench_util;
pub mod coordinator;
pub mod data;
pub mod fixed;
pub mod lns;
pub mod nn;
pub mod obs;
pub mod precision;
pub mod proptest_util;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod train;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
