//! Shard determinism: the headline guarantee of the data-parallel
//! trainer. For the MLP and the CNN, on all four number systems (float,
//! linear fixed point, LNS LUT, LNS bit-shift), training with
//! `n_shards ∈ {1, 2, 4, 8}` must produce **bit-identical** final
//! weights, biases, per-epoch losses and test metrics — and for the MLP,
//! `n_shards = 1` takes the pre-existing serial full-batch path, so the
//! same assertions prove the sharded reduction extends the serial
//! trainer bit for bit.
//!
//! Plus the reduction-contract unit tests: `accumulate_tree` depends
//! only on slot position (not compute/arrival order), and the MLP
//! per-sample-chain ≡ batched-fold theorem on the order-sensitive LNS
//! backend.

use lnsdnn::data::{stripes_dataset, synth_dataset, StripeSpec, SynthSpec};
use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{LnsConfig, LnsSystem};
use lnsdnn::nn::{CnnArch, GradStore, Gradients, InitScheme, Mlp, RawStepStats, SgdConfig};
use lnsdnn::rng::SplitMix64;
use lnsdnn::tensor::{Backend, FixedBackend, FloatBackend, LnsBackend, Tensor};
use lnsdnn::train::shard::{accumulate_tree, sample_row, ShardConfig};
use lnsdnn::train::{train, train_cnn, CnnTrainConfig, TrainConfig, TrainResult};

/// Shard counts compared against the `n_shards = 1` reference run (which
/// the helpers train once — rerunning 1 vs 1 would only test run-to-run
/// determinism, which `tests/train_integration.rs` already pins).
const SHARD_COUNTS: [usize; 3] = [2, 4, 8];

fn mlp_ds() -> lnsdnn::data::Dataset {
    synth_dataset(&SynthSpec {
        name: "shard-tiny".into(),
        classes: 3,
        train_per_class: 25,
        test_per_class: 8,
        strokes: 4,
        jitter_px: 1.5,
        jitter_rot: 0.15,
        noise: 0.04,
        seed: 41,
    })
}

fn mlp_cfg(n_shards: usize) -> TrainConfig {
    TrainConfig {
        dims: vec![784, 12, 3],
        epochs: 2,
        batch_size: 6,
        sgd: SgdConfig { lr: 0.02, weight_decay: 1e-4 },
        val_ratio: 5,
        init: InitScheme::HeNormal,
        seed: 13,
        shard: ShardConfig::with_shards(n_shards),
        precision: lnsdnn::precision::PrecisionMap::uniform(),
    }
}

fn cnn_ds() -> lnsdnn::data::Dataset {
    stripes_dataset(&StripeSpec {
        train_per_class: 12,
        test_per_class: 4,
        ..StripeSpec::cnn_default(1.0, 19)
    })
}

fn cnn_cfg(n_shards: usize) -> CnnTrainConfig {
    let mut cfg = CnnTrainConfig::lenet(12, 4);
    cfg.arch.c1 = 3;
    cfg.arch.c2 = 4;
    cfg.arch.hidden = 16;
    cfg.epochs = 1;
    cfg.batch_size = 6;
    cfg.sgd = SgdConfig { lr: 0.02, weight_decay: 0.0 };
    cfg.seed = 23;
    cfg.shard = ShardConfig::with_shards(n_shards);
    cfg
}

/// Assert two MLP runs are bit-identical: every parameter, every curve
/// point, the test metrics.
fn assert_mlp_identical<E: Copy + PartialEq + std::fmt::Debug>(
    tag: &str,
    n: usize,
    a: &TrainResult<Mlp<E>>,
    b: &TrainResult<Mlp<E>>,
) {
    for l in 0..a.model.layers.len() {
        assert_eq!(
            a.model.layers[l].w.data, b.model.layers[l].w.data,
            "{tag}: layer {l} weights diverge at n_shards={n}"
        );
        assert_eq!(
            a.model.layers[l].b, b.model.layers[l].b,
            "{tag}: layer {l} biases diverge at n_shards={n}"
        );
    }
    for (ea, eb) in a.curve.iter().zip(&b.curve) {
        assert_eq!(ea.train_loss, eb.train_loss, "{tag}: epoch loss diverges at n_shards={n}");
        assert_eq!(ea.val_accuracy, eb.val_accuracy, "{tag}: val acc diverges at n_shards={n}");
    }
    assert_eq!(a.test.accuracy, b.test.accuracy, "{tag}: test acc diverges at n_shards={n}");
    assert_eq!(a.test.loss, b.test.loss, "{tag}: test loss diverges at n_shards={n}");
}

fn mlp_shard_invariance<B: Backend>(backend: &B) {
    let ds = mlp_ds();
    let tag = backend.tag();
    // n_shards = 1 is the pre-existing serial full-batch trainer; every
    // sharded run must reproduce it exactly.
    let reference = train(backend, &ds, &mlp_cfg(1));
    for n in SHARD_COUNTS {
        let run = train(backend, &ds, &mlp_cfg(n));
        assert_mlp_identical(&tag, n, &reference, &run);
    }
}

#[test]
fn shard_mlp_bit_identical_float() {
    mlp_shard_invariance(&FloatBackend::default());
}

#[test]
fn shard_mlp_bit_identical_fixed16() {
    mlp_shard_invariance(&FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01));
}

#[test]
fn shard_mlp_bit_identical_lns16_lut() {
    mlp_shard_invariance(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01));
}

#[test]
fn shard_mlp_bit_identical_lns16_bitshift() {
    mlp_shard_invariance(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01));
}

fn cnn_shard_invariance<B: Backend>(backend: &B) {
    let ds = cnn_ds();
    let tag = backend.tag();
    let reference = train_cnn(backend, &ds, &cnn_cfg(1));
    for n in SHARD_COUNTS {
        let run = train_cnn(backend, &ds, &cnn_cfg(n));
        assert_eq!(
            reference.model.conv1.w.data, run.model.conv1.w.data,
            "{tag}: conv1 weights diverge at n_shards={n}"
        );
        assert_eq!(
            reference.model.conv2.w.data, run.model.conv2.w.data,
            "{tag}: conv2 weights diverge at n_shards={n}"
        );
        assert_eq!(
            reference.model.fc1.w.data, run.model.fc1.w.data,
            "{tag}: fc1 weights diverge at n_shards={n}"
        );
        assert_eq!(
            reference.model.fc2.w.data, run.model.fc2.w.data,
            "{tag}: fc2 weights diverge at n_shards={n}"
        );
        assert_eq!(
            reference.model.fc2.b, run.model.fc2.b,
            "{tag}: head biases diverge at n_shards={n}"
        );
        for (ea, eb) in reference.curve.iter().zip(&run.curve) {
            assert_eq!(ea.train_loss, eb.train_loss, "{tag}: CNN loss diverges at n_shards={n}");
        }
        assert_eq!(reference.test.accuracy, run.test.accuracy, "{tag}: CNN test acc (n={n})");
        assert_eq!(reference.test.loss, run.test.loss, "{tag}: CNN test loss (n={n})");
    }
}

#[test]
fn shard_cnn_bit_identical_float() {
    cnn_shard_invariance(&FloatBackend::default());
}

#[test]
fn shard_cnn_bit_identical_fixed16() {
    cnn_shard_invariance(&FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01));
}

#[test]
fn shard_cnn_bit_identical_lns16_lut() {
    cnn_shard_invariance(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01));
}

#[test]
fn shard_cnn_bit_identical_lns16_bitshift() {
    cnn_shard_invariance(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01));
}

/// The strided workload rides the same reduction contract: spot-check
/// shard invariance on the stride-2 variant (float + LNS-LUT).
#[test]
fn shard_cnn_strided_v1_bit_identical() {
    let ds = cnn_ds();
    for n in [2usize, 8] {
        let mut a = cnn_cfg(1);
        a.arch = CnnArch { c1: 3, c2: 4, hidden: 16, ..CnnArch::strided_v1(12, 4) };
        let mut b = cnn_cfg(n);
        b.arch = a.arch.clone();
        let backend = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let ra = train_cnn(&backend, &ds, &a);
        let rb = train_cnn(&backend, &ds, &b);
        assert_eq!(ra.model.conv1.w.data, rb.model.conv1.w.data, "strided conv1 (n={n})");
        assert_eq!(ra.model.fc2.w.data, rb.model.fc2.w.data, "strided fc2 (n={n})");
        assert_eq!(ra.test.accuracy, rb.test.accuracy, "strided test acc (n={n})");
    }
}

/// `accumulate_tree` is a function of slot *positions*, not of the order
/// the partials were computed or delivered in: filling the slot vector
/// in a permuted order and then restoring slot order yields the exact
/// same merged gradient — on the LNS backend, where ⊞ grouping genuinely
/// changes bits, so the test would catch an arrival-order reduction.
#[test]
fn shard_accumulate_tree_ignores_arrival_order() {
    let backend = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let mut rng = SplitMix64::new(77);
    let mlp = Mlp::init(&backend, &[6, 5, 3], InitScheme::HeNormal, &mut rng);
    let x = Tensor::from_vec(
        8,
        6,
        (0..48).map(|_| backend.encode(rng.uniform(-1.0, 1.0))).collect(),
    );
    let labels: Vec<usize> = (0..8).map(|i| i % 3).collect();

    let local = |i: usize| mlp.backprop_sums(&backend, &sample_row(&x, i), &labels[i..i + 1]);

    // Compute in ascending order.
    let fwd: Vec<_> = (0..8).map(local).map(|(g, _)| g).collect();
    // Compute in a scrambled order, deliver each partial to its slot.
    let arrival = [5usize, 0, 7, 2, 6, 1, 4, 3];
    let mut slots: Vec<Option<Gradients<_>>> = (0..8).map(|_| None).collect();
    for &i in &arrival {
        slots[i] = Some(local(i).0);
    }
    let permuted: Vec<_> = slots.into_iter().map(|s| s.expect("all slots filled")).collect();

    let a = accumulate_tree(&backend, fwd).unwrap();
    let b = accumulate_tree(&backend, permuted).unwrap();
    for l in 0..a.dw.len() {
        assert_eq!(a.dw[l].data, b.dw[l].data, "layer {l} dW depends on arrival order");
        assert_eq!(a.db[l], b.db[l], "layer {l} db depends on arrival order");
    }
}

/// The MLP equivalence theorem on the order-sensitive backend: merging
/// per-sample partials in slot order reproduces the batched ⊞ fold bit
/// for bit (each sample is exactly one term of that fold).
#[test]
fn shard_per_sample_chain_matches_batched_sums_lns() {
    let backend = LnsBackend::new(LnsSystem::new(LnsConfig::w12_lut()), 0.01);
    let mut rng = SplitMix64::new(3);
    let mlp = Mlp::init(&backend, &[10, 8, 4], InitScheme::HeNormal, &mut rng);
    let x = Tensor::from_vec(
        7,
        10,
        (0..70).map(|_| backend.encode(rng.uniform(-1.0, 1.0))).collect(),
    );
    let labels: Vec<usize> = (0..7).map(|i| i % 4).collect();

    let (batched, braw) = mlp.backprop_sums(&backend, &x, &labels);
    let mut stats = RawStepStats::default();
    let mut parts = Vec::new();
    for i in 0..x.rows {
        let (g, s) = mlp.backprop_sums(&backend, &sample_row(&x, i), &labels[i..i + 1]);
        stats.merge(&s);
        parts.push(g);
    }
    let merged = accumulate_tree(&backend, parts).unwrap();
    assert_eq!(stats.loss_sum, braw.loss_sum);
    assert_eq!(stats.correct, braw.correct);
    for l in 0..batched.dw.len() {
        assert_eq!(batched.dw[l].data, merged.dw[l].data, "layer {l} dW");
        assert_eq!(batched.db[l], merged.db[l], "layer {l} db");
    }
}

/// Scaling after the reduction is the same single ⊡ the serial backward
/// applies — `backprop` on a batch equals the reduced-and-scaled
/// per-sample path end to end (gradient-level twin of the training-level
/// invariance tests above).
#[test]
fn shard_scaled_reduction_matches_backprop_fixed() {
    let backend = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
    let mut rng = SplitMix64::new(29);
    let mlp = Mlp::init(&backend, &[9, 6, 3], InitScheme::HeNormal, &mut rng);
    let x = Tensor::from_vec(
        5,
        9,
        (0..45).map(|_| backend.encode(rng.uniform(-1.0, 1.0))).collect(),
    );
    let labels = vec![0usize, 1, 2, 1, 0];

    let (want, want_stats) = mlp.backprop(&backend, &x, &labels);
    let mut stats = RawStepStats::default();
    let mut parts = Vec::new();
    for i in 0..x.rows {
        let (g, s) = mlp.backprop_sums(&backend, &sample_row(&x, i), &labels[i..i + 1]);
        stats.merge(&s);
        parts.push(g);
    }
    let mut got = accumulate_tree(&backend, parts).unwrap();
    got.scale(&backend, 1.0 / stats.n as f64);
    assert_eq!(want_stats.loss, stats.finish().loss);
    for l in 0..want.dw.len() {
        assert_eq!(want.dw[l].data, got.dw[l].data, "layer {l} dW");
        assert_eq!(want.db[l], got.db[l], "layer {l} db");
    }
}
