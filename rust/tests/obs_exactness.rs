//! Observation is read-only.
//!
//! The acceptance theorem for the telemetry subsystem: enabling
//! counters and span tracing changes **nothing** about the numbers a
//! run produces — trained weights, per-epoch losses, and test metrics
//! are bit-identical with observation on and off, on all four backends,
//! serial and sharded and across real worker processes. On top of that,
//! counter values are pinned on hand-counted operand sets through the
//! *public* kernel dispatchers, under both the scalar and the lane ⊞
//! paths, and the `--trace` output is a valid Chrome trace with every
//! B event matched by an E.
//!
//! PR 8 extends the theorem to the value-distribution recorders and the
//! live HTTP endpoint: occupancy snapshots are deterministic per
//! config, the Prometheus rendering is well-formed with monotone
//! counters, and a scraper hammering `/metrics` mid-run still leaves
//! training bit-identical to the unobserved baseline.
//!
//! Every test here toggles process-global observation flags, so they
//! all serialize on one mutex and restore the flags on exit (including
//! panic exits — the lock is poison-tolerant for that reason).

use lnsdnn::coordinator::server::{train_multiproc, MultiprocSpec};
use lnsdnn::data::{stripes_dataset, synth_dataset, Dataset, StripeSpec, SynthSpec};
use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{lanes, LnsConfig, LnsSystem, LnsValue};
use lnsdnn::nn::{Cnn, InitScheme, Mlp, SgdConfig};
use lnsdnn::obs::{self, metrics};
use lnsdnn::tensor::{Backend, FixedBackend, FloatBackend, LnsBackend};
use lnsdnn::train::{
    train, train_cnn, CnnTrainConfig, ShardConfig, TrainConfig, TrainResult, Transport,
};
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, PoisonError};

/// One lock for every test in this file: observation flags and lane
/// selection are process-global, and cargo runs tests concurrently.
static OBS_LOCK: Mutex<()> = Mutex::new(());

/// RAII session: takes the lock, starts from a clean observation state,
/// and restores "everything off, lanes on" however the test exits.
struct ObsSession {
    _guard: MutexGuard<'static, ()>,
}

impl ObsSession {
    fn begin() -> ObsSession {
        // A previous test that panicked while holding the lock poisons
        // it; the shared state is just atomics, so recovery is safe.
        let guard = OBS_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        obs::set_all(false);
        obs::reset_all();
        lanes::set_enabled(true);
        ObsSession { _guard: guard }
    }
}

impl Drop for ObsSession {
    fn drop(&mut self) {
        obs::set_all(false);
        obs::reset_all();
        lanes::set_enabled(true);
    }
}

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_lnsdnn"))
}

fn tiny_ds() -> Dataset {
    synth_dataset(&SynthSpec {
        name: "tiny".into(),
        classes: 3,
        train_per_class: 14,
        test_per_class: 5,
        strokes: 4,
        jitter_px: 1.5,
        jitter_rot: 0.15,
        noise: 0.04,
        seed: 42,
    })
}

fn tiny_cfg() -> TrainConfig {
    TrainConfig {
        dims: vec![784, 8, 3],
        epochs: 2,
        batch_size: 5,
        sgd: SgdConfig { lr: 0.02, weight_decay: 1e-4 },
        val_ratio: 5,
        init: InitScheme::HeNormal,
        seed: 3,
        shard: ShardConfig::default(),
        precision: lnsdnn::precision::PrecisionMap::uniform(),
    }
}

fn assert_mlp_runs_equal<E: PartialEq + std::fmt::Debug>(
    label: &str,
    a: &TrainResult<Mlp<E>>,
    b: &TrainResult<Mlp<E>>,
) {
    assert_eq!(a.model.layers.len(), b.model.layers.len(), "{label}: layer count");
    for l in 0..a.model.layers.len() {
        assert_eq!(a.model.layers[l].w.data, b.model.layers[l].w.data, "{label}: layer {l} w");
        assert_eq!(a.model.layers[l].b, b.model.layers[l].b, "{label}: layer {l} b");
    }
    assert_eq!(a.test.accuracy, b.test.accuracy, "{label}: test accuracy");
    assert_eq!(a.test.loss, b.test.loss, "{label}: test loss");
    assert_eq!(a.curve.len(), b.curve.len(), "{label}: curve length");
    for (x, y) in a.curve.iter().zip(&b.curve) {
        assert_eq!(x.train_loss, y.train_loss, "{label}: epoch {} train loss", x.epoch);
        assert_eq!(x.val_accuracy, y.val_accuracy, "{label}: epoch {} val acc", x.epoch);
    }
}

/// Train the same config with observation off, then with counters and
/// tracing both on, and demand bit-identical results — at 1 and 2
/// in-process shards. `expect_counter`, when given, names a counter
/// that must have actually ticked during the observed run (proof the
/// counted path engaged rather than silently staying off).
fn check_obs_invariant_mlp<B, F>(label: &str, mk: F, expect_counter: Option<&str>)
where
    B: Backend,
    F: Fn() -> B,
{
    let ds = tiny_ds();
    for shards in [1usize, 2] {
        let mut cfg = tiny_cfg();
        if shards > 1 {
            cfg.shard = ShardConfig::with_shards(shards);
        }
        obs::set_all(false);
        let off = train(&mk(), &ds, &cfg);

        obs::set_all(true);
        obs::reset_all();
        let on = train(&mk(), &ds, &cfg);
        let snap = metrics::snapshot();
        obs::set_all(false);

        assert_mlp_runs_equal(&format!("{label} shards={shards} obs on vs off"), &off, &on);
        if let Some(name) = expect_counter {
            assert!(
                snap.get(name) > 0,
                "{label} shards={shards}: expected counter {name} to tick during training"
            );
        }
    }
}

#[test]
fn mlp_obs_invariant_float() {
    let _s = ObsSession::begin();
    check_obs_invariant_mlp("float32", FloatBackend::default, None);
}

#[test]
fn mlp_obs_invariant_fixed16() {
    let _s = ObsSession::begin();
    check_obs_invariant_mlp(
        "lin16",
        || FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01),
        None,
    );
}

#[test]
fn mlp_obs_invariant_lns16_lut() {
    let _s = ObsSession::begin();
    check_obs_invariant_mlp(
        "log16-lut",
        || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01),
        Some("delta_lut_adds"),
    );
}

#[test]
fn mlp_obs_invariant_lns16_bitshift() {
    let _s = ObsSession::begin();
    check_obs_invariant_mlp(
        "log16-bs",
        || LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01),
        Some("delta_shift_adds"),
    );
}

#[test]
fn cnn_obs_invariant_lns16_lut() {
    let _s = ObsSession::begin();
    let ds = stripes_dataset(&StripeSpec {
        train_per_class: 8,
        test_per_class: 3,
        ..StripeSpec::cnn_default(1.0, 17)
    });
    let mut cfg = CnnTrainConfig::lenet(12, 4);
    cfg.arch.c1 = 2;
    cfg.arch.c2 = 3;
    cfg.arch.hidden = 8;
    cfg.epochs = 1;
    cfg.sgd = SgdConfig { lr: 0.02, weight_decay: 0.0 };
    cfg.seed = 19;
    let mk = || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);

    obs::set_all(false);
    let off = train_cnn(&mk(), &ds, &cfg);

    obs::set_all(true);
    obs::reset_all();
    let on = train_cnn(&mk(), &ds, &cfg);
    let adds = metrics::snapshot().get("delta_lut_adds");
    obs::set_all(false);

    assert_cnn_runs_equal("cnn log16-lut obs on vs off", &off, &on);
    assert!(adds > 0, "CNN training under obs must tick the ⊞ counter");
}

fn assert_cnn_runs_equal<E: PartialEq + std::fmt::Debug>(
    label: &str,
    a: &TrainResult<Cnn<E>>,
    b: &TrainResult<Cnn<E>>,
) {
    assert_eq!(a.model.conv1.w.data, b.model.conv1.w.data, "{label}: conv1 w");
    assert_eq!(a.model.conv2.w.data, b.model.conv2.w.data, "{label}: conv2 w");
    assert_eq!(a.model.fc1.w.data, b.model.fc1.w.data, "{label}: fc1 w");
    assert_eq!(a.model.fc2.w.data, b.model.fc2.w.data, "{label}: fc2 w");
    assert_eq!(a.model.conv1.b, b.model.conv1.b, "{label}: conv1 b");
    assert_eq!(a.model.fc2.b, b.model.fc2.b, "{label}: fc2 b");
    assert_eq!(a.test.accuracy, b.test.accuracy, "{label}: test accuracy");
    assert_eq!(a.test.loss, b.test.loss, "{label}: test loss");
}

/// Two real worker processes, with heartbeats flowing: the observed run
/// must still be bit-identical to the unobserved one (and to serial).
#[test]
fn multiproc_obs_invariant_with_heartbeats() {
    let _s = ObsSession::begin();
    let ds = tiny_ds();
    let cfg = tiny_cfg();
    let mut spec = MultiprocSpec::new(2);
    spec.worker_exe = Some(worker_exe());
    spec.transport = Transport::Stdio;
    spec.worker_threads = 1;

    obs::set_all(false);
    let off = train_multiproc(&FloatBackend::default(), &ds, &cfg, &spec)
        .unwrap_or_else(|e| panic!("obs-off multi-process run failed: {e:#}"));

    obs::set_all(true);
    obs::reset_all();
    let on = train_multiproc(&FloatBackend::default(), &ds, &cfg, &spec)
        .unwrap_or_else(|e| panic!("obs-on multi-process run failed: {e:#}"));
    let snap = metrics::snapshot();
    obs::set_all(false);

    assert_mlp_runs_equal("float32 multiproc obs on vs off", &off, &on);
    let serial = train(&FloatBackend::default(), &ds, &cfg);
    assert_mlp_runs_equal("float32 serial obs-off vs multiproc obs-on", &serial, &on);

    // Heartbeats really flowed during the observed run — the invariant
    // holds *with* the extra frames on the wire, not by omitting them.
    assert!(snap.get("wire_frames_tx") > 0, "coordinator sent no frames?");
    assert!(snap.get("heartbeat_rx") > 0, "no worker heartbeats were received");
    assert_eq!(snap.get("worker_deaths"), 0, "no worker should die in a clean run");
}

#[test]
fn multiproc_obs_invariant_lns16_lut() {
    let _s = ObsSession::begin();
    let ds = tiny_ds();
    let cfg = tiny_cfg();
    let mut spec = MultiprocSpec::new(2);
    spec.worker_exe = Some(worker_exe());
    spec.transport = Transport::Stdio;
    spec.worker_threads = 1;
    let mk = || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);

    obs::set_all(false);
    let off = train_multiproc(&mk(), &ds, &cfg, &spec)
        .unwrap_or_else(|e| panic!("obs-off LNS multi-process run failed: {e:#}"));
    obs::set_all(true);
    obs::reset_all();
    let on = train_multiproc(&mk(), &ds, &cfg, &spec)
        .unwrap_or_else(|e| panic!("obs-on LNS multi-process run failed: {e:#}"));
    let hb = metrics::snapshot().get("heartbeat_rx");
    let worker_dist = obs::dist::worker_snapshots();
    obs::set_all(false);

    assert_mlp_runs_equal("log16-lut multiproc obs on vs off", &off, &on);
    assert!(hb > 0, "no worker heartbeats were received");
    assert!(
        !worker_dist.is_empty(),
        "no worker distribution deltas arrived via heartbeat v3"
    );
    assert!(
        worker_dist.iter().any(|(_, s)| s.entries.iter().any(|e| e.total() > 0)),
        "worker distribution deltas were all empty"
    );
}

/// Counter pins on hand-counted operand sets, driven through the
/// *public* dispatchers (`add_slice` / `mac_row` / `dot_acc`) rather
/// than the `_tallied` twins, under both lane settings. The counts are
/// part of the numerics contract: deterministic per config, identical
/// whether the lane kernels are enabled or not.
#[test]
fn lns_counter_pins_are_lane_invariant() {
    let _s = ObsSession::begin();
    obs::set_counters(true);
    for lanes_on in [true, false] {
        lanes::set_enabled(lanes_on);
        for (mode, cfg) in
            [("lut", LnsConfig::w16_lut()), ("bitshift", LnsConfig::w16_bitshift())]
        {
            let s = LnsSystem::new(cfg);
            let hi = s.config().m_max();
            let pos_max = LnsValue::new(hi, true);
            let one = LnsValue::ONE;
            let x = s.encode_f64(2.75);
            let label = format!("{mode} lanes={lanes_on}");
            obs::reset_all();

            // Exact cancellation: one ⊞ fold, one cancel.
            let mut acc = vec![x];
            s.add_slice(&mut acc, &[x.neg()]);
            assert!(acc[0].is_zero(), "{label}: x ⊞ (−x) must cancel to zero");

            // Top-of-range same-sign add: Δ+ pushes past m_max.
            let mut acc = vec![pos_max];
            s.add_slice(&mut acc, &[pos_max]);
            assert_eq!(acc[0].m, hi, "{label}: clamped add stays at m_max");

            // mac_row over [1, 0, max] with a = max: one zero skip, one
            // product saturation (max ⊡ max), two ⊞ folds onto acc = 1.
            let mut acc = vec![one, one, one];
            s.mac_row(&mut acc, pos_max, &[one, LnsValue::ZERO, pos_max]);

            // dot_acc zero skips count either-operand-zero pairs; the
            // surviving product lands in a zero accumulator (no ⊞).
            let out =
                s.dot_acc(LnsValue::ZERO, &[x, LnsValue::ZERO, x], &[LnsValue::ZERO, x, x]);
            assert!(!out.is_zero(), "{label}: dot_acc lost its product");

            let snap = metrics::snapshot();
            let (lut, shift) = if mode == "lut" { (4, 0) } else { (0, 4) };
            assert_eq!(snap.get("delta_lut_adds"), lut, "{label}: LUT ⊞ count");
            assert_eq!(snap.get("delta_shift_adds"), shift, "{label}: bit-shift ⊞ count");
            assert_eq!(snap.get("lns_cancel"), 1, "{label}: cancellations");
            assert_eq!(snap.get("lns_clamp_hi"), 1, "{label}: high clamps");
            assert_eq!(snap.get("lns_mul_sat"), 1, "{label}: product saturations");
            assert_eq!(snap.get("dot_zero_skip"), 3, "{label}: zero skips");
        }
    }
}

/// Fixed-point pins plus full-registry lane invariance: the same ops
/// under lanes on and lanes off leave identical counter totals.
#[test]
fn fixed_counter_pins_are_lane_invariant() {
    let _s = ObsSession::begin();
    obs::set_counters(true);
    let s = FixedSystem::new(FixedConfig::w16());
    let mc = s.config().max_code();
    let mut totals = Vec::new();
    for lanes_on in [true, false] {
        lanes::set_enabled(lanes_on);
        obs::reset_all();
        // max·max saturates the product; adding it to a max accumulator
        // saturates the accumulate too.
        let mut acc = vec![mc, 0];
        s.mac_row(&mut acc, mc, &[mc, 0]);
        assert_eq!(acc, vec![mc, 0], "lanes={lanes_on}: saturated mac_row values");
        let snap = metrics::snapshot();
        assert_eq!(snap.get("fixed_mul_sat"), 1, "lanes={lanes_on}: product saturation");
        assert_eq!(snap.get("fixed_acc_sat"), 1, "lanes={lanes_on}: accumulate saturation");
        totals.push(metrics::named_totals());
    }
    assert_eq!(totals[0], totals[1], "fixed counts must not depend on the lane path");
}

/// `--trace` output is structurally sound: valid JSON, Chrome
/// trace_event shape, every B matched by an E, nothing dropped.
#[test]
fn trace_output_is_valid_chrome_json() {
    let _s = ObsSession::begin();
    obs::set_all(true);
    obs::reset_all();
    let ds = tiny_ds();
    let mut cfg = tiny_cfg();
    cfg.epochs = 1;
    train(&FloatBackend::default(), &ds, &cfg);

    let path = std::env::temp_dir().join(format!("lnsdnn_obs_trace_{}.json", std::process::id()));
    obs::trace::write_chrome_trace(&path).expect("writing Chrome trace");
    let text = std::fs::read_to_string(&path).expect("reading trace back");
    let _ = std::fs::remove_file(&path);
    assert_eq!(obs::trace::dropped(), 0, "tiny run must fit the event buffer");
    obs::set_all(false);

    let pairs = lnsdnn::bench_util::validate_chrome_trace(&text)
        .unwrap_or_else(|e| panic!("trace failed validation: {e}"));
    assert!(pairs > 0, "trace must contain at least one completed span pair");
}

/// Two identical observed runs produce identical distribution
/// snapshots: the recorders sample at deterministic points (per-batch
/// gradient sums, post-update weights, forward activations), so the
/// occupancy histograms are reproducible per config.
#[test]
fn occupancy_snapshots_are_deterministic() {
    let _s = ObsSession::begin();
    let ds = tiny_ds();
    let cfg = tiny_cfg();
    let mk = || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);

    obs::set_counters(true);
    obs::reset_all();
    train(&mk(), &ds, &cfg);
    let first = obs::dist::snapshot();

    obs::reset_all();
    train(&mk(), &ds, &cfg);
    let second = obs::dist::snapshot();
    obs::set_counters(false);

    assert!(!first.entries.is_empty(), "observed run recorded no distributions");
    for class in obs::dist::TensorClass::ALL {
        assert!(
            first.entries.iter().any(|e| e.class == class.code() && e.total() > 0),
            "no samples recorded for class {}",
            class.name()
        );
    }
    assert_eq!(first, second, "occupancy snapshots must be reproducible per config");
}

/// Parse Prometheus text samples into `series-with-labels → value`.
fn parse_prometheus(text: &str) -> std::collections::HashMap<String, f64> {
    let mut out = std::collections::HashMap::new();
    for l in text.lines() {
        if l.is_empty() || l.starts_with('#') {
            continue;
        }
        let (series, value) = l.rsplit_once(' ').expect("sample line has a value");
        out.insert(series.to_string(), value.parse().expect("sample value parses"));
    }
    out
}

/// The `/metrics` rendering declares the new distribution families,
/// populates the per-layer series, and every counter-typed series is
/// monotone across scrapes (the Prometheus contract a scraper relies
/// on for `rate()`).
#[test]
fn prometheus_counters_are_monotone_and_declared() {
    let _s = ObsSession::begin();
    let ds = tiny_ds();
    let mut cfg = tiny_cfg();
    cfg.epochs = 1;
    let mk = || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);

    obs::set_counters(true);
    obs::reset_all();
    train(&mk(), &ds, &cfg);
    let first = parse_prometheus(&obs::serve::render_prometheus());
    train(&mk(), &ds, &cfg);
    let text = obs::serve::render_prometheus();
    let second = parse_prometheus(&text);
    obs::set_counters(false);

    for family in ["lnsdnn_dist_exp_total", "lnsdnn_grad_l1", "lnsdnn_grad_linf"] {
        assert!(text.contains(&format!("# TYPE {family} ")), "missing TYPE for {family}");
        assert!(text.contains(&format!("# HELP {family} ")), "missing HELP for {family}");
    }
    assert!(
        second.keys().any(|k| k.starts_with("lnsdnn_dist_exp_total{")),
        "no exponent-occupancy series rendered"
    );
    assert!(
        second.keys().any(|k| k.starts_with("lnsdnn_grad_l1{layer=")),
        "no per-layer gradient-norm gauge rendered"
    );
    let mut compared = 0;
    for (k, v1) in &first {
        let name = k.split('{').next().unwrap();
        if !(name.ends_with("_total") || name.ends_with("_bucket") || name.ends_with("_count")) {
            continue;
        }
        let v2 = second.get(k).unwrap_or_else(|| panic!("counter series vanished: {k}"));
        assert!(v2 >= v1, "counter went backwards: {k} {v1} -> {v2}");
        compared += 1;
    }
    assert!(compared > 0, "no counter series to compare across scrapes");
}

/// HTTP GET against a live endpoint; returns the raw response.
fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).expect("connect obs endpoint");
    let req = format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    s.write_all(req.as_bytes()).expect("send request");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("read response");
    resp
}

/// Run `f` with full observation on, an [`obs::serve::ObsServer`]
/// bound, and a scraper thread looping `GET /metrics` for the
/// duration. Asserts the scraper actually landed successful scrapes.
fn run_under_scraper<T>(f: impl FnOnce() -> T) -> T {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    obs::set_all(true);
    obs::reset_all();
    let srv = obs::serve::ObsServer::start("127.0.0.1:0").expect("bind obs endpoint");
    let addr = srv.addr();
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut n = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let resp = http_get(addr, "/metrics");
                assert!(resp.starts_with("HTTP/1.1 200"), "mid-run scrape failed");
                n += 1;
            }
            n
        })
    };
    let out = f();
    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().expect("scraper thread panicked");
    assert!(scrapes > 0, "the scraper never landed a scrape during the run");
    srv.stop();
    obs::set_all(false);
    out
}

/// A live `--obs-listen` endpoint with a scraper hammering `/metrics`
/// throughout the observed run still cannot perturb training: results
/// stay bit-identical to the unobserved baseline on the LNS backend
/// and on the dither-sensitive stochastic-rounding fixed backend.
#[test]
fn live_scraper_does_not_perturb_training() {
    let _s = ObsSession::begin();
    let ds = tiny_ds();
    let mut cfg = tiny_cfg();
    cfg.epochs = 1;

    {
        let mk = || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        obs::set_all(false);
        let off = train(&mk(), &ds, &cfg);
        let on = run_under_scraper(|| train(&mk(), &ds, &cfg));
        assert_mlp_runs_equal("log16-lut scraped obs on vs off", &off, &on);
    }
    {
        let mk = || FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        obs::set_all(false);
        let off = train(&mk(), &ds, &cfg);
        let on = run_under_scraper(|| train(&mk(), &ds, &cfg));
        assert_mlp_runs_equal("lin16 scraped obs on vs off", &off, &on);
    }
}
