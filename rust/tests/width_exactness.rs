//! Width-exactness pins for the runtime bitwidth axis (NUMERICS.md §11,
//! "width genericity").
//!
//! PR 10 made the word width a runtime parameter: `LnsConfig::for_width`
//! / `FixedConfig::for_width` build validated 8/12/16-bit (and beyond)
//! configs with the preset field layout, and a [`PrecisionMap`] assigns
//! narrower *storage* words per layer on top of a base backend. This
//! file pins the contract that widths change **values, never chain
//! order**:
//!
//! * lane kernels ≡ scalar twins ≡ definitional folds, bit-identically,
//!   at w8/w12/w16 × LUT/BitShift (extending `lane_exactness.rs`, which
//!   covers the paper's 12/16-bit presets) and for the fixed twins,
//! * encode/decode round-trip and saturation-boundary properties over
//!   every width, via `proptest_util`,
//! * `Backend::quantize` is idempotent, an identity at the base width,
//!   and lands exactly on the narrow word's grid and range,
//! * one mixed-precision MLP trains bit-identically serial ≡ in-process
//!   sharded ≡ across real worker processes,
//! * the w8 occupancy histograms are deterministic and confined to the
//!   8-bit word's representable exponent range.
//!
//! CI runs this file in release mode too (same reasoning as
//! `lane_exactness.rs`: autovectorized codegen is part of the contract).

use lnsdnn::coordinator::server::{train_multiproc, MultiprocSpec};
use lnsdnn::data::{synth_dataset, Dataset, SynthSpec};
use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{LnsConfig, LnsSystem, LnsValue, LANES};
use lnsdnn::nn::{InitScheme, SgdConfig};
use lnsdnn::obs::dist::{self, TensorClass};
use lnsdnn::precision::{PrecisionMap, WordSpec};
use lnsdnn::proptest_util::run_prop;
use lnsdnn::rng::SplitMix64;
use lnsdnn::tensor::{Backend, FixedBackend, LnsBackend};
use lnsdnn::train::{train, ShardConfig, TrainConfig, Transport};
use std::path::PathBuf;

/// The width axis under contract. 12/16 are the paper's settings (also
/// pinned by `lane_exactness.rs`); 8 is the narrow end the mixed-precision
/// sweep targets.
const WIDTHS: [u32; 3] = [8, 12, 16];

/// Every (width × Δ-mode) LNS system on the contract matrix.
fn systems() -> Vec<(String, LnsSystem)> {
    let mut out = Vec::new();
    for w in WIDTHS {
        for (mode, bitshift) in [("lut", false), ("bs", true)] {
            let cfg = LnsConfig::for_width(w, bitshift)
                .unwrap_or_else(|e| panic!("for_width({w}) must validate: {e}"));
            out.push((format!("w{w}_{mode}"), LnsSystem::new(cfg)));
        }
    }
    out
}

/// Lengths exercising full lanes plus every interesting remainder.
fn lens() -> Vec<usize> {
    vec![LANES * 2, LANES * 2 + 1, LANES * 3 - 1, 1, LANES - 1, 0]
}

/// Adversarial value mix: exact zeros, `m_max`/`m_min` boundary words
/// (both signs), rest ordinary encoded values (same recipe as
/// `lane_exactness.rs`).
fn arb_vals(sys: &LnsSystem, rng: &mut SplitMix64, n: usize) -> Vec<LnsValue> {
    let (m_min, m_max) = (sys.config().m_min(), sys.config().m_max());
    (0..n)
        .map(|_| match rng.next_u64() % 20 {
            0..=2 => LnsValue::ZERO,
            3 => LnsValue { m: m_max, s: rng.next_u64() % 2 == 0 },
            4 => LnsValue { m: m_min, s: rng.next_u64() % 2 == 0 },
            _ => sys.encode_f64(rng.uniform(-16.0, 16.0)),
        })
        .collect()
}

// ---------------------------------------------------------------------
// Lane ≡ scalar ≡ fold, across the width matrix
// ---------------------------------------------------------------------

#[test]
fn lns_mac_row_bit_identical_across_widths() {
    for (name, sys) in systems() {
        let mut rng = SplitMix64::new(0x81);
        for len in lens() {
            for trial in 0..10 {
                let acc0 = arb_vals(&sys, &mut rng, len);
                let w = arb_vals(&sys, &mut rng, len);
                let a = arb_vals(&sys, &mut rng, 1)[0];
                let mut lane = acc0.clone();
                sys.mac_row(&mut lane, a, &w);
                let mut scalar = acc0.clone();
                sys.mac_row_scalar(&mut scalar, a, &w);
                assert_eq!(lane, scalar, "{name} len={len} trial={trial}");
                let fold: Vec<LnsValue> =
                    acc0.iter().zip(&w).map(|(&o, &wv)| sys.mac(o, a, wv)).collect();
                assert_eq!(lane, fold, "{name} len={len} trial={trial} (fold)");
            }
        }
    }
}

#[test]
fn lns_mac_panel_and_dot_acc_bit_identical_across_widths() {
    for (name, sys) in systems() {
        let mut rng = SplitMix64::new(0x82);
        for nc in [LANES, LANES + 1, 2 * LANES - 1, 3] {
            let depth = 5;
            let a = arb_vals(&sys, &mut rng, depth);
            let panel = arb_vals(&sys, &mut rng, depth * nc);
            let acc0 = arb_vals(&sys, &mut rng, nc);
            let mut lane = acc0.clone();
            sys.mac_panel(&mut lane, &a, &panel);
            let mut scalar = acc0.clone();
            sys.mac_panel_scalar(&mut scalar, &a, &panel);
            assert_eq!(lane, scalar, "{name} nc={nc} (panel)");
        }
        for len in lens() {
            let a = arb_vals(&sys, &mut rng, len);
            let w = arb_vals(&sys, &mut rng, len);
            for acc0 in [LnsValue::ZERO, arb_vals(&sys, &mut rng, 1)[0]] {
                assert_eq!(
                    sys.dot_acc(acc0, &a, &w),
                    sys.dot_acc_scalar(acc0, &a, &w),
                    "{name} len={len} (dot)"
                );
            }
        }
    }
}

#[test]
fn lns_add_slice_bit_identical_across_widths() {
    for (name, sys) in systems() {
        let mut rng = SplitMix64::new(0x83);
        for len in lens() {
            let acc0 = arb_vals(&sys, &mut rng, len);
            let x = arb_vals(&sys, &mut rng, len);
            let mut lane = acc0.clone();
            sys.add_slice(&mut lane, &x);
            let mut scalar = acc0.clone();
            sys.add_slice_scalar(&mut scalar, &x);
            assert_eq!(lane, scalar, "{name} len={len}");
            let fold: Vec<LnsValue> = acc0.iter().zip(&x).map(|(&o, &y)| sys.add(o, y)).collect();
            assert_eq!(lane, fold, "{name} len={len} (add fold)");
        }
    }
}

#[test]
fn fixed_lane_kernels_bit_identical_across_widths() {
    for w in WIDTHS {
        let cfg = FixedConfig::for_width(w).unwrap();
        let s = FixedSystem::new(cfg);
        let mc = cfg.max_code();
        let mut rng = SplitMix64::new(0x84);
        for len in lens() {
            let codes = |rng: &mut SplitMix64| -> Vec<i32> {
                (0..len)
                    .map(|_| match rng.next_u64() % 10 {
                        0 => 0,
                        1 => mc,
                        2 => -mc,
                        _ => (rng.next_below(2 * mc as u64 + 1) as i32) - mc,
                    })
                    .collect()
            };
            let acc0 = codes(&mut rng);
            let wv = codes(&mut rng);
            for a in [0, 1, -1, mc, -mc, mc / 3] {
                let mut fast = acc0.clone();
                s.mac_row(&mut fast, a, &wv);
                let slow: Vec<i32> = acc0.iter().zip(&wv).map(|(&o, &x)| s.mac(o, a, x)).collect();
                assert_eq!(fast, slow, "fixed{w} len={len} a={a}");
            }
            let fast = s.dot_acc(7, &acc0, &wv);
            let mut slow = 7;
            for (&av, &xv) in acc0.iter().zip(&wv) {
                slow = s.mac(slow, av, xv);
            }
            assert_eq!(fast, slow, "fixed{w} len={len} (dot)");
        }
    }
}

// ---------------------------------------------------------------------
// Encode/decode round-trip and saturation, property-tested per width
// ---------------------------------------------------------------------

#[test]
fn lns_roundtrip_error_bounded_by_half_step_at_every_width() {
    for (name, sys) in systems() {
        let frac = sys.config().frac_bits;
        // Half a log-grid step, plus float slack far below any grid.
        let tol = 0.5 / (1u64 << frac) as f64 + 1e-9;
        run_prop(
            &format!("roundtrip_{name}"),
            0x91,
            256,
            |rng| {
                // Magnitudes inside every width's exponent range (±16 for
                // the preset layout), so no saturation interferes.
                let mag = rng.uniform(-14.0, 14.0).exp2();
                if rng.next_u64() % 2 == 0 {
                    mag
                } else {
                    -mag
                }
            },
            |&v| {
                let x = sys.encode_f64(v);
                let d = sys.decode_f64(x);
                if (d > 0.0) != (v > 0.0) {
                    return Err(format!("sign lost: {v} → {d}"));
                }
                let err = (d.abs().log2() - v.abs().log2()).abs();
                if err > tol {
                    return Err(format!("log2 error {err} > {tol} ({v} → {d})"));
                }
                // Re-encoding a grid point must be the identity.
                if sys.encode_f64(d) != x {
                    return Err(format!("re-encode moved the word: {v} → {x:?}"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn lns_saturation_clamps_to_boundary_words_at_every_width() {
    for (name, sys) in systems() {
        let (m_min, m_max) = (sys.config().m_min(), sys.config().m_max());
        run_prop(
            &format!("saturation_{name}"),
            0x92,
            256,
            |rng| {
                let exp = rng.uniform(17.0, 200.0);
                let big = rng.next_u64() % 2 == 0;
                let pos = rng.next_u64() % 2 == 0;
                let mag = if big { exp.exp2() } else { (-exp).exp2() };
                if pos {
                    mag
                } else {
                    -mag
                }
            },
            |&v| {
                let x = sys.encode_f64(v);
                let want = if v.abs() > 1.0 { m_max } else { m_min };
                if x.m != want {
                    return Err(format!("{v} encoded to m={}, want boundary {want}", x.m));
                }
                if x.s != (v > 0.0) {
                    return Err(format!("{v} lost its sign at the boundary"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn fixed_roundtrip_and_saturation_at_every_width() {
    for w in WIDTHS {
        let cfg = FixedConfig::for_width(w).unwrap();
        let s = FixedSystem::new(cfg);
        let max_val = s.decode_f64(cfg.max_code());
        let half_unit = cfg.unit() / 2.0 + 1e-12;
        run_prop(
            &format!("fixed_roundtrip_w{w}"),
            0x93,
            256,
            |rng| rng.uniform(-max_val, max_val),
            |&v| {
                let x = s.encode_f64(v);
                let d = s.decode_f64(x);
                if (d - v).abs() > half_unit {
                    return Err(format!("|{d} - {v}| > {half_unit}"));
                }
                if s.encode_f64(d) != x {
                    return Err(format!("re-encode moved the code: {v} → {x}"));
                }
                Ok(())
            },
        );
        assert_eq!(s.encode_f64(1e12), cfg.max_code(), "w{w} positive saturation");
        assert_eq!(s.encode_f64(-1e12), cfg.min_code(), "w{w} negative saturation");
        assert_eq!(s.encode_f64(0.0), 0, "w{w} zero is exact");
    }
}

// ---------------------------------------------------------------------
// Backend::quantize: grid, range, idempotence
// ---------------------------------------------------------------------

#[test]
fn quantize_is_idempotent_and_grid_exact() {
    // LNS: base w16-lut storage-quantized to the 8-bit word.
    let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let sys = LnsSystem::new(LnsConfig::w16_lut());
    let spec = WordSpec::for_backend_tag(8, "log16-lut").unwrap();
    let narrow = LnsConfig::for_width(8, false).unwrap();
    let step = 1i32 << (sys.config().frac_bits - spec.frac_bits);
    let bound = narrow.m_max() * step;
    let base_spec = WordSpec::for_backend_tag(16, "log16-lut").unwrap();
    run_prop(
        "lns_quantize_w16_to_w8",
        0x94,
        256,
        |rng| arb_vals(&sys, rng, 1)[0],
        |&x| {
            let q = b.quantize(x, spec);
            if b.quantize(q, spec) != q {
                return Err(format!("not idempotent: {x:?} → {q:?}"));
            }
            if b.quantize(x, base_spec) != x {
                return Err(format!("base-width spec must be the identity on {x:?}"));
            }
            if x.is_zero() {
                return if q.is_zero() { Ok(()) } else { Err("zero must stay zero".into()) };
            }
            if q.m % step != 0 {
                return Err(format!("off the w8 grid: m={} step={step}", q.m));
            }
            if q.m.abs() > bound {
                return Err(format!("outside the w8 range: m={} bound={bound}", q.m));
            }
            if q.s != x.s {
                return Err("quantize must preserve the linear sign".into());
            }
            Ok(())
        },
    );

    // Fixed: base lin16 storage-quantized to the 8-bit word.
    let fb = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
    let fcfg = FixedConfig::w16();
    let fspec = WordSpec::for_backend_tag(8, "lin16").unwrap();
    let fstep = 1i32 << (fcfg.frac_bits - fspec.frac_bits);
    let fbound = ((1i32 << (fspec.total_bits - 1)) - 1) * fstep;
    let fbase = WordSpec::for_backend_tag(16, "lin16").unwrap();
    let mc = fcfg.max_code();
    run_prop(
        "fixed_quantize_w16_to_w8",
        0x95,
        256,
        |rng| (rng.next_below(2 * mc as u64 + 1) as i32) - mc,
        |&x| {
            let q = fb.quantize(x, fspec);
            if fb.quantize(q, fspec) != q {
                return Err(format!("not idempotent: {x} → {q}"));
            }
            if fb.quantize(x, fbase) != x {
                return Err(format!("base-width spec must be the identity on {x}"));
            }
            if q % fstep != 0 {
                return Err(format!("off the w8 grid: {q} step={fstep}"));
            }
            if q.abs() > fbound {
                return Err(format!("outside the w8 range: {q} bound={fbound}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Mixed-precision training: serial ≡ sharded ≡ multi-process
// ---------------------------------------------------------------------

fn tiny_ds() -> Dataset {
    synth_dataset(&SynthSpec {
        name: "tiny".into(),
        classes: 3,
        train_per_class: 14,
        test_per_class: 5,
        strokes: 4,
        jitter_px: 1.5,
        jitter_rot: 0.15,
        noise: 0.04,
        seed: 42,
    })
}

fn mixed_cfg() -> TrainConfig {
    TrainConfig {
        dims: vec![784, 8, 3],
        epochs: 2,
        batch_size: 5,
        sgd: SgdConfig { lr: 0.02, weight_decay: 1e-4 },
        val_ratio: 5,
        init: InitScheme::HeNormal,
        seed: 3,
        shard: ShardConfig::default(),
        // Layer 0 stores its parameters in the 8-bit word; layer 1 keeps
        // the base 16-bit word.
        precision: PrecisionMap::parse("8,-", "log16-lut").expect("valid mixed spec"),
    }
}

#[test]
fn mixed_precision_mlp_serial_sharded_multiproc_bit_identical() {
    let mk = || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let ds = tiny_ds();
    let cfg = mixed_cfg();

    let serial = train(&mk(), &ds, &cfg);

    // The map must actually bite: against a uniform run, the quantized
    // layer's weights differ, and every stored word sits on the w8 grid.
    let mut uniform_cfg = cfg.clone();
    uniform_cfg.precision = PrecisionMap::uniform();
    let uniform = train(&mk(), &ds, &uniform_cfg);
    assert_ne!(
        serial.model.layers[0].w.data, uniform.model.layers[0].w.data,
        "the 8-bit storage assignment must change layer 0"
    );
    let step = 1i32 << (LnsConfig::w16_lut().frac_bits - LnsConfig::w8_lut().frac_bits);
    for v in &serial.model.layers[0].w.data {
        assert!(v.is_zero() || v.m % step == 0, "layer 0 word off the w8 grid: {v:?}");
    }

    let mut sharded_cfg = cfg.clone();
    sharded_cfg.shard = ShardConfig::with_shards(4);
    let sharded = train(&mk(), &ds, &sharded_cfg);

    let mut spec = MultiprocSpec::new(2);
    spec.worker_exe = Some(PathBuf::from(env!("CARGO_BIN_EXE_lnsdnn")));
    spec.transport = Transport::Stdio;
    spec.worker_threads = 1;
    let mp = train_multiproc(&mk(), &ds, &cfg, &spec)
        .unwrap_or_else(|e| panic!("mixed-precision multi-process run failed: {e:#}"));

    for (label, other) in [("serial vs sharded", &sharded), ("serial vs multiproc", &mp)] {
        assert_eq!(serial.model.layers.len(), other.model.layers.len(), "{label}");
        for l in 0..serial.model.layers.len() {
            assert_eq!(
                serial.model.layers[l].w.data, other.model.layers[l].w.data,
                "{label}: layer {l} w"
            );
            assert_eq!(serial.model.layers[l].b, other.model.layers[l].b, "{label}: layer {l} b");
        }
        assert_eq!(serial.test.accuracy, other.test.accuracy, "{label}: test accuracy");
        assert_eq!(serial.test.loss, other.test.loss, "{label}: test loss");
        for (x, y) in serial.curve.iter().zip(&other.curve) {
            assert_eq!(x.train_loss, y.train_loss, "{label}: epoch {} loss", x.epoch);
            assert_eq!(x.val_accuracy, y.val_accuracy, "{label}: epoch {} acc", x.epoch);
        }
    }
}

// ---------------------------------------------------------------------
// w8 occupancy histograms: deterministic, confined to the 8-bit range
// ---------------------------------------------------------------------

#[test]
fn w8_dist_snapshot_is_deterministic_and_range_confined() {
    // Layer 13 is uncontended: trainers record into layers 1..4, so a
    // concurrently running test in this binary cannot touch this cell.
    const LAYER: usize = 13;
    let b = LnsBackend::new(LnsSystem::new(LnsConfig::w8_lut()), 0.01);
    let was_on = lnsdnn::obs::counters_enabled();
    lnsdnn::obs::set_counters(true);

    let record = |seed: u64| -> dist::DistEntry {
        let sys = LnsSystem::new(LnsConfig::w8_lut());
        let mut rng = SplitMix64::new(seed);
        // Includes magnitudes far outside the w8 exponent range: they
        // must saturate at the 8-bit boundary, not the bank's edge.
        let vals: Vec<LnsValue> = (0..500)
            .map(|_| sys.encode_f64(rng.uniform(-40.0, 40.0).exp2() * if rng.next_u64() % 2 == 0 { 1.0 } else { -1.0 }))
            .collect();
        let before = dist::snapshot();
        dist::record_slice(&b, TensorClass::Weights, LAYER, &vals);
        let after = dist::snapshot();
        let cell = after.get(TensorClass::Weights, LAYER).expect("cell recorded").clone();
        // Delta against whatever this cell held before (other tests never
        // write layer 13, but a previous record() call in this test did).
        match before.get(TensorClass::Weights, LAYER) {
            None => cell,
            Some(prev) => dist::DistEntry {
                class: cell.class,
                layer: cell.layer,
                zeros: cell.zeros - prev.zeros,
                neg: cell.neg - prev.neg,
                buckets: cell
                    .buckets
                    .iter()
                    .zip(&prev.buckets)
                    .map(|(&c, &p)| c - p)
                    .collect(),
            },
        }
    };

    let first = record(0xA1);
    let second = record(0xA1);
    assert_eq!(first, second, "same seed must produce identical w8 histograms");

    let (lo, hi) = b.dist_exp_range();
    let (olo, ohi) = first.occupied_span().expect("samples landed");
    assert!(olo >= lo && ohi <= hi, "w8 span [{olo}, {ohi}] outside range [{lo}, {hi}]");
    // The generator exceeds the 8-bit exponent range on both sides, so
    // the boundary buckets must have absorbed the overflow exactly at
    // the config's edge.
    assert_eq!((olo, ohi), (lo, hi), "saturated samples must pin the w8 boundaries");

    lnsdnn::obs::set_counters(was_on);
}
