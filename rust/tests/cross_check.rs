//! Cross-language bit-exactness: the Rust native engine vs the Python
//! numeric core, over the golden corpus `aot.py` emits.
//!
//! Every Δ/pow2 table entry and every golden op result must match
//! **bit-exactly** — this is what entitles the PJRT artifacts and the
//! native engine to be used interchangeably.
//!
//! Requires `make artifacts` (tests skip with a notice when the corpus is
//! absent, so plain `cargo test` still passes pre-AOT).

use lnsdnn::lns::{DeltaMode, LnsConfig, LnsSystem, LnsValue, ZERO_M};
use lnsdnn::tensor::{Backend, LnsBackend};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("golden_lns.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {} missing (run `make artifacts`)", dir.display());
        None
    }
}

fn config_by_name(name: &str) -> LnsConfig {
    match name {
        "w16_lut" => LnsConfig::w16_lut(),
        "w12_lut" => LnsConfig::w12_lut(),
        "w16_bs" => LnsConfig::w16_bitshift(),
        "w12_bs" => LnsConfig::w12_bitshift(),
        other => panic!("unknown golden config {other}"),
    }
}

/// Python sentinel for the Δ− singular bin (any value far below −m_max is
/// semantically identical after saturation; table comparison special-cases
/// it).
const PY_MINUS_SAT: i64 = -(1 << 30);

#[test]
fn tables_match_bit_exactly() {
    let Some(dir) = artifacts_dir() else { return };
    let text = std::fs::read_to_string(dir.join("golden_tables.tsv")).unwrap();
    let mut checked = 0usize;
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        let (cname, tname, idx, val): (&str, &str, usize, i64) =
            (f[0], f[1], f[2].parse().unwrap(), f[3].parse().unwrap());
        let sys = LnsSystem::new(config_by_name(cname));
        let got = match tname {
            "delta_plus" => sys.delta().table_plus().get(idx).map(|&v| v as i64),
            "delta_minus" => sys.delta().table_minus().get(idx).map(|&v| v as i64),
            "sm_delta_plus" => sys.softmax_delta().table_plus().get(idx).map(|&v| v as i64),
            "sm_delta_minus" => sys.softmax_delta().table_minus().get(idx).map(|&v| v as i64),
            "pow2" => sys.pow2_table().entries().get(idx).copied(),
            other => panic!("unknown table {other}"),
        };
        let got = got.unwrap_or_else(|| panic!("{cname}/{tname}[{idx}] out of range"));
        // Both sides use a "hugely negative" sentinel for the Δ− singular
        // bin; values differ but semantics (saturate) are identical.
        let sentinel = val == PY_MINUS_SAT && got < -(1 << 24);
        assert!(
            got == val || sentinel,
            "{cname}/{tname}[{idx}]: rust {got} vs python {val}"
        );
        checked += 1;
    }
    assert!(checked > 4000, "expected a full table corpus, got {checked}");
}

fn val(m: i64, s: i64) -> LnsValue {
    LnsValue::new(m as i32, s == 1)
}

#[test]
fn golden_ops_match_bit_exactly() {
    let Some(dir) = artifacts_dir() else { return };
    let text = std::fs::read_to_string(dir.join("golden_lns.tsv")).unwrap();
    let mut systems: std::collections::HashMap<String, LnsSystem> = Default::default();
    let mut counts: std::collections::HashMap<String, usize> = Default::default();
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        let cname = f[0];
        let op = f[1];
        let sys = systems
            .entry(cname.to_string())
            .or_insert_with(|| LnsSystem::new(config_by_name(cname)));
        let p: Vec<i64> = f[2..].iter().map(|x| x.parse::<i64>().unwrap()).collect();
        match op {
            "mul" | "add" | "sub" => {
                let (x, y) = (val(p[0], p[1]), val(p[2], p[3]));
                let want = val(p[4], p[5]);
                let got = match op {
                    "mul" => sys.mul(x, y),
                    "add" => sys.add(x, y),
                    _ => sys.sub(x, y),
                };
                assert_eq!(got.m, want.m, "{cname} {op} {x:?} {y:?} magnitude");
                if !got.is_zero() {
                    assert_eq!(got.s, want.s, "{cname} {op} {x:?} {y:?} sign");
                }
            }
            "llrelu" => {
                let backend = LnsBackend::new(sys.clone(), 0.01);
                let got = backend.leaky_relu(val(p[0], p[1]));
                let want = val(p[2], p[3]);
                assert_eq!(got.m, want.m, "{cname} llrelu m({} {})", p[0], p[1]);
                if !got.is_zero() {
                    assert_eq!(got.s, want.s, "{cname} llrelu s");
                }
            }
            "softmax_logit" => {
                let got = sys.softmax_logit_units(val(p[0], p[1]));
                assert_eq!(got, p[2], "{cname} softmax_logit({} {})", p[0], p[1]);
            }
            "softmax_grad" => {
                // label, 5×(lm, ls), 5×(dm, ds), lp
                let label = p[0] as usize;
                let logits: Vec<LnsValue> =
                    (0..5).map(|j| val(p[1 + 2 * j], p[2 + 2 * j])).collect();
                let want: Vec<LnsValue> =
                    (0..5).map(|j| val(p[11 + 2 * j], p[12 + 2 * j])).collect();
                let want_lp = p[21];
                let mut grad = vec![LnsValue::ZERO; 5];
                let log2p = sys.log_softmax_ce_grad(&logits, label, &mut grad);
                let lp_units = sys.config().to_units(log2p);
                assert_eq!(lp_units, want_lp, "{cname} softmax_grad log2p");
                for j in 0..5 {
                    assert_eq!(grad[j].m, want[j].m, "{cname} softmax_grad δ[{j}] m");
                    if !grad[j].is_zero() {
                        assert_eq!(grad[j].s, want[j].s, "{cname} softmax_grad δ[{j}] s");
                    }
                }
            }
            other => panic!("unknown golden op {other}"),
        }
        *counts.entry(op.to_string()).or_default() += 1;
    }
    for op in ["mul", "add", "sub", "llrelu", "softmax_logit", "softmax_grad"] {
        assert!(
            counts.get(op).copied().unwrap_or(0) > 0,
            "golden corpus missing op {op}"
        );
    }
    eprintln!("golden op counts: {counts:?}");
}

#[test]
fn exact_delta_mode_not_in_golden_but_consistent() {
    // The Exact mode has no Python twin (it's a Rust-side ablation); probe
    // that it brackets the LUT mode sensibly so ablation results are
    // interpretable.
    let lut = LnsSystem::new(LnsConfig::w16_lut());
    let exact = LnsSystem::new(LnsConfig {
        delta: DeltaMode::Exact,
        softmax_delta: DeltaMode::Exact,
        ..LnsConfig::w16_lut()
    });
    for (a, b) in [(1.5, 2.5), (0.3, -0.7), (-4.0, -1.0)] {
        let (xa, xb) = (lut.encode_f64(a), lut.encode_f64(b));
        let l = lut.decode_f64(lut.add(xa, xb));
        let e = exact.decode_f64(exact.add(xa, xb));
        assert!(
            (l - e).abs() <= (a + b).abs() * 0.15 + 0.05,
            "LUT {l} vs exact {e} for {a}+{b}"
        );
    }
    let _ = ZERO_M; // keep import used
}
