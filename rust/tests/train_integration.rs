//! Integration tests over the full native training stack: dataset →
//! encode → train → evaluate, across number systems, plus property tests
//! on the arithmetic invariants (proptest-style via `proptest_util`).

use lnsdnn::data::{stripes_dataset, synth_dataset, StripeSpec, SynthSpec};
use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{DeltaMode, LnsConfig, LnsSystem, LnsValue};
use lnsdnn::nn::{Cnn, CnnArch, InitScheme, PoolKind, SgdConfig};
use lnsdnn::proptest_util::{run_prop, DEFAULT_CASES};
use lnsdnn::tensor::{Backend, FixedBackend, FloatBackend, LnsBackend, Tensor};
use lnsdnn::train::{train, train_cnn, CnnTrainConfig, ShardConfig, TrainConfig};

fn tiny_ds(seed: u64) -> lnsdnn::data::Dataset {
    synth_dataset(&SynthSpec {
        name: "tiny".into(),
        classes: 4,
        train_per_class: 50,
        test_per_class: 12,
        strokes: 4,
        jitter_px: 1.5,
        jitter_rot: 0.15,
        noise: 0.05,
        seed,
    })
}

fn cfg(classes: usize) -> TrainConfig {
    TrainConfig {
        dims: vec![784, 24, classes],
        epochs: 8,
        batch_size: 5,
        sgd: SgdConfig { lr: 0.02, weight_decay: 1e-4 },
        val_ratio: 5,
        init: InitScheme::HeNormal,
        seed: 11,
        shard: ShardConfig::default(),
        precision: lnsdnn::precision::PrecisionMap::uniform(),
    }
}

/// Paper's central claim, miniaturized: 16-bit LNS training lands within
/// a small gap of float, and the orderings float ≥ log16-lut ≥ log16-bs
/// and log16 ≥ log12 hold (up to small-task noise).
#[test]
fn accuracy_ordering_matches_paper_shape() {
    let ds = tiny_ds(3);
    let c = cfg(4);
    let float = train(&FloatBackend::default(), &ds, &c).test.accuracy;
    let log16 = train(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01), &ds, &c)
        .test
        .accuracy;
    let log12 = train(&LnsBackend::new(LnsSystem::new(LnsConfig::w12_lut()), 0.01), &ds, &c)
        .test
        .accuracy;
    let bs16 = train(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01), &ds, &c)
        .test
        .accuracy;
    eprintln!("float={float:.3} log16={log16:.3} log12={log12:.3} bs16={bs16:.3}");
    assert!(float > 0.7, "float must learn: {float}");
    assert!(log16 > float - 0.10, "16-bit LUT within ~paper gap: {log16} vs {float}");
    assert!(log12 > float - 0.30, "12-bit learns, degraded: {log12}");
    assert!(bs16 > float - 0.20, "bit-shift learns: {bs16}");
}

#[test]
fn fixed_baselines_learn() {
    let ds = tiny_ds(4);
    let c = cfg(4);
    let f16 = train(&FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01), &ds, &c)
        .test
        .accuracy;
    let f12 = train(&FixedBackend::new(FixedSystem::new(FixedConfig::w12()), 0.01), &ds, &c)
        .test
        .accuracy;
    eprintln!("lin16={f16:.3} lin12={f12:.3}");
    assert!(f16 > 0.6, "lin16: {f16}");
    assert!(f12 > 0.35, "lin12 learns at all: {f12}");
}

#[test]
fn exact_delta_ablation_at_least_as_good_as_lut() {
    let ds = tiny_ds(5);
    let c = cfg(4);
    let lut = train(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01), &ds, &c)
        .test
        .accuracy;
    let exact_cfg = LnsConfig {
        delta: DeltaMode::Exact,
        softmax_delta: DeltaMode::Exact,
        ..LnsConfig::w16_lut()
    };
    let exact = train(&LnsBackend::new(LnsSystem::new(exact_cfg), 0.01), &ds, &c).test.accuracy;
    eprintln!("lut={lut:.3} exact={exact:.3}");
    assert!(exact > lut - 0.08, "exact Δ shouldn't be (much) worse: {exact} vs {lut}");
}

// ---------------------------------------------------------------------
// Conv workload: gradient oracle + the paper-shaped accuracy claim
// ---------------------------------------------------------------------

/// Float-backend gradient oracle for the conv subsystem, mirroring the
/// MLP oracle: finite differences of the CE loss against the manual
/// backprop, through conv → pool → conv → pool → dense → dense. Average
/// pooling keeps the loss smooth everywhere the llReLU is (max pooling's
/// routing is pinned exactly by its own unit tests in `nn::conv`).
#[test]
fn cnn_gradcheck_float() {
    let b = FloatBackend::default();
    let mut rng = lnsdnn::rng::SplitMix64::new(17);
    let arch = CnnArch {
        c1: 3,
        c2: 4,
        k: 3,
        pad: 1,
        hidden: 10,
        pool_kind: PoolKind::Avg,
        ..CnnArch::lenet(8, 3)
    };
    let mut cnn = Cnn::init(&b, &arch, InitScheme::HeNormal, &mut rng);
    let x = Tensor::from_vec(
        4,
        arch.input_len(),
        (0..4 * arch.input_len()).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
    );
    let labels = vec![0usize, 2, 1, 2];

    // One mutable handle per perturbation, in the Gradients layer order
    // (conv1, conv2, fc1, fc2).
    fn layer_w(cnn: &mut Cnn<f32>, l: usize) -> &mut [f32] {
        match l {
            0 => &mut cnn.conv1.w.data,
            1 => &mut cnn.conv2.w.data,
            2 => &mut cnn.fc1.w.data,
            _ => &mut cnn.fc2.w.data,
        }
    }
    fn layer_b(cnn: &mut Cnn<f32>, l: usize) -> &mut [f32] {
        match l {
            0 => &mut cnn.conv1.b,
            1 => &mut cnn.conv2.b,
            2 => &mut cnn.fc1.b,
            _ => &mut cnn.fc2.b,
        }
    }

    let loss_of = |m: &Cnn<f32>| -> f64 { m.backprop(&b, &x, &labels).1.loss };
    let (grads, _) = cnn.backprop(&b, &x, &labels);
    let eps = 1e-3f32;

    // A scatter of weight and bias coords in all four layers.
    let w_coords = [(0usize, 5usize), (0, 20), (1, 3), (1, 77), (2, 11), (2, 100), (3, 0), (3, 25)];
    let b_coords = [(0usize, 1usize), (1, 2), (2, 4), (3, 1)];
    for (weights, coords) in [(true, &w_coords[..]), (false, &b_coords[..])] {
        for &(l, idx) in coords {
            let select = if weights { layer_w } else { layer_b };
            let orig = select(&mut cnn, l)[idx];
            select(&mut cnn, l)[idx] = orig + eps;
            let lp = loss_of(&cnn);
            select(&mut cnn, l)[idx] = orig - eps;
            let lm = loss_of(&cnn);
            select(&mut cnn, l)[idx] = orig;
            let num = (lp - lm) / (2.0 * eps as f64);
            let ana = f64::from(if weights { grads.dw[l].data[idx] } else { grads.db[l][idx] });
            assert!(
                (num - ana).abs() < 1e-2 * (1.0 + num.abs()),
                "{} layer {l} idx {idx}: numeric {num} vs analytic {ana}",
                if weights { "weight" } else { "bias" }
            );
        }
    }
}

/// The acceptance claim for the conv workload: the CNN learns the
/// oriented-stripes task on both the float and the 16-bit LNS-LUT
/// backends, with the LNS final accuracy within 2% of the float baseline.
#[test]
fn cnn_stripes_float_and_lns_within_two_percent() {
    let ds = stripes_dataset(&StripeSpec {
        train_per_class: 100,
        test_per_class: 25,
        jitter_rot: 0.08,
        noise: 0.02,
        ..StripeSpec::cnn_default(1.0, 21)
    });
    let mut cfg = CnnTrainConfig::lenet(12, 4);
    cfg.arch.c1 = 4;
    cfg.arch.c2 = 8;
    cfg.arch.hidden = 32;
    cfg.epochs = 6;
    cfg.sgd = SgdConfig { lr: 0.02, weight_decay: 0.0 };
    cfg.seed = 11;
    let float_acc = train_cnn(&FloatBackend::default(), &ds, &cfg).test.accuracy;
    let lns = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let lns_acc = train_cnn(&lns, &ds, &cfg).test.accuracy;
    eprintln!("cnn stripes: float={float_acc:.3} log16-lut={lns_acc:.3}");
    assert!(float_acc > 0.9, "float CNN must learn stripes: {float_acc}");
    assert!(
        lns_acc >= float_acc - 0.02,
        "16-bit LNS CNN within 2% of float: {lns_acc} vs {float_acc}"
    );
}

// ---------------------------------------------------------------------
// Property tests (the paper's §2 algebra, over random valid words)
// ---------------------------------------------------------------------

fn arb_value(rng: &mut lnsdnn::rng::SplitMix64, sys: &LnsSystem) -> LnsValue {
    if rng.next_f64() < 0.08 {
        return LnsValue::ZERO;
    }
    let span = (sys.config().m_max() as i64 - sys.config().m_min() as i64 + 1) as u64;
    LnsValue::new(
        (sys.config().m_min() as i64 + rng.next_below(span) as i64) as i32,
        rng.next_below(2) == 1,
    )
}

#[test]
fn prop_add_commutative_all_configs() {
    for cfg in [
        LnsConfig::w16_lut(),
        LnsConfig::w12_lut(),
        LnsConfig::w16_bitshift(),
        LnsConfig::w12_bitshift(),
    ] {
        let sys = LnsSystem::new(cfg);
        run_prop(
            "⊞ commutative",
            0xC0FFEE ^ cfg.total_bits as u64,
            DEFAULT_CASES,
            |rng| (arb_value(rng, &sys), arb_value(rng, &sys)),
            |&(x, y)| {
                let a = sys.add(x, y);
                let b = sys.add(y, x);
                if a == b || (a.is_zero() && b.is_zero()) {
                    Ok(())
                } else {
                    Err(format!("{a:?} != {b:?}"))
                }
            },
        );
    }
}

#[test]
fn prop_mul_exact_group_laws() {
    let sys = LnsSystem::new(LnsConfig::w16_lut());
    run_prop(
        "⊡ commutative + identity + zero",
        7,
        DEFAULT_CASES,
        |rng| (arb_value(rng, &sys), arb_value(rng, &sys)),
        |&(x, y)| {
            if sys.mul(x, y) != sys.mul(y, x) {
                return Err("⊡ not commutative".into());
            }
            if sys.mul(x, LnsValue::ONE) != x && !x.is_zero() {
                return Err("1 not identity".into());
            }
            if !sys.mul(x, LnsValue::ZERO).is_zero() {
                return Err("0 not annihilating".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_add_monotone_in_magnitude_same_sign() {
    // For positive x, y, z with |y| ≤ |z|: x ⊞ y ≤ x ⊞ z (approximations
    // are monotone — LUT entries and shifts are non-increasing in d).
    let sys = LnsSystem::new(LnsConfig::w16_lut());
    run_prop(
        "⊞ monotone",
        13,
        DEFAULT_CASES,
        |rng| {
            let mut v = [0i32; 3];
            for x in v.iter_mut() {
                *x = (sys.config().m_min() as i64
                    + rng.next_below(
                        (sys.config().m_max() as i64 - sys.config().m_min() as i64) as u64,
                    ) as i64) as i32;
            }
            v
        },
        |&[mx, my, mz]| {
            let (lo, hi) = if my <= mz { (my, mz) } else { (mz, my) };
            let x = LnsValue::new(mx, true);
            let a = sys.add(x, LnsValue::new(lo, true));
            let b = sys.add(x, LnsValue::new(hi, true));
            if a.m <= b.m {
                Ok(())
            } else {
                Err(format!("x⊞lo (m={}) > x⊞hi (m={})", a.m, b.m))
            }
        },
    );
}

#[test]
fn prop_sub_self_is_zero() {
    let sys = LnsSystem::new(LnsConfig::w12_lut());
    run_prop(
        "x ⊟ x = 0",
        17,
        DEFAULT_CASES,
        |rng| arb_value(rng, &sys),
        |&x| {
            if sys.sub(x, x).is_zero() {
                Ok(())
            } else {
                Err(format!("{:?} ⊟ itself = {:?}", x, sys.sub(x, x)))
            }
        },
    );
}

#[test]
fn prop_encode_decode_relative_error_bound() {
    let sys = LnsSystem::new(LnsConfig::w16_lut());
    let tol = (0.5f64 / 1024.0).exp2() - 1.0 + 1e-12;
    run_prop(
        "encode/decode error",
        23,
        DEFAULT_CASES,
        |rng| {
            // Values well inside the representable range: |log2|v|| < 14.
            let e = rng.uniform(-13.9, 13.9);
            let sign = if rng.next_below(2) == 1 { 1.0 } else { -1.0 };
            sign * e.exp2()
        },
        |&v| {
            let dec = sys.decode_f64(sys.encode_f64(v));
            let rel = ((dec - v) / v).abs();
            if rel <= tol {
                Ok(())
            } else {
                Err(format!("rel err {rel} > {tol} for {v}"))
            }
        },
    );
}

#[test]
fn prop_fixed_mul_round_symmetric() {
    let sys = FixedSystem::new(FixedConfig::w16());
    run_prop(
        "Q-format mul sign symmetry",
        29,
        DEFAULT_CASES,
        |rng| {
            (
                (rng.next_below(2 * 32767) as i64 - 32767) as i32,
                (rng.next_below(2 * 32767) as i64 - 32767) as i32,
            )
        },
        |&(a, b)| {
            if sys.mul(-a, b) == -sys.mul(a, b) && sys.mul(a, -b) == -sys.mul(a, b) {
                Ok(())
            } else {
                Err(format!("mul({a},{b}) asymmetric under negation"))
            }
        },
    );
}

/// Backend-level determinism: two identical runs produce identical models.
#[test]
fn lns_training_deterministic() {
    let ds = tiny_ds(9);
    let mut c = cfg(4);
    c.epochs = 2;
    let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let r1 = train(&b, &ds, &c);
    let r2 = train(&b, &ds, &c);
    assert_eq!(r1.model.layers[0].w.data, r2.model.layers[0].w.data);
    assert_eq!(r1.test.accuracy, r2.test.accuracy);
}

/// Failure injection: a dataset whose labels are shuffled noise should
/// train to ~chance and not crash any number system.
#[test]
fn random_labels_degrade_gracefully() {
    let mut ds = tiny_ds(10);
    let mut rng = lnsdnn::rng::SplitMix64::new(99);
    for l in ds.train_labels.iter_mut() {
        *l = rng.next_below(4) as u8;
    }
    let mut c = cfg(4);
    c.epochs = 3;
    let acc = train(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01), &ds, &c)
        .test
        .accuracy;
    assert!(acc < 0.6, "random labels can't be learned: {acc}");
}

#[test]
fn backend_encode_decode_agree_on_grid() {
    // The three backends must agree (to their own precision) on a value
    // grid — guards against systematic scale errors between domains.
    let fb = FloatBackend::default();
    let xb = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
    let lb = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    for i in -40..=40 {
        let v = i as f64 * 0.1;
        let f = fb.decode(fb.encode(v));
        let x = xb.decode(xb.encode(v));
        let l = lb.decode(lb.encode(v));
        assert!((f - v).abs() < 1e-6);
        assert!((x - v).abs() < 5e-4, "fixed at {v}: {x}");
        assert!((l - v).abs() < 2e-3 * v.abs().max(0.05), "lns at {v}: {l}");
    }
}
