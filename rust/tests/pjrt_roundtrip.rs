//! End-to-end L1/L2/L3 composition: load the AOT artifacts through the
//! PJRT runtime and verify they are **bit-exact** against the native Rust
//! LNS engine on identical parameters and inputs.
//!
//! This is the proof that the three layers implement one numeric spec:
//! Pallas kernel (L1) → JAX model (L2) → HLO text → xla/PJRT (runtime) →
//! matches `lnsdnn::nn::Mlp` over `LnsBackend` (L3's native engine).
//!
//! Requires `make artifacts`; tests skip with a notice otherwise.

use lnsdnn::lns::{LnsConfig, LnsSystem, LnsValue, ZERO_M};
use lnsdnn::nn::{Mlp, SgdConfig};
use lnsdnn::nn::mlp::Dense;
use lnsdnn::rng::SplitMix64;
use lnsdnn::runtime::{ArtifactExecutable, ArtifactRegistry, Runtime};
use lnsdnn::tensor::{LnsBackend, Tensor};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {} missing (run `make artifacts`)", dir.display());
        None
    }
}

const DIMS: [usize; 3] = [12, 8, 4];
const BATCH: usize = 3;

/// Random valid LNS planes (m, s) as i32 vectors.
fn random_planes(
    rng: &mut SplitMix64,
    sys: &LnsSystem,
    n: usize,
    zero_frac: f64,
) -> (Vec<i32>, Vec<i32>) {
    let (lo, hi) = (sys.config().m_min() as i64, sys.config().m_max() as i64);
    let mut m = Vec::with_capacity(n);
    let mut s = Vec::with_capacity(n);
    for _ in 0..n {
        if rng.next_f64() < zero_frac {
            m.push(ZERO_M);
            s.push(1);
        } else {
            m.push((lo + rng.next_below((hi - lo + 1) as u64) as i64) as i32);
            s.push(rng.next_below(2) as i32);
        }
    }
    (m, s)
}

/// Build the native MLP from raw planes (the artifact's parameter layout:
/// per layer W(m,s) then b(m,s)).
fn mlp_from_planes(params: &[(Vec<i32>, Vec<i32>)]) -> Mlp<LnsValue> {
    let mut layers = Vec::new();
    for l in 0..DIMS.len() - 1 {
        let (fi, fo) = (DIMS[l], DIMS[l + 1]);
        let (wm, ws) = &params[2 * l];
        let (bm, bs) = &params[2 * l + 1];
        let w: Vec<LnsValue> =
            wm.iter().zip(ws).map(|(&m, &s)| LnsValue::new(m, s == 1)).collect();
        let b: Vec<LnsValue> =
            bm.iter().zip(bs).map(|(&m, &s)| LnsValue::new(m, s == 1)).collect();
        layers.push(Dense { w: Tensor::from_vec(fi, fo, w), b });
    }
    Mlp { dims: DIMS.to_vec(), layers }
}

fn to_lit(m: &[i32], s: &[i32], dims: &[i64]) -> (xla::Literal, xla::Literal) {
    (
        ArtifactExecutable::lit_i32(m, dims).unwrap(),
        ArtifactExecutable::lit_i32(s, dims).unwrap(),
    )
}

struct Setup {
    backend: LnsBackend,
    /// Parameter planes in artifact order: w0, b0, w1, b1 (each (m, s)).
    params: Vec<(Vec<i32>, Vec<i32>)>,
    /// Input planes.
    x: (Vec<i32>, Vec<i32>),
}

fn setup(seed: u64) -> Setup {
    let sys = LnsSystem::new(LnsConfig::w16_lut());
    let mut rng = SplitMix64::new(seed);
    let mut params = Vec::new();
    for l in 0..DIMS.len() - 1 {
        let (fi, fo) = (DIMS[l], DIMS[l + 1]);
        params.push(random_planes(&mut rng, &sys, fi * fo, 0.05));
        params.push(random_planes(&mut rng, &sys, fo, 0.2));
    }
    let x = random_planes(&mut rng, &sys, BATCH * DIMS[0], 0.3);
    Setup { backend: LnsBackend::new(sys, 0.01), params, x }
}

fn param_literals(s: &Setup) -> Vec<xla::Literal> {
    let mut lits = Vec::new();
    for l in 0..DIMS.len() - 1 {
        let (fi, fo) = (DIMS[l] as i64, DIMS[l + 1] as i64);
        let (wm, ws) = &s.params[2 * l];
        let (bm, bs) = &s.params[2 * l + 1];
        let (a, b) = to_lit(wm, ws, &[fi, fo]);
        lits.push(a);
        lits.push(b);
        let (a, b) = to_lit(bm, bs, &[fo]);
        lits.push(a);
        lits.push(b);
    }
    lits
}

fn native_input_tensor(s: &Setup) -> Tensor<LnsValue> {
    let vals: Vec<LnsValue> =
        s.x.0.iter().zip(&s.x.1).map(|(&m, &sg)| LnsValue::new(m, sg == 1)).collect();
    Tensor::from_vec(BATCH, DIMS[0], vals)
}

#[test]
fn forward_artifact_bitexact_vs_native() {
    let Some(dir) = artifacts_dir() else { return };
    let s = setup(42);
    let rt = Runtime::cpu().unwrap();
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    let exe = reg.load(&rt, "lns_fwd_w16_lut_small").unwrap();

    let mut inputs = param_literals(&s);
    let (xm, xs) = to_lit(&s.x.0, &s.x.1, &[BATCH as i64, DIMS[0] as i64]);
    inputs.push(xm);
    inputs.push(xs);
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 2, "fwd artifact returns (m, s)");
    let got_m: Vec<i32> = out[0].to_vec().unwrap();
    let got_s: Vec<i32> = out[1].to_vec().unwrap();

    let mlp = mlp_from_planes(&s.params);
    let logits = mlp.logits(&s.backend, &native_input_tensor(&s));
    assert_eq!(logits.len(), got_m.len());
    for i in 0..got_m.len() {
        let native = logits.data[i];
        assert_eq!(native.m, got_m[i], "logit[{i}] magnitude");
        if !native.is_zero() {
            assert_eq!(native.s as i32, got_s[i], "logit[{i}] sign");
        }
    }
}

#[test]
fn train_step_artifact_bitexact_vs_native() {
    let Some(dir) = artifacts_dir() else { return };
    let s = setup(1234);
    let rt = Runtime::cpu().unwrap();
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    let exe = reg.load(&rt, "lns_train_w16_lut_small").unwrap();

    let labels: Vec<i32> = vec![0, 1, 3];
    let mut inputs = param_literals(&s);
    let (xm, xs) = to_lit(&s.x.0, &s.x.1, &[BATCH as i64, DIMS[0] as i64]);
    inputs.push(xm);
    inputs.push(xs);
    inputs.push(ArtifactExecutable::lit_i32(&labels, &[BATCH as i64]).unwrap());
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 9, "train artifact returns 8 params + log2p");

    // Native: one backprop + SGD step, spec lr/wd (LnsModelSpec defaults).
    let mut mlp = mlp_from_planes(&s.params);
    let x = native_input_tensor(&s);
    let lbl: Vec<usize> = labels.iter().map(|&l| l as usize).collect();
    let (grads, _) = mlp.backprop(&s.backend, &x, &lbl);
    SgdConfig { lr: 0.01, weight_decay: 1e-4 }.apply(&s.backend, &mut mlp, &grads);

    for l in 0..DIMS.len() - 1 {
        let wm: Vec<i32> = out[4 * l].to_vec().unwrap();
        let ws: Vec<i32> = out[4 * l + 1].to_vec().unwrap();
        let bm: Vec<i32> = out[4 * l + 2].to_vec().unwrap();
        let bs: Vec<i32> = out[4 * l + 3].to_vec().unwrap();
        for (i, v) in mlp.layers[l].w.data.iter().enumerate() {
            assert_eq!(v.m, wm[i], "layer {l} w[{i}] m");
            if !v.is_zero() {
                assert_eq!(v.s as i32, ws[i], "layer {l} w[{i}] s");
            }
        }
        for (i, v) in mlp.layers[l].b.iter().enumerate() {
            assert_eq!(v.m, bm[i], "layer {l} b[{i}] m");
            if !v.is_zero() {
                assert_eq!(v.s as i32, bs[i], "layer {l} b[{i}] s");
            }
        }
    }
}

#[test]
fn float_artifacts_compile_and_run() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    let meta = reg.meta("float_fwd_paper").cloned();
    let Some(meta) = meta else {
        eprintln!("SKIP: float artifacts not in bundle");
        return;
    };
    let exe = reg.load(&rt, "float_fwd_paper").unwrap();
    let mut rng = SplitMix64::new(5);
    let mut inputs = Vec::new();
    for l in 0..meta.dims.len() - 1 {
        let (fi, fo) = (meta.dims[l], meta.dims[l + 1]);
        let w: Vec<f32> = (0..fi * fo).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
        inputs.push(ArtifactExecutable::lit_f32(&w, &[fi as i64, fo as i64]).unwrap());
        inputs.push(ArtifactExecutable::lit_f32(&vec![0.0; fo], &[fo as i64]).unwrap());
    }
    let x: Vec<f32> =
        (0..meta.batch * meta.dims[0]).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
    inputs.push(
        ArtifactExecutable::lit_f32(&x, &[meta.batch as i64, meta.dims[0] as i64]).unwrap(),
    );
    let out = exe.run(&inputs).unwrap();
    let logits: Vec<f32> = out[0].to_vec().unwrap();
    assert_eq!(logits.len(), meta.batch * meta.dims[meta.dims.len() - 1]);
    assert!(logits.iter().all(|v| v.is_finite()));
}
