//! Parallel determinism: the rayon row-parallel matmuls must be
//! **bit-identical** to the serial references for every backend.
//!
//! This is the contract that lets the engine switch freely between the
//! serial and parallel paths (and size its thread pool to the machine)
//! without perturbing a single training run: the parallel drivers only
//! partition *output rows* across threads, and each row keeps the
//! documented sequential-over-`k`-ascending reduction — the same order
//! the Pallas kernels use, so cross-language bit-exactness is preserved
//! transitively.
//!
//! Shapes are randomized (including degenerate one-row/one-col cases and
//! shapes straddling the parallel-dispatch threshold) and operands carry
//! random sparsity so the exact-zero skip paths are exercised too.

use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{LnsConfig, LnsSystem};
use lnsdnn::nn::{Conv2d, InitScheme};
use lnsdnn::rng::SplitMix64;
use lnsdnn::tensor::{ops, Backend, ConvShape, FixedBackend, FloatBackend, LnsBackend, Tensor};

/// Random tensor with `zero_frac` exact-zero entries (the zero word is
/// backend-specific, so it goes through `Backend::zero`).
fn random_tensor<B: Backend>(
    b: &B,
    rng: &mut SplitMix64,
    rows: usize,
    cols: usize,
    zero_frac: f64,
) -> Tensor<B::E> {
    let data = (0..rows * cols)
        .map(|_| {
            if rng.next_f64() < zero_frac {
                b.zero()
            } else {
                b.encode(rng.uniform(-4.0, 4.0))
            }
        })
        .collect();
    Tensor::from_vec(rows, cols, data)
}

/// Assert serial ≡ parallel, element-bit-identical, for all three matmul
/// shapes plus the auto-dispatching entry points, over randomized shapes.
fn check_backend<B: Backend>(b: &B, seed: u64) {
    let mut rng = SplitMix64::new(seed);
    // Fixed shapes bracketing the dispatch threshold, then randomized.
    let mut shapes = vec![(1, 1, 1), (1, 7, 5), (2, 3, 4), (33, 48, 40), (5, 784, 100)];
    for _ in 0..8 {
        shapes.push((
            1 + rng.next_below(48) as usize,
            1 + rng.next_below(48) as usize,
            1 + rng.next_below(48) as usize,
        ));
    }
    for (m, k, n) in shapes {
        let zf = rng.next_f64() * 0.4;
        let tag = b.tag();

        // C = A·B over [m,k]·[k,n].
        let a = random_tensor(b, &mut rng, m, k, zf);
        let w = random_tensor(b, &mut rng, k, n, zf);
        let serial = ops::matmul_serial(b, &a, &w);
        let par = ops::matmul_par(b, &a, &w);
        assert!(serial.data == par.data, "{tag}: matmul serial≠parallel at {m}×{k}×{n}");
        let auto = ops::matmul(b, &a, &w);
        assert!(auto.data == serial.data, "{tag}: matmul dispatch diverged at {m}×{k}×{n}");

        // C = A·Bᵀ over [m,k]·[n,k].
        let wt = random_tensor(b, &mut rng, n, k, zf);
        let serial = ops::matmul_bt_serial(b, &a, &wt);
        let par = ops::matmul_bt_par(b, &a, &wt);
        assert!(serial.data == par.data, "{tag}: matmul_bt serial≠parallel at {m}×{k}×{n}");
        let auto = ops::matmul_bt(b, &a, &wt);
        assert!(auto.data == serial.data, "{tag}: matmul_bt dispatch diverged at {m}×{k}×{n}");

        // C = Aᵀ·B over [k,m]·[k,n] (the gradient outer-product shape).
        let at = random_tensor(b, &mut rng, k, m, zf);
        let wn = random_tensor(b, &mut rng, k, n, zf);
        let serial = ops::matmul_at_serial(b, &at, &wn);
        let par = ops::matmul_at_par(b, &at, &wn);
        assert!(serial.data == par.data, "{tag}: matmul_at serial≠parallel at {m}×{k}×{n}");
        let auto = ops::matmul_at(b, &at, &wn);
        assert!(auto.data == serial.data, "{tag}: matmul_at dispatch diverged at {m}×{k}×{n}");
    }
}

#[test]
fn float_parallel_matches_serial() {
    check_backend(&FloatBackend::default(), 0xF10A7);
}

#[test]
fn fixed_parallel_matches_serial() {
    check_backend(&FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01), 0xF16);
    check_backend(&FixedBackend::new(FixedSystem::new(FixedConfig::w12()), 0.01), 0xF12);
}

#[test]
fn lns_lut_parallel_matches_serial() {
    check_backend(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01), 0x106_16);
    check_backend(&LnsBackend::new(LnsSystem::new(LnsConfig::w12_lut()), 0.01), 0x106_12);
}

#[test]
fn lns_bitshift_parallel_matches_serial() {
    check_backend(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01), 0xB5_16);
    check_backend(&LnsBackend::new(LnsSystem::new(LnsConfig::w12_bitshift()), 0.01), 0xB5_12);
}

/// Conv forward/backward must be bit-identical between the serial and
/// rayon engine paths: the lowering only ever touches im2col (pure
/// gather), the matmuls (row-partitioned, order-preserving) and col2im
/// (sample-partitioned, fixed scatter order), so the guarantee is
/// inherited — this pins it per backend, including the auto dispatch.
fn check_conv_backend<B: Backend>(b: &B, seed: u64) {
    let mut rng = SplitMix64::new(seed);
    // Shapes straddling the dispatch thresholds: a tiny map, a padded
    // LeNet-ish layer, and a batch big enough to fan out.
    let cases = [
        (2usize, 6usize, 1usize, 3usize, 3usize, 1usize),
        (5, 12, 2, 4, 5, 2),
        (40, 8, 3, 8, 3, 0),
    ];
    for (batch, side, in_c, out_c, k, pad) in cases {
        let shape = ConvShape::square(in_c, side, k, 1, pad);
        let layer = Conv2d::init(b, shape, out_c, InitScheme::HeNormal, &mut rng);
        let x = random_tensor(b, &mut rng, batch, shape.in_len(), 0.3);
        let tag = b.tag();

        let (cols_s, y_s) = layer.forward_serial(b, &x);
        let (cols_p, y_p) = layer.forward_par(b, &x);
        assert!(cols_s.data == cols_p.data, "{tag}: im2col serial≠parallel at {side}/{k}/{pad}");
        assert!(y_s.data == y_p.data, "{tag}: conv fwd serial≠parallel at {side}/{k}/{pad}");
        let (cols_a, y_a) = layer.forward(b, &x);
        assert!(
            cols_a.data == cols_s.data && y_a.data == y_s.data,
            "{tag}: conv fwd dispatch diverged at {side}/{k}/{pad}"
        );

        let up = random_tensor(b, &mut rng, batch, shape.out_len(out_c), 0.2);
        let (dw_s, db_s, dx_s) = layer.backward_serial(b, &cols_s, &up, true);
        let (dw_p, db_p, dx_p) = layer.backward_par(b, &cols_s, &up, true);
        assert!(dw_s.data == dw_p.data, "{tag}: conv dW serial≠parallel at {side}/{k}/{pad}");
        assert!(db_s == db_p, "{tag}: conv db serial≠parallel at {side}/{k}/{pad}");
        assert!(
            dx_s.unwrap().data == dx_p.unwrap().data,
            "{tag}: col2im serial≠parallel at {side}/{k}/{pad}"
        );
        let (dw_a, db_a, dx_a) = layer.backward(b, &cols_s, &up, true);
        assert!(
            dw_a.data == dw_s.data && db_a == db_s && dx_a.is_some(),
            "{tag}: conv bwd dispatch diverged at {side}/{k}/{pad}"
        );
    }
}

#[test]
fn conv_float_parallel_matches_serial() {
    check_conv_backend(&FloatBackend::default(), 0xC0F107);
}

#[test]
fn conv_fixed_parallel_matches_serial() {
    check_conv_backend(&FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01), 0xC0F16);
    check_conv_backend(&FixedBackend::new(FixedSystem::new(FixedConfig::w12()), 0.01), 0xC0F12);
}

#[test]
fn conv_lns_lut_parallel_matches_serial() {
    check_conv_backend(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01), 0xC0_1616);
    check_conv_backend(&LnsBackend::new(LnsSystem::new(LnsConfig::w12_lut()), 0.01), 0xC0_1612);
}

#[test]
fn conv_lns_bitshift_parallel_matches_serial() {
    check_conv_backend(&LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01), 0xC0B516);
    check_conv_backend(&LnsBackend::new(LnsSystem::new(LnsConfig::w12_bitshift()), 0.01), 0xC0B512);
}

/// End-to-end CNN determinism with the parallel engine active: two
/// identical training runs produce bit-identical models.
#[test]
fn cnn_training_bitexact_across_runs() {
    use lnsdnn::data::{stripes_dataset, StripeSpec};
    use lnsdnn::train::{train_cnn, CnnTrainConfig};

    let ds = stripes_dataset(&StripeSpec {
        train_per_class: 15,
        test_per_class: 5,
        ..StripeSpec::cnn_default(1.0, 31)
    });
    let mut cfg = CnnTrainConfig::lenet(12, 4);
    cfg.arch.c1 = 3;
    cfg.arch.c2 = 6;
    cfg.arch.hidden = 16;
    cfg.epochs = 2;
    cfg.seed = 7;
    let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let r1 = train_cnn(&b, &ds, &cfg);
    let r2 = train_cnn(&b, &ds, &cfg);
    assert_eq!(r1.model.conv1.w.data, r2.model.conv1.w.data);
    assert_eq!(r1.model.conv2.w.data, r2.model.conv2.w.data);
    assert_eq!(r1.model.fc1.w.data, r2.model.fc1.w.data);
    assert_eq!(r1.model.fc2.b, r2.model.fc2.b);
    assert_eq!(r1.test.accuracy, r2.test.accuracy);
    assert_eq!(r1.test.loss, r2.test.loss);
}

/// The elementwise/broadcast ops must also be invariant under the
/// parallel dispatch (they are order-free per element, but this pins it).
#[test]
fn elementwise_ops_invariant_under_size() {
    let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let mut rng = SplitMix64::new(0xE1E);
    // Large enough that leaky_relu/scale take the parallel path.
    let x = random_tensor(&b, &mut rng, 300, 200, 0.2);
    let y = ops::leaky_relu(&b, &x);
    // Reference: scalar map in plain iteration order.
    let want: Vec<_> = x.data.iter().map(|&v| b.leaky_relu(v)).collect();
    assert!(y.data == want, "parallel leaky_relu diverged from scalar map");

    let up = random_tensor(&b, &mut rng, 300, 200, 0.2);
    let g = ops::leaky_relu_bwd(&b, &x, &up);
    let want: Vec<_> =
        x.data.iter().zip(&up.data).map(|(&p, &u)| b.leaky_relu_bwd(p, u)).collect();
    assert!(g.data == want, "parallel leaky_relu_bwd diverged from scalar map");

    let mut s = x.clone();
    ops::scale(&b, &mut s, 0.125);
    let ce = b.encode(0.125);
    let want: Vec<_> = x.data.iter().map(|&v| b.mul(v, ce)).collect();
    assert!(s.data == want, "parallel scale diverged from scalar map");
}

/// End-to-end determinism across the whole training stack with the
/// parallel engine active: two identical runs must produce bit-identical
/// models (this subsumes per-op determinism under rayon's nondeterministic
/// scheduling).
#[test]
fn training_bitexact_across_runs_with_parallel_engine() {
    use lnsdnn::data::{synth_dataset, SynthSpec};
    use lnsdnn::nn::{InitScheme, SgdConfig};
    use lnsdnn::train::{train, TrainConfig};

    let ds = synth_dataset(&SynthSpec {
        name: "det".into(),
        classes: 3,
        train_per_class: 40,
        test_per_class: 10,
        strokes: 4,
        jitter_px: 1.5,
        jitter_rot: 0.15,
        noise: 0.04,
        seed: 31,
    });
    let cfg = TrainConfig {
        dims: vec![784, 16, 3],
        epochs: 2,
        batch_size: 5,
        sgd: SgdConfig { lr: 0.02, weight_decay: 1e-4 },
        val_ratio: 5,
        init: InitScheme::HeNormal,
        seed: 7,
        shard: Default::default(),
        precision: Default::default(),
    };
    let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let r1 = train(&b, &ds, &cfg);
    let r2 = train(&b, &ds, &cfg);
    for l in 0..r1.model.layers.len() {
        assert_eq!(r1.model.layers[l].w.data, r2.model.layers[l].w.data, "layer {l} weights");
        assert_eq!(r1.model.layers[l].b, r2.model.layers[l].b, "layer {l} biases");
    }
    assert_eq!(r1.test.accuracy, r2.test.accuracy);
    assert_eq!(r1.test.loss, r2.test.loss);
}
