//! Bit-exactness pins for the branchless lane kernels (NUMERICS.md §2,
//! "lane-batched ⊞").
//!
//! The lane kernels in `lns::lanes` (and the fixed-point twins in
//! `fixed`) batch *independent output elements* into fixed-width arrays
//! so LLVM can autovectorize, but every single element's reduction chain
//! must stay exactly the scalar k-ascending fold — same Δ lookups, same
//! clamps, same canonical-zero handling, same bits. These tests compare
//! the lane paths against the retained `*_scalar` twins and against
//! hand-written scalar folds, on every backend, across:
//!
//! * tail lengths (`len % LANES ∈ {0, 1, LANES−1}`),
//! * both Δ± approximations (LUT and BitShift) at both word widths,
//! * saturation boundaries (`m_max`/`m_min` words, clamping products),
//! * exact cancellation (opposite signs, equal magnitudes → canonical
//!   zero), and zero words in every operand position,
//! * the process-global lane toggle through the public matmul entry
//!   points (both settings must agree — the toggle may only move time).
//!
//! CI runs this file in release mode too: autovectorized codegen is
//! exactly what the contract is about.

use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{lanes, LnsConfig, LnsSystem, LnsValue, LANES};
use lnsdnn::rng::SplitMix64;
use lnsdnn::tensor::{ops, LnsBackend, Tensor};

/// The four LNS systems under contract: LUT and BitShift Δ at 16 and 12
/// bits.
fn systems() -> Vec<(&'static str, LnsSystem)> {
    vec![
        ("w16_lut", LnsSystem::new(LnsConfig::w16_lut())),
        ("w12_lut", LnsSystem::new(LnsConfig::w12_lut())),
        ("w16_bs", LnsSystem::new(LnsConfig::w16_bitshift())),
        ("w12_bs", LnsSystem::new(LnsConfig::w12_bitshift())),
    ]
}

/// Lengths that exercise full lanes plus every interesting remainder.
fn lens() -> Vec<usize> {
    vec![LANES * 2, LANES * 2 + 1, LANES * 3 - 1, 1, LANES - 1, 0]
}

/// Adversarial value mix: ~15 % exact zeros, ~10 % `m_max`/`m_min`
/// boundary words (both signs), rest ordinary encoded values.
fn arb_vals(sys: &LnsSystem, rng: &mut SplitMix64, n: usize) -> Vec<LnsValue> {
    let (m_min, m_max) = (sys.config().m_min(), sys.config().m_max());
    (0..n)
        .map(|_| match rng.next_u64() % 20 {
            0..=2 => LnsValue::ZERO,
            3 => LnsValue { m: m_max, s: rng.next_u64() % 2 == 0 },
            4 => LnsValue { m: m_min, s: rng.next_u64() % 2 == 0 },
            _ => sys.encode_f64(rng.uniform(-16.0, 16.0)),
        })
        .collect()
}

#[test]
fn mac_row_matches_scalar_twin_all_tails() {
    for (name, sys) in systems() {
        let mut rng = SplitMix64::new(0x61);
        for len in lens() {
            for trial in 0..30 {
                let acc0 = arb_vals(&sys, &mut rng, len);
                let w = arb_vals(&sys, &mut rng, len);
                let a = arb_vals(&sys, &mut rng, 1)[0];
                let mut lane = acc0.clone();
                sys.mac_row(&mut lane, a, &w);
                let mut scalar = acc0.clone();
                sys.mac_row_scalar(&mut scalar, a, &w);
                assert_eq!(lane, scalar, "{name} len={len} trial={trial}");
                // And against the definitional per-element fold.
                let fold: Vec<LnsValue> =
                    acc0.iter().zip(&w).map(|(&o, &wv)| sys.mac(o, a, wv)).collect();
                assert_eq!(lane, fold, "{name} len={len} trial={trial} (fold)");
            }
        }
    }
}

#[test]
fn mac_panel_matches_scalar_twin_and_row_fold() {
    for (name, sys) in systems() {
        let mut rng = SplitMix64::new(0x62);
        for nc in [LANES, LANES + 1, 2 * LANES - 1, 3] {
            let depth = 5;
            let a = arb_vals(&sys, &mut rng, depth);
            let panel = arb_vals(&sys, &mut rng, depth * nc);
            let acc0 = arb_vals(&sys, &mut rng, nc);
            let mut lane = acc0.clone();
            sys.mac_panel(&mut lane, &a, &panel);
            let mut scalar = acc0.clone();
            sys.mac_panel_scalar(&mut scalar, &a, &panel);
            assert_eq!(lane, scalar, "{name} nc={nc}");
            let mut fold = acc0.clone();
            for (p, &av) in a.iter().enumerate() {
                sys.mac_row_scalar(&mut fold, av, &panel[p * nc..(p + 1) * nc]);
            }
            assert_eq!(lane, fold, "{name} nc={nc} (row fold)");
        }
    }
}

#[test]
fn dot_acc_matches_scalar_twin_and_mac_fold() {
    for (name, sys) in systems() {
        let mut rng = SplitMix64::new(0x63);
        for len in lens() {
            let a = arb_vals(&sys, &mut rng, len);
            let w = arb_vals(&sys, &mut rng, len);
            for acc0 in [LnsValue::ZERO, arb_vals(&sys, &mut rng, 1)[0]] {
                let lane = sys.dot_acc(acc0, &a, &w);
                let scalar = sys.dot_acc_scalar(acc0, &a, &w);
                assert_eq!(lane, scalar, "{name} len={len}");
                let mut fold = acc0;
                for (&av, &wv) in a.iter().zip(&w) {
                    fold = sys.mac(fold, av, wv);
                }
                assert_eq!(lane, fold, "{name} len={len} (mac fold)");
            }
        }
    }
}

#[test]
fn add_slice_matches_scalar_twin() {
    for (name, sys) in systems() {
        let mut rng = SplitMix64::new(0x64);
        for len in lens() {
            let acc0 = arb_vals(&sys, &mut rng, len);
            let x = arb_vals(&sys, &mut rng, len);
            let mut lane = acc0.clone();
            sys.add_slice(&mut lane, &x);
            let mut scalar = acc0.clone();
            sys.add_slice_scalar(&mut scalar, &x);
            assert_eq!(lane, scalar, "{name} len={len}");
            let fold: Vec<LnsValue> = acc0.iter().zip(&x).map(|(&o, &y)| sys.add(o, y)).collect();
            assert_eq!(lane, fold, "{name} len={len} (add fold)");
        }
    }
}

#[test]
fn exact_cancellation_yields_canonical_zero_in_lanes() {
    for (name, sys) in systems() {
        let mut rng = SplitMix64::new(0x65);
        let len = 2 * LANES + 3;
        // acc ⊞ (-acc): every lane (and the tail) must produce the one
        // canonical zero word, not merely "some zero".
        let acc0: Vec<LnsValue> = arb_vals(&sys, &mut rng, len);
        let x: Vec<LnsValue> = acc0.iter().map(|v| v.neg()).collect();
        let mut lane = acc0.clone();
        sys.add_slice(&mut lane, &x);
        for (j, v) in lane.iter().enumerate() {
            if !acc0[j].is_zero() {
                assert_eq!(*v, LnsValue::ZERO, "{name} j={j}");
            }
        }
        // Same through mac_row: acc[j] = -(a ⊡ w[j]).
        let w = arb_vals(&sys, &mut rng, len);
        let a = sys.encode_f64(1.7);
        let acc0: Vec<LnsValue> = w.iter().map(|&wv| sys.mul(a, wv).neg()).collect();
        let mut lane = acc0.clone();
        sys.mac_row(&mut lane, a, &w);
        let mut scalar = acc0.clone();
        sys.mac_row_scalar(&mut scalar, a, &w);
        assert_eq!(lane, scalar, "{name} (cancel mac_row)");
        for (j, v) in lane.iter().enumerate() {
            if !w[j].is_zero() {
                assert_eq!(*v, LnsValue::ZERO, "{name} j={j} (cancel mac_row)");
            }
        }
    }
}

#[test]
fn saturated_operands_stay_bit_identical() {
    for (name, sys) in systems() {
        let (m_min, m_max) = (sys.config().m_min(), sys.config().m_max());
        // Every combination of boundary words in acc/a/w, both signs.
        let edge = [
            LnsValue { m: m_max, s: true },
            LnsValue { m: m_max, s: false },
            LnsValue { m: m_min, s: true },
            LnsValue { m: m_min, s: false },
            LnsValue::ZERO,
            LnsValue::ONE,
        ];
        let len = edge.len() * edge.len(); // 36 = 4·8+4: lanes + tail
        let accs: Vec<LnsValue> = (0..len).map(|i| edge[i / edge.len()]).collect();
        let ws: Vec<LnsValue> = (0..len).map(|i| edge[i % edge.len()]).collect();
        for a in edge {
            let mut lane = accs.clone();
            sys.mac_row(&mut lane, a, &ws);
            let mut scalar = accs.clone();
            sys.mac_row_scalar(&mut scalar, a, &ws);
            assert_eq!(lane, scalar, "{name} a={a:?}");
            assert_eq!(
                sys.dot_acc(LnsValue::ONE, &accs, &ws),
                sys.dot_acc_scalar(LnsValue::ONE, &accs, &ws),
                "{name} a={a:?} (dot)"
            );
        }
    }
}

#[test]
fn fixed_point_lane_kernels_match_scalar_macs() {
    for cfg in [FixedConfig::w16(), FixedConfig::w12()] {
        let s = FixedSystem::new(cfg);
        let mc = cfg.max_code();
        let mut rng = SplitMix64::new(0x66);
        for len in lens() {
            let codes = |rng: &mut SplitMix64| -> Vec<i32> {
                (0..len)
                    .map(|_| match rng.next_u64() % 10 {
                        0 => 0,
                        1 => mc,
                        2 => -mc,
                        _ => (rng.next_below(2 * mc as u64 + 1) as i32) - mc,
                    })
                    .collect()
            };
            let acc0 = codes(&mut rng);
            let w = codes(&mut rng);
            for a in [0, 1, -1, mc, -mc, mc / 3] {
                let mut fast = acc0.clone();
                s.mac_row(&mut fast, a, &w);
                let slow: Vec<i32> = acc0.iter().zip(&w).map(|(&o, &wv)| s.mac(o, a, wv)).collect();
                assert_eq!(fast, slow, "fixed{} len={len} a={a}", cfg.total_bits);
            }
            let fast = s.dot_acc(7, &acc0, &w);
            let mut slow = 7;
            for (&av, &wv) in acc0.iter().zip(&w) {
                slow = s.mac(slow, av, wv);
            }
            assert_eq!(fast, slow, "fixed{} len={len} (dot)", cfg.total_bits);
        }
    }
}

#[test]
fn lane_toggle_is_invisible_through_public_matmuls() {
    // The toggle selects which code runs, never what it computes: every
    // matmul entry point must produce the same bits with lanes on and
    // off. (Other tests may flip the global toggle concurrently — that
    // is safe precisely because of the property asserted here.)
    let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01);
    let sys = LnsSystem::new(LnsConfig::w16_bitshift());
    let mut rng = SplitMix64::new(0x67);
    let (m, k, n) = (13, 37, 11); // odd sizes: tails everywhere
    let a = Tensor::from_vec(m, k, arb_vals(&sys, &mut rng, m * k));
    let w = Tensor::from_vec(k, n, arb_vals(&sys, &mut rng, k * n));
    let at = a.transpose();
    let wt = w.transpose();
    let run = || {
        (
            ops::matmul(&b, &a, &w),
            ops::matmul_tiled(&b, &a, &w),
            ops::matmul_bt(&b, &a, &wt),
            ops::matmul_at(&b, &at, &w),
        )
    };
    lanes::set_enabled(true);
    let on = run();
    lanes::set_enabled(false);
    let off = run();
    lanes::set_enabled(true);
    assert_eq!(on.0.data, off.0.data, "matmul");
    assert_eq!(on.1.data, off.1.data, "matmul_tiled");
    assert_eq!(on.2.data, off.2.data, "matmul_bt");
    assert_eq!(on.3.data, off.3.data, "matmul_at");
    // And the dispatch-selected path agrees with the serial reference.
    assert_eq!(on.0.data, ops::matmul_serial(&b, &a, &w).data, "vs serial");
}
