//! Tiled↔serial↔parallel bit-exactness: the cache-tiled matmul kernels
//! must reproduce the serial references **bit for bit** on all four
//! number systems (float, linear fixed point, LNS LUT, LNS bit-shift),
//! for every tiling — including degenerate 1×1×1 tiles and shapes that
//! leave remainders at every tile border — because tiling only re-orders
//! *which* output elements are computed when, never the per-element
//! `k`-ascending ⊞ chain.
//!
//! The second half re-runs the shard-determinism suite's training
//! workloads with the tiled kernels forced on via
//! [`ops::set_matmul_dispatch`]: full MLP and CNN training must produce
//! identical weights, per-epoch losses and test metrics whether the
//! undecorated matmuls take the row engine or the tiled kernels.

use lnsdnn::data::{stripes_dataset, synth_dataset, StripeSpec, SynthSpec};
use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{LnsConfig, LnsSystem};
use lnsdnn::nn::{CnnVariant, Conv2d, InitScheme, SgdConfig};
use lnsdnn::rng::SplitMix64;
use lnsdnn::tensor::ops::{self, MatmulDispatch, Tiling};
use lnsdnn::tensor::{Backend, ConvShape, FixedBackend, FloatBackend, LnsBackend, Tensor};
use lnsdnn::train::{train, train_cnn, CnnTrainConfig, ShardConfig, TrainConfig};
use std::sync::Mutex;

fn float_backend() -> FloatBackend {
    FloatBackend::default()
}

fn fixed_backend() -> FixedBackend {
    FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01)
}

fn lns_lut_backend() -> LnsBackend {
    LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01)
}

fn lns_bs_backend() -> LnsBackend {
    LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01)
}

/// Random encoded matrix with ~10% exact-zero words (the zero-skip path
/// must agree between the row and tiled kernels too).
fn enc_mat<B: Backend>(b: &B, rng: &mut SplitMix64, rows: usize, cols: usize) -> Tensor<B::E> {
    let data = (0..rows * cols)
        .map(|_| {
            let v = if rng.next_f64() < 0.1 { 0.0 } else { rng.uniform(-2.0, 2.0) };
            b.encode(v)
        })
        .collect();
    Tensor::from_vec(rows, cols, data)
}

/// Shapes with remainders at the default and custom tile borders, plus
/// the 1×k / k×1 degenerates.
const SHAPES: [(usize, usize, usize); 6] = [
    (1, 37, 1),
    (7, 1, 5),
    (1, 1, 1),
    (16, 33, 9),
    (33, 129, 65),
    (40, 64, 100),
];

const TILINGS: [Tiling; 4] = [
    Tiling::DEFAULT,
    Tiling { mc: 3, kc: 5, nc: 7 },
    Tiling { mc: 1, kc: 1, nc: 1 },
    Tiling { mc: 64, kc: 256, nc: 128 },
];

fn tiled_matches_serial_and_par<B: Backend>(b: &B, seed: u64) {
    let tag = b.tag();
    let mut rng = SplitMix64::new(seed);
    for (m, k, n) in SHAPES {
        let a = enc_mat(b, &mut rng, m, k);
        let w = enc_mat(b, &mut rng, k, n);
        let want = ops::matmul_serial(b, &a, &w);
        assert_eq!(ops::matmul_par(b, &a, &w).data, want.data, "{tag} par {m}x{k}x{n}");
        let wt = enc_mat(b, &mut rng, n, k); // [n,k] operand for bt
        let want_bt = ops::matmul_bt_serial(b, &a, &wt);
        let at = enc_mat(b, &mut rng, k, m); // [k,m] operand for at
        let want_at = ops::matmul_at_serial(b, &at, &w);
        for t in TILINGS {
            assert_eq!(
                ops::matmul_tiled_with(b, &a, &w, &t).data,
                want.data,
                "{tag} matmul {m}x{k}x{n} {t:?}"
            );
            assert_eq!(
                ops::matmul_bt_tiled_with(b, &a, &wt, &t).data,
                want_bt.data,
                "{tag} matmul_bt {m}x{k}x{n} {t:?}"
            );
            assert_eq!(
                ops::matmul_at_tiled_with(b, &at, &w, &t).data,
                want_at.data,
                "{tag} matmul_at {m}x{k}x{n} {t:?}"
            );
        }
    }
}

#[test]
fn tiled_bit_identical_float() {
    tiled_matches_serial_and_par(&float_backend(), 1);
}

#[test]
fn tiled_bit_identical_fixed16() {
    tiled_matches_serial_and_par(&fixed_backend(), 2);
}

#[test]
fn tiled_bit_identical_lns16_lut() {
    tiled_matches_serial_and_par(&lns_lut_backend(), 3);
}

#[test]
fn tiled_bit_identical_lns16_bitshift() {
    tiled_matches_serial_and_par(&lns_bs_backend(), 4);
}

/// Conv lowering through the forced-tiled path: forward patches/maps and
/// all three backward outputs must match the serial conv exactly.
fn conv_tiled_matches_serial<B: Backend>(b: &B, seed: u64) {
    let tag = b.tag();
    let mut rng = SplitMix64::new(seed);
    // Strided geometry with padding: remainders in every lowered matmul.
    let shape = ConvShape::square(2, 9, 3, 2, 1);
    let layer = Conv2d::init(b, shape, 5, InitScheme::HeNormal, &mut rng);
    let x = enc_mat(b, &mut rng, 6, shape.in_len());
    let (cols_s, y_s) = layer.forward_serial(b, &x);
    let (cols_t, y_t) = layer.forward_tiled(b, &x);
    assert_eq!(cols_s.data, cols_t.data, "{tag}: im2col diverged");
    assert_eq!(y_s.data, y_t.data, "{tag}: conv forward diverged");
    let up = enc_mat(b, &mut rng, 6, shape.out_len(5));
    let (dw_s, db_s, dx_s) = layer.backward_serial(b, &cols_s, &up, true);
    let (dw_t, db_t, dx_t) = layer.backward_tiled(b, &cols_t, &up, true);
    assert_eq!(dw_s.data, dw_t.data, "{tag}: conv dW diverged");
    assert_eq!(db_s, db_t, "{tag}: conv db diverged");
    assert_eq!(dx_s.unwrap().data, dx_t.unwrap().data, "{tag}: conv dX diverged");
}

#[test]
fn conv_tiled_bit_identical_all_backends() {
    conv_tiled_matches_serial(&float_backend(), 11);
    conv_tiled_matches_serial(&fixed_backend(), 12);
    conv_tiled_matches_serial(&lns_lut_backend(), 13);
    conv_tiled_matches_serial(&lns_bs_backend(), 14);
}

// ---------------------------------------------------------------------
// Forced-dispatch runs (global override ⇒ serialized by a lock)
// ---------------------------------------------------------------------

/// The dispatch override is process-global, so the tests that flip it
/// run under one lock and restore `Auto` before releasing. (Everything
/// else in this binary only calls the explicit `*_tiled_with`/`*_serial`
/// entry points, which ignore the override.)
static DISPATCH_LOCK: Mutex<()> = Mutex::new(());

struct DispatchGuard;

impl DispatchGuard {
    fn force(d: MatmulDispatch) {
        ops::set_matmul_dispatch(d);
    }
}

impl Drop for DispatchGuard {
    fn drop(&mut self) {
        ops::set_matmul_dispatch(MatmulDispatch::Auto);
    }
}

#[test]
fn public_entry_points_identical_under_forced_dispatch() {
    let _lock = DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = DispatchGuard;
    let b = lns_lut_backend();
    let mut rng = SplitMix64::new(21);
    let (m, k, n) = (24usize, 80usize, 50usize);
    let a = enc_mat(&b, &mut rng, m, k);
    let w = enc_mat(&b, &mut rng, k, n);
    let wt = enc_mat(&b, &mut rng, n, k);
    let at = enc_mat(&b, &mut rng, k, m);
    let want = ops::matmul_serial(&b, &a, &w).data;
    let want_bt = ops::matmul_bt_serial(&b, &a, &wt).data;
    let want_at = ops::matmul_at_serial(&b, &at, &w).data;
    for d in [MatmulDispatch::ForceRow, MatmulDispatch::ForceTiled, MatmulDispatch::Auto] {
        DispatchGuard::force(d);
        assert_eq!(ops::matmul(&b, &a, &w).data, want, "matmul under {d:?}");
        assert_eq!(ops::matmul_bt(&b, &a, &wt).data, want_bt, "matmul_bt under {d:?}");
        assert_eq!(ops::matmul_at(&b, &at, &w).data, want_at, "matmul_at under {d:?}");
    }
}

fn mlp_ds() -> lnsdnn::data::Dataset {
    synth_dataset(&SynthSpec {
        name: "tiled-tiny".into(),
        classes: 3,
        train_per_class: 25,
        test_per_class: 8,
        strokes: 4,
        jitter_px: 1.5,
        jitter_rot: 0.15,
        noise: 0.04,
        seed: 41,
    })
}

fn mlp_cfg(n_shards: usize) -> TrainConfig {
    TrainConfig {
        dims: vec![784, 12, 3],
        epochs: 2,
        // 60 train samples, batch 7 ⇒ a partial final batch of 4: the
        // forced-tiled rerun also exercises the sample-weighted epoch
        // loss on a `n % bs != 0` epoch.
        batch_size: 7,
        sgd: SgdConfig { lr: 0.02, weight_decay: 1e-4 },
        val_ratio: 5,
        init: InitScheme::HeNormal,
        seed: 13,
        shard: ShardConfig::with_shards(n_shards),
        precision: lnsdnn::precision::PrecisionMap::uniform(),
    }
}

/// The shard-determinism workload re-run with the tiled kernels forced
/// on: weights, losses and metrics must be bit-identical to the forced
/// row engine, at shard counts 1 and 4.
fn mlp_training_dispatch_invariant<B: Backend>(backend: &B) {
    let ds = mlp_ds();
    let tag = backend.tag();
    DispatchGuard::force(MatmulDispatch::ForceRow);
    let reference = train(backend, &ds, &mlp_cfg(1));
    for shards in [1usize, 4] {
        DispatchGuard::force(MatmulDispatch::ForceTiled);
        let run = train(backend, &ds, &mlp_cfg(shards));
        for l in 0..reference.model.layers.len() {
            assert_eq!(
                reference.model.layers[l].w.data, run.model.layers[l].w.data,
                "{tag}: layer {l} weights diverge (tiled, shards={shards})"
            );
            assert_eq!(
                reference.model.layers[l].b, run.model.layers[l].b,
                "{tag}: layer {l} biases diverge (tiled, shards={shards})"
            );
        }
        for (ea, eb) in reference.curve.iter().zip(&run.curve) {
            assert_eq!(
                ea.train_loss, eb.train_loss,
                "{tag}: epoch loss diverges (tiled, shards={shards})"
            );
            assert_eq!(
                ea.val_accuracy, eb.val_accuracy,
                "{tag}: val accuracy diverges (tiled, shards={shards})"
            );
        }
        assert_eq!(reference.test.accuracy, run.test.accuracy, "{tag}: test accuracy");
        assert_eq!(reference.test.loss, run.test.loss, "{tag}: test loss");
    }
}

#[test]
fn mlp_training_bit_identical_with_tiled_forced_float() {
    let _lock = DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = DispatchGuard;
    mlp_training_dispatch_invariant(&float_backend());
}

#[test]
fn mlp_training_bit_identical_with_tiled_forced_lns16_lut() {
    let _lock = DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = DispatchGuard;
    mlp_training_dispatch_invariant(&lns_lut_backend());
}

#[test]
fn cnn_training_bit_identical_with_tiled_forced() {
    let _lock = DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = DispatchGuard;
    let ds = stripes_dataset(&StripeSpec {
        train_per_class: 12,
        test_per_class: 4,
        ..StripeSpec::cnn_default(1.0, 19)
    });
    let cfg = |shards: usize| {
        let mut cfg = CnnTrainConfig::lenet(12, 4);
        cfg.arch.c1 = 3;
        cfg.arch.c2 = 4;
        cfg.arch.hidden = 16;
        cfg.epochs = 1;
        cfg.batch_size = 7; // 39-sample train split ⇒ partial final batch
        cfg.sgd = SgdConfig { lr: 0.02, weight_decay: 0.0 };
        cfg.seed = 23;
        cfg.shard = ShardConfig::with_shards(shards);
        cfg
    };
    let backend = lns_lut_backend();
    DispatchGuard::force(MatmulDispatch::ForceRow);
    let reference = train_cnn(&backend, &ds, &cfg(1));
    assert_eq!(reference.model.arch.variant, CnnVariant::Pooled);
    for shards in [1usize, 2] {
        DispatchGuard::force(MatmulDispatch::ForceTiled);
        let run = train_cnn(&backend, &ds, &cfg(shards));
        assert_eq!(reference.model.conv1.w.data, run.model.conv1.w.data, "conv1 (s={shards})");
        assert_eq!(reference.model.conv2.w.data, run.model.conv2.w.data, "conv2 (s={shards})");
        assert_eq!(reference.model.fc1.w.data, run.model.fc1.w.data, "fc1 (s={shards})");
        assert_eq!(reference.model.fc2.w.data, run.model.fc2.w.data, "fc2 (s={shards})");
        assert_eq!(reference.model.fc2.b, run.model.fc2.b, "head bias (s={shards})");
        for (ea, eb) in reference.curve.iter().zip(&run.curve) {
            assert_eq!(ea.train_loss, eb.train_loss, "CNN epoch loss (s={shards})");
        }
        assert_eq!(reference.test.accuracy, run.test.accuracy, "CNN test acc (s={shards})");
        assert_eq!(reference.test.loss, run.test.loss, "CNN test loss (s={shards})");
    }
}
